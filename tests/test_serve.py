"""Serving engine tests: batched generate, scoring, quantized weights."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TINY
from repro.core.quant.deploy import quantize_params_for_serving
from repro.models.transformer import init_lm, lm_forward
from repro.serve.engine import ServeEngine
from repro.serve.sampling import sample

CFG = TINY.replace(n_repeats=2, d_model=64, head_dim=16, d_ff=128)


def test_generate_shapes_and_determinism():
    params = init_lm(CFG, jax.random.PRNGKey(0))
    eng = ServeEngine(CFG, params)
    prompts = np.random.default_rng(0).integers(0, CFG.vocab_size, (4, 8))
    r1 = eng.generate(prompts, max_new=8, temperature=0.0)
    r2 = eng.generate(prompts, max_new=8, temperature=0.0)
    assert r1.tokens.shape == (4, 8)
    assert np.array_equal(r1.tokens, r2.tokens)  # greedy deterministic


def test_generate_matches_forward_greedy():
    """first generated token == argmax of teacher-forced logits."""
    params = init_lm(CFG, jax.random.PRNGKey(0))
    eng = ServeEngine(CFG, params)
    prompts = np.random.default_rng(1).integers(0, CFG.vocab_size, (2, 12))
    res = eng.generate(prompts, max_new=4, temperature=0.0)
    logits, _ = lm_forward(CFG, params, jnp.asarray(prompts, jnp.int32))
    expect = np.asarray(jnp.argmax(logits[:, -1, :], -1))
    assert np.array_equal(res.tokens[:, 0], expect)


def test_quantized_serving_runs():
    cfg = CFG.replace(serve_quant_bits=4, serve_quant_group=32)
    params = init_lm(cfg, jax.random.PRNGKey(0))
    qparams = quantize_params_for_serving(cfg, params)
    eng = ServeEngine(cfg, qparams)
    prompts = np.random.default_rng(2).integers(0, cfg.vocab_size, (2, 8))
    res = eng.generate(prompts, max_new=4, temperature=0.0)
    assert res.tokens.shape == (2, 4)
    # close to the float engine on the first step (W4 is mild)
    eng_f = ServeEngine(cfg, params)
    res_f = eng_f.generate(prompts, max_new=4, temperature=0.0)
    assert res.tokens.shape == res_f.tokens.shape


def test_score():
    params = init_lm(CFG, jax.random.PRNGKey(0))
    eng = ServeEngine(CFG, params)
    toks = np.random.default_rng(3).integers(0, CFG.vocab_size, (2, 10))
    ll = eng.score(toks)
    assert ll.shape == (2, 9)
    assert np.all(ll <= 0.0)


def test_sampling_topk_temperature():
    logits = jnp.asarray([[0.0, 5.0, 4.0, -2.0]])
    t0 = sample(logits, jax.random.PRNGKey(0), temperature=0.0)
    assert int(t0[0]) == 1
    for seed in range(10):
        tk = sample(logits, jax.random.PRNGKey(seed), temperature=1.0,
                    top_k=2)
        assert int(tk[0]) in (1, 2)


def test_int8_kv_cache_decode_close_to_float():
    """beyond-paper: int8 KV cache (~2x capacity) stays decode-accurate."""
    import jax
    import jax.numpy as jnp

    from repro.models.transformer import (init_cache, init_lm, lm_decode,
                                          lm_forward, lm_prefill)

    cfg0 = CFG
    params = init_lm(cfg0, jax.random.PRNGKey(0))
    b, s = 2, 24
    tokens = np.random.default_rng(5).integers(0, cfg0.vocab_size, (b, s))
    tokens = jnp.asarray(tokens, jnp.int32)
    logits, _ = lm_forward(cfg0, params, tokens)

    cfg = cfg0.replace(kv_cache_bits=8)
    cache = init_cache(cfg, b, 64)
    assert cache["stack"]["p0"]["attn"]["k"].dtype == jnp.int8
    lg, cache = lm_prefill(cfg, params, tokens[:, :s - 1], cache)
    lg, _ = lm_decode(cfg, params, tokens[:, s - 1:], cache,
                      jnp.full((b, 1), s - 1, jnp.int32))
    assert float(jnp.max(jnp.abs(lg - logits[:, s - 1]))) < 0.05
    assert bool(jnp.all(jnp.argmax(lg, -1) == jnp.argmax(logits[:, s - 1], -1)))
