"""Data pipeline + synthetic corpus tests."""
import numpy as np

from repro.data.pipeline import DataPipeline
from repro.data.synthetic import make_corpus, make_eval_sets


def test_corpus_language_structure():
    tokens, meta = make_corpus(256, 50_000, n_languages=4, seed=0)
    assert tokens.min() >= 4  # specials reserved
    # corpus share skewed toward language 0
    counts = []
    for lo, hi in meta.lang_ranges:
        counts.append(((tokens >= lo) & (tokens < hi)).sum())
    counts = np.array(counts, dtype=float) / len(tokens)
    assert counts[0] > 0.4  # dominant language
    assert counts[0] > counts[-1] * 2
    top = meta.top_language_tokens(2)
    lo0, hi0 = meta.lang_ranges[np.argmax(meta.mixture)]
    assert lo0 in top


def test_pipeline_deterministic_and_sharded():
    tokens, _ = make_corpus(256, 50_000, seed=0)
    p_a = DataPipeline(tokens, batch_size=8, seq_len=16, seed=3)
    p_b = DataPipeline(tokens, batch_size=8, seq_len=16, seed=3)
    b1, b2 = p_a.batch_at(7), p_b.batch_at(7)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    # labels shifted by one
    assert np.array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # shards partition the global batch
    shards = [DataPipeline(tokens, batch_size=8, seq_len=16, seed=3,
                           shard_id=i, n_shards=2).batch_at(7)["tokens"]
              for i in range(2)]
    assert np.array_equal(np.concatenate(shards), b1["tokens"])


def test_pipeline_prefetch_thread():
    tokens, _ = make_corpus(256, 20_000, seed=0)
    p = DataPipeline(tokens, batch_size=4, seq_len=16, seed=0)
    p.start(5)
    step, batch = p.next()
    assert step == 5
    step2, _ = p.next()
    assert step2 == 6
    p.stop()
    assert np.array_equal(batch["tokens"], p.batch_at(5)["tokens"])


def test_eval_sets_are_per_language():
    _, meta = make_corpus(256, 20_000, seed=0)
    evals = make_eval_sets(meta, n_tokens=500)
    assert len(evals) == meta.n_languages
    for l, (name, toks) in enumerate(sorted(evals.items())):
        lo, hi = meta.lang_ranges[l]
        assert ((toks >= lo) & (toks < hi)).all()


def test_byte_tokenizer_roundtrip():
    from repro.data.tokenizer import ByteTokenizer, BOS, EOS

    tok = ByteTokenizer()
    for text in ["hello world", "Beijing is the capital of China.", "ü¥ø"]:
        ids = tok.encode(text, bos=True, eos=True)
        assert ids[0] == BOS and ids[-1] == EOS
        assert tok.decode(ids) == text
    assert tok.vocab_size == 260
