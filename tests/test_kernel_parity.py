"""Differential kernel-parity harness.

Every Pallas kernel is run in interpret mode and checked against two
independent oracles per case: the pure-jnp reference in kernels/ref.py and
a plain dequantize-then-einsum. The matrix sweeps bits x group_size x shape
— including M=1 decode rows (skinny-M tile regime), ragged K/N, and
expert-stacked weights — so new kernels and block-dispatch changes cannot
silently diverge from the packed-format math.

Runs identically under REPRO_DEQUANT_IMPL=pallas (CI's interpret-mode
lowering job) and the default ref dispatch: the ops wrappers exercised here
always lower through pallas_call(interpret=True) on CPU.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant.types import (compute_scales, dequantize, pack_layout,
                                    quantize, quantize_activation,
                                    quantize_stacked)
from repro.kernels import ops, ref
from repro.kernels.paged_attention import paged_attention_pallas
from repro.kernels.paged_harness import (build_paged_case, build_prefill_case,
                                         build_verify_case, gather_oracle,
                                         prefill_live_rows, prefill_oracle,
                                         verify_oracle)
from repro.models.attention import _quant_kv
from repro.serve.kvcache import gather_dequant_pages, gather_pages

BITS = [2, 3, 4, 8]
GROUPS = [-1, 32, 64, 128]
# (M, K, N): M=1/3 decode-skinny rows, ragged (non-pow2-tile) K/N mixes
DENSE_SHAPES = [(1, 128, 64), (3, 256, 80), (8, 128, 192)]
# (E, C, K, N): C=5 forces capacity-dim padding inside the wrapper
EXPERT_SHAPES = [(2, 5, 128, 64), (3, 8, 256, 96)]
W8A8_SHAPES = [(1, 128, 96), (7, 256, 64)]


def _key(*salts):
    return jax.random.split(jax.random.PRNGKey(sum(salts) % (2 ** 31)))


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("gs", GROUPS)
@pytest.mark.parametrize("mkn", DENSE_SHAPES)
def test_dense_parity(bits, gs, mkn):
    m, k, n = mkn
    kx, kw = _key(bits, gs, m, k, n)
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32) * 0.1
    qt = quantize(w, bits, gs)
    y_pal = ops.dequant_matmul(x, qt)                  # pallas interpret
    y_ref = ref.dequant_matmul_ref(x, qt.qw, qt.scale, bits=bits,
                                   group_size=gs, k=k)
    y_ein = jnp.einsum("mk,kn->mn", x.astype(jnp.bfloat16),
                       dequantize(qt, jnp.bfloat16),
                       preferred_element_type=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ein),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("gs", GROUPS)
@pytest.mark.parametrize("eckn", EXPERT_SHAPES)
def test_expert_parity(bits, gs, eckn):
    e, c, k, n = eckn
    kx, kw = _key(bits, gs, e, c, k, n)
    x = jax.random.normal(kx, (e, c, k), jnp.float32)
    w = jax.random.normal(kw, (e, k, n), jnp.float32) * 0.1
    qt = quantize_stacked(w, bits, gs)
    y_pal = ops.expert_dequant_matmul(x, qt)           # pallas interpret
    y_ref = ref.expert_dequant_matmul_ref(x, qt.qw, qt.scale, bits=bits,
                                          group_size=gs, k=k)
    y_ein = jnp.einsum("eck,ekn->ecn", x.astype(jnp.bfloat16),
                       dequantize(qt, jnp.bfloat16),
                       preferred_element_type=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ein),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("gs", GROUPS)
@pytest.mark.parametrize("mkn", W8A8_SHAPES)
def test_w8a8_parity(bits, gs, mkn):
    m, k, n = mkn
    kx, kw = _key(bits, gs, m, k, n, 7)
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32) * 0.1
    qt = quantize(w, bits, gs, act_bits=8)
    y_pal = ops.w8a8_matmul(x, qt)                     # pallas interpret
    xq, xs = quantize_activation(x, 8)
    y_ref = ref.w8a8_matmul_ref(xq, qt.qw, qt.scale, bits=bits,
                                group_size=gs, k=k) * xs
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    # the int8-activation path must still track the float-activation
    # dequant matmul (A8 quantization noise only)
    y_f = jnp.einsum("mk,kn->mn", x, dequantize(qt, jnp.float32))
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_f),
                               rtol=5e-2, atol=5e-2 * float(jnp.max(jnp.abs(y_f))))


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("gs", GROUPS)
@pytest.mark.parametrize("eckn", EXPERT_SHAPES)
def test_expert_w8a8_parity(bits, gs, eckn):
    """The expert-stacked W4A8/W8A8 kernel (per-expert int8 x int8 -> int32
    MXU dots) matches the vmapped int32 reference and tracks the
    float-activation expert dequant matmul to A8 quantization noise."""
    e, c, k, n = eckn
    kx, kw = _key(bits, gs, e, c, k, n, 7)
    x = jax.random.normal(kx, (e, c, k), jnp.float32)
    w = jax.random.normal(kw, (e, k, n), jnp.float32) * 0.1
    qt = quantize_stacked(w, bits, gs, act_bits=8)
    y_pal = ops.expert_w8a8_matmul(x, qt)              # pallas interpret
    xq, xs = quantize_activation(x.reshape(e * c, k), 8)
    y_ref = ref.expert_w8a8_matmul_ref(
        xq.reshape(e, c, k), qt.qw, qt.scale, bits=bits, group_size=gs,
        k=k) * xs.reshape(e, c, 1)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    y_f = jnp.einsum("eck,ekn->ecn", x, dequantize(qt, jnp.float32))
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_f),
                               rtol=5e-2, atol=5e-2 * float(jnp.max(jnp.abs(y_f))))


# ---------------------------------------------------------------- dispatch

def test_skinny_decode_blocks_selected():
    """M <= 8 picks the decode tile regime: bm stays at the minimal 8-row
    tile while bn/bk widen (no padding up to prefill tiles)."""
    assert ops._matmul_blocks(1, 128, 256, 256) == (8, 512, 512)
    assert ops._matmul_blocks(8, 128, 256, 256) == (8, 512, 512)
    assert ops._matmul_blocks(9, 128, 256, 256) == (128, 256, 256)
    assert ops._matmul_blocks(128, 128, 256, 256) == (128, 256, 256)


def test_pick_bk_guard():
    """_pick_bk refuses un-tileable (K, group_size) combos instead of
    shrinking to bk=0 (regression: the quantize_pack loop had no guard and
    could spin into a mod-by-zero)."""
    assert ops._pick_bk(768, 3, 2, 256) is None        # gs=3 never tiles
    assert ops._pick_bk(256, 32, 2, 256) == 256
    assert ops._pick_bk(96, 64, 2, 256) is None        # 96/64 interlock
    assert ops._pick_bk(128, 128, 4, 256) == 128
    # halving must not yield a non-divisor of K (K=18 shrinks 18->9->4,
    # and 4 does not divide 18: reject, don't crash downstream)
    assert ops._pick_bk(18, 2, 4, 256) is None


def test_dequant_matmul_odd_k_falls_back():
    """K=18 / W2g2: every candidate block fails a tiling constraint — the
    wrapper must take the ref fallback, not assert inside pallas_call."""
    kx, kw = _key(13)
    x = jax.random.normal(kx, (4, 18), jnp.float32)
    w = jax.random.normal(kw, (18, 16), jnp.float32) * 0.1
    qt = quantize(w, 2, 2)
    y = ops.dequant_matmul(x, qt)
    y_ref = ref.dequant_matmul_ref(x, qt.qw, qt.scale, bits=2, group_size=2,
                                   k=18)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bits", [2, 4])
def test_quantize_pack_adversarial_group_size(bits, monkeypatch):
    """k=768, group_size=3: no valid K tile exists — must fall back to the
    jnp reference, not crash (regression for the unguarded shrink loop)."""
    monkeypatch.setenv("REPRO_DEQUANT_IMPL", "pallas")
    w = jax.random.normal(jax.random.PRNGKey(3), (768, 16)) * 0.2
    s = compute_scales(w, bits, 3)
    packed = ops.quantize_pack(w, s, bits=bits, group_size=3)
    assert np.array_equal(np.asarray(packed),
                          np.asarray(ref.quantize_pack_ref(w, s, bits=bits)))


def test_dequant_matmul_adversarial_group_size():
    """The dense matmul wrapper takes the same graceful fallback."""
    kx, kw = _key(11)
    x = jax.random.normal(kx, (4, 768), jnp.float32)
    w = jax.random.normal(kw, (768, 16), jnp.float32) * 0.1
    qt = quantize(w, 4, 3)
    y = ops.dequant_matmul(x, qt)
    y_ref = ref.dequant_matmul_ref(x, qt.qw, qt.scale, bits=4, group_size=3,
                                   k=768)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------- paged attention

# (S, W, ps, kvh, g, hd, fills, window): M=1 single-slot decode; ragged
# per-slot kv_len with an empty slot, a page-boundary fill (== ps) and a
# full table; GQA group > 1; SWA windows that skip whole pages
PAGED_CASES = [
    (1, 2, 8, 1, 1, 32, (9,), None),
    (4, 4, 8, 2, 3, 32, (0, 1, 8, 32), None),
    (3, 4, 8, 2, 2, 16, (5, 16, 29), 7),
    (2, 6, 16, 1, 4, 64, (33, 96), 20),
]


# pool/block-table builder + gather+einsum oracle are shared with
# benchmarks/paged_attn_bench.py via kernels/paged_harness.py
def _build_paged(seed, s, w, ps, kvh, g, hd, fills, kv_bits):
    return build_paged_case(seed, s, w, ps, kvh, g, hd, fills, kv_bits)


def _gather_oracle(q, pools, bt, kv_len, window):
    return np.asarray(gather_oracle(q, pools, bt, kv_len, window), np.float32)


@pytest.mark.parametrize("kv_bits", [0, 8])
@pytest.mark.parametrize("case", PAGED_CASES)
def test_paged_attention_parity(kv_bits, case):
    s, w, ps, kvh, g, hd, fills, window = case
    q, pools, bt, kv_len = _build_paged(sum(case[:6]) + kv_bits, s, w, ps,
                                        kvh, g, hd, fills, kv_bits)
    out = np.asarray(ops.paged_attention(
        q, pools["k_pool"], pools["v_pool"], bt, kv_len,
        k_scale_pool=pools["k_scale_pool"],
        v_scale_pool=pools["v_scale_pool"], window=window))
    orc = _gather_oracle(q, pools, bt, kv_len, window)
    live = np.asarray(kv_len) > 0
    # the oracle emits garbage for empty slots (softmax over all-masked);
    # the fused kernel defines them as exact zeros
    np.testing.assert_allclose(out[live], orc[live], rtol=2e-2, atol=2e-2)
    assert np.all(out[~live] == 0.0)


@pytest.mark.parametrize("kv_bits", [0, 8])
def test_paged_attention_interpret_matches_ref_exactly(kv_bits):
    """The interpret-mode kernel is bit-comparable with the jnp page-walk
    reference (same walk order, same f32 accumulation) — exact for bf16 KV
    and for int8 KV alike on CPU."""
    s, w, ps, kvh, g, hd, fills, window = PAGED_CASES[2]
    q, pools, bt, kv_len = _build_paged(17 + kv_bits, s, w, ps, kvh, g, hd,
                                        fills, kv_bits)
    qg = q.reshape(s, kvh, g, hd)
    for win in (window, None):
        ker = paged_attention_pallas(
            qg, pools["k_pool"], pools["v_pool"], bt, kv_len,
            pools["k_scale_pool"], pools["v_scale_pool"], window=win,
            tile=ps, interpret=True)
        rr = ref.paged_attention_ref(
            qg, pools["k_pool"], pools["v_pool"], bt, kv_len,
            pools["k_scale_pool"], pools["v_scale_pool"], window=win,
            tile=ps)
        np.testing.assert_array_equal(np.asarray(ker), np.asarray(rr))


def test_paged_attention_subpage_tiles_match_whole_page():
    """Splitting oversized pages into sub-tiles (read-width regime) walks
    the same tokens: tile=ps/2 must match the gather oracle too."""
    s, w, ps, kvh, g, hd, fills, window = PAGED_CASES[1]
    q, pools, bt, kv_len = _build_paged(23, s, w, ps, kvh, g, hd, fills, 8)
    qg = q.reshape(s, kvh, g, hd)
    out = paged_attention_pallas(
        qg, pools["k_pool"], pools["v_pool"], bt, kv_len,
        pools["k_scale_pool"], pools["v_scale_pool"], window=window,
        tile=ps // 2, interpret=True).reshape(s, kvh * g, hd)
    orc = _gather_oracle(q, pools, bt, kv_len, window)
    live = np.asarray(kv_len) > 0
    np.testing.assert_allclose(np.asarray(out)[live], orc[live],
                               rtol=2e-2, atol=2e-2)


def test_paged_tile_regime():
    """Common serving pages ride whole; oversized pages split to <=256."""
    assert ops._paged_tile(8) == 8
    assert ops._paged_tile(16) == 16
    assert ops._paged_tile(256) == 256
    assert ops._paged_tile(512) == 256
    assert ops._paged_tile(1024) == 256


# ------------------------------------------- spec-decode verify read (M>1)

# (S, M, W, ps, kvh, g, hd, fills, window): the small-M verify regime —
# per-slot fills must be 0 (idle) or >= M (the verify tail sits at the top
# of the fill); same empty-slot / page-boundary / GQA / SWA adversaries as
# PAGED_CASES
VERIFY_CASES = [
    (1, 2, 2, 8, 1, 1, 32, (9,), None),
    (4, 3, 4, 8, 2, 3, 32, (0, 3, 8, 32), None),
    (3, 4, 4, 8, 2, 2, 16, (5, 16, 29), 7),
    (2, 5, 6, 16, 1, 4, 64, (33, 96), 20),
]


@pytest.mark.parametrize("kv_bits", [0, 8])
@pytest.mark.parametrize("case", VERIFY_CASES)
def test_paged_attention_verify_parity(kv_bits, case):
    """The fused verify read (M query rows per slot, per-row causal fill
    mask) matches the gathered dense-attention oracle."""
    s, m, w, ps, kvh, g, hd, fills, window = case
    q, pools, bt, kv_len = build_verify_case(
        sum(case[:7]) + kv_bits, s, m, w, ps, kvh, g, hd, fills, kv_bits)
    out = np.asarray(ops.paged_attention_verify(
        q, pools["k_pool"], pools["v_pool"], bt, kv_len,
        k_scale_pool=pools["k_scale_pool"],
        v_scale_pool=pools["v_scale_pool"], window=window))
    orc = np.asarray(verify_oracle(q, pools, bt, kv_len, window), np.float32)
    live = np.asarray(kv_len) > 0
    np.testing.assert_allclose(out[live], orc[live], rtol=2e-2, atol=2e-2)
    assert np.all(out[~live] == 0.0)


@pytest.mark.parametrize("kv_bits", [0, 8])
def test_paged_attention_verify_interpret_matches_ref_exactly(kv_bits):
    """Interpret-mode verify kernel is bit-comparable with the jnp
    reference page walk at M>1, like the decode read at M=1."""
    s, m, w, ps, kvh, g, hd, fills, window = VERIFY_CASES[2]
    q, pools, bt, kv_len = build_verify_case(31 + kv_bits, s, m, w, ps, kvh,
                                             g, hd, fills, kv_bits)
    qg = q.reshape(s, m, kvh, g, hd).transpose(0, 2, 1, 3, 4)
    qg = qg.reshape(s, kvh, m * g, hd)
    for win in (window, None):
        ker = paged_attention_pallas(
            qg, pools["k_pool"], pools["v_pool"], bt, kv_len,
            pools["k_scale_pool"], pools["v_scale_pool"], window=win,
            tile=ps, m_rows=m, interpret=True)
        rr = ref.paged_attention_ref(
            qg, pools["k_pool"], pools["v_pool"], bt, kv_len,
            pools["k_scale_pool"], pools["v_scale_pool"], window=win,
            tile=ps, m_rows=m)
        np.testing.assert_array_equal(np.asarray(ker), np.asarray(rr))


@pytest.mark.parametrize("kv_bits", [0, 8])
def test_paged_attention_verify_m1_matches_decode(kv_bits):
    """A single-row verify is the decode read: same q, same pools, same
    numbers (to f32 tolerance — XLA may vectorize the two shapes
    differently) through both entry points."""
    s, w, ps, kvh, g, hd, fills, window = PAGED_CASES[2]
    q, pools, bt, kv_len = _build_paged(41 + kv_bits, s, w, ps, kvh, g, hd,
                                        fills, kv_bits)
    dec = np.asarray(ops.paged_attention(
        q, pools["k_pool"], pools["v_pool"], bt, kv_len,
        k_scale_pool=pools["k_scale_pool"],
        v_scale_pool=pools["v_scale_pool"], window=window))
    ver = np.asarray(ops.paged_attention_verify(
        q[:, None], pools["k_pool"], pools["v_pool"], bt, kv_len,
        k_scale_pool=pools["k_scale_pool"],
        v_scale_pool=pools["v_scale_pool"], window=window))[:, 0]
    np.testing.assert_allclose(ver, dec, rtol=1e-5, atol=1e-5)


# --------------------------------------- fused chunked-prefill read (M>1)

# (S, M, W, ps, kvh, g, hd, fills, chunk, window): the prefill regime —
# fill = ctx + chunk per slot, chunk <= M (left-padded bucket) and, unlike
# verify, fills may be *smaller* than M (short prompt padded into the
# bucket). Adversaries: chunk ending exactly on a page boundary, ragged
# chunk lengths inside one bucket (incl. an idle slot), SWA skipping whole
# pages behind the window, GQA group > 1
PREFILL_CASES = [
    (2, 8, 4, 8, 1, 2, 32, (8, 16), (8, 8), None),      # page boundary
    (3, 8, 4, 8, 2, 1, 32, (5, 0, 11), (5, 0, 8), None),  # ragged chunk
    (2, 8, 4, 8, 1, 2, 16, (9, 17), (8, 5), 6),        # sliding window
    (2, 4, 4, 8, 2, 4, 32, (7, 12), (4, 2), None),     # GQA g=4
]


@pytest.mark.parametrize("kv_bits", [0, 8])
@pytest.mark.parametrize("case", PREFILL_CASES)
def test_paged_attention_prefill_parity(kv_bits, case):
    """The fused prefill read (a slot's left-padded chunk against its own
    earlier pages + shared prefix pages) matches the gather-the-context
    oracle on every row the engine consumes."""
    s, m, w, ps, kvh, g, hd, fills, chunk, window = case
    q, pools, bt, kv_len = build_prefill_case(
        sum(case[:7]) + kv_bits, s, m, w, ps, kvh, g, hd, fills, kv_bits)
    out = np.asarray(ops.paged_attention_prefill(
        q, pools["k_pool"], pools["v_pool"], bt, kv_len,
        k_scale_pool=pools["k_scale_pool"],
        v_scale_pool=pools["v_scale_pool"], window=window))
    orc = np.asarray(prefill_oracle(q, pools, bt, kv_len, window, chunk),
                     np.float32)
    live = prefill_live_rows(kv_len, chunk, m)
    np.testing.assert_allclose(out[live], orc[live], rtol=2e-2, atol=2e-2)
    # idle slots read back as exact zeros (all rows dead)
    slot_live = np.asarray(kv_len) > 0
    assert np.all(out[~slot_live] == 0.0)


@pytest.mark.parametrize("kv_bits", [0, 8])
def test_paged_attention_prefill_interpret_matches_ref_exactly(kv_bits):
    """Interpret-mode prefill kernel is bit-comparable with the jnp
    reference page walk, like decode (M=1) and verify."""
    s, m, w, ps, kvh, g, hd, fills, _chunk, window = PREFILL_CASES[2]
    q, pools, bt, kv_len = build_prefill_case(53 + kv_bits, s, m, w, ps,
                                              kvh, g, hd, fills, kv_bits)
    qg = q.reshape(s, m, kvh, g, hd).transpose(0, 2, 1, 3, 4)
    qg = qg.reshape(s, kvh, m * g, hd)
    for win in (window, None):
        ker = paged_attention_pallas(
            qg, pools["k_pool"], pools["v_pool"], bt, kv_len,
            pools["k_scale_pool"], pools["v_scale_pool"], window=win,
            tile=ps, m_rows=m, interpret=True)
        rr = ref.paged_attention_prefill_ref(
            qg, pools["k_pool"], pools["v_pool"], bt, kv_len,
            pools["k_scale_pool"], pools["v_scale_pool"], window=win,
            tile=ps, m_rows=m)
        np.testing.assert_array_equal(np.asarray(ker), np.asarray(rr))


# ------------------------------------------------- packed storage density

@pytest.mark.parametrize("bits", BITS)
def test_packed_footprint_is_subbyte(bits):
    """End-to-end storage density of the packed format: qw must cost at
    most ceil-to-group bits/8 bytes per weight — in particular W3 packs 8
    values into 3 bytes (0.375 B/value), not one byte each."""
    k, n = 256, 64
    w = jax.random.normal(jax.random.PRNGKey(bits), (k, n)) * 0.1
    qt = quantize(w, bits, 32)
    bpg, vpg = pack_layout(bits)
    assert qt.qw.dtype == jnp.uint8
    assert qt.qw.shape == (-(-k // vpg) * bpg, n)
    bytes_per_value = qt.qw.size / (k * n)
    assert bytes_per_value <= bits / 8 + 1e-9
    if bits == 3:
        assert bytes_per_value <= 0.5


# hypothesis property: quantize -> page-write -> kernel-read round trip.
# Guarded import so tier-1 collection stays green without the dev extra.
try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                                      # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000), s=st.integers(1, 4),
           w=st.integers(1, 4), logps=st.integers(2, 4),
           scale_mag=st.floats(0.01, 10.0))
    def test_paged_int8_roundtrip_error_bound(seed, s, w, logps, scale_mag):
        """int8 KV written through the page pool and read back (the
        single-pass gather_dequant_pages) stays within the per-(token,
        head) quantization bound scale/2 = amax/254; and the fused kernel
        reads exactly those dequantized values — its output matches the
        gather oracle over the same pool to f32 tolerance."""
        ps = 1 << logps
        kvh, g, hd = 2, 2, 16
        rng = np.random.default_rng(seed)
        fills = tuple(int(rng.integers(0, w * ps + 1)) for _ in range(s))
        q, pools, bt, kv_len = _build_paged(seed, s, w, ps, kvh, g, hd,
                                            fills, 8)
        # re-quantize a known float pool at this magnitude for the bound
        x = jnp.asarray(rng.normal(size=(1 + s * w, ps, kvh, hd)) * scale_mag,
                        jnp.float32)
        xq, xs = _quant_kv(x)
        back = gather_dequant_pages(xq, xs, bt, jnp.float32)
        orig = gather_pages(x, bt)
        bound = np.asarray(gather_pages(xs[..., None], bt))[..., 0] / 2.0
        err = np.abs(np.asarray(back) - np.asarray(orig))
        assert np.all(err <= bound[..., None] * (1 + 1e-5) + 1e-7)
        # kernel-read leg: fused output over the written pool == oracle
        out = np.asarray(ops.paged_attention(
            q, pools["k_pool"], pools["v_pool"], bt, kv_len,
            k_scale_pool=pools["k_scale_pool"],
            v_scale_pool=pools["v_scale_pool"]))
        orc = _gather_oracle(q, pools, bt, kv_len, None)
        live = np.asarray(kv_len) > 0
        np.testing.assert_allclose(out[live], orc[live], rtol=1e-4,
                                   atol=1e-4)


# ------------------------------------------------- MoE forward integration

def test_quantized_moe_forward_uses_expert_kernel(monkeypatch):
    """A quantized MoE block must route its expert matmuls through the
    expert-batched kernel and never dequantize the full expert stack."""
    from repro.configs import TINY
    from repro.models import linear as linear_mod
    from repro.models.config import MoEConfig
    from repro.models.mlp_moe import apply_moe, init_moe

    monkeypatch.setenv("REPRO_DEQUANT_IMPL", "pallas")
    cfg = TINY.replace(d_model=64, moe=MoEConfig(n_experts=4, top_k=2,
                                                 d_ff_expert=64))
    p = init_moe(cfg, jax.random.PRNGKey(0))
    for name in ("wi", "wg", "wo"):
        p["experts"][name]["w"] = quantize_stacked(
            p["experts"][name]["w"], 4, 32)

    calls = []
    real = ops.expert_dequant_matmul

    def spy(*a, **kw):
        calls.append(a[1].shape)
        return real(*a, **kw)

    monkeypatch.setattr(ops, "expert_dequant_matmul", spy)

    def no_dequant(*a, **kw):
        raise AssertionError("quantized expert stack was dequantized")

    monkeypatch.setattr(linear_mod, "dequantize", no_dequant)

    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 64)) * 0.3
    y, _aux = apply_moe(cfg, p, x)
    assert y.shape == (1, 16, 64)
    assert len(calls) == 3                             # wg, wi, wo
    assert np.all(np.isfinite(np.asarray(y)))
