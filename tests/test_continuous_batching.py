"""Continuous-batching engine tests: token equivalence against the static
engine, paged-cache correctness across architectures, and page-pool
invariants (no leaks, admission blocks on exhaustion)."""
import jax
import numpy as np
import pytest

from repro.configs import TINY
from repro.models.transformer import init_lm
from repro.serve.engine import ContinuousEngine, ServeEngine
from repro.serve.kvcache import PagePool, PageSpec, default_page_spec

CFG = TINY.replace(n_repeats=2, d_model=64, head_dim=16, d_ff=128)

# 16 requests / 8 slots, mixed prompt lengths 8-64, staggered arrivals.
# Four distinct (prompt_len, max_new) shapes keep jit compile count small.
WORKLOAD = [(8, 6), (16, 4), (32, 8), (64, 5)] * 4


@pytest.fixture(scope="module")
def tiny_lm():
    return init_lm(CFG, jax.random.PRNGKey(0))


def _make_requests(rng):
    return [(rng.integers(0, CFG.vocab_size, plen), max_new, float(i % 5))
            for i, (plen, max_new) in enumerate(WORKLOAD)]


def test_token_equivalence_mixed_lengths_staggered_arrivals(tiny_lm):
    """Greedy continuous-batching output == static per-request output."""
    reqs = _make_requests(np.random.default_rng(0))
    eng = ContinuousEngine(CFG, tiny_lm, n_slots=8, max_len=128,
                           page_size=16, prefill_bucket=8)
    handles = [eng.submit(p, max_new=m, arrival=a) for p, m, a in reqs]
    done = eng.run(max_steps=2000)
    assert len(done) == len(reqs) and all(r.done for r in done)

    static = ServeEngine(CFG, tiny_lm)
    for (prompt, max_new, _), handle in zip(reqs, handles):
        ref = static.generate(prompt[None, :], max_new=max_new,
                              temperature=0.0)
        assert handle.tokens == list(ref.tokens[0]), \
            f"request {handle.rid} diverged"
    # every page returned once all requests retired
    assert eng.pool.n_free == eng.spec.n_pages - 1
    assert np.all(eng.pool.tables == -1)


def test_admission_blocks_when_pool_exhausted(tiny_lm):
    """More slots than pages: admission must wait for pages, not overflow."""
    # pool covers exactly two concurrent requests (budget 16 tokens = 2
    # pages of 8), plus the reserved scratch page
    spec_pages = 1 + 2 * 2
    # decode_block=1 so slot occupancy is observable at step boundaries
    eng = ContinuousEngine(CFG, tiny_lm, n_slots=4, max_len=16, page_size=8,
                           n_pages=spec_pages, prefill_bucket=8,
                           decode_block=1)
    rng = np.random.default_rng(1)
    for i in range(5):
        eng.submit(rng.integers(0, CFG.vocab_size, 8), max_new=8)

    max_concurrent = 0
    steps = 0
    while not eng.sched.all_done():
        eng.step(float(steps))
        max_concurrent = max(max_concurrent, len(eng.sched.active_slots()))
        assert eng.pool.n_free >= 0
        steps += 1
        assert steps < 500
    assert max_concurrent == 2          # free slots existed, pages gated
    assert len(eng.sched.finished) == 5
    assert eng.pool.n_free == spec_pages - 1


def test_page_pool_alloc_release_invariants():
    spec = PageSpec(n_pages=9, page_size=4, max_pages=4)
    pool = PagePool(spec, n_slots=3)
    assert pool.n_free == 8
    pool.alloc(0, 9)                    # 3 pages
    pool.alloc(1, 16)                   # 4 pages
    assert pool.n_free == 1
    assert not pool.can_alloc(8)        # 2 pages > 1 free
    with pytest.raises(RuntimeError):
        pool.alloc(2, 8)
    pool.release(0)
    assert pool.n_free == 4
    pool.alloc(2, 8)
    pool.release(1)
    pool.release(2)
    assert pool.n_free == 8
    assert np.all(pool.tables == -1)
    # no page handed out twice: allocate everything, check uniqueness
    pool.alloc(0, 16)
    pool.alloc(1, 16)
    held = pool.tables[pool.tables >= 0]
    assert len(set(held.tolist())) == len(held) == 8


def test_eos_retires_slot_early(tiny_lm):
    """A request hitting EOS frees its slot and pages before max_new."""
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, CFG.vocab_size, 8)
    ref = ServeEngine(CFG, tiny_lm).generate(prompt[None, :], max_new=8,
                                             temperature=0.0)
    eos = int(ref.tokens[0, 2])         # third greedy token acts as EOS
    eng = ContinuousEngine(CFG, tiny_lm, n_slots=2, max_len=32, page_size=8,
                           prefill_bucket=8, eos_id=eos)
    handle = eng.submit(prompt, max_new=8)
    eng.run(max_steps=200)
    assert handle.tokens[-1] == eos
    assert len(handle.tokens) == 3      # stopped at EOS, not max_new
    assert eng.pool.n_free == eng.spec.n_pages - 1


def test_moe_pad_tokens_do_not_shift_routing():
    """Left-pad junk must not consume expert capacity or displace real
    tokens' dispatch slots (same capacity => identical real-row outputs)."""
    import jax.numpy as jnp

    from repro.models.config import MoEConfig
    from repro.models.mlp_moe import apply_moe, init_moe, moe_capacity

    cfg = CFG.replace(moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64,
                                    capacity_factor=1.0))
    p = init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 12, CFG.d_model)) * 0.3
    assert moe_capacity(cfg, 12) == moe_capacity(cfg, 16)  # same cap bucket
    y_ref, _ = apply_moe(cfg, p, x)
    pad = jax.random.normal(jax.random.PRNGKey(2), (1, 4, CFG.d_model)) * 5.0
    xp = jnp.concatenate([pad, x], axis=1)
    valid = jnp.concatenate([jnp.zeros((1, 4), bool),
                             jnp.ones((1, 12), bool)], axis=1)
    y_pad, _ = apply_moe(cfg, p, xp, valid=valid)
    np.testing.assert_array_equal(np.asarray(y_pad[:, 4:]),
                                  np.asarray(y_ref))


def test_token_equivalence_mla_and_hybrid():
    """Paged serving matches the static engine across MLA, SSM-hybrid and
    SWA/MoE architectures (single-request prefill batches: capacity-MoE
    routing is cross-token, so co-batched prefills may legitimately differ
    when capacity binds — see DESIGN.md)."""
    from repro.configs import get_smoke_config

    for arch, bucket in [("deepseek-v2-lite-16b", 8),
                         ("jamba-1.5-large-398b", 1),
                         ("mixtral-8x22b", 8)]:
        cfg = get_smoke_config(arch)
        params = init_lm(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(1)
        static = ServeEngine(cfg, params)
        eng = ContinuousEngine(cfg, params, n_slots=3, max_len=64,
                               page_size=8, prefill_bucket=bucket,
                               prefill_batch=1)
        reqs = [(rng.integers(0, cfg.vocab_size, plen), max_new)
                for plen, max_new in [(8, 4), (12, 5), (16, 3), (9, 4)]]
        for i, (prompt, max_new) in enumerate(reqs):
            eng.submit(prompt, max_new=max_new, arrival=float(i % 2))
        done = eng.run(max_steps=500)
        for (prompt, max_new), r in zip(reqs, done):
            ref = static.generate(prompt[None], max_new=max_new,
                                  temperature=0.0)
            assert r.tokens == list(ref.tokens[0]), f"{arch} rid {r.rid}"
        assert eng.pool.n_free == eng.spec.n_pages - 1


def test_quantized_moe_token_equivalence():
    """Continuous-batching greedy tokens on a W4 MoE model (packed via the
    engines' quant_bits plumbing -> quantize_params_for_serving) match
    per-request static decoding with the same quantized params, so the
    expert-batched / decode-shaped kernel dispatch sits under the serving
    stack without changing tokens."""
    import jax.numpy as jnp

    from repro.core.quant.types import QuantizedTensor
    from repro.models.config import LayerSpec, MoEConfig
    from repro.utils.tree import tree_get

    # capacity_factor=1.0 keeps moe_capacity in the same 8-bucket for the
    # static (unpadded t) and bucketed-prefill (padded t) token counts —
    # capacity is cross-token, so differing caps would legitimately diverge
    cfg = CFG.replace(
        pattern=(LayerSpec(kind="attn", mlp="moe"),),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64,
                      capacity_factor=1.0))
    params = init_lm(cfg, jax.random.PRNGKey(0))

    static = ServeEngine(cfg, params, quant_bits=4, quant_group=32)
    eng = ContinuousEngine(cfg, params, n_slots=3, max_len=64, page_size=8,
                           prefill_bucket=8, prefill_batch=1,
                           quant_bits=4, quant_group=32)
    # both engines packed identically, experts included (stacked packed
    # layout: scan dim L x expert dim E in front of (K/vpb, N))
    wq = tree_get(eng.params, "stack/p0/moe/experts/wi")["w"]
    assert isinstance(wq, QuantizedTensor) and wq.bits == 4
    assert wq.qw.ndim == 4 and wq.qw.dtype == jnp.uint8

    rng = np.random.default_rng(3)
    reqs = [(rng.integers(0, cfg.vocab_size, plen), max_new)
            for plen, max_new in [(8, 4), (12, 5), (16, 3), (9, 4)]]
    for i, (prompt, max_new) in enumerate(reqs):
        eng.submit(prompt, max_new=max_new, arrival=float(i % 2))
    done = eng.run(max_steps=500)
    assert len(done) == len(reqs)
    for (prompt, max_new), r in zip(reqs, done):
        ref = static.generate(prompt[None], max_new=max_new,
                              temperature=0.0)
        assert r.tokens == list(ref.tokens[0]), f"quantized rid {r.rid}"
    assert eng.pool.n_free == eng.spec.n_pages - 1


def test_fused_paged_attention_token_equivalence():
    """Greedy tokens from the fused paged-attention decode kernel match the
    gather->dequant->einsum oracle path across the zoo axes the kernel
    covers: dense MHA, GQA (group > 1), sliding-window, and int8 KV."""
    variants = [
        ("dense", CFG),
        ("gqa", CFG.replace(n_kv_heads=2)),
        ("swa", CFG.replace(attn_window=12)),
        ("int8-kv", CFG.replace(kv_cache_bits=8)),
        ("gqa-swa-int8", CFG.replace(n_kv_heads=2, attn_window=12,
                                     kv_cache_bits=8)),
    ]
    rng = np.random.default_rng(7)
    reqs = [(rng.integers(0, CFG.vocab_size, plen), max_new)
            for plen, max_new in [(8, 5), (13, 6), (24, 4)]]
    for name, cfg in variants:
        params = init_lm(cfg, jax.random.PRNGKey(0))
        assert cfg.paged_attn_impl == "fused"          # default path
        outs = {}
        for impl in ("fused", "gather"):
            eng = ContinuousEngine(cfg, params, n_slots=3, max_len=64,
                                   page_size=8, prefill_bucket=8,
                                   paged_attn=impl)
            for i, (prompt, max_new) in enumerate(reqs):
                eng.submit(prompt, max_new=max_new, arrival=float(i % 2))
            done = eng.run(max_steps=500)
            outs[impl] = [r.tokens for r in done]
            assert eng.pool.n_free == eng.spec.n_pages - 1
        assert outs["fused"] == outs["gather"], f"{name} diverged"


def test_default_page_spec_capacity():
    spec = default_page_spec(n_slots=4, max_len=100, page_size=16)
    assert spec.max_pages == 7
    assert spec.n_pages == 1 + 4 * 7    # scratch + full provisioning
    assert spec.pages_for(1) == 1 and spec.pages_for(16) == 1
    assert spec.pages_for(17) == 2
