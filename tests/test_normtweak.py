"""Norm-tweaking unit tests: losses, schedule, pipeline invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TINY
from repro.core.calibration.generator import random_calibration
from repro.core.normtweak.losses import (activation_divergence, l_dist, l_kl,
                                         l_mse)
from repro.core.normtweak.pipeline import NTConfig, norm_tweak_ptq
from repro.core.normtweak.schedule import layer_lr
from repro.core.quant.types import QuantizedTensor
from repro.models.norms import is_norm_path
from repro.models.transformer import init_lm, lm_forward
from repro.utils.tree import tree_map_with_path

CFG = TINY.replace(n_repeats=2, d_model=64, head_dim=16, d_ff=128)


def test_losses_zero_at_identity():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 16))
    for fn in (l_dist, l_mse, l_kl):
        assert float(fn(x, x)) < 1e-6
        assert float(fn(x, x + 0.5)) > 0.0


def test_l_dist_matches_eq2_shape_semantics():
    f = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 4))
    q = f * 2.0 + 1.0
    mu_f = jnp.mean(f.reshape(-1, 4), 0)
    var_f = jnp.var(f.reshape(-1, 4), 0)
    mu_q = jnp.mean(q.reshape(-1, 4), 0)
    var_q = jnp.var(q.reshape(-1, 4), 0)
    expect = jnp.mean(jnp.abs(mu_f - mu_q) + jnp.abs(var_f - var_q))
    np.testing.assert_allclose(float(l_dist(f, q)), float(expect), rtol=1e-5)


def test_layer_lr_schedule_eq3():
    assert layer_lr(1e-5, 10.0, 0, 24) == pytest.approx(1e-5)
    assert layer_lr(1e-5, 10.0, 12, 24) == pytest.approx(6e-5)
    assert layer_lr(1e-5, 10.0, 23, 24) > layer_lr(1e-5, 10.0, 1, 24)


@pytest.fixture(scope="module")
def tiny_setup():
    params = init_lm(CFG, jax.random.PRNGKey(0))
    calib = random_calibration(CFG, jax.random.PRNGKey(1), n_samples=4,
                               token_length=16)
    return params, calib


def test_pipeline_quantizes_all_linears(tiny_setup):
    params, calib = tiny_setup
    nt = NTConfig(method="rtn", bits=4, tweak=False)
    qp, _ = norm_tweak_ptq(CFG, params, calib, nt)
    n_q = [0]

    def count(path, leaf):
        if isinstance(leaf, QuantizedTensor):
            n_q[0] += 1
        return leaf

    jax.tree.map(lambda x: x, qp)  # structure intact
    tree_map_with_path(count, qp,)
    # 4 attn + 3 mlp linears per stacked pattern position
    assert n_q[0] == 7
    # forward must run and change outputs
    tokens = calib[:2]
    lf, _ = lm_forward(CFG, params, tokens)
    lq, _ = lm_forward(CFG, qp, tokens)
    assert lq.shape == lf.shape
    assert float(jnp.max(jnp.abs(lf - lq))) > 0.0
    assert not bool(jnp.any(jnp.isnan(lq)))


def test_tweak_changes_only_norm_params(tiny_setup):
    params, calib = tiny_setup
    nt_off = NTConfig(method="rtn", bits=3, tweak=False)
    nt_on = NTConfig(method="rtn", bits=3, tweak=True, lr0=1e-3, iters=1,
                     sample_batch=2)
    qp0, _ = norm_tweak_ptq(CFG, params, calib, nt_off)
    qp1, stats = norm_tweak_ptq(CFG, params, calib, nt_on)

    diffs = []

    def cmp(path, a):
        return a

    flat0 = jax.tree_util.tree_leaves_with_path(qp0)
    flat1 = jax.tree_util.tree_leaves_with_path(qp1)
    for (p0, a), (p1, b) in zip(flat0, flat1):
        assert jax.tree_util.keystr(p0) == jax.tree_util.keystr(p1)
        path = jax.tree_util.keystr(p0)
        d = float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                  b.astype(jnp.float32))))
        if d > 0:
            diffs.append(path)
    assert diffs, "tweaking must change something"
    for path in diffs:
        norm_path = path.replace("[", "/").replace("]", "").replace("'", "")
        assert is_norm_path(norm_path), f"non-norm param changed: {path}"


def test_tweak_reduces_dist_loss(tiny_setup):
    params, calib = tiny_setup
    nt = NTConfig(method="rtn", bits=2, group_size=16, tweak=True, lr0=1e-3,
                  iters=2, sample_batch=2)
    _, stats = norm_tweak_ptq(CFG, params, calib, nt)
    assert len(stats["layer_loss"]) == CFG.n_layers
    assert all(np.isfinite(v) for v in stats["layer_loss"])


def test_tweak_scan_matches_per_chunk_loop(tiny_setup):
    """The fused lax.scan inner loop (_tweak_scan, one dispatch per layer
    with donated buffers) must produce the same final norms as the
    per-chunk _tweak_step loop it replaced — same chunk order, same math."""
    from repro.core.normtweak.pipeline import _tweak_scan, _tweak_step
    from repro.core.normtweak.schedule import layer_lr
    from repro.core.quant.blockquant import quantize_block
    from repro.models.transformer import _embed, block_spec, get_block
    from repro.optim.adam import adam_init
    from repro.utils.tree import tree_partition

    params, calib = tiny_setup
    n, s = calib.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (n, s))
    x0 = _embed(CFG, params, calib, None, positions)
    spec, bp = block_spec(CFG, 0), get_block(CFG, params, 0)
    from repro.models.blocks import apply_block
    fout, _, _ = apply_block(CFG, spec, bp, x0, positions=positions,
                             mode="train")
    taps = {}
    apply_block(CFG, spec, bp, x0, positions=positions, mode="train",
                taps=taps)
    qbp = quantize_block(bp, taps, method="rtn", bits=4, group_size=-1)
    norms0, rest = tree_partition(qbp, is_norm_path)
    lr = layer_lr(1e-3, 10.0, 0, CFG.n_layers)
    sb, iters = 2, 2
    assert n % sb == 0                      # the fused path's precondition

    loop_norms, loop_state = norms0, adam_init(norms0)
    for _ in range(iters):
        for s0 in range(0, n, sb):
            loop_norms, loop_state, loop_loss = _tweak_step(
                CFG, spec, "dist", loop_norms, rest, loop_state,
                x0[s0:s0 + sb], fout[s0:s0 + sb], positions[s0:s0 + sb], lr)

    chunk = lambda a: a.reshape((n // sb, sb) + a.shape[1:])
    scan_norms, _, scan_loss = _tweak_scan(
        CFG, spec, "dist", norms0, rest, adam_init(norms0), chunk(x0),
        chunk(fout), chunk(positions), lr, iters=iters)

    flat_l = jax.tree_util.tree_leaves_with_path(loop_norms)
    flat_s = jax.tree_util.tree_leaves_with_path(scan_norms)
    assert len(flat_l) == len(flat_s) > 0
    for (pl_, a), (ps_, b) in zip(flat_l, flat_s):
        assert jax.tree_util.keystr(pl_) == jax.tree_util.keystr(ps_)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=jax.tree_util.keystr(pl_))
    np.testing.assert_allclose(float(scan_loss), float(loop_loss), rtol=1e-6)


def test_divergence_metric_positive_after_quant(tiny_setup):
    params, calib = tiny_setup
    nt = NTConfig(method="rtn", bits=2, group_size=16, tweak=False)
    qp, _ = norm_tweak_ptq(CFG, params, calib, nt)
    lf, _ = lm_forward(CFG, params, calib[:2])
    lq, _ = lm_forward(CFG, qp, calib[:2])
    assert float(activation_divergence(lf, lq)) > 0.0
