import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax

jax.config.update("jax_enable_x64", False)
