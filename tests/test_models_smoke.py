"""Per-architecture smoke tests (assignment requirement): reduced config of
the same family, one forward + one train step on CPU, shape + no-NaN."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config, list_archs
from repro.models.encdec import encdec_forward, encdec_loss, init_encdec
from repro.models.transformer import init_lm, lm_forward, lm_loss
from repro.optim.schedules import constant
from repro.train.train_step import init_opt_state, make_train_step

ARCHS = list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    cfg.validate()
    key = jax.random.PRNGKey(0)
    b, s = 2, 16

    if cfg.enc_dec:
        params = init_encdec(cfg, key)
        frames = jax.random.normal(key, (b, 8, cfg.d_model)) * 0.3
        tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
        logits, aux = encdec_forward(cfg, params, frames, tokens)
        assert logits.shape == (b, s, cfg.vocab_size)
        assert not bool(jnp.any(jnp.isnan(logits)))
        batch = {"frames": frames, "tokens": tokens,
                 "labels": jnp.roll(tokens, -1, 1)}
        step = make_train_step(cfg, lr_schedule=constant(1e-3),
                               loss_fn=encdec_loss, donate=False)
        opt = init_opt_state(cfg, params)
        p2, _, m = step(params, opt, batch, jnp.asarray(0), jax.random.PRNGKey(1))
        assert np.isfinite(float(m["loss"]))
        return

    params = init_lm(cfg, key)
    ext = None
    if cfg.frontend == "vision":
        ext = jax.random.normal(key, (b, cfg.frontend_len, cfg.d_model)) * 0.3
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    logits, aux = lm_forward(cfg, params, tokens, ext_embeds=ext)
    total = s + (cfg.frontend_len if ext is not None else 0)
    assert logits.shape == (b, total, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))

    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}
    if ext is not None:
        batch["ext_embeds"] = ext
    step = make_train_step(cfg, lr_schedule=constant(1e-3), donate=False)
    opt = init_opt_state(cfg, params)
    p2, _, m = step(params, opt, batch, jnp.asarray(0), jax.random.PRNGKey(1))
    assert np.isfinite(float(m["loss"]))
    # params actually moved
    delta = jax.tree.map(lambda a, b_: float(jnp.max(jnp.abs(a - b_))),
                         params, p2)
    assert max(jax.tree.leaves(delta)) > 0.0
