"""Serving-path equivalence: prefill+decode must reproduce the training
forward (per family, incl. SWA ring buffer, MLA absorbed decode, SSD
recurrence, whisper cross-attention)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models.encdec import (encdec_decode, encdec_forward,
                                 encdec_init_cache, encdec_prefill,
                                 init_encdec)
from repro.models.transformer import (init_cache, init_lm, lm_decode,
                                      lm_forward, lm_prefill)

FAMS = ["llama3.2-1b", "qwen2-0.5b", "chatglm3-6b", "granite-20b",
        "mixtral-8x22b", "deepseek-v2-lite-16b", "mamba2-2.7b",
        "jamba-1.5-large-398b"]


def _cfg(arch):
    cfg = get_smoke_config(arch)
    if cfg.moe:  # avoid token dropping noise in equivalence tests
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=8.0))
    return cfg


@pytest.mark.parametrize("arch", FAMS)
def test_prefill_decode_matches_forward(arch):
    cfg = _cfg(arch)
    key = jax.random.PRNGKey(0)
    params = init_lm(cfg, key)
    b, s = 2, 24
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    logits, _ = lm_forward(cfg, params, tokens)
    cache = init_cache(cfg, b, 64)
    lg_pre, cache = lm_prefill(cfg, params, tokens[:, :s - 1], cache)
    lg_dec, _ = lm_decode(cfg, params, tokens[:, s - 1:], cache,
                          jnp.full((b, 1), s - 1, jnp.int32))
    assert float(jnp.max(jnp.abs(lg_pre - logits[:, s - 2]))) < 2e-4
    assert float(jnp.max(jnp.abs(lg_dec - logits[:, s - 1]))) < 2e-4


def test_swa_ring_buffer_decode_past_window():
    cfg = _cfg("mixtral-8x22b").replace(attn_window=16)
    key = jax.random.PRNGKey(0)
    params = init_lm(cfg, key)
    b, s = 1, 48
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    logits, _ = lm_forward(cfg, params, tokens)
    cache = init_cache(cfg, b, 64)
    lg, cache = lm_prefill(cfg, params, tokens[:, :40], cache)
    assert float(jnp.max(jnp.abs(lg - logits[:, 39]))) < 2e-4
    for t in range(40, s):
        lg, cache = lm_decode(cfg, params, tokens[:, t:t + 1], cache,
                              jnp.full((b, 1), t, jnp.int32))
        assert float(jnp.max(jnp.abs(lg - logits[:, t]))) < 2e-4


def test_chunked_attention_matches_single_shot():
    cfg = _cfg("llama3.2-1b").replace(attn_block_kv=8)
    params = init_lm(cfg, jax.random.PRNGKey(1))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 40), 0,
                                cfg.vocab_size)
    l_chunk, _ = lm_forward(cfg, params, tokens)
    l_full, _ = lm_forward(cfg.replace(attn_block_kv=4096), params, tokens)
    assert float(jnp.max(jnp.abs(l_chunk - l_full))) < 2e-4


def test_whisper_encdec_consistency():
    cfg = _cfg("whisper-medium")
    key = jax.random.PRNGKey(0)
    params = init_encdec(cfg, key)
    b, se, sd = 2, 16, 12
    frames = jax.random.normal(key, (b, se, cfg.d_model)) * 0.3
    tokens = jax.random.randint(key, (b, sd), 0, cfg.vocab_size)
    logits, _ = encdec_forward(cfg, params, frames, tokens)
    cache = encdec_init_cache(cfg, b, 64, enc_len=se)
    lg, cache = encdec_prefill(cfg, params, frames, tokens[:, :sd - 1], cache)
    assert float(jnp.max(jnp.abs(lg - logits[:, sd - 2]))) < 2e-4
    lg, _ = encdec_decode(cfg, params, tokens[:, sd - 1:], cache,
                          jnp.full((b, 1), sd - 1, jnp.int32))
    assert float(jnp.max(jnp.abs(lg - logits[:, sd - 1]))) < 2e-4


def test_scan_matches_unrolled():
    cfg = _cfg("llama3.2-1b")
    params = init_lm(cfg, jax.random.PRNGKey(3))
    tokens = jax.random.randint(jax.random.PRNGKey(4), (2, 16), 0,
                                cfg.vocab_size)
    l_scan, _ = lm_forward(cfg.replace(scan_layers=True), params, tokens)
    l_unr, _ = lm_forward(cfg.replace(scan_layers=False), params, tokens)
    assert float(jnp.max(jnp.abs(l_scan - l_unr))) < 2e-4
