"""Prefix cache + chunked prefill: token identity against the monolithic
no-sharing engine, page refcount/index invariants, and the scheduler
regressions that rode along (width gating, insort intake, first-token
reproducibility across prefill batching)."""
import jax
import numpy as np
import pytest

from repro.configs import TINY
from repro.models.transformer import init_lm
from repro.serve.engine import ContinuousEngine
from repro.serve.kvcache import PagePool, PageSpec
from repro.serve.scheduler import Request, Scheduler

CFG = TINY.replace(n_repeats=2, d_model=64, head_dim=16, d_ff=128)


@pytest.fixture(scope="module")
def tiny_lm():
    return init_lm(CFG, jax.random.PRNGKey(0))


def _shared_prefix_requests(rng, n_shared=32, tails=((8, 5), (13, 4),
                                                     (24, 6), (5, 5))):
    """Requests whose prompts all start with the same n_shared tokens."""
    system = rng.integers(0, CFG.vocab_size, n_shared)
    return [(np.concatenate([system, rng.integers(0, CFG.vocab_size, t)]), m)
            for t, m in tails]


def _run(cfg, params, reqs, *, arrivals=None, **kw):
    eng = ContinuousEngine(cfg, params, n_slots=3, max_len=128, page_size=16,
                           prefill_bucket=8, **kw)
    for i, (prompt, max_new) in enumerate(reqs):
        arrival = float(i) if arrivals is None else arrivals[i]
        eng.submit(prompt, max_new=max_new, arrival=arrival)
    done = eng.run(max_steps=2000)
    return eng, {r.rid: r.tokens for r in done}


# ---------------------------------------------------------- scheduler fixes

def test_can_alloc_gates_on_block_table_width():
    """A request wider than one block-table row is un-admittable even when
    the pool has plenty of free pages (the old check only counted pages,
    so admit() crashed inside alloc instead of queueing cleanly)."""
    spec = PageSpec(n_pages=17, page_size=4, max_pages=2)
    pool = PagePool(spec, n_slots=2)
    assert pool.n_free == 16
    assert pool.can_alloc(8)            # 2 pages == table width
    assert not pool.can_alloc(9)        # 3 pages > width, 16 free
    with pytest.raises(ValueError):
        pool.alloc(0, 9)


def test_scheduler_rejects_overwide_request_without_raising():
    """Driving the Scheduler directly (no engine.submit pre-check): an
    over-wide budget retires as rejected and the queue keeps moving."""
    spec = PageSpec(n_pages=17, page_size=4, max_pages=2)
    pool = PagePool(spec, n_slots=2)
    sched = Scheduler(2, pool)
    wide = Request(rid=0, prompt=np.zeros(6, np.int32), max_new=6)  # 3 pages
    ok = Request(rid=1, prompt=np.zeros(4, np.int32), max_new=4)    # 2 pages
    sched.submit(wide)
    sched.submit(ok)
    admitted = sched.admit(0.0)         # must not raise
    assert [r.rid for _, r in admitted] == [1]
    assert wide.rejected and wide.done and not wide.tokens
    assert not ok.rejected
    pool.check_invariants()
    # the rejected request is reported with the finished ones
    assert wide in sched.finished


def test_submit_insort_intake_order_large_n():
    """Shuffled large-N submission ingests in arrival order, ties stable."""
    spec = PageSpec(n_pages=5, page_size=4, max_pages=4)
    sched = Scheduler(1, PagePool(spec, n_slots=1))
    n = 2000
    rng = np.random.default_rng(0)
    arrivals = rng.integers(0, 50, n).astype(float)   # many ties
    for rid, arr in enumerate(arrivals):
        sched.submit(Request(rid=rid, prompt=np.zeros(1, np.int32),
                             max_new=1, arrival=float(arr)))
    sched._ingest(now=25.0)
    got = [(r.arrival, r.rid) for r in sched.queue]
    assert all(a <= 25.0 for a, _ in got)
    assert got == sorted(got)           # arrival order, rid-stable ties
    assert len(got) + len(sched._pending) == n
    sched._ingest(now=1e9)
    assert not sched._pending and len(sched.queue) == n


def test_first_token_reproducible_across_prefill_batch(tiny_lm):
    """Sampled (temperature > 0) runs give the same tokens whether admitted
    prompts prefill one-per-call or co-batched: the first token comes from
    a per-request device key, not a host RNG consumed in batch order."""
    rng = np.random.default_rng(3)
    reqs = [(rng.integers(0, CFG.vocab_size, plen), 6)
            for plen in (8, 12, 16, 9, 24, 5)]
    outs = []
    for batch in (1, 8):
        eng = ContinuousEngine(CFG, tiny_lm, n_slots=6, max_len=64,
                               page_size=16, prefill_bucket=8,
                               prefill_batch=batch, temperature=0.8, seed=11)
        for prompt, max_new in reqs:
            eng.submit(prompt, max_new=max_new, arrival=0.0)
        outs.append({r.rid: r.tokens for r in eng.run(max_steps=2000)})
    assert outs[0] == outs[1]


# ------------------------------------------------- token identity + savings

def test_shared_prefix_reduces_prefill_and_keeps_tokens(tiny_lm):
    """Acceptance: 16 requests sharing a 2-page prefix prefill measurably
    fewer tokens than the no-share baseline and emit identical greedy
    tokens; admission never raises; the pool drains consistent."""
    rng = np.random.default_rng(0)
    tails = [(4 + 3 * (i % 7), 3 + i % 4) for i in range(16)]
    reqs = _shared_prefix_requests(rng, n_shared=32, tails=tails)
    base_eng, base = _run(CFG, tiny_lm, reqs)
    share_eng, share = _run(CFG, tiny_lm, reqs, prefix_share=True)
    assert share == base
    assert share_eng.n_prefill_tokens < base_eng.n_prefill_tokens
    assert share_eng.n_shared_tokens > 0
    # all shared tokens were whole pages of the common 32-token prefix
    assert share_eng.n_shared_tokens % share_eng.spec.page_size == 0
    share_eng.pool.check_invariants()
    assert np.all(share_eng.pool.tables == -1)      # every slot unmapped
    # conservation incl. the cache: free + cached == allocatable
    assert (share_eng.pool.n_free + share_eng.pool.n_cached
            == share_eng.spec.n_pages - 1)


def test_token_identity_zoo_prefix_and_chunked(tiny_lm):
    """Prefix-hit and chunked-prefill runs emit the same greedy tokens as
    the monolithic no-sharing baseline across the attention zoo the
    features cover: dense, GQA, SWA, int8-KV."""
    variants = [
        ("dense", CFG),
        ("gqa", CFG.replace(n_kv_heads=2)),
        ("swa", CFG.replace(attn_window=12)),
        ("int8-kv", CFG.replace(kv_cache_bits=8)),
        ("gqa-swa-int8", CFG.replace(n_kv_heads=2, attn_window=12,
                                     kv_cache_bits=8)),
    ]
    rng = np.random.default_rng(7)
    reqs = _shared_prefix_requests(rng)
    for name, cfg in variants:
        params = tiny_lm if cfg is CFG else init_lm(cfg, jax.random.PRNGKey(0))
        _, base = _run(cfg, params, reqs)
        for kw in (dict(prefix_share=True), dict(chunked_prefill=16),
                   dict(prefix_share=True, chunked_prefill=16)):
            eng, out = _run(cfg, params, reqs, **kw)
            assert out == base, f"{name} diverged under {kw}"
            eng.pool.check_invariants()


def test_fused_paged_attention_reads_stitched_tables(tiny_lm):
    """The fused decode kernel walks block tables whose rows stitch shared
    prefix pages before owned tail pages — same tokens as the gather
    oracle on the same prefix-shared, chunked workload."""
    cfg = CFG.replace(n_kv_heads=2, kv_cache_bits=8)
    params = init_lm(cfg, jax.random.PRNGKey(0))
    reqs = _shared_prefix_requests(np.random.default_rng(5))
    outs = {}
    for impl in ("fused", "gather"):
        eng, outs[impl] = _run(cfg, params, reqs, paged_attn=impl,
                               prefix_share=True, chunked_prefill=16)
        eng.pool.check_invariants()
    assert outs["fused"] == outs["gather"]


def test_chunked_prefill_interleaves_decode(tiny_lm):
    """A long prompt split into chunks must not stall decode: a short
    request admitted alongside it keeps emitting tokens between chunks
    and finishes while the long prompt is still prefilling."""
    rng = np.random.default_rng(2)
    long_p = rng.integers(0, CFG.vocab_size, 96)     # 6 chunks of 16
    short_p = rng.integers(0, CFG.vocab_size, 8)
    eng = ContinuousEngine(CFG, tiny_lm, n_slots=2, max_len=128,
                           page_size=16, prefill_bucket=8, decode_block=1,
                           chunked_prefill=16)
    long_r = eng.submit(long_p, max_new=4, arrival=0.0)
    short_r = eng.submit(short_p, max_new=4, arrival=0.0)
    eng.run(max_steps=500)
    # short finished decoding strictly before the long prompt produced its
    # first token (virtual clock: one step() per tick)
    assert short_r.finished_at < long_r.first_token_at
    assert eng.n_prefills >= 6 + 1
    # and the chunked long prompt decoded the same tokens as monolithic
    _, base = _run(CFG, tiny_lm, [(long_p, 4)])
    assert long_r.tokens == base[0]


def test_chunked_rejects_unsupported_archs(tiny_lm):
    from repro.configs import get_smoke_config

    for arch in ("deepseek-v2-lite-16b", "jamba-1.5-large-398b"):
        cfg = get_smoke_config(arch)
        params = init_lm(cfg, jax.random.PRNGKey(1))
        for kw in (dict(prefix_share=True), dict(chunked_prefill=16)):
            with pytest.raises(NotImplementedError):
                ContinuousEngine(cfg, params, n_slots=2, max_len=64,
                                 page_size=8, **kw)


# --------------------------------------------------------- pool invariants

def test_pool_refcount_lifecycle_direct():
    """Shared pages are referenced not copied, survive holder retirement
    via the index reference, and are never freed while any slot holds
    them; release conserves pages."""
    spec = PageSpec(n_pages=13, page_size=4, max_pages=6)
    pool = PagePool(spec, n_slots=3, prefix_cache=True)
    prompt = np.arange(9, dtype=np.int32)            # 2 full pages + 1 tok
    pool.alloc(0, 12)                                # 3 pages
    pool.register_prefix(prompt, 0)                  # pages 0,1 of slot 0
    shared = pool.lookup_prefix(prompt)
    assert len(shared) == 2
    assert pool.refcount[shared].tolist() == [2, 2]  # slot + index
    pool.check_invariants()

    # a second slot stitches the shared pages; refcount rises
    assert pool.can_alloc(12, shared_pages=shared)
    pool.alloc(1, 12, shared_pages=shared)
    assert pool.tables[1][:2].tolist() == shared
    assert pool.refcount[shared].tolist() == [3, 3]
    pool.check_invariants()

    # original holder retires: shared pages stay (slot 1 + index hold them)
    pool.release(0)
    assert pool.refcount[shared].tolist() == [2, 2]
    assert not set(shared) & set(pool._free)
    pool.release(1)
    assert pool.refcount[shared].tolist() == [1, 1]  # index only: cached
    assert not set(shared) & set(pool._free)
    pool.check_invariants()
    assert pool.n_free + pool.n_cached == spec.n_pages - 1

    # a same-prefix lookup still hits after every holder retired
    assert pool.lookup_prefix(prompt) == shared


def test_prefix_cache_lookup_is_strict_prefix():
    """A lookup never covers the whole prompt (the suffix prefill must
    keep >= 1 token) and never matches when any earlier token differs."""
    spec = PageSpec(n_pages=13, page_size=4, max_pages=6)
    pool = PagePool(spec, n_slots=2, prefix_cache=True)
    prompt = np.arange(8, dtype=np.int32)            # exactly 2 pages
    pool.alloc(0, 8)
    pool.register_prefix(prompt, 0)
    # identical prompt: only the first page may be reused (strict prefix)
    assert len(pool.lookup_prefix(prompt)) == 1
    # longer prompt with the same head: both pages hit
    assert len(pool.lookup_prefix(np.arange(12, dtype=np.int32))) == 2
    # same second page content but different first page: no hit at all
    other = np.concatenate([np.full(4, 99, np.int32),
                            np.arange(4, 8, dtype=np.int32), [1]])
    assert pool.lookup_prefix(other) == []


def test_eviction_prefers_chain_leaves():
    """Evicting a cached chain drops its deepest entry first: taking the
    head would strand the descendants — unreachable via lookup (which
    walks from page 0) yet still holding pages."""
    spec = PageSpec(n_pages=4, page_size=4, max_pages=3)
    pool = PagePool(spec, n_slots=1, prefix_cache=True)
    prompt = np.arange(12, dtype=np.int32)           # 3 full pages
    pool.alloc(0, 12)
    pool.register_prefix(prompt, 0)
    chain = pool.lookup_prefix(np.arange(16, dtype=np.int32))
    assert len(chain) == 3
    pool.release(0)                                  # index-only: evictable
    pool._evict_one()
    pool.check_invariants()
    # the 2-page head of the chain is still reachable, the leaf is gone
    assert pool.lookup_prefix(np.arange(16, dtype=np.int32)) == chain[:2]
    pool._evict_one()
    assert pool.lookup_prefix(np.arange(16, dtype=np.int32)) == chain[:1]
    pool.check_invariants()


def test_prefix_cache_eviction_under_pressure(tiny_lm):
    """A pool too small to cache every distinct prefix evicts index-only
    pages to admit new work; everything completes and conserves pages."""
    rng = np.random.default_rng(4)
    # 11 allocatable pages; each request needs 3 pages (prompt 32 + 8 new)
    eng = ContinuousEngine(CFG, tiny_lm, n_slots=2, max_len=48, page_size=16,
                           n_pages=12, prefill_bucket=8, prefix_share=True)
    for i in range(6):                               # 6 distinct prefixes
        prompt = rng.integers(0, CFG.vocab_size, 32 + (i % 2))
        eng.submit(prompt, max_new=8, arrival=float(i))
    done = eng.run(max_steps=2000)
    assert len(done) == 6 and all(r.done and not r.rejected for r in done)
    eng.pool.check_invariants()
    assert np.all(eng.pool.tables == -1)
    assert eng.pool.n_free + eng.pool.n_cached == eng.spec.n_pages - 1


def test_pool_fuzz_invariants_hold_every_step(tiny_lm):
    """Randomized traffic with colliding prefixes (tiny alphabet): the
    refcount/index/free-list invariants hold after every scheduler step
    and admission never raises."""
    rng = np.random.default_rng(9)
    eng = ContinuousEngine(CFG, tiny_lm, n_slots=3, max_len=64, page_size=8,
                           n_pages=20, prefill_bucket=8, prefix_share=True,
                           chunked_prefill=8)
    for i in range(12):
        plen = int(rng.integers(4, 40))
        prompt = rng.integers(0, 3, plen)            # heavy prefix collisions
        eng.submit(prompt, max_new=int(rng.integers(1, 6)),
                   arrival=float(rng.integers(0, 6)))
    steps = 0
    while not eng.sched.all_done():
        eng.step(float(steps))
        eng.pool.check_invariants()
        assert eng.pool.refcount.min() >= 0
        steps += 1
        assert steps < 1000
    assert len(eng.sched.finished) == 12
