"""Hypothesis property tests on system invariants.

Hypothesis is a dev-only dependency (requirements-dev.txt); skip the whole
module when it isn't installed so tier-1 collection stays green.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.normtweak.losses import l_dist, l_kl, l_mse
from repro.core.quant.smoothquant import (fold_into_norm, scale_weight_rows,
                                          smooth_scales)
from repro.core.quant.types import (dequantize, qmax_for_bits, quantize,
                                    quantize_activation, quantize_stacked)
from repro.models.attention import _cache_write, init_kv_cache
from repro.models.config import ModelConfig


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), shift=st.floats(-2.0, 2.0))
def test_losses_nonnegative_and_monotone_in_shift(seed, shift):
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 8, 8))
    for fn in (l_dist, l_mse, l_kl):
        v0 = float(fn(x, x))
        v1 = float(fn(x, x + shift))
        assert v0 >= -1e-6
        assert v1 >= v0 - 1e-6


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), alpha=st.floats(0.1, 0.9))
def test_smoothquant_scales_positive_and_transform_invertible(seed, alpha):
    key = jax.random.PRNGKey(seed)
    amax = jnp.abs(jax.random.normal(key, (16,))) + 0.1
    w = jax.random.normal(key, (16, 8))
    s = smooth_scales(amax, [w], alpha)
    assert bool(jnp.all(s > 0))
    w2 = scale_weight_rows(scale_weight_rows(w, s), 1.0 / s)
    np.testing.assert_allclose(np.asarray(w2), np.asarray(w), rtol=1e-5,
                               atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(bits=st.sampled_from([2, 4, 8]), seed=st.integers(0, 500))
def test_dequant_never_exceeds_group_amax(bits, seed):
    w = jax.random.normal(jax.random.PRNGKey(seed), (32, 8)) * 3.0
    qt = quantize(w, bits, 8)
    deq = np.asarray(dequantize(qt))
    wg = np.asarray(w).reshape(4, 8, 8)
    dg = deq.reshape(4, 8, 8)
    amax = np.abs(wg).max(axis=1, keepdims=True)
    assert np.all(np.abs(dg) <= amax + 1e-5)


@settings(max_examples=10, deadline=None)
@given(window=st.sampled_from([4, 8]), n=st.integers(5, 20))
def test_ring_cache_holds_last_window_positions(window, n):
    cfg = ModelConfig(d_model=16, n_heads=2, n_kv_heads=2, head_dim=8)
    cache = init_kv_cache(cfg, 1, 64, window=window)
    for t in range(n):
        k = jnp.full((1, 1, 2, 8), float(t))
        pos = jnp.full((1, 1), t, jnp.int32)
        cache = _cache_write(cache, k, k, pos)
    held = sorted(int(p) for p in np.asarray(cache["pos"][0]) if p >= 0)
    expect = list(range(max(0, n - window), n))
    assert held == expect
    # values stored where expected
    slot = (n - 1) % window
    assert float(cache["k"][0, slot, 0, 0]) == float(n - 1)


@settings(max_examples=12, deadline=None)
@given(bits=st.sampled_from([2, 4]),
       e=st.integers(1, 3),
       k=st.sampled_from([16, 32]),
       n=st.sampled_from([8, 16]),
       seed=st.integers(0, 2 ** 16))
def test_property_packed_grid_survives_expert_kernel_exactly(bits, e, k, n,
                                                             seed):
    """Random int2/int4 grids round-trip quantize_stacked -> the Pallas
    expert dequant kernel bit-exactly: with every column's amax pinned to
    qmax the scale is exactly 1.0, so pack/unpack, the bf16 cast (integers
    <= 127 are exact), and the one-hot identity matmul add no error."""
    qmax = qmax_for_bits(bits)
    rng = np.random.default_rng(seed)
    q = rng.integers(-qmax, qmax + 1, size=(e, k, n))
    q[:, 0, :] = qmax                                  # pin scale to 1.0
    w = jnp.asarray(q, jnp.float32)
    qt = quantize_stacked(w, bits, -1)
    eye = jnp.broadcast_to(jnp.eye(k, dtype=jnp.float32), (e, k, k))

    from repro.kernels import ops
    deq = ops.expert_dequant_matmul(eye, qt, out_dtype=jnp.float32)
    assert np.array_equal(np.asarray(deq), np.asarray(w))
    # the jnp unpack path agrees bit-exactly too
    assert np.array_equal(np.asarray(dequantize(qt)), np.asarray(w))


@settings(max_examples=15, deadline=None)
@given(bits=st.sampled_from([2, 3]),
       k=st.sampled_from([16, 24, 32, 64]),
       n=st.sampled_from([8, 16]),
       seed=st.integers(0, 2 ** 16))
def test_property_subbyte_pack_roundtrip_through_kernel(bits, k, n, seed):
    """Random sub-byte grids survive quantize -> bit-pack -> inline kernel
    unpack exactly: with scales pinned to 1.0 the W2/W3 word packing (4
    values/byte, 8 values per 3-byte group) and the dense dequant kernel's
    word reassembly must reproduce every code verbatim — the storage layer
    under the speculative draft. Both the Pallas path and the jnp unpack
    must agree bit-exactly with the source grid."""
    qmax = qmax_for_bits(bits)
    rng = np.random.default_rng(seed)
    q = rng.integers(-qmax, qmax + 1, size=(k, n))
    q[0, :] = qmax                                     # pin scale to 1.0
    w = jnp.asarray(q, jnp.float32)
    qt = quantize(w, bits, -1)
    # packed density: never more than bits/8 bytes per value (+ pad group)
    from repro.core.quant.types import pack_layout
    bpg, vpg = pack_layout(bits)
    assert qt.qw.shape[-2] == -(-k // vpg) * bpg
    assert np.array_equal(np.asarray(dequantize(qt)), np.asarray(q))

    from repro.kernels import ops
    deq = ops.dequant_matmul(jnp.eye(k, dtype=jnp.float32), qt,
                             out_dtype=jnp.float32)
    assert np.array_equal(np.asarray(deq), np.asarray(q))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 16),
       t=st.sampled_from([1, 3, 16]),
       k=st.sampled_from([32, 128]),
       mag=st.floats(1e-3, 1e3))
def test_property_activation_quantize_error_bounded(seed, t, k, mag):
    """int8 activation quantize-dequant error is bounded by scale/2
    elementwise: every row amax lands exactly on the grid, so rounding —
    never clipping — is the only error source (the W8A8 rescale premise)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (t, k)) * mag
    q, scale = quantize_activation(x, 8)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(q, np.float32) * np.asarray(scale) -
                 np.asarray(x))
    bound = np.asarray(scale) / 2 + 1e-6 * mag
    assert np.all(err <= bound)


@settings(max_examples=25, deadline=None)
@given(bits=st.sampled_from([2, 4, 8]),
       k=st.sampled_from([16, 32, 64]),
       n=st.sampled_from([8, 24]),
       seed=st.integers(0, 2 ** 16))
def test_property_quantize_bounded_and_symmetric(bits, k, n, seed):
    """Moved from test_quant_types.py so that module stays hypothesis-free."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n))
    qt = quantize(w, bits)
    deq = np.asarray(dequantize(qt))
    qmax = qmax_for_bits(bits)
    scale = np.asarray(qt.scale)[0]
    # dequantized values lie on the symmetric grid within qmax steps
    assert np.all(np.abs(deq) <= scale * qmax + 1e-6)
    # negating the input negates the quantization (symmetric grid)
    qt_neg = quantize(-w, bits)
    np.testing.assert_allclose(np.asarray(dequantize(qt_neg)), -deq, atol=1e-5)
