"""Unit tests for kernels/autotune.py: the deterministic fallback table,
shape-class bucketing, JSON cache hygiene (version hash, corrupt files,
invalid entries), and the measured REPRO_AUTOTUNE=1 search."""
import json

import pytest

from repro.kernels import autotune, template


@pytest.fixture(autouse=True)
def _fresh_cache_state():
    """Each test starts and ends with a cold in-memory cache."""
    autotune.reset()
    yield
    autotune.reset()


# ------------------------------------------------ deterministic fallback

def test_cold_cache_resolution_is_the_fallback_table(monkeypatch):
    """Default mode with no cache file resolves every shape class from the
    deterministic table — and does so identically on repeat calls (the
    replay-twice / sanitizer contract)."""
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    monkeypatch.delenv("REPRO_AUTOTUNE_CACHE", raising=False)
    shapes = [(1, 128, 256, 4, 32), (64, 256, 512, 2, -1),
              (9, 128, 128, 8, 64)]
    for kind in ("dequant", "expert_dequant", "w8a8", "expert_w8a8"):
        for m, k, n, bits, gs in shapes:
            want = autotune.fallback_matmul_plan(
                m, k, n, bits=bits, group_size=gs, bm=128, bn=256, bk=256)
            got = autotune.matmul_plan(kind, m, k, n, bits=bits,
                                       group_size=gs)
            assert got == want
            assert autotune.matmul_plan(kind, m, k, n, bits=bits,
                                        group_size=gs) == got
    assert autotune.paged_tile(16, "bf16", 1) == 16
    assert autotune.paged_tile(512, "int8", 4) == 256


def test_mode_zero_ignores_a_warm_cache(tmp_path, monkeypatch):
    """REPRO_AUTOTUNE=0 pins the table even when a valid warm cache entry
    exists (CI / deterministic replay)."""
    path = str(tmp_path / "tune.json")
    key = autotune.matmul_key("dequant", 4, 128, 256, 4, 32)
    autotune.save_cache(path, {key: {"bm": 8, "bn": 128, "bk": 64}})
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    want = autotune.fallback_matmul_plan(4, 128, 256, bits=4, group_size=32,
                                         bm=128, bn=256, bk=256)
    assert autotune.matmul_plan("dequant", 4, 128, 256, bits=4,
                                group_size=32) == want


def test_pick_bk_per_channel_fast_path():
    """group_size == K (per-channel) takes the direct largest-divisor path:
    the halving loop could only ever return K itself (regression: W4 g=-1
    at K=1012 ran one whole-K block instead of 11 x 92-row blocks)."""
    assert autotune.pick_bk(1012, 1012, 4, 256) == 92
    assert autotune.pick_bk(128, 128, 4, 256) == 128
    assert autotune.pick_bk(24, 24, 8, 256) == 24
    # K not a multiple of the byte group can never pack
    assert autotune.pick_bk(1012, 1012, 8, 256) is None
    # no >= 8-row divisor under the target: one whole-K block
    assert autotune.pick_bk(1012, 1012, 4, 8) == 1012


# ------------------------------------------------------- shape-class keys

def test_m_bucket_collapses_decode_and_pow2():
    assert [autotune.m_bucket(m) for m in (1, 3, 8)] == [8, 8, 8]
    assert autotune.m_bucket(9) == 16
    assert autotune.m_bucket(16) == 16
    assert autotune.m_bucket(17) == 32


def test_shape_class_keys():
    k1 = autotune.matmul_key("dequant", 1, 256, 512, 4, 32)
    k8 = autotune.matmul_key("dequant", 8, 256, 512, 4, 32)
    k9 = autotune.matmul_key("dequant", 9, 256, 512, 4, 32)
    assert k1 == k8 and k8 != k9                       # decode class
    assert autotune.matmul_key("w8a8", 1, 256, 512, 4, 32) != k1
    assert (autotune.paged_key(16, "bf16", 1)
            != autotune.paged_key(16, "int8", 1))
    assert (autotune.paged_key(16, "bf16", 1)
            == autotune.paged_key(16, "bf16", 8))      # m-rows bucket


# ------------------------------------------------------------ cache files

def test_cache_round_trip(tmp_path, monkeypatch):
    path = str(tmp_path / "tune.json")
    key = autotune.matmul_key("dequant", 4, 128, 256, 4, 32)
    autotune.save_cache(path, {key: {"bm": 8, "bn": 128, "bk": 64}})
    assert autotune.load_cache(path) == {key: {"bm": 8, "bn": 128, "bk": 64}}
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    assert autotune.matmul_plan("dequant", 4, 128, 256, bits=4,
                                group_size=32) == (8, 128, 64)
    # shape classes not in the cache still resolve from the table
    want = autotune.fallback_matmul_plan(4, 128, 256, bits=4, group_size=64,
                                         bm=128, bn=256, bk=256)
    assert autotune.matmul_plan("dequant", 4, 128, 256, bits=4,
                                group_size=64) == want


def test_missing_cache_file_is_cold(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "absent.json"))
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    want = autotune.fallback_matmul_plan(1, 128, 256, bits=4, group_size=32,
                                         bm=128, bn=256, bk=256)
    assert autotune.matmul_plan("dequant", 1, 128, 256, bits=4,
                                group_size=32) == want


def test_stale_template_version_is_ignored(tmp_path, caplog):
    path = str(tmp_path / "tune.json")
    key = autotune.matmul_key("dequant", 4, 128, 256, 4, 32)
    payload = {"version": "0" * 16,
               "entries": {key: {"bm": 8, "bn": 128, "bk": 64}}}
    with open(path, "w", encoding="utf-8") as f:
        json.dump(payload, f)
    with caplog.at_level("WARNING"):
        assert autotune.load_cache(path) == {}
    assert "template" in caplog.text


@pytest.mark.parametrize("content", ["{not json", '["a", "list"]',
                                     '{"version": "x"}'])
def test_corrupt_cache_falls_back(tmp_path, caplog, content, monkeypatch):
    """Corrupt / wrong-shape cache files warn and hand over to the table —
    never an exception on the serving path."""
    path = str(tmp_path / "tune.json")
    with open(path, "w", encoding="utf-8") as f:
        f.write(content)
    with caplog.at_level("WARNING"):
        assert autotune.load_cache(path) == {}
    assert "unreadable" in caplog.text or "template" in caplog.text
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    want = autotune.fallback_matmul_plan(4, 128, 256, bits=4, group_size=32,
                                         bm=128, bn=256, bk=256)
    assert autotune.matmul_plan("dequant", 4, 128, 256, bits=4,
                                group_size=32) == want


def test_invalid_cached_entry_is_revalidated_away(tmp_path, monkeypatch,
                                                  caplog):
    """A hand-edited or stale entry that violates the tiling constraints
    can never reach pallas_call: it is dropped with a warning."""
    path = str(tmp_path / "tune.json")
    mk = autotune.matmul_key("dequant", 4, 128, 256, 4, 32)
    pk = autotune.paged_key(16, "bf16", 1)
    autotune.save_cache(path, {mk: {"bm": 8, "bn": 100, "bk": 64},
                               pk: {"tile": 13}})
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    want = autotune.fallback_matmul_plan(4, 128, 256, bits=4, group_size=32,
                                         bm=128, bn=256, bk=256)
    with caplog.at_level("WARNING"):
        assert autotune.matmul_plan("dequant", 4, 128, 256, bits=4,
                                    group_size=32) == want
        assert autotune.paged_tile(16, "bf16", 1) == 16
    assert "violates" in caplog.text


# ------------------------------------------------------- measured search

def test_measured_mode_persists_and_reuses(tmp_path, monkeypatch):
    """REPRO_AUTOTUNE=1 measures real pallas_call candidates, persists the
    winner under the current template version, and the default mode then
    serves it warm."""
    path = str(tmp_path / "tune.json")
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    plan = autotune.matmul_plan("dequant", 4, 128, 128, bits=4,
                                group_size=32)
    assert plan is not None
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    assert data["version"] == template.TEMPLATE_VERSION
    key = autotune.matmul_key("dequant", 4, 128, 128, 4, 32)
    assert data["entries"][key] == {"bm": plan[0], "bn": plan[1],
                                    "bk": plan[2]}
    monkeypatch.setenv("REPRO_AUTOTUNE", "")
    autotune.reset()
    assert autotune.matmul_plan("dequant", 4, 128, 128, bits=4,
                                group_size=32) == plan


def test_measured_mode_untileable_shape_returns_none(monkeypatch):
    """No candidate lowers (K=18, gs=2): the search degrades to the
    fallback, which is None — callers take the jnp reference."""
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    monkeypatch.delenv("REPRO_AUTOTUNE_CACHE", raising=False)
    assert autotune.matmul_plan("dequant", 4, 18, 16, bits=2,
                                group_size=2) is None


def test_measured_paged_tile(tmp_path, monkeypatch):
    path = str(tmp_path / "tune.json")
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    tile = autotune.paged_tile(128, "bf16", 1)
    assert tile in (64, 128)
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    assert data["entries"][autotune.paged_key(128, "bf16", 1)] == \
        {"tile": tile}
