"""Training substrate: convergence, grad accumulation, compression, schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TINY
from repro.data.pipeline import DataPipeline
from repro.data.synthetic import make_corpus
from repro.models.transformer import init_lm
from repro.optim.adam import adam_init, adam_update, clip_by_global_norm
from repro.optim.compression import compress_decompress
from repro.optim.schedules import constant, linear_decay, warmup_cosine
from repro.train.train_step import init_opt_state, make_train_step

CFG = TINY.replace(n_repeats=2, d_model=64, head_dim=16, d_ff=128)


def _train(cfg, steps, **kw):
    corpus, _ = make_corpus(cfg.vocab_size, 30_000, seed=0)
    params = init_lm(cfg, jax.random.PRNGKey(0))
    pipe = DataPipeline(corpus, batch_size=8, seq_len=32, seed=0)
    step_fn = make_train_step(cfg, lr_schedule=constant(3e-3), **kw)
    opt = init_opt_state(cfg, params,
                         grad_compress_bits=kw.get("grad_compress_bits", 0))
    losses = []
    for s in range(steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
        params, opt, m = step_fn(params, opt, batch, jnp.asarray(s),
                                 jax.random.fold_in(jax.random.PRNGKey(9), s))
        losses.append(float(m["loss"]))
    return losses


def test_loss_decreases():
    losses = _train(CFG, 30)
    assert losses[-1] < losses[0] * 0.9


def test_grad_compression_still_converges():
    """int8 compression + error feedback must not break optimization."""
    plain = _train(CFG, 30)
    comp = _train(CFG, 30, grad_compress_bits=8)
    assert comp[-1] < comp[0] * 0.9
    assert abs(comp[-1] - plain[-1]) < 0.5


def test_grad_accumulation_matches_full_batch():
    cfg = CFG
    corpus, _ = make_corpus(cfg.vocab_size, 30_000, seed=0)
    params = init_lm(cfg, jax.random.PRNGKey(0))
    pipe = DataPipeline(corpus, batch_size=8, seq_len=32, seed=0)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    rng = jax.random.PRNGKey(9)

    f_full = make_train_step(cfg, lr_schedule=constant(1e-3), donate=False)
    f_acc = make_train_step(cfg, lr_schedule=constant(1e-3), accum_steps=4,
                            donate=False)
    o1 = init_opt_state(cfg, params)
    o2 = init_opt_state(cfg, params)
    p1, _, m1 = f_full(params, o1, batch, jnp.asarray(0), rng)
    p2, _, m2 = f_acc(params, o2, batch, jnp.asarray(0), rng)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
    d = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2)))
    assert d < 1e-4


def test_adam_decreases_quadratic():
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = adam_init(params)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}
        params, state = adam_update(grads, state, params, lr=0.1)
    assert float(jnp.max(jnp.abs(params["x"]))) < 0.2


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(20.0)
    from repro.optim.adam import global_norm
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_compression_error_feedback_is_lossless_in_sum():
    """error feedback: quantization error is carried, not dropped."""
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=64),
                          jnp.float32)}
    ef = {"w": jnp.zeros((64,))}
    total_sent = jnp.zeros((64,))
    for i in range(50):
        deq, ef = compress_decompress(g, ef, bits=4,
                                      rng=jax.random.PRNGKey(i))
        total_sent = total_sent + deq["w"]
    # average transmitted gradient converges to the true gradient
    np.testing.assert_allclose(np.asarray(total_sent / 50),
                               np.asarray(g["w"]), atol=0.05)


def test_schedules():
    sc = warmup_cosine(1.0, 10, 100)
    assert float(sc(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(sc(jnp.asarray(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(sc(jnp.asarray(100))) == pytest.approx(0.0, abs=1e-3)
    ld = linear_decay(1.0, 100)
    assert float(ld(jnp.asarray(50))) == pytest.approx(0.5)
