"""Self-speculative decoding tests.

The core contract: greedy spec-decode emits only target argmaxes, so its
token stream is *identical* to target-only ContinuousEngine decode — the
draft can only change how many target forwards it takes, never the output.
Verified across the architecture zoo (dense/GQA/SWA, int8 KV on/off) and
both paged-attention read impls, plus acceptance-rule unit tests and
engine gating/accounting checks.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TINY
from repro.models.transformer import init_lm
from repro.serve.engine import ContinuousEngine
from repro.serve.sampling import spec_accept_greedy, spec_accept_sample

CFG = TINY.replace(n_repeats=2, d_model=64, head_dim=16, d_ff=128)


@pytest.fixture(scope="module")
def tiny_lm():
    return init_lm(CFG, jax.random.PRNGKey(0))


def _reqs(rng):
    return [(rng.integers(0, CFG.vocab_size, plen), max_new)
            for plen, max_new in [(8, 5), (13, 6), (24, 4)]]


def _run(cfg, params, reqs, **kw):
    eng = ContinuousEngine(cfg, params, n_slots=3, max_len=64, page_size=8,
                           prefill_bucket=8, **kw)
    for i, (prompt, max_new) in enumerate(reqs):
        eng.submit(prompt, max_new=max_new, arrival=float(i % 2))
    done = eng.run(max_steps=500)
    return [r.tokens for r in done], eng


def test_spec_decode_greedy_identity_zoo():
    """Greedy spec-decode tokens are bit-identical to target-only decode
    across dense MHA, GQA, sliding-window, and int8-KV — on both the fused
    verify kernel and the gathered-context read."""
    variants = [
        ("dense", CFG),
        ("gqa", CFG.replace(n_kv_heads=2)),
        ("swa", CFG.replace(attn_window=12)),
        ("int8-kv", CFG.replace(kv_cache_bits=8)),
        ("gqa-swa-int8", CFG.replace(n_kv_heads=2, attn_window=12,
                                     kv_cache_bits=8)),
    ]
    reqs = _reqs(np.random.default_rng(7))
    for name, cfg in variants:
        params = init_lm(cfg, jax.random.PRNGKey(0))
        base, _ = _run(cfg, params, reqs)
        for impl in ("fused", "gather"):
            spec, eng = _run(cfg, params, reqs, paged_attn=impl,
                             spec_decode=True, draft_bits=2, spec_k=4)
            assert spec == base, f"{name}/{impl} diverged from target-only"
            # both page pools drain (the draft cache shares the allocator)
            assert eng.pool.n_free == eng.spec.n_pages - 1


def test_spec_decode_full_acceptance_and_stats():
    """A draft quantized exactly like the target proposes the target's own
    argmaxes — every draft token is accepted, the stream still matches the
    W3 target-only engine, and the stats see the speedup."""
    reqs = _reqs(np.random.default_rng(7))
    params = init_lm(CFG, jax.random.PRNGKey(0))
    base, beng = _run(CFG, params, reqs, quant_bits=3)
    spec, eng = _run(CFG, params, reqs, quant_bits=3, spec_decode=True,
                     draft_bits=3, spec_k=4)
    assert spec == base
    st = eng.spec_stats()
    assert st["acceptance_rate"] == 1.0
    assert st["draft_tokens"] > 0
    # spec rounds emit everything except each request's first token
    # (sampled at prefill)
    assert st["emitted_tokens"] == sum(len(t) for t in spec) - len(reqs)
    assert st["mean_accepted_len"] > 1.0
    # one target forward per spec round; full acceptance means strictly
    # fewer target forwards than the token-at-a-time baseline would need
    assert eng.n_decode_steps == st["rounds"]
    assert st["rounds"] < beng.n_decode_steps


def test_spec_decode_temperature_smoke():
    """temperature>0 residual resampling: budgets respected, pool drains,
    per-slot accounting consistent (the stream itself is distribution- not
    bit-matched, so only invariants are asserted)."""
    reqs = _reqs(np.random.default_rng(3))
    params = init_lm(CFG, jax.random.PRNGKey(0))
    toks, eng = _run(CFG, params, reqs, spec_decode=True, draft_bits=2,
                     spec_k=4, temperature=0.8, top_k=20)
    for t, (_, max_new) in zip(toks, reqs):
        assert 0 < len(t) <= max_new
    assert eng.pool.n_free == eng.spec.n_pages - 1
    st = eng.spec_stats()
    assert st["emitted_tokens"] == sum(len(t) for t in toks) - len(reqs)
    assert 0.0 <= st["acceptance_rate"] <= 1.0


def test_spec_decode_gating():
    params = init_lm(CFG, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="draft_bits"):
        ContinuousEngine(CFG, params, spec_decode=True, draft_bits=8)
    with pytest.raises(ValueError, match="spec_k"):
        ContinuousEngine(CFG, params, spec_decode=True, spec_k=0)
    from repro.models.config import LayerSpec, MoEConfig
    moe = CFG.replace(pattern=(LayerSpec(kind="attn", mlp="moe"),),
                      moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64,
                                    capacity_factor=1.0))
    with pytest.raises(NotImplementedError, match="MoE"):
        ContinuousEngine(moe, init_lm(moe, jax.random.PRNGKey(0)),
                         spec_decode=True)
    with pytest.raises(NotImplementedError):
        ContinuousEngine(CFG, params, spec_decode=True, prefix_share=True)


def test_spec_decode_refuses_prepacked_params():
    """The draft is requantized from float params; a pre-packed tree can't
    be re-packed at a different width."""
    from repro.core.quant.deploy import quantize_params_for_serving
    params = init_lm(CFG, jax.random.PRNGKey(0))
    packed = quantize_params_for_serving(CFG, params, bits=4, group_size=32)
    with pytest.raises(ValueError, match="float params"):
        ContinuousEngine(CFG, packed, spec_decode=True)


# ------------------------------------------------------ acceptance rules

def test_spec_accept_greedy_prefix_rule():
    v = 16
    t = np.array([[3, 5, 7, 9], [1, 1, 1, 1]])         # target argmaxes
    logits = np.full((2, 4, v), -10.0, np.float32)
    for s in range(2):
        for m in range(4):
            logits[s, m, t[s, m]] = 10.0
    # slot 0: drafts match rows 0-1 then diverge; slot 1: all match
    drafts = jnp.asarray([[3, 5, 0], [1, 1, 1]], jnp.int32)
    out, n_emit = spec_accept_greedy(jnp.asarray(logits), drafts)
    assert np.array_equal(np.asarray(out), t)          # always the argmaxes
    assert np.asarray(n_emit).tolist() == [3, 4]       # 2 accepted + 1 free


def test_spec_accept_greedy_single_row():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(3, 1, 8)),
                         jnp.float32)
    out, n_emit = spec_accept_greedy(logits, jnp.zeros((3, 0), jnp.int32))
    assert np.array_equal(np.asarray(out)[:, 0],
                          np.asarray(jnp.argmax(logits[:, 0], -1)))
    assert np.asarray(n_emit).tolist() == [1, 1, 1]


def test_spec_accept_sample_identical_dists_accept_all():
    """p_draft == p_target => accept probability min(1, p_t/p_d) = 1 on
    every row: all drafts emitted plus a bonus token."""
    rng = np.random.default_rng(5)
    tl = jnp.asarray(rng.normal(size=(3, 5, 32)), jnp.float32)
    drafts = jnp.asarray(rng.integers(0, 32, size=(3, 4)), jnp.int32)
    out, n_emit = spec_accept_sample(tl, tl[:, :-1], drafts,
                                     jax.random.PRNGKey(0), temperature=0.7,
                                     top_k=0)
    assert np.asarray(n_emit).tolist() == [5, 5, 5]
    assert np.array_equal(np.asarray(out)[:, :4], np.asarray(drafts))


def test_spec_accept_sample_rejecting_draft():
    """A draft proposing tokens the target gives ~zero mass is rejected at
    row 0; the resample must come from the target's residual support."""
    v = 16
    tl = np.full((1, 3, v), -30.0, np.float32)
    tl[:, :, 2] = 5.0                                   # target: token 2
    dl = np.full((1, 2, v), -30.0, np.float32)
    dl[:, :, 9] = 5.0                                   # draft: token 9
    drafts = jnp.asarray([[9, 9]], jnp.int32)
    out, n_emit = spec_accept_sample(jnp.asarray(tl), jnp.asarray(dl),
                                     drafts, jax.random.PRNGKey(1),
                                     temperature=1.0)
    assert np.asarray(n_emit).tolist() == [1]
    assert int(np.asarray(out)[0, 0]) == 2


# hypothesis property: greedy acceptance is lossless by construction —
# whatever the draft proposes, the emitted prefix is exactly the target
# argmax sequence. Guarded dev-only import (see tests/test_property.py).
try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                                      # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:

    @settings(max_examples=50, deadline=None)
    @given(seed=st.integers(0, 2 ** 16), s=st.integers(1, 4),
           m=st.integers(1, 6), v=st.integers(2, 33),
           adversarial=st.booleans())
    def test_property_greedy_acceptance_lossless(seed, s, m, v, adversarial):
        """For random target logits and *any* draft — random, or an
        adversarial copy of the argmaxes with one flipped position — every
        emitted token equals the target argmax and n_emit never exceeds
        the first divergence + 1."""
        rng = np.random.default_rng(seed)
        logits = jnp.asarray(rng.normal(size=(s, m, v)), jnp.float32)
        t = np.asarray(jnp.argmax(logits, -1))
        if adversarial and m > 1:
            drafts = t[:, :-1].copy()
            flip = rng.integers(0, m - 1)
            drafts[:, flip] = (drafts[:, flip] + 1) % v
        else:
            drafts = rng.integers(0, v, size=(s, m - 1))
        out, n_emit = spec_accept_greedy(logits,
                                         jnp.asarray(drafts, jnp.int32))
        out, n_emit = np.asarray(out), np.asarray(n_emit)
        for si in range(s):
            n = int(n_emit[si])
            assert 1 <= n <= m
            # lossless: the emitted prefix is the target's own stream
            assert np.array_equal(out[si, :n], t[si, :n])
            # and n is exactly (first draft divergence) + 1
            div = m - 1
            for j in range(m - 1):
                if drafts[si, j] != t[si, j]:
                    div = j
                    break
            assert n == div + 1
