"""GPTQ solver tests: error-compensation beats RTN under the data metric."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant.gptq import (gptq_quantize, gptq_quantize_array,
                                   hessian_from_inputs)
from repro.core.quant.types import dequantize, fake_quant


def _data_mse(w, wq, x):
    y = x @ w
    yq = x @ wq
    return float(jnp.mean((y - yq) ** 2))


@pytest.mark.parametrize("bits,gs", [(2, 16), (3, -1), (4, -1)])
def test_gptq_beats_rtn_on_data_loss(bits, gs):
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (256, 64)) * jnp.linspace(0.2, 3.0, 64)  # anisotropic
    w = jax.random.normal(k2, (64, 32)) * 0.2
    h = hessian_from_inputs(x)
    qt, _ = gptq_quantize(w, h, bits=bits, group_size=gs)
    wq_gptq = dequantize(qt)
    wq_rtn = fake_quant(w, bits, gs)
    assert _data_mse(w, wq_gptq, x) < _data_mse(w, wq_rtn, x)


def test_gptq_identity_hessian_close_to_rtn():
    """With an isotropic Hessian there is nothing to compensate: GPTQ ~ RTN."""
    key = jax.random.PRNGKey(1)
    w = jax.random.normal(key, (32, 16)) * 0.3
    h = jnp.eye(32) * 2.0
    q, scale, _ = gptq_quantize_array(w, h, bits=8, group_size=-1, damp=1e-6)
    deq = q.astype(jnp.float32).reshape(1, 32, 16) * scale[:, None, :]
    np.testing.assert_allclose(np.asarray(deq[0]),
                               np.asarray(fake_quant(w, 8, -1)), atol=1e-4)


def test_gptq_actorder_runs_and_unpermutes():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (128, 32)) * jnp.linspace(0.1, 2.0, 32)
    w = jax.random.normal(key, (32, 16)) * 0.2
    h = hessian_from_inputs(x)
    qt, _ = gptq_quantize(w, h, bits=4, actorder=True)
    wq = dequantize(qt)
    assert wq.shape == w.shape
    # still a sane approximation after un-permutation
    assert _data_mse(w, wq, x) < _data_mse(w, jnp.zeros_like(w), x)


def test_gptq_experts_vmapped():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (4, 64, 16))
    w = jax.random.normal(key, (4, 16, 8)) * 0.2
    h = jax.vmap(hessian_from_inputs)(x)
    qt, err = gptq_quantize(w, h, bits=4)
    assert qt.qw.shape == (4, 8, 8)
    assert dequantize(qt).shape == w.shape


def test_gptq_dead_columns_survive():
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (64, 16)).at[:, 3].set(0.0)  # dead input 3
    w = jax.random.normal(key, (16, 8)) * 0.2
    h = hessian_from_inputs(x)
    qt, _ = gptq_quantize(w, h, bits=4)
    assert np.all(np.isfinite(np.asarray(dequantize(qt))))
