"""Pallas kernel tests: shape/dtype sweeps vs the pure-jnp ref oracles,
executed in interpret mode on CPU (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant.types import compute_scales, quantize
from repro.kernels import ref
from repro.kernels.channel_stats import channel_stats_pallas
from repro.kernels.dequant_matmul import dequant_matmul_pallas
from repro.kernels.quantize import quantize_pack_pallas


@pytest.mark.parametrize("bits,gs", [(2, -1), (2, 16), (3, -1), (4, -1),
                                     (4, 32), (8, -1), (8, 64)])
@pytest.mark.parametrize("mkn", [(8, 64, 32), (32, 128, 64)])
def test_dequant_matmul_vs_ref(bits, gs, mkn):
    m, k, n = mkn
    kx, kw = jax.random.split(jax.random.PRNGKey(bits * 100 + max(gs, 0)))
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32) * 0.1
    qt = quantize(w, bits, gs)
    y = dequant_matmul_pallas(x, qt.qw, qt.scale, bits=bits, group_size=gs,
                              bm=8, bn=32, bk=32, interpret=True)
    y_ref = ref.dequant_matmul_ref(x, qt.qw, qt.scale, bits=bits,
                                   group_size=gs, k=k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16])
def test_dequant_matmul_dtypes(xdtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 64)).astype(xdtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 32)) * 0.1
    qt = quantize(w, 4, 16)
    y = dequant_matmul_pallas(x, qt.qw, qt.scale, bits=4, group_size=16,
                              bm=16, bn=32, bk=32, interpret=True)
    y_ref = ref.dequant_matmul_ref(x, qt.qw, qt.scale, bits=4, group_size=16,
                                   k=64)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("t,c,bt,bc", [(256, 128, 64, 64), (128, 64, 128, 64),
                                       (512, 32, 256, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_channel_stats_vs_ref(t, c, bt, bc, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(0), (t, c)) * 3 + 1).astype(dtype)
    m_p, v_p = channel_stats_pallas(x, bt=bt, bc=bc, interpret=True)
    m_r, v_r = ref.channel_stats_ref(x)
    np.testing.assert_allclose(np.asarray(m_p), np.asarray(m_r),
                               rtol=1e-2, atol=1e-2)
    np.testing.assert_allclose(np.asarray(v_p), np.asarray(v_r),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("bits,gs", [(2, -1), (2, 32), (4, -1), (4, 64),
                                     (8, -1)])
def test_quantize_pack_vs_ref(bits, gs):
    w = jax.random.normal(jax.random.PRNGKey(7), (128, 64)) * 0.2
    s = compute_scales(w, bits, gs)
    p_pal = quantize_pack_pallas(w, s, bits=bits, group_size=gs, bk=64,
                                 bn=32, interpret=True)
    p_ref = ref.quantize_pack_ref(w, s, bits=bits)
    assert np.array_equal(np.asarray(p_pal), np.asarray(p_ref))


def test_ops_wrapper_pads_tokens():
    import os

    from repro.kernels import ops
    os.environ["REPRO_DEQUANT_IMPL"] = "pallas"
    try:
        x = jax.random.normal(jax.random.PRNGKey(0), (5, 64))  # M=5 pads
        w = jax.random.normal(jax.random.PRNGKey(1), (64, 32)) * 0.1
        qt = quantize(w, 4, 16)
        y = ops.dequant_matmul(x, qt)
        y_ref = ref.dequant_matmul_ref(x, qt.qw, qt.scale, bits=4,
                                       group_size=16, k=64)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-5, atol=1e-5)
    finally:
        os.environ.pop("REPRO_DEQUANT_IMPL", None)
