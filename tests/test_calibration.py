"""Calibration-data generation tests (paper §Calibration Data Generation)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TINY
from repro.core.calibration.generator import (generate_calibration,
                                              random_calibration,
                                              real_calibration)
from repro.data.synthetic import make_corpus
from repro.models.transformer import init_lm

CFG = TINY.replace(n_repeats=2, d_model=64, head_dim=16, d_ff=128)


def test_generated_shape_and_first_token_restriction():
    params = init_lm(CFG, jax.random.PRNGKey(0))
    _, meta = make_corpus(CFG.vocab_size, 20_000, seed=0)
    allowed = meta.top_language_tokens(2)
    calib = generate_calibration(CFG, params, jax.random.PRNGKey(1),
                                 n_samples=6, token_length=24,
                                 allowed_first=allowed, batch_size=4)
    assert calib.shape == (6, 24)
    assert np.all(np.isin(np.asarray(calib[:, 0]), allowed))


def test_generated_v1_unrestricted():
    params = init_lm(CFG, jax.random.PRNGKey(0))
    calib = generate_calibration(CFG, params, jax.random.PRNGKey(2),
                                 n_samples=4, token_length=16)
    assert calib.shape == (4, 16)
    assert int(calib.max()) < CFG.vocab_size


def test_two_stage_sampling_mixes_then_greedy():
    """identical prompts diverge in the stochastic prefix, then settle."""
    params = init_lm(CFG, jax.random.PRNGKey(0))
    c = generate_calibration(CFG, params, jax.random.PRNGKey(3), n_samples=8,
                             token_length=16,
                             allowed_first=np.asarray([7]),
                             stochastic_prefix=4)
    first = np.asarray(c[:, 0])
    assert np.all(first == 7)
    # stochastic region should differ across samples (same first token)
    assert len(np.unique(np.asarray(c[:, 1:4]), axis=0)) > 1


def test_random_and_real_calibration():
    corpus, _ = make_corpus(CFG.vocab_size, 20_000, seed=0)
    r = random_calibration(CFG, jax.random.PRNGKey(4), n_samples=3,
                           token_length=8)
    assert r.shape == (3, 8)
    real = real_calibration(corpus, jax.random.PRNGKey(5), n_samples=3,
                            token_length=8)
    assert real.shape == (3, 8)
    # real windows actually come from the corpus
    flat = np.asarray(real).ravel()
    assert np.isin(flat, corpus).all()
