"""Quantizer + packing unit tests (hypothesis-free; the property-based
cases live in test_property.py, which skips without hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant.types import (QuantizedTensor, compute_scales,
                                    dequantize, fake_quant, pack,
                                    qmax_for_bits, quantize, quantize_stacked,
                                    quantize_values, unpack)


@pytest.mark.parametrize("bits", [2, 3, 4, 8])
@pytest.mark.parametrize("k,n", [(8, 4), (64, 32), (62, 8)])
def test_pack_unpack_roundtrip(bits, k, n):
    rng = np.random.default_rng(0)
    qmax = qmax_for_bits(bits)
    q = jnp.asarray(rng.integers(-qmax, qmax + 1, size=(k, n)), jnp.int32)
    assert jnp.all(unpack(pack(q, bits), bits, k) == q)


@pytest.mark.parametrize("bits,gs", [(2, -1), (4, -1), (4, 16), (8, 32), (3, -1)])
def test_quantize_dequantize_error_bound(bits, gs):
    key = jax.random.PRNGKey(1)
    w = jax.random.normal(key, (64, 32))
    qt = quantize(w, bits, gs)
    deq = dequantize(qt)
    # max error is half a quantization step per group
    k = 64
    g = qt.scale.shape[0]
    step = np.repeat(np.asarray(qt.scale), k // g, axis=0)
    assert np.all(np.abs(np.asarray(deq - w)) <= step / 2 + 1e-6)


def test_fake_quant_matches_pack_path():
    w = jax.random.normal(jax.random.PRNGKey(2), (32, 16))
    fq = fake_quant(w, 4, 8)
    deq = dequantize(quantize(w, 4, 8))
    np.testing.assert_allclose(np.asarray(fq), np.asarray(deq), atol=1e-6)


def test_fake_quant_idempotent():
    w = jax.random.normal(jax.random.PRNGKey(3), (32, 16))
    s = compute_scales(w, 4, -1)
    once = fake_quant(w, 4, -1, scale=s)
    twice = fake_quant(once, 4, -1, scale=s)
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice), atol=1e-6)


def test_stacked_experts_roundtrip():
    w = jax.random.normal(jax.random.PRNGKey(4), (3, 32, 16))
    qt = quantize_stacked(w, 4, 8)
    assert qt.qw.shape == (3, 16, 16)
    deq = dequantize(qt)
    assert deq.shape == w.shape
    per = [dequantize(quantize(w[i], 4, 8)) for i in range(3)]
    np.testing.assert_allclose(np.asarray(deq), np.stack(per), atol=1e-6)


def test_quantized_tensor_is_pytree():
    w = jax.random.normal(jax.random.PRNGKey(5), (16, 8))
    qt = quantize(w, 4)
    leaves, treedef = jax.tree.flatten(qt)
    assert len(leaves) == 2
    qt2 = jax.tree.unflatten(treedef, leaves)
    assert qt2.bits == 4 and qt2.shape == (16, 8)
    # scan-style leading-dim slicing survives the static aux
    stacked = jax.tree.map(lambda x: jnp.stack([x, x]), qt)
    sliced = jax.tree.map(lambda x: x[0], stacked)
    np.testing.assert_allclose(np.asarray(dequantize(sliced)),
                               np.asarray(dequantize(qt)), atol=1e-6)
