"""Engine-level checks for the fused chunked-prefill paged-attention path:
greedy-token identity against the gather oracle and the monolithic
baseline across the attention zoo, and the structural guarantee that
attention-only archs never dispatch the gather oracle during prefill when
the fused impl is selected."""
import jax
import numpy as np
import pytest

from repro.configs import TINY
from repro.models.transformer import init_lm
from repro.serve.engine import ContinuousEngine

CFG = TINY.replace(n_repeats=2, d_model=64, head_dim=16, d_ff=128)


@pytest.fixture(scope="module")
def tiny_lm():
    return init_lm(CFG, jax.random.PRNGKey(0))


def _shared_prefix_requests(rng, cfg, n_shared=32,
                            tails=((8, 5), (13, 4), (24, 6), (5, 5))):
    system = rng.integers(0, cfg.vocab_size, n_shared)
    return [(np.concatenate([system, rng.integers(0, cfg.vocab_size, t)]), m)
            for t, m in tails]


def _run(cfg, params, reqs, **kw):
    eng = ContinuousEngine(cfg, params, n_slots=3, max_len=128, page_size=16,
                           prefill_bucket=8, **kw)
    for i, (prompt, max_new) in enumerate(reqs):
        eng.submit(prompt, max_new=max_new, arrival=float(i))
    done = eng.run(max_steps=2000)
    return eng, {r.rid: r.tokens for r in done}


def test_fused_prefill_token_identity_zoo(tiny_lm):
    """Chunked + prefix-shared serving under the fused prefill kernel
    emits the same greedy tokens as the gather-oracle impl and as the
    monolithic no-sharing baseline, across dense / GQA / SWA / int8-KV."""
    variants = [
        ("dense", CFG),
        ("gqa", CFG.replace(n_kv_heads=2)),
        ("swa", CFG.replace(attn_window=12)),
        ("int8-kv", CFG.replace(kv_cache_bits=8)),
        ("gqa-swa-int8", CFG.replace(n_kv_heads=2, attn_window=12,
                                     kv_cache_bits=8)),
    ]
    rng = np.random.default_rng(21)
    for name, cfg in variants:
        params = tiny_lm if cfg is CFG else init_lm(cfg, jax.random.PRNGKey(0))
        reqs = _shared_prefix_requests(np.random.default_rng(21), cfg)
        _, base = _run(cfg, params, reqs)
        outs = {}
        for impl in ("fused", "gather"):
            eng, outs[impl] = _run(cfg, params, reqs, paged_attn=impl,
                                   prefix_share=True, chunked_prefill=16)
            eng.pool.check_invariants()
        assert outs["fused"] == base, f"{name}: fused diverged from baseline"
        assert outs["fused"] == outs["gather"], f"{name}: impls diverged"
    del rng


def test_fused_prefill_never_gathers(tiny_lm, monkeypatch):
    """Acceptance: with the fused impl, no phase of an attention-only
    arch's serving loop — fresh prompts, chunked/suffix prefill over prior
    chunks and shared prefix pages, decode — materializes the gathered
    (S, width*page, ...) context view. The gather entry points are
    replaced with tripwires for the whole run."""
    # unique geometry so jit caches from other tests cannot satisfy the
    # traces this run needs (a cached compile would skip the tripwire)
    cfg = CFG.replace(n_kv_heads=2, attn_window=20, kv_cache_bits=8)
    params = init_lm(cfg, jax.random.PRNGKey(0))
    reqs = _shared_prefix_requests(np.random.default_rng(6), cfg)
    _, base = _run(cfg, params, reqs, prefix_share=True, chunked_prefill=16)

    from repro.models import attention as attn_mod

    def tripwire(*a, **kw):
        raise AssertionError("gather oracle dispatched under fused impl")

    monkeypatch.setattr(attn_mod, "gather_pages", tripwire)
    monkeypatch.setattr(attn_mod, "gather_dequant_pages", tripwire)
    eng, out = _run(cfg, params, reqs, paged_attn="fused",
                    prefix_share=True, chunked_prefill=16)
    assert out == base
    eng.pool.check_invariants()
