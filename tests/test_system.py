"""End-to-end behaviour tests for the paper's system: quantize a small LM
with Norm-Tweaking through the full pipeline and serve it."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TINY, get_smoke_config
from repro.core.calibration.generator import random_calibration
from repro.core.normtweak.pipeline import (NTConfig, norm_tweak_ptq,
                                           norm_tweak_ptq_encdec)
from repro.models.encdec import encdec_forward, init_encdec
from repro.models.transformer import init_lm, lm_forward
from repro.serve.engine import ServeEngine

CFG = TINY.replace(n_repeats=2, d_model=64, head_dim=16, d_ff=128)


@pytest.fixture(scope="module")
def quantized_lm():
    params = init_lm(CFG, jax.random.PRNGKey(0))
    calib = random_calibration(CFG, jax.random.PRNGKey(1), n_samples=4,
                               token_length=16)
    nt = NTConfig(method="gptq", bits=4, tweak=True, lr0=1e-4, iters=1,
                  sample_batch=2)
    qp, stats = norm_tweak_ptq(CFG, params, calib, nt)
    return params, qp, stats


def test_full_pipeline_w4_close_to_float(quantized_lm):
    params, qp, _ = quantized_lm
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                CFG.vocab_size)
    lf, _ = lm_forward(CFG, params, tokens)
    lq, _ = lm_forward(CFG, qp, tokens)
    # W4 on a random-init model: logits correlated with float
    cf = np.corrcoef(np.asarray(lf).ravel(), np.asarray(lq).ravel())[0, 1]
    assert cf > 0.85


def test_quantized_model_serves(quantized_lm):
    _, qp, _ = quantized_lm
    eng = ServeEngine(CFG, qp)
    prompts = np.random.default_rng(0).integers(0, CFG.vocab_size, (2, 8))
    res = eng.generate(prompts, max_new=4, temperature=0.0)
    assert res.tokens.shape == (2, 4)


def test_stats_per_layer(quantized_lm):
    _, _, stats = quantized_lm
    assert len(stats["layer_loss"]) == CFG.n_layers
    assert len(stats["layer_lr"]) == CFG.n_layers
    # Eq.3: deeper layers get larger LR
    assert stats["layer_lr"][-1] > stats["layer_lr"][0]


def test_encdec_pipeline_whisper_family():
    cfg = get_smoke_config("whisper-medium")
    params = init_encdec(cfg, jax.random.PRNGKey(0))
    frames = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model)) * .3
    tokens = jax.random.randint(jax.random.PRNGKey(2), (4, 12), 0,
                                cfg.vocab_size)
    nt = NTConfig(method="rtn", bits=4, tweak=True, lr0=1e-4, iters=1,
                  sample_batch=2)
    qp, stats = norm_tweak_ptq_encdec(cfg, params, frames, tokens, nt)
    n_layers = cfg.n_enc_repeats + cfg.n_layers
    assert len(stats["layer_loss"]) == n_layers
    lq, _ = encdec_forward(cfg, qp, frames, tokens)
    assert not bool(jnp.any(jnp.isnan(lq)))


@pytest.mark.parametrize("arch", ["mixtral-8x22b", "deepseek-v2-lite-16b",
                                  "mamba2-2.7b", "jamba-1.5-large-398b"])
def test_nt_pipeline_on_exotic_families(arch):
    """the paper's plugin must run on MoE / MLA / SSM / hybrid blocks."""
    cfg = get_smoke_config(arch)
    params = init_lm(cfg, jax.random.PRNGKey(0))
    calib = random_calibration(cfg, jax.random.PRNGKey(1), n_samples=2,
                               token_length=16)
    nt = NTConfig(method="rtn", bits=4, tweak=True, lr0=1e-4, iters=1,
                  sample_batch=2)
    qp, stats = norm_tweak_ptq(cfg, params, calib, nt)
    lq, _ = lm_forward(cfg, qp, calib)
    assert not bool(jnp.any(jnp.isnan(lq)))
    assert len(stats["layer_loss"]) == cfg.n_layers
