"""AWQ quantizer tests."""
import jax
import jax.numpy as jnp

from repro.core.quant.awq import awq_search_scales
from repro.core.quant.types import fake_quant


def test_awq_beats_plain_rtn_with_activation_outliers():
    key = jax.random.PRNGKey(0)
    d, n, t = 64, 32, 256
    x = jax.random.normal(key, (t, d)).at[:, :4].mul(25.0)
    w = jax.random.normal(key, (d, n)) * 0.2
    y = x @ w

    err_rtn = jnp.mean((y - x @ fake_quant(w, 4, -1)) ** 2)
    s, alpha = awq_search_scales(x, [w], bits=4, group_size=-1)
    wq = fake_quant(w * s[:, None], 4, -1) / s[:, None]
    err_awq = jnp.mean((y - x @ wq) ** 2)
    assert float(err_awq) < float(err_rtn)
    assert 0.0 < alpha <= 1.0  # outliers push the search off alpha=0


def test_awq_alpha_zero_recovers_rtn():
    """without activation skew the search may pick alpha=0 == plain RTN."""
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (128, 32))
    w = jax.random.normal(key, (32, 16)) * 0.2
    s, alpha = awq_search_scales(x, [w], bits=8, group_size=-1)
    assert s.shape == (32,)
    assert bool(jnp.all(s > 0))


def test_awq_block_integration():
    from repro.configs import TINY
    from repro.core.calibration.generator import random_calibration
    from repro.core.normtweak.pipeline import NTConfig, norm_tweak_ptq
    from repro.models.transformer import init_lm, lm_forward

    cfg = TINY.replace(n_repeats=2, d_model=64, head_dim=16, d_ff=128)
    params = init_lm(cfg, jax.random.PRNGKey(0))
    calib = random_calibration(cfg, jax.random.PRNGKey(1), n_samples=2,
                               token_length=16)
    nt = NTConfig(method="awq", bits=4, tweak=True, lr0=1e-4, iters=1,
                  sample_batch=2)
    qp, _ = norm_tweak_ptq(cfg, params, calib, nt)
    lq, _ = lm_forward(cfg, qp, calib)
    assert not bool(jnp.any(jnp.isnan(lq)))
