"""Sharding rules, partitioning, elastic planning (no multi-device needed:
spec construction is pure logic; the 512-device path is launch/dryrun.py)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec

from repro.configs import get_config
from repro.distributed.partitioning import (logical_axes_for,
                                            rules_for_config)
from repro.distributed.sharding import DEFAULT_RULES, spec_for
from repro.launch.elastic import ElasticCoordinator, plan_mesh
from repro.launch.shapes import SHAPES, input_specs, skip_reason


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


MESH = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_spec_for_divisibility_guard():
    rules = dict(DEFAULT_RULES)
    s = spec_for((14, 64), ("heads", "embed"), mesh=MESH, rules=rules)
    assert s == PartitionSpec(None, None)  # 14 % 16 != 0 -> dropped
    s2 = spec_for((32, 64), ("heads", "embed"), mesh=MESH, rules=rules)
    assert s2[0] == "model"


def test_spec_for_duplicate_axis_guard():
    rules = {"a": "model", "b": "model"}
    s = spec_for((32, 32), ("a", "b"), mesh=MESH, rules=rules)
    assert s[0] == "model" and s[1] is None


def test_spec_for_missing_axis_dropped():
    single = FakeMesh({"data": 16, "model": 16})
    rules = {"batch": ("pod", "data")}
    s = spec_for((256, 8), ("batch", None), mesh=single, rules=rules)
    assert s[0] == "data"


def test_param_rules_attention():
    assert logical_axes_for("stack/p0/attn/wq/w", 3) == \
        (None, "embed_fsdp", "heads_flat")
    assert logical_axes_for("prefix/0/mlp/wo/w", 2) == ("mlp", "embed_fsdp")
    assert logical_axes_for("stack/p0/ln1/scale", 2) == (None, None)


def test_moe_rules_switch_on_divisibility():
    ds = get_config("deepseek-v2-lite-16b")     # 64 experts: EP
    r = rules_for_config(ds, MESH)
    assert r["expert"] == "model" and r["expert_ff"] is None
    mx = get_config("mixtral-8x22b")            # 8 experts: expert-TP
    r2 = rules_for_config(mx, MESH)
    assert r2["expert"] is None and r2["expert_ff"] == "model"
    assert r2["capacity"] == "model"


def test_input_specs_all_cells():
    n_ok, n_skip = 0, 0
    for arch in ["qwen2-0.5b", "whisper-medium", "internvl2-2b",
                 "mamba2-2.7b", "jamba-1.5-large-398b"]:
        cfg = get_config(arch)
        for name, shape in SHAPES.items():
            if skip_reason(cfg, shape):
                n_skip += 1
                continue
            specs = input_specs(cfg, shape)
            assert all(hasattr(v, "shape") for v in specs.values())
            n_ok += 1
    assert n_ok >= 15 and n_skip >= 2


def test_elastic_plan_full_and_degraded():
    p = plan_mesh(512, model_parallel=16, chips_per_pod=256)
    assert p.shape == (2, 16, 16) and p.accum_steps == 1
    # lose a host (8 chips): data axis shrinks, accumulation covers batch
    p2 = plan_mesh(512, model_parallel=16, chips_per_pod=256,
                   healthy_chips=504)
    used = 1
    for v in p2.shape:
        used *= v
    assert used <= 504
    assert p2.accum_steps >= 1


def test_elastic_coordinator_eviction():
    coord = ElasticCoordinator(512, model_parallel=16, chips_per_pod=256,
                               straggler_tolerance=2)
    assert coord.straggler(10, 3.0) is None
    plan = coord.straggler(11, 3.1)
    assert plan is not None  # evicted after repeated strikes
    assert coord.healthy < 512
    assert len(coord.events) == 3  # 2 straggler + 1 node_down
