"""Overload discipline: priority/SLO scheduling, preemptive KV spill to
host RAM, and the deterministic traffic-replay harness.

Covers the scheduler units (priority order, aging starvation-freedom,
victim determinism, rejected-vs-preempted accounting), the PagePool
spill/restore lifecycle against check_invariants (shared prefix pages kept
by reference, owned live pages copied, dead tails freed without copy),
token identity for preempted-and-restored requests across the attention
zoo (dense/GQA/SWA/int8-KV, including a page-boundary and a mid-prefill
preemption), and an exact admission/preemption event-sequence regression
on a seeded bursty trace under the virtual clock."""
import jax
import numpy as np
import pytest

from repro.configs import TINY
from repro.models.transformer import init_lm
from repro.serve import traffic
from repro.serve.engine import ContinuousEngine
from repro.serve.kvcache import PagePool, PageSpec
from repro.serve.scheduler import BATCH, INTERACTIVE, Request, Scheduler

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:        # dev-only dependency: tier-1 stays green without
    HAVE_HYPOTHESIS = False

CFG = TINY.replace(n_repeats=2, d_model=64, head_dim=16, d_ff=128)


@pytest.fixture(scope="module")
def tiny_lm():
    return init_lm(CFG, jax.random.PRNGKey(0))


def _req(rid, *, plen=4, max_new=4, arrival=0.0, priority=INTERACTIVE):
    return Request(rid=rid, prompt=np.zeros(plen, np.int32),
                   max_new=max_new, arrival=arrival, priority=priority)


def _pool(n_pages=17, page_size=4, max_pages=4, n_slots=2, **kw):
    spec = PageSpec(n_pages=n_pages, page_size=page_size,
                    max_pages=max_pages)
    return PagePool(spec, n_slots=n_slots, **kw)


def _fake_spill_hook(pool):
    """Scheduler-level stand-in for the engine hook: real pool bookkeeping,
    no data movement."""
    def hook(slot, req, now):
        return pool.spill(slot, req.n_prompt, lambda pages: None)
    return hook


# --------------------------------------------------------- scheduler units

def test_interactive_head_admitted_before_earlier_batch():
    pool = _pool(n_slots=2)
    sched = Scheduler(2, pool)
    sched.submit(_req(0, arrival=0.0, priority=BATCH))
    sched.submit(_req(1, arrival=1.0, priority=INTERACTIVE))
    admitted = sched.admit(1.0)
    # class outranks arrival: the fresher interactive request goes first
    assert [r.rid for _, r in admitted] == [1, 0]
    assert [e[0] for e in sched.events] == ["admit", "admit"]


def test_priority_rejects_bad_class():
    sched = Scheduler(1, _pool(n_slots=1))
    with pytest.raises(ValueError):
        sched.submit(_req(0, priority=7))


def test_aging_promotes_batch_head_starvation_freedom():
    """Under sustained interactive pressure an aged batch request wins the
    next free slot (its promoted class ties, its earlier arrival wins);
    without aging it would wait behind every fresher interactive forever."""
    def drive(age_promote):
        pool = _pool(n_slots=1, n_pages=9)
        sched = Scheduler(1, pool, age_promote=age_promote)
        sched.submit(_req(0, arrival=0.0, priority=BATCH))
        # one interactive request in flight at every instant
        for i in range(1, 6):
            sched.submit(_req(i, arrival=float(i - 1),
                              priority=INTERACTIVE))
        order = []
        for t in range(12):
            for slot, r in sched.admit(float(t)):
                order.append(r.rid)
            if sched.active_slots():
                sched.retire(0, float(t) + 0.5)   # 1-step service time
        return order
    starved = drive(age_promote=None)
    aged = drive(age_promote=3.0)
    # without aging, batch rid 0 runs dead last
    assert starved.index(0) == len(starved) - 1
    # with aging it overtakes interactive requests still waiting
    assert aged.index(0) < len(aged) - 1
    assert sorted(starved) == sorted(aged)        # nobody is lost either way


def test_victim_choice_is_latest_arriving_lower_class():
    pool = _pool(n_slots=2)
    sched = Scheduler(2, pool, preempt_hook=_fake_spill_hook(pool))
    sched.submit(_req(0, arrival=0.0, priority=BATCH))
    sched.submit(_req(1, arrival=1.0, priority=BATCH))
    assert len(sched.admit(1.0)) == 2
    sched.submit(_req(2, arrival=2.0, priority=INTERACTIVE))
    admitted = sched.admit(2.0)
    # the LATEST-arriving batch request (rid 1) is evicted, never rid 0
    assert [r.rid for _, r in admitted] == [2]
    assert ("preempt", 2.0, 1, 1) in sched.events
    victim = sched.queues[BATCH][0]
    assert victim.rid == 1 and victim.spill is not None
    assert victim.n_preempts == 1
    pool.check_invariants()


def test_aged_batch_head_never_preempts():
    """Aging grants admission standing, not eviction rights: a promoted
    batch head blocked on slots must wait, not churn other batch work."""
    pool = _pool(n_slots=1, n_pages=9)
    sched = Scheduler(1, pool, age_promote=2.0,
                      preempt_hook=_fake_spill_hook(pool))
    sched.submit(_req(0, arrival=0.0, priority=BATCH))
    sched.submit(_req(1, arrival=1.0, priority=BATCH))
    assert [r.rid for _, r in sched.admit(1.0)] == [0]
    # rid 1 is long since aged, rid 0 occupies the only slot: no eviction
    assert sched.admit(50.0) == []
    assert sched.n_preemptions == 0
    # once the slot frees, the aged head admits ahead of a fresher true
    # interactive — and that interactive must NOT victimize it in the same
    # admit() call (the engine hasn't even started it yet)
    sched.submit(_req(2, arrival=51.0, priority=INTERACTIVE))
    sched.retire(0, 51.0)
    assert [r.rid for _, r in sched.admit(51.0)] == [1]
    assert sched.n_preemptions == 0
    # ... only on a later tick, after the engine has run it, may it be
    # evicted — progress per admit cycle is what keeps aging meaningful
    assert [r.rid for _, r in sched.admit(52.0)] == [2]
    assert sched.n_preemptions == 1


def test_victim_with_only_shared_pages_skipped_when_short_on_pages():
    """When the shortage is pages (a slot is free), spilling a victim whose
    pages are all shared frees nothing — it must not be churned."""
    pool = _pool(n_slots=2, n_pages=5, max_pages=2, prefix_cache=True)
    sched = Scheduler(2, pool, prefix_share=True,
                      preempt_hook=_fake_spill_hook(pool))
    prompt = np.arange(8, dtype=np.int32)           # 2 full pages
    r0 = Request(rid=0, prompt=prompt, max_new=0, priority=BATCH)
    sched.submit(r0)
    assert len(sched.admit(0.0)) == 1
    pool.register_prefix(prompt, 0)                 # both pages now shared
    assert pool.slot_owned_pages(0) == 0
    # 4 allocatable pages: slot 0 holds 2 (shared with the index), a fresh
    # 2-page interactive request needs 2 fresh but only 2 remain... take
    # them with a second batch request so the pool is truly dry
    sched.submit(_req(1, arrival=1.0, plen=5, max_new=3, priority=BATCH))
    assert len(sched.admit(1.0)) == 1
    sched.submit(_req(2, arrival=2.0, plen=5, max_new=3,
                      priority=INTERACTIVE))
    sched.retire(1, 2.0)                            # slot free, pages still
    pool.alloc(1, 8)                                # ...taken right back
    admitted = sched.admit(2.0)
    # slot 1 is free in the scheduler but the pool is dry; the only victim
    # (slot 0) owns zero pages, so no preemption happens and nothing admits
    assert admitted == []
    assert sched.n_preemptions == 0
    pool.release(1)
    pool.check_invariants()


def test_rejected_vs_preempted_accounting():
    """stats() separates the two unserved-at-some-point populations:
    structurally-impossible requests (rejected, never run) vs requests that
    finished despite a mid-run eviction."""
    pool = _pool(n_slots=2)
    sched = Scheduler(2, pool, preempt_hook=_fake_spill_hook(pool))
    sched.submit(_req(9, plen=20, max_new=20))      # 10 pages > width 4
    sched.submit(_req(0, arrival=0.0, priority=BATCH))
    sched.submit(_req(1, arrival=1.0, priority=BATCH))
    assert len(sched.admit(1.0)) == 2               # wide one rejected
    sched.submit(_req(2, arrival=2.0, priority=INTERACTIVE))
    assert [r.rid for _, r in sched.admit(2.0)] == [2]   # evicts rid 1
    sched.retire(0, 3.0)
    readmitted = sched.admit(3.0)
    assert [r.rid for _, r in readmitted] == [1]
    assert readmitted[0][1].spill is not None       # restore, engine's cue
    assert [e[0] for e in sched.events] == \
        ["reject", "admit", "admit", "preempt", "admit", "restore"]
    sched.retire(readmitted[0][0], 4.0)
    sched.retire(sched.slots.index(
        next(r for r in sched.slots if r and r.rid == 2)), 4.0)
    assert sched.stats() == {"n_preemptions": 1, "n_restored": 1,
                             "n_rejected": 1, "n_finished_ok": 3,
                             "n_finished_preempted": 1, "n_shed": 0,
                             "n_cancelled": 0, "n_quarantined": 0}
    drained = sched.drain_finished()
    assert {r.rid for r in drained} == {9, 0, 1, 2}
    # stats are cumulative: draining must not zero them
    assert sched.stats()["n_preemptions"] == 1
    pool.check_invariants()
    assert np.all(pool.tables == -1)


def test_preempted_request_accumulates_queue_wait():
    pool = _pool(n_slots=1, n_pages=9)
    sched = Scheduler(1, pool, preempt_hook=_fake_spill_hook(pool))
    r0 = _req(0, arrival=0.0, priority=BATCH)
    sched.submit(r0)
    sched.submit(_req(1, arrival=3.0, priority=INTERACTIVE))
    assert len(sched.admit(0.0)) == 1
    assert r0.queue_wait == 0.0
    sched.admit(3.0)                                # preempts r0
    sched.retire(0, 7.0)                            # interactive finishes
    sched.admit(7.0)                                # r0 restored
    # waited 3.0 -> 7.0 while preempted, on top of zero initial wait
    assert r0.queue_wait == 4.0
    assert r0.admitted_at == 0.0                    # first admission only


# ------------------------------------------------ pool spill/restore units

def test_spill_keeps_shared_pages_by_reference():
    """Prefix-index pages never move: the snapshot holds a reference, the
    data stays on device, and a concurrent slot can still stitch them."""
    pool = _pool(n_slots=3, prefix_cache=True)
    prompt = np.arange(9, dtype=np.int32)           # 2 full pages + 1 token
    pool.alloc(0, 12)                               # 3 pages
    pool.register_prefix(prompt, 0)
    shared = pool.lookup_prefix(np.arange(12, dtype=np.int32))
    assert len(shared) == 2
    copied_ids = []
    snap = pool.spill(0, 9, lambda pages: copied_ids.extend(pages) or "host")
    assert [p for _, p in snap.kept] == shared      # by reference, in place
    assert snap.copied == [2] and copied_ids not in ([], None)
    assert set(copied_ids).isdisjoint(shared)
    assert snap.host == "host"
    pool.check_invariants()                         # conservation w/ snapshot
    # while preempted: the shared pages are still live cache hits
    assert pool.lookup_prefix(np.arange(12, dtype=np.int32)) == shared
    pool.alloc(1, 12, shared_pages=shared)
    assert pool.refcount[shared].tolist() == [3, 3]  # index + snap + slot 1
    pool.check_invariants()
    fresh = pool.restore(2, snap)
    assert pool.tables[2, :2].tolist() == shared    # original positions
    assert len(fresh) == 1 and pool.tables[2, 2] == fresh[0]
    assert snap.restored == fresh
    pool.check_invariants()
    pool.release(1)
    pool.release(2)
    pool.check_invariants()


def test_spill_dead_tail_pages_freed_without_copy():
    pool = _pool(n_slots=1)
    pool.alloc(0, 16)                               # 4 pages, budget
    seen = []
    snap = pool.spill(0, 5, lambda pages: seen.extend(pages))
    # 5 live tokens = 2 live pages; 2 dead tail pages freed, never copied
    assert snap.copied == [0, 1] and len(seen) == 2
    assert snap.kept == [] and snap.n_pages == 4 and snap.n_live == 5
    assert pool.n_free == 16                        # everything back
    pool.check_invariants()
    got = pool.restore(0, snap)
    assert len(got) == 2                            # fresh ids for the copied
    assert int(np.sum(pool.tables[0] >= 0)) == 4    # full budget remapped
    pool.check_invariants()


def test_restore_gated_and_raises_when_dry():
    pool = _pool(n_slots=3, n_pages=9)              # 8 allocatable
    pool.alloc(0, 16)                               # 4 pages
    snap = pool.spill(0, 16, lambda pages: pages)
    assert pool.can_restore(snap)
    pool.alloc(1, 16)
    pool.alloc(0, 16)                               # pool now dry
    assert not pool.can_restore(snap)
    with pytest.raises(RuntimeError):
        pool.restore(2, snap)
    pool.release(0)
    assert pool.can_restore(snap)
    pool.restore(2, snap)
    pool.check_invariants()


# ------------------------------------------------------ lifecycle fuzzing

def _fuzz_lifecycle(seed, n_ops=120):
    """Random alloc/register/spill/restore/release traffic on a bare pool;
    check_invariants() must hold after every operation. Spilled snapshots
    must keep exactly the shared pages and copy exactly the owned live
    pages, and a prefix-index page must never reach copy_out."""
    rng = np.random.default_rng(seed)
    spec = PageSpec(n_pages=14, page_size=4, max_pages=4)
    pool = PagePool(spec, n_slots=3, prefix_cache=True)
    prompts = [np.arange(12, dtype=np.int32),
               np.arange(12, dtype=np.int32) + 1]   # colliding families
    live: dict = {}                                 # slot -> n_tokens
    snaps: list = []
    for _ in range(n_ops):
        op = rng.integers(0, 5)
        free_slots = [s for s in range(3)
                      if s not in live and np.all(pool.tables[s] == -1)]
        if op == 0 and free_slots:                  # alloc (maybe shared)
            n_tok = int(rng.integers(1, spec.max_len + 1))
            prompt = prompts[rng.integers(0, 2)]
            shared = (pool.lookup_prefix(prompt)
                      if rng.integers(0, 2) else [])
            shared = shared[:spec.pages_for(n_tok)]
            if pool.can_alloc(n_tok, shared_pages=shared):
                slot = free_slots[0]
                pool.alloc(slot, n_tok, shared_pages=shared)
                live[slot] = n_tok
                if n_tok >= 12 and rng.integers(0, 2) and not shared:
                    pool.register_prefix(prompt, slot)
        elif op == 1 and live:                      # release
            slot = list(live)[rng.integers(0, len(live))]
            pool.release(slot)
            del live[slot]
        elif op == 2 and live:                      # spill
            slot = list(live)[rng.integers(0, len(live))]
            n_live = int(rng.integers(1, live[slot] + 1))
            index_pages = set(pool._prefix_index.values())
            seen = []
            snap = pool.spill(slot, n_live, lambda p: seen.extend(p) or p)
            assert not set(seen) & index_pages, \
                "prefix-index page copied out"
            assert len(seen) == len(snap.copied)
            snaps.append(snap)
            del live[slot]
        elif op == 3 and snaps and free_slots:      # restore
            snap = snaps[rng.integers(0, len(snaps))]
            if pool.can_restore(snap):
                slot = free_slots[0]
                got = pool.restore(slot, snap)
                assert len(got) == len(snap.copied)
                for (pos, page) in snap.kept:
                    assert pool.tables[slot, pos] == page
                snaps.remove(snap)
                live[slot] = snap.n_live
        elif op == 4 and rng.integers(0, 4) == 0:
            pool.clear_prefix_cache()
        pool.check_invariants()
    for slot in list(live):
        pool.release(slot)
    pool.check_invariants()
    # conservation at the end: only snapshot-kept pages remain referenced
    assert pool.n_free == spec.n_pages - 1 - len(
        {p for s in snaps for _, p in s.kept} | set(
            pool._prefix_index.values()))


def test_spill_restore_lifecycle_fuzz_seeded():
    for seed in range(8):
        _fuzz_lifecycle(seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_spill_restore_lifecycle_fuzz_hypothesis(seed):
        _fuzz_lifecycle(seed)


# ------------------------------------------- token identity across the zoo

ZOO = [
    ("dense", CFG),
    ("gqa", CFG.replace(n_kv_heads=2)),
    ("swa", CFG.replace(attn_window=12)),
    ("int8-kv", CFG.replace(kv_cache_bits=8)),
    ("gqa-swa-int8", CFG.replace(n_kv_heads=2, attn_window=12,
                                 kv_cache_bits=8)),
]


def _preempt_engine(cfg, params, **kw):
    return ContinuousEngine(cfg, params, n_slots=1, max_len=40, page_size=8,
                            prefill_bucket=8, decode_block=1, preempt=True,
                            **kw)


def _spill_depth_probe(eng):
    """Wrap the preempt hook to record each spill's live-token count."""
    lives, orig = [], eng.sched.preempt_hook

    def hook(slot, req, now):
        snap = orig(slot, req, now)
        lives.append(snap.n_live)
        return snap
    eng.sched.preempt_hook = hook
    return lives


def test_preempt_restore_token_identity_zoo(tiny_lm):
    """A batch request preempted mid-decode (KV spilled to host, restored
    later) emits greedy tokens bit-identical to an unpreempted run, across
    dense/GQA/SWA/int8-KV — including a preemption landing exactly on a
    page boundary."""
    rng = np.random.default_rng(11)
    batch_p = rng.integers(0, CFG.vocab_size, 8)    # exactly one page
    inter_p = rng.integers(0, CFG.vocab_size, 8)
    boundary_seen = []
    for name, cfg in ZOO:
        params = tiny_lm if cfg is CFG else init_lm(cfg, jax.random.PRNGKey(0))
        solo = {}
        for tag, p, mn in (("batch", batch_p, 20), ("inter", inter_p, 4)):
            eng = _preempt_engine(cfg, params)
            r = eng.submit(p, max_new=mn)
            eng.run(max_steps=500)
            solo[tag] = r.tokens
        # decode_block=1 under the virtual clock: the interactive arrival
        # step picks the exact decode depth the victim is cut at; arrival 8
        # lands cur_len on 16 = 2 full pages (page_size 8)
        for arrival in (4.0, 8.0):
            eng = _preempt_engine(cfg, params)
            lives = _spill_depth_probe(eng)
            victim = eng.submit(batch_p, max_new=20, arrival=0.0, priority=1)
            inter = eng.submit(inter_p, max_new=4, arrival=arrival,
                               priority=0)
            eng.run(max_steps=500)
            assert victim.n_preempts == 1, (name, arrival)
            assert eng.n_spilled_pages > 0 and \
                eng.n_restored_pages == eng.n_spilled_pages
            assert victim.tokens == solo["batch"], \
                f"{name}: preemption at t={arrival} changed victim tokens"
            assert inter.tokens == solo["inter"], \
                f"{name}: preemption changed the preemptor's tokens"
            eng.pool.check_invariants()
            assert np.all(eng.pool.tables == -1)
            boundary_seen.extend(l % 8 == 0 for l in lives)
    # at least one preemption in the sweep cut exactly at a page boundary
    assert any(boundary_seen)


def test_preempt_mid_prefill_resumes_without_recompute(tiny_lm):
    """A victim evicted while its chunked prefill is still running resumes
    at its old progress: no prompt token is prefilled twice and the final
    greedy tokens match the undisturbed run."""
    rng = np.random.default_rng(5)
    long_p = rng.integers(0, CFG.vocab_size, 24)    # 3 chunks of 8
    inter_p = rng.integers(0, CFG.vocab_size, 8)
    base_eng = ContinuousEngine(CFG, tiny_lm, n_slots=1, max_len=40,
                                page_size=8, prefill_bucket=8,
                                decode_block=1, chunked_prefill=8)
    base = base_eng.submit(long_p, max_new=6)
    base_eng.run(max_steps=500)
    eng = _preempt_engine(CFG, tiny_lm, chunked_prefill=8)
    victim = eng.submit(long_p, max_new=6, arrival=0.0, priority=1)
    inter = eng.submit(inter_p, max_new=4, arrival=1.0, priority=0)
    eng.run(max_steps=500)
    assert victim.n_preempts == 1 and not victim.prefill_done
    assert victim.tokens == base.tokens
    assert inter.tokens
    # 24 + 8 prompt tokens total: nothing was re-prefilled after restore
    assert eng.n_prefill_tokens == 32
    eng.pool.check_invariants()


def test_preempt_gates_unsupported_configs(tiny_lm):
    from repro.configs import get_smoke_config

    for arch in ("deepseek-v2-lite-16b", "jamba-1.5-large-398b"):
        cfg = get_smoke_config(arch)
        params = init_lm(cfg, jax.random.PRNGKey(1))
        with pytest.raises(NotImplementedError):
            ContinuousEngine(cfg, params, n_slots=2, max_len=64,
                             page_size=8, preempt=True)
    with pytest.raises(NotImplementedError):
        ContinuousEngine(CFG, tiny_lm, n_slots=2, max_len=64, page_size=8,
                         preempt=True, spec_decode=True)


# ------------------------------------------- deterministic trace replay

def test_trace_replay_deterministic_regression(tiny_lm):
    """The seeded bursty trace through the preempting engine (fused paged
    attention) produces an exact admission/preemption event sequence,
    preemption count, and per-class completion order — the same on every
    machine, because the virtual clock makes scheduling a pure function of
    (trace seed, engine config)."""
    trace = traffic.make_trace(kind="bursty", n=8, rate=1.0, seed=3,
                               vocab_size=CFG.vocab_size, prompt_len=(6, 12),
                               max_new=(3, 6), batch_frac=0.5,
                               burst_len=0.4, idle_len=10.0,
                               burst_rate_mult=8.0)
    for it in trace:
        if it.priority == 1:
            it.max_new = 24                         # batch holds its slot
    runs = []
    for _ in range(2):                              # determinism: run twice
        eng = ContinuousEngine(CFG, tiny_lm, n_slots=2, max_len=48,
                               page_size=8, prefill_bucket=8, n_pages=10,
                               decode_block=2, paged_attn="fused",
                               preempt=True, age_promote=64.0)
        report = traffic.replay(eng, trace, max_steps=5000)
        events = [(e[0], e[2]) for e in eng.sched.events]
        done = [r for r in report["requests"] if not r.rejected]
        by_cls = {c: [r.rid for r in sorted(done, key=lambda r: (
            r.finished_at, r.rid)) if r.priority == c] for c in (0, 1)}
        runs.append((events, eng.sched.stats(), by_cls,
                     {r.rid: r.tokens for r in done}))
        eng.pool.check_invariants()
        assert np.all(eng.pool.tables == -1)
    assert runs[0] == runs[1], "replay is not deterministic"
    events, stats, by_cls, _ = runs[0]
    # the exact decision sequence this trace pins down (regression: any
    # scheduler change that reorders admissions/preemptions must be heard)
    assert events == EXPECTED_EVENTS
    assert stats == EXPECTED_STATS
    assert by_cls == EXPECTED_COMPLETION_ORDER


# pinned decision sequence of the trace above: the second burst's
# interactive pair (rids 4, 6) evicts both running batch requests (3 then
# 1, latest-arriving first), which restore once the burst drains
EXPECTED_EVENTS = [
    ("admit", 0), ("admit", 2), ("admit", 1), ("admit", 3),
    ("preempt", 3), ("admit", 4), ("preempt", 1), ("admit", 6),
    ("restore", 1), ("restore", 3), ("admit", 5), ("admit", 7),
]
EXPECTED_STATS = {"n_preemptions": 2, "n_restored": 2, "n_rejected": 0,
                  "n_finished_ok": 8, "n_finished_preempted": 2,
                  "n_shed": 0, "n_cancelled": 0, "n_quarantined": 0}
EXPECTED_COMPLETION_ORDER = {0: [0, 2, 6, 4], 1: [1, 3, 5, 7]}


def test_traffic_trace_is_seed_deterministic():
    kw = dict(kind="bursty", n=16, rate=2.0, seed=9, vocab_size=101,
              shared_prefix=8)
    a, b = traffic.make_trace(**kw), traffic.make_trace(**kw)
    assert len(a) == 16
    for x, y in zip(a, b):
        assert (x.arrival, x.max_new, x.priority) == \
            (y.arrival, y.max_new, y.priority)
        assert np.array_equal(x.prompt, y.prompt)
        assert np.array_equal(x.prompt[:8], a[0].prompt[:8])  # shared head
    # class mix is a deterministic stride, not a draw
    assert [it.priority for it in a] == [0, 1] * 8
    c = traffic.make_trace(**{**kw, "seed": 10})
    assert any(not np.array_equal(x.prompt, y.prompt) for x, y in zip(a, c))


def test_replay_reports_per_class_latency_bookkeeping(tiny_lm):
    """Satellite: queue-wait and first-token stamps survive retire/drain,
    so per-class TTFT/TPOT percentiles come straight off the requests."""
    trace = traffic.make_trace(kind="uniform", n=6, rate=1.0, seed=2,
                               vocab_size=CFG.vocab_size, prompt_len=(6, 10),
                               max_new=(3, 5), batch_frac=0.5)
    eng = ContinuousEngine(CFG, tiny_lm, n_slots=2, max_len=32, page_size=8,
                           prefill_bucket=8, preempt=True)
    report = traffic.replay(eng, trace, max_steps=2000)
    for r in report["requests"]:
        assert r.done and not r.rejected
        assert r.first_token_at is not None and r.finished_at is not None
        assert r.ttft is not None and r.ttft >= 0
        assert r.queue_wait >= 0
        assert r.finished_at >= r.first_token_at >= r.arrival
        if len(r.tokens) >= 2:
            assert r.tpot is not None and r.tpot >= 0
    cls = report["classes"]
    assert set(cls) <= {"interactive", "batch"}
    for m in cls.values():
        assert m["n_served"] == m["n"] and np.isfinite(m["ttft_p95"])
        assert m["goodput_tok_per_t"] > 0
    assert report["overall"]["n"] == 6
