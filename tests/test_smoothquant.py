"""SmoothQuant: exactness of the float transform + outlier-case benefit."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant.smoothquant import (fold_into_norm, scale_weight_rows,
                                          smooth_scales)
from repro.core.quant.types import fake_quant, fake_quant_activation
from repro.models.config import ModelConfig
from repro.models.norms import apply_norm, init_norm


def test_smoothing_is_exact_in_float():
    cfg = ModelConfig(norm="layernorm")
    key = jax.random.PRNGKey(0)
    d, n = 32, 16
    norm = init_norm(cfg, d)
    norm["scale"] = jax.random.normal(key, (d,)) * 0.1 + 1.0
    norm["bias"] = jax.random.normal(key, (d,)) * 0.1
    w = jax.random.normal(key, (d, n)) * 0.2
    x = jax.random.normal(key, (4, 8, d)) * jnp.linspace(0.1, 8.0, d)

    y_ref = apply_norm(cfg, norm, x) @ w
    amax = jnp.max(jnp.abs(apply_norm(cfg, norm, x).reshape(-1, d)), axis=0)
    s = smooth_scales(amax, [w], alpha=0.5)
    norm2 = fold_into_norm(norm, s)
    w2 = scale_weight_rows(w, s)
    y_smooth = apply_norm(cfg, norm2, x) @ w2
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_smooth),
                               rtol=2e-5, atol=2e-5)


def test_smoothing_reduces_w8a8_error_with_outliers():
    key = jax.random.PRNGKey(1)
    d, n, t = 64, 32, 256
    # activation outliers in a few channels (the LLM.int8 phenomenon)
    x = jax.random.normal(key, (t, d))
    x = x.at[:, :4].mul(30.0)
    w = jax.random.normal(key, (d, n)) * 0.2
    y_ref = x @ w

    def w8a8(xx, ww):
        return fake_quant_activation(xx, 8) @ fake_quant(ww, 8, -1)

    err_plain = jnp.mean((y_ref - w8a8(x, w)) ** 2)
    s = smooth_scales(jnp.max(jnp.abs(x), axis=0), [w], alpha=0.5)
    err_smooth = jnp.mean((y_ref - w8a8(x / s, w * s[:, None])) ** 2)
    assert float(err_smooth) < float(err_plain) * 0.5
