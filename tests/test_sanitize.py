"""Compile-count sanitizer: unit semantics of the trace counters and the
replay-twice regression — the seeded bursty trace from serve/traffic.py
run twice in one process must add zero tracings on the second replay
(every shape bucket already compiled), with every variant compiled
exactly once."""
import jax
import numpy as np
import pytest

from repro.analysis import sanitize
from repro.configs import TINY
from repro.models.transformer import init_lm
from repro.serve.engine import ContinuousEngine
from repro.serve.traffic import make_trace, replay

CFG = TINY.replace(n_repeats=2, d_model=64, head_dim=16, d_ff=128)


@pytest.fixture(scope="module")
def tiny_lm():
    return init_lm(CFG, jax.random.PRNGKey(0))


def test_note_trace_is_gated_by_env(monkeypatch):
    sanitize.reset_trace_counts()
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    sanitize.note_trace("op", bucket=16)
    assert sanitize.trace_counts() == {}
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    sanitize.note_trace("op", bucket=16)
    sanitize.note_trace("op", bucket=16)
    sanitize.note_trace("op", bucket=32)
    counts = sanitize.trace_counts()
    assert counts[("op", (("bucket", 16),))] == 2
    assert counts[("op", (("bucket", 32),))] == 1
    sanitize.reset_trace_counts()


def test_new_traces_and_budget(monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    sanitize.reset_trace_counts()
    sanitize.note_trace("op", bucket=16)
    base = sanitize.trace_counts()
    assert sanitize.new_traces(base) == {}
    sanitize.note_trace("op", bucket=16)
    assert sanitize.new_traces(base) == {("op", (("bucket", 16),)): 1}
    assert sanitize.budget_violations(max_per_key=1) == {
        ("op", (("bucket", 16),)): 2}
    assert sanitize.budget_violations(max_per_key=2) == {}
    sanitize.reset_trace_counts()


def test_seeded_replay_twice_adds_zero_tracings(monkeypatch, tiny_lm):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    sanitize.reset_trace_counts()
    trace = make_trace(kind="bursty", n=16, seed=0,
                       vocab_size=CFG.vocab_size)

    eng = ContinuousEngine(CFG, tiny_lm, n_slots=4)
    rep1 = replay(eng, trace)
    baseline = sanitize.trace_counts()

    eng2 = ContinuousEngine(CFG, tiny_lm, n_slots=4)
    rep2 = replay(eng2, trace)

    fresh = sanitize.new_traces(baseline)
    assert fresh == {}, (
        "second replay of the identical seeded trace retraced: "
        f"{sanitize.format_report(baseline)}")
    # each variant key IS the intended compile-cache signature — tracing
    # one twice means the cache was defeated by something outside the key
    assert sanitize.budget_violations(max_per_key=1) == {}, \
        sanitize.format_report()
    # determinism ride-along: the replays must agree token-for-token
    toks1 = [np.asarray(r.tokens) for r in rep1["requests"]]
    toks2 = [np.asarray(r.tokens) for r in rep2["requests"]]
    for a, b in zip(toks1, toks2):
        np.testing.assert_array_equal(a, b)
    sanitize.reset_trace_counts()
