"""Tensor-parallel serving tests: TP=2/4 greedy tokens bit-identical to
TP=1 across the config zoo (dense/GQA/SWA/int8-KV, W4 grouped + per-channel,
W8A8, MLA), fused-vs-gather parity on sharded pools, a prefix-cache-hit
case, verifiable placement (no replicated qw/scale/page leaves), scheduler
TP-invariance, engine host-state int32 regression, and the grouped-quant
scale sharding contract in distributed/partitioning.py.

The TP>1 cases need a multi-device host; the tier-1 run on a single CPU
device skips them. The `tp-cpu` CI job (and local runs) force them on:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m pytest -x -q tests/test_tp_serve.py
"""
import jax
import numpy as np
import pytest

from repro.configs import TINY, get_config
from repro.core.quant.types import quantize, quantize_stacked
from repro.distributed import partitioning as P
from repro.distributed.sharding import spec_for
from repro.models.config import LayerSpec, MLAConfig, MoEConfig
from repro.models.transformer import init_lm
from repro.serve.engine import ContinuousEngine
from repro.serve.kvcache import PagePool, PageSpec
from repro.serve.scheduler import Request, Scheduler

NDEV = len(jax.devices())
needs4 = pytest.mark.skipif(
    NDEV < 4, reason="needs 4 local devices (run with XLA_FLAGS="
                     "--xla_force_host_platform_device_count=4)")

BASE = TINY.replace(n_repeats=2, d_model=64, head_dim=16, d_ff=128)
GQA = BASE.replace(n_heads=8, n_kv_heads=4, head_dim=8)
MLA = BASE.replace(attention="mla", n_heads=4, n_kv_heads=4,
                   mla=MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                                 qk_rope_head_dim=8, v_head_dim=16))
# four shapes keep compile count small; one ragged (9) prompt
WORKLOAD = [(8, 6), (16, 4), (24, 5), (9, 4)]


def _run(cfg, params, tp, **kw):
    eng = ContinuousEngine(cfg, params, n_slots=4, max_len=64, page_size=16,
                           prefill_bucket=8, tp=tp, **kw)
    rng = np.random.default_rng(0)
    handles = [eng.submit(rng.integers(0, cfg.vocab_size, plen), max_new=mn)
               for plen, mn in WORKLOAD]
    eng.run(max_steps=500)
    return [h.tokens for h in handles], eng


# ------------------------------------------------------ token identity zoo

ZOO = [
    ("dense-w4", BASE, 4, dict(quant_bits=4, quant_group=-1)),
    ("gqa-w4-grouped", GQA, 4, dict(quant_bits=4, quant_group=8)),
    ("gqa-w4-grouped-tp2", GQA, 2, dict(quant_bits=4, quant_group=8)),
    ("gqa-swa", GQA.replace(attn_window=16), 2, {}),
    ("gqa-int8kv-w4", GQA.replace(kv_cache_bits=8), 4,
     dict(quant_bits=4, quant_group=-1)),
    ("dense-w8a8", BASE, 2, dict(quant_bits=8, quant_group=-1, act_bits=8)),
    # W3A8 routes through the legacy per-tensor fake-quant activation path
    # (bits=3 has no kernel) — its amax must be pmax'ed under TP too
    ("dense-w3a8", BASE, 2, dict(quant_bits=3, quant_group=-1, act_bits=8)),
    ("mla-float", MLA, 2, {}),
    # quantized MLA: wq/wukv/wo shard, wdkv stays replicated by design
    # (per-token latent) and must not trip the placement report
    ("mla-w4", MLA, 2, dict(quant_bits=4, quant_group=-1)),
]


@needs4
@pytest.mark.parametrize("name,cfg,tp,kw", ZOO, ids=[z[0] for z in ZOO])
def test_tp_token_identity(name, cfg, tp, kw):
    """TP=N greedy tokens are bit-identical to the TP=1 engine."""
    params = init_lm(cfg, jax.random.PRNGKey(0))
    t1, _ = _run(cfg, params, 1, **kw)
    tn, eng = _run(cfg, params, tp, **kw)
    for rid, (a, b) in enumerate(zip(t1, tn)):
        assert a == b, f"{name}: request {rid} diverged under tp={tp}"
    assert eng.pool.n_free == eng.spec.n_pages - 1  # pages all returned


@needs4
def test_tp_fused_vs_gather_on_sharded_pools():
    """The fused paged-attention kernel and the gather oracle agree on
    head-sharded pools (int8 KV so the inline dequant rides the shards)."""
    cfg = GQA.replace(kv_cache_bits=8)
    params = init_lm(cfg, jax.random.PRNGKey(0))
    fused, _ = _run(cfg, params, 4, quant_bits=4, quant_group=-1,
                    paged_attn="fused")
    gather, _ = _run(cfg, params, 4, quant_bits=4, quant_group=-1,
                     paged_attn="gather")
    assert fused == gather


@needs4
def test_tp_prefix_cache_hit():
    """Prefix-cache hits stitch shared pages into TP-sharded pools: a
    second wave sharing a 16-token (full-page) system prompt reuses pages
    and still matches the TP=1 engine token-for-token."""

    def run(tp):
        eng = ContinuousEngine(BASE, init_lm(BASE, jax.random.PRNGKey(0)),
                               n_slots=4, max_len=64, page_size=16,
                               prefill_bucket=8, tp=tp, prefix_share=True,
                               chunked_prefill=16)
        rng = np.random.default_rng(3)
        system = rng.integers(0, BASE.vocab_size, 16)
        # wave 1 registers the system page; wave 2 prefix-hits it (two
        # runs, or simultaneous admission would race the registration)
        handles = [eng.submit(np.concatenate(
            [system, rng.integers(0, BASE.vocab_size, 8)]), max_new=4)]
        eng.run(max_steps=500)
        for i in range(3):
            tail = rng.integers(0, BASE.vocab_size, 8 + 4 * i)
            handles.append(eng.submit(np.concatenate([system, tail]),
                                      max_new=4))
        eng.run(max_steps=500)
        return [h.tokens for h in handles], eng.n_shared_tokens

    t1, shared1 = run(1)
    t4, shared4 = run(4)
    assert t1 == t4
    assert shared1 == shared4 == 3 * 16   # wave 2 hit the cached system page


@needs4
def test_w8a8_activation_grid_global_under_tp():
    """Row-parallel W8A8 must quantize activations on the single-device
    grid: the per-token amax is pmax'ed over the shard axis, so TP never
    changes the quantization itself (only float summation order). A
    shard-local amax would yield a different int8 grid per shard and
    silently different logits than TP=1."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec

    from repro.core.quant.types import quantize_activation

    mesh = Mesh(np.asarray(jax.devices()[:4]), ("model",))
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
    _, s_ref = quantize_activation(x, 8)

    def body(xl):
        _, s = quantize_activation(xl, 8, axis_name="model")
        return s

    s_tp = shard_map(body, mesh=mesh,
                     in_specs=PartitionSpec(None, "model"),
                     out_specs=PartitionSpec(None, None),
                     check_rep=False)(x)
    np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(s_tp))


# ------------------------------------------------------------- placement

@needs4
def test_tp_placement_verifiably_sharded():
    """No replicated qw/scale/page leaves on the W4 GQA config: every
    projection leaf and every KV pool leaf holds only its model-axis slice
    per device, while the page geometry stays global (shard-invariant
    scheduler budget)."""
    params = init_lm(GQA, jax.random.PRNGKey(0))
    _, eng = _run(GQA, params, 4, quant_bits=4, quant_group=8)
    rep = eng.tp_placement_report()
    assert rep["replicated_quant_leaves"] == []
    assert rep["replicated_pool_leaves"] == []
    assert rep["params"]["per_device_bytes"] < rep["params"]["global_bytes"]
    # pool leaves: kv-head dim divided by 4, page axes untouched
    from repro.serve.kvcache import POOL_KEYS, pool_head_dim
    for key, leaf in eng._iter_cache_leaves():
        if key not in POOL_KEYS:
            continue
        hdim = pool_head_dim(key, leaf.ndim)
        shard = eng._shard_shape(leaf)
        assert shard[hdim] * 4 == leaf.shape[hdim]
        assert shard[:hdim] == tuple(leaf.shape[:hdim])
    # KV per-device bytes track the head split (scale pools + scan stacking
    # included, so exactly global/4 for this attention-only config)
    assert rep["kv"]["per_device_bytes"] * 4 == rep["kv"]["global_bytes"]


@needs4
def test_tp_placement_report_exempts_mla_latent():
    """Quantized MLA serves under TP with wdkv replicated by design (the
    latent projection has no head dim): the placement report must not list
    it as a violation, and the latent pools stay replicated."""
    params = init_lm(MLA, jax.random.PRNGKey(0))
    _, eng = _run(MLA, params, 2, quant_bits=4, quant_group=-1)
    rep = eng.tp_placement_report()
    assert rep["replicated_quant_leaves"] == []
    assert rep["replicated_pool_leaves"] == []    # KVH==1: structurally so


@needs4
def test_tp_grouped_scale_misalignment_raises():
    """A group size that leaves partial scale groups per shard must fail
    loudly at placement, not serve silently replicated weights."""
    # d_ff=128, tp=4 -> K/tp=32 rows of mlp/wo per shard; gs=64 -> groups
    # of 64 rows straddle shards (G=2 not divisible by 4)
    params = init_lm(BASE, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="scale groups"):
        ContinuousEngine(BASE, params, n_slots=4, max_len=64, page_size=16,
                         tp=4, quant_bits=4, quant_group=64)


# ----------------------------------------------------------- legal widths

def test_tp_width_legality_gqa_alignment():
    """Legal TP widths divide the kv-head count (GQA groups stay whole) and
    the MLP hidden dim; MLA is constrained by query heads only."""
    assert P.serve_tp_widths(GQA) == [1, 2, 4]              # kvh=4 caps it
    assert P.serve_tp_widths(GQA.replace(n_kv_heads=1)) == [1]   # MQA
    assert P.serve_tp_widths(MLA) == [1, 2, 4]              # latent KV
    assert 8 in P.serve_tp_widths(GQA.replace(n_kv_heads=8, d_ff=128))


def test_tp_illegal_width_raises():
    params = init_lm(GQA, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="legal widths"):
        ContinuousEngine(GQA, params, n_slots=4, max_len=64, tp=3)


def test_tp_moe_and_ssm_gated():
    moe_cfg = BASE.replace(
        pattern=(LayerSpec(kind="attn", mlp="moe"),),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64))
    with pytest.raises(NotImplementedError, match="dense attention"):
        ContinuousEngine(moe_cfg, init_lm(moe_cfg, jax.random.PRNGKey(0)),
                         n_slots=4, max_len=64, tp=4)


# ------------------------------------------- scheduler TP invariance

def test_scheduler_page_budget_tp_invariant():
    """Same pool geometry + request sequence -> identical admission trace
    for tp=1 and tp=4: the page budget is counted in tokens and pools shard
    along kv-heads only, so admission needs no TP awareness."""

    def trace(tp):
        pool = PagePool(PageSpec(n_pages=9, page_size=8, max_pages=4), 3)
        sched = Scheduler(3, pool, tp=tp)
        for i, budget in enumerate([16, 16, 24, 8, 40]):
            sched.submit(Request(rid=i, prompt=np.zeros(8, np.int32),
                                 max_new=budget - 8, arrival=float(i)))
        events = []
        for t in range(10):
            admitted = sched.admit(float(t))
            events.append([(s, r.rid) for s, r in admitted])
            if t == 2 and sched.slots[0] is not None:
                sched.retire(0, float(t))
                events.append(("retire", 0))
        return events

    assert trace(1) == trace(4)


# ------------------------------------------- engine host-state int32

def test_engine_host_state_int32_end_to_end():
    """Regression for the int64 host-mirror drift: cur_len/last_tok stay
    int32 through admit -> prefill -> decode -> retire, so there is no
    cast boundary where a long-context length could silently truncate."""
    params = init_lm(BASE, jax.random.PRNGKey(0))
    eng = ContinuousEngine(BASE, params, n_slots=2, max_len=64, page_size=16,
                           prefill_bucket=8)
    assert eng.cur_len.dtype == np.int32
    assert eng.last_tok.dtype == np.int32
    eng.submit(np.arange(8), max_new=4)
    eng.run(max_steps=100)
    assert eng.cur_len.dtype == np.int32
    assert eng.last_tok.dtype == np.int32


# --------------------------- grouped-quant scale sharding (partitioning)

class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


MESH = FakeMesh({"data": 2, "model": 4})


def _specs(tree, rules):
    def fn(path, leaf, names):
        return spec_for(leaf.shape, names, mesh=MESH, rules=rules)

    return P._walk(tree, "", fn)


def _qt(k, n, gs, lead=()):
    w = jax.random.normal(jax.random.PRNGKey(0), lead + (k, n))
    return quantize_stacked(w, 4, gs) if lead else quantize(w, 4, gs)


def test_param_rules_scale_inherits_output_dim_sharding():
    """For every dense _PARAM_RULES entry the grouped-quant scale leaf
    (K/gs, N) shards its output dim exactly like the packed weight."""
    rules = P.rules_for_config(BASE)
    tree = {"stack": {"p0": {
        "attn": {"wq": {"w": _qt(64, 64, 16)}, "wk": {"w": _qt(64, 32, 16)},
                 "wv": {"w": _qt(64, 32, 16)}, "wo": {"w": _qt(64, 64, 16)}},
        "mlp": {"wi": {"w": _qt(64, 128, 16)}, "wo": {"w": _qt(128, 64, 16)}},
    }}}
    specs = _specs(tree, rules)
    for name in ("wq", "wk", "wv"):
        qt = specs["stack"]["p0"]["attn"][name]["w"]
        assert qt.qw[-1] == "model" and qt.scale[-1] == "model", name
    mlp = specs["stack"]["p0"]["mlp"]
    assert mlp["wi"]["w"].qw[-1] == "model"
    assert mlp["wi"]["w"].scale[-1] == "model"
    # row-parallel wo: K dim sharded on qw -> group dim sharded on scale
    assert mlp["wo"]["w"].qw[0] == "model"
    assert mlp["wo"]["w"].scale[0] == "model"


def test_param_rules_scale_sharding_moe_expert_slabs():
    """Scan-stacked MoE expert slabs (L, E, K, N): the scale inherits the
    expert/output sharding of the packed weight in both the EP regime
    (expert dim on model) and the expert-TP regime (expert_ff on model)."""
    tree = {"stack": {"p0": {"moe": {"experts": {
        "wi": {"w": _qt(64, 128, 8, lead=(2, 4))},
        "wo": {"w": _qt(128, 64, 8, lead=(2, 4))},
    }}}}}
    # 64 DeepSeek experts % 4 == 0 -> EP regime on a model=4 mesh
    ep_rules = P.rules_for_config(get_config("deepseek-v2-lite-16b"), MESH)
    specs = _specs(tree, ep_rules)
    wi = specs["stack"]["p0"]["moe"]["experts"]["wi"]["w"]
    assert wi.qw[1] == "model" and wi.scale[1] == "model"      # expert dim
    assert wi.qw[-1] == wi.scale[-1]
    # 8 Mixtral experts % 16 != 0 -> expert-TP regime on a model=16 mesh
    mesh16 = FakeMesh({"data": 2, "model": 16})

    def specs16(t, rules):
        def fn(path, leaf, names):
            return spec_for(leaf.shape, names, mesh=mesh16, rules=rules)

        return P._walk(t, "", fn)

    etp_rules = P.rules_for_config(get_config("mixtral-8x22b"), mesh16)
    specs = specs16(tree, etp_rules)
    wi = specs["stack"]["p0"]["moe"]["experts"]["wi"]["w"]
    assert wi.qw[-1] == "model" and wi.scale[-1] == "model"    # expert_ff
    wo = specs["stack"]["p0"]["moe"]["experts"]["wo"]["w"]
    assert wo.qw[-2] == "model" and wo.scale[-2] == "model"    # K -> groups


def test_per_channel_scale_stays_whole_on_row_parallel():
    """Per-channel (1, N) scales never shard their group dim: every K shard
    needs the full output-channel scale row."""
    rules = P.rules_for_config(BASE)
    tree = {"stack": {"p0": {"mlp": {"wo": {"w": _qt(128, 64, -1)}}}}}
    specs = _specs(tree, rules)
    wo = specs["stack"]["p0"]["mlp"]["wo"]["w"]
    assert wo.qw[0] == "model" and wo.scale[0] is None


def test_serve_specs_drop_k_sharding_jointly():
    """When the scale groups don't divide the TP width, the serving specs
    drop the K sharding from qw AND scale together — never only one side."""
    class M(FakeMesh):
        pass

    mesh = M({"model": 4})
    qt = _qt(128, 64, 64)                    # G=2, tp=4 -> indivisible
    qw_spec, sc_spec = P._qt_serve_spec(
        qt, ("mlp", "embed_fsdp"), mesh, P.serve_tp_rules(BASE))
    assert qw_spec[0] is None and sc_spec[0] is None
    qt_ok = _qt(128, 64, 16)                 # G=8 -> divisible
    qw_spec, sc_spec = P._qt_serve_spec(
        qt_ok, ("mlp", "embed_fsdp"), mesh, P.serve_tp_rules(BASE))
    assert qw_spec[0] == "model" and sc_spec[0] == "model"
