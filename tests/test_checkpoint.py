"""Checkpointing + fault-tolerant resume tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.store import load_tree, save_tree
from repro.configs import TINY
from repro.core.quant.types import QuantizedTensor, dequantize, quantize
from repro.data.pipeline import DataPipeline
from repro.data.synthetic import make_corpus
from repro.models.transformer import init_lm
from repro.optim.schedules import constant
from repro.train.train_step import init_opt_state, make_train_step
from repro.train.trainer import StepTimeMonitor, Trainer

CFG = TINY.replace(n_repeats=2, d_model=64, head_dim=16, d_ff=128)


def test_store_roundtrip_with_quantized(tmp_path):
    tree = {"a": {"w": jnp.arange(6.0).reshape(2, 3)},
            "q": quantize(jax.random.normal(jax.random.PRNGKey(0), (16, 8)),
                          4, 8),
            "meta": {"n": 3}}
    save_tree(str(tmp_path / "ck"), tree, {"tag": "x"})
    loaded, extra = load_tree(str(tmp_path / "ck"))
    assert extra["tag"] == "x"
    assert loaded["meta"]["n"] == 3
    np.testing.assert_allclose(np.asarray(loaded["a"]["w"]),
                               np.asarray(tree["a"]["w"]))
    assert isinstance(loaded["q"], QuantizedTensor)
    np.testing.assert_allclose(np.asarray(dequantize(loaded["q"])),
                               np.asarray(dequantize(tree["q"])))


def test_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in [10, 20, 30]:
        mgr.save(s, {"w": jnp.full((2,), float(s))})
    assert mgr.steps() == [20, 30]
    step, params, opt, extra = mgr.restore()
    assert step == 30
    assert float(params["w"][0]) == 30.0


def _make_trainer(tmp_path, crash_at=None):
    corpus, _ = make_corpus(CFG.vocab_size, 30_000, seed=0)
    params = init_lm(CFG, jax.random.PRNGKey(0))
    pipe = DataPipeline(corpus, batch_size=8, seq_len=32, seed=0)
    step_fn = make_train_step(CFG, lr_schedule=constant(1e-3), donate=False)
    opt = init_opt_state(CFG, params)
    ckpt = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    return Trainer(CFG, params, opt, step_fn, pipe, ckpt)


def test_crash_resume_bit_exact(tmp_path):
    # uninterrupted run
    t_ref = _make_trainer(tmp_path / "ref")
    t_ref.run(20, ckpt_every=5, log_every=0)
    ref_params = t_ref.params

    # crashing run + resume
    t1 = _make_trainer(tmp_path / "crash")
    with pytest.raises(RuntimeError):
        t1.run(20, ckpt_every=5, log_every=0, crash_at=11)
    t2 = _make_trainer(tmp_path / "crash")
    resumed_from = t2.maybe_resume()
    assert resumed_from == 10  # last checkpoint at step 9 (save at (s+1)%5)
    t2.run(20, ckpt_every=5, log_every=0)

    d = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), ref_params, t2.params)))
    assert d == 0.0, f"resume not bit-exact: max delta {d}"


def test_async_save_does_not_corrupt(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5, async_save=True)
    for s in range(5):
        mgr.save(s, {"w": jnp.full((1024,), float(s))})
    mgr.wait()
    step, params, _, _ = mgr.restore()
    assert step == 4 and float(params["w"][0]) == 4.0


def test_straggler_monitor():
    mon = StepTimeMonitor(warmup=3, z=3.0)
    flags = [mon.update(0.1) for _ in range(10)]
    assert not any(flags)
    assert mon.update(1.0)  # 10x slower step flagged
