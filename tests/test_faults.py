"""Fault tolerance: engine snapshot/restore, deterministic fault
injection, and graceful degradation.

Covers snapshot -> kill -> restore token identity across the attention
zoo (including the disk round trip through checkpoint.store), fingerprint
refusal of mismatched configs, every fault kind's degradation path
(nan-logit quarantine shielding co-batched slots, pool-exhaust holds that
release on schedule, crash recovery via run_resilient, spill-corruption
checksum catches, fused->gather kernel fallback with identical tokens),
deadline shed/cancel accounting, and a seeded chaos matrix asserting
bit-identical replays with zero invariant violations and zero leaked
pages."""
import jax
import numpy as np
import pytest

from repro.checkpoint.store import load_snapshot, save_snapshot
from repro.configs import TINY
from repro.models.transformer import init_lm
from repro.serve import traffic
from repro.serve.engine import ContinuousEngine
from repro.serve.faults import Fault, FaultPlan, run_resilient

CFG = TINY.replace(n_repeats=2, d_model=64, head_dim=16, d_ff=128)

ZOO = [
    ("dense", CFG),
    ("gqa", CFG.replace(n_kv_heads=2)),
    ("swa", CFG.replace(attn_window=12)),
    ("int8-kv", CFG.replace(kv_cache_bits=8)),
    ("gqa-swa-int8", CFG.replace(n_kv_heads=2, attn_window=12,
                                 kv_cache_bits=8)),
]


@pytest.fixture(scope="module", autouse=True)
def _release_executables():
    # this module compiles many one-off engine variants (the zoo x cut
    # points, odd slot/page geometries for fingerprint tests); drop the
    # executables when the module finishes so the rest of the suite does
    # not carry their jit footprint in the same process
    yield
    jax.clear_caches()


@pytest.fixture(scope="module")
def tiny_lm():
    return init_lm(CFG, jax.random.PRNGKey(0))


def _engine(cfg, params, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 48)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_bucket", 8)
    kw.setdefault("decode_block", 2)
    return ContinuousEngine(cfg, params, **kw)


def _submit_mixed(eng, vocab, *, seed=7):
    """Four requests that exercise preemption under n_slots=2: a long
    batch victim, two interactive arrivals that evict it, a late batch."""
    rng = np.random.default_rng(seed)
    spec = [(16, 0.0, 1), (4, 3.0, 0), (5, 5.0, 0), (6, 7.0, 1)]
    return [eng.submit(rng.integers(0, vocab, 8), max_new=mn,
                       arrival=t, priority=p) for mn, t, p in spec]


def _tokens(done):
    return {r.rid: tuple(r.tokens) for r in done}


def _step_n(eng, n):
    """Advance the virtual clock by hand, exactly like run(clock=None)."""
    steps = 0
    while not eng.sched.all_done() and steps < n:
        eng.step(float(eng.t))
        eng.t += 1
        steps += 1


def _assert_drained(eng):
    eng.pool.check_invariants()
    assert np.all(eng.pool.tables == -1), "pages mapped after drain"
    held = sum(len(h[1]) for h in eng._fault_holds)
    assert eng.pool.n_free == eng.spec.n_pages - 1 - held, \
        "leaked pages after drain"


# ------------------------------------------------ snapshot / restore

def test_snapshot_restore_token_identity_zoo(tiny_lm, tmp_path):
    """Kill the engine mid-trace at several cut points, restore the
    snapshot into a freshly built engine, and finish: greedy tokens are
    bit-identical to the uninterrupted run, across dense/GQA/SWA/int8-KV
    with preemption in flight. The dense entry also round-trips one
    snapshot through the on-disk store."""
    for name, cfg in ZOO:
        params = tiny_lm if cfg is CFG else \
            init_lm(cfg, jax.random.PRNGKey(0))
        base = _engine(cfg, params, preempt=True, age_promote=64.0)
        _submit_mixed(base, cfg.vocab_size)
        want = _tokens(base.run(max_steps=2000))
        _assert_drained(base)
        for cut in (2, 6):
            eng = _engine(cfg, params, preempt=True, age_promote=64.0)
            _submit_mixed(eng, cfg.vocab_size)
            _step_n(eng, cut)
            snap = eng.snapshot()
            if name == "dense" and cut == 6:
                path = str(tmp_path / "snap")
                save_snapshot(path, snap)
                snap = load_snapshot(path)
            fresh = _engine(cfg, params, preempt=True, age_promote=64.0)
            fresh.restore(snap)
            got = _tokens(fresh.run(max_steps=2000))
            assert got == want, \
                f"{name}: restore at step {cut} changed tokens"
            assert fresh.sched.stats() == base.sched.stats(), \
                f"{name}: restore at step {cut} changed accounting"
            _assert_drained(fresh)


def test_snapshot_fingerprint_mismatch_raises(tiny_lm):
    eng = _engine(CFG, tiny_lm)
    snap = eng.snapshot()
    for kw in ({"n_slots": 3}, {"decode_block": 4}, {"page_size": 16}):
        other = _engine(CFG, tiny_lm, **kw)
        with pytest.raises(ValueError, match="fingerprint"):
            other.restore(snap)
    gqa = _engine(CFG.replace(n_kv_heads=2),
                  init_lm(CFG.replace(n_kv_heads=2), jax.random.PRNGKey(0)))
    with pytest.raises(ValueError, match="fingerprint"):
        gqa.restore(snap)


def test_snapshot_preserves_free_list_order(tiny_lm):
    """Allocation determinism: the restored pool hands out the same pages
    in the same order the original would have."""
    eng = _engine(CFG, tiny_lm)
    _submit_mixed(eng, CFG.vocab_size)
    _step_n(eng, 3)
    snap = eng.snapshot()
    fresh = _engine(CFG, tiny_lm)
    fresh.restore(snap)
    assert list(fresh.pool._free) == list(eng.pool._free)
    assert np.array_equal(fresh.pool.tables, eng.pool.tables)
    fresh.run(max_steps=2000)


# ------------------------------------------------------- fault kinds

def test_nan_quarantine_shields_cobatched_slot(tiny_lm):
    """A NaN-poisoned slot is quarantined by the isfinite sentinel (error
    recorded, pages freed); the co-batched slot's tokens are identical to
    a run where it decodes alone."""
    rng = np.random.default_rng(3)
    p0 = rng.integers(0, CFG.vocab_size, 8)
    p1 = rng.integers(0, CFG.vocab_size, 8)
    solo_eng = _engine(CFG, tiny_lm)
    solo = solo_eng.submit(p0, max_new=10)
    solo_eng.run(max_steps=500)
    eng = _engine(CFG, tiny_lm,
                  faults=FaultPlan([Fault(step=2, kind="nan_logits",
                                          slot=1)]))
    survivor = eng.submit(p0, max_new=10, arrival=0.0)
    victim = eng.submit(p1, max_new=10, arrival=0.0)
    eng.run(max_steps=500)
    assert victim.error == "nonfinite_logits"
    assert len(victim.tokens) < 10 + 1
    assert survivor.error is None
    assert survivor.tokens == solo.tokens, \
        "quarantine perturbed the co-batched slot"
    st = eng.fault_stats()
    assert st["n_nonfinite"] == 1 and st["n_quarantined"] == 1
    assert eng.sched.stats()["n_quarantined"] == 1
    _assert_drained(eng)


def test_pool_exhaust_delays_admission_then_releases(tiny_lm):
    """Held pages make admission wait; the hold releases on schedule, the
    delayed request still finishes with identical tokens, and the pool
    conserves pages throughout."""
    rng = np.random.default_rng(4)
    p0 = rng.integers(0, CFG.vocab_size, 8)
    p1 = rng.integers(0, CFG.vocab_size, 8)

    def drive(faults):
        eng = _engine(CFG, tiny_lm, n_pages=8, faults=faults)
        eng.debug = True
        # r0 outlives the hold window so its own retirement can't hand
        # pages to r1 while the hold is active
        r0 = eng.submit(p0, max_new=12, arrival=0.0)
        r1 = eng.submit(p1, max_new=4, arrival=2.0)
        eng.run(max_steps=500)
        _assert_drained(eng)
        admit_t = {e[2]: e[1] for e in eng.sched.events
                   if e[0] == "admit"}
        return eng, (r0.tokens, r1.tokens), admit_t

    _, base_toks, base_admit = drive(None)
    plan = FaultPlan([Fault(step=1, kind="pool_exhaust", pages=6,
                            duration=4)])
    eng, toks, admit = drive(plan)
    assert toks == base_toks
    assert admit[1] > base_admit[1], "hold did not delay admission"
    assert eng.fault_stats()["held_pages"] == 0, "hold never released"
    assert eng.fault_stats()["n_faults_applied"] == 1


def test_step_exception_crash_restore_resilient(tiny_lm, tmp_path):
    """run_resilient survives injected crashes: the engine is rebuilt from
    the last snapshot and the drained token streams are bit-identical to
    the fault-free run — both with in-memory snapshots and through the
    on-disk store."""
    trace = traffic.make_trace(kind="uniform", n=6, rate=1.0, seed=5,
                               vocab_size=CFG.vocab_size, prompt_len=(6, 10),
                               max_new=(4, 8))
    base_eng = _engine(CFG, tiny_lm, preempt=True, age_promote=64.0)
    base = traffic.replay(base_eng, trace, max_steps=2000)
    want = _tokens(base["requests"])

    def build():
        return _engine(CFG, tiny_lm, preempt=True, age_promote=64.0)

    plan = FaultPlan([Fault(step=4, kind="step_exception"),
                      Fault(step=9, kind="step_exception")])
    for store_dir in (None, str(tmp_path / "snap")):
        res = run_resilient(build, trace, faults=plan, snapshot_every=3,
                            store_dir=store_dir, max_steps=2000)
        assert res["n_crashes"] == 2
        assert res["n_snapshots"] > 0
        assert _tokens(res["requests"]) == want, \
            "crash recovery changed the token streams"
        _assert_drained(res["engine"])


def test_spill_corruption_caught_on_restore(tiny_lm):
    """A spill snapshot corrupted in host RAM fails its checksum at
    restore time: the victim is quarantined (never resumed on garbage KV),
    the preemptor's tokens are untouched, and no page leaks."""
    rng = np.random.default_rng(11)
    batch_p = rng.integers(0, CFG.vocab_size, 8)
    inter_p = rng.integers(0, CFG.vocab_size, 8)
    solo_eng = _engine(CFG, tiny_lm, n_slots=1, max_len=40,
                       decode_block=1, preempt=True)
    solo = solo_eng.submit(inter_p, max_new=4)
    solo_eng.run(max_steps=500)
    plan = FaultPlan([Fault(step=1, kind="spill_corrupt")])
    eng = _engine(CFG, tiny_lm, n_slots=1, max_len=40, decode_block=1,
                  preempt=True, faults=plan)
    victim = eng.submit(batch_p, max_new=20, arrival=0.0, priority=1)
    inter = eng.submit(inter_p, max_new=4, arrival=4.0, priority=0)
    eng.run(max_steps=500)
    assert victim.n_preempts == 1
    assert victim.error == "spill_corrupt"
    assert inter.tokens == solo.tokens
    st = eng.fault_stats()
    assert st["n_spill_corruptions"] == 1
    assert st["n_spill_checksum_fails"] == 1
    assert st["n_quarantined"] == 1
    _assert_drained(eng)


def test_kernel_fault_falls_back_with_identical_tokens(tiny_lm):
    """A failed fused paged-attention dispatch downgrades the engine to
    the gather path mid-trace; the token streams don't change (the two
    impls are bitwise-identical) and the fallback is counted."""
    base = _engine(CFG, tiny_lm, paged_attn="fused")
    _submit_mixed(base, CFG.vocab_size)
    want = _tokens(base.run(max_steps=2000))
    plan = FaultPlan([Fault(step=3, kind="kernel_fault")])
    eng = _engine(CFG, tiny_lm, paged_attn="fused", faults=plan)
    _submit_mixed(eng, CFG.vocab_size)
    got = _tokens(eng.run(max_steps=2000))
    assert got == want, "fused->gather fallback changed tokens"
    st = eng.fault_stats()
    assert st["n_kernel_fallbacks"] == 1
    assert st["paged_attn_impl"] == "gather"
    _assert_drained(eng)


# ------------------------------------------------- deadlines / shedding

def test_deadline_shed_cancel_completed_disjoint(tiny_lm):
    """Deadline enforcement splits the trace into three disjoint
    populations — shed from the queue, cancelled mid-run, completed — and
    the scheduler counters agree with the per-request flags. The event
    log is identical across two replays."""
    trace = traffic.make_trace(kind="bursty", n=8, rate=1.0, seed=3,
                               vocab_size=CFG.vocab_size, prompt_len=(6, 10),
                               max_new=(4, 16), burst_len=0.4, idle_len=6.0,
                               burst_rate_mult=8.0, deadline=8.0)
    runs = []
    for _ in range(2):
        eng = _engine(CFG, tiny_lm, n_slots=1, max_len=48, decode_block=1)
        report = traffic.replay(eng, trace, max_steps=2000)
        reqs = report["requests"]
        runs.append((list(eng.sched.events),
                     eng.sched.stats(), _tokens(reqs)))
        shed = {r.rid for r in reqs if r.shed}
        cancelled = {r.rid for r in reqs if r.cancelled}
        completed = {r.rid for r in reqs
                     if not (r.shed or r.cancelled or r.rejected
                             or r.error)}
        assert shed and cancelled and completed, \
            "trace must exercise all three populations"
        assert not (shed & cancelled) and not (shed & completed) \
            and not (cancelled & completed)
        assert shed | cancelled | completed == {r.rid for r in reqs}
        stats = eng.sched.stats()
        assert stats["n_shed"] == len(shed)
        assert stats["n_cancelled"] == len(cancelled)
        assert stats["n_finished_ok"] + stats["n_finished_preempted"] \
            == len(completed)
        # shed requests never produced a token; cancelled ones never
        # reached their full budget
        assert all(not r.tokens for r in reqs if r.shed)
        assert all(len(r.tokens) < r.max_new + 1
                   for r in reqs if r.cancelled)
        assert report["overall"]["n_shed"] == len(shed)
        _assert_drained(eng)
    assert runs[0] == runs[1], "deadline replay is not deterministic"


# -------------------------------------------------- debug mode / leaks

def test_repro_debug_env_validates_every_step(tiny_lm, monkeypatch):
    """REPRO_DEBUG=1 arms the per-step invariant check at construction;
    a clean preempting trace passes it on every step."""
    monkeypatch.setenv("REPRO_DEBUG", "1")
    eng = _engine(CFG, tiny_lm, preempt=True, age_promote=64.0)
    assert eng.debug
    _submit_mixed(eng, CFG.vocab_size)
    eng.run(max_steps=2000)
    _assert_drained(eng)


def test_repro_debug_catches_corrupted_mirror(tiny_lm):
    """The debug check actually fires: corrupting a slot mirror by hand
    fails the very next step instead of surfacing at drain."""
    eng = _engine(CFG, tiny_lm)
    eng.debug = True
    eng.submit(np.arange(8, dtype=np.int32), max_new=8)
    _step_n(eng, 2)
    eng.cur_len[0] += 1                              # simulated corruption
    with pytest.raises(AssertionError, match="disagrees|exceeds"):
        eng.step(float(eng.t))


def test_mixed_trace_leak_audit(tiny_lm):
    """Every early-exit path at once — preemption, deadline shed/cancel,
    nan quarantine, corrupt-spill quarantine — and the pool still drains
    to empty with invariants intact after every step."""
    plan = FaultPlan([Fault(step=1, kind="spill_corrupt"),
                      Fault(step=6, kind="nan_logits", slot=0),
                      Fault(step=9, kind="pool_exhaust", pages=4,
                            duration=3),
                      Fault(step=12, kind="latency_spike", duration=4)])
    trace = traffic.make_trace(kind="bursty", n=10, rate=1.0, seed=9,
                               vocab_size=CFG.vocab_size, prompt_len=(6, 12),
                               max_new=(4, 14), burst_len=0.5, idle_len=5.0,
                               burst_rate_mult=8.0, deadline=20.0)
    eng = _engine(CFG, tiny_lm, preempt=True, age_promote=32.0,
                  faults=plan)
    eng.debug = True                 # invariants checked after every step
    report = traffic.replay(eng, trace, max_steps=2000)
    st = eng.fault_stats()
    assert st["n_faults_applied"] == len(plan)
    _assert_drained(eng)
    assert eng._fault_holds == []
    assert all(r.finished_at is not None for r in report["requests"])


# --------------------------------------------------- seeded chaos matrix

def test_chaos_matrix_deterministic_and_leak_free(tiny_lm):
    """The acceptance gate: seeded fault schedules (including crashes)
    replay bit-for-bit — same events, same fault accounting, same token
    streams — with per-step invariant checks on and zero leaked pages;
    untouched survivors match the fault-free baseline."""
    trace = traffic.make_trace(kind="bursty", n=8, rate=1.0, seed=13,
                               vocab_size=CFG.vocab_size, prompt_len=(6, 10),
                               max_new=(4, 10), burst_len=0.5, idle_len=6.0,
                               burst_rate_mult=8.0)
    base_eng = _engine(CFG, tiny_lm, preempt=True, age_promote=64.0)
    base = traffic.replay(base_eng, trace, max_steps=2000)
    want = _tokens(base["requests"])

    def build():
        eng = _engine(CFG, tiny_lm, preempt=True, age_promote=64.0)
        eng.debug = True
        return eng

    total_crashes = 0
    for seed in range(3):
        plan = FaultPlan.seeded(seed, n_steps=15, n_slots=2, n_faults=4,
                                crashes=1)
        runs = []
        for _ in range(2):
            res = run_resilient(build, trace, faults=plan,
                                snapshot_every=4, max_steps=4000)
            eng = res["engine"]
            _assert_drained(eng)
            untouched = {r.rid: tuple(r.tokens) for r in res["requests"]
                         if not (r.error or r.shed or r.cancelled
                                 or r.n_preempts)}
            for rid, toks in untouched.items():
                assert toks == want[rid], \
                    f"seed {seed}: fault plan perturbed survivor {rid}"
            runs.append((res["n_crashes"], list(eng.sched.events),
                         eng.fault_stats(), _tokens(res["requests"])))
        assert runs[0] == runs[1], f"seed {seed}: chaos replay diverged"
        total_crashes += runs[0][0]
    # the matrix as a whole exercised crash recovery (a crash scheduled
    # past the trace's natural end is legitimately unreached)
    assert total_crashes >= 1


# ------------------------------------------------------- FaultPlan units

def test_fault_plan_seeded_reproducible_and_ordered():
    a = FaultPlan.seeded(42, n_steps=32, n_slots=4, n_faults=8, crashes=2)
    b = FaultPlan.seeded(42, n_steps=32, n_slots=4, n_faults=8, crashes=2)
    assert a.faults == b.faults
    assert len(a) == 10
    assert sum(f.kind == "step_exception" for f in a) == 2
    steps = [f.step for f in a]
    assert steps == sorted(steps)
    for s in set(steps):
        assert a.at(s) == [f for f in a if f.step == s]


def test_fault_plan_drop_removes_one_occurrence():
    f = Fault(step=3, kind="step_exception")
    plan = FaultPlan([f, f, Fault(step=1, kind="nan_logits")])
    assert len(plan.drop(f)) == 2
    assert len(plan.drop(f).drop(f)) == 1
    with pytest.raises(ValueError):
        FaultPlan([Fault(step=0, kind="not_a_fault")])
