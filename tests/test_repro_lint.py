"""repro-lint golden fixtures: per rule, one minimal snippet that must
trigger it and one near-miss that must pass, plus pragma suppression,
the clean-run-over-src gate, and the RL004 registry coverage checks."""
import ast
import os
import textwrap

from repro.analysis.core import RULE_DOCS, module_name_for
from repro.analysis.lint import (cross_check_registry, extract_registry,
                                 iter_py_files, lint_paths, lint_source)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
TESTS = os.path.dirname(__file__)


def codes(src, module, registry=None):
    src = textwrap.dedent(src)
    return {f.rule for f in lint_source(src, "fixture.py", module=module,
                                        registry=registry)}


# ------------------------------------------------------------------ RL001

def test_rl001_trigger_wall_clock():
    assert "RL001" in codes("""
        import time
        def step(self):
            return time.time()
        """, "repro.serve.engine")


def test_rl001_trigger_stdlib_random():
    assert "RL001" in codes("""
        import random
        def pick(xs):
            return random.choice(xs)
        """, "repro.kernels.ops")


def test_rl001_trigger_unseeded_np_random():
    assert "RL001" in codes("""
        import numpy as np
        def noise(n):
            return np.random.rand(n)
        """, "repro.serve.traffic")
    assert "RL001" in codes("""
        import numpy as np
        def noise(n):
            return np.random.default_rng().normal(size=n)
        """, "repro.serve.traffic")


def test_rl001_trigger_unordered_dict_iteration():
    assert "RL001" in codes("""
        def drain(self):
            for slot in self._prefilling:
                self.finish(slot)
        """, "repro.serve.engine")


def test_rl001_near_misses():
    # sleep paces, seeded rng is sanctioned, sorted() normalizes order,
    # and launch/ modules are outside the virtual-clock contract
    assert "RL001" not in codes("""
        import time
        import numpy as np
        def ok(self, seed):
            time.sleep(0.01)
            rng = np.random.default_rng(seed)
            for slot in sorted(self._prefilling):
                self.finish(slot)
            return rng.normal()
        """, "repro.serve.engine")
    assert "RL001" not in codes("""
        import time
        def bench():
            return time.time()
        """, "repro.launch.serve")


# ------------------------------------------------------------------ RL002

def test_rl002_trigger_view_assignment():
    assert "RL002" in codes("""
        def sync(self, out):
            self.cur_len = out
        """, "repro.serve.engine")


def test_rl002_trigger_upload_without_copy():
    assert "RL002" in codes("""
        import jax.numpy as jnp
        def push(self):
            return jnp.asarray(self.last_tok)
        """, "repro.serve.engine")


def test_rl002_near_misses():
    assert "RL002" not in codes("""
        import jax.numpy as jnp
        import numpy as np
        def push(self, width):
            self.cur_len = np.asarray(self.cur_len, np.int32).copy()
            a = jnp.asarray(self.last_tok.copy())
            b = jnp.asarray(self.pool.tables[:, :width].copy())
            c = jnp.asarray(self.pool.tables[slots])  # fancy index copies
            return a, b, c
        """, "repro.serve.engine")


# ------------------------------------------------------------------ RL003

def test_rl003_trigger_read_after_donation():
    assert "RL003" in codes("""
        import functools
        import jax
        @functools.partial(jax.jit, donate_argnames=("cache",))
        def f(x, cache):
            return cache
        def g(y, cache):
            out = f(y, cache)
            return cache
        """, "repro.serve.engine")


def test_rl003_near_miss_rebound_result():
    assert "RL003" not in codes("""
        import functools
        import jax
        @functools.partial(jax.jit, donate_argnames=("cache",))
        def f(x, cache):
            return cache
        def g(y, cache):
            cache = f(y, cache)
            return cache
        """, "repro.serve.engine")


# ------------------------------------------------------------------ RL004

def test_rl004_trigger_unregistered_pallas_call():
    assert "RL004" in codes("""
        from jax.experimental import pallas as pl
        def my_op_pallas(x):
            return pl.pallas_call(lambda r, o: None)(x)
        """, "repro.kernels.my_op", registry=None)


def test_rl004_near_miss_registered_site():
    registry = {"my_op_pallas": {
        "module": "repro.kernels.my_op",
        "ref": "repro.kernels.ref:my_op_ref",
        "parity": ("tests/test_kernels.py::test_my_op",)}}
    assert "RL004" not in codes("""
        from jax.experimental import pallas as pl
        def my_op_pallas(x):
            return pl.pallas_call(lambda r, o: None)(x)
        """, "repro.kernels.my_op", registry=registry)


# ------------------------------------------------------------------ RL005

def test_rl005_trigger_jit_in_loop():
    assert "RL005" in codes("""
        import jax
        def run(xs):
            for x in xs:
                f = jax.jit(lambda a: a + 1)
                f(x)
        """, "repro.serve.engine")


def test_rl005_trigger_unhashable_static():
    assert "RL005" in codes("""
        import functools
        import jax
        @functools.partial(jax.jit, static_argnames=("ks",))
        def f(x, ks):
            return x
        def g(x):
            return f(x, ks=[1, 2])
        """, "repro.serve.engine")


def test_rl005_near_misses():
    assert "RL005" not in codes("""
        import functools
        import jax
        f = jax.jit(lambda a: a + 1)
        @functools.partial(jax.jit, static_argnames=("ks",))
        def h(x, ks):
            return x
        def g(xs):
            for x in xs:
                f(x)
            return h(xs[0], ks=(1, 2))
        """, "repro.serve.engine")


# ------------------------------------------------------------------ RL006

def test_rl006_trigger_default_int_mirror():
    assert "RL006" in codes("""
        import numpy as np
        def reset(self, n):
            self.cur_len = np.zeros(n)
        """, "repro.serve.engine")


def test_rl006_near_miss_explicit_int32():
    assert "RL006" not in codes("""
        import numpy as np
        def reset(self, n, spec):
            self.cur_len = np.zeros(n, np.int32)
            self.tables = np.full((n, spec), -1, np.int32)
        """, "repro.serve.engine")


# ------------------------------------------------------------------ RL007

def test_rl007_trigger_inline_pspec():
    assert "RL007" in codes("""
        from jax.sharding import PartitionSpec
        def specs():
            return PartitionSpec("model", None)
        """, "repro.serve.engine")
    assert "RL007" in codes("""
        from jax.sharding import PartitionSpec as P
        def specs():
            return P("model", None, None)
        """, "repro.models.moe_shardmap")


def test_rl007_near_misses():
    # replicated () encodes no placement; partitioning.py is the one home
    assert "RL007" not in codes("""
        from jax.sharding import PartitionSpec
        def specs():
            return PartitionSpec()
        """, "repro.serve.engine")
    assert "RL007" not in codes("""
        from jax.sharding import PartitionSpec
        def specs():
            return PartitionSpec("model", None)
        """, "repro.distributed.partitioning")


# ------------------------------------------------------------------ RL008

def test_rl008_trigger_direct_env_read():
    assert "RL008" in codes("""
        import os
        DEBUG = os.environ.get("REPRO_DEBUG", "") == "1"
        """, "repro.serve.engine")
    assert "RL008" in codes("""
        import os
        IMPL = os.getenv("REPRO_DEQUANT_IMPL")
        """, "repro.kernels.ops")


def test_rl008_near_misses():
    assert "RL008" not in codes("""
        import os
        FLAGS = os.environ.get("XLA_FLAGS", "")
        """, "repro.launch.dryrun")
    assert "RL008" not in codes("""
        import os
        DEBUG = os.environ.get("REPRO_DEBUG", "") == "1"
        """, "repro.debug_flags")


# ------------------------------------------------------------------ pragma

def test_pragma_suppresses_only_named_rule():
    src = """
        import os
        A = os.environ.get("REPRO_DEBUG")  # repro-lint: disable=RL008
        B = os.environ.get("REPRO_DEBUG")  # repro-lint: disable=RL001
        C = os.environ.get("REPRO_DEBUG")
        """
    found = lint_source(textwrap.dedent(src), "fixture.py",
                        module="repro.serve.engine")
    lines = sorted(f.line for f in found if f.rule == "RL008")
    assert lines == [4, 5]  # A suppressed; B names the wrong rule; C bare


def test_pragma_on_preceding_line():
    src = """
        import os
        # repro-lint: disable=RL008
        A = os.environ.get("REPRO_DEBUG")
        """
    assert lint_source(textwrap.dedent(src), "fixture.py",
                       module="repro.serve.engine") == []


# ------------------------------------------------- tree-level acceptance

def test_linter_runs_clean_on_src():
    findings = lint_paths([SRC], tests=TESTS)
    assert findings == [], "\n".join(f.format() for f in findings)


def test_diagnostic_format_is_file_line_rule_message():
    found = lint_source("import os\nX = os.getenv('REPRO_X')\n",
                        "src/repro/serve/x.py")
    assert len(found) == 1
    path, line, rule = found[0].path, found[0].line, found[0].rule
    assert found[0].format().startswith(f"{path}:{line} {rule} ")
    assert rule in RULE_DOCS


def test_registry_covers_every_pallas_call_site():
    files = iter_py_files([SRC])
    registry = extract_registry(files)
    assert registry, "KERNEL_CONTRACTS literal missing from kernels/ops.py"
    sites = {}
    for path in files:
        with open(path, encoding="utf-8") as f:
            source = f.read()
        if "pallas_call" not in source:
            continue
        tree = ast.parse(source)
        stack = [(tree, None)]
        # map each pallas_call to its enclosing def name
        def walk(node, fname):
            for child in ast.iter_child_nodes(node):
                nm = fname
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    nm = child.name
                if (isinstance(child, ast.Call)
                        and isinstance(child.func, ast.Attribute)
                        and child.func.attr == "pallas_call"):
                    sites[fname] = module_name_for(path)
                walk(child, nm)
        walk(tree, None)
    assert sites, "no pallas_call sites found under src/"
    for wrapper, mod in sorted(sites.items()):
        assert wrapper in registry, f"unregistered pallas kernel {wrapper}"
        assert registry[wrapper]["module"] == mod
    for wrapper in registry:
        assert wrapper in sites, f"stale registry entry {wrapper}"


def test_registry_cross_check_is_clean_and_catches_breakage():
    files = iter_py_files([SRC])
    registry = extract_registry(files)
    assert cross_check_registry(registry, files, TESTS) == []
    # a dangling parity id / ref oracle must be reported
    broken = dict(registry)
    broken["ghost_pallas"] = {"module": "repro.kernels.ghost",
                              "ref": "repro.kernels.ref:ghost_ref",
                              "parity": ("tests/test_nope.py::test_x",)}
    found = cross_check_registry(broken, files, TESTS)
    assert any(f.rule == "RL004" for f in found)
