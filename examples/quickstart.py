"""Quickstart: quantize a small LM with Norm-Tweaking in ~2 minutes on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import TINY
from repro.core.calibration.generator import generate_calibration
from repro.core.normtweak.pipeline import NTConfig, norm_tweak_ptq
from repro.data.synthetic import heldout_split, make_corpus
from repro.data.pipeline import DataPipeline
from repro.models.transformer import init_lm
from repro.optim.schedules import warmup_cosine
from repro.serve.engine import ServeEngine
from repro.train.evaluate import perplexity
from repro.train.train_step import init_opt_state, make_train_step


def main():
    cfg = TINY.replace(n_repeats=4)
    corpus, meta = make_corpus(cfg.vocab_size, 60_000, seed=0)
    train_toks, held = heldout_split(corpus)

    print("== 1. train a small float LM (100 steps) ==")
    params = init_lm(cfg, jax.random.PRNGKey(0))
    pipe = DataPipeline(train_toks, batch_size=16, seq_len=64, seed=0)
    step = make_train_step(cfg, lr_schedule=warmup_cosine(3e-3, 10, 100))
    opt = init_opt_state(cfg, params)
    for s in range(100):
        batch = {k: jax.numpy.asarray(v) for k, v in pipe.batch_at(s).items()}
        params, opt, m = step(params, opt, batch, jax.numpy.asarray(s),
                              jax.random.PRNGKey(1))
    print(f"   float ppl = {perplexity(cfg, params, held)['ppl']:.3f}")

    print("== 2. self-generate calibration data (paper §Calibration) ==")
    calib = generate_calibration(
        cfg, params, jax.random.PRNGKey(7), n_samples=16, token_length=64,
        allowed_first=meta.top_language_tokens(2))

    print("== 3. GPTQ W4 baseline vs GPTQ + Norm-Tweaking ==")
    for tweak in (False, True):
        nt = NTConfig(method="gptq", bits=4, tweak=tweak, lr0=1e-3, iters=1,
                      sample_batch=4)
        qp, _ = norm_tweak_ptq(cfg, params, calib, nt)
        tag = "gptq+nt" if tweak else "gptq   "
        print(f"   {tag} ppl = {perplexity(cfg, qp, held)['ppl']:.3f}")

    print("== 4. serve the quantized model ==")
    eng = ServeEngine(cfg, qp)
    prompts = np.asarray(held[:32]).reshape(2, 16)
    res = eng.generate(prompts, max_new=16, temperature=0.0)
    print("   generated token ids:", res.tokens[0].tolist())


if __name__ == "__main__":
    main()
