"""End-to-end serving driver (the paper's deployment story):

  1. loads the trained tiny LM (trains + caches it on first run),
  2. quantizes it W4 / W2g64 with GPTQ + Norm-Tweaking,
  3. serves a batch of requests through the batched engine with packed
     low-bit weights (the Pallas dequant-matmul path on TPU),
  4. prints side-by-side continuations (paper Table 5, subjective eval).

    PYTHONPATH=src:. python examples/serve_quantized.py [--bits 2]
"""
import argparse

import jax
import numpy as np

from benchmarks.common import get_trained_tiny
from repro.core.calibration.generator import generate_calibration
from repro.core.normtweak.pipeline import NTConfig, norm_tweak_ptq
from repro.serve.engine import ServeEngine
from repro.train.evaluate import perplexity


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--group-size", type=int, default=-1)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg, params, (corpus, meta, train_toks, held, evals) = get_trained_tiny()
    calib = generate_calibration(
        cfg, params, jax.random.PRNGKey(7), n_samples=32, token_length=64,
        allowed_first=meta.top_language_tokens(2))

    engines = {"fp32": ServeEngine(cfg, params)}
    for tweak in (False, True):
        nt = NTConfig(method="gptq", bits=args.bits,
                      group_size=args.group_size, tweak=tweak, lr0=1e-3,
                      iters=1, sample_batch=4)
        qp, _ = norm_tweak_ptq(cfg, params, calib, nt)
        name = f"gptq{'+nt' if tweak else ''}_w{args.bits}"
        engines[name] = ServeEngine(cfg, qp)
        print(f"{name}: heldout ppl = "
              f"{perplexity(cfg, qp, held)['ppl']:.3f}")

    rng = np.random.default_rng(0)
    starts = rng.integers(0, len(held) - 64, size=args.batch)
    prompts = np.stack([held[s:s + 16] for s in starts])

    print(f"\n== batched generation ({args.batch} requests, "
          f"{args.max_new} new tokens) ==")
    for name, eng in engines.items():
        res = eng.generate(prompts, max_new=args.max_new, temperature=0.0)
        print(f"[{name}] request 0 continuation: {res.tokens[0].tolist()}")


if __name__ == "__main__":
    main()
