"""Quantize any zoo architecture with Norm-Tweaking (smoke-scale weights).

    PYTHONPATH=src python examples/quantize_llm.py --arch mixtral-8x22b \
        --bits 4 --method gptq --out /tmp/qmodel

Runs the full Algorithm-1 pipeline on the reduced config of the chosen
architecture (full configs need a pod — see launch/dryrun.py) and saves a
servable packed checkpoint.
"""
import argparse

import jax

from repro.checkpoint.store import save_tree
from repro.configs import get_smoke_config, list_archs
from repro.core.calibration.generator import (generate_calibration,
                                              random_calibration)
from repro.core.normtweak.pipeline import NTConfig, norm_tweak_ptq
from repro.models.transformer import init_lm, lm_forward
from repro.utils.tree import tree_size_bytes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=list_archs())
    ap.add_argument("--method", default="gptq",
                    choices=["gptq", "rtn", "smoothquant"])
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--group-size", type=int, default=-1)
    ap.add_argument("--no-tweak", action="store_true")
    ap.add_argument("--lr0", type=float, default=1e-3)
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if cfg.enc_dec:
        raise SystemExit("use tests/test_system.py::test_encdec_pipeline for "
                         "whisper; this driver covers decoder-only archs")
    print(f"arch={cfg.name} (smoke config, {cfg.n_layers} layers)")
    params = init_lm(cfg, jax.random.PRNGKey(0))
    print(f"float params: {tree_size_bytes(params) / 1e6:.1f} MB")

    # self-generated calibration (random-init models generate noise, which
    # still exercises the full pipeline; trained models generate text)
    calib = generate_calibration(cfg, params, jax.random.PRNGKey(1),
                                 n_samples=8, token_length=32)
    nt = NTConfig(method=args.method, bits=args.bits,
                  group_size=args.group_size, tweak=not args.no_tweak,
                  lr0=args.lr0, iters=1, sample_batch=4,
                  act_bits=8 if args.method == "smoothquant" else 0)
    qparams, stats = norm_tweak_ptq(cfg, params, calib, nt,
                                    log=lambda s: print("  " + s))
    print(f"quantized params: {tree_size_bytes(qparams) / 1e6:.1f} MB")
    logits, _ = lm_forward(cfg, qparams, calib[:2])
    print(f"quantized forward ok: {logits.shape}")
    if args.out:
        save_tree(args.out, qparams, {"arch": cfg.name, "bits": args.bits,
                                      "method": args.method})
        print(f"saved -> {args.out}")


if __name__ == "__main__":
    main()
