"""Fault-tolerant training driver: checkpoint/resume + straggler detection.

    PYTHONPATH=src python examples/train_lm.py --steps 150 --crash-at 60

With --crash-at N the process injects a failure at step N; re-running the
same command resumes bit-exactly from the last checkpoint (the data pipeline
is a pure function of (seed, step), so no batches are skipped or replayed).
"""
import argparse

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs import TINY
from repro.data.pipeline import DataPipeline
from repro.data.synthetic import heldout_split, make_corpus
from repro.launch.elastic import ElasticCoordinator
from repro.models.transformer import init_lm
from repro.optim.schedules import warmup_cosine
from repro.train.evaluate import perplexity
from repro.train.train_step import init_opt_state, make_train_step
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--crash-at", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--grad-compress-bits", type=int, default=0)
    args = ap.parse_args()

    cfg = TINY.replace(n_repeats=4)
    corpus, _ = make_corpus(cfg.vocab_size, 100_000, seed=0)
    train_toks, held = heldout_split(corpus)

    params = init_lm(cfg, jax.random.PRNGKey(0))
    pipe = DataPipeline(train_toks, batch_size=16, seq_len=64, seed=0)
    step_fn = make_train_step(
        cfg, lr_schedule=warmup_cosine(3e-3, 20, args.steps),
        grad_compress_bits=args.grad_compress_bits)
    opt = init_opt_state(cfg, params,
                         grad_compress_bits=args.grad_compress_bits)
    ckpt = CheckpointManager(args.ckpt_dir, keep=3)

    coord = ElasticCoordinator(512)  # pod-scale policy (informational here)

    def on_straggler(step, dt):
        plan = coord.straggler(step, dt)
        if plan:
            print(f"!! persistent straggler at step {step}: would remesh to "
                  f"{plan.shape} with accum x{plan.accum_steps}")

    trainer = Trainer(cfg, params, opt, step_fn, pipe, ckpt,
                      on_straggler=on_straggler)
    start = trainer.maybe_resume()
    if start:
        print(f"resumed from checkpoint at step {start}")
    result = trainer.run(args.steps, ckpt_every=25, log_every=25,
                         crash_at=args.crash_at)
    print(f"done: {result}")
    print(f"heldout ppl = {perplexity(cfg, trainer.params, held)['ppl']:.3f}")


if __name__ == "__main__":
    main()
