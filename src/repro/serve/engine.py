"""Serving engines for (quantized) LMs.

Weights may be float or packed QuantizedTensor (the paper's deployment
format — dequant happens inside the fused Pallas matmul on TPU; both
engines accept `quant_bits=...` to pack a float tree in place via
`quantize_params_for_serving`). Decode steps present M = n_slots (or
batch) token rows per linear, which rides the decode-shaped skinny-M
kernel tiles picked by kernels/ops.py; quantized MoE experts run the
expert-batched kernel without materializing float expert stacks. Two
engines share the model code:

  * ServeEngine        — static batch: one prompt length, lockstep decode to
                         max_new. Kept as the baseline and for scoring.
  * ContinuousEngine   — continuous batching over a fixed slot pool with a
                         paged KV cache (serve/kvcache.py): requests are
                         admitted into free slots as others retire, each
                         slot decodes at its own depth, and finished
                         requests stop burning decode FLOPs. All jitted
                         shapes are static (slot count, page pool, bucketed
                         prefill lengths), so steady-state serving never
                         recompiles. Decode attention runs the fused
                         paged-attention kernel by default (block-table walk
                         + inline int8-KV dequant inside the kernel); pass
                         paged_attn="gather" for the gather->dequant->einsum
                         oracle path (see DESIGN.md "Paged-attention decode
                         kernel").

`ContinuousEngine(tp=N)` runs the whole serving step tensor-parallel over
an N-way "model" mesh: packed weights and scales are placed per the
serving TP contract (distributed/partitioning.py), the paged KV pools
shard along their kv-head dim (each device holds its head slice of every
page), and the prefill/decode jits run the model per-shard under
`shard_map` with psums at the attention/MLP output projections. Logits
come out identical on every shard (replicated lm_head), so sampling and
all host-side bookkeeping — scheduler, page budget, block tables — are
TP-invariant. See DESIGN.md "Tensor-parallel serving".

The traffic driver (Poisson arrivals, latency percentiles) lives in
launch/serve.py; admission policy lives in serve/scheduler.py.
"""
from __future__ import annotations

import dataclasses
import functools
import zlib
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec

from repro.analysis.sanitize import note_trace
from repro.core.quant.types import QuantizedTensor, localize_quantized
from repro.debug_flags import debug_enabled
from repro.distributed.partitioning import (paged_pool_pspecs,
                                            serve_param_shardings,
                                            serve_tp_widths, tp_local_cfg)
from repro.distributed.sharding import TP_AXIS, sharding_ctx
from repro.models.config import ModelConfig
from repro.models.transformer import (init_cache, lm_decode, lm_forward,
                                      lm_prefill, lm_verify)
from repro.serve.faults import FaultInjected, FaultPlan
from repro.serve.kvcache import (POOL_KEYS, PagePool, PageSpec,
                                 default_page_spec, pool_head_dim)
from repro.serve.sampling import (sample, spec_accept_greedy,
                                  spec_accept_sample)
from repro.serve.scheduler import Request, Scheduler


@dataclasses.dataclass
class GenerateResult:
    tokens: np.ndarray          # (B, max_new)
    n_prompt: int
    steps: int


@functools.partial(jax.jit, static_argnames=("cfg", "max_new", "temperature",
                                             "top_k", "eos_id"))
def _generate_jit(cfg, params, prompts, key, max_new, temperature, top_k,
                  eos_id):
    b, s = prompts.shape
    # note_trace calls sit inside jit bodies on purpose: the Python side
    # effect runs once per compilation and never on cache hits, so under
    # REPRO_SANITIZE=1 they count compiled variants (repro.analysis.sanitize)
    note_trace("generate", batch=b, prompt=s, max_new=max_new,
               temperature=temperature, top_k=top_k)
    cache = init_cache(cfg, b, s + max_new)
    logits, cache = lm_prefill(cfg, params, prompts, cache)

    def step(carry, t):
        cache, logits, key, done = carry
        key, sk = jax.random.split(key)
        tok = sample(logits, sk, temperature=temperature, top_k=top_k)
        tok = jnp.where(done, eos_id, tok)
        done = done | (tok == eos_id) if eos_id >= 0 else done
        pos = jnp.full((b, 1), s + t, jnp.int32)
        logits, cache = lm_decode(cfg, params, tok[:, None], cache, pos)
        return (cache, logits, key, done), tok

    (_, _, _, _), toks = jax.lax.scan(
        step, (cache, logits, key, jnp.zeros((b,), bool)),
        jnp.arange(max_new, dtype=jnp.int32))
    return toks.T                                              # (B, max_new)


def _maybe_quantize(cfg, params, quant_bits, quant_group, act_bits,
                    mesh=None):
    """Pack a float param tree for serving when quant_bits is set (no-op on
    already-packed trees: QuantizedTensor leaves are left untouched).
    quant_group follows the deploy convention: 0 = cfg.serve_quant_group,
    -1 = per-channel. With a mesh, packing is followed by the TP placement
    step — packed and float leaves alike are device_put per the serving
    contract instead of staying replicated."""
    from repro.core.quant.deploy import (place_params_for_serving,
                                         quantize_params_for_serving)

    if not quant_bits:
        if act_bits:
            raise ValueError("act_bits requires quant_bits (A8 tags live on "
                             "packed QuantizedTensors)")
        return place_params_for_serving(cfg, params, mesh)
    leaves = jax.tree_util.tree_leaves(
        params, is_leaf=lambda x: isinstance(x, QuantizedTensor))
    if any(isinstance(x, QuantizedTensor) for x in leaves):
        raise ValueError("params already hold packed QuantizedTensors; "
                         "pass quant_bits=0 (re-packing is a silent no-op "
                         "and would drop the requested act_bits/group)")
    return quantize_params_for_serving(cfg, params, bits=quant_bits,
                                       group_size=quant_group,
                                       act_bits=act_bits, mesh=mesh)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, eos_id: int = -1,
                 quant_bits: int = 0, quant_group: int = 0,
                 act_bits: int = 0):
        self.cfg = cfg
        self.params = _maybe_quantize(cfg, params, quant_bits, quant_group,
                                      act_bits)
        self.eos_id = eos_id

    def generate(self, prompts: np.ndarray, *, max_new: int = 32,
                 temperature: float = 0.0, top_k: int = 0,
                 key: Optional[jax.Array] = None) -> GenerateResult:
        key = key if key is not None else jax.random.PRNGKey(0)
        toks = _generate_jit(self.cfg, self.params,
                             jnp.asarray(prompts, jnp.int32), key, max_new,
                             temperature, top_k, self.eos_id)
        return GenerateResult(np.asarray(toks), prompts.shape[1], max_new)

    def score(self, tokens: np.ndarray) -> np.ndarray:
        """Per-token log-likelihoods (B, S-1)."""
        toks = jnp.asarray(tokens, jnp.int32)
        logits, _ = lm_forward(self.cfg, self.params, toks[:, :-1])
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, toks[:, 1:][..., None],
                                 axis=-1)[..., 0]
        return np.asarray(ll - lse)


# ------------------------------------------------------- continuous batching

def _params_sig(params) -> str:
    """Coarse weight signature for sanitizer trace keys: the quantized
    bit-widths present in the tree ("w2", "w4/8"), or "f32". Target and
    draft params reach the same jits with different leaf shapes — without
    this in the key, their two legitimate compilations would read as one
    variant traced twice (a false budget violation)."""
    leaves = jax.tree_util.tree_leaves(
        params, is_leaf=lambda x: isinstance(x, QuantizedTensor))
    bits = sorted({x.bits for x in leaves if isinstance(x, QuantizedTensor)})
    return "w" + "/".join(map(str, bits)) if bits else "f32"


@functools.partial(jax.jit, static_argnames=("cfg",),
                   donate_argnames=("cache",))
def _paged_prefill_jit(cfg, params, tokens, cache, positions, paged):
    note_trace("paged_prefill", batch=tokens.shape[0],
               bucket=tokens.shape[1], impl=cfg.paged_attn_impl,
               w=_params_sig(params))
    return lm_prefill(cfg, params, tokens, cache, positions=positions,
                      paged=paged)


@functools.partial(jax.jit, static_argnames=("temperature", "top_k"))
def _sample_first_jit(logits, keys, *, temperature, top_k):
    """Per-request first-token sampling: logits (B, V), keys (B, 2).

    Each row draws from its own key (folded from the request id by the
    engine), so the result does not depend on how admitted requests were
    grouped into prefill batches — the same seed gives the same tokens at
    prefill_batch=1 and prefill_batch=8. Also returns the per-row isfinite
    sentinel so a prompt whose prefill produced non-finite logits is
    quarantined before it ever enters the decode set."""
    note_trace("sample_first", batch=logits.shape[0],
               temperature=temperature, top_k=top_k)
    toks = jax.vmap(lambda l, k: sample(l[None], k, temperature=temperature,
                                        top_k=top_k)[0])(logits, keys)
    return toks, jnp.all(jnp.isfinite(logits), axis=-1)


# ------------------------------------------------------ KV spill / restore
#
# Preemption moves a victim slot's exclusively-owned live pages to host RAM
# and back. Both directions walk the whole cache tree and touch only the
# paged pool leaves (k/v pools + their int8 scale pools), indexing each
# along its page axis — (P, ps, KVH[, hd]) unstacked, (L, P, ...) for the
# scan-stacked layer dim — so one call moves every layer's slice of the
# spilled pages at once. Page-count shapes are pow2-padded by the engine
# (pad entries target the scratch page, which is never read) to bound the
# number of compiled shapes.

def _pool_page_axis(key: str, ndim: int) -> int:
    """Page axis of a paged pool leaf: two dims left of the kv-head dim
    (pool layout ... P, page_size, KVH[, hd])."""
    return pool_head_dim(key, ndim) - 2


def _tree_checksum(tree) -> int:
    """crc32 over every array leaf of a (nested-dict) host tree, walked in
    sorted-key order so the digest is layout-stable. Cheap enough to run on
    every spill (host RAM bandwidth, no device sync) and catches the
    corruption class that matters: bytes flipped while a snapshot sits in
    host memory awaiting restore."""
    crc = 0
    if isinstance(tree, dict):
        for k in sorted(tree):
            crc = zlib.crc32(str(k).encode(), crc)
            crc = zlib.crc32(_tree_checksum(tree[k]).to_bytes(4, "little"),
                             crc)
        return crc
    if tree is None:
        return 0
    arr = np.ascontiguousarray(np.asarray(tree))
    return zlib.crc32(arr.tobytes(), zlib.crc32(str(arr.dtype).encode()))


def _corrupt_first_leaf(tree):
    """Flip one byte of the first array leaf (sorted-key walk) — the
    spill_corrupt fault's payload damage. Returns (new_tree, corrupted)."""
    if isinstance(tree, dict):
        out, hit = {}, False
        for k in sorted(tree):
            if hit:
                out[k] = tree[k]
            else:
                out[k], hit = _corrupt_first_leaf(tree[k])
        # preserve original (insertion) key order of the input dict
        return {k: out[k] for k in tree}, hit
    if tree is None:
        return tree, False
    arr = np.asarray(tree).copy()
    flat = arr.view(np.uint8).reshape(-1)
    flat[0] ^= 0xFF
    return arr, True


@jax.jit
def _spill_gather_jit(cache, idx):
    """Gather pages `idx` (P,) from every pool leaf -> host-bound tree
    with a leading/inner page dim of len(idx); non-pool leaves drop."""
    note_trace("spill_gather", pages=idx.shape[0])

    def walk(tree, key=None):
        if isinstance(tree, dict):
            return {k: walk(v, k) for k, v in tree.items()}
        if key in POOL_KEYS:
            return jnp.take(tree, idx, axis=_pool_page_axis(key, tree.ndim))
        return None
    return walk(cache)


@functools.partial(jax.jit, donate_argnames=("cache",))
def _spill_scatter_jit(cache, idx, host):
    """Scatter a spill snapshot back: write host[...] into pages `idx` of
    every pool leaf (inverse of _spill_gather_jit)."""
    note_trace("spill_scatter", pages=idx.shape[0])

    def walk(tree, htree, key=None):
        if isinstance(tree, dict):
            return {k: walk(v, htree[k], k) for k, v in tree.items()}
        if key in POOL_KEYS and htree is not None:
            ax = _pool_page_axis(key, tree.ndim)
            loc = (slice(None),) * ax + (idx,)
            return tree.at[loc].set(htree.astype(tree.dtype))
        return tree
    return walk(cache, host)


def _decode_scan(cfg, params, cache, last_tok, cur_len, active,
                 block_table, key, *, k_steps, page_size,
                 temperature, top_k, with_logits=False, poison=None):
    """K fused decode steps over all slots with on-device sampling.

    One dispatch and one host sync per K tokens — the per-step Python/
    transfer overhead of a step-at-a-time loop would otherwise rival the
    model compute. Slots whose request finishes mid-block keep stepping;
    their extra writes fall off the block table onto the scratch page and
    the host drops the surplus tokens. Returns ((K, S) tokens, (K, S)
    alive, cache) — or ((K, S) tokens, (K, S, V) logits, (K, S) alive,
    cache) under `with_logits`, for the speculative draft whose
    temperature>0 acceptance rule needs the distribution each proposal was
    sampled from.

    The alive mask is the graceful-degradation sentinel: one cheap (S,)
    isfinite reduction over each step's logits. A slot whose logits go
    non-finite is *deactivated inside the scan* — its token freezes, its
    fill count stops, and its rows stop feeding the model — so a poisoned
    slot cannot perturb co-batched slots through cross-token paths
    (capacity-MoE routing) on later steps of the same block. The host
    reads alive, drops the garbage token, and quarantines the request.
    `poison` (S,) bool is the fault-injection hook: marked slots get NaN
    logits on the first step, exercising exactly the real failure path.

    Shared by the single-device jit and the shard_map TP jit below — under
    TP, `cfg` is the head-localized per-shard view and `params`/`cache`
    are the shard-local slices (tokens, lengths, tables, key replicated).
    """
    n_slots, max_pages = block_table.shape
    sl = jnp.arange(n_slots)
    if poison is None:
        poison = jnp.zeros(n_slots, bool)

    def body(carry, first):
        cache, tok, clen, key, alive = carry
        act = active & alive
        key, sk = jax.random.split(key)
        page_idx = jnp.clip(clen // page_size, 0, max_pages - 1)
        paged = {
            "block_table": block_table,
            "write_page": jnp.where(
                act, jnp.maximum(block_table[sl, page_idx], 0), 0),
            "write_off": jnp.where(act, clen % page_size, 0),
            "kv_len": jnp.where(act, clen + 1, 0),
        }
        pos = jnp.where(act, clen, 0)[:, None]
        logits, cache = lm_decode(cfg, params, tok[:, None], cache, pos,
                                  paged=paged)
        logits = jnp.where((first & poison)[:, None],
                           jnp.float32(jnp.nan).astype(logits.dtype), logits)
        # sentinel: a slot dies the step its logits stop being finite
        # (inactive slots read garbage rows — only active ones can die)
        alive = alive & (jnp.all(jnp.isfinite(logits), axis=-1) | ~act)
        nxt = sample(logits, sk, temperature=temperature, top_k=top_k)
        keep = act & alive
        tok = jnp.where(keep, nxt, tok)
        clen = clen + keep.astype(clen.dtype)
        return (cache, tok, clen, key, alive), (
            (nxt, logits, alive) if with_logits else (nxt, alive))

    first = jnp.zeros(k_steps, bool).at[0].set(True)
    (cache, _, _, _, _), ys = jax.lax.scan(
        body, (cache, last_tok, cur_len, key, jnp.ones(n_slots, bool)),
        first, length=k_steps)
    if with_logits:
        return ys[0], ys[1], ys[2], cache
    return ys[0], ys[1], cache


@functools.partial(jax.jit,
                   static_argnames=("cfg", "k_steps", "page_size",
                                    "temperature", "top_k"),
                   donate_argnames=("cache",))
def _paged_decode_scan_jit(cfg, params, cache, last_tok, cur_len, active,
                           block_table, key, poison, *, k_steps, page_size,
                           temperature, top_k):
    note_trace("paged_decode_scan", k=k_steps, slots=block_table.shape[0],
               width=block_table.shape[1], temperature=temperature,
               top_k=top_k, impl=cfg.paged_attn_impl, w=_params_sig(params))
    return _decode_scan(cfg, params, cache, last_tok, cur_len, active,
                        block_table, key, k_steps=k_steps,
                        page_size=page_size, temperature=temperature,
                        top_k=top_k, poison=poison)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "k_steps", "page_size",
                                    "temperature", "top_k"),
                   donate_argnames=("cache", "draft_cache"))
def _spec_block_jit(cfg, params, draft_params, cache, draft_cache, last_tok,
                    cur_len, active, block_table, key, *, k_steps, page_size,
                    temperature, top_k):
    """One fused speculative round: draft-propose, target-verify, accept.

    The low-bit draft runs k_steps+1 autoregressive decode steps from the
    shared `last_tok`/`cur_len` state — step i writes the K/V of the token
    it was fed, so after the extra step the draft cache is complete through
    position cur_len + k_steps whatever prefix the target accepts (rollback
    is then free: rejected-tail entries sit beyond the advanced fill count
    and are masked by construction until overwritten). The last proposal is
    discarded; d_1..d_k plus the pending last token form the (S, k+1)
    verify batch the target scores in a single prefill-shaped forward
    (fused small-M page walk — see kernels/paged_attention.py). Greedy
    acceptance emits only target argmaxes, so the stream is bit-identical
    to target-only decode; temperature>0 uses residual resampling.

    Returns (out (S, M) tokens, n_emit (S,), cache, draft_cache) — slot s
    emits out[s, :n_emit[s]].
    """
    n_slots = block_table.shape[0]
    note_trace("spec_block", k=k_steps, slots=n_slots,
               width=block_table.shape[1], temperature=temperature,
               top_k=top_k, impl=cfg.paged_attn_impl,
               w=_params_sig(params), dw=_params_sig(draft_params))
    kd, kv = jax.random.split(key)
    m = k_steps + 1
    draft = _decode_scan(cfg, draft_params, draft_cache, last_tok, cur_len,
                         active, block_table, kd, k_steps=m,
                         page_size=page_size, temperature=temperature,
                         top_k=top_k, with_logits=(temperature > 0.0))
    if temperature > 0.0:
        draft_toks, draft_logits, _, draft_cache = draft
    else:
        (draft_toks, _, draft_cache), draft_logits = draft, None
    # verify rows: [last_tok, d_1..d_k] at absolute positions cur_len..
    # cur_len+k (inactive slots parked at -1 / kv_len 0 — their writes land
    # on the scratch page and their rows read as garbage we never emit)
    x = jnp.concatenate([last_tok[:, None], draft_toks[:m - 1].T], axis=1)
    positions = jnp.where(
        active[:, None],
        cur_len[:, None] + jnp.arange(m, dtype=cur_len.dtype)[None, :], -1)
    paged = {"bt_rows": block_table,
             "slots": jnp.arange(n_slots, dtype=jnp.int32),
             "kv_len": jnp.where(active, cur_len + m, 0),
             "verify": jnp.int32(1)}
    logits, cache = lm_verify(cfg, params, x, cache, positions, paged)
    if temperature > 0.0:
        out, n_emit = spec_accept_sample(
            logits, draft_logits[:m - 1].transpose(1, 0, 2), x[:, 1:], kv,
            temperature=temperature, top_k=top_k)
    else:
        out, n_emit = spec_accept_greedy(logits, x[:, 1:])
    return out, jnp.where(active, n_emit, 0), cache, draft_cache


# ------------------------------------------------- tensor-parallel variants
#
# The TP jits wrap the same model code in a shard_map over the serving
# mesh: params/caches enter with their placement specs (shard-local heads
# and mlp slices inside), everything host-shaped — tokens, positions,
# lengths, block tables, RNG keys — is replicated, and the outputs are
# replicated logits/tokens plus the re-sharded cache. Row-parallel psums
# inside the model (cfg.tp > 1) make per-shard activations exact, so every
# shard samples the same token from the same key — no token collective.
# QuantizedTensor statics are re-localized at body entry because shard_map
# splits the qw/scale children but not the recorded (K, N).

def _tp_in_specs(cfg, mesh, params, cache, paged):
    rep = PartitionSpec()
    pspecs = serve_param_shardings(mesh, cfg, params, specs_only=True)
    cspecs = paged_pool_pspecs(cache, mesh, axis=TP_AXIS)
    paged_specs = jax.tree.map(lambda _: rep, paged)
    return pspecs, cspecs, paged_specs


@functools.partial(jax.jit, static_argnames=("cfg", "mesh"),
                   donate_argnames=("cache",))
def _paged_prefill_tp_jit(cfg, mesh, params, tokens, cache, positions, paged):
    note_trace("paged_prefill_tp", batch=tokens.shape[0],
               bucket=tokens.shape[1], tp=cfg.tp, impl=cfg.paged_attn_impl,
               w=_params_sig(params))
    lcfg = tp_local_cfg(cfg)
    rep = PartitionSpec()
    pspecs, cspecs, paged_specs = _tp_in_specs(cfg, mesh, params, cache, paged)

    def body(params, tokens, cache, positions, paged):
        params = localize_quantized(params)
        with sharding_ctx(None):   # no nested GSPMD constraints under shard_map
            return lm_prefill(lcfg, params, tokens, cache,
                              positions=positions, paged=paged)

    return shard_map(body, mesh=mesh,
                     in_specs=(pspecs, rep, cspecs, rep, paged_specs),
                     out_specs=(rep, cspecs), check_rep=False)(
        params, tokens, cache, positions, paged)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "mesh", "k_steps", "page_size",
                                    "temperature", "top_k"),
                   donate_argnames=("cache",))
def _paged_decode_scan_tp_jit(cfg, mesh, params, cache, last_tok, cur_len,
                              active, block_table, key, poison, *, k_steps,
                              page_size, temperature, top_k):
    note_trace("paged_decode_scan_tp", k=k_steps,
               slots=block_table.shape[0], width=block_table.shape[1],
               tp=cfg.tp, temperature=temperature, top_k=top_k,
               impl=cfg.paged_attn_impl, w=_params_sig(params))
    lcfg = tp_local_cfg(cfg)
    rep = PartitionSpec()
    pspecs, cspecs, _ = _tp_in_specs(cfg, mesh, params, cache, {})

    def body(params, cache, last_tok, cur_len, active, block_table, key,
             poison):
        params = localize_quantized(params)
        with sharding_ctx(None):
            return _decode_scan(lcfg, params, cache, last_tok, cur_len,
                                active, block_table, key, k_steps=k_steps,
                                page_size=page_size, temperature=temperature,
                                top_k=top_k, poison=poison)

    return shard_map(body, mesh=mesh,
                     in_specs=(pspecs, cspecs, rep, rep, rep, rep, rep, rep),
                     out_specs=(rep, rep, cspecs), check_rep=False)(
        params, cache, last_tok, cur_len, active, block_table, key, poison)


class ContinuousEngine:
    """Slot-stepping execution core for continuous batching.

    Holds the paged cache, the per-slot host state (fill depth, last token),
    and the jitted prefill/decode steps. Admission policy and request
    bookkeeping are delegated to serve/scheduler.py. One `step()`:

      1. retire-then-admit: the scheduler maps queued requests onto free
         slots (FIFO; whole-budget page allocation minus any prefix-cache
         hit — see below);
      2. slots still ingesting their prompt advance by one prefill chunk —
         jitted calls batched per chunk-length bucket (pow2 batch sizes,
         capped at `prefill_batch`) that scatter K/V into the admitted
         slots' pages while every other slot's cache state is untouched;
         a slot whose prompt completes samples its first token and joins
         the decode set;
      3. one fused block of `decode_block` lockstep decode steps over all
         slots (a device-side lax.scan with on-device sampling — one
         dispatch and one host sync per K tokens). Idle and mid-prefill
         slots write to the scratch page and are masked; slots finishing
         mid-block overshoot onto the scratch page and the surplus tokens
         are dropped.

    `prefix_share=True` turns on the pool's prefix cache: a prompt whose
    full-page prefix was already prefilled by an earlier request reuses
    those pages by reference and prefills only the unshared suffix.
    `chunked_prefill=N` caps each prefill call at N tokens (rounded to a
    page multiple), spreading a long prompt across `step()` ticks so
    decode slots keep stepping instead of stalling behind it. Both
    features need the gathered-context prefill read path and per-page
    prompt state, so they cover attention-only decoders (no SSM state, no
    MLA latent prefill). See DESIGN.md "Prefix cache & chunked prefill".

    `spec_decode=True` swaps the decode block for self-speculative
    rounds: a W2/W3 draft packed from the *same* params proposes up to
    `spec_k` tokens per slot, the target verifies them in one fused
    (S, k+1)-row forward, and each slot emits its accepted prefix — the
    greedy stream is bit-identical to target-only decode, only the number
    of target forwards changes. The draft KV rides a second cache over
    the same PageSpec/block tables. See DESIGN.md "Self-speculative
    decoding".

    `preempt=True` arms overload discipline: `submit(..., priority=1)`
    marks batch-class work, and when an interactive request cannot be
    admitted the scheduler evicts a batch victim — the engine spills the
    victim's exclusively-owned live KV pages to host RAM (shared prefix
    pages stay resident by reference), frees its slot, and restores it
    later by re-stitching the block table and scattering the spilled
    pages back, resuming the token stream exactly where it stopped (a
    new `preempted` lifecycle state beside prefilling/decoding;
    `age_promote` bounds batch starvation). See DESIGN.md "Overload &
    preemption".

    `prefill_bucket` trades compile count for pad waste: prompts are
    left-padded (pos = -1, masked everywhere) up to the next multiple.
    Bucket 1 reproduces the static engine's unpadded prefill bit-for-bit.
    `decode_block` trades admission latency (new arrivals wait for the
    current block) against per-token dispatch overhead.
    """

    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 8,
                 max_len: int = 512, page_size: int = 16,
                 n_pages: Optional[int] = None, eos_id: int = -1,
                 prefill_bucket: int = 16, prefill_batch: int = 8,
                 decode_block: int = 8,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 quant_bits: int = 0, quant_group: int = 0,
                 act_bits: int = 0, paged_attn: Optional[str] = None,
                 prefix_share: bool = False, chunked_prefill: int = 0,
                 tp: int = 1, mesh=None, spec_decode: bool = False,
                 draft_bits: int = 2, spec_k: int = 4,
                 preempt: bool = False,
                 age_promote: Optional[float] = None,
                 faults: Optional[FaultPlan] = None):
        if cfg.enc_dec:
            raise NotImplementedError("paged serving covers decoder-only LMs")
        if mesh is not None and tp == 1:
            tp = int(mesh.shape.get(TP_AXIS, 1))
        if tp > 1:
            specs = cfg.all_layer_specs()
            if any(s.kind != "attn" for s in specs) or \
                    any(s.mlp == "moe" for s in specs):
                # EP-sharded MoE serving and SSM-state sharding are open
                # items (ROADMAP) — the placement contract below only
                # covers dense attention decoders
                raise NotImplementedError(
                    "tensor-parallel serving covers dense attention "
                    "decoders (no MoE, no SSM blocks)")
            if tp not in serve_tp_widths(cfg):
                raise ValueError(
                    f"tp={tp} is illegal for {cfg.name}: GQA head groups "
                    f"must stay whole per shard and d_ff must split evenly "
                    f"— legal widths {serve_tp_widths(cfg)}")
            if mesh is None:
                devs = jax.devices()
                if len(devs) < tp:
                    raise ValueError(f"tp={tp} needs {tp} devices, have "
                                     f"{len(devs)} (on CPU force more with "
                                     f"XLA_FLAGS=--xla_force_host_platform_"
                                     f"device_count=N)")
                mesh = jax.sharding.Mesh(np.asarray(devs[:tp]), (TP_AXIS,))
            if int(mesh.shape.get(TP_AXIS, 1)) != tp:
                raise ValueError(f"mesh axis {TP_AXIS!r} has size "
                                 f"{mesh.shape.get(TP_AXIS)} != tp={tp}")
            cfg = cfg.replace(tp=tp)
        self.tp = tp
        self.mesh = mesh if tp > 1 else None
        self.preempt = bool(preempt)
        if self.preempt:
            has_ssm = any(spec.kind != "attn"
                          for spec in cfg.all_layer_specs())
            if has_ssm or cfg.attention == "mla":
                # SSM recurrence state is slot-indexed, not page-addressed
                # (a spill snapshot of pages misses it), and a mid-prefill
                # MLA victim would need the gathered-context suffix
                # prefill MLA doesn't have — same wall as chunked prefill
                raise NotImplementedError(
                    "preempt covers attention-only decoders "
                    "(no SSM blocks, no MLA)")
            if tp > 1:
                raise NotImplementedError(
                    "preempt + tensor-parallel serving is an open item "
                    "(spill must gather per-shard kv-head slices)")
            if spec_decode:
                raise NotImplementedError(
                    "preempt + spec_decode is an open item (the draft "
                    "cache would need spilling in lockstep)")
        if prefix_share or chunked_prefill:
            has_ssm = any(spec.kind != "attn"
                          for spec in cfg.all_layer_specs())
            if has_ssm or cfg.attention == "mla":
                # SSM state is not page-addressed (a shared page carries no
                # recurrence state) and MLA's non-absorbed prefill never
                # reads the paged latent back — both would be silently
                # wrong, so refuse up front
                raise NotImplementedError(
                    "prefix_share/chunked_prefill cover attention-only "
                    "decoders (no SSM blocks, no MLA)")
        if paged_attn is not None:
            # per-engine override of the decode attention path: "fused"
            # (paged-attention kernel) or "gather" (oracle). Threaded via
            # the config because the dispatch lives in models/attention.py.
            if paged_attn not in ("fused", "gather"):
                raise ValueError(f"paged_attn must be 'fused' or 'gather', "
                                 f"got {paged_attn!r}")
            cfg = cfg.replace(paged_attn_impl=paged_attn)
        self.spec_decode = bool(spec_decode)
        self.spec_k = spec_k
        self.draft_bits = draft_bits
        if self.spec_decode:
            specs = cfg.all_layer_specs()
            if (any(s.kind != "attn" for s in specs)
                    or cfg.attention == "mla"):
                raise NotImplementedError(
                    "spec_decode covers attention-only decoders: the "
                    "verify forward rides the paged gathered/fused read "
                    "(no SSM recurrence rewind, no MLA latent prefill)")
            if any(s.mlp == "moe" for s in specs):
                # capacity routing is cross-token: an (S, M) verify batch
                # can route tokens differently from M single-token decode
                # steps, so draft/target parity (and greedy losslessness)
                # would silently break
                raise NotImplementedError(
                    "spec_decode does not cover capacity-routed MoE")
            if tp > 1 or prefix_share:
                raise NotImplementedError(
                    "spec_decode is single-device and unshared for now "
                    "(no tp>1, no prefix_share)")
            if draft_bits not in (2, 3):
                raise ValueError(f"draft_bits must be 2 or 3 (a draft at "
                                 f"the target's own width buys nothing), "
                                 f"got {draft_bits}")
            if spec_k < 1:
                raise ValueError(f"spec_k must be >= 1, got {spec_k}")
        self.cfg = cfg
        self.params = _maybe_quantize(cfg, params, quant_bits, quant_group,
                                      act_bits, mesh=self.mesh)
        if self.spec_decode:
            # the draft is the *same* params requantized harder — W2/W3
            # packed sub-byte (kernels/dequant_matmul.py unpacks inline),
            # so it adds ~bits/16 of the bf16 footprint, no second model
            from repro.core.quant.deploy import quantize_params_for_serving
            leaves = jax.tree_util.tree_leaves(
                params, is_leaf=lambda x: isinstance(x, QuantizedTensor))
            if any(isinstance(x, QuantizedTensor) for x in leaves):
                raise ValueError(
                    "spec_decode requantizes the float params into the "
                    "draft; pass float params (+ quant_bits for the "
                    "target), not a pre-packed tree")
            self.draft_params = quantize_params_for_serving(
                cfg, params, bits=draft_bits, group_size=quant_group)
        self.n_slots = n_slots
        self.eos_id = eos_id
        self.prefill_bucket = max(1, prefill_bucket)
        # prefill_batch=1 avoids co-batched prefills entirely: capacity-MoE
        # routing is cross-token, so co-batched requests can perturb each
        # other's expert assignment when capacity binds (see DESIGN.md)
        self.prefill_batch = max(1, prefill_batch)
        self.decode_block = max(1, decode_block)
        self.temperature = temperature
        self.top_k = top_k
        self.prefix_share = bool(prefix_share)
        # chunk sizes are page-aligned so every chunk boundary (and every
        # shared-prefix handoff) starts exactly at a page start
        self.chunk_tokens = (max(1, chunked_prefill // page_size) * page_size
                             if chunked_prefill else 0)
        if n_pages is None:
            self.spec = default_page_spec(n_slots, max_len, page_size)
        else:
            self.spec = PageSpec(n_pages=n_pages, page_size=page_size,
                                 max_pages=-(-max_len // page_size))
        self.pool = PagePool(self.spec, n_slots,
                             prefix_cache=self.prefix_share)
        self.sched = Scheduler(n_slots, self.pool,
                               prefix_share=self.prefix_share, tp=self.tp,
                               age_promote=age_promote,
                               preempt_hook=(self._spill_slot
                                             if self.preempt else None))
        self.cache = init_cache(cfg, n_slots, self.spec.max_len,
                                paged=self.spec)
        if self.spec_decode:
            # draft KV rides the same PagePool geometry (identical block
            # tables / scratch page / kv_cache_bits) in its own pools —
            # one allocator decision covers both caches, and the fused
            # verify read sees the same page walk either way
            self.draft_cache = init_cache(cfg, n_slots, self.spec.max_len,
                                          paged=self.spec)
        if self.tp > 1:
            # shard every paged pool along its kv-head dim; page axes stay
            # whole on purpose (the scheduler's page budget must be
            # shard-invariant — asserted below)
            self.cache = jax.tree.map(
                lambda x, s: jax.device_put(x, NamedSharding(self.mesh, s)),
                self.cache, paged_pool_pspecs(self.cache, self.mesh,
                                              axis=TP_AXIS))
            self._assert_tp_placement()
        # host mirrors are int32 end-to-end: every jit consumes int32, so an
        # int64 mirror would silently truncate at the cast boundary — keep
        # the dtypes aligned and the geometry provably in range
        assert self.spec.max_len < np.iinfo(np.int32).max, \
            "per-slot capacity overflows the int32 host/jit length contract"
        self.cur_len = np.zeros(n_slots, np.int32)   # tokens in cache per slot
        self.last_tok = np.zeros(n_slots, np.int32)  # next token to feed
        self.active = np.zeros(n_slots, bool)
        self._prefilling: dict[int, Request] = {}    # slot -> mid-prompt req
        self._key, self._first_key = jax.random.split(jax.random.PRNGKey(seed))
        self._next_rid = 0
        # the virtual clock is engine state (not a run()-local counter) so
        # a snapshot/restore resumes arrival gating mid-trace; run() keeps
        # ticking it from wherever the restore left it
        self.t = 0
        self.n_steps_total = 0       # step() call count — fault step index
        # ------------------------------------------------ fault tolerance
        self.faults = faults         # FaultPlan consumed by _apply_faults
        # snapshot at construction (tests toggle it per-instance); the
        # env is read through the debug_flags funnel, never directly
        self.debug = debug_enabled()
        self.n_kernel_fallbacks = 0  # fused -> gather decode retries
        self.n_spill_corruptions = 0     # corruption faults injected
        self.n_spill_checksum_fails = 0  # ... caught at restore time
        self.n_nonfinite = 0         # slots the isfinite sentinel killed
        self.n_faults_applied = 0    # total injected-fault firings
        self._poison_slots: set[int] = set()  # NaN-inject at next decode
        self._kernel_fault = False   # fail the next fused decode dispatch
        self._spill_corrupt = False  # corrupt the next spill payload
        # pages pinned by pool_exhaust faults: [release_step, [pages]]
        self._fault_holds: list[list] = []
        self.n_decode_steps = 0
        self.n_prefills = 0
        self.n_prefill_tokens = 0    # real prompt tokens actually prefilled
        self.n_shared_tokens = 0     # prompt tokens served from the prefix cache
        # preemption accounting: pages actually moved (kept-by-reference
        # shared pages never count — spill must not duplicate them)
        self.n_spilled_pages = 0     # owned live pages copied to host RAM
        self.n_restored_pages = 0    # pages scattered back on re-admission
        # speculative-decoding acceptance accounting (spec_stats())
        self.n_spec_rounds = 0       # fused draft+verify dispatches
        self.n_draft_tokens = 0      # draft proposals across active slots
        self.n_spec_emitted = 0      # tokens emitted by spec rounds
        self.spec_accept_sum = np.zeros(n_slots, np.int64)   # per-slot n_emit
        self.spec_round_count = np.zeros(n_slots, np.int64)  # per-slot rounds

    # -------------------------------------------------------- TP placement
    _TP_COL = ("attn/wq/w", "attn/wk/w", "attn/wv/w", "attn/wukv/w",
               "mlp/wi/w", "mlp/wg/w")
    _TP_ROW = ("attn/wo/w", "mlp/wo/w")

    def _iter_param_leaves(self):
        def walk(tree, prefix):
            if isinstance(tree, QuantizedTensor):
                yield prefix + "#qw", tree.qw
                yield prefix + "#scale", tree.scale
            elif isinstance(tree, dict):
                for k, v in tree.items():
                    yield from walk(v, f"{prefix}/{k}" if prefix else k)
            else:
                yield prefix, tree

        yield from walk(self.params, "")

    def _iter_cache_leaves(self):
        def walk(tree, key=None):
            if isinstance(tree, dict):
                for k, v in tree.items():
                    yield from walk(v, k)
            else:
                yield key, tree

        yield from walk(self.cache)

    @staticmethod
    def _shard_shape(leaf):
        sh = getattr(leaf, "sharding", None)
        if sh is None:
            return tuple(leaf.shape)
        return tuple(sh.shard_shape(leaf.shape))

    def _tp_exempt_replicated(self, path, leaf) -> bool:
        """The one projection leaf legitimately replicated under TP: a
        per-channel (1, N) scale of a row-parallel weight — every K shard
        needs the whole output-channel row. Shared by the placement assert
        and the report so they can never disagree."""
        base = path.rsplit("#", 1)[0]
        return (path.endswith("#scale") and leaf.shape[-2] == 1
                and any(base.endswith(t) for t in self._TP_ROW))

    def _assert_tp_placement(self) -> None:
        """Verify the placement contract on the live buffers, not on specs:
        every attention/MLP projection leaf — packed qw AND scale included —
        is sharded over the model axis, and every paged pool leaf holds only
        its kv-head slice per shard while the page geometry stays global
        (the scheduler's whole-budget page gating is therefore TP-invariant
        by construction). Raises with an actionable message instead of
        serving silently replicated weights."""
        bad = []
        for path, leaf in self._iter_param_leaves():
            base = path.rsplit("#", 1)[0]
            if not any(base.endswith(t) for t in self._TP_COL + self._TP_ROW):
                continue
            if self._tp_exempt_replicated(path, leaf):
                continue
            if self._shard_shape(leaf) == tuple(leaf.shape):
                bad.append(path)
        if bad:
            raise ValueError(
                f"tp={self.tp}: projection leaves stayed replicated: {bad}. "
                f"For grouped quantization every shard must hold whole scale "
                f"groups — pick a group_size dividing K/tp, or per-channel "
                f"(group_size=-1)")
        for key, leaf in self._iter_cache_leaves():
            if key not in POOL_KEYS:
                continue
            hdim = pool_head_dim(key, leaf.ndim)
            shard = self._shard_shape(leaf)
            assert (shard[:hdim] == tuple(leaf.shape[:hdim])
                    and shard[hdim + 1:] == tuple(leaf.shape[hdim + 1:])), \
                f"{key}: page geometry must be identical on every shard"
            if leaf.shape[hdim] % self.tp == 0:
                assert shard[hdim] * self.tp == leaf.shape[hdim], \
                    f"{key}: kv-head dim left replicated under tp={self.tp}"

    def tp_placement_report(self) -> dict:
        """Per-device placement summary: bytes each device holds for params
        and paged KV pools, plus any quantized/pool leaves left replicated.
        Drives benchmarks/tp_serve_bench.py's modeled per-device HBM and the
        TP test suite's no-replicated-leaves assertion."""
        def nbytes(shape, dtype):
            return int(np.prod(shape)) * np.dtype(dtype).itemsize

        rep = {"tp": self.tp,
               "params": {"global_bytes": 0, "per_device_bytes": 0},
               "kv": {"global_bytes": 0, "per_device_bytes": 0},
               "replicated_quant_leaves": [],
               "replicated_pool_leaves": []}
        for path, leaf in self._iter_param_leaves():
            shard = self._shard_shape(leaf)
            rep["params"]["global_bytes"] += nbytes(leaf.shape, leaf.dtype)
            rep["params"]["per_device_bytes"] += nbytes(shard, leaf.dtype)
            # same classification as _assert_tp_placement: only projection
            # leaves the contract says to shard count as violations (e.g.
            # quantized MLA wdkv is replicated *by design* — per-token
            # latent, no head dim — and must not be reported)
            base = path.rsplit("#", 1)[0]
            is_proj = any(base.endswith(t)
                          for t in self._TP_COL + self._TP_ROW)
            if ("#" in path and is_proj and self.tp > 1
                    and shard == tuple(leaf.shape)
                    and not self._tp_exempt_replicated(path, leaf)):
                rep["replicated_quant_leaves"].append(path)
        for key, leaf in self._iter_cache_leaves():
            rep["kv"]["global_bytes"] += nbytes(leaf.shape, leaf.dtype)
            shard = self._shard_shape(leaf)
            rep["kv"]["per_device_bytes"] += nbytes(shard, leaf.dtype)
            if key in POOL_KEYS:
                hdim = pool_head_dim(key, leaf.ndim)
                if (self.tp > 1 and shard == tuple(leaf.shape)
                        and leaf.shape[hdim] % self.tp == 0):
                    rep["replicated_pool_leaves"].append(key)
        return rep

    # ------------------------------------------------------------- intake
    def submit(self, prompt: np.ndarray, *, max_new: int = 32,
               arrival: float = 0.0, priority: int = 0,
               deadline: Optional[float] = None) -> Request:
        """`priority`: SLO class — 0 interactive (may preempt batch work
        when `preempt=True`), 1 batch (admitted when interactive traffic
        leaves room; aging keeps it starvation-free).
        `deadline`: absolute time past which the answer is worthless — the
        scheduler sheds the request from the queue (never admitted) or the
        engine cancels it mid-run, freeing slot and pages either way."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size + max_new > self.spec.max_len:
            raise ValueError(
                f"request budget {prompt.size + max_new} exceeds per-slot "
                f"capacity {self.spec.max_len}")
        need = self.spec.pages_for(prompt.size + max_new)
        if need > self.spec.n_pages - 1:
            # an under-provisioned pool could otherwise head-of-line block
            # this request forever (admission waits for pages that can
            # never all be free at once)
            raise ValueError(
                f"request needs {need} pages but the pool only has "
                f"{self.spec.n_pages - 1} allocatable pages")
        req = Request(rid=self._next_rid, prompt=prompt, max_new=max_new,
                      arrival=arrival, priority=priority, deadline=deadline)
        self._next_rid += 1
        self.sched.submit(req)
        return req

    # -------------------------------------------------- preemption support
    def _pad_pages(self, pages: list[int]) -> np.ndarray:
        """Pow2-pad a page-id list with scratch-page entries so the spill
        gather/scatter jits compile O(log max_pages) shapes, not one per
        distinct spill size. Scratch writes/reads are dead by construction."""
        n = max(1, len(pages))
        padded = 1 << (n - 1).bit_length()
        from repro.serve.kvcache import SCRATCH_PAGE
        return np.asarray(pages + [SCRATCH_PAGE] * (padded - len(pages)),
                          np.int32)

    def _spill_slot(self, slot: int, req: Request, now: float):
        """Scheduler preempt hook: checkpoint `slot`'s KV and host state so
        the request can resume later exactly where it stopped.

        Pool bookkeeping (which pages spill by copy vs stay resident by
        reference) lives in PagePool.spill; this hook supplies the data
        movement — a jitted whole-tree page gather, synced to numpy so the
        snapshot really lives in host RAM — and clears the engine's slot
        mirrors. Owned live pages only: shared prefix pages never move."""
        n_live = int(self.cur_len[slot])

        def copy_out(pages):
            host = _spill_gather_jit(self.cache, self._pad_pages(pages))
            host = jax.tree.map(np.asarray, host)   # force sync, host RAM
            self.n_spilled_pages += len(pages)
            return host

        req.prefill_done = slot not in self._prefilling
        snap = self.pool.spill(slot, n_live, copy_out)
        if snap.host is not None:
            # checksum BEFORE any injected corruption: restore re-verifies
            # against what the data looked like when it really left device
            snap.checksum = _tree_checksum(snap.host)
            if self._spill_corrupt:
                self._spill_corrupt = False
                snap.host, hit = _corrupt_first_leaf(snap.host)
                if hit:
                    self.n_spill_corruptions += 1
        self._prefilling.pop(slot, None)
        self.active[slot] = False
        self.cur_len[slot] = 0
        self.last_tok[slot] = 0
        return snap

    def _restore_slot(self, slot: int, req: Request, now: float) -> None:
        """Finish a scheduler restore: scatter the spilled KV back into the
        fresh pages the pool picked, rebuild the slot's host mirrors, and
        re-enter the request where it left off — decoding slots resume with
        their last emitted token pending, mid-prefill slots rejoin the
        chunked-prefill set at their old progress (only tokens that were
        never prefilled get prefilled; nothing is recomputed).

        The host payload is checksum-verified first: scattering a corrupted
        snapshot would resume the stream on garbage KV (and a shared page's
        neighbors would read it too), so a mismatch quarantines the request
        instead — pages freed, error recorded, co-batched slots untouched."""
        snap = req.spill
        assert snap is not None and snap.restored is not None
        if (snap.copied and snap.checksum is not None
                and _tree_checksum(snap.host) != snap.checksum):
            self.n_spill_checksum_fails += 1
            req.spill = None
            # the pool already converted the snapshot's kept references
            # into slot references in restore(); quarantine releases them
            # all along with the fresh pages
            self.sched.quarantine(slot, now, "spill_corrupt")
            return
        if snap.copied:
            idx = self._pad_pages(snap.restored)
            self.cache = _spill_scatter_jit(self.cache, jnp.asarray(idx),
                                            snap.host)
            self.n_restored_pages += len(snap.copied)
        req.spill = None
        self.cur_len[slot] = snap.n_live
        if req.prefill_done:
            self.last_tok[slot] = req.tokens[-1]
            self.active[slot] = True
        else:
            self._prefilling[slot] = req

    # -------------------------------------------- engine snapshot / restore
    _SNAP_COUNTERS = ("n_decode_steps", "n_prefills", "n_prefill_tokens",
                      "n_shared_tokens", "n_spilled_pages",
                      "n_restored_pages", "n_spec_rounds", "n_draft_tokens",
                      "n_spec_emitted", "n_kernel_fallbacks",
                      "n_spill_corruptions", "n_spill_checksum_fails",
                      "n_nonfinite", "n_faults_applied")

    def _fingerprint(self) -> dict:
        """Identity of the serving configuration a snapshot belongs to.
        Restore refuses a snapshot from a different config/geometry — the
        cache tree shapes, RNG stream, and scheduler semantics would all
        silently diverge. `paged_attn_impl` is excluded on purpose: the
        fused and gather paths are bitwise-identical, and a kernel-fault
        fallback mid-trace must not orphan earlier snapshots (the live
        impl is carried in the snapshot body instead)."""
        return {
            "cfg": repr(self.cfg.replace(paged_attn_impl="fused")),
            "n_slots": self.n_slots,
            "spec": (self.spec.n_pages, self.spec.page_size,
                     self.spec.max_pages),
            "eos_id": self.eos_id,
            "prefill_bucket": self.prefill_bucket,
            "prefill_batch": self.prefill_batch,
            "decode_block": self.decode_block,
            "temperature": self.temperature, "top_k": self.top_k,
            "prefix_share": self.prefix_share,
            "chunk_tokens": self.chunk_tokens,
            "tp": self.tp,
            "spec_decode": self.spec_decode,
            "draft_bits": self.draft_bits, "spec_k": self.spec_k,
            "preempt": self.preempt,
            "age_promote": self.sched.age_promote,
        }

    def snapshot(self) -> dict:
        """Capture the full serving state as a plain nested dict of host
        values: every cache pool leaf, the allocator (free-list order
        included — allocation determinism), the scheduler (requests, queue
        order, event log, counters), the slot host mirrors, the RNG keys,
        the virtual clock, and the in-flight fault one-shots. The result
        is self-contained (no live object references), serializable via
        ``checkpoint.store.save_snapshot``, and consumable by ``restore``
        on a freshly built identical engine — which then resumes the trace
        with bit-identical greedy tokens. The FaultPlan itself is *not*
        captured: the crash driver owns it (see serve/faults.py)."""
        snap = {
            "fingerprint": self._fingerprint(),
            "t": self.t,
            "n_steps_total": self.n_steps_total,
            "next_rid": self._next_rid,
            "paged_attn_impl": self.cfg.paged_attn_impl,
            "rng": {"key": np.asarray(self._key),
                    "first_key": np.asarray(self._first_key)},
            "mirrors": {"cur_len": self.cur_len.copy(),
                        "last_tok": self.last_tok.copy(),
                        "active": self.active.copy()},
            "prefilling": {int(s): r.rid
                           for s, r in self._prefilling.items()},
            # np.asarray forces the device sync leaf-by-leaf: after this,
            # the snapshot is consistent even if the process dies mid-write
            "cache": jax.tree.map(np.asarray, self.cache),
            "pool": self.pool.state_dict(),
            "sched": self.sched.state_dict(),
            "counters": {k: getattr(self, k) for k in self._SNAP_COUNTERS},
            "spec_accept_sum": self.spec_accept_sum.copy(),
            "spec_round_count": self.spec_round_count.copy(),
            "fault_state": {
                "poison_slots": sorted(self._poison_slots),
                "kernel_fault": self._kernel_fault,
                "spill_corrupt": self._spill_corrupt,
                "fault_holds": [[int(s), [int(p) for p in pages]]
                                for s, pages in self._fault_holds],
            },
        }
        if self.spec_decode:
            snap["draft_cache"] = jax.tree.map(np.asarray, self.draft_cache)
        return snap

    def restore(self, snap: dict) -> None:
        """Load a ``snapshot()`` into this engine (built with the same
        config/geometry — validated against the fingerprint) and resume:
        the next ``step()``/``run()`` continues the interrupted trace with
        bit-identical greedy tokens. The scheduler's requests are rebuilt
        by value and re-linked into every membership structure by rid, so
        object identity (slot <-> prefilling <-> queue) holds again."""
        fp, got = self._fingerprint(), dict(snap["fingerprint"])
        if got != fp:
            bad = sorted(k for k in set(fp) | set(got)
                         if fp.get(k) != got.get(k))
            raise ValueError(f"snapshot fingerprint mismatch on {bad}: "
                             f"snapshot from a different engine config")
        impl = str(snap["paged_attn_impl"])
        if impl != self.cfg.paged_attn_impl:
            self.cfg = self.cfg.replace(paged_attn_impl=impl)
        self.t = int(snap["t"])
        self.n_steps_total = int(snap["n_steps_total"])
        self._next_rid = int(snap["next_rid"])
        self._key = jnp.asarray(np.asarray(snap["rng"]["key"]))
        self._first_key = jnp.asarray(np.asarray(snap["rng"]["first_key"]))
        self.cur_len = np.asarray(snap["mirrors"]["cur_len"],
                                  np.int32).copy()
        self.last_tok = np.asarray(snap["mirrors"]["last_tok"],
                                   np.int32).copy()
        self.active = np.asarray(snap["mirrors"]["active"], bool).copy()
        cache = jax.tree.map(jnp.asarray, snap["cache"])
        if self.tp > 1:
            cache = jax.tree.map(
                lambda x, s: jax.device_put(x, NamedSharding(self.mesh, s)),
                cache, paged_pool_pspecs(cache, self.mesh, axis=TP_AXIS))
        self.cache = cache
        if self.spec_decode:
            self.draft_cache = jax.tree.map(jnp.asarray,
                                            snap["draft_cache"])
        self.pool.load_state_dict(snap["pool"])
        by_rid = self.sched.load_state_dict(snap["sched"])
        self._prefilling = {int(s): by_rid[int(r)]
                            for s, r in snap["prefilling"].items()}
        for k in self._SNAP_COUNTERS:
            setattr(self, k, int(snap["counters"][k]))
        self.spec_accept_sum = np.asarray(snap["spec_accept_sum"],
                                          np.int64).copy()
        self.spec_round_count = np.asarray(snap["spec_round_count"],
                                           np.int64).copy()
        fs = snap["fault_state"]
        self._poison_slots = {int(s) for s in fs["poison_slots"]}
        self._kernel_fault = bool(fs["kernel_fault"])
        self._spill_corrupt = bool(fs["spill_corrupt"])
        self._fault_holds = [[int(s), [int(p) for p in pages]]
                             for s, pages in fs["fault_holds"]]
        if self.debug:
            self._debug_check()

    def fault_stats(self) -> dict:
        """Fault-tolerance accounting: injections applied, sentinel and
        checksum catches, kernel fallbacks, and the scheduler's
        deadline/quarantine counters — everything the chaos suite and the
        launcher report assert on."""
        return {
            "n_steps": self.n_steps_total,
            "n_faults_applied": self.n_faults_applied,
            "n_nonfinite": self.n_nonfinite,
            "n_kernel_fallbacks": self.n_kernel_fallbacks,
            "n_spill_corruptions": self.n_spill_corruptions,
            "n_spill_checksum_fails": self.n_spill_checksum_fails,
            "n_quarantined": self.sched.n_quarantined,
            "n_shed": self.sched.n_shed,
            "n_cancelled": self.sched.n_cancelled,
            "held_pages": sum(len(h[1]) for h in self._fault_holds),
            "paged_attn_impl": self.cfg.paged_attn_impl,
        }

    def _debug_check(self) -> None:
        """REPRO_DEBUG=1 per-step validation: pool invariants plus
        slot-mirror/scheduler-state agreement, so chaos and fuzz runs fail
        at the step corruption happens instead of at drain. Cheap (host
        arithmetic only, no device sync) but O(slots + pages) per step —
        opt-in via the env var, not default-on."""
        self.pool.check_invariants()
        for slot in range(self.n_slots):
            req = self.sched.slots[slot]
            if req is None:
                assert not self.active[slot], \
                    f"slot {slot}: active with no request"
                assert slot not in self._prefilling, \
                    f"slot {slot}: prefilling with no request"
                assert np.all(self.pool.tables[slot] == -1), \
                    f"slot {slot}: pages mapped with no request"
                continue
            assert req.slot == slot, \
                f"slot {slot}: request {req.rid} thinks it is in {req.slot}"
            mapped = int(np.sum(self.pool.tables[slot] >= 0))
            if slot in self._prefilling:
                assert not self.active[slot], \
                    f"slot {slot}: both prefilling and decoding"
                assert int(self.cur_len[slot]) <= req.n_prompt, \
                    f"slot {slot}: prefill fill beyond the prompt"
            else:
                assert self.active[slot], \
                    f"slot {slot}: occupied but neither prefilling nor " \
                    f"decoding"
                assert (int(self.cur_len[slot])
                        == req.n_prompt + len(req.tokens) - 1), \
                    f"slot {slot}: fill count disagrees with the token " \
                    f"stream ({int(self.cur_len[slot])} vs " \
                    f"{req.n_prompt}+{len(req.tokens)}-1)"
            assert mapped >= self.spec.pages_for(int(self.cur_len[slot])), \
                f"slot {slot}: fill {int(self.cur_len[slot])} exceeds its " \
                f"{mapped} mapped pages"

    # ------------------------------------------------- fault-plan plumbing
    def _apply_faults(self, step_idx: int, now: float) -> None:
        """Fire every fault scheduled for this step (see serve/faults.py).
        Holds from expired pool_exhaust faults release first so a fault
        plan can never permanently shrink the pool."""
        due = [h for h in self._fault_holds if h[0] <= step_idx]
        for h in due:
            self.pool.release_hold(h[1])
            self._fault_holds.remove(h)
        if self.faults is None:
            return
        for f in self.faults.at(step_idx):
            self.n_faults_applied += 1
            if f.kind == "step_exception":
                raise FaultInjected(f)
            elif f.kind == "nan_logits":
                self._poison_slots.add(max(0, f.slot) % self.n_slots)
            elif f.kind == "pool_exhaust":
                pages = self.pool.hold(f.pages)
                if pages:
                    self._fault_holds.append(
                        [step_idx + max(1, f.duration), pages])
            elif f.kind == "latency_spike":
                # virtual time jumps; run() passes `now` from self.t, so
                # the spike ages queues/deadlines from the next tick on
                self.t += max(1, f.duration)
            elif f.kind == "kernel_fault":
                self._kernel_fault = True
            elif f.kind == "spill_corrupt":
                self._spill_corrupt = True

    def _enforce_deadlines(self, now: float) -> bool:
        """Cancel running/prefilling requests whose deadline has passed
        (queued ones are shed inside scheduler.admit). Clearing the slot
        mirrors here is what _spill_slot does on eviction — the slot is
        immediately reusable."""
        did = False
        for slot, req in enumerate(self.sched.slots):
            if (req is not None and req.deadline is not None
                    and now > req.deadline):
                self._prefilling.pop(slot, None)
                self.active[slot] = False
                self.cur_len[slot] = 0
                self.last_tok[slot] = 0
                self.sched.cancel(slot, now)
                did = True
        return did

    def _quarantine(self, slot: int, req: Request, reason: str,
                    now: float) -> None:
        """Retire a slot the sentinel flagged: clear the engine mirrors and
        let the scheduler free its pages + record the error status. The
        other slots' state is untouched — their tokens this block came out
        of the same scan, already shielded by the in-scan deactivation."""
        self.n_nonfinite += reason == "nonfinite_logits"
        self._prefilling.pop(slot, None)
        self.active[slot] = False
        self.cur_len[slot] = 0
        self.last_tok[slot] = 0
        self.sched.quarantine(slot, now, reason)

    # ------------------------------------------------------------ serving
    def step(self, now: float = 0.0) -> bool:
        """One scheduler tick: fire scheduled faults and shed/cancel
        expired deadlines, admit new requests, advance every mid-prefill
        slot by one chunk (batched by chunk bucket), then run one fused
        block of decode steps over all decoding slots. Returns False when
        there was nothing to do."""
        step_idx = self.n_steps_total
        self.n_steps_total += 1
        self._apply_faults(step_idx, now)    # may raise FaultInjected
        did = self._enforce_deadlines(now)
        for slot, req in self.sched.admit(now):
            if req.spill is not None:
                # re-admission of a preempted request: scatter its spilled
                # KV back and resume (decode or mid-prompt prefill) — no
                # token is ever re-prefilled, the stream picks up exactly
                # where the eviction cut it
                did = True
                self._restore_slot(slot, req, now)
                continue
            # a prefix hit starts the prefill past the shared pages — the
            # cache already holds positions 0..n_shared-1 for this prompt
            self.cur_len[slot] = req.n_shared
            self.n_shared_tokens += req.n_shared
            self._prefilling[slot] = req
        if self._prefilling:
            did = True
            self._prefill_tick(now)
        act = np.nonzero(self.active)[0]
        if act.size:
            did = True
            if self.spec_decode:
                self._spec_block(self.active.copy(), now)
            else:
                toks, alive = self._decode_block()            # (K, n_slots)
                for t in range(toks.shape[0]):
                    for slot in act:
                        req = self.sched.slots[slot]
                        if req is None:                       # retired
                            continue
                        if not alive[t, slot]:
                            # sentinel fired: the token at (and after) this
                            # step is garbage; the scan already froze the
                            # slot, so only this retire remains
                            self._quarantine(slot, req,
                                             "nonfinite_logits", now)
                            continue
                        self._emit(slot, req, int(toks[t, slot]), now)
        if self.debug:
            self._debug_check()
        return did

    def run(self, *, clock=None, max_steps: Optional[int] = None):
        """Drain every submitted request; returns the requests that finished
        during this call, in submit order.

        `clock`: callable giving the current time for arrival gating and
        latency stamps (wall-clock driver); default is a virtual step
        counter, so `arrival` is then measured in scheduler steps. The
        virtual clock is the persistent ``self.t`` — a restored engine
        resumes mid-trace with arrival gating intact, and back-to-back
        run() calls keep monotonic time (latency_spike faults advance it
        too; reset ``engine.t = 0`` to re-zero between measured runs).
        """
        import time as _time

        steps = 0
        while not self.sched.all_done():
            if max_steps is not None and steps >= max_steps:
                raise RuntimeError(f"serve loop exceeded {max_steps} steps")
            now = clock() if clock is not None else float(self.t)
            did = self.step(now)
            if did or clock is None:
                steps += 1
                # virtual time must tick even when idle (arrival gating),
                # but under a wall clock an idle spin would burn CPU and
                # exhaust max_steps between sparse arrivals — sleep instead
                self.t += 1
            else:
                _time.sleep(1e-3)
        return sorted(self.sched.drain_finished(), key=lambda r: r.rid)

    # ----------------------------------------------------------- internals
    def _bucket(self, n: int) -> int:
        b = self.prefill_bucket
        return -(-n // b) * b

    def _read_width(self, n_tokens: int) -> int:
        """Pow2 page count covering n_tokens, capped at the table width —
        the read-width bucketing shared by decode and chunked prefill."""
        need = self.spec.pages_for(n_tokens)
        width = 1
        while width < need:
            width *= 2
        return min(width, self.spec.max_pages)

    def _prefill_tick(self, now: float) -> None:
        """Advance every mid-prefill slot by one (page-aligned) chunk.

        Chunks are batched per (length bucket, has-context) pair: rows
        whose chunk starts at position 0 keep the original self-attending
        prefill read path (bit-identical to the monolithic engine), while
        suffix/later chunks need the gathered-context path because their
        earlier tokens live in pages — their own prior chunks, or shared
        prefix pages written by another request."""
        work = []
        for slot in sorted(self._prefilling):
            req = self._prefilling[slot]
            start = int(self.cur_len[slot])
            end = req.n_prompt
            if self.chunk_tokens and end - start > self.chunk_tokens:
                end = start + self.chunk_tokens
            work.append((slot, req, start, end))
        groups: dict[tuple[int, bool], list] = {}
        for item in work:
            slot, req, start, end = item
            groups.setdefault((self._bucket(end - start), start > 0),
                              []).append(item)
        for (padded, has_ctx), items in sorted(groups.items()):
            i = 0
            while i < len(items):
                # pow2 chunk sizes bound the number of compiled shapes
                size = min(1 << ((len(items) - i).bit_length() - 1),
                           self.prefill_batch)
                self._prefill_chunk(items[i:i + size], padded, has_ctx, now)
                i += size

    def _prefill_chunk(self, items: Sequence[tuple], padded: int,
                       has_ctx: bool, now: float) -> None:
        """Prefill one same-bucket batch of (slot, req, start, end) chunks;
        rows that complete their prompt sample a first token and switch
        the slot to decoding."""
        batch = len(items)
        toks = np.zeros((batch, padded), np.int32)
        pos = np.full((batch, padded), -1, np.int32)
        for row, (slot, req, start, end) in enumerate(items):
            n = end - start
            toks[row, padded - n:] = req.prompt[start:end]
            pos[row, padded - n:] = np.arange(start, end, dtype=np.int32)
        slots = np.asarray([slot for slot, _, _, _ in items], np.int32)
        if has_ctx:
            # pow2-bucketed read width over the deepest chunk end, so the
            # gathered context scales with fill, not provisioned max_len
            kv_end = np.asarray([end for _, _, _, end in items], np.int32)
            width = self._read_width(int(kv_end.max()))
            paged = {"bt_rows": jnp.asarray(np.ascontiguousarray(
                         self.pool.tables[slots][:, :width])),
                     "slots": jnp.asarray(slots),
                     "kv_len": jnp.asarray(kv_end)}
        else:
            paged = {"bt_rows": jnp.asarray(self.pool.tables[slots]),
                     "slots": jnp.asarray(slots)}
        if self.tp > 1:
            logits, self.cache = _paged_prefill_tp_jit(
                self.cfg, self.mesh, self.params, jnp.asarray(toks),
                self.cache, jnp.asarray(pos), paged)
        else:
            logits, self.cache = _paged_prefill_jit(
                self.cfg, self.params, jnp.asarray(toks), self.cache,
                jnp.asarray(pos), paged)
        if self.spec_decode:
            # mirror the chunk into the draft cache so draft decode starts
            # from the same fill state (its logits are discarded — the
            # first token is always the target's)
            _, self.draft_cache = _paged_prefill_jit(
                self.cfg, self.draft_params, jnp.asarray(toks),
                self.draft_cache, jnp.asarray(pos), paged)
        self.n_prefills += 1
        self.n_prefill_tokens += sum(end - start for _, _, start, end in items)
        finish = []
        for row, (slot, req, start, end) in enumerate(items):
            self.cur_len[slot] = end
            if end >= req.n_prompt:
                finish.append(row)
        if not finish:
            return
        keys = jnp.stack([jax.random.fold_in(self._first_key, items[row][1].rid)
                          for row in finish])
        first, fin_ok = _sample_first_jit(
            logits[jnp.asarray(finish)], keys,
            temperature=self.temperature, top_k=self.top_k)
        first, fin_ok = np.asarray(first), np.asarray(fin_ok)
        for tok, okf, row in zip(first, fin_ok, finish):
            slot, req, _, _ = items[row]
            del self._prefilling[slot]
            if not okf:
                # non-finite prefill logits: quarantine before the slot
                # ever joins the decode set (and never publish its pages
                # into the prefix index)
                self._quarantine(slot, req, "nonfinite_logits", now)
                continue
            self.active[slot] = True
            if self.prefix_share:
                # publish this prompt's full pages before _emit can retire
                # the slot (an immediate EOS/max_new=1 would unmap it)
                self.pool.register_prefix(req.prompt, slot)
            self._emit(slot, req, int(tok), now)

    def _decode_block(self) -> tuple[np.ndarray, np.ndarray]:
        """One fused block of decode steps; returns ((K, n_slots) tokens,
        (K, n_slots) alive) — alive[t, s] False marks s's tokens from step
        t on as garbage (non-finite logits; the caller quarantines).

        K adapts to the smallest remaining budget among active requests
        (pow2-capped at decode_block) so slots retire exactly at a block
        boundary instead of idling through overshoot steps."""
        act = self.active.copy()
        self._key, sk = jax.random.split(self._key)
        # min over *decoding* slots only: a mid-prefill request has its
        # whole max_new outstanding and must not shrink everyone's block
        remaining = min(req.max_new - len(req.tokens)
                        for slot, req in enumerate(self.sched.slots)
                        if req is not None and act[slot])
        k_steps = min(self.decode_block,
                      1 << (max(remaining, 1).bit_length() - 1))
        # bucket the attention read width (pow2 pages over the deepest slot
        # at block end) so shallow traffic doesn't pay max_len-wide gathers
        width = self._read_width(int(self.cur_len[act].max()) + k_steps)
        # host mirrors feed the jit directly — int32 end-to-end, no cast
        # boundary where an int64 length could silently truncate
        assert (self.cur_len.dtype == np.int32
                and self.last_tok.dtype == np.int32), \
            "engine host state drifted off the int32 jit contract"
        poison = np.zeros(self.n_slots, bool)
        if self._poison_slots:
            for s in self._poison_slots:
                poison[s] = True
            self._poison_slots.clear()
        kw = dict(k_steps=k_steps, page_size=self.spec.page_size,
                  temperature=self.temperature, top_k=self.top_k)

        def dispatch():
            # .copy(): the transfer of a host buffer may be deferred past
            # this call's (async) dispatch, and the engine mutates these
            # mirrors right after — handing jax the live array is a data
            # race (the old .astype(int32) made an incidental copy; keep
            # an explicit one). Rebuilt per attempt: donated buffers must
            # not be reused by the fallback retry.
            args = (self.params, self.cache,
                    jnp.asarray(self.last_tok.copy()),
                    jnp.asarray(self.cur_len.copy()), jnp.asarray(act),
                    jnp.asarray(self.pool.tables[:, :width].copy()), sk,
                    jnp.asarray(poison))
            if self._kernel_fault:
                # simulates the *fused* kernel failing to dispatch; once
                # the engine has already degraded to the gather oracle
                # there is no fused path left to fail, so the injection
                # is consumed as a no-op
                self._kernel_fault = False
                if self.cfg.paged_attn_impl != "gather":
                    raise RuntimeError("injected kernel dispatch failure")
            if self.tp > 1:
                return _paged_decode_scan_tp_jit(
                    self.cfg, self.mesh, *args, **kw)
            return _paged_decode_scan_jit(self.cfg, *args, **kw)

        try:
            toks, alive, self.cache = dispatch()
        except FaultInjected:
            raise
        except Exception:
            # kernel-dispatch failure (trace/lowering raises before the
            # donated cache is consumed — execution-time donation makes
            # the retry safe): permanently fall back to the gather oracle
            # paged-attention path and retry once. Correctness is
            # bitwise-identical (gather is the fused kernel's oracle);
            # only bandwidth is lost, and the counter makes it visible.
            if self.cfg.paged_attn_impl == "gather":
                raise
            self.cfg = self.cfg.replace(paged_attn_impl="gather")
            self.n_kernel_fallbacks += 1
            toks, alive, self.cache = dispatch()
        self.cur_len[act] += k_steps
        self.n_decode_steps += k_steps
        return np.asarray(toks), np.asarray(alive)

    def _spec_block(self, act: np.ndarray, now: float) -> None:
        """One speculative round over all decoding slots.

        The draft proposes up to `spec_k` tokens, the target scores them in
        a single (S, k+1)-row verify forward, and each slot emits its
        accepted prefix plus the target's own token for the first divergent
        row (so even a useless draft makes one token of progress — k_eff=0
        degenerates to a single-row verify, i.e. plain decode). k adapts to
        the smallest remaining budget among active slots (pow2-bucketed
        like _decode_block to bound the compiled-shape count)."""
        self._key, sk = jax.random.split(self._key)
        remaining = min(req.max_new - len(req.tokens)
                        for slot, req in enumerate(self.sched.slots)
                        if req is not None and act[slot])
        k_eff = min(self.spec_k, max(remaining - 1, 0))
        if k_eff:
            k_eff = 1 << (k_eff.bit_length() - 1)
        m = k_eff + 1
        width = self._read_width(int(self.cur_len[act].max()) + m)
        assert (self.cur_len.dtype == np.int32
                and self.last_tok.dtype == np.int32), \
            "engine host state drifted off the int32 jit contract"
        out, n_emit, self.cache, self.draft_cache = _spec_block_jit(
            self.cfg, self.params, self.draft_params, self.cache,
            self.draft_cache, jnp.asarray(self.last_tok.copy()),
            jnp.asarray(self.cur_len.copy()), jnp.asarray(act),
            jnp.asarray(self.pool.tables[:, :width].copy()), sk,
            k_steps=k_eff, page_size=self.spec.page_size,
            temperature=self.temperature, top_k=self.top_k)
        out = np.asarray(out)
        n_emit = np.asarray(n_emit)
        act_idx = np.nonzero(act)[0]
        self.n_spec_rounds += 1
        self.n_decode_steps += 1         # one target forward per round
        self.n_draft_tokens += k_eff * act_idx.size
        for slot in act_idx:
            n = int(n_emit[slot])
            self.spec_accept_sum[slot] += n
            self.spec_round_count[slot] += 1
            self.n_spec_emitted += n
            # the cache holds positions 0..cur_len+n-1 = the old pending
            # token plus the accepted drafts; the final emitted token stays
            # unwritten (it is next round's last_tok), and the rejected
            # tail beyond the new fill is dead by masking
            self.cur_len[slot] += n
            for t in range(n):
                req = self.sched.slots[slot]
                if req is None:          # retired mid-round (EOS/max_new)
                    break
                self._emit(slot, req, int(out[slot, t]), now)

    def spec_stats(self) -> dict:
        """Acceptance accounting for speculative decoding: overall rate,
        mean accepted length per slot-round, and the per-slot means."""
        slot_rounds = int(self.spec_round_count.sum())
        accepted = int(self.n_spec_emitted) - slot_rounds
        per_slot = np.where(
            self.spec_round_count > 0,
            self.spec_accept_sum / np.maximum(self.spec_round_count, 1), 0.0)
        return {
            "rounds": int(self.n_spec_rounds),
            "slot_rounds": slot_rounds,
            "draft_tokens": int(self.n_draft_tokens),
            "emitted_tokens": int(self.n_spec_emitted),
            "accepted_draft_tokens": accepted,
            "acceptance_rate": (accepted / self.n_draft_tokens
                                if self.n_draft_tokens else 0.0),
            "mean_accepted_len": (self.n_spec_emitted / slot_rounds
                                  if slot_rounds else 0.0),
            "per_slot_mean_accepted_len": [round(float(x), 4)
                                           for x in per_slot],
        }

    def _emit(self, slot: int, req: Request, tok: int, now: float) -> None:
        if req.first_token_at is None:
            req.first_token_at = now
        req.tokens.append(tok)
        self.last_tok[slot] = tok
        if len(req.tokens) >= req.max_new or tok == self.eos_id:
            self.active[slot] = False
            self.sched.retire(slot, now)
