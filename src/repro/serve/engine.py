"""Serving engines for (quantized) LMs.

Weights may be float or packed QuantizedTensor (the paper's deployment
format — dequant happens inside the fused Pallas matmul on TPU; both
engines accept `quant_bits=...` to pack a float tree in place via
`quantize_params_for_serving`). Decode steps present M = n_slots (or
batch) token rows per linear, which rides the decode-shaped skinny-M
kernel tiles picked by kernels/ops.py; quantized MoE experts run the
expert-batched kernel without materializing float expert stacks. Two
engines share the model code:

  * ServeEngine        — static batch: one prompt length, lockstep decode to
                         max_new. Kept as the baseline and for scoring.
  * ContinuousEngine   — continuous batching over a fixed slot pool with a
                         paged KV cache (serve/kvcache.py): requests are
                         admitted into free slots as others retire, each
                         slot decodes at its own depth, and finished
                         requests stop burning decode FLOPs. All jitted
                         shapes are static (slot count, page pool, bucketed
                         prefill lengths), so steady-state serving never
                         recompiles. Decode attention runs the fused
                         paged-attention kernel by default (block-table walk
                         + inline int8-KV dequant inside the kernel); pass
                         paged_attn="gather" for the gather->dequant->einsum
                         oracle path (see DESIGN.md "Paged-attention decode
                         kernel").

The traffic driver (Poisson arrivals, latency percentiles) lives in
launch/serve.py; admission policy lives in serve/scheduler.py.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import (init_cache, lm_decode, lm_forward,
                                      lm_prefill)
from repro.serve.kvcache import PagePool, PageSpec, default_page_spec
from repro.serve.sampling import sample, sample_np
from repro.serve.scheduler import Request, Scheduler


@dataclasses.dataclass
class GenerateResult:
    tokens: np.ndarray          # (B, max_new)
    n_prompt: int
    steps: int


@functools.partial(jax.jit, static_argnames=("cfg", "max_new", "temperature",
                                             "top_k", "eos_id"))
def _generate_jit(cfg, params, prompts, key, max_new, temperature, top_k,
                  eos_id):
    b, s = prompts.shape
    cache = init_cache(cfg, b, s + max_new)
    logits, cache = lm_prefill(cfg, params, prompts, cache)

    def step(carry, t):
        cache, logits, key, done = carry
        key, sk = jax.random.split(key)
        tok = sample(logits, sk, temperature=temperature, top_k=top_k)
        tok = jnp.where(done, eos_id, tok)
        done = done | (tok == eos_id) if eos_id >= 0 else done
        pos = jnp.full((b, 1), s + t, jnp.int32)
        logits, cache = lm_decode(cfg, params, tok[:, None], cache, pos)
        return (cache, logits, key, done), tok

    (_, _, _, _), toks = jax.lax.scan(
        step, (cache, logits, key, jnp.zeros((b,), bool)),
        jnp.arange(max_new, dtype=jnp.int32))
    return toks.T                                              # (B, max_new)


def _maybe_quantize(cfg, params, quant_bits, quant_group, act_bits):
    """Pack a float param tree for serving when quant_bits is set (no-op on
    already-packed trees: QuantizedTensor leaves are left untouched).
    quant_group follows the deploy convention: 0 = cfg.serve_quant_group,
    -1 = per-channel."""
    if not quant_bits:
        if act_bits:
            raise ValueError("act_bits requires quant_bits (A8 tags live on "
                             "packed QuantizedTensors)")
        return params
    from repro.core.quant.deploy import quantize_params_for_serving
    from repro.core.quant.types import QuantizedTensor

    leaves = jax.tree_util.tree_leaves(
        params, is_leaf=lambda x: isinstance(x, QuantizedTensor))
    if any(isinstance(x, QuantizedTensor) for x in leaves):
        raise ValueError("params already hold packed QuantizedTensors; "
                         "pass quant_bits=0 (re-packing is a silent no-op "
                         "and would drop the requested act_bits/group)")
    return quantize_params_for_serving(cfg, params, bits=quant_bits,
                                       group_size=quant_group,
                                       act_bits=act_bits)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, eos_id: int = -1,
                 quant_bits: int = 0, quant_group: int = 0,
                 act_bits: int = 0):
        self.cfg = cfg
        self.params = _maybe_quantize(cfg, params, quant_bits, quant_group,
                                      act_bits)
        self.eos_id = eos_id

    def generate(self, prompts: np.ndarray, *, max_new: int = 32,
                 temperature: float = 0.0, top_k: int = 0,
                 key: Optional[jax.Array] = None) -> GenerateResult:
        key = key if key is not None else jax.random.PRNGKey(0)
        toks = _generate_jit(self.cfg, self.params,
                             jnp.asarray(prompts, jnp.int32), key, max_new,
                             temperature, top_k, self.eos_id)
        return GenerateResult(np.asarray(toks), prompts.shape[1], max_new)

    def score(self, tokens: np.ndarray) -> np.ndarray:
        """Per-token log-likelihoods (B, S-1)."""
        toks = jnp.asarray(tokens, jnp.int32)
        logits, _ = lm_forward(self.cfg, self.params, toks[:, :-1])
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, toks[:, 1:][..., None],
                                 axis=-1)[..., 0]
        return np.asarray(ll - lse)


# ------------------------------------------------------- continuous batching

@functools.partial(jax.jit, static_argnames=("cfg",),
                   donate_argnames=("cache",))
def _paged_prefill_jit(cfg, params, tokens, cache, positions, paged):
    return lm_prefill(cfg, params, tokens, cache, positions=positions,
                      paged=paged)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "k_steps", "page_size",
                                    "temperature", "top_k"),
                   donate_argnames=("cache",))
def _paged_decode_scan_jit(cfg, params, cache, last_tok, cur_len, active,
                           block_table, key, *, k_steps, page_size,
                           temperature, top_k):
    """K fused decode steps over all slots with on-device sampling.

    One dispatch and one host sync per K tokens — the per-step Python/
    transfer overhead of a step-at-a-time loop would otherwise rival the
    model compute. Slots whose request finishes mid-block keep stepping;
    their extra writes fall off the block table onto the scratch page and
    the host drops the surplus tokens. Returns ((K, S) tokens, cache).
    """
    n_slots, max_pages = block_table.shape
    sl = jnp.arange(n_slots)

    def body(carry, _):
        cache, tok, clen, key = carry
        key, sk = jax.random.split(key)
        page_idx = jnp.clip(clen // page_size, 0, max_pages - 1)
        paged = {
            "block_table": block_table,
            "write_page": jnp.where(
                active, jnp.maximum(block_table[sl, page_idx], 0), 0),
            "write_off": jnp.where(active, clen % page_size, 0),
            "kv_len": jnp.where(active, clen + 1, 0),
        }
        pos = jnp.where(active, clen, 0)[:, None]
        logits, cache = lm_decode(cfg, params, tok[:, None], cache, pos,
                                  paged=paged)
        nxt = sample(logits, sk, temperature=temperature, top_k=top_k)
        tok = jnp.where(active, nxt, tok)
        clen = clen + active.astype(clen.dtype)
        return (cache, tok, clen, key), nxt

    (cache, _, _, _), toks = jax.lax.scan(
        body, (cache, last_tok, cur_len, key), None, length=k_steps)
    return toks, cache


class ContinuousEngine:
    """Slot-stepping execution core for continuous batching.

    Holds the paged cache, the per-slot host state (fill depth, last token),
    and the jitted prefill/decode steps. Admission policy and request
    bookkeeping are delegated to serve/scheduler.py. One `step()`:

      1. retire-then-admit: the scheduler maps queued requests onto free
         slots (whole-budget page allocation, FIFO);
      2. newly admitted requests are prefilled into their slots — jitted
         calls batched per prompt-length bucket (pow2 batch sizes, capped
         at `prefill_batch`) that scatter K/V into the admitted slots'
         pages while every other slot's cache state is untouched;
      3. one fused block of `decode_block` lockstep decode steps over all
         slots (a device-side lax.scan with on-device sampling — one
         dispatch and one host sync per K tokens). Idle slots write to the
         scratch page and are masked; slots finishing mid-block overshoot
         onto the scratch page and the surplus tokens are dropped.

    `prefill_bucket` trades compile count for pad waste: prompts are
    left-padded (pos = -1, masked everywhere) up to the next multiple.
    Bucket 1 reproduces the static engine's unpadded prefill bit-for-bit.
    `decode_block` trades admission latency (new arrivals wait for the
    current block) against per-token dispatch overhead.
    """

    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 8,
                 max_len: int = 512, page_size: int = 16,
                 n_pages: Optional[int] = None, eos_id: int = -1,
                 prefill_bucket: int = 16, prefill_batch: int = 8,
                 decode_block: int = 8,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 quant_bits: int = 0, quant_group: int = 0,
                 act_bits: int = 0, paged_attn: Optional[str] = None):
        if cfg.enc_dec:
            raise NotImplementedError("paged serving covers decoder-only LMs")
        if paged_attn is not None:
            # per-engine override of the decode attention path: "fused"
            # (paged-attention kernel) or "gather" (oracle). Threaded via
            # the config because the dispatch lives in models/attention.py.
            if paged_attn not in ("fused", "gather"):
                raise ValueError(f"paged_attn must be 'fused' or 'gather', "
                                 f"got {paged_attn!r}")
            cfg = cfg.replace(paged_attn_impl=paged_attn)
        self.cfg = cfg
        self.params = _maybe_quantize(cfg, params, quant_bits, quant_group,
                                      act_bits)
        self.n_slots = n_slots
        self.eos_id = eos_id
        self.prefill_bucket = max(1, prefill_bucket)
        # prefill_batch=1 avoids co-batched prefills entirely: capacity-MoE
        # routing is cross-token, so co-batched requests can perturb each
        # other's expert assignment when capacity binds (see DESIGN.md)
        self.prefill_batch = max(1, prefill_batch)
        self.decode_block = max(1, decode_block)
        self.temperature = temperature
        self.top_k = top_k
        if n_pages is None:
            self.spec = default_page_spec(n_slots, max_len, page_size)
        else:
            self.spec = PageSpec(n_pages=n_pages, page_size=page_size,
                                 max_pages=-(-max_len // page_size))
        self.pool = PagePool(self.spec, n_slots)
        self.sched = Scheduler(n_slots, self.pool)
        self.cache = init_cache(cfg, n_slots, self.spec.max_len,
                                paged=self.spec)
        self.cur_len = np.zeros(n_slots, np.int64)   # tokens in cache per slot
        self.last_tok = np.zeros(n_slots, np.int64)  # next token to feed
        self.active = np.zeros(n_slots, bool)
        self._rng = np.random.default_rng(seed)
        self._key = jax.random.PRNGKey(seed)
        self._next_rid = 0
        self.n_decode_steps = 0
        self.n_prefills = 0

    # ------------------------------------------------------------- intake
    def submit(self, prompt: np.ndarray, *, max_new: int = 32,
               arrival: float = 0.0) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size + max_new > self.spec.max_len:
            raise ValueError(
                f"request budget {prompt.size + max_new} exceeds per-slot "
                f"capacity {self.spec.max_len}")
        need = self.spec.pages_for(prompt.size + max_new)
        if need > self.spec.n_pages - 1:
            # an under-provisioned pool could otherwise head-of-line block
            # this request forever (admission waits for pages that can
            # never all be free at once)
            raise ValueError(
                f"request needs {need} pages but the pool only has "
                f"{self.spec.n_pages - 1} allocatable pages")
        req = Request(rid=self._next_rid, prompt=prompt, max_new=max_new,
                      arrival=arrival)
        self._next_rid += 1
        self.sched.submit(req)
        return req

    # ------------------------------------------------------------ serving
    def step(self, now: float = 0.0) -> bool:
        """One scheduler tick: admit + prefill new requests (batched by
        prompt bucket), then run one fused block of decode steps over all
        slots. Returns False when there was nothing to do."""
        did = False
        admits = self.sched.admit(now)
        groups: dict[int, list] = {}
        for slot, req in admits:
            groups.setdefault(self._bucket(req.n_prompt), []).append(
                (slot, req))
        for padded, items in sorted(groups.items()):
            did = True
            i = 0
            while i < len(items):
                # pow2 chunk sizes bound the number of compiled shapes
                size = min(1 << ((len(items) - i).bit_length() - 1),
                           self.prefill_batch)
                chunk = items[i:i + size]
                i += size
                logits = self._prefill(chunk, padded)
                for row, (slot, req) in enumerate(chunk):
                    tok = sample_np(logits[row], self._rng,
                                    temperature=self.temperature,
                                    top_k=self.top_k)
                    self._emit(slot, req, tok, now)
        act = np.nonzero(self.active)[0]
        if act.size:
            did = True
            toks = self._decode_block()                       # (K, n_slots)
            for t in range(toks.shape[0]):
                for slot in act:
                    req = self.sched.slots[slot]
                    if req is not None:                       # not yet retired
                        self._emit(slot, req, int(toks[t, slot]), now)
        return did

    def run(self, *, clock=None, max_steps: Optional[int] = None):
        """Drain every submitted request; returns the requests that finished
        during this call, in submit order.

        `clock`: callable giving the current time for arrival gating and
        latency stamps (wall-clock driver); default is a virtual step
        counter, so `arrival` is then measured in scheduler steps.
        """
        import time as _time

        t = 0
        while not self.sched.all_done():
            if max_steps is not None and t >= max_steps:
                raise RuntimeError(f"serve loop exceeded {max_steps} steps")
            now = clock() if clock is not None else float(t)
            did = self.step(now)
            if did or clock is None:
                # virtual time must tick even when idle (arrival gating),
                # but under a wall clock an idle spin would burn CPU and
                # exhaust max_steps between sparse arrivals — sleep instead
                t += 1
            else:
                _time.sleep(1e-3)
        return sorted(self.sched.drain_finished(), key=lambda r: r.rid)

    # ----------------------------------------------------------- internals
    def _bucket(self, n: int) -> int:
        b = self.prefill_bucket
        return -(-n // b) * b

    def _prefill(self, chunk: Sequence[tuple[int, Request]],
                 padded: int) -> np.ndarray:
        """Prefill a same-bucket batch of admitted (slot, request) pairs.
        Returns (B, V) last-token logits."""
        batch = len(chunk)
        toks = np.zeros((batch, padded), np.int32)
        pos = np.full((batch, padded), -1, np.int32)
        for row, (slot, req) in enumerate(chunk):
            length = req.n_prompt
            toks[row, padded - length:] = req.prompt
            pos[row, padded - length:] = np.arange(length, dtype=np.int32)
        slots = np.asarray([slot for slot, _ in chunk], np.int32)
        paged = {"bt_rows": jnp.asarray(self.pool.tables[slots]),
                 "slots": jnp.asarray(slots)}
        logits, self.cache = _paged_prefill_jit(
            self.cfg, self.params, jnp.asarray(toks), self.cache,
            jnp.asarray(pos), paged)
        for slot, req in chunk:
            self.cur_len[slot] = req.n_prompt
            self.active[slot] = True
        self.n_prefills += 1
        return np.asarray(logits)

    def _decode_block(self) -> np.ndarray:
        """One fused block of decode steps; returns (K, n_slots) tokens.

        K adapts to the smallest remaining budget among active requests
        (pow2-capped at decode_block) so slots retire exactly at a block
        boundary instead of idling through overshoot steps."""
        act = self.active.copy()
        self._key, sk = jax.random.split(self._key)
        remaining = min(req.max_new - len(req.tokens)
                        for req in self.sched.slots if req is not None)
        k_steps = min(self.decode_block,
                      1 << (max(remaining, 1).bit_length() - 1))
        # bucket the attention read width (pow2 pages over the deepest slot
        # at block end) so shallow traffic doesn't pay max_len-wide gathers
        ps, maxp = self.spec.page_size, self.spec.max_pages
        deepest = int(self.cur_len[act].max()) + k_steps
        need = -(-deepest // ps)
        width = 1
        while width < need:
            width *= 2
        width = min(width, maxp)
        toks, self.cache = _paged_decode_scan_jit(
            self.cfg, self.params, self.cache,
            jnp.asarray(self.last_tok.astype(np.int32)),
            jnp.asarray(self.cur_len.astype(np.int32)),
            jnp.asarray(act),
            jnp.asarray(np.ascontiguousarray(self.pool.tables[:, :width])),
            sk, k_steps=k_steps, page_size=self.spec.page_size,
            temperature=self.temperature, top_k=self.top_k)
        self.cur_len[act] += k_steps
        self.n_decode_steps += k_steps
        return np.asarray(toks)

    def _emit(self, slot: int, req: Request, tok: int, now: float) -> None:
        if req.first_token_at is None:
            req.first_token_at = now
        req.tokens.append(tok)
        self.last_tok[slot] = tok
        if len(req.tokens) >= req.max_new or tok == self.eos_id:
            self.active[slot] = False
            self.sched.retire(slot, now)
