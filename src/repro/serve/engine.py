"""Batched serving engine for (quantized) LMs.

Static-batch engine with jitted prefill and decode steps; weights may be
float or packed QuantizedTensor (the paper's deployment format — dequant
happens inside the fused Pallas matmul on TPU). Exposes:

  * generate(prompts)       — batched prefill + greedy/sampled decode
  * score(tokens)           — teacher-forced log-likelihoods

Continuous batching at pod scale is driven by launch/serve.py; this module
is the single-replica execution core.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import (init_cache, lm_decode, lm_forward,
                                      lm_prefill)
from repro.serve.sampling import sample


@dataclasses.dataclass
class GenerateResult:
    tokens: np.ndarray          # (B, max_new)
    n_prompt: int
    steps: int


@functools.partial(jax.jit, static_argnames=("cfg", "max_new", "temperature",
                                             "top_k", "eos_id"))
def _generate_jit(cfg, params, prompts, key, max_new, temperature, top_k,
                  eos_id):
    b, s = prompts.shape
    cache = init_cache(cfg, b, s + max_new)
    logits, cache = lm_prefill(cfg, params, prompts, cache)

    def step(carry, t):
        cache, logits, key, done = carry
        key, sk = jax.random.split(key)
        tok = sample(logits, sk, temperature=temperature, top_k=top_k)
        tok = jnp.where(done, eos_id, tok)
        done = done | (tok == eos_id) if eos_id >= 0 else done
        pos = jnp.full((b, 1), s + t, jnp.int32)
        logits, cache = lm_decode(cfg, params, tok[:, None], cache, pos)
        return (cache, logits, key, done), tok

    (_, _, _, _), toks = jax.lax.scan(
        step, (cache, logits, key, jnp.zeros((b,), bool)),
        jnp.arange(max_new, dtype=jnp.int32))
    return toks.T                                              # (B, max_new)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, eos_id: int = -1):
        self.cfg = cfg
        self.params = params
        self.eos_id = eos_id

    def generate(self, prompts: np.ndarray, *, max_new: int = 32,
                 temperature: float = 0.0, top_k: int = 0,
                 key: Optional[jax.Array] = None) -> GenerateResult:
        key = key if key is not None else jax.random.PRNGKey(0)
        toks = _generate_jit(self.cfg, self.params,
                             jnp.asarray(prompts, jnp.int32), key, max_new,
                             temperature, top_k, self.eos_id)
        return GenerateResult(np.asarray(toks), prompts.shape[1], max_new)

    def score(self, tokens: np.ndarray) -> np.ndarray:
        """Per-token log-likelihoods (B, S-1)."""
        toks = jnp.asarray(tokens, jnp.int32)
        logits, _ = lm_forward(self.cfg, self.params, toks[:, :-1])
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, toks[:, 1:][..., None],
                                 axis=-1)[..., 0]
        return np.asarray(ll - lse)
