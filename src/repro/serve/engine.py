"""Serving engines for (quantized) LMs.

Weights may be float or packed QuantizedTensor (the paper's deployment
format — dequant happens inside the fused Pallas matmul on TPU; both
engines accept `quant_bits=...` to pack a float tree in place via
`quantize_params_for_serving`). Decode steps present M = n_slots (or
batch) token rows per linear, which rides the decode-shaped skinny-M
kernel tiles picked by kernels/ops.py; quantized MoE experts run the
expert-batched kernel without materializing float expert stacks. Two
engines share the model code:

  * ServeEngine        — static batch: one prompt length, lockstep decode to
                         max_new. Kept as the baseline and for scoring.
  * ContinuousEngine   — continuous batching over a fixed slot pool with a
                         paged KV cache (serve/kvcache.py): requests are
                         admitted into free slots as others retire, each
                         slot decodes at its own depth, and finished
                         requests stop burning decode FLOPs. All jitted
                         shapes are static (slot count, page pool, bucketed
                         prefill lengths), so steady-state serving never
                         recompiles. Decode attention runs the fused
                         paged-attention kernel by default (block-table walk
                         + inline int8-KV dequant inside the kernel); pass
                         paged_attn="gather" for the gather->dequant->einsum
                         oracle path (see DESIGN.md "Paged-attention decode
                         kernel").

The traffic driver (Poisson arrivals, latency percentiles) lives in
launch/serve.py; admission policy lives in serve/scheduler.py.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import (init_cache, lm_decode, lm_forward,
                                      lm_prefill)
from repro.serve.kvcache import PagePool, PageSpec, default_page_spec
from repro.serve.sampling import sample
from repro.serve.scheduler import Request, Scheduler


@dataclasses.dataclass
class GenerateResult:
    tokens: np.ndarray          # (B, max_new)
    n_prompt: int
    steps: int


@functools.partial(jax.jit, static_argnames=("cfg", "max_new", "temperature",
                                             "top_k", "eos_id"))
def _generate_jit(cfg, params, prompts, key, max_new, temperature, top_k,
                  eos_id):
    b, s = prompts.shape
    cache = init_cache(cfg, b, s + max_new)
    logits, cache = lm_prefill(cfg, params, prompts, cache)

    def step(carry, t):
        cache, logits, key, done = carry
        key, sk = jax.random.split(key)
        tok = sample(logits, sk, temperature=temperature, top_k=top_k)
        tok = jnp.where(done, eos_id, tok)
        done = done | (tok == eos_id) if eos_id >= 0 else done
        pos = jnp.full((b, 1), s + t, jnp.int32)
        logits, cache = lm_decode(cfg, params, tok[:, None], cache, pos)
        return (cache, logits, key, done), tok

    (_, _, _, _), toks = jax.lax.scan(
        step, (cache, logits, key, jnp.zeros((b,), bool)),
        jnp.arange(max_new, dtype=jnp.int32))
    return toks.T                                              # (B, max_new)


def _maybe_quantize(cfg, params, quant_bits, quant_group, act_bits):
    """Pack a float param tree for serving when quant_bits is set (no-op on
    already-packed trees: QuantizedTensor leaves are left untouched).
    quant_group follows the deploy convention: 0 = cfg.serve_quant_group,
    -1 = per-channel."""
    if not quant_bits:
        if act_bits:
            raise ValueError("act_bits requires quant_bits (A8 tags live on "
                             "packed QuantizedTensors)")
        return params
    from repro.core.quant.deploy import quantize_params_for_serving
    from repro.core.quant.types import QuantizedTensor

    leaves = jax.tree_util.tree_leaves(
        params, is_leaf=lambda x: isinstance(x, QuantizedTensor))
    if any(isinstance(x, QuantizedTensor) for x in leaves):
        raise ValueError("params already hold packed QuantizedTensors; "
                         "pass quant_bits=0 (re-packing is a silent no-op "
                         "and would drop the requested act_bits/group)")
    return quantize_params_for_serving(cfg, params, bits=quant_bits,
                                       group_size=quant_group,
                                       act_bits=act_bits)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, eos_id: int = -1,
                 quant_bits: int = 0, quant_group: int = 0,
                 act_bits: int = 0):
        self.cfg = cfg
        self.params = _maybe_quantize(cfg, params, quant_bits, quant_group,
                                      act_bits)
        self.eos_id = eos_id

    def generate(self, prompts: np.ndarray, *, max_new: int = 32,
                 temperature: float = 0.0, top_k: int = 0,
                 key: Optional[jax.Array] = None) -> GenerateResult:
        key = key if key is not None else jax.random.PRNGKey(0)
        toks = _generate_jit(self.cfg, self.params,
                             jnp.asarray(prompts, jnp.int32), key, max_new,
                             temperature, top_k, self.eos_id)
        return GenerateResult(np.asarray(toks), prompts.shape[1], max_new)

    def score(self, tokens: np.ndarray) -> np.ndarray:
        """Per-token log-likelihoods (B, S-1)."""
        toks = jnp.asarray(tokens, jnp.int32)
        logits, _ = lm_forward(self.cfg, self.params, toks[:, :-1])
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, toks[:, 1:][..., None],
                                 axis=-1)[..., 0]
        return np.asarray(ll - lse)


# ------------------------------------------------------- continuous batching

@functools.partial(jax.jit, static_argnames=("cfg",),
                   donate_argnames=("cache",))
def _paged_prefill_jit(cfg, params, tokens, cache, positions, paged):
    return lm_prefill(cfg, params, tokens, cache, positions=positions,
                      paged=paged)


@functools.partial(jax.jit, static_argnames=("temperature", "top_k"))
def _sample_first_jit(logits, keys, *, temperature, top_k):
    """Per-request first-token sampling: logits (B, V), keys (B, 2).

    Each row draws from its own key (folded from the request id by the
    engine), so the result does not depend on how admitted requests were
    grouped into prefill batches — the same seed gives the same tokens at
    prefill_batch=1 and prefill_batch=8."""
    return jax.vmap(lambda l, k: sample(l[None], k, temperature=temperature,
                                        top_k=top_k)[0])(logits, keys)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "k_steps", "page_size",
                                    "temperature", "top_k"),
                   donate_argnames=("cache",))
def _paged_decode_scan_jit(cfg, params, cache, last_tok, cur_len, active,
                           block_table, key, *, k_steps, page_size,
                           temperature, top_k):
    """K fused decode steps over all slots with on-device sampling.

    One dispatch and one host sync per K tokens — the per-step Python/
    transfer overhead of a step-at-a-time loop would otherwise rival the
    model compute. Slots whose request finishes mid-block keep stepping;
    their extra writes fall off the block table onto the scratch page and
    the host drops the surplus tokens. Returns ((K, S) tokens, cache).
    """
    n_slots, max_pages = block_table.shape
    sl = jnp.arange(n_slots)

    def body(carry, _):
        cache, tok, clen, key = carry
        key, sk = jax.random.split(key)
        page_idx = jnp.clip(clen // page_size, 0, max_pages - 1)
        paged = {
            "block_table": block_table,
            "write_page": jnp.where(
                active, jnp.maximum(block_table[sl, page_idx], 0), 0),
            "write_off": jnp.where(active, clen % page_size, 0),
            "kv_len": jnp.where(active, clen + 1, 0),
        }
        pos = jnp.where(active, clen, 0)[:, None]
        logits, cache = lm_decode(cfg, params, tok[:, None], cache, pos,
                                  paged=paged)
        nxt = sample(logits, sk, temperature=temperature, top_k=top_k)
        tok = jnp.where(active, nxt, tok)
        clen = clen + active.astype(clen.dtype)
        return (cache, tok, clen, key), nxt

    (cache, _, _, _), toks = jax.lax.scan(
        body, (cache, last_tok, cur_len, key), None, length=k_steps)
    return toks, cache


class ContinuousEngine:
    """Slot-stepping execution core for continuous batching.

    Holds the paged cache, the per-slot host state (fill depth, last token),
    and the jitted prefill/decode steps. Admission policy and request
    bookkeeping are delegated to serve/scheduler.py. One `step()`:

      1. retire-then-admit: the scheduler maps queued requests onto free
         slots (FIFO; whole-budget page allocation minus any prefix-cache
         hit — see below);
      2. slots still ingesting their prompt advance by one prefill chunk —
         jitted calls batched per chunk-length bucket (pow2 batch sizes,
         capped at `prefill_batch`) that scatter K/V into the admitted
         slots' pages while every other slot's cache state is untouched;
         a slot whose prompt completes samples its first token and joins
         the decode set;
      3. one fused block of `decode_block` lockstep decode steps over all
         slots (a device-side lax.scan with on-device sampling — one
         dispatch and one host sync per K tokens). Idle and mid-prefill
         slots write to the scratch page and are masked; slots finishing
         mid-block overshoot onto the scratch page and the surplus tokens
         are dropped.

    `prefix_share=True` turns on the pool's prefix cache: a prompt whose
    full-page prefix was already prefilled by an earlier request reuses
    those pages by reference and prefills only the unshared suffix.
    `chunked_prefill=N` caps each prefill call at N tokens (rounded to a
    page multiple), spreading a long prompt across `step()` ticks so
    decode slots keep stepping instead of stalling behind it. Both
    features need the gathered-context prefill read path and per-page
    prompt state, so they cover attention-only decoders (no SSM state, no
    MLA latent prefill). See DESIGN.md "Prefix cache & chunked prefill".

    `prefill_bucket` trades compile count for pad waste: prompts are
    left-padded (pos = -1, masked everywhere) up to the next multiple.
    Bucket 1 reproduces the static engine's unpadded prefill bit-for-bit.
    `decode_block` trades admission latency (new arrivals wait for the
    current block) against per-token dispatch overhead.
    """

    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 8,
                 max_len: int = 512, page_size: int = 16,
                 n_pages: Optional[int] = None, eos_id: int = -1,
                 prefill_bucket: int = 16, prefill_batch: int = 8,
                 decode_block: int = 8,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 quant_bits: int = 0, quant_group: int = 0,
                 act_bits: int = 0, paged_attn: Optional[str] = None,
                 prefix_share: bool = False, chunked_prefill: int = 0):
        if cfg.enc_dec:
            raise NotImplementedError("paged serving covers decoder-only LMs")
        if prefix_share or chunked_prefill:
            has_ssm = any(spec.kind != "attn"
                          for spec in cfg.prefix_pattern + cfg.pattern)
            if has_ssm or cfg.attention == "mla":
                # SSM state is not page-addressed (a shared page carries no
                # recurrence state) and MLA's non-absorbed prefill never
                # reads the paged latent back — both would be silently
                # wrong, so refuse up front
                raise NotImplementedError(
                    "prefix_share/chunked_prefill cover attention-only "
                    "decoders (no SSM blocks, no MLA)")
        if paged_attn is not None:
            # per-engine override of the decode attention path: "fused"
            # (paged-attention kernel) or "gather" (oracle). Threaded via
            # the config because the dispatch lives in models/attention.py.
            if paged_attn not in ("fused", "gather"):
                raise ValueError(f"paged_attn must be 'fused' or 'gather', "
                                 f"got {paged_attn!r}")
            cfg = cfg.replace(paged_attn_impl=paged_attn)
        self.cfg = cfg
        self.params = _maybe_quantize(cfg, params, quant_bits, quant_group,
                                      act_bits)
        self.n_slots = n_slots
        self.eos_id = eos_id
        self.prefill_bucket = max(1, prefill_bucket)
        # prefill_batch=1 avoids co-batched prefills entirely: capacity-MoE
        # routing is cross-token, so co-batched requests can perturb each
        # other's expert assignment when capacity binds (see DESIGN.md)
        self.prefill_batch = max(1, prefill_batch)
        self.decode_block = max(1, decode_block)
        self.temperature = temperature
        self.top_k = top_k
        self.prefix_share = bool(prefix_share)
        # chunk sizes are page-aligned so every chunk boundary (and every
        # shared-prefix handoff) starts exactly at a page start
        self.chunk_tokens = (max(1, chunked_prefill // page_size) * page_size
                             if chunked_prefill else 0)
        if n_pages is None:
            self.spec = default_page_spec(n_slots, max_len, page_size)
        else:
            self.spec = PageSpec(n_pages=n_pages, page_size=page_size,
                                 max_pages=-(-max_len // page_size))
        self.pool = PagePool(self.spec, n_slots,
                             prefix_cache=self.prefix_share)
        self.sched = Scheduler(n_slots, self.pool,
                               prefix_share=self.prefix_share)
        self.cache = init_cache(cfg, n_slots, self.spec.max_len,
                                paged=self.spec)
        self.cur_len = np.zeros(n_slots, np.int64)   # tokens in cache per slot
        self.last_tok = np.zeros(n_slots, np.int64)  # next token to feed
        self.active = np.zeros(n_slots, bool)
        self._prefilling: dict[int, Request] = {}    # slot -> mid-prompt req
        self._key, self._first_key = jax.random.split(jax.random.PRNGKey(seed))
        self._next_rid = 0
        self.n_decode_steps = 0
        self.n_prefills = 0
        self.n_prefill_tokens = 0    # real prompt tokens actually prefilled
        self.n_shared_tokens = 0     # prompt tokens served from the prefix cache

    # ------------------------------------------------------------- intake
    def submit(self, prompt: np.ndarray, *, max_new: int = 32,
               arrival: float = 0.0) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size + max_new > self.spec.max_len:
            raise ValueError(
                f"request budget {prompt.size + max_new} exceeds per-slot "
                f"capacity {self.spec.max_len}")
        need = self.spec.pages_for(prompt.size + max_new)
        if need > self.spec.n_pages - 1:
            # an under-provisioned pool could otherwise head-of-line block
            # this request forever (admission waits for pages that can
            # never all be free at once)
            raise ValueError(
                f"request needs {need} pages but the pool only has "
                f"{self.spec.n_pages - 1} allocatable pages")
        req = Request(rid=self._next_rid, prompt=prompt, max_new=max_new,
                      arrival=arrival)
        self._next_rid += 1
        self.sched.submit(req)
        return req

    # ------------------------------------------------------------ serving
    def step(self, now: float = 0.0) -> bool:
        """One scheduler tick: admit new requests, advance every
        mid-prefill slot by one chunk (batched by chunk bucket), then run
        one fused block of decode steps over all decoding slots. Returns
        False when there was nothing to do."""
        did = False
        for slot, req in self.sched.admit(now):
            # a prefix hit starts the prefill past the shared pages — the
            # cache already holds positions 0..n_shared-1 for this prompt
            self.cur_len[slot] = req.n_shared
            self.n_shared_tokens += req.n_shared
            self._prefilling[slot] = req
        if self._prefilling:
            did = True
            self._prefill_tick(now)
        act = np.nonzero(self.active)[0]
        if act.size:
            did = True
            toks = self._decode_block()                       # (K, n_slots)
            for t in range(toks.shape[0]):
                for slot in act:
                    req = self.sched.slots[slot]
                    if req is not None:                       # not yet retired
                        self._emit(slot, req, int(toks[t, slot]), now)
        return did

    def run(self, *, clock=None, max_steps: Optional[int] = None):
        """Drain every submitted request; returns the requests that finished
        during this call, in submit order.

        `clock`: callable giving the current time for arrival gating and
        latency stamps (wall-clock driver); default is a virtual step
        counter, so `arrival` is then measured in scheduler steps.
        """
        import time as _time

        t = 0
        while not self.sched.all_done():
            if max_steps is not None and t >= max_steps:
                raise RuntimeError(f"serve loop exceeded {max_steps} steps")
            now = clock() if clock is not None else float(t)
            did = self.step(now)
            if did or clock is None:
                # virtual time must tick even when idle (arrival gating),
                # but under a wall clock an idle spin would burn CPU and
                # exhaust max_steps between sparse arrivals — sleep instead
                t += 1
            else:
                _time.sleep(1e-3)
        return sorted(self.sched.drain_finished(), key=lambda r: r.rid)

    # ----------------------------------------------------------- internals
    def _bucket(self, n: int) -> int:
        b = self.prefill_bucket
        return -(-n // b) * b

    def _read_width(self, n_tokens: int) -> int:
        """Pow2 page count covering n_tokens, capped at the table width —
        the read-width bucketing shared by decode and chunked prefill."""
        need = self.spec.pages_for(n_tokens)
        width = 1
        while width < need:
            width *= 2
        return min(width, self.spec.max_pages)

    def _prefill_tick(self, now: float) -> None:
        """Advance every mid-prefill slot by one (page-aligned) chunk.

        Chunks are batched per (length bucket, has-context) pair: rows
        whose chunk starts at position 0 keep the original self-attending
        prefill read path (bit-identical to the monolithic engine), while
        suffix/later chunks need the gathered-context path because their
        earlier tokens live in pages — their own prior chunks, or shared
        prefix pages written by another request."""
        work = []
        for slot in sorted(self._prefilling):
            req = self._prefilling[slot]
            start = int(self.cur_len[slot])
            end = req.n_prompt
            if self.chunk_tokens and end - start > self.chunk_tokens:
                end = start + self.chunk_tokens
            work.append((slot, req, start, end))
        groups: dict[tuple[int, bool], list] = {}
        for item in work:
            slot, req, start, end = item
            groups.setdefault((self._bucket(end - start), start > 0),
                              []).append(item)
        for (padded, has_ctx), items in sorted(groups.items()):
            i = 0
            while i < len(items):
                # pow2 chunk sizes bound the number of compiled shapes
                size = min(1 << ((len(items) - i).bit_length() - 1),
                           self.prefill_batch)
                self._prefill_chunk(items[i:i + size], padded, has_ctx, now)
                i += size

    def _prefill_chunk(self, items: Sequence[tuple], padded: int,
                       has_ctx: bool, now: float) -> None:
        """Prefill one same-bucket batch of (slot, req, start, end) chunks;
        rows that complete their prompt sample a first token and switch
        the slot to decoding."""
        batch = len(items)
        toks = np.zeros((batch, padded), np.int32)
        pos = np.full((batch, padded), -1, np.int32)
        for row, (slot, req, start, end) in enumerate(items):
            n = end - start
            toks[row, padded - n:] = req.prompt[start:end]
            pos[row, padded - n:] = np.arange(start, end, dtype=np.int32)
        slots = np.asarray([slot for slot, _, _, _ in items], np.int32)
        if has_ctx:
            # pow2-bucketed read width over the deepest chunk end, so the
            # gathered context scales with fill, not provisioned max_len
            kv_end = np.asarray([end for _, _, _, end in items], np.int32)
            width = self._read_width(int(kv_end.max()))
            paged = {"bt_rows": jnp.asarray(np.ascontiguousarray(
                         self.pool.tables[slots][:, :width])),
                     "slots": jnp.asarray(slots),
                     "kv_len": jnp.asarray(kv_end)}
        else:
            paged = {"bt_rows": jnp.asarray(self.pool.tables[slots]),
                     "slots": jnp.asarray(slots)}
        logits, self.cache = _paged_prefill_jit(
            self.cfg, self.params, jnp.asarray(toks), self.cache,
            jnp.asarray(pos), paged)
        self.n_prefills += 1
        self.n_prefill_tokens += sum(end - start for _, _, start, end in items)
        finish = []
        for row, (slot, req, start, end) in enumerate(items):
            self.cur_len[slot] = end
            if end >= req.n_prompt:
                finish.append(row)
        if not finish:
            return
        keys = jnp.stack([jax.random.fold_in(self._first_key, items[row][1].rid)
                          for row in finish])
        first = np.asarray(_sample_first_jit(
            logits[jnp.asarray(finish)], keys,
            temperature=self.temperature, top_k=self.top_k))
        for tok, row in zip(first, finish):
            slot, req, _, _ = items[row]
            del self._prefilling[slot]
            self.active[slot] = True
            if self.prefix_share:
                # publish this prompt's full pages before _emit can retire
                # the slot (an immediate EOS/max_new=1 would unmap it)
                self.pool.register_prefix(req.prompt, slot)
            self._emit(slot, req, int(tok), now)

    def _decode_block(self) -> np.ndarray:
        """One fused block of decode steps; returns (K, n_slots) tokens.

        K adapts to the smallest remaining budget among active requests
        (pow2-capped at decode_block) so slots retire exactly at a block
        boundary instead of idling through overshoot steps."""
        act = self.active.copy()
        self._key, sk = jax.random.split(self._key)
        # min over *decoding* slots only: a mid-prefill request has its
        # whole max_new outstanding and must not shrink everyone's block
        remaining = min(req.max_new - len(req.tokens)
                        for slot, req in enumerate(self.sched.slots)
                        if req is not None and act[slot])
        k_steps = min(self.decode_block,
                      1 << (max(remaining, 1).bit_length() - 1))
        # bucket the attention read width (pow2 pages over the deepest slot
        # at block end) so shallow traffic doesn't pay max_len-wide gathers
        width = self._read_width(int(self.cur_len[act].max()) + k_steps)
        toks, self.cache = _paged_decode_scan_jit(
            self.cfg, self.params, self.cache,
            jnp.asarray(self.last_tok.astype(np.int32)),
            jnp.asarray(self.cur_len.astype(np.int32)),
            jnp.asarray(act),
            jnp.asarray(np.ascontiguousarray(self.pool.tables[:, :width])),
            sk, k_steps=k_steps, page_size=self.spec.page_size,
            temperature=self.temperature, top_k=self.top_k)
        self.cur_len[act] += k_steps
        self.n_decode_steps += k_steps
        return np.asarray(toks)

    def _emit(self, slot: int, req: Request, tok: int, now: float) -> None:
        if req.first_token_at is None:
            req.first_token_at = now
        req.tokens.append(tok)
        self.last_tok[slot] = tok
        if len(req.tokens) >= req.max_new or tok == self.eos_id:
            self.active[slot] = False
            self.sched.retire(slot, now)
