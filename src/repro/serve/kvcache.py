"""Paged KV cache: fixed page pool + per-slot block tables.

Replaces the dense ``(B, max_len, ...)`` decode cache with a pool of
fixed-size pages shared by all serving slots. Each slot owns a block table —
a row of page indices — and attention reads gather the slot's pages back
into a contiguous ``(S, n_pages_read * page_size, ...)`` view. Because a
slot's cache is always the contiguous positions ``0..len-1`` (prompt then
decoded tokens), the position mask is derived from the per-slot fill count
alone — no position pool is stored, and recycled pages need no
invalidation: stale entries beyond ``len`` are masked by construction.

All shapes are compile-time constants (pool size, page size, table width),
so the jitted prefill/decode steps never recompile as requests come and go;
the engine buckets the *read* width (pow2 pages over the deepest live slot)
so shallow traffic doesn't pay full-depth attention.

Page 0 is a reserved scratch page: idle slots (and padded prompt positions)
write there, and nothing ever reads it. The allocator itself is host-side
(`PagePool`); only the gather/scatter helpers below run under jit.

Pages are refcounted so slots can share them: with `prefix_cache=True` the
pool keeps a token-keyed index over *full* pages (prefix length rounded
down to a page boundary), and a new request whose prompt hits the index is
stitched onto the cached pages instead of re-prefilling them. Because only
whole pages of pure prompt tokens are ever shared, a shared page holds
exactly positions ``0..p-1`` and no copy-on-write is needed — decode
writes always land on pages the slot owns exclusively (its tail pages).
The index itself holds one reference per cached page, so a cached page
survives its last slot retiring; index-only pages (refcount 1) are the
eviction pool when fresh allocations outrun the free list.

Preemption support: ``spill(slot)`` checkpoints a victim slot's mapping so
the slot (and its exclusively-owned pages) can be handed to a higher-class
request, and ``restore(slot, snap)`` re-stitches an equivalent block table
later. Pages the slot shares with anyone else (prefix-index entries, other
slots) are *kept by reference* — the snapshot holds one refcount on each, so
they survive on device untouched and spill never duplicates prefix-cache
pages. Only exclusively-owned live pages have their contents handed to the
caller (`copy_out`) for host storage; the allocator tracks snapshot-held
references so `check_invariants` keeps conserving pages across the whole
preempt -> spill -> restore lifecycle.

Layering note: repro.models.{attention,mla,blocks} import this module, so
it must stay dependency-free — importing anything from repro.models (or
repro.serve.engine) here would create a package cycle.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Any, Callable, Optional

import jax.numpy as jnp
import numpy as np

SCRATCH_PAGE = 0


@dataclasses.dataclass(frozen=True)
class PageSpec:
    """Compile-time geometry of the page pool."""

    n_pages: int          # total pages, including the reserved scratch page
    page_size: int        # tokens per page
    max_pages: int        # block-table width (pages a single slot may hold)

    @property
    def max_len(self) -> int:
        return self.max_pages * self.page_size

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)


def default_page_spec(n_slots: int, max_len: int,
                      page_size: int = 16) -> PageSpec:
    """Fully-provisioned pool: every slot can hold max_len tokens."""
    max_pages = -(-max_len // page_size)
    return PageSpec(n_pages=1 + n_slots * max_pages, page_size=page_size,
                    max_pages=max_pages)


@dataclasses.dataclass
class SpillSnapshot:
    """Checkpoint of one slot's page mapping taken by ``PagePool.spill``.

    ``kept`` pages stay resident on device — the snapshot holds one
    reference on each, so neither the free list nor prefix-cache eviction
    can reclaim them while the request sits preempted. ``copied`` pages
    were exclusively owned (refcount 1); their contents were handed to the
    spill caller's `copy_out` and the pages themselves returned to the free
    list — the ids recorded here are stale the moment spill returns and are
    kept only so restore knows *where* in the rebuilt table the host data
    goes. ``host`` is whatever `copy_out` returned (the engine stores the
    gathered KV tree as numpy — host RAM)."""

    n_pages: int                     # pages the slot had mapped (full budget)
    n_live: int                      # tokens whose KV was resident at spill
    kept: list                       # (table_pos, page_id) resident by ref
    copied: list                     # table_pos of pages whose data spilled
    host: Any = None                 # opaque payload from copy_out
    restored: Optional[list] = None  # fresh page ids restore picked for the
    #                                  copied positions (set by restore, in
    #                                  snap.copied order) — the engine
    #                                  scatters `host` back into these
    checksum: Optional[int] = None   # crc over `host` set by the engine at
    #                                  spill time; verified before scatter so
    #                                  a corrupted snapshot quarantines the
    #                                  request instead of resuming on garbage


class PagePool:
    """Host-side refcounted page allocator and per-slot block tables.

    A page's writers never collide: decode only ever writes to a slot's
    *tail* pages, which have refcount 1 from that slot alone (idle slots
    all target the scratch page, whose contents are never read). Shared
    prefix pages may be read by many slots at once, but hold frozen prompt
    tokens, so reads need no coordination.
    """

    def __init__(self, spec: PageSpec, n_slots: int,
                 prefix_cache: bool = False):
        self.spec = spec
        self.n_slots = n_slots
        self.prefix_cache = prefix_cache
        self._free = list(range(spec.n_pages - 1, SCRATCH_PAGE, -1))
        self.tables = np.full((n_slots, spec.max_pages), -1, np.int32)
        self.refcount = np.zeros(spec.n_pages, np.int32)
        # prefix key -> page id, insertion-ordered so eviction pops the
        # oldest entry (hits re-insert: approximate LRU). Keys are chained
        # digests key_k = H(key_{k-1} || page_k token bytes): each page key
        # commits to the *whole* prefix up to its end — two prompts share a
        # page only when every earlier token matches — at O(L) total
        # keying cost instead of O(L^2) byte-prefix keys. Parent links and
        # per-key cached-child counts make chain-leaf detection O(1)
        # during eviction.
        self._prefix_index: OrderedDict[bytes, int] = OrderedDict()
        self._parent: dict[bytes, Optional[bytes]] = {}
        # key -> number of live entries whose parent link is `key` (the
        # key itself need not be live: strands keep their parent link)
        self._children: dict[bytes, int] = {}
        # bumped on every index mutation; lets admission cache a blocked
        # queue head's prefix lookup across ticks
        self.generation = 0
        # references held by live SpillSnapshots (preempted slots): counted
        # into refcount so eviction/free can't touch a spilled page, and
        # tracked separately so check_invariants can still prove
        # conservation while requests sit preempted
        self._spill_refs = np.zeros(spec.n_pages, np.int32)
        # references held by hold() (fault injection pins pages to simulate
        # exhaustion) — same conservation treatment as spill refs
        self._hold_refs = np.zeros(spec.n_pages, np.int32)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_cached(self) -> int:
        """Pages held by the prefix index (possibly also held by slots)."""
        return len(self._prefix_index)

    def _n_evictable(self, exclude=()) -> int:
        ex = set(exclude)
        return sum(1 for p in self._prefix_index.values()
                   if self.refcount[p] == 1 and p not in ex)

    def can_alloc(self, n_tokens: int, shared_pages=()) -> bool:
        """True when a request of `n_tokens` could be admitted now.

        Gates on the block-table width too: a request needing more pages
        than one table row can hold is structurally impossible, and must
        report un-admittable here rather than blowing up inside `alloc`
        after the caller has already committed a slot. `shared_pages` are
        prefix-cache pages the caller will reuse: they reduce the fresh-
        page need but must not be counted as evictable headroom."""
        need = self.spec.pages_for(n_tokens)
        if need > self.spec.max_pages:
            return False
        need -= len(shared_pages)
        return need <= len(self._free) + self._n_evictable(shared_pages)

    def _prefix_keys(self, tokens: np.ndarray, n_pages: int) -> list[bytes]:
        """Chained page keys for the first n_pages full pages of `tokens`."""
        toks = np.ascontiguousarray(np.asarray(tokens, np.int32))
        ps = self.spec.page_size
        keys, prev = [], b""
        for k in range(n_pages):
            h = hashlib.blake2b(prev, digest_size=16)
            h.update(toks[k * ps:(k + 1) * ps].tobytes())
            prev = h.digest()
            keys.append(prev)
        return keys

    def lookup_prefix(self, tokens: np.ndarray) -> list[int]:
        """Longest indexed run of full pages covering a *strict* prefix.

        Capped at len(tokens) - 1 so the suffix prefill always has at least
        one token left to produce the last-token logits from."""
        if not self.prefix_cache:
            return []
        n_full = (len(tokens) - 1) // self.spec.page_size
        pages = []
        for key in self._prefix_keys(tokens, n_full):
            page = self._prefix_index.get(key)
            if page is None:
                break
            self._prefix_index.move_to_end(key)     # refresh LRU position
            pages.append(page)
        return pages

    def register_prefix(self, tokens: np.ndarray, slot: int) -> int:
        """Publish `slot`'s full-page prompt prefixes into the index.

        Called once the prompt is fully prefilled. Only pages holding pure
        prompt tokens are registered (the page at ``len // page_size`` —
        partial, or about to receive decode tokens — never is). Idempotent
        on already-indexed keys; returns the number of pages added."""
        if not self.prefix_cache:
            return 0
        n_full = len(tokens) // self.spec.page_size
        added = 0
        parent = None
        for k, key in enumerate(self._prefix_keys(tokens, n_full)):
            if key in self._prefix_index:
                self._prefix_index.move_to_end(key)
                parent = key
                continue
            page = int(self.tables[slot, k])
            assert page >= 0, f"slot {slot} prefix page {k} not mapped"
            self._prefix_index[key] = page
            self._parent[key] = parent
            if parent is not None:
                self._children[parent] = self._children.get(parent, 0) + 1
            self.refcount[page] += 1                # the index holds a ref
            self.generation += 1
            added += 1
            parent = key
        return added

    def _drop_entry(self, key: bytes) -> None:
        """Remove one index entry, dropping the index's page reference.

        `self._children[key]` is deliberately kept: it counts live entries
        whose parent link targets `key`, and those children stay cached
        (as strands) when `key` itself is dropped — if the same prefix is
        re-registered later, the surviving count keeps leaf detection
        exact. The count dies naturally when its last child drops."""
        page = self._prefix_index.pop(key)
        parent = self._parent.pop(key)
        if parent is not None:
            self._children[parent] -= 1
            if not self._children[parent]:
                del self._children[parent]
        self.generation += 1
        self.refcount[page] -= 1
        if self.refcount[page] == 0:
            self._free.append(int(page))

    def _evict_one(self) -> None:
        """Drop one index-only (refcount 1) cached page to the free list.
        Caller guarantees one exists (via can_alloc).

        Prefers the oldest entry with no cached descendant (a chain leaf):
        evicting a chain's head first would strand its deeper entries —
        unreachable via lookup (which walks from page 0) yet still holding
        pages. Falls back to the plain oldest evictable entry when every
        candidate has a descendant pinned by a live slot, so the
        can_alloc/_n_evictable accounting always stays honest."""
        fallback = None
        for key, page in self._prefix_index.items():
            if self.refcount[page] != 1:
                continue
            if fallback is None:
                fallback = key
            if not self._children.get(key):
                fallback = key
                break
        if fallback is None:
            raise RuntimeError("no evictable prefix-cache page")
        self._drop_entry(fallback)

    def clear_prefix_cache(self) -> int:
        """Drop every prefix-index entry (pages still held by live slots
        keep their slot references and just leave the index). Returns the
        number of entries dropped — drivers use this to separate warm-up
        registrations from measured traffic."""
        n = len(self._prefix_index)
        while self._prefix_index:
            self._drop_entry(next(iter(self._prefix_index)))
        return n

    def alloc(self, slot: int, n_tokens: int, shared_pages=()) -> None:
        """Map `slot` to pages for n_tokens: `shared_pages` (prefix-cache
        hits, referenced not copied) stitched in front of freshly allocated
        tail pages. Caller checks can_alloc with the same shared list."""
        need = self.spec.pages_for(n_tokens)
        if need > self.spec.max_pages:
            raise ValueError(f"request needs {need} pages > block-table "
                             f"width {self.spec.max_pages}")
        shared = [int(p) for p in shared_pages]
        fresh = need - len(shared)
        if fresh > len(self._free) + self._n_evictable(shared):
            raise RuntimeError(f"page pool exhausted: need {fresh} fresh, "
                               f"free {len(self._free)}")
        assert np.all(self.tables[slot] == -1), f"slot {slot} already mapped"
        # take the shared references first so eviction below can never
        # reclaim the very pages this request is reusing
        for p in shared:
            self.refcount[p] += 1
        pages = []
        for _ in range(fresh):
            if not self._free:
                self._evict_one()
            page = self._free.pop()
            self.refcount[page] += 1
            pages.append(page)
        self.tables[slot, :need] = shared + pages

    def release(self, slot: int) -> None:
        """Drop `slot`'s references; pages free when nobody holds them.

        Shared prefix pages stay alive while other slots or the prefix
        index still reference them."""
        for p in self.tables[slot]:
            if p < 0:
                continue
            self.refcount[p] -= 1
            assert self.refcount[p] >= 0, f"page {int(p)} over-released"
            if self.refcount[p] == 0:
                self._free.append(int(p))
        self.tables[slot] = -1

    # ---------------------------------------------------- preemption spill
    def slot_owned_pages(self, slot: int) -> int:
        """Mapped pages only this slot holds (refcount 1) — the pages a
        preemption would actually return to the free list. The scheduler
        consults this before picking a victim so it never spills a slot
        whose pages are all shared (freeing nothing)."""
        row = self.tables[slot]
        return int(sum(1 for p in row if p >= 0 and self.refcount[p] == 1))

    def spill(self, slot: int, n_live_tokens: int,
              copy_out: Callable[[list], Any]) -> SpillSnapshot:
        """Checkpoint and unmap `slot` so the slot + its owned pages can be
        reassigned; returns the snapshot `restore` later consumes.

        Pages with refcount > 1 (prefix-index entries, pages other slots
        stitched) are *kept by reference*: the snapshot takes one refcount
        on each and their contents never move — spill cannot duplicate a
        prefix-cache page by construction. Exclusively-owned pages holding
        live tokens (positions 0..n_live_tokens-1) are passed to `copy_out`
        — called BEFORE any page is released, so the caller can read their
        contents off-device synchronously — and then freed along with the
        dead tail pages (allocated for future decode, never written)."""
        row = self.tables[slot]
        n_mapped = int(np.sum(row >= 0))
        assert n_mapped > 0, f"slot {slot} has nothing to spill"
        live = self.spec.pages_for(n_live_tokens)
        assert live <= n_mapped, \
            f"slot {slot}: {n_live_tokens} live tokens exceed its " \
            f"{n_mapped}-page mapping"
        index_pages = set(self._prefix_index.values())
        kept, copied = [], []
        for i in range(n_mapped):
            page = int(row[i])
            if self.refcount[page] > 1:
                kept.append((i, page))
            elif i < live:
                # exclusively owned AND written: its contents exist nowhere
                # else. A prefix-index page can never land here (the index
                # itself holds a reference, so refcount >= 2).
                assert page not in index_pages, \
                    f"prefix-index page {page} about to be spilled by copy"
                copied.append(i)
        host = copy_out([int(row[i]) for i in copied]) if copied else None
        snap = SpillSnapshot(n_pages=n_mapped, n_live=n_live_tokens,
                             kept=kept, copied=copied, host=host)
        for _, page in kept:
            self.refcount[page] += 1
            self._spill_refs[page] += 1
        self.release(slot)
        return snap

    def can_restore(self, snap: SpillSnapshot) -> bool:
        """True when the fresh pages a restore needs are available now."""
        fresh = snap.n_pages - len(snap.kept)
        return fresh <= len(self._free) + self._n_evictable()

    def restore(self, slot: int, snap: SpillSnapshot) -> list[int]:
        """Re-stitch `slot`'s block table from a spill snapshot.

        Kept pages return to their original table positions (the snapshot's
        reference converts into the slot's — contents were never touched).
        Every other position gets a fresh page; the ids at the snapshot's
        `copied` positions are returned in order so the caller can scatter
        the host KV back in. Dead-tail positions get fresh (garbage) pages
        too — they sit beyond the fill count, masked by construction, same
        as a normal allocation."""
        assert np.all(self.tables[slot] == -1), f"slot {slot} already mapped"
        fresh_n = snap.n_pages - len(snap.kept)
        if fresh_n > len(self._free) + self._n_evictable():
            raise RuntimeError(f"page pool exhausted on restore: need "
                               f"{fresh_n} fresh, free {len(self._free)}")
        kept_pos = {i for i, _ in snap.kept}
        copied_pos = set(snap.copied)
        for i, page in snap.kept:
            # snapshot ref -> slot ref: net refcount unchanged
            self.tables[slot, i] = page
            self._spill_refs[page] -= 1
            assert self._spill_refs[page] >= 0, "spill ref over-released"
        out = []
        for i in range(snap.n_pages):
            if i in kept_pos:
                continue
            if not self._free:
                self._evict_one()
            page = self._free.pop()
            self.refcount[page] += 1
            self.tables[slot, i] = page
            if i in copied_pos:
                out.append(page)
        # out[] aligns with snap.copied: both ascend by table position
        snap.restored = out
        return out

    def discard_spill(self, snap: SpillSnapshot) -> None:
        """Drop a spill snapshot without restoring it (the preempted request
        was shed/cancelled): release the snapshot's kept-page references so
        shared pages stop being pinned. The copied host payload just gets
        garbage-collected with the snapshot."""
        for _, page in snap.kept:
            self._spill_refs[page] -= 1
            assert self._spill_refs[page] >= 0, "spill ref over-released"
            self.refcount[page] -= 1
            assert self.refcount[page] >= 0, f"page {page} over-released"
            if self.refcount[page] == 0:
                self._free.append(int(page))
        snap.kept = []

    # --------------------------------------------------- fault injection
    def hold(self, n: int) -> list[int]:
        """Pin up to `n` free pages (fault injection: simulated exhaustion).

        Held pages leave the free list and take a reference, so admission
        sees a genuinely smaller pool; `release_hold` gives them back.
        Returns the pages actually held (the free list may be shorter than
        asked — holding never evicts cached pages)."""
        pages = [self._free.pop() for _ in range(min(n, len(self._free)))]
        for p in pages:
            self.refcount[p] += 1
            self._hold_refs[p] += 1
        return pages

    def release_hold(self, pages: list[int]) -> None:
        """Return pages pinned by `hold` to the free list."""
        for p in pages:
            self._hold_refs[p] -= 1
            assert self._hold_refs[p] >= 0, "hold ref over-released"
            self.refcount[p] -= 1
            assert self.refcount[p] >= 0, f"page {p} over-released"
            if self.refcount[p] == 0:
                self._free.append(int(p))

    # ------------------------------------------------- snapshot / restore
    def state_dict(self) -> dict:
        """Full allocator state for engine snapshots. The free list keeps
        its LIFO *order* (allocation order after restore must match an
        uninterrupted run for bit-identical replay), and the prefix index
        keeps its LRU insertion order for the same reason."""
        return {
            "free": list(self._free),
            "tables": self.tables.copy(),
            "refcount": self.refcount.copy(),
            "spill_refs": self._spill_refs.copy(),
            "hold_refs": self._hold_refs.copy(),
            "generation": self.generation,
            # insertion-ordered: (hex key, page) pairs reproduce the LRU
            "prefix_index": [(k.hex(), int(p))
                             for k, p in self._prefix_index.items()],
            "parent": [(k.hex(), None if p is None else p.hex())
                       for k, p in self._parent.items()],
            "children": [(k.hex(), int(n))
                         for k, n in self._children.items()],
        }

    def load_state_dict(self, state: dict) -> None:
        self._free = [int(p) for p in state["free"]]
        self.tables = np.asarray(state["tables"], np.int32).copy()
        self.refcount = np.asarray(state["refcount"], np.int32).copy()
        self._spill_refs = np.asarray(state["spill_refs"], np.int32).copy()
        self._hold_refs = np.asarray(state["hold_refs"], np.int32).copy()
        self.generation = int(state["generation"])
        self._prefix_index = OrderedDict(
            (bytes.fromhex(k), int(p)) for k, p in state["prefix_index"])
        self._parent = {bytes.fromhex(k):
                        (None if p is None else bytes.fromhex(p))
                        for k, p in state["parent"]}
        self._children = {bytes.fromhex(k): int(n)
                          for k, n in state["children"]}

    def check_invariants(self) -> None:
        """Assert the refcount/free-list/index bookkeeping is consistent:
        every page's refcount equals its holder count, the free list is
        disjoint from held/cached pages, and no page is lost or duplicated
        (conservation: free + referenced = n_pages - 1)."""
        held = self.tables[self.tables >= 0].astype(np.int64)
        counts = np.bincount(held, minlength=self.spec.n_pages)
        for page in self._prefix_index.values():
            counts[page] += 1
        assert np.all(self._spill_refs >= 0), "negative spill refcount"
        assert np.all(self._hold_refs >= 0), "negative hold refcount"
        counts = counts + self._spill_refs + self._hold_refs
        assert np.all(self.refcount >= 0), "negative refcount"
        assert np.array_equal(self.refcount, counts), \
            "refcounts out of sync with holders"
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate free-list entries"
        referenced = {int(p) for p in np.nonzero(counts)[0]}
        assert not (free & referenced), "page both free and referenced"
        assert SCRATCH_PAGE not in free and SCRATCH_PAGE not in referenced
        assert len(free) + len(referenced) == self.spec.n_pages - 1, \
            "pages lost or duplicated"
        assert set(self._parent) == set(self._prefix_index), \
            "parent links out of sync with index entries"
        children: dict = {}
        for par in self._parent.values():
            if par is not None:
                children[par] = children.get(par, 0) + 1
        assert children == self._children, "cached-child counts out of sync"


# ------------------------------------------------------------- jit helpers

POOL_KEYS = ("k_pool", "v_pool", "k_scale_pool", "v_scale_pool")


def pool_head_dim(key: str, ndim: int) -> int:
    """Index of the kv-head dim in a paged pool leaf: value pools are
    (..., P, page, KVH, hd), scale pools (..., P, page, KVH). The single
    place this layout rule lives — the TP placement specs below and the
    engine's placement asserts/report all consult it."""
    return ndim - 2 if key in ("k_pool", "v_pool") else ndim - 1


def gather_pages(pool: jnp.ndarray, block_table: jnp.ndarray) -> jnp.ndarray:
    """pool: (P, ps, ...); block_table: (S, maxp) -> (S, maxp*ps, ...)."""
    s, mp = block_table.shape
    ps = pool.shape[1]
    out = pool[jnp.maximum(block_table, 0)]            # (S, maxp, ps, ...)
    return out.reshape((s, mp * ps) + pool.shape[2:])


def gather_dequant_pages(pool: jnp.ndarray, scale_pool: jnp.ndarray,
                         block_table: jnp.ndarray, dtype) -> jnp.ndarray:
    """Gather + dequant for an int8 pool in one helper: (P, ps, ...) int8
    values and (P, ps, ..) scales -> (S, maxp*ps, ...) in `dtype`.

    One call per pool (two per decode step — K, V) replaces the former
    four ``gather_pages`` calls + ``_dequant_kv``: the page index is
    computed once and the value/scale reads and the dequant sit in a
    single expression XLA can fuse, with the pool layout invariant (scales
    ride the same block table) kept in one place. The *bandwidth* win for
    int8 decode lives in the fused kernel (kernels/paged_attention.py);
    this is the gather/oracle path's tidier equivalent of the same read."""
    s, mp = block_table.shape
    ps = pool.shape[1]
    idx = jnp.maximum(block_table, 0)                  # (S, maxp), once
    vals = pool[idx]                                   # (S, maxp, ps, ...)
    out = vals.astype(jnp.float32) * scale_pool[idx][..., None]
    return out.astype(dtype).reshape((s, mp * ps) + pool.shape[2:])


def contiguous_positions(kv_len: jnp.ndarray, width: int) -> jnp.ndarray:
    """kv_len: (S,) per-slot fill counts -> (S, width) positions, -1 beyond.

    Paged slots always hold positions 0..len-1 contiguously, so the mask
    positions are a function of the fill count, not stored state."""
    ar = jnp.arange(width, dtype=jnp.int32)[None, :]
    return jnp.where(ar < kv_len[:, None], ar, -1)


def prefill_page_index(bt_rows: jnp.ndarray, positions: jnp.ndarray,
                       page_size: int):
    """Map a prefill batch's prompt positions to (page, offset) indices.

    bt_rows: (B, maxp) the admitted slots' block tables; positions: (B, L)
    absolute positions, -1 for left padding. Pads route to the scratch
    page. Returns (B, L) pages and offsets.
    """
    valid = positions >= 0
    idx = jnp.clip(jnp.where(valid, positions, 0) // page_size, 0,
                   bt_rows.shape[1] - 1)
    pages = jnp.where(valid,
                      jnp.maximum(jnp.take_along_axis(bt_rows, idx, axis=1),
                                  0),
                      SCRATCH_PAGE)
    offs = jnp.where(valid, positions % page_size, 0)
    return pages, offs
