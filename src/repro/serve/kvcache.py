"""Paged KV cache: fixed page pool + per-slot block tables.

Replaces the dense ``(B, max_len, ...)`` decode cache with a pool of
fixed-size pages shared by all serving slots. Each slot owns a block table —
a row of page indices — and attention reads gather the slot's pages back
into a contiguous ``(S, n_pages_read * page_size, ...)`` view. Because a
slot's cache is always the contiguous positions ``0..len-1`` (prompt then
decoded tokens), the position mask is derived from the per-slot fill count
alone — no position pool is stored, and recycled pages need no
invalidation: stale entries beyond ``len`` are masked by construction.

All shapes are compile-time constants (pool size, page size, table width),
so the jitted prefill/decode steps never recompile as requests come and go;
the engine buckets the *read* width (pow2 pages over the deepest live slot)
so shallow traffic doesn't pay full-depth attention.

Page 0 is a reserved scratch page: idle slots (and padded prompt positions)
write there, and nothing ever reads it. The allocator itself is host-side
(`PagePool`); only the gather/scatter helpers below run under jit.

Layering note: repro.models.{attention,mla,blocks} import this module, so
it must stay dependency-free — importing anything from repro.models (or
repro.serve.engine) here would create a package cycle.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

SCRATCH_PAGE = 0


@dataclasses.dataclass(frozen=True)
class PageSpec:
    """Compile-time geometry of the page pool."""

    n_pages: int          # total pages, including the reserved scratch page
    page_size: int        # tokens per page
    max_pages: int        # block-table width (pages a single slot may hold)

    @property
    def max_len(self) -> int:
        return self.max_pages * self.page_size

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)


def default_page_spec(n_slots: int, max_len: int,
                      page_size: int = 16) -> PageSpec:
    """Fully-provisioned pool: every slot can hold max_len tokens."""
    max_pages = -(-max_len // page_size)
    return PageSpec(n_pages=1 + n_slots * max_pages, page_size=page_size,
                    max_pages=max_pages)


class PagePool:
    """Host-side page allocator and per-slot block tables.

    Pages are owned by exactly one slot from admission to retirement, so
    device-side scatters never collide (idle slots all target the scratch
    page, whose contents are never read).
    """

    def __init__(self, spec: PageSpec, n_slots: int):
        self.spec = spec
        self.n_slots = n_slots
        self._free = list(range(spec.n_pages - 1, SCRATCH_PAGE, -1))
        self.tables = np.full((n_slots, spec.max_pages), -1, np.int32)

    @property
    def n_free(self) -> int:
        return len(self._free)

    def can_alloc(self, n_tokens: int) -> bool:
        return self.spec.pages_for(n_tokens) <= len(self._free)

    def alloc(self, slot: int, n_tokens: int) -> None:
        """Give `slot` enough pages for n_tokens. Caller checks can_alloc."""
        need = self.spec.pages_for(n_tokens)
        if need > len(self._free):
            raise RuntimeError(f"page pool exhausted: need {need}, "
                               f"free {len(self._free)}")
        if need > self.spec.max_pages:
            raise ValueError(f"request needs {need} pages > block-table "
                             f"width {self.spec.max_pages}")
        assert np.all(self.tables[slot] == -1), f"slot {slot} already mapped"
        pages = [self._free.pop() for _ in range(need)]
        self.tables[slot, :need] = pages

    def release(self, slot: int) -> None:
        """Return all of `slot`'s pages to the free list."""
        held = self.tables[slot]
        self._free.extend(int(p) for p in held if p >= 0)
        self.tables[slot] = -1


# ------------------------------------------------------------- jit helpers

def gather_pages(pool: jnp.ndarray, block_table: jnp.ndarray) -> jnp.ndarray:
    """pool: (P, ps, ...); block_table: (S, maxp) -> (S, maxp*ps, ...)."""
    s, mp = block_table.shape
    ps = pool.shape[1]
    out = pool[jnp.maximum(block_table, 0)]            # (S, maxp, ps, ...)
    return out.reshape((s, mp * ps) + pool.shape[2:])


def gather_dequant_pages(pool: jnp.ndarray, scale_pool: jnp.ndarray,
                         block_table: jnp.ndarray, dtype) -> jnp.ndarray:
    """Gather + dequant for an int8 pool in one helper: (P, ps, ...) int8
    values and (P, ps, ..) scales -> (S, maxp*ps, ...) in `dtype`.

    One call per pool (two per decode step — K, V) replaces the former
    four ``gather_pages`` calls + ``_dequant_kv``: the page index is
    computed once and the value/scale reads and the dequant sit in a
    single expression XLA can fuse, with the pool layout invariant (scales
    ride the same block table) kept in one place. The *bandwidth* win for
    int8 decode lives in the fused kernel (kernels/paged_attention.py);
    this is the gather/oracle path's tidier equivalent of the same read."""
    s, mp = block_table.shape
    ps = pool.shape[1]
    idx = jnp.maximum(block_table, 0)                  # (S, maxp), once
    vals = pool[idx]                                   # (S, maxp, ps, ...)
    out = vals.astype(jnp.float32) * scale_pool[idx][..., None]
    return out.astype(dtype).reshape((s, mp * ps) + pool.shape[2:])


def contiguous_positions(kv_len: jnp.ndarray, width: int) -> jnp.ndarray:
    """kv_len: (S,) per-slot fill counts -> (S, width) positions, -1 beyond.

    Paged slots always hold positions 0..len-1 contiguously, so the mask
    positions are a function of the fill count, not stored state."""
    ar = jnp.arange(width, dtype=jnp.int32)[None, :]
    return jnp.where(ar < kv_len[:, None], ar, -1)


def prefill_page_index(bt_rows: jnp.ndarray, positions: jnp.ndarray,
                       page_size: int):
    """Map a prefill batch's prompt positions to (page, offset) indices.

    bt_rows: (B, maxp) the admitted slots' block tables; positions: (B, L)
    absolute positions, -1 for left padding. Pads route to the scratch
    page. Returns (B, L) pages and offsets.
    """
    valid = positions >= 0
    idx = jnp.clip(jnp.where(valid, positions, 0) // page_size, 0,
                   bt_rows.shape[1] - 1)
    pages = jnp.where(valid,
                      jnp.maximum(jnp.take_along_axis(bt_rows, idx, axis=1),
                                  0),
                      SCRATCH_PAGE)
    offs = jnp.where(valid, positions % page_size, 0)
    return pages, offs
