"""Deterministic fault injection for the continuous-batching engine.

Chaos testing is only useful when a failing run can be replayed exactly:
every fault here is pinned to an engine *step index* (``step()`` call
count, which under the virtual clock is a pure function of the trace), so
a `FaultPlan` turns "the server fell over under load" into a seeded,
step-indexed schedule that reproduces bit-for-bit on every machine. The
engine consumes the plan inside ``step()`` — see
``ContinuousEngine._apply_faults`` — and each fault kind exercises one
graceful-degradation path:

  nan_logits      poison one slot's decode logits with NaN; the isfinite
                  sentinel in the decode scan must quarantine that slot
                  (retire with ``error``, free pages) without perturbing
                  co-batched slots' tokens.
  pool_exhaust    pin free pages for a few steps so admission sees a full
                  pool; scheduling must degrade (queue/preempt), never
                  crash, and the pages come back on schedule.
  step_exception  raise ``FaultInjected`` out of ``step()`` — a simulated
                  process crash. ``run_resilient`` below rebuilds the
                  engine and resumes from the last snapshot.
  spill_corrupt   flip bytes in the next spill snapshot's host payload;
                  the checksum taken at spill time must catch it on
                  restore and quarantine the request instead of resuming
                  a stream on garbage KV.
  latency_spike   jump the virtual clock, aging every queued request at
                  once (deadline shedding and aging promotion both fire).
  kernel_fault    fail the next fused decode dispatch; the engine must
                  fall back fused -> gather paged attention and keep the
                  token stream identical.

The driver (`run_resilient`) owns the plan across crashes: a
`step_exception` that fired is dropped from the plan handed to the
rebuilt engine — exactly like a real crash, which does not repeat just
because the process restarted — while every other fault kind stays and
re-fires deterministically when the restored engine replays its steps.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

FAULT_KINDS = ("nan_logits", "pool_exhaust", "step_exception",
               "spill_corrupt", "latency_spike", "kernel_fault")


class FaultInjected(RuntimeError):
    """An injected step_exception — the simulated process crash."""

    def __init__(self, fault: "Fault"):
        super().__init__(f"injected fault: {fault}")
        self.fault = fault


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled failure.

    `step` is the engine step index (``n_steps_total``) at which it fires.
    `slot` targets nan_logits (-1 = slot 0 at fire time); `pages` and
    `duration` parameterize pool_exhaust (pages pinned, steps held) and
    latency_spike (virtual-time jump)."""

    step: int
    kind: str
    slot: int = -1
    pages: int = 0
    duration: int = 0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {FAULT_KINDS}")


class FaultPlan:
    """An immutable, deterministically-ordered fault schedule."""

    def __init__(self, faults: Sequence[Fault] = (),
                 seed: Optional[int] = None):
        self.faults = tuple(sorted(
            faults, key=lambda f: (f.step, FAULT_KINDS.index(f.kind),
                                   f.slot, f.pages, f.duration)))
        self.seed = seed

    def __len__(self) -> int:
        return len(self.faults)

    def __iter__(self):
        return iter(self.faults)

    def __repr__(self) -> str:
        return f"FaultPlan(seed={self.seed}, n={len(self.faults)})"

    def at(self, step: int) -> list[Fault]:
        """Faults scheduled for engine step `step`, in canonical order."""
        return [f for f in self.faults if f.step == step]

    def drop(self, fault: Fault) -> "FaultPlan":
        """A new plan without `fault` (one occurrence) — how the crash
        driver retires a step_exception that already fired."""
        rest = list(self.faults)
        rest.remove(fault)
        return FaultPlan(rest, seed=self.seed)

    @classmethod
    def seeded(cls, seed: int, *, n_steps: int = 64, n_slots: int = 8,
               n_faults: int = 6,
               kinds: Sequence[str] = ("nan_logits", "pool_exhaust",
                                       "latency_spike", "kernel_fault",
                                       "spill_corrupt"),
               crashes: int = 0) -> "FaultPlan":
        """Draw a reproducible schedule: `n_faults` failures of the given
        kinds over the first `n_steps` engine steps, plus `crashes`
        step_exceptions (separate knob — they need a crash-recovery driver,
        so plain replay callers get none by default)."""
        rng = np.random.default_rng(seed)
        faults = []
        for _ in range(n_faults):
            kind = str(rng.choice(list(kinds)))
            faults.append(Fault(
                step=int(rng.integers(1, max(2, n_steps))), kind=kind,
                slot=int(rng.integers(0, n_slots)),
                pages=int(rng.integers(1, 9)),
                duration=int(rng.integers(1, 5))))
        for _ in range(crashes):
            faults.append(Fault(step=int(rng.integers(1, max(2, n_steps))),
                                kind="step_exception"))
        return cls(faults, seed=seed)


def run_resilient(build_engine: Callable[[], object], trace, *,
                  faults: Optional[FaultPlan] = None,
                  snapshot_every: int = 8, store_dir: Optional[str] = None,
                  max_steps: int = 200_000) -> dict:
    """Crash-tolerant trace replay: snapshot periodically, and when a step
    raises `FaultInjected` (the simulated crash), rebuild the engine from
    scratch and restore the last snapshot — in-flight work replays from
    the checkpoint with bit-identical tokens.

    `build_engine` must construct a fresh engine identical to the one that
    crashed (same params/config/geometry); `store_dir`, when given, routes
    every snapshot through ``checkpoint.store.save_snapshot`` /
    ``load_snapshot`` so the disk round trip is exercised too. Returns the
    traffic report plus crash/snapshot accounting."""
    from repro.serve.traffic import summarize

    plan = faults if faults is not None else FaultPlan()
    eng = build_engine()
    eng.faults = plan
    for it in trace:
        eng.submit(it.prompt, max_new=it.max_new, arrival=it.arrival,
                   priority=it.priority,
                   deadline=getattr(it, "deadline", None))
    snap = eng.snapshot()      # step-0 checkpoint: a crash before the
    #                            first periodic snapshot is still recoverable
    n_crashes = n_snapshots = steps = 0
    while not eng.sched.all_done():
        if steps >= max_steps:
            raise RuntimeError(f"resilient loop exceeded {max_steps} steps")
        steps += 1
        try:
            eng.step(float(eng.t))
            eng.t += 1
        except FaultInjected as e:
            plan = plan.drop(e.fault)
            n_crashes += 1
            eng = build_engine()
            eng.faults = plan
            eng.restore(snap)
            continue
        if snapshot_every and steps % snapshot_every == 0:
            snap = eng.snapshot()
            if store_dir is not None:
                from repro.checkpoint.store import (load_snapshot,
                                                    save_snapshot)
                save_snapshot(store_dir, snap)
                snap = load_snapshot(store_dir)
            n_snapshots += 1
    done = sorted(eng.sched.drain_finished(), key=lambda r: r.rid)
    report = summarize(done)
    report["scheduler"] = eng.sched.stats()
    report["spill"] = {"spilled_pages": eng.n_spilled_pages,
                       "restored_pages": eng.n_restored_pages}
    report["faults"] = eng.fault_stats()
    # `done` (not the objects submit returned) is authoritative: after a
    # crash the restored engine rebuilt its Request objects from the
    # snapshot, so pre-crash handles go stale
    report["requests"] = done
    return {"engine": eng, "report": report, "requests": done,
            "n_crashes": n_crashes, "n_snapshots": n_snapshots}
