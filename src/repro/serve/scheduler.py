"""Continuous-batching scheduler: admission queue + slot/page bookkeeping.

Holds per-request state (prompt, emitted tokens, done, timing) and decides
which queued request enters which slot. Admission is FIFO with head-of-line
blocking: a request is admitted only when a slot is free AND the page pool
can cover its whole budget (prompt + max_new tokens), so a running request
can never hit pool exhaustion mid-decode. Pages return to the pool the
moment a request retires. A request whose budget exceeds the block-table
width is *structurally* un-admittable — it is rejected at the queue head
(``rejected=True``) rather than blocking the queue forever or raising
mid-admit.

With ``prefix_share=True`` admission consults the pool's prefix index:
pages covering the prompt's cached full-page prefix are stitched into the
slot's block table by reference, the request is admitted against only its
non-shared page budget, and ``req.n_shared`` tells the engine how many
prompt tokens are already in cache (its prefill starts there).

This module is model-free — the execution core (jitted prefill/decode over
the paged cache) lives in serve/engine.py.
"""
from __future__ import annotations

import bisect
import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from repro.serve.kvcache import PagePool


@dataclasses.dataclass
class Request:
    """One generation request and its lifecycle state."""

    rid: int
    prompt: np.ndarray              # (L,) int32
    max_new: int
    arrival: float = 0.0
    # lifecycle (filled by the scheduler/engine)
    tokens: list = dataclasses.field(default_factory=list)
    slot: int = -1
    done: bool = False
    rejected: bool = False          # structurally un-admittable (too wide)
    n_shared: int = 0               # prompt tokens served from the prefix cache
    admitted_at: Optional[float] = None
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None

    @property
    def n_prompt(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def budget(self) -> int:
        """Worst-case tokens this request may occupy in the cache."""
        return self.n_prompt + self.max_new


class Scheduler:
    """Admission queue over a fixed slot pool backed by a PagePool.

    Admission accounting is deliberately *tensor-parallel-invariant*: pages
    and budgets are counted in tokens, and under TP serving the KV pools
    shard along the kv-head dim only — every shard holds its head slice of
    every page, so the page count, block tables, and whole-budget gating
    are identical on every shard and the scheduler needs no TP awareness.
    `tp` is accepted purely to pin that contract with an assert (the engine
    separately verifies on the live buffers that no pool leaf is sharded
    along a page axis).
    """

    def __init__(self, n_slots: int, pool: PagePool,
                 prefix_share: bool = False, tp: int = 1):
        # the page budget must not scale with tp: admission math is host-
        # side and token-denominated, so the block tables it hands the
        # engine must themselves be host arrays (replicated onto every
        # shard), never device-sharded state. If a future placement splits
        # the page axis, admission needs per-shard budgets and this module
        # is the wrong place to hide that. (The engine separately asserts
        # on the live pool buffers that no page axis is sharded.)
        assert tp >= 1, tp
        assert type(pool.tables) is np.ndarray, \
            "block tables must stay host-side (shard-invariant) under TP"
        self.n_slots = n_slots
        self.pool = pool
        self.tp = tp
        self.prefix_share = prefix_share
        self._pending: list[Request] = []     # submitted, sorted by arrival
        self.queue: deque[Request] = deque()  # arrived, waiting for a slot
        self.slots: list[Optional[Request]] = [None] * n_slots
        self._retired: list[Request] = []
        # (rid, pool generation) -> shared pages of the blocked queue head,
        # so a head-of-line-blocked request doesn't re-hash its whole
        # prompt on every tick it spends waiting for pages
        self._hol_lookup: Optional[tuple[tuple[int, int], list[int]]] = None

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        # insort (not re-sort): O(log n) to find the spot instead of an
        # O(n log n) full sort per call; ties keep submission order
        bisect.insort(self._pending, req, key=lambda r: r.arrival)

    def _ingest(self, now: float) -> None:
        i = bisect.bisect_right(self._pending, now,
                                key=lambda r: r.arrival)
        if i:
            self.queue.extend(self._pending[:i])
            del self._pending[:i]

    # ---------------------------------------------------------- admission
    def admit(self, now: float = 0.0) -> list[tuple[int, Request]]:
        """Admit FIFO requests into free slots while pages last.

        Never raises for a submitted request: a budget wider than one
        block-table row can never be satisfied, so such a request is
        retired as ``rejected`` (instead of blocking the queue head
        forever or letting ``alloc`` raise mid-admit) and admission moves
        on to the next request."""
        self._ingest(now)
        out = []
        free = [s for s, r in enumerate(self.slots) if r is None]
        while self.queue and free:
            req = self.queue[0]
            if (self.pool.spec.pages_for(req.budget)
                    > self.pool.spec.max_pages):
                self.queue.popleft()          # structurally impossible
                req.rejected = True
                req.done = True
                req.finished_at = now
                self._retired.append(req)
                continue
            shared: list[int] = []
            if self.prefix_share:
                state = (req.rid, self.pool.generation)
                if self._hol_lookup and self._hol_lookup[0] == state:
                    shared = self._hol_lookup[1]
                else:
                    # safe to cache across blocked ticks: eviction only
                    # runs inside alloc, and new entries bump generation
                    shared = self.pool.lookup_prefix(req.prompt)
                    self._hol_lookup = (state, shared)
            if not self.pool.can_alloc(req.budget, shared_pages=shared):
                break                         # head-of-line blocks on pages
            self.queue.popleft()
            slot = free.pop(0)
            self.pool.alloc(slot, req.budget, shared_pages=shared)
            req.n_shared = len(shared) * self.pool.spec.page_size
            self.slots[slot] = req
            req.slot = slot
            req.admitted_at = now
            out.append((slot, req))
        return out

    def retire(self, slot: int, now: float = 0.0) -> None:
        req = self.slots[slot]
        assert req is not None
        self.pool.release(slot)
        self.slots[slot] = None
        req.done = True
        req.finished_at = now
        req.slot = -1
        self._retired.append(req)

    # ------------------------------------------------------------- status
    def active_slots(self) -> list[int]:
        return [s for s, r in enumerate(self.slots) if r is not None]

    def all_done(self) -> bool:
        return (not self._pending and not self.queue
                and all(r is None for r in self.slots))

    @property
    def finished(self) -> list[Request]:
        return list(self._retired)

    def drain_finished(self) -> list[Request]:
        """Pop everything retired since the last drain (engine.run uses this
        so back-to-back drains don't re-report earlier batches)."""
        out, self._retired = self._retired, []
        return out
