"""Continuous-batching scheduler: admission queue + slot/page bookkeeping.

Holds per-request state (prompt, emitted tokens, done, timing) and decides
which queued request enters which slot. Admission is priority-ordered FIFO
over SLO classes (0 = ``interactive``, 1 = ``batch``): within a class
requests admit in arrival order, the interactive queue head is always
considered before the batch head, and an *aging* rule promotes a batch
request to interactive standing once it has waited ``age_promote`` time
units — so sustained interactive pressure can delay batch work but never
starve it forever. A request is admitted only when a slot is free AND the
page pool can cover its whole budget (prompt + max_new tokens), so a
running request can never hit pool exhaustion mid-decode. A request whose
budget exceeds the block-table width is *structurally* un-admittable — it
is retired as ``rejected`` rather than blocking its queue forever or
raising mid-admit.

With a ``preempt_hook`` installed (the engine wires its KV spill here), a
*true* interactive head that cannot be admitted — no free slot, or not
enough pages — may evict a running batch request: the victim's KV pages
spill (owned pages to host RAM, shared prefix pages stay resident by
reference — see kvcache.SpillSnapshot), the slot frees, and the victim
re-queues at the *front* of its class carrying its progress, to be
re-admitted by ``restore`` when capacity returns. Aged batch requests gain
admission standing but never preemption rights, so batch work cannot churn
batch work.

With ``prefix_share=True`` admission consults the pool's prefix index:
pages covering the prompt's cached full-page prefix are stitched into the
slot's block table by reference, the request is admitted against only its
non-shared page budget, and ``req.n_shared`` tells the engine how many
prompt tokens are already in cache (its prefill starts there).

This module is model-free — the execution core (jitted prefill/decode over
the paged cache, and the actual KV spill/restore data movement) lives in
serve/engine.py.
"""
from __future__ import annotations

import bisect
import dataclasses
from collections import deque
from typing import Callable, Optional

import numpy as np

from repro.serve.kvcache import PagePool, SpillSnapshot

INTERACTIVE, BATCH = 0, 1
N_CLASSES = 2


@dataclasses.dataclass
class Request:
    """One generation request and its lifecycle state."""

    rid: int
    prompt: np.ndarray              # (L,) int32
    max_new: int
    arrival: float = 0.0
    priority: int = INTERACTIVE     # SLO class: 0 interactive, 1 batch
    deadline: Optional[float] = None   # absolute; past it the request is
    #                                    shed from the queue or cancelled
    #                                    mid-run instead of finishing
    # lifecycle (filled by the scheduler/engine)
    tokens: list = dataclasses.field(default_factory=list)
    slot: int = -1
    done: bool = False
    rejected: bool = False          # structurally un-admittable (too wide)
    shed: bool = False              # dropped from the queue past deadline
    cancelled: bool = False         # evicted mid-run past deadline
    error: Optional[str] = None     # quarantine reason (non-finite logits,
    #                                 corrupted spill snapshot, ...)
    n_shared: int = 0               # prompt tokens served from the prefix cache
    admitted_at: Optional[float] = None   # FIRST admission (not re-admits)
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    # preemption lifecycle
    n_preempts: int = 0             # times this request was evicted mid-run
    spill: Optional[SpillSnapshot] = None   # set while preempted
    prefill_done: bool = False      # had it reached decode when preempted?
    queue_wait: float = 0.0         # total time spent waiting for a slot,
    #                                 accumulated across re-admissions
    _enqueued_at: float = 0.0       # start of the current waiting stretch

    @property
    def n_prompt(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def budget(self) -> int:
        """Worst-case tokens this request may occupy in the cache."""
        return self.n_prompt + self.max_new

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token, from arrival (None until one is emitted)."""
        if self.first_token_at is None:
            return None
        return self.first_token_at - self.arrival

    @property
    def tpot(self) -> Optional[float]:
        """Mean time per output token after the first."""
        if (self.first_token_at is None or self.finished_at is None
                or len(self.tokens) < 2):
            return None
        return ((self.finished_at - self.first_token_at)
                / (len(self.tokens) - 1))


# plain-value request (de)serialization for engine snapshots — every field
# except the two needing conversion (prompt array, spill snapshot)
_REQ_SCALARS = ("rid", "max_new", "arrival", "priority", "deadline", "slot",
                "done", "rejected", "shed", "cancelled", "error", "n_shared",
                "admitted_at", "first_token_at", "finished_at", "n_preempts",
                "prefill_done", "queue_wait", "_enqueued_at")


def _req_state(req: Request) -> dict:
    d = {f: getattr(req, f) for f in _REQ_SCALARS}
    d["prompt"] = np.asarray(req.prompt, np.int32)
    d["tokens"] = list(req.tokens)
    s = req.spill
    d["spill"] = None if s is None else {
        "n_pages": s.n_pages, "n_live": s.n_live,
        "kept": [(int(i), int(p)) for i, p in s.kept],
        "copied": [int(i) for i in s.copied],
        "host": s.host, "checksum": s.checksum,
    }
    return d


def _req_from_state(d: dict) -> Request:
    req = Request(rid=int(d["rid"]), prompt=np.asarray(d["prompt"], np.int32),
                  max_new=int(d["max_new"]))
    for f in _REQ_SCALARS:
        setattr(req, f, d[f])
    req.tokens = list(d["tokens"])
    s = d["spill"]
    if s is not None:
        req.spill = SpillSnapshot(
            n_pages=int(s["n_pages"]), n_live=int(s["n_live"]),
            kept=[(int(i), int(p)) for i, p in s["kept"]],
            copied=[int(i) for i in s["copied"]],
            host=s["host"], checksum=s["checksum"])
    return req


class Scheduler:
    """Priority admission over a fixed slot pool backed by a PagePool.

    Admission accounting is deliberately *tensor-parallel-invariant*: pages
    and budgets are counted in tokens, and under TP serving the KV pools
    shard along the kv-head dim only — every shard holds its head slice of
    every page, so the page count, block tables, and whole-budget gating
    are identical on every shard and the scheduler needs no TP awareness.
    `tp` is accepted purely to pin that contract with an assert (the engine
    separately verifies on the live buffers that no pool leaf is sharded
    along a page axis).

    `age_promote`: waiting time (in whatever units `now` uses — scheduler
    ticks under the virtual clock, seconds under a wall clock) after which
    a batch request competes at interactive standing. None disables aging.
    `preempt_hook(slot, req, now)`: engine callback that spills the
    victim's KV and returns its SpillSnapshot; installing it enables
    preemptive eviction.
    """

    def __init__(self, n_slots: int, pool: PagePool,
                 prefix_share: bool = False, tp: int = 1,
                 age_promote: Optional[float] = None,
                 preempt_hook: Optional[
                     Callable[[int, Request, float], SpillSnapshot]] = None):
        # the page budget must not scale with tp: admission math is host-
        # side and token-denominated, so the block tables it hands the
        # engine must themselves be host arrays (replicated onto every
        # shard), never device-sharded state. If a future placement splits
        # the page axis, admission needs per-shard budgets and this module
        # is the wrong place to hide that. (The engine separately asserts
        # on the live pool buffers that no page axis is sharded.)
        assert tp >= 1, tp
        assert type(pool.tables) is np.ndarray, \
            "block tables must stay host-side (shard-invariant) under TP"
        self.n_slots = n_slots
        self.pool = pool
        self.tp = tp
        self.prefix_share = prefix_share
        self.age_promote = age_promote
        self.preempt_hook = preempt_hook
        self._pending: list[Request] = []     # submitted, sorted by arrival
        self.queues: list[deque[Request]] = [deque() for _ in range(N_CLASSES)]
        self.slots: list[Optional[Request]] = [None] * n_slots
        self._retired: list[Request] = []
        # admission/preemption event log: (event, now, rid, slot) tuples in
        # decision order — "admit" | "restore" | "preempt" | "reject".
        # The trace-replay tests assert exact sequences against this.
        self.events: list[tuple] = []
        self.n_preemptions = 0
        self.n_restored = 0
        self.n_rejected = 0
        self.n_finished_ok = 0          # retired complete (not rejected)
        self.n_finished_preempted = 0   # ... of which were evicted >= once
        # deadline / fault accounting — disjoint from n_finished_ok: a
        # request counts in exactly one of ok/rejected/shed/cancelled/
        # quarantined when it retires
        self.n_shed = 0                 # dropped from the queue past deadline
        self.n_cancelled = 0            # running, cancelled past deadline
        self.n_quarantined = 0          # retired with an error status
        # (rid, pool generation) -> shared pages of the blocked queue head,
        # so a head-of-line-blocked request doesn't re-hash its whole
        # prompt on every tick it spends waiting for pages
        self._hol_lookup: Optional[tuple[tuple[int, int], list[int]]] = None

    # ------------------------------------------------------------- intake
    @property
    def queue(self) -> list[Request]:
        """All waiting requests, in admission-consideration order (class
        then arrival). Kept as the single flat view callers iterate."""
        return [r for q in self.queues for r in q]

    def submit(self, req: Request) -> None:
        if not 0 <= req.priority < N_CLASSES:
            raise ValueError(f"priority must be 0 (interactive) .. "
                             f"{N_CLASSES - 1} (batch), got {req.priority}")
        req._enqueued_at = req.arrival
        # insort (not re-sort): O(log n) to find the spot instead of an
        # O(n log n) full sort per call; ties keep submission order
        bisect.insort(self._pending, req, key=lambda r: r.arrival)

    def _ingest(self, now: float) -> None:
        i = bisect.bisect_right(self._pending, now,
                                key=lambda r: r.arrival)
        if i:
            for req in self._pending[:i]:
                self.queues[req.priority].append(req)
            del self._pending[:i]

    # ---------------------------------------------------------- admission
    def _eff_priority(self, req: Request, now: float) -> int:
        """Class the request competes in *right now*: its own, or
        interactive once it has aged past the promotion threshold."""
        if (self.age_promote is not None
                and now - req._enqueued_at >= self.age_promote):
            return INTERACTIVE
        return req.priority

    def _head(self, now: float, skipped=()) -> Optional[Request]:
        """Best waiting candidate: lowest (effective class, arrival, rid).

        Only queue *heads* compete — admission stays FIFO within a class,
        and an aged batch head with an earlier arrival outranks a fresher
        interactive head (that is what makes aging a starvation-freedom
        guarantee rather than a cosmetic counter). `skipped` classes are
        passed over (the idle-system deadlock valve in admit)."""
        best, best_key = None, None
        for cls, q in enumerate(self.queues):
            if not q or cls in skipped:
                continue
            r = q[0]
            key = (self._eff_priority(r, now), r.arrival, r.rid)
            if best_key is None or key < best_key:
                best, best_key = r, key
        return best

    def _pick_victim(self, candidate: Request, need_pages: bool,
                     exclude=()) -> Optional[int]:
        """Deterministic victim choice for preempting `candidate` in: the
        latest-arriving running request of a strictly lower class (ties
        broken by rid then slot). When the shortage is pages (not slots),
        skip victims whose pages are all shared — spilling them frees
        nothing and would churn KV for no headroom. `exclude` slots are
        never victims: admit() passes the slots it filled *this call*,
        whose requests the engine hasn't started yet — spilling one would
        read slot mirrors the engine never initialized (and for a pending
        restore, snapshot KV that was never scattered back)."""
        best, best_key = None, None
        for slot, req in enumerate(self.slots):
            if req is None or req.priority <= candidate.priority:
                continue
            if slot in exclude:
                continue
            if need_pages and self.pool.slot_owned_pages(slot) == 0:
                continue
            key = (req.arrival, req.rid, slot)
            if best_key is None or key > best_key:
                best, best_key = slot, key
        return best

    def _admit_one(self, req: Request, slot: int, now: float,
                   shared: list[int]) -> None:
        self.queues[req.priority].remove(req)
        req.queue_wait += now - req._enqueued_at
        if req.spill is not None:
            self.pool.restore(slot, req.spill)   # engine re-stitches data
            self.n_restored += 1
            self.events.append(("restore", now, req.rid, slot))
        else:
            self.pool.alloc(slot, req.budget, shared_pages=shared)
            req.n_shared = len(shared) * self.pool.spec.page_size
            req.admitted_at = now
            self.events.append(("admit", now, req.rid, slot))
        self.slots[slot] = req
        req.slot = slot

    def _preempt(self, slot: int, now: float) -> None:
        """Evict the running request in `slot`: the engine hook spills its
        KV (pool bookkeeping included), then the request re-queues at the
        front of its own class, keeping its original arrival so it stays
        ahead of everything that arrived after it."""
        req = self.slots[slot]
        assert req is not None and self.preempt_hook is not None
        req.spill = self.preempt_hook(slot, req, now)
        self.slots[slot] = None
        req.slot = -1
        req.n_preempts += 1
        req._enqueued_at = now
        self.queues[req.priority].appendleft(req)
        self.n_preemptions += 1
        self.events.append(("preempt", now, req.rid, slot))

    def admit(self, now: float = 0.0) -> list[tuple[int, Request]]:
        """Admit waiting requests into free slots while pages last.

        Never raises for a submitted request: a budget wider than one
        block-table row can never be satisfied, so such a request is
        retired as ``rejected`` (instead of blocking the queue head
        forever or letting ``alloc`` raise mid-admit) and admission moves
        on to the next request. Returns (slot, request) pairs in admission
        order; a pair whose request has ``spill`` set is a *restore* — the
        engine must re-stitch the spilled KV before stepping it."""
        self._ingest(now)
        self._shed_expired(now)
        out = []
        skipped: set[int] = set()
        while True:
            req = self._head(now, skipped)
            if req is None:
                break
            if (self.pool.spec.pages_for(req.budget)
                    > self.pool.spec.max_pages):
                self.queues[req.priority].remove(req)  # structurally impossible
                req.rejected = True
                req.done = True
                req.finished_at = now
                self.n_rejected += 1
                self.events.append(("reject", now, req.rid, -1))
                self._retired.append(req)
                continue
            shared: list[int] = []
            if self.prefix_share and req.spill is None:
                state = (req.rid, self.pool.generation)
                if self._hol_lookup and self._hol_lookup[0] == state:
                    shared = self._hol_lookup[1]
                else:
                    # safe to cache across blocked ticks: eviction only
                    # runs inside alloc, and new entries bump generation
                    shared = self.pool.lookup_prefix(req.prompt)
                    self._hol_lookup = (state, shared)
            free = [s for s, r in enumerate(self.slots) if r is None]
            fits = (self.pool.can_restore(req.spill) if req.spill is not None
                    else self.pool.can_alloc(req.budget, shared_pages=shared))
            if free and fits:
                slot = free[0]
                self._admit_one(req, slot, now, shared)
                out.append((slot, req))
                continue
            # blocked: a true interactive head may evict a batch victim.
            # Aged batch heads have admission standing but no preemption
            # rights (batch churning batch buys nothing), and each evicted
            # victim either opens the way or we run out of victims.
            if (self.preempt_hook is not None
                    and req.priority == INTERACTIVE):
                victim = self._pick_victim(req, need_pages=bool(free),
                                           exclude={s for s, _ in out})
                if victim is not None:
                    self._preempt(victim, now)
                    continue
            if not any(r is not None for r in self.slots) and not out:
                # deadlock valve: the whole system is idle, so no retire
                # will ever free the pages this head is waiting for (spill
                # snapshots can pin pages with nothing running). Strict
                # priority blocking would spin forever — let another
                # class's head through instead of stalling the pool.
                skipped.add(req.priority)   # the queue it sits in
                continue
            break                     # head-of-line blocks on slots/pages
        return out

    # -------------------------------------------------- deadlines / faults
    def _shed_expired(self, now: float) -> int:
        """Drop queued requests whose deadline has passed — serving them
        would burn prefill/decode work on answers nobody will read. A shed
        preempted request discards its spill snapshot (releasing the
        kept-page references it pinned); the host payload goes with it."""
        n = 0
        for q in self.queues:
            expired = [r for r in q
                       if r.deadline is not None and now > r.deadline]
            for req in expired:
                q.remove(req)
                if req.spill is not None:
                    self.pool.discard_spill(req.spill)
                    req.spill = None
                req.queue_wait += now - req._enqueued_at
                req.shed = True
                req.done = True
                req.finished_at = now
                self.n_shed += 1
                self.events.append(("shed", now, req.rid, -1))
                self._retired.append(req)
                n += 1
        return n

    def cancel(self, slot: int, now: float) -> None:
        """Cancel the running request in `slot` (deadline passed mid-run):
        free its pages, retire it flagged ``cancelled``. The engine clears
        its own slot mirrors around this call."""
        req = self.slots[slot]
        assert req is not None
        self.pool.release(slot)
        self.slots[slot] = None
        req.slot = -1
        req.cancelled = True
        req.done = True
        req.finished_at = now
        self.n_cancelled += 1
        self.events.append(("cancel", now, req.rid, slot))
        self._retired.append(req)

    def quarantine(self, slot: int, now: float, reason: str) -> None:
        """Retire the request in `slot` with an error status (non-finite
        logits, corrupted spill snapshot): free its pages so the fault
        cannot leak capacity, record the reason, never count it as ok."""
        req = self.slots[slot]
        assert req is not None
        self.pool.release(slot)
        self.slots[slot] = None
        req.slot = -1
        req.error = reason
        req.done = True
        req.finished_at = now
        self.n_quarantined += 1
        self.events.append(("quarantine", now, req.rid, slot))
        self._retired.append(req)

    def retire(self, slot: int, now: float = 0.0) -> None:
        req = self.slots[slot]
        assert req is not None
        self.pool.release(slot)
        self.slots[slot] = None
        req.done = True
        req.finished_at = now
        req.slot = -1
        self.n_finished_ok += 1
        if req.n_preempts:
            self.n_finished_preempted += 1
        self._retired.append(req)

    # ------------------------------------------------------------- status
    def active_slots(self) -> list[int]:
        return [s for s, r in enumerate(self.slots) if r is not None]

    def all_done(self) -> bool:
        return (not self._pending and not any(self.queues)
                and all(r is None for r in self.slots))

    @property
    def finished(self) -> list[Request]:
        return list(self._retired)

    def drain_finished(self) -> list[Request]:
        """Pop everything retired since the last drain (engine.run uses this
        so back-to-back drains don't re-report earlier batches). Rejected
        requests ride along flagged ``rejected``; requests that were
        preempted mid-run carry ``n_preempts`` / accumulated ``queue_wait``
        — `stats()` separates the two populations."""
        out, self._retired = self._retired, []
        return out

    def stats(self) -> dict:
        """Rejected-vs-preempted accounting, cumulative across drains:
        `n_rejected` counts structurally-impossible requests retired
        unserved, `n_finished_preempted` counts requests that completed
        *despite* being evicted mid-run — the two populations a
        drain_finished caller must not conflate."""
        return {
            "n_preemptions": self.n_preemptions,
            "n_restored": self.n_restored,
            "n_rejected": self.n_rejected,
            "n_finished_ok": self.n_finished_ok,
            "n_finished_preempted": self.n_finished_preempted,
            "n_shed": self.n_shed,
            "n_cancelled": self.n_cancelled,
            "n_quarantined": self.n_quarantined,
        }

    # ------------------------------------------------- snapshot / restore
    _COUNTERS = ("n_preemptions", "n_restored", "n_rejected",
                 "n_finished_ok", "n_finished_preempted", "n_shed",
                 "n_cancelled", "n_quarantined")

    def state_dict(self) -> dict:
        """Full scheduler state, by value, for engine snapshots. Requests
        are serialized once (keyed by rid) and every membership list refers
        to them by rid, so identity relations (a request in a slot AND
        mid-prefill) survive the round trip. The head-of-line lookup cache
        is deliberately dropped: it only ever caches a lookup whose
        ``move_to_end`` already ran, and it re-validates against the pool
        generation, so rebuilding it lazily is free and exact."""
        reqs = {}
        for req in self._pending:
            reqs[req.rid] = _req_state(req)
        for q in self.queues:
            for req in q:
                reqs[req.rid] = _req_state(req)
        for req in self.slots:
            if req is not None:
                reqs[req.rid] = _req_state(req)
        for req in self._retired:
            reqs[req.rid] = _req_state(req)
        return {
            "requests": reqs,
            "pending": [r.rid for r in self._pending],
            "queues": [[r.rid for r in q] for q in self.queues],
            "slots": [None if r is None else r.rid for r in self.slots],
            "retired": [r.rid for r in self._retired],
            "events": [tuple(e) for e in self.events],
            "counters": {k: getattr(self, k) for k in self._COUNTERS},
        }

    def load_state_dict(self, state: dict) -> dict:
        """Rebuild scheduler state from `state_dict` output; returns the
        rid -> Request map so the engine can re-link its own views (the
        prefilling set) to the *same* objects."""
        by_rid = {int(rid): _req_from_state(s)
                  for rid, s in state["requests"].items()}
        self._pending = [by_rid[r] for r in state["pending"]]
        self.queues = [deque(by_rid[r] for r in q) for q in state["queues"]]
        self.slots = [None if r is None else by_rid[r]
                      for r in state["slots"]]
        self._retired = [by_rid[r] for r in state["retired"]]
        self.events = [tuple(e) for e in state["events"]]
        for k in self._COUNTERS:
            setattr(self, k, int(state["counters"][k]))
        self._hol_lookup = None
        return by_rid
