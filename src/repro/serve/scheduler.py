"""Continuous-batching scheduler: admission queue + slot/page bookkeeping.

Holds per-request state (prompt, emitted tokens, done, timing) and decides
which queued request enters which slot. Admission is FIFO with head-of-line
blocking: a request is admitted only when a slot is free AND the page pool
can cover its whole budget (prompt + max_new tokens), so a running request
can never hit pool exhaustion mid-decode. Pages return to the pool the
moment a request retires.

This module is model-free — the execution core (jitted prefill/decode over
the paged cache) lives in serve/engine.py.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from repro.serve.kvcache import PagePool


@dataclasses.dataclass
class Request:
    """One generation request and its lifecycle state."""

    rid: int
    prompt: np.ndarray              # (L,) int32
    max_new: int
    arrival: float = 0.0
    # lifecycle (filled by the scheduler/engine)
    tokens: list = dataclasses.field(default_factory=list)
    slot: int = -1
    done: bool = False
    admitted_at: Optional[float] = None
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None

    @property
    def n_prompt(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def budget(self) -> int:
        """Worst-case tokens this request may occupy in the cache."""
        return self.n_prompt + self.max_new


class Scheduler:
    """Admission queue over a fixed slot pool backed by a PagePool."""

    def __init__(self, n_slots: int, pool: PagePool):
        self.n_slots = n_slots
        self.pool = pool
        self._pending: list[Request] = []     # submitted, arrival in future
        self.queue: deque[Request] = deque()  # arrived, waiting for a slot
        self.slots: list[Optional[Request]] = [None] * n_slots
        self._retired: list[Request] = []

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        self._pending.append(req)
        self._pending.sort(key=lambda r: r.arrival)

    def _ingest(self, now: float) -> None:
        while self._pending and self._pending[0].arrival <= now:
            self.queue.append(self._pending.pop(0))

    # ---------------------------------------------------------- admission
    def admit(self, now: float = 0.0) -> list[tuple[int, Request]]:
        """Admit FIFO requests into free slots while pages last."""
        self._ingest(now)
        out = []
        free = [s for s, r in enumerate(self.slots) if r is None]
        while self.queue and free:
            req = self.queue[0]
            if not self.pool.can_alloc(req.budget):
                break                         # head-of-line blocks on pages
            self.queue.popleft()
            slot = free.pop(0)
            self.pool.alloc(slot, req.budget)
            self.slots[slot] = req
            req.slot = slot
            req.admitted_at = now
            out.append((slot, req))
        return out

    def retire(self, slot: int, now: float = 0.0) -> None:
        req = self.slots[slot]
        assert req is not None
        self.pool.release(slot)
        self.slots[slot] = None
        req.done = True
        req.finished_at = now
        req.slot = -1
        self._retired.append(req)

    # ------------------------------------------------------------- status
    def active_slots(self) -> list[int]:
        return [s for s, r in enumerate(self.slots) if r is not None]

    def all_done(self) -> bool:
        return (not self._pending and not self.queue
                and all(r is None for r in self.slots))

    @property
    def finished(self) -> list[Request]:
        return list(self._retired)

    def drain_finished(self) -> list[Request]:
        """Pop everything retired since the last drain (engine.run uses this
        so back-to-back drains don't re-report earlier batches)."""
        out, self._retired = self._retired, []
        return out
