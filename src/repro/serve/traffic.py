"""Deterministic traffic harness for the continuous-batching engine.

Scheduling changes must be assertable, not anecdotal: this module builds
*seeded* arrival traces (Poisson trickle, bursty on/off overload, or
verbatim replay of a recorded trace) and replays them through an engine
under its **virtual clock** — `ContinuousEngine.run()` with no wall clock
ticks `now` once per scheduler step, so every admission, preemption, and
retirement lands at an integer step index that is a pure function of
(trace seed, engine config). The same trace through the same engine gives
the same event log, token streams, and latency numbers on every machine;
tier-1 tests assert exact admission orders against it, and
benchmarks/overload_bench.py measures per-class SLO behaviour on top of
the identical machinery.

Metrics are reported per SLO class (interactive/batch): TTFT and TPOT
percentiles, end-to-end latency, queue wait, preemption counts, and
goodput — completed-request tokens per unit of virtual (or wall) time,
the number that actually degrades when an overloaded FIFO scheduler
head-of-line blocks interactive traffic behind batch work.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

CLASS_NAMES = {0: "interactive", 1: "batch"}


@dataclasses.dataclass
class TraceItem:
    """One request of a traffic trace (engine-agnostic)."""

    arrival: float
    prompt: np.ndarray
    max_new: int
    priority: int = 0
    deadline: Optional[float] = None    # absolute, like `arrival`


def make_trace(*, kind: str = "poisson", n: int = 32, rate: float = 4.0,
               seed: int = 0, vocab_size: int = 256,
               prompt_len: tuple[int, int] = (8, 48),
               max_new: tuple[int, int] = (8, 32),
               batch_frac: float = 0.5,
               burst_len: float = 4.0, idle_len: float = 8.0,
               burst_rate_mult: float = 8.0,
               shared_prefix: int = 0,
               deadline: Optional[float] = None) -> list[TraceItem]:
    """Build a seeded arrival trace.

    kind="poisson": exponential inter-arrivals at `rate`.
    kind="bursty":  on/off overload — arrivals cluster in bursts of
        `burst_len` time units at `rate * burst_rate_mult`, separated by
        idle gaps of `idle_len` (sustained-overload shape: the queue grows
        during a burst faster than slots drain it).
    kind="uniform": n arrivals evenly spaced over n/rate time units (the
        most reproducible shape for regression tests).

    Every `1/batch_frac`-th request (deterministically, not sampled) is
    batch-class so class mix never depends on the draw order; prompt and
    decode lengths come from the seeded rng. `shared_prefix` prepends a
    common system prompt to every request (prefix-cache traffic).
    `deadline` gives every request an SLO of that many time units after
    its arrival — the scheduler sheds/cancels whatever misses it.
    """
    rng = np.random.default_rng(seed)
    if kind == "poisson":
        arrivals = np.cumsum(rng.exponential(1.0 / rate, n))
    elif kind == "uniform":
        arrivals = np.arange(n) / rate
    elif kind == "bursty":
        arrivals, t = [], 0.0
        while len(arrivals) < n:
            burst_end = t + burst_len
            while t < burst_end and len(arrivals) < n:
                t += float(rng.exponential(1.0 / (rate * burst_rate_mult)))
                arrivals.append(t)
            t = burst_end + idle_len
        arrivals = np.asarray(arrivals)
    else:
        raise ValueError(f"unknown trace kind {kind!r}")
    system = rng.integers(0, vocab_size, shared_prefix)
    stride = int(round(1.0 / batch_frac)) if batch_frac > 0 else 0
    items = []
    for i in range(n):
        plen = int(rng.integers(prompt_len[0], prompt_len[1] + 1))
        mnew = int(rng.integers(max_new[0], max_new[1] + 1))
        prompt = np.concatenate([system,
                                 rng.integers(0, vocab_size, plen)])
        prio = 1 if (stride and i % stride == stride - 1) else 0
        items.append(TraceItem(
            arrival=float(arrivals[i]), prompt=prompt, max_new=mnew,
            priority=prio,
            deadline=(None if deadline is None
                      else float(arrivals[i]) + deadline)))
    return items


def replay(engine, trace: Sequence[TraceItem], *, clock=None,
           max_steps: int = 200_000) -> dict:
    """Submit a trace and drain it; returns the metrics report.

    With `clock=None` the engine's virtual clock drives time (fully
    deterministic — one step() call per time unit); pass a wall clock
    callable for real-time measurement. The report carries the drained
    requests under "requests" so callers can assert token streams."""
    reqs = [engine.submit(it.prompt, max_new=it.max_new,
                          arrival=it.arrival, priority=it.priority,
                          deadline=it.deadline)
            for it in trace]
    done = engine.run(clock=clock, max_steps=max_steps)
    makespan = max((r.finished_at for r in done if r.finished_at is not None),
                   default=0.0)
    report = summarize(done, makespan=makespan)
    report["scheduler"] = engine.sched.stats()
    report["spill"] = {"spilled_pages": engine.n_spilled_pages,
                       "restored_pages": engine.n_restored_pages}
    if hasattr(engine, "fault_stats"):
        report["faults"] = engine.fault_stats()
    report["requests"] = reqs
    return report


def _pct(xs: list, q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else float("nan")


def _failed(r) -> bool:
    """Request retired without a complete answer: structurally rejected,
    deadline-shed/cancelled, or quarantined with an error status."""
    return bool(r.rejected or getattr(r, "shed", False)
                or getattr(r, "cancelled", False)
                or getattr(r, "error", None))


def _class_metrics(reqs: list, makespan: float) -> dict:
    served = [r for r in reqs if not _failed(r)]
    ttft = [r.ttft for r in served if r.ttft is not None]
    tpot = [r.tpot for r in served if r.tpot is not None]
    lat = [r.finished_at - r.arrival for r in served
           if r.finished_at is not None]
    tokens = sum(len(r.tokens) for r in served)
    return {
        "n": len(reqs),
        "n_served": len(served),
        "n_rejected": sum(1 for r in reqs if r.rejected),
        "n_preempted": sum(1 for r in reqs if r.n_preempts > 0),
        "n_shed": sum(1 for r in reqs if getattr(r, "shed", False)),
        "n_cancelled": sum(1 for r in reqs
                           if getattr(r, "cancelled", False)),
        "n_error": sum(1 for r in reqs if getattr(r, "error", None)),
        "tokens": tokens,
        "goodput_tok_per_t": tokens / makespan if makespan > 0 else 0.0,
        "ttft_p50": _pct(ttft, 50), "ttft_p95": _pct(ttft, 95),
        "ttft_p99": _pct(ttft, 99),
        "tpot_p50": _pct(tpot, 50), "tpot_p95": _pct(tpot, 95),
        "latency_p50": _pct(lat, 50), "latency_p95": _pct(lat, 95),
        "queue_wait_p95": _pct([r.queue_wait for r in served], 95),
    }


def summarize(done: Sequence, *, makespan: Optional[float] = None) -> dict:
    """Per-class + overall percentile report over drained requests.

    Time units follow whatever clock produced the stamps: virtual steps
    under the deterministic harness, seconds under a wall clock."""
    done = list(done)
    if makespan is None:
        makespan = max((r.finished_at for r in done
                        if r.finished_at is not None), default=0.0)
    by_cls: dict[int, list] = {}
    for r in done:
        by_cls.setdefault(r.priority, []).append(r)
    out = {"makespan": makespan,
           "overall": _class_metrics(done, makespan),
           "classes": {CLASS_NAMES.get(c, str(c)): _class_metrics(rs, makespan)
                       for c, rs in sorted(by_cls.items())}}
    return out


def format_report(report: dict, *, unit: str = "steps") -> str:
    """Human-readable per-class table for launcher output."""
    lines = []
    head = (f"{'class':<12} {'n':>4} {'srv':>4} {'rej':>4} {'pre':>4} "
            f"{'shd':>4} {'cxl':>4} {'err':>4} "
            f"{'ttft p50':>9} {'ttft p95':>9} {'tpot p50':>9} "
            f"{'lat p95':>9} {'goodput':>9}")
    lines.append(head)
    rows = [("overall", report["overall"])]
    rows += [(name, m) for name, m in report["classes"].items()]
    for name, m in rows:
        lines.append(
            f"{name:<12} {m['n']:>4} {m['n_served']:>4} "
            f"{m['n_rejected']:>4} {m['n_preempted']:>4} "
            f"{m.get('n_shed', 0):>4} {m.get('n_cancelled', 0):>4} "
            f"{m.get('n_error', 0):>4} "
            f"{m['ttft_p50']:>9.2f} {m['ttft_p95']:>9.2f} "
            f"{m['tpot_p50']:>9.2f} {m['latency_p95']:>9.2f} "
            f"{m['goodput_tok_per_t']:>9.2f}")
    lines.append(f"(times in {unit}; goodput = completed tokens / makespan; "
                 f"shd/cxl = deadline shed/cancelled, err = quarantined)")
    if "faults" in report:
        f = report["faults"]
        lines.append(
            f"faults: {f['n_faults_applied']} injected, "
            f"{f['n_nonfinite']} non-finite quarantines, "
            f"{f['n_kernel_fallbacks']} kernel fallbacks "
            f"(attn impl now {f['paged_attn_impl']}), "
            f"{f['n_spill_checksum_fails']} corrupt spills caught")
    return "\n".join(lines)
