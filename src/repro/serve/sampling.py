"""Token sampling strategies for the serving engines."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def sample_np(logits: np.ndarray, rng: np.random.Generator, *,
              temperature: float = 0.0, top_k: int = 0) -> int:
    """Host-side sampling of a single (V,) logits row.

    Kept for host-side callers/tools; the continuous engine now samples
    first tokens on-device from per-request keys (fold_in by rid) so
    seeded runs don't depend on prefill batch grouping.
    """
    if temperature <= 0.0:
        return int(np.argmax(logits))
    logits = logits.astype(np.float64) / temperature
    if top_k > 0:
        kth = np.partition(logits, -top_k)[-top_k]
        logits = np.where(logits < kth, -1e30, logits)
    gumbel = -np.log(-np.log(rng.uniform(1e-12, 1.0, logits.shape)))
    return int(np.argmax(logits + gumbel))


def sample(logits: jax.Array, key, *, temperature: float = 1.0,
           top_k: int = 0, top_p: float = 0.0) -> jax.Array:
    """logits: (B, V) -> (B,) int32."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        kth = jnp.sort(logits, axis=-1)[:, -top_k][:, None]
        logits = jnp.where(logits < kth, -1e30, logits)
    if top_p > 0.0:
        srt = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(srt, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cut_idx = jnp.sum(cum < top_p, axis=-1)             # first idx past p
        kth = jnp.take_along_axis(srt, cut_idx[:, None], axis=-1)
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def _filtered(logits: jax.Array, temperature: float, top_k: int) -> jax.Array:
    """The same temperature/top-k filtering `sample` applies, batched over
    any leading dims — spec-decode acceptance must compare the *filtered*
    draft and target distributions or the accept ratio would mix grids."""
    logits = logits / temperature
    if top_k > 0:
        kth = jnp.sort(logits, axis=-1)[..., -top_k][..., None]
        logits = jnp.where(logits < kth, -1e30, logits)
    return logits


def spec_accept_greedy(target_logits: jax.Array,
                       draft_tokens: jax.Array):
    """Greedy (temperature 0) speculative acceptance.

    target_logits: (S, M, V) — row m is the target's next-token
    distribution after the already-emitted prefix plus m verified tokens.
    draft_tokens: (S, M-1) — the draft's proposals d_1..d_{M-1}, which were
    fed as verify rows 1..M-1.

    Returns (out_tokens (S, M) int32, n_emit (S,) int32): emit
    out_tokens[:, :n_emit]. Every emitted token is the target argmax of
    row m, and row m's context is valid iff all drafts before it matched
    those argmaxes — so emission is *lossless by construction*: the token
    stream is exactly what target-only greedy decode would produce,
    whatever the draft proposed. n_emit = accepted prefix + 1 (the target's
    own token for the first mismatching row rides along free)."""
    t = jnp.argmax(target_logits, axis=-1).astype(jnp.int32)    # (S, M)
    m = target_logits.shape[1]
    if m == 1:
        return t, jnp.ones((t.shape[0],), jnp.int32)
    match = (draft_tokens == t[:, :-1]).astype(jnp.int32)       # (S, M-1)
    n_acc = jnp.sum(jnp.cumprod(match, axis=-1), axis=-1)       # leading run
    return t, (n_acc + 1).astype(jnp.int32)


def spec_accept_sample(target_logits: jax.Array, draft_logits: jax.Array,
                       draft_tokens: jax.Array, key, *, temperature: float,
                       top_k: int = 0):
    """Temperature>0 speculative acceptance with residual resampling
    (Leviathan et al. / Chen et al.): accept draft d_i with probability
    min(1, p_t(d_i) / p_d(d_i)); at the first rejection sample from the
    residual normalize(max(p_t - p_d, 0)); when every draft survives,
    sample the bonus token from the last target row. The emitted stream is
    distributed exactly as target-only sampling.

    target_logits: (S, M, V); draft_logits: (S, M-1, V) — row i is the
    distribution d_{i+1} was sampled from; draft_tokens: (S, M-1).
    Returns (out_tokens (S, M) int32, n_emit (S,) int32)."""
    s, m, v = target_logits.shape
    pt = jax.nn.softmax(_filtered(target_logits, temperature, top_k), -1)
    out = jnp.zeros((s, m), jnp.int32)
    if m == 1:
        tok = sample(target_logits[:, 0], key, temperature=temperature,
                     top_k=top_k)
        return out.at[:, 0].set(tok), jnp.ones((s,), jnp.int32)
    pd = jax.nn.softmax(_filtered(draft_logits, temperature, top_k), -1)
    ku, kr, kb = jax.random.split(key, 3)
    p_t_d = jnp.take_along_axis(pt[:, :-1], draft_tokens[..., None],
                                axis=-1)[..., 0]                # (S, M-1)
    p_d_d = jnp.take_along_axis(pd, draft_tokens[..., None],
                                axis=-1)[..., 0]                # (S, M-1)
    u = jax.random.uniform(ku, (s, m - 1))
    accept = u * p_d_d < p_t_d                                  # (S, M-1)
    n_acc = jnp.sum(jnp.cumprod(accept.astype(jnp.int32), axis=-1), axis=-1)
    # per-row residual resample (only row n_acc is ever used; a zero-mass
    # residual means p_t <= p_d pointwise never triggered a rejection there,
    # but guard it for the masked rows we discard anyway)
    res = jnp.maximum(pt[:, :-1] - pd, 0.0)
    mass = jnp.sum(res, axis=-1, keepdims=True)
    res = jnp.where(mass > 0, res / jnp.maximum(mass, 1e-30), pt[:, :-1])
    res_tok = jax.random.categorical(
        kr, jnp.log(jnp.maximum(res, 1e-30)), axis=-1).astype(jnp.int32)
    bonus = sample(target_logits[:, -1], kb, temperature=temperature,
                   top_k=top_k)                                 # (S,)
    # out[:, i] = accepted draft for i < n_acc; the resample (or bonus when
    # everything was accepted) at i == n_acc; padding beyond stays 0
    idx = jnp.arange(m, dtype=jnp.int32)[None, :]
    final = jnp.where(n_acc[:, None] == m - 1, bonus[:, None],
                      jnp.take_along_axis(
                          res_tok, jnp.minimum(n_acc, m - 2)[:, None],
                          axis=-1))
    drafts = jnp.pad(draft_tokens, ((0, 0), (0, 1)))
    out = jnp.where(idx < n_acc[:, None], drafts,
                    jnp.where(idx == n_acc[:, None], final, 0))
    return out.astype(jnp.int32), (n_acc + 1).astype(jnp.int32)
