"""Token sampling strategies for the serving engines."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def sample_np(logits: np.ndarray, rng: np.random.Generator, *,
              temperature: float = 0.0, top_k: int = 0) -> int:
    """Host-side sampling of a single (V,) logits row.

    Kept for host-side callers/tools; the continuous engine now samples
    first tokens on-device from per-request keys (fold_in by rid) so
    seeded runs don't depend on prefill batch grouping.
    """
    if temperature <= 0.0:
        return int(np.argmax(logits))
    logits = logits.astype(np.float64) / temperature
    if top_k > 0:
        kth = np.partition(logits, -top_k)[-top_k]
        logits = np.where(logits < kth, -1e30, logits)
    gumbel = -np.log(-np.log(rng.uniform(1e-12, 1.0, logits.shape)))
    return int(np.argmax(logits + gumbel))


def sample(logits: jax.Array, key, *, temperature: float = 1.0,
           top_k: int = 0, top_p: float = 0.0) -> jax.Array:
    """logits: (B, V) -> (B,) int32."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        kth = jnp.sort(logits, axis=-1)[:, -top_k][:, None]
        logits = jnp.where(logits < kth, -1e30, logits)
    if top_p > 0.0:
        srt = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(srt, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cut_idx = jnp.sum(cum < top_p, axis=-1)             # first idx past p
        kth = jnp.take_along_axis(srt, cut_idx[:, None], axis=-1)
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
