"""Multi-pod dry-run: AOT lower + compile every (arch × shape × mesh) cell.

For each cell:
  * builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  * derives params as ShapeDtypeStructs (jax.eval_shape — no allocation),
  * attaches NamedShardings from the partitioning rules,
  * lowers + compiles the train/prefill/decode step,
  * records memory_analysis, cost_analysis and parsed collective bytes
    (JSON, one file per cell) for EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
      --shape train_4k --mesh single --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
from __future__ import annotations

import os

# MUST precede any jax import/init: the dry-run needs 512 placeholder host
# devices so jax.make_mesh can build the production mesh. Never set globally.
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.core.quant.deploy import quantize_params_for_serving
from repro.distributed.partitioning import rules_for_config, shard_struct
from repro.distributed.sharding import named_sharding, sharding_ctx, spec_for
from repro.launch.mesh import chips_in_mesh, make_production_mesh
from repro.launch.roofline import (collective_bytes, model_flops, roofline)
from repro.launch.shapes import (SHAPES, WHISPER_ENC_LEN, input_specs,
                                 skip_reason)
from repro.models.config import ModelConfig
from repro.models.encdec import (encdec_decode, encdec_init_cache,
                                 encdec_loss, encdec_prefill, init_encdec)
from repro.models.transformer import init_cache, init_lm, lm_decode, lm_prefill
from repro.optim.schedules import constant
from repro.train.train_step import init_opt_state, make_train_step
from repro.utils.tree import tree_map_with_path


def dry_cfg(cfg: ModelConfig, kind: str) -> ModelConfig:
    """Dry-run numerics: bf16 everywhere, remat on for training."""
    cfg = cfg.replace(dtype="bfloat16", param_dtype="bfloat16",
                      remat=(kind == "train"))
    return cfg


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree,
        is_leaf=lambda x: hasattr(x, "shape"))


def _attach(mesh, tree, names_fn):
    """Attach shardings to an SDS tree via names_fn(path, leaf)->names."""
    def fn(path, leaf):
        if not hasattr(leaf, "shape"):
            return leaf
        names = names_fn(path, leaf)
        return jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype,
            sharding=named_sharding(leaf.shape, names, mesh=mesh))
    return tree_map_with_path(fn, tree)


def _cache_names(path: str, leaf) -> tuple:
    nd = leaf.ndim
    key = path.split("/")[-1]
    lead = (None,) * max(0, nd - {"k": 4, "v": 4, "pos": 2, "len": 1,
                                  "state": 4, "conv": 3, "k_scale": 3,
                                  "v_scale": 3}.get(key, nd))
    if key in ("k_scale", "v_scale"):
        return lead + ("batch", "cache_seq", None)
    if key in ("k", "v"):
        kvh = leaf.shape[-2]
        seq_ax = "cache_seq" if kvh % 16 != 0 or kvh == 1 else None
        head_ax = "kv_heads" if kvh % 16 == 0 else None
        return lead + ("batch", seq_ax, head_ax, None)
    if key == "pos":
        return lead + ("batch", None)
    if key == "len":
        return lead + ("batch",)
    if key == "state":
        return lead + ("batch", "ssm_heads", None, None)
    if key == "conv":
        return lead + ("batch", None, None)
    return (None,) * nd


def build_cell(cfg: ModelConfig, shape_name: str, mesh, variant=None):
    """Returns (fn, example_args: SDS-with-shardings tuple).

    `variant` (perf hillclimbing): {"cfg": {field: value}, "rules": {...},
    "donate_cache": bool, "grad_compress_bits": int}."""
    variant = variant or {}
    shape = SHAPES[shape_name]
    cfg = dry_cfg(cfg, shape.kind)
    if variant.get("cfg"):
        cfg = cfg.replace(**variant["cfg"])
    rules = rules_for_config(cfg, mesh)
    rules["cache_seq"] = "model"
    rules.update(variant.get("rules", {}))
    key = jax.random.PRNGKey(0)

    init_fn = init_encdec if cfg.enc_dec else init_lm
    params_shape = jax.eval_shape(lambda: init_fn(cfg, key))
    specs = input_specs(cfg, shape)

    def batch_names(path, leaf):
        base = path.split("/")[-1]
        if base in ("tokens", "labels", "positions"):
            return ("batch",) + (None,) * (leaf.ndim - 1)
        if base in ("frames", "ext_embeds"):
            return ("batch", None, None)
        return (None,) * leaf.ndim

    with sharding_ctx(mesh, rules):
        if shape.kind == "train":
            loss_fn = encdec_loss if cfg.enc_dec else None
            step = make_train_step(
                cfg, lr_schedule=constant(1e-4), clip_norm=1.0,
                loss_fn=loss_fn, donate=False,
                grad_compress_bits=variant.get("grad_compress_bits", 0))
            gcb = variant.get("grad_compress_bits", 0)
            opt_shape = jax.eval_shape(
                lambda p: init_opt_state(cfg, p, grad_compress_bits=gcb),
                params_shape)
            p_sds = shard_struct(mesh, cfg, params_shape)
            o_sds = {"adam": {"m": shard_struct(mesh, cfg,
                                                opt_shape["adam"]["m"]),
                              "v": shard_struct(mesh, cfg,
                                                opt_shape["adam"]["v"]),
                              "step": jax.ShapeDtypeStruct((), jnp.int32)}}
            if gcb:
                o_sds["ef"] = shard_struct(mesh, cfg, opt_shape["ef"])
            b_sds = _attach(mesh, specs, batch_names)
            args = (p_sds, o_sds, b_sds,
                    jax.ShapeDtypeStruct((), jnp.int32),
                    jax.ShapeDtypeStruct((2,), jnp.uint32))
            return step, args, cfg

        # serving: maybe quantized weights
        qparams_shape = jax.eval_shape(
            lambda p: quantize_params_for_serving(cfg, p), params_shape)
        p_sds = shard_struct(mesh, cfg, qparams_shape)

        if shape.kind == "prefill":
            if cfg.enc_dec:
                cache_shape = jax.eval_shape(lambda: encdec_init_cache(
                    cfg, shape.global_batch, shape.seq_len, WHISPER_ENC_LEN))

                def fn(params, frames, tokens, cache):
                    return encdec_prefill(cfg, params, frames, tokens, cache)

                c_sds = _attach(mesh, _sds(cache_shape), _cache_names)
                b = _attach(mesh, specs, batch_names)
                return fn, (p_sds, b["frames"], b["tokens"], c_sds), cfg

            cache_shape = jax.eval_shape(lambda: init_cache(
                cfg, shape.global_batch, shape.seq_len))

            if cfg.frontend == "vision":
                def fn(params, tokens, ext, cache):
                    return lm_prefill(cfg, params, tokens, cache,
                                      ext_embeds=ext)

                c_sds = _attach(mesh, _sds(cache_shape), _cache_names)
                b = _attach(mesh, specs, batch_names)
                return fn, (p_sds, b["tokens"], b["ext_embeds"], c_sds), cfg

            def fn(params, tokens, cache):
                return lm_prefill(cfg, params, tokens, cache)

            c_sds = _attach(mesh, _sds(cache_shape), _cache_names)
            b = _attach(mesh, specs, batch_names)
            return fn, (p_sds, b["tokens"], c_sds), cfg

        # decode
        if cfg.enc_dec:
            cache_shape = jax.eval_shape(lambda: encdec_init_cache(
                cfg, shape.global_batch, shape.seq_len, WHISPER_ENC_LEN))

            def fn(params, tokens, cache, positions):
                return encdec_decode(cfg, params, tokens, cache, positions)
        else:
            cache_shape = jax.eval_shape(lambda: init_cache(
                cfg, shape.global_batch, shape.seq_len))

            def fn(params, tokens, cache, positions):
                return lm_decode(cfg, params, tokens, cache, positions)

        c_sds = _attach(mesh, _sds(cache_shape), _cache_names)
        b = _attach(mesh, specs, batch_names)
        return fn, (p_sds, b["tokens"], c_sds, b["positions"]), cfg


def _compile_cell(cfg0, shape_name, mesh, n_repeats=None, scan_off=False,
                  variant=None):
    variant = variant or {}
    cfg_in = cfg0 if n_repeats is None else cfg0.replace(n_repeats=n_repeats)
    if scan_off:
        # unrolled: every layer appears in HLO, so cost_analysis is exact
        # (scan bodies are counted once regardless of trip count)
        cfg_in = cfg_in.replace(scan_layers=False)
    fn, args, cfg = build_cell(cfg_in, shape_name, mesh, variant)
    rules = rules_for_config(cfg, mesh)
    rules["cache_seq"] = "model"
    rules.update(variant.get("rules", {}))
    donate = ()
    if variant.get("donate_cache") and SHAPES[shape_name].kind != "train":
        # cache is the last-but-one positional arg for decode, last for prefill
        donate = (len(args) - 2,) if SHAPES[shape_name].kind == "decode" \
            else (len(args) - 1,)
    with sharding_ctx(mesh, rules):
        lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
    return cfg, mem, cost, collective_bytes(hlo)


def _extrapolate(v1, v2, r1, r2, r):
    """Linear in repeats: XLA's cost_analysis counts a scan body once, so we
    compile at two reduced depths and extrapolate to the real depth."""
    if v1 is None or v2 is None:
        return None
    slope = (v2 - v1) / (r2 - r1)
    return v1 + slope * (r - r1)


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             compile_only: bool = False, variant=None,
             variant_name: str = "") -> dict:
    mesh_name = "multi" if multi_pod else "single"
    cfg0 = get_config(arch)
    shape = SHAPES[shape_name]
    reason = skip_reason(cfg0, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if variant_name:
        rec["variant"] = variant_name
    if reason:
        rec.update({"status": "skipped", "reason": reason})
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        # full-depth compile: the dry-run proof + true memory analysis
        cfg, mem, cost_full, coll_full = _compile_cell(
            cfg0, shape_name, mesh, variant=variant)
        t_compile = time.time() - t0
        # reduced-depth *unrolled* compiles (R=1, R=2): per-layer costs are
        # exact there; extrapolate linearly to the real depth
        r = cfg0.n_repeats
        _, _, cost1, coll1 = _compile_cell(cfg0, shape_name, mesh, 1,
                                           scan_off=True, variant=variant)
        _, _, cost2, coll2 = _compile_cell(cfg0, shape_name, mesh, 2,
                                           scan_off=True, variant=variant)
        cost = {k: _extrapolate(cost1.get(k), cost2.get(k), 1, 2, r)
                for k in ("flops", "bytes accessed", "transcendentals")}
        coll = {k: _extrapolate(coll1.get(k, 0), coll2.get(k, 0), 1, 2, r)
                for k in coll1 if k != "counts"}
        coll["counts"] = coll_full["counts"]
        t_lower = 0.0
        chips = chips_in_mesh(mesh)
        init_fn = init_encdec if cfg.enc_dec else init_lm
        params_shape = jax.eval_shape(
            lambda: init_fn(cfg, jax.random.PRNGKey(0)))
        mf = model_flops(cfg, params_shape, shape)
        terms = roofline(cost, coll, chips=chips, model_flops_total=mf)
        rec.update({
            "status": "ok",
            "compile_s": round(t_compile, 1),
            "chips": chips,
            "cost_uncorrected": {k: cost_full.get(k) for k in
                                 ("flops", "bytes accessed")},
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            },
            "cost": {k: cost.get(k) for k in
                     ("flops", "bytes accessed", "transcendentals")},
            "collectives": {k: v for k, v in coll.items() if k != "counts"},
            "collective_counts": coll["counts"],
            "model_flops_total": mf,
            "roofline": {
                "compute_s": terms.compute_s,
                "memory_s": terms.memory_s,
                "collective_s": terms.collective_s,
                "dominant": terms.dominant,
                "useful_flops_ratio": terms.useful_flops_ratio,
                "roofline_fraction": terms.roofline_fraction,
            },
        })
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
    rec["wall_s"] = round(time.time() - t0, 1)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        vtag = f"__{variant_name}" if variant_name else ""
        fname = f"{arch}__{shape_name}__{mesh_name}{vtag}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(rec, f, indent=2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "multi" if mp else "single"
                fname = os.path.join(args.out,
                                     f"{arch}__{shape}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(fname):
                    print(f"[skip existing] {arch} {shape} {mesh_name}")
                    continue
                rec = run_cell(arch, shape, mp, args.out)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f"dom={r['dominant']} "
                             f"frac={r['roofline_fraction']:.3f} "
                             f"compile={rec['compile_s']}s")
                elif status == "error":
                    extra = rec["error"][:120]
                print(f"[{status}] {arch} {shape} {mesh_name} {extra}",
                      flush=True)


if __name__ == "__main__":
    main()
