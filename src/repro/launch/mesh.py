"""Production mesh builders (TPU v5e 16x16 pods; 2 pods multi-pod).

Functions, not module constants — importing this module never touches jax
device state (required: smoke tests must see 1 device, the dry-run 512).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_from_plan(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Elastic path: build whatever mesh launch/elastic.py planned."""
    return jax.make_mesh(shape, axes)


def chips_in_mesh(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
