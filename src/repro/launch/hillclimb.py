"""Perf hillclimbing driver: re-lower a cell with a named variant and diff
the roofline terms against the baseline.

    PYTHONPATH=src python -m repro.launch.hillclimb --cell \
        chatglm3-6b:decode_32k --variant chunked_decode

Variants encode the hypothesis log in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse
import json

# named variants: {"cfg": {...}, "rules": {...}, "donate_cache", ...}
VARIANTS = {
    "base_fixed": {},
    # --- decode (serving) ---
    "donate_cache": {"donate_cache": True},
    "kv_int8": {"cfg": {"kv_cache_bits": 8}, "donate_cache": True},
    "chunked_decode": {"cfg": {"chunked_decode": True, "attn_block_kv": 2048},
                       "donate_cache": True},
    "chunked_decode_512": {"cfg": {"chunked_decode": True,
                                   "attn_block_kv": 512},
                           "donate_cache": True},
    # --- train memory ---
    "blockkv_2048": {"cfg": {"attn_block_kv": 2048}},
    "blockkv_4096": {"cfg": {"attn_block_kv": 4096}},
    "remat_dots": {"cfg": {"remat_policy": "dots"}},
    "blockkv2048_rematdots": {"cfg": {"attn_block_kv": 2048,
                                      "remat_policy": "dots"}},
    "blockkv4096_rematdots": {"cfg": {"attn_block_kv": 4096,
                                      "remat_policy": "dots"}},
    # --- collectives ---
    "fsdp": {"cfg": {"fsdp": True}},
    "fsdp_gc8": {"cfg": {"fsdp": True}, "grad_compress_bits": 8},
    "gc8": {"grad_compress_bits": 8},
    "expert_tp": {"rules": {"expert": None, "expert_ff": "model",
                            "capacity": "model"}},
    "moe_shardmap": {"cfg": {"moe_impl": "shard_map"}},
    "moe_shardmap_fsdp": {"cfg": {"moe_impl": "shard_map", "fsdp": True}},
    "fsdp_expert_tp": {"cfg": {"fsdp": True},
                       "rules": {"expert": None, "expert_ff": "model",
                                 "capacity": "model"}},
    # context-parallel decode: replicate the (tiny) q heads, keep the KV
    # cache seq-sharded end-to-end -> no per-layer cache all-gather
    "ctx_parallel_decode": {"rules": {"heads": None, "kv_heads": None,
                                      "cache_seq": "model"}},
    "bf16_scores": {"cfg": {"attn_scores_dtype": "bfloat16"}},
    "bf16_scores_rematdots": {"cfg": {"attn_scores_dtype": "bfloat16",
                                      "remat_policy": "dots"}},
    # --- SWA ---
    "banded_swa": {"cfg": {"banded_window_attn": True}},
    "banded_blockkv": {"cfg": {"banded_window_attn": True,
                               "attn_block_kv": 2048}},
}


def main():
    from repro.launch.dryrun import run_cell

    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--variant", required=True,
                    help=f"one of {list(VARIANTS)} or k=v cfg overrides")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--out", default="results/perf")
    args = ap.parse_args()

    arch, shape = args.cell.split(":")
    variant = VARIANTS[args.variant]
    rec = run_cell(arch, shape, args.mesh == "multi", args.out,
                   variant=variant, variant_name=args.variant)
    base_path = f"results/dryrun/{arch}__{shape}__{args.mesh}.json"
    if rec["status"] == "ok" and os.path.exists(base_path):
        base = json.load(open(base_path))
        br, vr = base["roofline"], rec["roofline"]
        print(f"{arch} {shape} [{args.variant}] vs baseline:")
        for k in ("compute_s", "memory_s", "collective_s"):
            b, v = br[k], vr[k]
            delta = (v - b) / b * 100 if b else 0.0
            print(f"  {k:13s} {b:10.4f} -> {v:10.4f}  ({delta:+.1f}%)")
        print(f"  dominant      {br['dominant']} -> {vr['dominant']}")
        print(f"  frac          {br['roofline_fraction']:.4f} -> "
              f"{vr['roofline_fraction']:.4f}")
        pb = base["memory"].get("peak_bytes") or 0
        pv = rec["memory"].get("peak_bytes") or 0
        print(f"  peak HBM      {pb / 2 ** 30:.2f}GB -> {pv / 2 ** 30:.2f}GB")
    else:
        print(json.dumps(rec, indent=2)[:2000])


if __name__ == "__main__":
    main()
