"""Elastic scaling + failure handling policy for 1000+ node runs.

Single-controller view (as in JAX multi-host): the coordinator owns the mesh
recipe. On node failure or persistent straggler:

  1. drain: stop issuing steps, wait for the last async checkpoint;
  2. remesh: choose the largest (pod, data, model) mesh that the surviving
     hosts support — the model axis is fixed by the sharding recipe (TP
     degree must divide attention heads / mlp), so capacity loss shrinks
     the *data* axis first, then drops a pod;
  3. resume: restore the latest checkpoint with the new shardings (our
     checkpoints are host-side full tensors keyed by path, so resharding is
     a pure load-time layout choice) and re-enter the training loop with the
     same (seed, step) data cursor — global batch is preserved by raising
     grad-accumulation steps to cover the lost data-parallel rank(s).

This module computes the policy decisions; the mechanics (mesh build, load)
live in launch/mesh.py and checkpoint/. Tests simulate failures by dropping
"hosts" and asserting the chosen mesh + accum factor keep the global batch.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]            # (pod, data, model) or (data, model)
    axes: tuple[str, ...]
    accum_steps: int                  # grad-accum multiplier vs healthy run
    dropped_hosts: int


def plan_mesh(total_chips: int, *, model_parallel: int = 16,
              chips_per_pod: int = 256, global_batch: int = 256,
              healthy_chips: Optional[int] = None) -> MeshPlan:
    """Pick the best mesh for the currently healthy chip count."""
    healthy = healthy_chips if healthy_chips is not None else total_chips
    assert healthy >= model_parallel, "cannot satisfy TP degree"
    pods = max(1, healthy // chips_per_pod)
    per_pod = healthy // pods
    data = per_pod // model_parallel
    # shrink until it divides cleanly
    while pods * data * model_parallel > healthy and data > 1:
        data -= 1
    used = pods * data * model_parallel
    healthy_data = (total_chips // max(
        1, (total_chips // chips_per_pod))) // model_parallel
    healthy_ranks = max(1, (total_chips // chips_per_pod) * healthy_data)
    ranks = pods * data
    accum = max(1, -(-healthy_ranks // max(ranks, 1)))
    if pods > 1:
        return MeshPlan((pods, data, model_parallel),
                        ("pod", "data", "model"), accum,
                        total_chips - used)
    return MeshPlan((data, model_parallel), ("data", "model"), accum,
                    total_chips - used)


@dataclasses.dataclass
class FailureEvent:
    step: int
    kind: str            # "node_down" | "straggler"
    detail: str = ""


class ElasticCoordinator:
    """Tracks health events and decides remesh points."""

    def __init__(self, total_chips: int, *, model_parallel: int = 16,
                 chips_per_pod: int = 256, straggler_tolerance: int = 3):
        self.total = total_chips
        self.healthy = total_chips
        self.mp = model_parallel
        self.cpp = chips_per_pod
        self.events: list[FailureEvent] = []
        self._straggler_strikes = 0
        self.tol = straggler_tolerance

    def current_plan(self, global_batch: int = 256) -> MeshPlan:
        return plan_mesh(self.total, model_parallel=self.mp,
                         chips_per_pod=self.cpp, global_batch=global_batch,
                         healthy_chips=self.healthy)

    def node_down(self, step: int, chips_lost: int) -> MeshPlan:
        self.healthy -= chips_lost
        self.events.append(FailureEvent(step, "node_down",
                                        f"-{chips_lost} chips"))
        return self.current_plan()

    def straggler(self, step: int, dt: float) -> Optional[MeshPlan]:
        """Repeated stragglers -> treat the slow host as failed (evict)."""
        self.events.append(FailureEvent(step, "straggler", f"{dt:.2f}s"))
        self._straggler_strikes += 1
        if self._straggler_strikes >= self.tol:
            self._straggler_strikes = 0
            return self.node_down(step, chips_lost=self.cpp // 64)  # 1 host
        return None
