"""Assigned input-shape sets and ShapeDtypeStruct builders per (arch, shape).

  train_4k    : seq 4096,   global batch 256  -> train_step
  prefill_32k : seq 32768,  global batch 32   -> serve prefill
  decode_32k  : 1 new token, KV cache 32768, batch 128 -> serve decode
  long_500k   : 1 new token, context 524288, batch 1   -> serve decode
                (sub-quadratic archs only: mamba2 / jamba / mixtral-SWA)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# archs with sub-quadratic attention/state for the 500k cell
LONG_OK_FAMILIES = {"ssm", "hybrid"}


def long_ok(cfg: ModelConfig) -> bool:
    return cfg.family in LONG_OK_FAMILIES or cfg.attn_window is not None


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> str | None:
    if shape.name == "long_500k" and not long_ok(cfg):
        return ("full-attention arch: 500k decode requires sub-quadratic "
                "attention (noted in DESIGN.md)")
    return None


WHISPER_ENC_LEN = 1500  # whisper's native encoder length (30s audio)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).
    Shardings are attached later by the dry-run (they depend on the mesh)."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    if cfg.enc_dec:  # whisper: frame embeddings + decoder tokens
        if shape.kind == "train":
            return {"frames": jax.ShapeDtypeStruct(
                        (b, WHISPER_ENC_LEN, cfg.d_model), cfg.adtype),
                    "tokens": jax.ShapeDtypeStruct((b, s), i32),
                    "labels": jax.ShapeDtypeStruct((b, s), i32)}
        if shape.kind == "prefill":
            return {"frames": jax.ShapeDtypeStruct(
                        (b, WHISPER_ENC_LEN, cfg.d_model), cfg.adtype),
                    "tokens": jax.ShapeDtypeStruct((b, s), i32)}
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32),
                "positions": jax.ShapeDtypeStruct((b, 1), i32)}

    if cfg.frontend == "vision":  # VLM: patch embeds prepended
        f = cfg.frontend_len
        if shape.kind == "train":
            return {"tokens": jax.ShapeDtypeStruct((b, s - f), i32),
                    "labels": jax.ShapeDtypeStruct((b, s - f), i32),
                    "ext_embeds": jax.ShapeDtypeStruct(
                        (b, f, cfg.d_model), cfg.adtype)}
        if shape.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((b, s - f), i32),
                    "ext_embeds": jax.ShapeDtypeStruct(
                        (b, f, cfg.d_model), cfg.adtype)}
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32),
                "positions": jax.ShapeDtypeStruct((b, 1), i32)}

    if shape.kind == "train":
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32)}
    if shape.kind == "prefill":
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    return {"tokens": jax.ShapeDtypeStruct((b, 1), i32),
            "positions": jax.ShapeDtypeStruct((b, 1), i32)}
