"""Distributed training launcher.

On a real TPU pod this runs under `jax.distributed.initialize()` with the
production mesh; on CPU it runs the same code on a 1-device mesh. The loop
is the fault-tolerant Trainer (checkpoint/resume, straggler detection,
elastic remesh policy).

    PYTHONPATH=src python -m repro.launch.train --arch tiny --steps 200 \
        --batch 16 --seq 64 --ckpt-dir /tmp/repro_run
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config, get_smoke_config, list_archs
from repro.data.pipeline import DataPipeline
from repro.data.synthetic import heldout_split, make_corpus
from repro.distributed.partitioning import param_shardings, rules_for_config
from repro.distributed.sharding import sharding_ctx
from repro.launch.elastic import ElasticCoordinator
from repro.launch.mesh import chips_in_mesh
from repro.models.transformer import init_lm
from repro.optim.schedules import warmup_cosine
from repro.train.evaluate import perplexity
from repro.train.train_step import init_opt_state, make_train_step
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny",
                    choices=["tiny"] + list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config of --arch")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compress-bits", type=int, default=0)
    ap.add_argument("--multi-pod", action="store_true",
                    help="build the 2x16x16 mesh (needs 512 devices)")
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    n_dev = len(jax.devices())
    if args.multi_pod or n_dev >= 256:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        mesh = jax.make_mesh((n_dev, 1), ("data", "model"))
    print(f"mesh: {dict(mesh.shape)} ({chips_in_mesh(mesh)} chips)")
    coord = ElasticCoordinator(chips_in_mesh(mesh))

    corpus, _ = make_corpus(cfg.vocab_size, 200_000, seed=0)
    train_toks, held = heldout_split(corpus)
    pipe = DataPipeline(train_toks, batch_size=args.batch, seq_len=args.seq,
                        seed=0)
    rules = rules_for_config(cfg, mesh)

    with sharding_ctx(mesh, rules):
        params = init_lm(cfg, jax.random.PRNGKey(0))
        if chips_in_mesh(mesh) > 1:
            shardings = param_shardings(mesh, cfg, params)
            params = jax.device_put(params, shardings)
        step_fn = make_train_step(
            cfg, lr_schedule=warmup_cosine(args.lr, 20, args.steps),
            grad_compress_bits=args.grad_compress_bits)
        opt = init_opt_state(cfg, params,
                             grad_compress_bits=args.grad_compress_bits)
        ckpt = CheckpointManager(args.ckpt_dir, keep=3)

        def on_straggler(step, dt):
            plan = coord.straggler(step, dt)
            if plan:
                print(f"!! evicting slow host: remesh plan {plan.shape}, "
                      f"grad-accum x{plan.accum_steps}")

        trainer = Trainer(cfg, params, opt, step_fn, pipe, ckpt,
                          on_straggler=on_straggler)
        start = trainer.maybe_resume()
        if start:
            print(f"resumed at step {start}")
        result = trainer.run(args.steps, ckpt_every=args.ckpt_every)
        print(f"done: {result}")
        print("heldout:", perplexity(cfg, trainer.params, held,
                                     seq_len=args.seq))


if __name__ == "__main__":
    main()
