"""Aggregate dry-run JSON records into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m repro.launch.report --dir results/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def fmt_s(v):
    if v is None:
        return "-"
    if v < 1e-3:
        return f"{v * 1e6:.1f}us"
    if v < 1.0:
        return f"{v * 1e3:.1f}ms"
    return f"{v:.2f}s"


def load(dir_):
    recs = []
    for f in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def table(recs, mesh="single"):
    rows = []
    hdr = ("| arch | shape | status | compute | memory | collective | "
           "dominant | useful | frac | HBM/dev |")
    sep = "|" + "---|" * 10
    rows.append(hdr)
    rows.append(sep)
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | skipped | - | - | - "
                        f"| - | - | - | - |")
            continue
        if r["status"] == "error":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | - | - | - | "
                        f"- | - | - | - |")
            continue
        rf = r["roofline"]
        peak = r["memory"].get("peak_bytes") or r["memory"].get("temp_bytes")
        uf = rf.get("useful_flops_ratio")
        uf_s = f"{uf:.2f}" if uf is not None else "-"
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok "
            f"| {fmt_s(rf['compute_s'])} | {fmt_s(rf['memory_s'])} "
            f"| {fmt_s(rf['collective_s'])} | {rf['dominant']} "
            f"| {uf_s} | {rf['roofline_fraction']:.3f} "
            f"| {fmt_bytes(peak)} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    recs = load(args.dir)
    n_ok = sum(1 for r in recs if r["status"] == "ok")
    n_skip = sum(1 for r in recs if r["status"] == "skipped")
    n_err = sum(1 for r in recs if r["status"] == "error")
    print(f"records: {len(recs)} (ok={n_ok} skipped={n_skip} err={n_err})\n")
    print(table(recs, args.mesh))
    if n_err:
        print("\nerrors:")
        for r in recs:
            if r["status"] == "error":
                print(f"  {r['arch']} {r['shape']} {r['mesh']}: "
                      f"{r['error'][:160]}")


if __name__ == "__main__":
    main()
