"""Serving launcher: quantize (GPTQ/RTN/SmoothQuant ± Norm-Tweaking) and
serve batched requests with packed low-bit weights.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
        --bits 4 --method gptq --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config, list_archs
from repro.core.calibration.generator import generate_calibration
from repro.core.normtweak.pipeline import NTConfig, norm_tweak_ptq
from repro.distributed.partitioning import rules_for_config
from repro.distributed.sharding import sharding_ctx
from repro.models.transformer import init_lm
from repro.serve.engine import ServeEngine
from repro.utils.tree import tree_size_bytes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    choices=["tiny"] + list_archs())
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--method", default="gptq",
                    choices=["gptq", "rtn", "smoothquant", "none"])
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--group-size", type=int, default=-1)
    ap.add_argument("--no-tweak", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.enc_dec:
        raise SystemExit("whisper serving demo lives in tests/test_system.py")
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev, 1), ("data", "model")) if n_dev > 1 else None
    rules = rules_for_config(cfg, mesh) if mesh else None

    with sharding_ctx(mesh, rules):
        params = init_lm(cfg, jax.random.PRNGKey(0))
        print(f"{cfg.name}: float {tree_size_bytes(params) / 1e6:.1f} MB")
        if args.method != "none":
            calib = generate_calibration(cfg, params, jax.random.PRNGKey(1),
                                         n_samples=8, token_length=32)
            nt = NTConfig(method=args.method, bits=args.bits,
                          group_size=args.group_size,
                          tweak=not args.no_tweak, lr0=1e-3, iters=1,
                          sample_batch=4,
                          act_bits=8 if args.method == "smoothquant" else 0)
            params, _ = norm_tweak_ptq(cfg, params, calib, nt,
                                       log=lambda s: print("  " + s))
            print(f"quantized: {tree_size_bytes(params) / 1e6:.1f} MB "
                  f"(W{args.bits}{'+NT' if not args.no_tweak else ''})")

        eng = ServeEngine(cfg, params)
        prompts = np.random.default_rng(0).integers(
            0, cfg.vocab_size, (args.requests, args.prompt_len))
        t0 = time.time()
        res = eng.generate(prompts, max_new=args.max_new, temperature=0.0)
        dt = time.time() - t0
        tps = args.requests * args.max_new / dt
        print(f"served {args.requests} requests x {args.max_new} tokens in "
              f"{dt:.2f}s ({tps:.1f} tok/s)")
        print("request 0:", res.tokens[0].tolist())


if __name__ == "__main__":
    main()
