"""Serving launcher: quantize (GPTQ/RTN/SmoothQuant ± Norm-Tweaking) and
drive the continuous-batching engine with Poisson traffic, reporting
throughput and per-request latency percentiles.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
        --bits 4 --method gptq --requests 16 --rate 8.0

`--no-smoke` runs the full-size config. `--engine static` runs the old
static-batch engine on the same workload for comparison. `--spec-decode`
(float checkpoints, `--method none`) turns on self-speculative decoding:
a packed W2/W3 draft of the same params proposes `--spec-k` tokens per
round, the target verifies in one forward — greedy output stays
bit-identical to target-only decode, and the summary reports the
acceptance counters.

Traffic shapes come from serve/traffic.py: `--trace poisson` (default
trickle), `--trace bursty` (on/off overload), or `--trace uniform`;
`--batch-frac` marks that fraction of requests batch-class. `--preempt`
arms priority scheduling with KV spill — interactive requests evict
batch victims under pressure (`--age-promote` bounds batch starvation) —
and the summary reports per-class TTFT/TPOT percentiles, goodput, and
the preemption/spill counters. `--virtual-clock` drives the run on the
deterministic step clock instead of wall time (same seed, same numbers,
every machine).

Fault tolerance (serve/faults.py): `--deadline T` gives every request an
SLO of T time units after arrival (missed = shed from the queue or
cancelled mid-run), `--faults SEED` injects a seeded chaos schedule —
NaN logits, pool exhaustion, kernel faults, corrupt spills, latency
spikes, plus one crash recovered from the latest snapshot — and
`--snapshot-every N` checkpoints the full engine state every N steps.
The report then carries shed/cancelled/quarantined columns and the
fault counters.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.analysis import sanitize
from repro.configs import get_config, get_smoke_config, list_archs
from repro.core.calibration.generator import generate_calibration
from repro.core.normtweak.pipeline import NTConfig, norm_tweak_ptq
from repro.debug_flags import sanitize_enabled
from repro.distributed.partitioning import rules_for_config
from repro.distributed.sharding import sharding_ctx
from repro.models.transformer import init_lm
from repro.serve import traffic
from repro.serve.engine import ContinuousEngine, ServeEngine
from repro.serve.faults import FaultPlan, run_resilient
from repro.utils.tree import tree_size_bytes


def build_params(cfg, args):
    params = init_lm(cfg, jax.random.PRNGKey(0))
    print(f"{cfg.name}: float {tree_size_bytes(params) / 1e6:.1f} MB")
    if args.method != "none":
        calib = generate_calibration(cfg, params, jax.random.PRNGKey(1),
                                     n_samples=8, token_length=32)
        nt = NTConfig(method=args.method, bits=args.bits,
                      group_size=args.group_size,
                      tweak=not args.no_tweak, lr0=1e-3, iters=1,
                      sample_batch=4,
                      act_bits=8 if args.method == "smoothquant" else 0)
        params, _ = norm_tweak_ptq(cfg, params, calib, nt,
                                   log=lambda s: print("  " + s))
        print(f"quantized: {tree_size_bytes(params) / 1e6:.1f} MB "
              f"(W{args.bits}{'+NT' if not args.no_tweak else ''})")
    return params


def make_workload(cfg, args):
    """Seeded trace from the traffic harness (serve/traffic.py): Poisson
    trickle, bursty on/off overload, or uniform arrivals, with a
    deterministic interactive/batch class mix.

    `--shared-prefix N` models system-prompt traffic: every request's
    prompt starts with the same N tokens (the prefix cache's target
    workload) followed by a unique tail."""
    return traffic.make_trace(
        kind=args.trace, n=args.requests,
        rate=args.rate if args.rate > 0 else 1e9,
        seed=args.seed, vocab_size=cfg.vocab_size,
        prompt_len=(args.prompt_len_min, args.prompt_len_max),
        max_new=(args.max_new_min, args.max_new_max),
        batch_frac=args.batch_frac,
        burst_len=args.burst_len, idle_len=args.idle_len,
        burst_rate_mult=args.burst_rate_mult,
        shared_prefix=args.shared_prefix,
        deadline=args.deadline)


def run_continuous(cfg, params, work, args):
    # per-slot capacity must cover a bucket-padded prompt plus max decode,
    # or the bucket-length warm-up requests below would overflow it
    plen_max = max(len(it.prompt) for it in work)
    bucket_up = -(-plen_max // args.prefill_bucket) * args.prefill_bucket
    max_len = bucket_up + args.max_new_max

    def build():
        # also run_resilient's crash-recovery constructor: a rebuilt
        # engine must warm and reset identically to the first one (the
        # jit caches themselves are process-global, so only the first
        # build pays the compiles)
        eng = ContinuousEngine(cfg, params, n_slots=args.slots,
                               max_len=max_len, page_size=args.page_size,
                               prefill_bucket=args.prefill_bucket,
                               paged_attn=args.paged_attn,
                               prefix_share=args.prefix_share,
                               chunked_prefill=args.chunked_prefill,
                               tp=args.tp, spec_decode=args.spec_decode,
                               draft_bits=args.draft_bits,
                               spec_k=args.spec_k,
                               preempt=args.preempt,
                               age_promote=args.age_promote)
        if args.tp > 1:
            rep = eng.tp_placement_report()
            print(f"tensor-parallel x{args.tp}: params "
                  f"{rep['params']['per_device_bytes'] / 1e6:.1f} MB/device "
                  f"(global {rep['params']['global_bytes'] / 1e6:.1f} MB), "
                  f"KV pools "
                  f"{rep['kv']['per_device_bytes'] / 1e6:.1f} MB/device")
            assert not rep["replicated_quant_leaves"], \
                rep["replicated_quant_leaves"]
        # warm the jit caches — every prefill bucket in the workload,
        # decoded both shallow and to full depth so the common (k, width)
        # decode-scan shapes compile before timing (odd depth/remaining
        # combos in the real traffic can still hit a fresh shape mid-run)
        buckets = sorted({eng._bucket(len(it.prompt)) for it in work})
        waves = 2 if args.prefix_share else 1
        shared_floor = ((args.shared_prefix // args.page_size)
                        * args.page_size if args.prefix_share else 0)
        for wave in range(waves):
            # with prefix sharing, the first wave registers its prompts
            # and a second wave prefix-hits exactly the system-prefix
            # floor (its tails differ, like real traffic), compiling the
            # gathered-context suffix-prefill shapes the timed run takes
            for b in buckets:
                for mn in {2, args.max_new_max}:
                    p = np.zeros(b, np.int64)
                    if wave > 0 and 0 < shared_floor < b:
                        p[shared_floor:] = 1
                    eng.submit(p, max_new=mn)
            eng.run(max_steps=10_000)
        print(f"warmed {len(buckets)} prefill buckets "
              f"({waves} wave{'s' if waves > 1 else ''}): {buckets}")
        # report the timed run only: reset the counters, the virtual
        # clock, and the step index (fault plans are step-indexed), and
        # drop the warm-up prompts' cache registrations, so stats and
        # injected faults reflect measured traffic alone
        eng.t = 0
        eng.n_steps_total = 0
        eng.n_decode_steps = eng.n_prefills = 0
        eng.n_prefill_tokens = eng.n_shared_tokens = 0
        eng.n_spilled_pages = eng.n_restored_pages = 0
        eng.sched.events.clear()
        eng.sched.n_preemptions = eng.sched.n_restored = 0
        eng.sched.n_rejected = 0
        eng.sched.n_finished_ok = eng.sched.n_finished_preempted = 0
        eng.sched.n_shed = eng.sched.n_cancelled = 0
        eng.sched.n_quarantined = 0
        if args.spec_decode:
            eng.n_spec_rounds = eng.n_draft_tokens = eng.n_spec_emitted = 0
            eng.spec_accept_sum[:] = 0
            eng.spec_round_count[:] = 0
        eng.pool.clear_prefix_cache()
        return eng

    if args.faults is not None or args.snapshot_every > 0:
        # fault injection and periodic snapshotting run under the
        # deterministic step clock (fault plans are step-indexed and a
        # crash-restored engine replays virtual time, not wall time);
        # dt includes the (first) warm-up — run_resilient owns building
        plan = (FaultPlan.seeded(args.faults, n_steps=max(64, 4 * len(work)),
                                 n_slots=args.slots, crashes=1)
                if args.faults is not None else None)
        t0 = time.time()
        res = run_resilient(build, work, faults=plan,
                            snapshot_every=args.snapshot_every,
                            max_steps=1_000_000)
        dt = time.time() - t0
        eng, report = res["engine"], res["report"]
        print(f"resilient: {res['n_crashes']} crash(es) recovered from "
              f"snapshot, {res['n_snapshots']} periodic snapshots"
              + (f", fault plan {plan!r}" if plan is not None else ""))
    else:
        eng = build()
        t0 = time.time()
        clock = None if args.virtual_clock else (lambda: time.time() - t0)
        report = traffic.replay(eng, work, clock=clock,
                                max_steps=1_000_000)
        dt = time.time() - t0
    done = report["requests"]
    total_tok = sum(len(r.tokens) for r in done)
    print(f"continuous: {len(done)} requests, {total_tok} tokens in {dt:.2f}s "
          f"({total_tok / dt:.1f} tok/s; {eng.n_decode_steps} decode steps, "
          f"{eng.n_prefills} prefills)")
    print(f"  prefilled {eng.n_prefill_tokens} prompt tokens, "
          f"{eng.n_shared_tokens} reused from the prefix cache "
          f"({eng.pool.n_cached} pages cached)")
    if args.preempt:
        sc = report["scheduler"]
        sp = report["spill"]
        print(f"  overload {sc['n_preemptions']} preemptions "
              f"({sp['spilled_pages']} pages spilled, "
              f"{sp['restored_pages']} restored), "
              f"{sc['n_rejected']} rejected, "
              f"{sc['n_finished_preempted']} finished after preemption")
    virtual = (args.virtual_clock or args.faults is not None
               or args.snapshot_every > 0)
    print(traffic.format_report(report, unit="steps" if virtual else "s"))
    if args.spec_decode:
        st = eng.spec_stats()
        print(f"  spec     {st['rounds']} rounds, {st['draft_tokens']} draft "
              f"tokens proposed, {st['accepted_draft_tokens']} accepted "
              f"(rate {st['acceptance_rate']:.3f})")
        print(f"  accepted len  mean {st['mean_accepted_len']:.2f} "
              f"tokens/slot-round, per slot "
              f"{st['per_slot_mean_accepted_len']}")
    if sanitize_enabled():
        # REPRO_SANITIZE=1: show which jit variants this run compiled and
        # whether any cache-key leak forced a variant to retrace
        print(sanitize.format_report())
        over = sanitize.budget_violations(max_per_key=1)
        if over:
            print(f"  WARNING: {len(over)} variant(s) exceeded the "
                  "per-variant compile budget (see repro.analysis.sanitize)")
    print("request 0:", done[0].tokens)


def run_static(cfg, params, work, args):
    """Static-batch baseline: uniform-length groups decoded in lockstep."""
    eng = ServeEngine(cfg, params)
    groups: dict[int, list] = {}
    for it in work:
        groups.setdefault(len(it.prompt), []).append((it.prompt, it.max_new))
    t0 = time.time()
    total = 0
    for plen, items in sorted(groups.items()):
        for i in range(0, len(items), args.slots):
            chunk = items[i:i + args.slots]
            prompts = np.stack([p for p, _ in chunk])
            mnew = max(m for _, m in chunk)
            eng.generate(prompts, max_new=mnew, temperature=0.0)
            total += sum(m for _, m in chunk)
    dt = time.time() - t0
    print(f"static: {len(work)} requests, {total} useful tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s, incl. compile)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    choices=["tiny"] + list_archs())
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="use the reduced config of --arch (--no-smoke for "
                         "full size)")
    ap.add_argument("--method", default="gptq",
                    choices=["gptq", "rtn", "smoothquant", "none"])
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--group-size", type=int, default=-1)
    ap.add_argument("--no-tweak", action="store_true")
    ap.add_argument("--engine", default="continuous",
                    choices=["continuous", "static", "both"])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=8.0,
                    help="Poisson arrival rate, req/s (0 = all at t=0)")
    ap.add_argument("--trace", default="poisson",
                    choices=["poisson", "bursty", "uniform"],
                    help="arrival shape (bursty = on/off overload)")
    ap.add_argument("--batch-frac", type=float, default=0.5,
                    help="fraction of requests in the batch SLO class "
                         "(deterministic stride, not sampled)")
    ap.add_argument("--burst-len", type=float, default=4.0,
                    help="bursty trace: on-phase length, time units")
    ap.add_argument("--idle-len", type=float, default=8.0,
                    help="bursty trace: off-phase length, time units")
    ap.add_argument("--burst-rate-mult", type=float, default=8.0,
                    help="bursty trace: rate multiplier during a burst")
    ap.add_argument("--preempt", action="store_true",
                    help="priority scheduling with preemptive KV spill: "
                         "interactive arrivals evict batch victims to host "
                         "RAM under slot/page pressure")
    ap.add_argument("--age-promote", type=float, default=None,
                    help="promote a batch request to interactive priority "
                         "after waiting this long (starvation bound)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request SLO: shed from the queue or cancel "
                         "mid-run any request still unfinished this many "
                         "time units after its arrival")
    ap.add_argument("--faults", type=int, default=None, metavar="SEED",
                    help="inject a seeded chaos schedule (nan logits, pool "
                         "exhaustion, kernel faults, corrupt spills, "
                         "latency spikes, one crash) and serve through it "
                         "via the crash-recovery driver; implies the "
                         "virtual clock")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="snapshot the full engine state every N steps "
                         "(0 = off); with --faults the crash recovers "
                         "from the latest snapshot")
    ap.add_argument("--virtual-clock", action="store_true",
                    help="drive the run on the deterministic step clock "
                         "instead of wall time")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel width for the continuous engine "
                         "(shards heads/mlp/KV pools over a 'model' mesh; "
                         "on CPU force devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--paged-attn", default=None,
                    choices=["fused", "gather"],
                    help="decode attention path: fused paged-attention "
                         "kernel (config default) or the gather oracle")
    ap.add_argument("--prefill-bucket", type=int, default=16)
    ap.add_argument("--prefix-share", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="reuse full prompt-prefix pages across requests "
                         "(attention-only archs)")
    ap.add_argument("--chunked-prefill", type=int, default=0,
                    help="max tokens per prefill chunk, page-aligned "
                         "(0 = whole prompt in one call)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend a common system prompt of this many "
                         "tokens to every request")
    ap.add_argument("--spec-decode", action="store_true",
                    help="self-speculative decoding: a truly-packed W2/W3 "
                         "draft of the same checkpoint proposes, the target "
                         "verifies (greedy output bit-identical to "
                         "target-only decode)")
    ap.add_argument("--draft-bits", type=int, default=2, choices=(2, 3),
                    help="draft weight width (packed sub-byte)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="max draft proposals per verify round")
    ap.add_argument("--prompt-len-min", type=int, default=8)
    ap.add_argument("--prompt-len-max", type=int, default=64)
    ap.add_argument("--max-new-min", type=int, default=8)
    ap.add_argument("--max-new-max", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.enc_dec:
        raise SystemExit("whisper serving demo lives in tests/test_system.py")
    if args.tp > 1 and args.engine != "continuous":
        # the static baseline has no TP path — refusing beats silently
        # timing a differently-configured engine in a "comparison"
        raise SystemExit("--tp applies to the continuous engine only "
                         "(use --engine continuous)")
    if args.spec_decode and args.method != "none":
        # the normtweak pipeline hands back pre-packed QuantizedTensor
        # leaves; the engine quantizes its own draft from the float
        # checkpoint and refuses packed trees
        raise SystemExit("--spec-decode quantizes its own low-bit draft "
                         "from the float checkpoint; use --method none")
    if args.spec_decode and args.engine != "continuous":
        raise SystemExit("--spec-decode applies to the continuous engine "
                         "only (use --engine continuous)")
    n_dev = len(jax.devices())
    # with --tp the continuous engine owns placement (it builds a 1-D
    # ("model",) mesh and device_puts weights + KV pools itself), so the
    # GSPMD data-parallel ctx below stays out of its way
    if args.tp > 1:
        mesh, rules = None, None
    else:
        mesh = (jax.make_mesh((n_dev, 1), ("data", "model"))
                if n_dev > 1 else None)
        rules = rules_for_config(cfg, mesh) if mesh else None

    with sharding_ctx(mesh, rules):
        params = build_params(cfg, args)
        work = make_workload(cfg, args)
        if args.engine in ("continuous", "both"):
            run_continuous(cfg, params, work, args)
        if args.engine in ("static", "both"):
            run_static(cfg, params, work, args)


if __name__ == "__main__":
    main()
