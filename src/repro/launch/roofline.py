"""Roofline analysis from compiled AOT artifacts (no hardware needed).

Sources:
  * compiled.cost_analysis() -> per-device HLO FLOPs + bytes accessed
    (the compiled module is the post-SPMD per-device program);
  * compiled.as_text()       -> optimized HLO; collective ops are parsed and
    their wire bytes summed per semantics below.

TPU v5e constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 1


def collective_bytes(hlo_text: str) -> dict:
    """Wire bytes per collective kind (operand-size convention):
    all-reduce/all-to-all/permute: result size; all-gather: result/G;
    reduce-scatter: result*G."""
    out = {k: 0 for k in _COLL_KINDS}
    counts = {k: 0 for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"%?[\w.\-]+ = (.*)$", stripped)
        if m is None:
            continue
        rhs = m.group(1)
        kind = None
        for k in _COLL_KINDS:
            # result type(s) then " kind(" — exclude -done/-start suffix dups
            km = re.search(r"\s" + k + r"(-start)?\(", rhs)
            if km:
                kind = k
                lhs_types = rhs[:km.start()]
                break
        if kind is None:
            continue
        size = _shape_bytes(lhs_types)
        g = _group_size(line)
        if kind == "all-gather":
            size = size // max(g, 1)
        elif kind == "reduce-scatter":
            size = size * max(g, 1)
        out[kind] += size
        counts[kind] += 1
    out["total"] = sum(out[k] for k in _COLL_KINDS)
    out["counts"] = counts
    return out


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    model_flops_total: Optional[float] = None
    useful_flops_ratio: Optional[float] = None

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the binding roofline that useful model FLOPs achieve:
        (model_flops/chips/peak) / max(term)."""
        if not self.model_flops_total or self.step_time_s <= 0:
            return 0.0
        ideal = self.model_flops_total_per_device / PEAK_FLOPS
        return ideal / self.step_time_s

    @property
    def model_flops_total_per_device(self):
        return (self.model_flops_total or 0.0) / max(self._chips, 1)

    _chips: int = 1


def roofline(cost: dict, coll: dict, *, chips: int,
             model_flops_total: Optional[float] = None) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    cb = float(coll.get("total", 0))
    t = RooflineTerms(
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=cb / ICI_BW,
        flops_per_device=flops,
        bytes_per_device=byts,
        coll_bytes_per_device=cb,
        model_flops_total=model_flops_total,
    )
    t._chips = chips
    if model_flops_total and flops > 0:
        t.useful_flops_ratio = (model_flops_total / chips) / flops
    return t


def count_params(params_shape, *, exclude=("embed", "pos")) -> int:
    """Total param count from an eval_shape tree, excluding embeddings."""
    import jax

    from repro.utils.tree import tree_map_with_path
    total = [0]

    def fn(path, leaf):
        if hasattr(leaf, "size") and not any(e in path for e in exclude):
            total[0] += int(leaf.size)
        return leaf

    tree_map_with_path(fn, params_shape)
    return total[0]


def model_flops(cfg, params_shape, shape_spec) -> float:
    """6·N_active·D (train) or 2·N_active·D (inference) global FLOPs."""
    n_total = count_params(params_shape)
    n_active = n_total
    if cfg.moe is not None:
        # routed experts: only top_k/E of expert params are active per token
        e, k = cfg.moe.n_experts, cfg.moe.top_k
        expert_params = 0
        n_moe_layers = sum(1 for s in cfg.all_layer_specs() if s.mlp == "moe")
        expert_params = n_moe_layers * e * 3 * cfg.d_model * cfg.moe.d_ff_expert
        n_active = n_total - expert_params + expert_params * k / e
    if shape_spec.kind == "train":
        tokens = shape_spec.global_batch * shape_spec.seq_len
        return 6.0 * n_active * tokens
    if shape_spec.kind == "prefill":
        tokens = shape_spec.global_batch * shape_spec.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape_spec.global_batch  # decode: 1 token/seq
