"""Byte-level tokenizer for text demos (vocab = 256 bytes + specials)."""
from __future__ import annotations

import numpy as np

PAD, BOS, EOS, UNK = 0, 1, 2, 3
N_SPECIALS = 4


class ByteTokenizer:
    """Reversible byte tokenizer; ids are offset past the special tokens so
    it composes with the synthetic corpus (which reserves ids < 4)."""

    vocab_size = 256 + N_SPECIALS

    def encode(self, text: str, *, bos: bool = False,
               eos: bool = False) -> np.ndarray:
        ids = np.frombuffer(text.encode("utf-8"), dtype=np.uint8).astype(
            np.int32) + N_SPECIALS
        parts = []
        if bos:
            parts.append([BOS])
        parts.append(ids)
        if eos:
            parts.append([EOS])
        return np.concatenate([np.asarray(p, np.int32) for p in parts])

    def decode(self, ids) -> str:
        arr = np.asarray(ids, dtype=np.int64).ravel()
        arr = arr[(arr >= N_SPECIALS) & (arr < self.vocab_size)]
        return (arr - N_SPECIALS).astype(np.uint8).tobytes().decode(
            "utf-8", errors="replace")
