"""Synthetic multi-language corpus (offline stand-in for BLOOM-style data).

The paper's calibration-generation insight (Table 1/8) hinges on a skew
between *corpus* language proportions and *vocabulary* share. We reproduce
that structure synthetically: the vocab is partitioned into `n_languages`
id ranges with roughly equal vocab share, but the training corpus mixes
languages with a heavily skewed distribution (~55/20/10/...). Each language
is a seeded first-order Markov chain (so a tiny LM can actually learn it,
and quantization damage is measurable as PPL).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CorpusMeta:
    vocab_size: int
    n_languages: int
    lang_ranges: list[tuple[int, int]]   # [start, end) token ids per language
    mixture: np.ndarray                  # corpus share per language
    transitions: list[np.ndarray]        # per-language (size, branching) maps

    def top_language_tokens(self, top_k: int = 2) -> np.ndarray:
        """First-token restriction set: ids of the top-k corpus languages
        (the paper's 'language scope restriction', GenData V2)."""
        order = np.argsort(-self.mixture)[:top_k]
        ids = [np.arange(*self.lang_ranges[l]) for l in order]
        return np.concatenate(ids)


def make_corpus(vocab_size: int = 256, n_tokens: int = 200_000,
                n_languages: int = 4, branching: int = 4, seed: int = 0,
                reserved: int = 4):
    """Returns (tokens np.int32 (n_tokens,), CorpusMeta). ids < reserved are
    specials (pad/bos/eos/unk) and never appear in the corpus."""
    rng = np.random.default_rng(seed)
    usable = vocab_size - reserved
    per = usable // n_languages
    ranges = [(reserved + i * per, reserved + (i + 1) * per)
              for i in range(n_languages)]
    mixture = np.array([0.55, 0.20, 0.10, 0.15 / max(n_languages - 3, 1)]
                       [:n_languages], dtype=np.float64)
    if n_languages > 4:
        mixture = np.concatenate(
            [mixture, np.full(n_languages - 4, 0.15 / (n_languages - 3))])
    mixture = mixture / mixture.sum()

    transitions = []
    for lo, hi in ranges:
        size = hi - lo
        trans = rng.integers(0, size, size=(size, branching))
        transitions.append(trans)

    out = np.empty(n_tokens, dtype=np.int32)
    i = 0
    while i < n_tokens:
        lang = rng.choice(n_languages, p=mixture)
        lo, hi = ranges[lang]
        trans = transitions[lang]
        length = int(rng.integers(32, 128))
        tok = int(rng.integers(0, hi - lo))
        for _ in range(min(length, n_tokens - i)):
            out[i] = lo + tok
            i += 1
            tok = int(trans[tok, rng.integers(0, branching)])
    meta = CorpusMeta(vocab_size, n_languages, ranges, mixture, transitions)
    return out, meta


def heldout_split(tokens: np.ndarray, frac: float = 0.05):
    cut = int(len(tokens) * (1.0 - frac))
    return tokens[:cut], tokens[cut:]


def make_eval_sets(meta: CorpusMeta, n_tokens: int = 20_000, seed: int = 1):
    """Per-language held-out corpora — the WikiText2/PTB/C4 analogue for the
    Table 8 cross-dataset generalization ablation."""
    sets = {}
    for l in range(meta.n_languages):
        rng = np.random.default_rng(seed + 100 + l)
        lo, hi = meta.lang_ranges[l]
        trans = meta.transitions[l]
        out = np.empty(n_tokens, dtype=np.int32)
        tok = int(rng.integers(0, hi - lo))
        for i in range(n_tokens):
            out[i] = lo + tok
            tok = int(trans[tok, rng.integers(0, trans.shape[1])])
        sets[f"lang{l}"] = out
    return sets
