"""Deterministic, sharded, resumable data pipeline.

Every batch is a pure function of (seed, step, shard) — a restart after a
failure resumes bit-exactly from the checkpointed step with no data replay
or skip, and elastic re-sharding (different n_shards) keeps coverage.
A background prefetch thread hides host-side batch assembly.
"""
from __future__ import annotations

import queue
import threading

import numpy as np


class DataPipeline:
    def __init__(self, tokens: np.ndarray, *, batch_size: int, seq_len: int,
                 shard_id: int = 0, n_shards: int = 1, seed: int = 0,
                 prefetch: int = 2):
        assert batch_size % n_shards == 0
        self.tokens = tokens
        self.batch = batch_size
        self.local_batch = batch_size // n_shards
        self.seq = seq_len
        self.shard_id = shard_id
        self.n_shards = n_shards
        self.seed = seed
        self.n_windows = max(1, (len(tokens) - 1) // seq_len)
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._thread = None
        self._stop = threading.Event()

    def batch_at(self, step: int) -> dict:
        """Pure: the global batch for `step`, restricted to this shard."""
        rng = np.random.default_rng((self.seed, step))
        idx = rng.integers(0, self.n_windows, size=self.batch)
        idx = idx[self.shard_id * self.local_batch:
                  (self.shard_id + 1) * self.local_batch]
        starts = idx * self.seq
        toks = np.stack([self.tokens[s:s + self.seq] for s in starts])
        labels = np.stack([self.tokens[s + 1:s + self.seq + 1] for s in starts])
        return {"tokens": toks.astype(np.int32),
                "labels": labels.astype(np.int32)}

    # ----------------------------------------------------- prefetch iterator
    def start(self, start_step: int):
        self._stop.clear()

        def work():
            step = start_step
            while not self._stop.is_set():
                b = self.batch_at(step)
                while not self._stop.is_set():
                    try:
                        self._q.put((step, b), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                step += 1

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def next(self) -> tuple[int, dict]:
        return self._q.get()

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
        while not self._q.empty():
            self._q.get_nowait()
