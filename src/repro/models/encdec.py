"""Encoder-decoder LM (Whisper backbone). Frontend conv is a stub: the
encoder consumes precomputed frame embeddings (B, S_enc, d_model)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.norms import apply_norm, init_norm
from repro.models.rope import sinusoidal_positions
from repro.models.transformer import (_head, _run_stack, init_cache, init_lm,
                                      _embed)
from repro.models.blocks import init_block
from repro.utils.tree import tree_stack


def enc_config(cfg: ModelConfig) -> ModelConfig:
    return cfg.replace(pattern=cfg.enc_pattern, n_repeats=cfg.n_enc_repeats,
                       prefix_pattern=(), enc_dec=False, pos_emb="none",
                       attn_window=None, frontend="none")


def dec_config(cfg: ModelConfig) -> ModelConfig:
    return cfg.replace(enc_dec=False, frontend="none")


def init_encdec(cfg: ModelConfig, key) -> dict:
    ke, kd = jax.random.split(key)
    ecfg = enc_config(cfg)
    enc = {"final_norm": init_norm(ecfg, ecfg.d_model), "stack": {}}
    ks = jax.random.split(ke, len(ecfg.pattern))
    for j, spec in enumerate(ecfg.pattern):
        reps = [init_block(ecfg, spec, kk)
                for kk in jax.random.split(ks[j], ecfg.n_repeats)]
        enc["stack"][f"p{j}"] = tree_stack(reps)
    dec = init_lm(dec_config(cfg), kd)
    return {"enc": enc, "dec": dec}


def encode(cfg: ModelConfig, params: dict, frames: jax.Array) -> jax.Array:
    """frames: (B, S_enc, d) precomputed embeddings (conv frontend stub)."""
    ecfg = enc_config(cfg)
    b, s, d = frames.shape
    x = frames.astype(ecfg.adtype) + sinusoidal_positions(s, d, ecfg.adtype)[None]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x, _, _ = _run_stack(ecfg, params["enc"], x, positions=positions,
                         mode="encode", cache=None)
    return apply_norm(ecfg, params["enc"]["final_norm"], x)


def encdec_forward(cfg: ModelConfig, params: dict, frames: jax.Array,
                   tokens: jax.Array):
    """Teacher-forced forward. Returns (dec logits f32, aux)."""
    enc_out = encode(cfg, params, frames)
    dcfg = dec_config(cfg)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = _embed(dcfg, params["dec"], tokens, None, positions)
    x, _, aux = _run_stack(dcfg, params["dec"], x, positions=positions,
                           mode="train", cache=None, enc_out=enc_out)
    return _head(dcfg, params["dec"], x), aux


def encdec_loss(cfg: ModelConfig, params: dict, batch: dict):
    logits, aux = encdec_forward(cfg, params, batch["frames"], batch["tokens"])
    labels = batch["labels"]
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    mask = batch.get("mask", jnp.ones_like(nll))
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + aux, {"nll": loss, "aux": aux}


def encdec_init_cache(cfg: ModelConfig, batch: int, max_len: int,
                      enc_len: int) -> dict:
    return init_cache(dec_config(cfg), batch, max_len, enc_len=enc_len)


def encdec_prefill(cfg: ModelConfig, params: dict, frames: jax.Array,
                   tokens: jax.Array, cache: dict):
    """Encode audio + ingest decoder prompt. Returns (logits (B,V), cache)."""
    enc_out = encode(cfg, params, frames)
    dcfg = dec_config(cfg)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = _embed(dcfg, params["dec"], tokens, None, positions)
    x, new_cache, _ = _run_stack(dcfg, params["dec"], x, positions=positions,
                                 mode="prefill", cache=cache, enc_out=enc_out)
    logits = _head(dcfg, params["dec"], x[:, -1:, :])
    return logits[:, 0, :], new_cache


def encdec_decode(cfg: ModelConfig, params: dict, tokens: jax.Array,
                  cache: dict, positions: jax.Array):
    """One decoder step against cached self+cross K/V."""
    dcfg = dec_config(cfg)
    x = _embed(dcfg, params["dec"], tokens, None, positions)
    x, new_cache, _ = _run_stack(dcfg, params["dec"], x, positions=positions,
                                 mode="decode", cache=cache, enc_out=None)
    logits = _head(dcfg, params["dec"], x)
    return logits[:, 0, :], new_cache
