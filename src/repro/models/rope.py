"""Rotary position embeddings: full (llama), half ("2d", ChatGLM) variants."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def apply_rope(x: jax.Array, positions: jax.Array, *, theta: float = 10000.0,
               variant: str = "full") -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32. Rotate-half convention.

    variant "full": rotate all head dims; "half": rotate only the first
    hd//2 dims (ChatGLM's 2D RoPE applies rotary to half the channels);
    "none": identity.
    """
    if variant == "none":
        return x
    hd = x.shape[-1]
    rot = hd if variant == "full" else hd // 2
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs       # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]                            # (B, S, 1, half)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = xr[..., :half].astype(jnp.float32), xr[..., half:].astype(jnp.float32)
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    out = jnp.concatenate([rotated.astype(x.dtype), xp], axis=-1)
    return out


def sinusoidal_positions(seq_len: int, d_model: int, dtype=jnp.float32) -> jax.Array:
    """Whisper-style fixed sinusoidal table (seq_len, d_model)."""
    half = d_model // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / (half - 1))
    ang = jnp.arange(seq_len)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)
