"""Attention: GQA/MQA with full/causal/sliding-window/cross modes.

Long sequences use chunked online-softmax attention (flash-style, pure JAX
`lax.scan` over KV blocks) so prefill at 32k+ never materializes an (S, S)
score matrix. Decode uses a single-shot masked pass over the cache.

KV caches:
  * contiguous: {"k","v": (B, Smax, KVH, hd), "pos": (B, Smax) abs positions
    (-1 = empty), "len": (B,) fill counts}
  * sliding-window (Mixtral SWA): same structure with Smax = window; writes
    wrap modulo window (ring buffer), masking is driven by the "pos" array.
  * paged (continuous batching): {"k_pool","v_pool": (P, page, KVH, hd)};
    decode runs the fused Pallas paged-attention kernel by default
    (cfg.paged_attn_impl == "fused"): the kernel walks the slot's block
    table directly and dequantizes int8 K/V inline, so no gathered
    (S, maxp*page, ...) view is ever materialized. `paged_attn_impl ==
    "gather"` keeps the gather->dequant->einsum oracle path, which also
    serves paged *prefill* (see serve/kvcache.py, DESIGN.md
    "Paged-attention decode kernel").
RoPE is applied before cache insertion (post-rope keys are cached).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import TP_AXIS, lc
from repro.kernels.ops import (paged_attention, paged_attention_prefill,
                               paged_attention_verify)
from repro.models.config import ModelConfig
from repro.models.linear import dense, init_dense
from repro.models.rope import apply_rope
from repro.serve.kvcache import (PageSpec, contiguous_positions,
                                 gather_dequant_pages, gather_pages,
                                 prefill_page_index)

NEG = -1e30


def _mask(q_pos, kv_pos, *, causal: bool, window: Optional[int]):
    """q_pos: (B, Sq); kv_pos: (B, Skv) absolute positions (-1 = invalid)."""
    m = kv_pos[:, None, :] >= 0
    if causal:
        m &= kv_pos[:, None, :] <= q_pos[:, :, None]
    if window is not None:
        m &= kv_pos[:, None, :] > (q_pos[:, :, None] - window)
    return m  # (B, Sq, Skv)


def banded_attention(q, k, v, *, q_pos, kv_pos, window: int,
                     block_q: int = 1024):
    """Sliding-window self-attention that only computes the live band.

    Scans over q blocks; each block attends a (window + block_q)-wide key
    slice — O(S·window) compute/memory instead of O(S²) (the plain chunked
    path still *computes* fully-masked blocks). Requires sq == skv
    (aligned self-attention positions)."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    bq = min(block_q, s)
    pad_q = (-s) % bq
    w = window
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)), constant_values=-1)
    nb = (s + pad_q) // bq
    # pad keys with `w` dead slots in front so every slice is in-bounds
    kp = jnp.pad(k, ((0, 0), (w, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (w, 0), (0, 0), (0, 0)))
    pp = jnp.pad(kv_pos, ((0, 0), (w, 0)), constant_values=-1)

    def one_block(i):
        qi = jax.lax.dynamic_slice_in_dim(q, i * bq, bq, axis=1)
        qpi = jax.lax.dynamic_slice_in_dim(q_pos, i * bq, bq, axis=1)
        ki = jax.lax.dynamic_slice_in_dim(kp, i * bq, w + bq, axis=1)
        vi = jax.lax.dynamic_slice_in_dim(vp, i * bq, w + bq, axis=1)
        pi = jax.lax.dynamic_slice_in_dim(pp, i * bq, w + bq, axis=1)
        return attention_core(qi, ki, vi, q_pos=qpi, kv_pos=pi, causal=True,
                              window=w, block_kv=w + bq)

    out = jax.lax.map(one_block, jnp.arange(nb))        # (nb, B, bq, H, hdv)
    out = out.swapaxes(0, 1).reshape(b, nb * bq, h, v.shape[-1])
    return out[:, :s]


def attention_core(q, k, v, *, q_pos, kv_pos, causal=True,
                   window: Optional[int] = None, block_kv: int = 512,
                   banded: bool = False, chunked_decode: bool = False,
                   scores_dtype=jnp.float32):
    """q: (B,Sq,H,hd); k,v: (B,Skv,KVH,hd). Returns (B,Sq,H,hd)."""
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]  # may differ from hd (MLA)
    if (banded and causal and window is not None and sq == skv
            and sq > 2 * window):
        return banded_attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                                window=window,
                                block_q=max(256, min(1024, window)))
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, hd)
    scale = 1.0 / (hd ** 0.5)

    single_shot = skv <= block_kv or (sq == 1 and not chunked_decode)
    if single_shot:
        # keep operands in their storage dtype (bf16 on TPU) and accumulate
        # in f32 via preferred_element_type — materializing an f32 copy of a
        # (gathered) KV cache doubles decode HBM/ICI traffic
        s = jnp.einsum("bqkgh,btkh->bkgqt", qg, k,
                       preferred_element_type=jnp.float32) * scale
        m = _mask(q_pos, kv_pos, causal=causal, window=window)
        s = jnp.where(m[:, None, None, :, :], s, NEG)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqt,btkh->bqkgh", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        return o.reshape(b, sq, h, hd_v).astype(q.dtype)

    # chunked online softmax over KV blocks
    nblk = -(-skv // block_kv)
    pad = nblk * block_kv - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
    kc = k.reshape(b, nblk, block_kv, kvh, hd).swapaxes(0, 1)
    vc = v.reshape(b, nblk, block_kv, kvh, hd_v).swapaxes(0, 1)
    pc = kv_pos.reshape(b, nblk, block_kv).swapaxes(0, 1)

    def body(carry, xs):
        m_run, l_run, acc = carry
        kb, vb, pb = xs
        # scores materialize in `scores_dtype` (bf16 halves the dominant
        # HBM traffic); all reductions/accumulators stay f32
        s = jnp.einsum("bqkgh,btkh->bkgqt", qg, kb,
                       preferred_element_type=scores_dtype)
        s = (s.astype(jnp.float32)) * scale
        msk = _mask(q_pos, pb, causal=causal, window=window)
        s = jnp.where(msk[:, None, None, :, :], s, NEG)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        corr = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqt,btkh->bkgqh", p.astype(scores_dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc), None

    # flash-attention memory behaviour: without this, scan-backward stacks
    # every step's (B,KVH,G,Sq,block) score tensor as residuals — O(S²)
    # saved activations; with it only the O(S·hd) carries are saved and
    # scores are recomputed per block in the backward pass
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)

    init = (jnp.full((b, kvh, g, sq), NEG, jnp.float32),
            jnp.zeros((b, kvh, g, sq), jnp.float32),
            jnp.zeros((b, kvh, g, sq, hd_v), jnp.float32))
    (m_run, l_run, acc), _ = jax.lax.scan(body, init, (kc, vc, pc))
    o = acc / jnp.maximum(l_run, 1e-30)[..., None]
    o = o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd_v)
    return o.astype(q.dtype)


# ---------------------------------------------------------------- GQA module

def init_attention(cfg: ModelConfig, key, *, cross: bool = False) -> dict:
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": init_dense(ks[0], d, h * hd, bias=cfg.qkv_bias, dtype=cfg.pdtype),
        "wk": init_dense(ks[1], d, kvh * hd, bias=cfg.qkv_bias, dtype=cfg.pdtype),
        "wv": init_dense(ks[2], d, kvh * hd, bias=cfg.qkv_bias, dtype=cfg.pdtype),
        "wo": init_dense(ks[3], h * hd, d, bias=cfg.o_bias, dtype=cfg.pdtype),
    }


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                  window: Optional[int] = None) -> dict:
    size = min(max_len, window) if window else max_len
    kvh, hd = cfg.n_kv_heads, cfg.hd
    cache = {
        "pos": jnp.full((batch, size), -1, jnp.int32),
        "len": jnp.zeros((batch,), jnp.int32),
    }
    if cfg.kv_cache_bits == 8:
        # int8 storage with per-(token, head) scales: ~1.9x less HBM than
        # bf16 — beyond-paper extension of its low-bit deployment story
        cache["k"] = jnp.zeros((batch, size, kvh, hd), jnp.int8)
        cache["v"] = jnp.zeros((batch, size, kvh, hd), jnp.int8)
        cache["k_scale"] = jnp.zeros((batch, size, kvh), jnp.float32)
        cache["v_scale"] = jnp.zeros((batch, size, kvh), jnp.float32)
    else:
        cache["k"] = jnp.zeros((batch, size, kvh, hd), cfg.adtype)
        cache["v"] = jnp.zeros((batch, size, kvh, hd), cfg.adtype)
    return cache


def init_paged_kv_cache(cfg: ModelConfig, spec: PageSpec) -> dict:
    """Page-pool cache for one attention block (continuous batching).

    Sliding-window models still allocate full-length pages under paging;
    the window mask in attention_core keeps reads correct (see DESIGN.md).
    """
    kvh, hd = cfg.n_kv_heads, cfg.hd
    cache = {}
    if cfg.kv_cache_bits == 8:
        cache["k_pool"] = jnp.zeros((spec.n_pages, spec.page_size, kvh, hd),
                                    jnp.int8)
        cache["v_pool"] = jnp.zeros((spec.n_pages, spec.page_size, kvh, hd),
                                    jnp.int8)
        cache["k_scale_pool"] = jnp.zeros((spec.n_pages, spec.page_size, kvh),
                                          jnp.float32)
        cache["v_scale_pool"] = jnp.zeros((spec.n_pages, spec.page_size, kvh),
                                          jnp.float32)
    else:
        cache["k_pool"] = jnp.zeros((spec.n_pages, spec.page_size, kvh, hd),
                                    cfg.adtype)
        cache["v_pool"] = jnp.zeros((spec.n_pages, spec.page_size, kvh, hd),
                                    cfg.adtype)
    return cache


def _paged_update(cache: dict, k, v, positions, paged: dict):
    """Scatter new K/V into the page pool; return (new_cache, read view).

    Prefill (paged has "bt_rows"): writes a batch of admitted slots'
    (left-padded) prompts; the read view is the current sequence itself — a
    fresh request attends only to its own prompt. Chunked / prefix-suffix
    prefill (paged additionally has "kv_len": per-row total fill counts
    after this chunk) instead gathers the whole 0..kv_len-1 context back
    through the block-table rows, because earlier tokens live in pages the
    current call never saw — the slot's own earlier chunks, or shared
    prefix pages written by another request entirely. Decode (paged has
    "block_table"): writes one token per slot at (write_page, write_off),
    then gathers each slot's pages into a contiguous (S, width*page, ...)
    view for attention, with mask positions derived from the per-slot fill
    counts in paged["kv_len"]. Block tables passed for decode and chunked
    prefill may be truncated to the live read width (pow2 pages) by the
    engine.
    """
    new = dict(cache)
    quant = "k_scale_pool" in cache
    if "bt_rows" in paged:                          # prefill (batch of slots)
        bt = paged["bt_rows"]
        new = _paged_write_prefill(cache, k, v, positions, bt)
        if "kv_len" not in paged:           # fresh full prompt: self-attend
            return new, (k, v, positions)
        if quant:
            kg = gather_dequant_pages(new["k_pool"], new["k_scale_pool"],
                                      bt, k.dtype)
            vg = gather_dequant_pages(new["v_pool"], new["v_scale_pool"],
                                      bt, v.dtype)
        else:
            kg = gather_pages(new["k_pool"], bt)
            vg = gather_pages(new["v_pool"], bt)
        kv_pos = contiguous_positions(paged["kv_len"], kg.shape[1])
        return new, (kg, vg, kv_pos)
    bt = paged["block_table"]                                 # decode step
    new = _paged_write_decode(cache, k, v, paged)
    if quant:
        # one gather+dequant call per pool (see gather_dequant_pages)
        kg = gather_dequant_pages(new["k_pool"], new["k_scale_pool"], bt,
                                  k.dtype)
        vg = gather_dequant_pages(new["v_pool"], new["v_scale_pool"], bt,
                                  v.dtype)
    else:
        kg = gather_pages(new["k_pool"], bt)
        vg = gather_pages(new["v_pool"], bt)
    kv_pos = contiguous_positions(paged["kv_len"], kg.shape[1])
    return new, (kg, vg, kv_pos)


def _paged_write_prefill(cache: dict, k, v, positions, bt) -> dict:
    """Scatter a (B, S) batch of tokens at their block-table page slots
    (negative positions route to the reserved scratch page). Shared by the
    paged prefill path and the spec-decode verify write."""
    ps = cache["k_pool"].shape[1]
    pages, offs = prefill_page_index(bt, positions, ps)
    new = dict(cache)
    if "k_scale_pool" in cache:
        kq, ks = _quant_kv(k)
        vq, vs = _quant_kv(v)
        new["k_pool"] = cache["k_pool"].at[pages, offs].set(kq)
        new["v_pool"] = cache["v_pool"].at[pages, offs].set(vq)
        new["k_scale_pool"] = cache["k_scale_pool"].at[pages, offs].set(ks)
        new["v_scale_pool"] = cache["v_scale_pool"].at[pages, offs].set(vs)
    else:
        new["k_pool"] = cache["k_pool"].at[pages, offs].set(
            k.astype(cache["k_pool"].dtype))
        new["v_pool"] = cache["v_pool"].at[pages, offs].set(
            v.astype(cache["v_pool"].dtype))
    return new


def _paged_write_decode(cache: dict, k, v, paged: dict) -> dict:
    """Scatter one decode token per slot at (write_page, write_off).

    Shared by the fused-kernel and gather decode paths — the fused path
    stops here and hands the pools straight to kernels/paged_attention."""
    new = dict(cache)
    wp, wo = paged["write_page"], paged["write_off"]
    if "k_scale_pool" in cache:
        kq, ks = _quant_kv(k)
        vq, vs = _quant_kv(v)
        new["k_pool"] = cache["k_pool"].at[wp, wo].set(kq[:, 0])
        new["v_pool"] = cache["v_pool"].at[wp, wo].set(vq[:, 0])
        new["k_scale_pool"] = cache["k_scale_pool"].at[wp, wo].set(ks[:, 0])
        new["v_scale_pool"] = cache["v_scale_pool"].at[wp, wo].set(vs[:, 0])
    else:
        new["k_pool"] = cache["k_pool"].at[wp, wo].set(
            k[:, 0].astype(cache["k_pool"].dtype))
        new["v_pool"] = cache["v_pool"].at[wp, wo].set(
            v[:, 0].astype(cache["v_pool"].dtype))
    return new


def _quant_kv(x: jax.Array):
    """x: (B, S, KVH, hd) -> (int8 values, (B, S, KVH) scales)."""
    amax = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1), 1e-6)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def _cache_write(cache: dict, k, v, positions) -> dict:
    """Write S new entries at ring slots positions % size.

    If S exceeds the ring size (SWA prefill longer than the window), only the
    last `size` entries are written — older ones could never be attended to,
    and truncating keeps ring slots unique within one scatter.
    """
    b, s = positions.shape
    size = cache["k"].shape[1]
    if s > size:
        k, v, positions = k[:, -size:], v[:, -size:], positions[:, -size:]
        s = size
    slots = positions % size                                   # (B, S)
    new = dict(cache)
    bidx = jnp.arange(b)[:, None]
    if "k_scale" in cache:  # int8 cache
        kq, ks = _quant_kv(k)
        vq, vs = _quant_kv(v)
        new["k"] = cache["k"].at[bidx, slots].set(kq)
        new["v"] = cache["v"].at[bidx, slots].set(vq)
        new["k_scale"] = cache["k_scale"].at[bidx, slots].set(ks)
        new["v_scale"] = cache["v_scale"].at[bidx, slots].set(vs)
    else:
        new["k"] = cache["k"].at[bidx, slots].set(k.astype(cache["k"].dtype))
        new["v"] = cache["v"].at[bidx, slots].set(v.astype(cache["v"].dtype))
    new["pos"] = cache["pos"].at[bidx, slots].set(positions)
    new["len"] = cache["len"] + s
    return new


def apply_attention(cfg: ModelConfig, p: dict, x: jax.Array, *,
                    positions: jax.Array, causal: bool = True,
                    window: Optional[int] = None,
                    cache: Optional[dict] = None,
                    kv_src: Optional[jax.Array] = None,
                    kv_positions: Optional[jax.Array] = None,
                    rope_variant: Optional[str] = None,
                    paged: Optional[dict] = None,
                    taps: Optional[dict] = None, tap_prefix: str = ""):
    """Returns (y, new_cache). `kv_src` => cross-attention (no rope/cache-write
    unless cache holds precomputed cross K/V under k/v). `paged` carries the
    block-table indices for a paged cache (see serve/kvcache.py)."""
    b, s, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    rope_variant = rope_variant if rope_variant is not None else cfg.rope

    if taps is not None:
        taps[tap_prefix + "wq"] = x
        if kv_src is None:
            taps[tap_prefix + "wk"] = x
            taps[tap_prefix + "wv"] = x
        else:
            taps[tap_prefix + "wk"] = kv_src
            taps[tap_prefix + "wv"] = kv_src

    q = dense(p["wq"], x).reshape(b, s, h, hd)
    q = lc(q, "batch", "seq", "heads", "head_dim")
    q = apply_rope(q, positions, theta=cfg.rope_theta, variant=rope_variant)

    fused_o = None
    if (cache is not None and "len" not in cache and "k_pool" not in cache
            and kv_src is None):
        # precomputed cross-attention K/V (whisper decode)
        k, v = cache["k"], cache["v"]
        kv_pos = cache["pos"]
        new_cache = cache
    else:
        src = kv_src if kv_src is not None else x
        kv_b, kv_s = src.shape[0], src.shape[1]
        k = dense(p["wk"], src).reshape(kv_b, kv_s, kvh, hd)
        v = dense(p["wv"], src).reshape(kv_b, kv_s, kvh, hd)
        kpos = kv_positions if kv_positions is not None else positions
        k = apply_rope(k, kpos, theta=cfg.rope_theta, variant=rope_variant)
        if (cache is not None and "k_pool" in cache
                and paged is not None and "block_table" in paged
                and s == 1 and cfg.paged_attn_impl == "fused"):
            # fused paged decode: scatter the new token into the pools, then
            # walk the block table *inside* the kernel — int8 K/V dequantized
            # inline from the scale pools, no gathered (S, maxp*page, ...)
            # view in HBM, dead pages never read
            new_cache = _paged_write_decode(cache, k, v, paged)
            fused_o = paged_attention(
                q[:, 0], new_cache["k_pool"], new_cache["v_pool"],
                paged["block_table"], paged["kv_len"],
                k_scale_pool=new_cache.get("k_scale_pool"),
                v_scale_pool=new_cache.get("v_scale_pool"),
                window=window, out_dtype=q.dtype)[:, None]
        elif (cache is not None and "k_pool" in cache
                and paged is not None and "verify" in paged
                and cfg.paged_attn_impl == "fused"):
            # spec-decode verify: scatter the s tail tokens with the prefill
            # scatter (inactive slots carry positions < 0 and route to the
            # scratch page), then read all s rows in one fused page walk
            # with per-row causal fill masks — each live KV tile streams
            # once for the whole verify batch
            new_cache = _paged_write_prefill(cache, k, v, kpos,
                                             paged["bt_rows"])
            fused_o = paged_attention_verify(
                q, new_cache["k_pool"], new_cache["v_pool"],
                paged["bt_rows"], paged["kv_len"],
                k_scale_pool=new_cache.get("k_scale_pool"),
                v_scale_pool=new_cache.get("v_scale_pool"),
                window=window, out_dtype=q.dtype)
        elif (cache is not None and "k_pool" in cache
                and paged is not None and "bt_rows" in paged
                and "kv_len" in paged and causal
                and cfg.paged_attn_impl == "fused"):
            # fused chunked/suffix prefill: scatter the left-padded chunk
            # with the prefill scatter (pad rows carry positions < 0 and
            # route to the scratch page), then read all s rows in one
            # fused page walk — row j sits at fill position kv_len - s + j
            # exactly like a verify row, so earlier context (prior chunks,
            # shared prefix pages) streams through the page walk instead
            # of being gathered into a contiguous HBM view
            new_cache = _paged_write_prefill(cache, k, v, kpos,
                                             paged["bt_rows"])
            fused_o = paged_attention_prefill(
                q, new_cache["k_pool"], new_cache["v_pool"],
                paged["bt_rows"], paged["kv_len"],
                k_scale_pool=new_cache.get("k_scale_pool"),
                v_scale_pool=new_cache.get("v_scale_pool"),
                window=window, out_dtype=q.dtype)
        elif cache is not None and "k_pool" in cache:
            # paged cache (continuous batching): scatter new K/V into the
            # page pool, read back via the slot block tables
            assert paged is not None, \
                "paged cache requires block-table indices"
            new_cache, (k, v, kv_pos) = _paged_update(cache, k, v, kpos,
                                                      paged)
        elif cache is not None and "len" not in cache:
            # cross-attention cache fill (enc-dec prefill)
            new_cache = {"k": k.astype(cache["k"].dtype),
                         "v": v.astype(cache["v"].dtype), "pos": kpos}
            kv_pos = kpos
        elif cache is not None:
            new_cache = _cache_write(cache, k, v, kpos)
            if s == 1:
                # decode: attend over the whole (ring) cache
                if "k_scale" in new_cache:
                    k = _dequant_kv(new_cache["k"], new_cache["k_scale"],
                                    x.dtype)
                    v = _dequant_kv(new_cache["v"], new_cache["v_scale"],
                                    x.dtype)
                else:
                    k, v = new_cache["k"], new_cache["v"]
                kv_pos = new_cache["pos"]
            else:
                # one-shot prefill: attend over the current sequence directly
                # (a ring cache may already have evicted early positions that
                # early queries still need; the banded mask handles windowing)
                kv_pos = kpos
        else:
            new_cache = None
            kv_pos = kpos
    if fused_o is not None:
        o = fused_o                                        # (B, 1, H, hd_v)
    else:
        k = lc(k, "batch", "kv_seq", "kv_heads", "head_dim")
        v = lc(v, "batch", "kv_seq", "kv_heads", "head_dim")
        o = attention_core(q, k, v, q_pos=positions, kv_pos=kv_pos,
                           causal=causal, window=window,
                           block_kv=cfg.attn_block_kv,
                           banded=cfg.banded_window_attn,
                           chunked_decode=cfg.chunked_decode,
                           scores_dtype=jnp.dtype(cfg.attn_scores_dtype))
    o = o.reshape(b, s, h * hd)
    if taps is not None:
        taps[tap_prefix + "wo"] = o
    # under serving TP (cfg.tp > 1, inside the engine's shard_map) the
    # output projection is row-parallel: each shard holds its heads' slice
    # of wo, so the matmul is a partial sum reduced over the model axis
    y = dense(p["wo"], o, reduce_axis=TP_AXIS if cfg.tp > 1 else None)
    return lc(y, "batch", "seq", "embed"), new_cache
