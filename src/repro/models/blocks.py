"""Transformer/Mamba block: init, cache init, and apply for all layer kinds."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import LayerSpec, ModelConfig
from repro.models.attention import (apply_attention, init_attention,
                                    init_kv_cache, init_paged_kv_cache)
from repro.models.mla import apply_mla, init_mla, init_mla_cache, \
    init_paged_mla_cache
from repro.models.mamba2 import apply_mamba, init_mamba, init_mamba_cache
from repro.models.mlp_moe import apply_mlp, apply_moe, init_mlp, init_moe
from repro.models.norms import apply_norm, init_norm
from repro.serve.kvcache import PageSpec


def init_block(cfg: ModelConfig, spec: LayerSpec, key) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: dict = {"ln1": init_norm(cfg, d)}
    if spec.kind == "attn":
        if cfg.attention == "mla":
            p["attn"] = init_mla(cfg, ks[0])
        else:
            p["attn"] = init_attention(cfg, ks[0])
        if spec.cross_attn:
            p["lnx"] = init_norm(cfg, d)
            p["xattn"] = init_attention(cfg, ks[1], cross=True)
    else:
        p["mamba"] = init_mamba(cfg, ks[0])
    if spec.mlp == "dense":
        p["ln2"] = init_norm(cfg, d)
        p["mlp"] = init_mlp(cfg, ks[2], cfg.d_ff)
    elif spec.mlp == "moe":
        p["ln2"] = init_norm(cfg, d)
        p["moe"] = init_moe(cfg, ks[2])
    return p


def init_block_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     max_len: int, enc_len: int = 0,
                     paged: Optional[PageSpec] = None) -> dict:
    """`paged`: build page-pool caches for continuous batching; `batch` is
    then the slot count (Mamba state caches stay slot-indexed, unpaged)."""
    c: dict = {}
    if spec.kind == "attn":
        if paged is not None:
            if spec.cross_attn:
                raise NotImplementedError(
                    "paged serving does not cover enc-dec cross-attention")
            c["attn"] = (init_paged_mla_cache(cfg, paged)
                         if cfg.attention == "mla"
                         else init_paged_kv_cache(cfg, paged))
            return c
        if cfg.attention == "mla":
            c["attn"] = init_mla_cache(cfg, batch, max_len)
        else:
            c["attn"] = init_kv_cache(cfg, batch, max_len,
                                      window=cfg.attn_window)
        if spec.cross_attn:
            kvh, hd = cfg.n_kv_heads, cfg.hd
            c["xattn"] = {
                "k": jnp.zeros((batch, enc_len, kvh, hd), cfg.adtype),
                "v": jnp.zeros((batch, enc_len, kvh, hd), cfg.adtype),
                "pos": jnp.full((batch, enc_len), -1, jnp.int32),
            }
    else:
        c["mamba"] = init_mamba_cache(cfg, batch)
    return c


def apply_block(cfg: ModelConfig, spec: LayerSpec, p: dict, x: jax.Array, *,
                positions: jax.Array, mode: str = "train",
                cache: Optional[dict] = None,
                enc_out: Optional[jax.Array] = None,
                paged: Optional[dict] = None,
                taps: Optional[dict] = None, tap_prefix: str = ""):
    """Returns (y, new_cache, aux). mode: train|encode|prefill|decode.
    `paged` carries block-table indices for paged caches (serve/kvcache.py)."""
    causal = mode != "encode"
    decode = mode == "decode"
    new_cache: dict = dict(cache) if cache is not None else None
    aux = jnp.zeros((), jnp.float32)

    h = apply_norm(cfg, p["ln1"], x)
    if spec.kind == "attn":
        if cfg.attention == "mla":
            y, nc = apply_mla(cfg, p["attn"], h, positions=positions,
                              cache=None if cache is None else cache["attn"],
                              decode=decode, paged=paged, taps=taps,
                              tap_prefix=tap_prefix + "attn/")
        else:
            y, nc = apply_attention(
                cfg, p["attn"], h, positions=positions, causal=causal,
                window=cfg.attn_window,
                cache=None if cache is None else cache["attn"],
                paged=paged, taps=taps, tap_prefix=tap_prefix + "attn/")
        if new_cache is not None and nc is not None:
            new_cache["attn"] = nc
    else:
        y, nc = apply_mamba(cfg, p["mamba"], h,
                            cache=None if cache is None else cache["mamba"],
                            decode=decode, positions=positions,
                            slot=None if paged is None else paged.get("slots"),
                            taps=taps, tap_prefix=tap_prefix + "mamba/")
        if new_cache is not None and nc is not None:
            new_cache["mamba"] = nc
    x = x + y

    if spec.cross_attn:
        hx = apply_norm(cfg, p["lnx"], x)
        xc = None if cache is None else cache.get("xattn")
        kv_src = enc_out
        kv_positions = None
        if enc_out is not None:
            kv_positions = jnp.broadcast_to(
                jnp.arange(enc_out.shape[1], dtype=jnp.int32)[None, :],
                (enc_out.shape[0], enc_out.shape[1]))
        y, ncx = apply_attention(
            cfg, p["xattn"], hx, positions=positions, causal=False,
            cache=xc, kv_src=kv_src, kv_positions=kv_positions,
            rope_variant="none", taps=taps, tap_prefix=tap_prefix + "xattn/")
        if new_cache is not None and ncx is not None:
            new_cache["xattn"] = ncx
        x = x + y

    if spec.mlp == "dense":
        h2 = apply_norm(cfg, p["ln2"], x)
        x = x + apply_mlp(cfg, p["mlp"], h2, taps, tap_prefix + "mlp/")
    elif spec.mlp == "moe":
        h2 = apply_norm(cfg, p["ln2"], x)
        # paged serving carries junk tokens that must not compete for
        # expert capacity (see apply_moe): left-padding in prefill
        # (pos = -1) and idle slots in decode (kv_len == 0). Unpaged modes
        # never do — pass None so the shard_map MoE fast path stays
        # available to them.
        if paged is not None and mode == "prefill":
            moe_valid = positions >= 0
        elif paged is not None and mode == "decode" and "kv_len" in paged:
            moe_valid = (paged["kv_len"] > 0)[:, None]
        else:
            moe_valid = None
        y2, aux = apply_moe(cfg, p["moe"], h2, taps, tap_prefix + "moe/",
                            valid=moe_valid)
        x = x + y2
    return x, new_cache, aux
