"""Mamba2 (SSD — state-space duality) block, chunked-parallel + recurrent decode.

Training/prefill run the chunk-parallel SSD form (arXiv:2405.21060 §6):
intra-chunk quadratic term + inter-chunk state recurrence via `lax.scan`.
Decode is the O(1) recurrence over the (H, P, N) state.

Params per layer: in_proj -> [z (di), xBC (di + 2*G*N), dt (H)], depthwise
causal conv over xBC, A_log/D/dt_bias per head, gated RMSNorm, out_proj.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import lc
from repro.models.config import ModelConfig
from repro.models.linear import dense, init_dense
from repro.models.norms import apply_gated_rmsnorm

def _dims(cfg: ModelConfig):
    m = cfg.mamba
    di = m.d_inner(cfg.d_model)
    h = m.n_heads(cfg.d_model)
    conv_dim = di + 2 * m.n_groups * m.d_state
    return m, di, h, conv_dim


def init_mamba(cfg: ModelConfig, key) -> dict:
    m, di, h, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * di + 2 * m.n_groups * m.d_state + h
    p = {
        "in_proj": init_dense(ks[0], cfg.d_model, d_in_proj, dtype=cfg.pdtype),
        "conv_w": (jax.random.normal(ks[1], (m.d_conv, conv_dim)) * 0.1
                   ).astype(cfg.pdtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.pdtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "gnorm": {"scale": jnp.ones((di,), cfg.pdtype)},
        "out_proj": init_dense(ks[2], di, cfg.d_model, dtype=cfg.pdtype),
    }
    return p


def init_mamba_cache(cfg: ModelConfig, batch: int) -> dict:
    m, di, h, conv_dim = _dims(cfg)
    return {
        "state": jnp.zeros((batch, h, m.head_dim, m.d_state), jnp.float32),
        "conv": jnp.zeros((batch, m.d_conv - 1, conv_dim), cfg.adtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def _split_proj(cfg, zxbcdt):
    m, di, h, conv_dim = _dims(cfg)
    gn = m.n_groups * m.d_state
    z, xbc, dt = jnp.split(zxbcdt, [di, di + di + 2 * gn], axis=-1)
    return z, xbc, dt


def _causal_conv(cfg, p, xbc, conv_state=None):
    """Depthwise causal conv1d over (B, L, C). Returns (y, new_conv_state)."""
    m = cfg.mamba
    w = p["conv_w"].astype(jnp.float32)                         # (K, C)
    kk = m.d_conv
    xf = xbc.astype(jnp.float32)
    if conv_state is not None:
        xf = jnp.concatenate([conv_state.astype(jnp.float32), xf], axis=1)
    else:
        xf = jnp.pad(xf, ((0, 0), (kk - 1, 0), (0, 0)))
    # y[t] = sum_k w[k] * x[t + k]  over the padded sequence
    y = sum(xf[:, i:i + xbc.shape[1], :] * w[i] for i in range(kk))
    y = y + p["conv_b"].astype(jnp.float32)
    new_state = xf[:, -(kk - 1):, :].astype(xbc.dtype) if kk > 1 else None
    return jax.nn.silu(y).astype(xbc.dtype), new_state


def _ssd_chunked(cfg, x, dt, a, bm, cm):
    """Chunk-parallel SSD.

    x: (B,L,H,P) head inputs; dt: (B,L,H) post-softplus; a: (H,) negative;
    bm, cm: (B,L,G,N). Returns (y: (B,L,H,P), final_state: (B,H,P,N)).
    """
    m = cfg.mamba
    b, l0, h, pdim = x.shape
    g, n = bm.shape[2], bm.shape[3]
    q = min(m.chunk, l0)
    pad = (-l0) % q
    if pad:  # zero-pad: dt=0 -> decay 1, x=0 -> no state contribution
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    l = l0 + pad
    nc = l // q
    rep = h // g

    # reshape into chunks
    xc = x.reshape(b, nc, q, h, pdim).astype(jnp.float32)
    dtc = dt.reshape(b, nc, q, h).astype(jnp.float32)
    bc = jnp.repeat(bm.reshape(b, nc, q, g, n), rep, axis=3).astype(jnp.float32)
    cc = jnp.repeat(cm.reshape(b, nc, q, g, n), rep, axis=3).astype(jnp.float32)

    da = dtc * a[None, None, None, :]                            # (B,nc,Q,H)
    cs = jnp.cumsum(da, axis=2)                                  # within-chunk cumsum
    xdt = xc * dtc[..., None]                                    # (B,nc,Q,H,P)

    # intra-chunk (diagonal blocks): att[q,t] = exp(cs_q - cs_t), t <= q
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]            # (B,nc,Q,T,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    seg = jnp.where(tri[None, None, :, :, None], seg, -jnp.inf)
    att = jnp.exp(seg) * jnp.einsum("bcqhn,bcthn->bcqth", cc, bc)
    y_diag = jnp.einsum("bcqth,bcthp->bcqhp", att, xdt)

    # per-chunk end states: S_c = sum_t exp(cs_last - cs_t) * B_t x_t dt_t
    decay = jnp.exp(cs[:, :, -1:, :] - cs)                       # (B,nc,Q,H)
    states = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn", decay, bc, xdt)

    # inter-chunk recurrence over nc
    chunk_decay = jnp.exp(cs[:, :, -1, :])                       # (B,nc,H)

    def body(s_prev, xs):
        st, dec = xs                                             # (B,H,P,N), (B,H)
        s_before = s_prev
        s_next = s_prev * dec[:, :, None, None] + st
        return s_next, s_before

    final, s_befores = jax.lax.scan(
        body, jnp.zeros((b, h, pdim, n), jnp.float32),
        (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    s_befores = s_befores.swapaxes(0, 1)                         # (B,nc,H,P,N)

    # inter-chunk contribution: y_off[q] = exp(cs_q) * C_q . S_before
    y_off = jnp.einsum("bcqh,bcqhn,bchpn->bcqhp",
                       jnp.exp(cs), cc, s_befores)
    y = (y_diag + y_off).reshape(b, l, h, pdim)[:, :l0]
    return y.astype(x.dtype), final


def apply_mamba(cfg: ModelConfig, p: dict, u: jax.Array, *,
                cache: Optional[dict] = None, decode: bool = False,
                positions: Optional[jax.Array] = None,
                slot: Optional[jax.Array] = None,
                taps: Optional[dict] = None, tap_prefix: str = ""):
    """u: (B, L, d_model). Returns (y, new_cache).

    `positions` (B, L) marks left-padding with -1 (continuous-batching
    prefill): padded steps are forced to dt=0 / x=0 so they neither move the
    SSM state nor leak through the causal conv — a left-padded prompt yields
    exactly the state of the unpadded one. `slot` ((B,) indices) routes a
    prefill batch's final states into those rows of an (n_slots, ...) cache.
    """
    m, di, h, conv_dim = _dims(cfg)
    b, l, _ = u.shape
    g, n, pdim = m.n_groups, m.d_state, m.head_dim

    if taps is not None:
        taps[tap_prefix + "in_proj"] = u

    zxbcdt = dense(p["in_proj"], u)
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    a = -jnp.exp(p["A_log"])                                     # (H,)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"][None, None, :])            # (B,L,H)
    if positions is not None and not decode:
        valid = positions >= 0                                   # (B, L)
        xbc = xbc * valid[..., None].astype(xbc.dtype)
        dt = dt * valid[..., None].astype(dt.dtype)

    new_cache = dict(cache) if cache is not None else None
    if decode:
        assert cache is not None and l == 1
        conv_state = cache["conv"]
        xbc_f, _ = _causal_conv(cfg, p, xbc, conv_state)
        new_cache["conv"] = jnp.concatenate(
            [conv_state[:, 1:], xbc.astype(conv_state.dtype)], axis=1)
        x, bm, cm = jnp.split(xbc_f, [di, di + g * n], axis=-1)
        xh = x.reshape(b, h, pdim).astype(jnp.float32)
        bmh = jnp.repeat(bm.reshape(b, g, n), h // g, axis=1)    # (B,H,N)
        cmh = jnp.repeat(cm.reshape(b, g, n), h // g, axis=1)
        dt1 = dt[:, 0, :]                                        # (B,H)
        dec = jnp.exp(dt1 * a[None, :])                          # (B,H)
        s = cache["state"] * dec[:, :, None, None] + \
            jnp.einsum("bh,bhp,bhn->bhpn", dt1, xh, bmh)
        y = jnp.einsum("bhpn,bhn->bhp", s, cmh)
        y = y + p["D"][None, :, None] * xh
        y = y.reshape(b, 1, di).astype(u.dtype)
        new_cache["state"] = s
        new_cache["len"] = cache["len"] + 1
    else:
        # slot-prefill (paged serving): the request is fresh, so the conv
        # starts from zero padding and the result lands in this slot's row
        # of the (n_slots, ...) cache rather than replacing the whole batch
        fresh = slot is not None
        conv_state = cache["conv"] if (cache is not None and not fresh) \
            else None
        xbc_f, conv_tail = _causal_conv(cfg, p, xbc, conv_state)
        x, bm, cm = jnp.split(xbc_f, [di, di + g * n], axis=-1)
        xh = lc(x.reshape(b, l, h, pdim), "batch", "seq", "ssm_heads", None)
        bmg = bm.reshape(b, l, g, n)
        cmg = cm.reshape(b, l, g, n)
        y, final_state = _ssd_chunked(cfg, xh, dt, a, bmg, cmg)
        y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
        y = y.reshape(b, l, di).astype(u.dtype)
        if new_cache is not None and fresh:
            n_real = (jnp.sum(positions >= 0, axis=1).astype(jnp.int32)
                      if positions is not None
                      else jnp.full((b,), l, jnp.int32))
            new_cache["state"] = cache["state"].at[slot].set(final_state)
            new_cache["conv"] = cache["conv"].at[slot].set(conv_tail)
            new_cache["len"] = cache["len"].at[slot].set(n_real)
        elif new_cache is not None:
            new_cache["state"] = final_state
            new_cache["conv"] = conv_tail
            new_cache["len"] = cache["len"] + l

    y = apply_gated_rmsnorm(cfg, p["gnorm"], y, z)
    y = lc(y, "batch", "seq", None)
    if taps is not None:
        taps[tap_prefix + "out_proj"] = y
    return dense(p["out_proj"], y), new_cache
