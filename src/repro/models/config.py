"""Model configuration for the unified architecture zoo.

One `ModelConfig` describes every assigned architecture: dense GQA/MQA
decoders, MLA (DeepSeek), MoE (Mixtral/DeepSeek/Jamba), Mamba2 SSD blocks,
hybrid interleaves (Jamba), encoder-decoder (Whisper), and stub-fronted
multimodal backbones (InternVL2 / Whisper audio).

The layer stack is `prefix_pattern` (unstacked, e.g. DeepSeek's first dense
layer) followed by `pattern` repeated `n_repeats` times. Repeats are stored
stacked and executed with `lax.scan`, so compile time is O(pattern), not
O(depth).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer of the repeating pattern."""

    kind: str = "attn"        # "attn" | "mamba"
    mlp: str = "dense"        # "dense" | "moe" | "none"
    cross_attn: bool = False  # decoder layers of enc-dec models


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    n_shared: int = 0           # shared (always-on) experts, DeepSeek-style
    d_ff_expert: int = 0        # per-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0        # 0 = full-rank Q projection (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    n_groups: int = 1
    chunk: int = 64             # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "tiny"
    family: str = "dense"       # dense | moe | ssm | hybrid | audio | vlm
    vocab_size: int = 256
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0           # 0 = d_model // n_heads
    d_ff: int = 512
    # --- layer stack ---
    prefix_pattern: tuple[LayerSpec, ...] = ()
    pattern: tuple[LayerSpec, ...] = (LayerSpec(),)
    n_repeats: int = 2
    # --- norm / act / positions ---
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    norm_eps: float = 1e-5
    act: str = "silu"           # silu (SwiGLU) | gelu (plain MLP)
    rope: str = "full"          # full | half ("2d") | none
    rope_theta: float = 10000.0
    pos_emb: str = "none"       # none | learned | sinusoidal
    max_position: int = 8192    # for learned positions
    # --- attention ---
    attention: str = "gqa"      # gqa | mla
    attn_window: Optional[int] = None  # sliding-window size (Mixtral SWA)
    qkv_bias: bool = False
    o_bias: bool = False
    mlp_bias: bool = False
    logit_softcap: float = 0.0
    # --- submodule configs ---
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[Mamba2Config] = None
    # --- enc-dec (whisper) ---
    enc_dec: bool = False
    n_enc_repeats: int = 0
    enc_pattern: tuple[LayerSpec, ...] = ()
    # --- multimodal frontend stub ---
    frontend: str = "none"      # none | audio | vision
    frontend_len: int = 0       # frames/patches prepended (vision) or enc len (audio)
    # --- embeddings / output ---
    tie_embeddings: bool = False
    # --- numerics ---
    dtype: str = "float32"          # activation/compute dtype
    param_dtype: str = "float32"
    serve_quant_bits: int = 0       # >0: serve with packed low-bit weights
    serve_quant_group: int = 128
    remat_policy: str = "nothing"   # nothing | dots (save matmul outputs)
    banded_window_attn: bool = False  # skip fully-masked SWA blocks (perf)
    chunked_decode: bool = False    # flash-style decode attention (perf)
    attn_scores_dtype: str = "float32"  # bfloat16 halves score HBM traffic
    moe_impl: str = "spmd"          # spmd | shard_map (explicit all-to-all EP)
    kv_cache_bits: int = 0          # 8: int8 KV cache (≈2x capacity/bandwidth)
    paged_attn_impl: str = "fused"  # fused: block-table-walking decode kernel
                                    # (kernels/paged_attention.py, inline int8
                                    # dequant); gather: gather->dequant->einsum
                                    # oracle path
    remat: bool = True
    attn_block_kv: int = 512        # chunked-attention kv block
    # --- distribution knobs (consumed by distributed/sharding.py) ---
    fsdp: bool = False              # shard params over the data axis too
    scan_layers: bool = True
    tp: int = 1                     # tensor-parallel width the model code is
                                    # *currently running under* (inside the
                                    # serving shard_map the engine passes a
                                    # head-localized cfg with tp>1 so
                                    # row-parallel linears psum over the
                                    # "model" axis; everywhere else tp == 1
                                    # and no collective is emitted)

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def n_layers(self) -> int:
        return len(self.prefix_pattern) + len(self.pattern) * self.n_repeats

    @property
    def adtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def all_layer_specs(self) -> list[LayerSpec]:
        return list(self.prefix_pattern) + list(self.pattern) * self.n_repeats

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def validate(self) -> None:
        assert self.n_heads % max(self.n_kv_heads, 1) == 0
        if any(s.mlp == "moe" for s in self.all_layer_specs()):
            assert self.moe is not None
        if any(s.kind == "mamba" for s in self.all_layer_specs()):
            assert self.mamba is not None
        if self.attention == "mla":
            assert self.mla is not None
        if self.enc_dec:
            assert self.n_enc_repeats > 0 and self.enc_pattern
