"""Multi-head Latent Attention (DeepSeek-V2) with compressed KV cache.

Train/prefill use the non-absorbed form (materialize K/V from the latent,
chunked flash attention). Decode uses the *absorbed* form: queries are
projected into the latent space and attention runs directly against the
cached (c_kv, k_rope) — the deployment-relevant O(r + rope) cache per token.

The latent RMSNorm ("kvnorm") is a tweakable norm for the paper's pipeline.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import TP_AXIS, lc
from repro.models.config import ModelConfig
from repro.models.attention import attention_core, _cache_write, _paged_update
from repro.models.linear import dense, init_dense, materialize
from repro.models.norms import apply_norm, init_norm
from repro.models.rope import apply_rope
from repro.serve.kvcache import PageSpec


def init_mla(cfg: ModelConfig, key) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": init_dense(ks[0], d, h * qk, dtype=cfg.pdtype),
        "wdkv": init_dense(ks[1], d, m.kv_lora_rank + m.qk_rope_head_dim,
                           dtype=cfg.pdtype),
        "kvnorm": init_norm(cfg, m.kv_lora_rank),
        "wukv": init_dense(ks[2], m.kv_lora_rank,
                           h * (m.qk_nope_head_dim + m.v_head_dim),
                           dtype=cfg.pdtype),
        "wo": init_dense(ks[3], h * m.v_head_dim, d, dtype=cfg.pdtype),
    }


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    m = cfg.mla
    return {
        "k": jnp.zeros((batch, max_len, 1, m.kv_lora_rank), cfg.adtype),   # c_kv
        "v": jnp.zeros((batch, max_len, 1, m.qk_rope_head_dim), cfg.adtype),  # k_pe
        "pos": jnp.full((batch, max_len), -1, jnp.int32),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def init_paged_mla_cache(cfg: ModelConfig, spec: PageSpec) -> dict:
    """Page-pool latent cache (c_kv under "k_pool", k_pe under "v_pool")."""
    m = cfg.mla
    return {
        "k_pool": jnp.zeros((spec.n_pages, spec.page_size, 1, m.kv_lora_rank),
                            cfg.adtype),
        "v_pool": jnp.zeros((spec.n_pages, spec.page_size, 1,
                             m.qk_rope_head_dim), cfg.adtype),
    }


def _project_latent(cfg, p, x, positions):
    """Returns (c_kv normed, k_pe roped): (B,S,r), (B,S,rope)."""
    m = cfg.mla
    ckv_kpe = dense(p["wdkv"], x)
    c_kv, k_pe = jnp.split(ckv_kpe, [m.kv_lora_rank], axis=-1)
    c_kv = apply_norm(cfg, p["kvnorm"], c_kv)
    k_pe = apply_rope(k_pe[:, :, None, :], positions, theta=cfg.rope_theta,
                      variant="full")[:, :, 0, :]
    return c_kv, k_pe


def _queries(cfg, p, x, positions):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = dense(p["wq"], x).reshape(b, s, h, qk)
    q_nope, q_pe = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_pe = apply_rope(q_pe, positions, theta=cfg.rope_theta, variant="full")
    return q_nope, q_pe


def apply_mla(cfg: ModelConfig, p: dict, x: jax.Array, *,
              positions: jax.Array, cache: Optional[dict] = None,
              decode: bool = False, paged: Optional[dict] = None,
              taps: Optional[dict] = None, tap_prefix: str = ""):
    """Returns (y, new_cache)."""
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads

    if taps is not None:
        taps[tap_prefix + "wq"] = x
        taps[tap_prefix + "wdkv"] = x

    q_nope, q_pe = _queries(cfg, p, x, positions)
    c_kv, k_pe = _project_latent(cfg, p, x, positions)
    if taps is not None:
        taps[tap_prefix + "wukv"] = c_kv

    new_cache = cache
    paged_view = None
    if cache is not None and "k_pool" in cache:
        if not decode and paged is not None and "kv_len" in paged:
            # chunked / prefix-suffix prefill hands back a gathered latent
            # context, but the non-absorbed prefill below attends only to
            # the current chunk's materialized K/V — silently wrong, so
            # refuse (the engine gates MLA off these features already)
            raise NotImplementedError(
                "chunked/prefix-shared prefill is not supported for MLA")
        new_cache, paged_view = _paged_update(
            cache, c_kv[:, :, None, :], k_pe[:, :, None, :], positions, paged)
    elif cache is not None:
        new_cache = _cache_write(cache, c_kv[:, :, None, :], k_pe[:, :, None, :],
                                 positions)

    if decode:
        assert cache is not None
        if paged_view is not None:
            ckv_g, kpe_g, kv_pos = paged_view
            ckv_all = ckv_g[:, :, 0, :]                          # (B, T, r)
            kpe_all = kpe_g[:, :, 0, :]                          # (B, T, rope)
        else:
            ckv_all = new_cache["k"][:, :, 0, :]                 # (B, T, r)
            kpe_all = new_cache["v"][:, :, 0, :]                 # (B, T, rope)
            kv_pos = new_cache["pos"]
        wukv = materialize(p["wukv"]["w"], jnp.float32).reshape(
            m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim)
        wuk, wuv = wukv[:, :, :m.qk_nope_head_dim], wukv[:, :, m.qk_nope_head_dim:]
        # absorb: q_latent = q_nope @ W_uk  -> (B, S, H, r)
        ql = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32), wuk)
        scale = 1.0 / ((m.qk_nope_head_dim + m.qk_rope_head_dim) ** 0.5)
        sc = (jnp.einsum("bshr,btr->bhst", ql, ckv_all.astype(jnp.float32)) +
              jnp.einsum("bshp,btp->bhst", q_pe.astype(jnp.float32),
                         kpe_all.astype(jnp.float32))) * scale
        msk = (kv_pos[:, None, :] >= 0) & \
              (kv_pos[:, None, :] <= positions[:, :, None])       # (B,S,T)
        sc = jnp.where(msk[:, None, :, :], sc, -1e30)             # (B,H,S,T)
        probs = jax.nn.softmax(sc, axis=-1)                      # (B,H,S,T)
        ctx = jnp.einsum("bhst,btr->bshr", probs, ckv_all.astype(jnp.float32))
        o = jnp.einsum("bshr,rhv->bshv", ctx, wuv)               # (B,S,H,v)
        o = o.astype(x.dtype).reshape(b, s, h * m.v_head_dim)
    else:
        # non-absorbed: materialize per-head K/V (MHA), chunked attention
        kv = dense(p["wukv"], c_kv).reshape(
            b, s, h, m.qk_nope_head_dim + m.v_head_dim)
        k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_pe[:, :, None, :],
                                      (b, s, h, m.qk_rope_head_dim))], axis=-1)
        q = jnp.concatenate([q_nope, q_pe], axis=-1)
        q = lc(q, "batch", "seq", "heads", "qk_dim")
        k = lc(k, "batch", "kv_seq", "heads", "qk_dim")
        v = lc(v, "batch", "kv_seq", "heads", "head_dim")
        o = attention_core(q, k, v, q_pos=positions, kv_pos=positions,
                           causal=True, block_kv=cfg.attn_block_kv)
        o = o.reshape(b, s, h * m.v_head_dim)

    if taps is not None:
        taps[tap_prefix + "wo"] = o
    # serving TP: wq/wukv are head-column-parallel, the latent projection
    # wdkv is replicated (per-token latent, no head dim), and wo is
    # row-parallel over the local heads' value slice
    y = dense(p["wo"], o, reduce_axis=TP_AXIS if cfg.tp > 1 else None)
    return lc(y, "batch", "seq", "embed"), new_cache
