"""RMSNorm / LayerNorm — the paper's tweakable parameters.

Norm params live under keys starting with "ln" (or "gnorm" for Mamba2's
gated RMSNorm, "qnorm"/"kvnorm" for MLA's low-rank norms) so the
norm-tweaking pipeline can address exactly these leaves.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def init_norm(cfg: ModelConfig, d: int) -> dict:
    p = {"scale": jnp.ones((d,), cfg.pdtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), cfg.pdtype)
    return p


def apply_norm(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(dtype)


def apply_gated_rmsnorm(cfg: ModelConfig, p: dict, x: jax.Array,
                        z: jax.Array) -> jax.Array:
    """Mamba2 gated norm: RMSNorm(x * silu(z)) * scale."""
    dtype = x.dtype
    xf = x.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return y.astype(dtype)


def is_norm_path(path: str) -> bool:
    """True if this param path belongs to a tweakable normalization layer."""
    parts = path.split("/")
    return any(
        seg.startswith("ln") or seg in ("gnorm", "qnorm", "kvnorm", "final_norm")
        for seg in parts
    )
