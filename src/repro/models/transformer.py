"""Decoder-only LM (covers dense/MoE/SSM/hybrid/VLM archs).

Layer stack = unstacked `prefix` blocks + `stack` of the repeating pattern,
executed with `lax.scan` over repeats (compile-time O(|pattern|), not
O(depth)). Per-layer access for the PTQ/norm-tweak pipeline goes through
`get_block` / `set_block`, which view into the stacked arrays.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import lc
from repro.models.blocks import apply_block, init_block, init_block_cache
from repro.models.config import ModelConfig
from repro.models.norms import apply_norm, init_norm
from repro.utils.tree import tree_index, tree_stack


# ----------------------------------------------------------------- init

def init_lm(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 6 + len(cfg.prefix_pattern)
                          + len(cfg.pattern) * cfg.n_repeats)
    ki = iter(range(len(ks)))
    params: dict = {
        "embed": {"w": (jax.random.normal(ks[next(ki)],
                                          (cfg.vocab_size, cfg.d_model)) * 0.02
                        ).astype(cfg.pdtype)},
        "final_norm": init_norm(cfg, cfg.d_model),
    }
    if cfg.pos_emb == "learned":
        params["pos"] = {"w": (jax.random.normal(
            ks[next(ki)], (cfg.max_position, cfg.d_model)) * 0.02
        ).astype(cfg.pdtype)}
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": (jax.random.normal(
            ks[next(ki)], (cfg.d_model, cfg.vocab_size)) * 0.02
        ).astype(cfg.pdtype)}
    prefix = {}
    for i, spec in enumerate(cfg.prefix_pattern):
        prefix[str(i)] = init_block(cfg, spec, ks[next(ki)])
    if prefix:
        params["prefix"] = prefix
    stack = {}
    for j, spec in enumerate(cfg.pattern):
        reps = [init_block(cfg, spec, ks[next(ki)]) for _ in range(cfg.n_repeats)]
        stack[f"p{j}"] = tree_stack(reps)
    params["stack"] = stack
    return params


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: int = 0, paged=None) -> dict:
    """`paged`: a serve.kvcache.PageSpec — build page-pool caches for the
    continuous-batching engine (`batch` is then the slot count)."""
    cache: dict = {}
    if cfg.prefix_pattern:
        cache["prefix"] = {
            str(i): init_block_cache(cfg, spec, batch, max_len, enc_len,
                                     paged=paged)
            for i, spec in enumerate(cfg.prefix_pattern)}
    cache["stack"] = {}
    for j, spec in enumerate(cfg.pattern):
        one = init_block_cache(cfg, spec, batch, max_len, enc_len,
                               paged=paged)
        cache["stack"][f"p{j}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(
                x[None], (cfg.n_repeats,) + x.shape).copy() if hasattr(
                    x, "shape") else x, one)
    return cache


# ----------------------------------------------------------------- blocks

def num_blocks(cfg: ModelConfig) -> int:
    return cfg.n_layers


def block_spec(cfg: ModelConfig, i: int):
    np_ = len(cfg.prefix_pattern)
    if i < np_:
        return cfg.prefix_pattern[i]
    return cfg.pattern[(i - np_) % len(cfg.pattern)]


def get_block(cfg: ModelConfig, params: dict, i: int) -> dict:
    np_ = len(cfg.prefix_pattern)
    if i < np_:
        return params["prefix"][str(i)]
    j = (i - np_) % len(cfg.pattern)
    r = (i - np_) // len(cfg.pattern)
    return tree_index(params["stack"][f"p{j}"], r)


def set_block(cfg: ModelConfig, params: dict, i: int, new_block: dict) -> dict:
    np_ = len(cfg.prefix_pattern)
    out = dict(params)
    if i < np_:
        out["prefix"] = dict(out["prefix"])
        out["prefix"][str(i)] = new_block
        return out
    j = (i - np_) % len(cfg.pattern)
    r = (i - np_) // len(cfg.pattern)
    key = f"p{j}"
    out["stack"] = dict(out["stack"])
    out["stack"][key] = jax.tree.map(
        lambda stacked, nb: stacked.at[r].set(nb.astype(stacked.dtype))
        if hasattr(stacked, "at") else stacked,
        out["stack"][key], new_block)
    return out


# ----------------------------------------------------------------- forward

def _embed(cfg: ModelConfig, params: dict, tokens: jax.Array,
           ext_embeds: Optional[jax.Array], positions: jax.Array) -> jax.Array:
    x = jnp.take(params["embed"]["w"], tokens, axis=0).astype(cfg.adtype)
    if ext_embeds is not None:  # VLM: patch embeds prepended to text tokens
        x = jnp.concatenate([ext_embeds.astype(cfg.adtype), x], axis=1)
    if cfg.pos_emb == "learned":
        pe = jnp.take(params["pos"]["w"],
                      jnp.clip(positions, 0, cfg.max_position - 1), axis=0)
        x = x + pe.astype(cfg.adtype)
    return lc(x, "batch", "seq", "embed")


def _run_stack(cfg: ModelConfig, params: dict, x: jax.Array, *,
               positions: jax.Array, mode: str, cache: Optional[dict],
               enc_out: Optional[jax.Array] = None,
               paged: Optional[dict] = None):
    """Prefix blocks then scanned pattern repeats. Returns (x, new_cache, aux).
    `paged` (block-table indices) is loop-invariant across layers — each
    block's page pool is indexed by the same per-slot tables."""
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: dict = {} if cache is not None else None

    for i, spec in enumerate(cfg.prefix_pattern):
        c = cache["prefix"][str(i)] if cache is not None else None
        x, nc, aux = apply_block(cfg, spec, params["prefix"][str(i)], x,
                                 positions=positions, mode=mode, cache=c,
                                 enc_out=enc_out, paged=paged)
        aux_total += aux
        if cache is not None:
            new_cache.setdefault("prefix", {})[str(i)] = nc

    pat = cfg.pattern
    stacks = tuple(params["stack"][f"p{j}"] for j in range(len(pat)))
    cstacks = tuple(cache["stack"][f"p{j}"] if cache is not None else None
                    for j in range(len(pat)))

    def one_repeat(x, slices, cslices):
        aux_sum = jnp.zeros((), jnp.float32)
        ncs = []
        for j, spec in enumerate(pat):
            x, nc, aux = apply_block(
                cfg, spec, slices[j], x, positions=positions, mode=mode,
                cache=cslices[j] if cslices is not None else None,
                enc_out=enc_out, paged=paged)
            aux_sum += aux
            ncs.append(nc)
        return x, tuple(ncs), aux_sum

    if cfg.remat and mode == "train":
        policy = {
            "nothing": jax.checkpoint_policies.nothing_saveable,
            "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        }[cfg.remat_policy]
        one_repeat = jax.checkpoint(one_repeat, policy=policy)

    if cfg.scan_layers:
        def body(carry, xs):
            x, aux_sum = carry
            slices = xs[0]
            cslices = xs[1] if cache is not None else None
            x, ncs, aux = one_repeat(x, slices, cslices)
            return (x, aux_sum + aux), ncs

        (x, aux_scan), ncs_stacked = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)),
            (stacks, cstacks if cache is not None else None))
        aux_total += aux_scan
        if cache is not None:
            new_cache["stack"] = {f"p{j}": ncs_stacked[j]
                                  for j in range(len(pat))}
    else:
        for r in range(cfg.n_repeats):
            slices = tuple(tree_index(s, r) for s in stacks)
            cslices = (tuple(tree_index(c, r) for c in cstacks)
                       if cache is not None else None)
            x, ncs, aux = one_repeat(x, slices, cslices)
            aux_total += aux
            if cache is not None:
                for j in range(len(pat)):
                    new_cache.setdefault("stack", {}).setdefault(
                        f"p{j}", []).append(ncs[j])
        if cache is not None and "stack" in new_cache:
            new_cache["stack"] = {k: tree_stack(v)
                                  for k, v in new_cache["stack"].items()}
    return x, new_cache, aux_total


def _head(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    x = apply_norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x,
                            params["embed"]["w"].astype(x.dtype),
                            preferred_element_type=jnp.float32)
    else:
        from repro.models.linear import dense
        logits = dense(params["lm_head"], x, dtype=x.dtype).astype(jnp.float32)
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return lc(logits.astype(jnp.float32), "batch", "seq", "vocab")


def lm_forward(cfg: ModelConfig, params: dict, tokens: jax.Array,
               ext_embeds: Optional[jax.Array] = None):
    """Full-sequence causal forward. Returns (logits f32, aux)."""
    b = tokens.shape[0]
    s = tokens.shape[1] + (ext_embeds.shape[1] if ext_embeds is not None else 0)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = _embed(cfg, params, tokens, ext_embeds, positions)
    x, _, aux = _run_stack(cfg, params, x, positions=positions,
                           mode="train", cache=None)
    return _head(cfg, params, x), aux


def lm_prefill(cfg: ModelConfig, params: dict, tokens: jax.Array, cache: dict,
               ext_embeds: Optional[jax.Array] = None,
               positions: Optional[jax.Array] = None,
               paged: Optional[dict] = None):
    """Prompt ingestion. Returns (last-token logits (B, V), new_cache).

    `positions` (B, S) overrides the default arange for continuous batching:
    left-padded prompts mark pads with -1 (masked everywhere, routed to the
    scratch page) so the real last token stays at index -1. `paged` carries
    the target slot's block-table row (serve/kvcache.py).
    """
    b = tokens.shape[0]
    s = tokens.shape[1] + (ext_embeds.shape[1] if ext_embeds is not None else 0)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                     (b, s))
    x = _embed(cfg, params, tokens, ext_embeds, positions)
    x, new_cache, _ = _run_stack(cfg, params, x, positions=positions,
                                 mode="prefill", cache=cache, paged=paged)
    logits = _head(cfg, params, x[:, -1:, :])
    return logits[:, 0, :], new_cache


def lm_decode(cfg: ModelConfig, params: dict, tokens: jax.Array,
              cache: dict, positions: jax.Array,
              paged: Optional[dict] = None):
    """One decode step. tokens: (B, 1); positions: (B, 1) absolute. With a
    paged cache, B is the slot count and `paged` holds per-slot write
    targets plus the block tables for gather-based reads."""
    x = _embed(cfg, params, tokens, None, positions)
    x, new_cache, _ = _run_stack(cfg, params, x, positions=positions,
                                 mode="decode", cache=cache, paged=paged)
    logits = _head(cfg, params, x)
    return logits[:, 0, :], new_cache


def lm_verify(cfg: ModelConfig, params: dict, tokens: jax.Array,
              cache: dict, positions: jax.Array, paged: dict):
    """Spec-decode verify forward: score M draft tokens per slot in one
    batched pass. tokens: (B, M) = [last emitted token, d_1..d_{M-1}];
    positions: (B, M) absolute (inactive slots -1). Runs the prefill-shaped
    stack — `paged` carries bt_rows + kv_len (fill *including* the M
    tokens) plus a "verify" marker that routes the fused small-M
    paged-attention read (gather impl needs no marker: its prefill path
    already reads the whole context). Returns (logits (B, M, V) f32,
    new_cache); row m is the next-token distribution after the prefix plus
    tokens[:, :m+1]."""
    x = _embed(cfg, params, tokens, None, positions)
    x, new_cache, _ = _run_stack(cfg, params, x, positions=positions,
                                 mode="prefill", cache=cache, paged=paged)
    return _head(cfg, params, x), new_cache


def lm_loss(cfg: ModelConfig, params: dict, batch: dict):
    """Next-token cross-entropy (+ MoE aux). batch: tokens, labels, [mask]."""
    logits, aux = lm_forward(cfg, params, batch["tokens"],
                             batch.get("ext_embeds"))
    labels = batch["labels"]
    # align: ext embeds (if any) prepended -> score only the token positions
    if batch.get("ext_embeds") is not None:
        logits = logits[:, batch["ext_embeds"].shape[1]:, :]
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(nll)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + aux, {"nll": loss, "aux": aux}
