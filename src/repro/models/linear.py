"""Single entry point for every linear layer in the zoo.

A linear's params are {"w": W} or {"w": W, "b": b}. W may be a plain array
(K, N) / stacked experts (E, K, N), or a packed `QuantizedTensor` — the
paper's deployment format. Dispatch (see DESIGN.md "Quantized serving fast
paths" for the full table):

  * plain array                    -> jnp.einsum (MXU)
  * QuantizedTensor, TPU           -> Pallas fused dequant-matmul kernel
    - (K, N) weight                  -> kernels/dequant_matmul
    - (E, K, N) stacked experts      -> kernels/expert_dequant_matmul
      (packed expert slabs consumed directly; no float stack)
    - act_bits == 8                  -> kernels/w8a8_matmul (true int8 MXU)
    - act_bits == 8, stacked experts -> kernels/expert_w8a8_matmul
      (int8 x int8 MXU dots per expert slab)
  * QuantizedTensor, CPU           -> reference dequant + einsum / the
    int32 W8A8 reference (same math)

`act_bits == 8` selects the true A8 path: per-token int8 activation
quantization feeding an int8 x int8 -> int32 matmul (FPTQ's W4A8/W8A8
regime). Other act_bits values keep the legacy per-tensor fake-quant.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.quant.types import (QuantizedTensor, dequantize,
                                    fake_quant_activation,
                                    quantize_activation)
from repro.debug_flags import dequant_impl

_KERNEL_BITS = (2, 3, 4, 8)


def _use_pallas() -> bool:
    force = dequant_impl()
    if force == "pallas":
        return True
    if force == "ref":
        return False
    return jax.default_backend() == "tpu"


def materialize(w: Any, dtype) -> jax.Array:
    if isinstance(w, QuantizedTensor):
        return dequantize(w, dtype)
    return w.astype(dtype)


def _dense_quantized(w: QuantizedTensor, x: jax.Array, dtype,
                     reduce_axis: str | None = None) -> jax.Array:
    """2-D quantized matmul: route to the W8A8 int8 path, the fused
    dequant kernel, or the reference dequant + einsum. `reduce_axis` (TP
    row-parallel: K split over that shard axis) makes the A8 per-token
    activation grid global via a pmax'ed amax — the psum of the partial
    outputs itself stays in `dense` below."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if w.act_bits == 8 and w.bits in _KERNEL_BITS:
        if _use_pallas():
            from repro.kernels import ops as kops

            y2 = kops.w8a8_matmul(x2, w, out_dtype=dtype,
                                  amax_axis=reduce_axis)
        else:
            from repro.kernels import ref as kref

            xq, xs = quantize_activation(x2, 8, axis_name=reduce_axis)
            y2 = (kref.w8a8_matmul_ref(xq, w.qw, w.scale, bits=w.bits,
                                       group_size=w.group_size,
                                       k=w.k) * xs).astype(dtype)
    else:
        if w.act_bits:  # legacy per-tensor fake-quant (act_bits != 8)
            x2 = fake_quant_activation(x2, w.act_bits,
                                       axis_name=reduce_axis)
        if _use_pallas() and w.bits in _KERNEL_BITS:
            from repro.kernels import ops as kops

            y2 = kops.dequant_matmul(x2, w, out_dtype=dtype)
        else:
            y2 = jnp.einsum("mk,kn->mn", x2, dequantize(w, dtype),
                            preferred_element_type=jnp.float32).astype(dtype)
    return y2.reshape(*lead, w.n)


def dense(p: dict, x: jax.Array, *, dtype=None,
          reduce_axis: str | None = None) -> jax.Array:
    """y = x @ w (+ b). x: (..., K). Handles quantized + biased linears.

    `reduce_axis` marks a *row-parallel* call under tensor parallelism: the
    weight's K dim is sharded over that mesh axis, so the per-shard matmul
    is a partial sum that is psum'ed before the bias is added (adding the
    replicated bias per-shard would count it `tp` times). Callers pass it
    only inside the serving shard_map (cfg.tp > 1)."""
    w = p["w"]
    dtype = dtype or x.dtype
    if isinstance(w, QuantizedTensor) and w.qw.ndim == 2:
        y = _dense_quantized(w, x, dtype, reduce_axis=reduce_axis)
    elif isinstance(w, QuantizedTensor):
        if w.act_bits:
            x = fake_quant_activation(x, w.act_bits)
        wm = dequantize(w, dtype)
        y = jnp.einsum("...k,kn->...n", x, wm,
                       preferred_element_type=jnp.float32).astype(dtype)
    else:
        y = jnp.einsum("...k,kn->...n", x.astype(dtype), w.astype(dtype),
                       preferred_element_type=jnp.float32).astype(dtype)
    if reduce_axis is not None:
        y = jax.lax.psum(y, reduce_axis)
    if "b" in p and p["b"] is not None:
        y = y + p["b"].astype(dtype)
    return y


def dense_experts(p: dict, x: jax.Array, *, dtype=None) -> jax.Array:
    """Batched expert matmul: x (E, C, K) @ w (E, K, N) -> (E, C, N).

    Quantized expert stacks take the expert-batched Pallas kernel: packed
    (E, packed_rows(K), N) slabs are consumed directly, so the float expert stack is
    never materialized (the old path dequantized all E experts per call)."""
    w = p["w"]
    dtype = dtype or x.dtype
    if isinstance(w, QuantizedTensor):
        if (w.act_bits == 8 and w.qw.ndim == 3
                and w.bits in _KERNEL_BITS):
            # true W4A8/W8A8 expert path: per-token int8 activations feed
            # the int8 x int8 -> int32 MXU dots (no bf16 dequant stack)
            if _use_pallas():
                from repro.kernels import ops as kops

                y = kops.expert_w8a8_matmul(x, w, out_dtype=dtype)
            else:
                from repro.kernels import ref as kref

                e, c, k = x.shape
                xq, xs = quantize_activation(x.reshape(e * c, k), 8)
                y = (kref.expert_w8a8_matmul_ref(
                    xq.reshape(e, c, k), w.qw, w.scale, bits=w.bits,
                    group_size=w.group_size,
                    k=w.k) * xs.reshape(e, c, 1)).astype(dtype)
            if "b" in p and p["b"] is not None:
                y = y + p["b"][:, None, :].astype(dtype)
            return y
        if w.act_bits:
            x = fake_quant_activation(x, w.act_bits)
        if _use_pallas() and w.qw.ndim == 3 and w.bits in _KERNEL_BITS:
            from repro.kernels import ops as kops

            y = kops.expert_dequant_matmul(x, w, out_dtype=dtype)
        else:
            wm = dequantize(w, dtype)
            y = jnp.einsum("eck,ekn->ecn", x.astype(dtype), wm,
                           preferred_element_type=jnp.float32).astype(dtype)
    else:
        y = jnp.einsum("eck,ekn->ecn", x.astype(dtype), w.astype(dtype),
                       preferred_element_type=jnp.float32).astype(dtype)
    if "b" in p and p["b"] is not None:
        y = y + p["b"][:, None, :].astype(dtype)
    return y


def init_dense(key, k: int, n: int, *, bias: bool = False, dtype=jnp.float32,
               scale: float | None = None) -> dict:
    std = scale if scale is not None else (1.0 / (k ** 0.5))
    p = {"w": (jax.random.normal(key, (k, n)) * std).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((n,), dtype)
    return p


def init_dense_experts(key, e: int, k: int, n: int, *, dtype=jnp.float32,
                       scale: float | None = None) -> dict:
    std = scale if scale is not None else (1.0 / (k ** 0.5))
    return {"w": (jax.random.normal(key, (e, k, n)) * std).astype(dtype)}
