"""Single entry point for every linear layer in the zoo.

A linear's params are {"w": W} or {"w": W, "b": b}. W may be a plain array
(K, N) / stacked experts (E, K, N), or a packed `QuantizedTensor` — the
paper's deployment format. Dispatch:

  * plain array          -> jnp.einsum (MXU)
  * QuantizedTensor, TPU -> Pallas fused dequant-matmul kernel
  * QuantizedTensor, CPU -> reference dequant + einsum (same math)

`act_bits` on the QuantizedTensor fake-quants the activation first
(SmoothQuant W_xA8 mode).
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.quant.types import QuantizedTensor, dequantize, fake_quant_activation


def _use_pallas() -> bool:
    force = os.environ.get("REPRO_DEQUANT_IMPL", "")
    if force == "pallas":
        return True
    if force == "ref":
        return False
    return jax.default_backend() == "tpu"


def materialize(w: Any, dtype) -> jax.Array:
    if isinstance(w, QuantizedTensor):
        return dequantize(w, dtype)
    return w.astype(dtype)


def dense(p: dict, x: jax.Array, *, dtype=None) -> jax.Array:
    """y = x @ w (+ b). x: (..., K). Handles quantized + biased linears."""
    w = p["w"]
    dtype = dtype or x.dtype
    if isinstance(w, QuantizedTensor):
        if w.act_bits:
            x = fake_quant_activation(x, w.act_bits)
        if _use_pallas() and w.qw.ndim == 2 and w.bits in (2, 4, 8):
            from repro.kernels import ops as kops

            lead = x.shape[:-1]
            y2 = kops.dequant_matmul(x.reshape(-1, x.shape[-1]), w, out_dtype=dtype)
            y = y2.reshape(*lead, w.n)
        else:
            wm = dequantize(w, dtype)
            y = jnp.einsum("...k,kn->...n", x, wm,
                           preferred_element_type=jnp.float32).astype(dtype)
    else:
        y = jnp.einsum("...k,kn->...n", x.astype(dtype), w.astype(dtype),
                       preferred_element_type=jnp.float32).astype(dtype)
    if "b" in p and p["b"] is not None:
        y = y + p["b"].astype(dtype)
    return y


def dense_experts(p: dict, x: jax.Array, *, dtype=None) -> jax.Array:
    """Batched expert matmul: x (E, C, K) @ w (E, K, N) -> (E, C, N)."""
    w = p["w"]
    dtype = dtype or x.dtype
    if isinstance(w, QuantizedTensor):
        if w.act_bits:
            x = fake_quant_activation(x, w.act_bits)
        wm = dequantize(w, dtype)
    else:
        wm = w.astype(dtype)
    y = jnp.einsum("eck,ekn->ecn", x.astype(dtype), wm,
                   preferred_element_type=jnp.float32).astype(dtype)
    if "b" in p and p["b"] is not None:
        y = y + p["b"][:, None, :].astype(dtype)
    return y


def init_dense(key, k: int, n: int, *, bias: bool = False, dtype=jnp.float32,
               scale: float | None = None) -> dict:
    std = scale if scale is not None else (1.0 / (k ** 0.5))
    p = {"w": (jax.random.normal(key, (k, n)) * std).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((n,), dtype)
    return p


def init_dense_experts(key, e: int, k: int, n: int, *, dtype=jnp.float32,
                       scale: float | None = None) -> dict:
    std = scale if scale is not None else (1.0 / (k ** 0.5))
    return {"w": (jax.random.normal(key, (e, k, n)) * std).astype(dtype)}
