"""Expert-parallel MoE dispatch via shard_map + all_to_all.

XLA SPMD cannot lower the einsum/scatter dispatch to an efficient all-to-all
(§Perf: it either replicates expert FLOPs across the data axis or
all-gathers token slots). This module expresses the communication explicitly:

  * tokens are split across the `model` axis (each model rank dispatches a
    distinct 1/M slice of its data-shard's tokens);
  * per-expert slots go through `lax.all_to_all` over `model` to the rank
    owning the expert (E % M == 0, E_loc = E/M experts per rank);
  * expert FFNs run on local weight shards;
  * a reverse all_to_all + local combine + `all_gather` rebuilds the
    token-major output.

Per-layer wire bytes/device ≈ (2·top_k + 1)·T_loc·d·dtype / M — an order of
magnitude below the SPMD fallback for DeepSeek-style expert counts.
Requires E % model_size == 0 and (B_loc·S) % model_size == 0; callers fall
back to the SPMD path otherwise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map

from repro.distributed.partitioning import moe_ep_dispatch_pspecs
from repro.models.config import ModelConfig
from repro.models.linear import dense


def _positions_in_expert(flat_idx, e):
    order = jnp.argsort(flat_idx, stable=True)
    sorted_e = flat_idx[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    pos_sorted = jnp.arange(flat_idx.shape[0], dtype=jnp.int32) - \
        starts[sorted_e].astype(jnp.int32)
    return jnp.zeros_like(flat_idx).at[order].set(pos_sorted)


def moe_ep_shardmap(cfg: ModelConfig, p: dict, x: jax.Array, mesh,
                    data_axes=("pod", "data")):
    """x: (B, S, d) batch-sharded over `data_axes`. Returns (y, aux)."""
    m = cfg.moe
    e, k = m.n_experts, m.top_k
    b, s, d = x.shape
    msize = mesh.shape["model"]
    e_loc = e // msize
    daxes = tuple(a for a in data_axes if a in mesh.shape)

    router_w = p["router"]["w"].astype(jnp.float32)
    wi, wg, wo = (p["experts"][n]["w"] for n in ("wi", "wg", "wo"))

    def local(xb, rw, wi_l, wg_l, wo_l):
        # xb: (B_loc, S, d) — replicated over `model`; take this rank's slice
        ax = jax.lax.axis_index("model")
        t_loc = xb.shape[0] * s
        assert t_loc % msize == 0, (t_loc, msize)
        t_r = t_loc // msize
        xf = xb.reshape(t_loc, d)
        xr = jax.lax.dynamic_slice_in_dim(xf, ax * t_r, t_r, axis=0)

        logits = jnp.einsum("td,de->te", xr.astype(jnp.float32), rw)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, idx = jax.lax.top_k(probs, k)
        gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

        cap = max(8, -(-int(m.capacity_factor * t_r * k / e) // 8) * 8)
        flat_idx = idx.reshape(t_r * k)
        pos = _positions_in_expert(flat_idx, e)
        keep = pos < cap
        safe_e = jnp.where(keep, flat_idx, e)
        safe_pos = jnp.where(keep, pos, 0)
        xk = jnp.repeat(xr[:, None, :], k, axis=1).reshape(t_r * k, d)
        buf = jnp.zeros((e + 1, cap, d), x.dtype).at[safe_e, safe_pos].add(xk)
        buf = buf[:e]                                    # (E, cap, d)

        # send expert-e slots to the rank owning e: (E, cap, d) ->
        # (E_loc, msize*cap, d), the received dim ordered by source rank
        recv = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                                  tiled=True)

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", recv, wg_l)) \
            * jnp.einsum("ecd,edf->ecf", recv, wi_l)
        out = jnp.einsum("ecf,efd->ecd", h, wo_l)        # (E_loc, m*cap, d)

        # route results back to the source ranks: inverse all_to_all
        out = jax.lax.all_to_all(out, "model", split_axis=1, concat_axis=0,
                                 tiled=True)             # (E, cap, d)

        gathered = out[jnp.minimum(safe_e, e - 1), safe_pos]
        gathered = gathered * (keep & (safe_e < e))[:, None]
        gathered = gathered * gate.reshape(t_r * k, 1).astype(x.dtype)
        y_r = jnp.sum(gathered.reshape(t_r, k, d), axis=1)   # (t_r, d)

        # rebuild the full local token set across model ranks
        y_full = jax.lax.all_gather(y_r, "model", axis=0).reshape(t_loc, d)

        # load-balance aux (local estimate, averaged over model ranks)
        me = jnp.mean(probs, axis=0)
        counts = jnp.zeros((e,), jnp.float32).at[flat_idx].add(1.0)
        aux = m.router_aux_weight * e * jnp.sum(me * counts / t_r)
        aux = jax.lax.pmean(aux, "model")
        return y_full.reshape(xb.shape), aux

    in_specs, out_specs = moe_ep_dispatch_pspecs(daxes)
    fn = shard_map(local, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    y, aux = fn(x, router_w, wi, wg, wo)

    if "shared" in p:
        from repro.models.mlp_moe import apply_mlp
        y = y + apply_mlp(cfg, p["shared"], x)
    return y, jnp.mean(aux)
