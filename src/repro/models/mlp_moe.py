"""Dense MLP (SwiGLU / GELU) and capacity-based top-k MoE.

MoE dispatch is the XLA-friendly scatter/gather formulation: tokens are
scattered into a per-expert (E, C, d) buffer (C = capacity), experts run as
one batched einsum (sharded over the `expert`/model axis -> expert
parallelism), and results are gathered back with router gates. Overflowing
tokens are dropped (tracked in aux stats), as in Switch/GShard.

Quantized expert stacks (`QuantizedTensor` with (E, K, N) shape) run the
(E, C) buffer through the expert-batched Pallas dequant kernel via
`dense_experts` — the packed slabs are consumed in place, never expanded
to a float (E, K, N) stack (see DESIGN.md "Quantized serving fast paths").
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import TP_AXIS, lc
from repro.models.config import ModelConfig
from repro.models.linear import dense, dense_experts, init_dense, init_dense_experts


def init_mlp(cfg: ModelConfig, key, d_ff: int, d_in: int = 0) -> dict:
    d = d_in or cfg.d_model
    ks = jax.random.split(key, 3)
    p = {"wi": init_dense(ks[0], d, d_ff, bias=cfg.mlp_bias, dtype=cfg.pdtype),
         "wo": init_dense(ks[1], d_ff, d, bias=cfg.mlp_bias, dtype=cfg.pdtype)}
    if cfg.act == "silu":
        p["wg"] = init_dense(ks[2], d, d_ff, bias=cfg.mlp_bias, dtype=cfg.pdtype)
    return p


def apply_mlp(cfg: ModelConfig, p: dict, x: jax.Array,
              taps: Optional[dict] = None, tap_prefix: str = "") -> jax.Array:
    if taps is not None:
        taps[tap_prefix + "wi"] = x
        if "wg" in p:
            taps[tap_prefix + "wg"] = x
    if cfg.act == "silu":
        h = jax.nn.silu(dense(p["wg"], x)) * dense(p["wi"], x)
    else:
        h = jax.nn.gelu(dense(p["wi"], x), approximate=True)
    h = lc(h, "batch", "seq", "mlp")
    if taps is not None:
        taps[tap_prefix + "wo"] = h
    # serving TP: wi/wg are column-parallel (local d_ff slice), the down
    # projection is row-parallel and reduces over the model axis
    return dense(p["wo"], h, reduce_axis=TP_AXIS if cfg.tp > 1 else None)


# ------------------------------------------------------------------- MoE

def init_moe(cfg: ModelConfig, key) -> dict:
    m = cfg.moe
    d, ff = cfg.d_model, m.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": init_dense(ks[0], d, m.n_experts, dtype=jnp.float32),
        "experts": {
            "wi": init_dense_experts(ks[1], m.n_experts, d, ff, dtype=cfg.pdtype),
            "wg": init_dense_experts(ks[2], m.n_experts, d, ff, dtype=cfg.pdtype),
            "wo": init_dense_experts(ks[3], m.n_experts, ff, d, dtype=cfg.pdtype),
        },
    }
    if m.n_shared:
        p["shared"] = init_mlp(cfg, ks[4], m.n_shared * ff)
    return p


def moe_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    m = cfg.moe
    c = int(m.capacity_factor * n_tokens * m.top_k / m.n_experts)
    # round up to 8: the minimal sublane tile, so quantized expert stacks hit
    # kernels/expert_dequant_matmul without capacity-dim padding (decode-time
    # capacities land exactly on its skinny bm=8 tile)
    return max(8, -(-c // 8) * 8)


def apply_moe(cfg: ModelConfig, p: dict, x: jax.Array,
              taps: Optional[dict] = None, tap_prefix: str = "",
              valid: Optional[jax.Array] = None):
    """x: (B, S, d). Returns (y, aux_loss_scalar).

    `valid` (B, S) marks real tokens; False = left-padding (continuous-
    batching prefill). Pad tokens are routed straight to the overflow bin so
    they neither consume expert capacity nor shift real tokens' dispatch
    positions — without this, junk pads can displace real tokens whenever
    capacity binds.
    """
    m = cfg.moe
    b, s, d = x.shape

    if cfg.moe_impl == "shard_map" and taps is None and valid is None:
        from repro.core.quant.types import QuantizedTensor
        from repro.distributed.sharding import active_mesh
        mesh = active_mesh()
        float_experts = not isinstance(p["experts"]["wi"]["w"],
                                       QuantizedTensor)
        if (mesh is not None and "model" in mesh.shape and float_experts
                and m.n_experts % mesh.shape["model"] == 0):
            dp = 1
            for a in ("pod", "data"):
                dp *= mesh.shape.get(a, 1)
            if b % dp == 0 and (b // dp) * s % mesh.shape["model"] == 0:
                from repro.models.moe_shardmap import moe_ep_shardmap
                return moe_ep_shardmap(cfg, p, x, mesh)
    t = b * s
    e, k = m.n_experts, m.top_k
    cap = moe_capacity(cfg, t)
    xf = x.reshape(t, d)

    if taps is not None:
        taps[tap_prefix + "router"] = xf

    logits = dense(p["router"], xf.astype(jnp.float32))          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                          # (T, k)
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)

    # slot positions within each expert — sort-based (O(T·k) memory; the
    # one-hot/cumsum formulation is O(T·k·E) and blows up at pod scale)
    flat_idx = idx.reshape(t * k)
    if valid is not None:
        flat_idx = jnp.where(jnp.repeat(valid.reshape(t), k), flat_idx, e)
    order = jnp.argsort(flat_idx, stable=True)
    sorted_e = flat_idx[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    pos_sorted = jnp.arange(t * k, dtype=jnp.int32) - \
        starts[sorted_e].astype(jnp.int32)
    pos = jnp.zeros((t * k,), jnp.int32).at[order].set(pos_sorted)

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    counts = jnp.zeros((e,), jnp.float32).at[flat_idx].add(1.0)
    ce = counts / t
    aux = m.router_aux_weight * e * jnp.sum(me * ce)

    keep = pos < cap
    safe_e = jnp.where(keep, flat_idx, e)                        # overflow -> bin E
    safe_pos = jnp.where(keep, pos, 0)

    # dispatch: (E+1, C, d) scatter (unique (e,pos) per slot -> add == set)
    xk = jnp.repeat(xf[:, None, :], k, axis=1).reshape(t * k, d)
    buf = jnp.zeros((e + 1, cap, d), x.dtype).at[safe_e, safe_pos].add(xk)
    buf = lc(buf[:e], "expert", "capacity", "embed")

    if taps is not None:
        taps[tap_prefix + "experts"] = buf

    h = jax.nn.silu(dense_experts(p["experts"]["wg"], buf)) * \
        dense_experts(p["experts"]["wi"], buf)
    h = lc(h, "expert", "capacity", "mlp")
    if taps is not None:
        taps[tap_prefix + "experts_out"] = h
    out = dense_experts(p["experts"]["wo"], h)                   # (E, C, d)
    out = lc(out, "expert", "capacity", "embed")

    # combine
    gathered = out[jnp.minimum(safe_e, e - 1), safe_pos]         # (T*k, d)
    gathered = gathered * (keep[:, None] & (safe_e < e)[:, None])
    gathered = gathered * gate.reshape(t * k, 1).astype(x.dtype)
    y = jnp.sum(gathered.reshape(t, k, d), axis=1)

    if "shared" in p:
        if taps is not None:
            sh_taps = {}
            ysh = apply_mlp(cfg, p["shared"], x, sh_taps, "")
            for kk, vv in sh_taps.items():
                taps[tap_prefix + "shared/" + kk] = vv
        else:
            ysh = apply_mlp(cfg, p["shared"], x)
        y = y.reshape(b, s, d) + ysh
    else:
        y = y.reshape(b, s, d)
    return lc(y, "batch", "seq", "embed"), aux
