"""The single parse point for every ``REPRO_*`` environment flag.

Before this module existed, each consumer re-parsed the environment
independently (``serve/engine.py`` captured ``REPRO_DEBUG`` once at
construction, ``kernels/ops.py`` and ``models/linear.py`` read
``REPRO_STRICT_KERNELS`` / ``REPRO_DEQUANT_IMPL`` per call), so a
mid-process change — a test monkeypatching the environment, a driver
flipping debug on for one phase — was observed by some modules and not
others. Now every read funnels through :func:`flags`, which re-reads the
environment through one code path and hands back one immutable typed
snapshot: either every module sees a change, or none does, and there is
exactly one place where the string -> typed-value parse can be wrong.

The repro-lint rule RL008 (``repro.analysis``) enforces the funnel
statically: any ``os.environ`` access naming a ``REPRO_*`` flag outside
this module is a lint error.

Flags:
  REPRO_DEBUG=1          per-step engine/pool invariant validation
  REPRO_STRICT_KERNELS=1 kernel dispatch failures raise instead of
                         falling back to the reference impl
  REPRO_SANITIZE=1       compile-count sanitizer: engine jit entry points
                         record one tracing event per compiled variant
                         (see repro.analysis.sanitize)
  REPRO_DEQUANT_IMPL     "pallas" forces the Pallas lowering (interpret
                         mode on CPU), "ref" forces the jnp reference,
                         "" picks by backend
  REPRO_AUTOTUNE         kernel tile selection (kernels/autotune.py):
                         "" (default) uses the warm JSON cache when one is
                         readable, else the deterministic fallback table;
                         "0" always uses the table (CI / replay); "1"
                         measures real pallas_call candidates and records
                         the winners
  REPRO_AUTOTUNE_CACHE   path of the autotune JSON config cache ("" = no
                         on-disk cache: measured winners stay in-process)
"""
from __future__ import annotations

import dataclasses
import os


@dataclasses.dataclass(frozen=True)
class Flags:
    """Typed snapshot of the REPRO_* environment flags."""

    debug: bool
    strict_kernels: bool
    sanitize: bool
    dequant_impl: str  # "", "pallas", or "ref"
    autotune: str  # "", "0", or "1"
    autotune_cache: str  # cache file path ("" = none)


_ENV_KEYS = ("REPRO_DEBUG", "REPRO_STRICT_KERNELS", "REPRO_SANITIZE",
             "REPRO_DEQUANT_IMPL", "REPRO_AUTOTUNE", "REPRO_AUTOTUNE_CACHE")
_VALID_IMPLS = ("", "pallas", "ref")
_VALID_AUTOTUNE = ("", "0", "1")

# (raw env tuple, parsed Flags) — rebuilt only when the raw values change,
# so hot callers pay four dict lookups, not a dataclass construction
_cache: tuple = (None, None)


def flags() -> Flags:
    """Current flag snapshot. Re-reads the environment on every call (one
    parse point, consistently observed by every module), memoized on the
    raw values so unchanged environments return the same object."""
    global _cache
    raw = tuple(os.environ.get(k, "") for k in _ENV_KEYS)
    if raw != _cache[0]:
        impl = raw[3]
        if impl not in _VALID_IMPLS:
            raise ValueError(
                f"REPRO_DEQUANT_IMPL={impl!r}: expected one of "
                f"{_VALID_IMPLS} (typo'd values used to silently fall "
                f"through to the backend default)")
        tune = raw[4]
        if tune not in _VALID_AUTOTUNE:
            raise ValueError(
                f"REPRO_AUTOTUNE={tune!r}: expected one of "
                f"{_VALID_AUTOTUNE}")
        _cache = (raw, Flags(debug=raw[0] == "1",
                             strict_kernels=raw[1] == "1",
                             sanitize=raw[2] == "1",
                             dequant_impl=impl,
                             autotune=tune,
                             autotune_cache=raw[5]))
    return _cache[1]


def debug_enabled() -> bool:
    return flags().debug


def strict_kernels() -> bool:
    return flags().strict_kernels


def sanitize_enabled() -> bool:
    return flags().sanitize


def dequant_impl() -> str:
    return flags().dequant_impl


def autotune_mode() -> str:
    return flags().autotune


def autotune_cache_path() -> str:
    return flags().autotune_cache
