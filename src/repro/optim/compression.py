"""Gradient compression (distributed-optimization trick).

int8/int4 symmetric per-leaf quantization with stochastic rounding and error
feedback (residual accumulation): the compressed representation is what a
bandwidth-limited DP all-reduce would carry; error feedback keeps SGD/Adam
convergence (Seide et al. 2014, Karimireddy et al. 2019).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _compress_leaf(g, ef, key, bits: int):
    gf = g.astype(jnp.float32) + ef
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / qmax
    scaled = gf / scale
    noise = jax.random.uniform(key, gf.shape, minval=-0.5, maxval=0.5)
    q = jnp.clip(jnp.round(scaled + noise), -qmax, qmax)
    deq = q * scale
    return deq, gf - deq


def compress_decompress(grads, ef_state, *, bits: int, rng):
    """Returns (decompressed grads, new error-feedback state)."""
    leaves, treedef = jax.tree.flatten(grads)
    ef_leaves = jax.tree.leaves(ef_state)
    keys = jax.random.split(rng, len(leaves))
    outs = [_compress_leaf(g, e, k, bits)
            for g, e, k in zip(leaves, ef_leaves, keys)]
    deq = treedef.unflatten([o[0] for o in outs])
    new_ef = treedef.unflatten([o[1] for o in outs])
    return deq, new_ef
