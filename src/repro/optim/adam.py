"""Adam/AdamW in pure JAX (no optax dependency), pytree-wise."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def adam_init(params: Any) -> dict:
    zeros = lambda p: jax.tree.map(
        lambda x: jnp.zeros_like(x, dtype=jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def adam_update(grads: Any, state: dict, params: Any, *, lr,
                b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
                weight_decay: float = 0.0):
    """Returns (new_params, new_state). lr may be a traced scalar."""
    step = state["step"] + 1
    sf = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** sf
    bc2 = 1.0 - b2 ** sf

    new_m = jax.tree.map(
        lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
        state["m"], grads)
    new_v = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state["v"], grads)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    return new_params, {"m": new_m, "v": new_v, "step": step}


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads: Any, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn
