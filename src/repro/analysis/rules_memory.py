"""RL002 / RL003 — jit-boundary memory-discipline rules.

RL002  host mirrors (PR 5's deferred-transfer race): a numpy view handed
       to / taken from a jit call can alias a buffer jax still owns (or
       one the host is about to mutate); the transfer is async, so the
       corruption is timing-dependent and survives every fast test. All
       mirror traffic across the boundary goes through .copy() /
       np.asarray-of-a-copy.
RL003  donation (PR 8's retry bug): after `f(x)` with x donated, x's
       buffer is deleted — a later read raises on GPU and, worse,
       silently reads stale memory in some interpret paths. A donated
       name may not be loaded again in the same scope unless it is
       rebound first.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from repro.analysis.core import (FileContext, Finding, dotted,
                                 enclosing_statement, jit_info)

# host-side mirror attributes of the engine/pool that shadow device state
MIRROR_ATTRS = {"cur_len", "last_tok", "active", "tables"}
# numpy constructors that materialize fresh host memory (not views)
_FRESH_NP = {"zeros", "ones", "full", "empty", "asarray", "array",
             "arange", "ascontiguousarray", "copy", "concatenate",
             "stack", "where"}


def check_rl002(ctx: FileContext) -> List[Finding]:
    if not ctx.module.startswith("repro.serve"):
        return []
    out = []
    for node in ast.walk(ctx.tree):
        # (a) whole-mirror assignment: self.cur_len = <rhs> — the RHS must
        # be freshly-owned host memory, not a view of a jit output
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if (isinstance(tgt, ast.Attribute)
                        and tgt.attr in MIRROR_ATTRS
                        and isinstance(tgt.value, ast.Name)
                        and not _owns_memory(node.value)):
                    out.append(Finding(
                        ctx.path, node.lineno, "RL002",
                        f"mirror {tgt.attr!r} assigned a possible view; "
                        "route through .copy()/np.asarray so the host "
                        "mirror never aliases a jit buffer"))
        # (b) device upload: jnp.asarray(<expr over a mirror>) — the
        # transfer is deferred, so the mirror must not be mutated before
        # it lands; a .copy() at the boundary decouples them
        elif isinstance(node, ast.Call):
            fn = dotted(node.func)
            if fn in ("jnp.asarray", "jnp.array", "jax.numpy.asarray",
                      "jax.numpy.array") and node.args:
                attr = _unprotected_mirror(node.args[0])
                if attr is not None:
                    out.append(Finding(
                        ctx.path, node.lineno, "RL002",
                        f"mirror {attr!r} uploaded to device without "
                        ".copy(): the transfer is deferred and races "
                        "with host mutation of the mirror"))
    return out


def _owns_memory(rhs: ast.AST) -> bool:
    """True when the RHS provably materializes fresh host memory."""
    if isinstance(rhs, ast.Call):
        fn = dotted(rhs.func)
        if fn:
            head, _, tail = fn.rpartition(".")
            if head in ("np", "numpy") and tail in _FRESH_NP:
                return True
            if tail in ("copy", "astype", "tolist", "item"):
                return True
        # any other call: a helper/factory returning its own array —
        # the rule polices direct view-producing expressions, not
        # interprocedural ownership
        return True
    if isinstance(rhs, (ast.Constant, ast.List, ast.Tuple, ast.Dict,
                        ast.ListComp, ast.DictComp, ast.BinOp, ast.Compare,
                        ast.IfExp, ast.BoolOp, ast.UnaryOp)):
        return True  # scalars / fresh containers / computed arrays
    if isinstance(rhs, ast.Subscript):
        # advanced indexing (array/list index) copies; basic slicing views
        idx = rhs.slice
        return isinstance(idx, (ast.Name, ast.List, ast.Attribute, ast.Call))
    if isinstance(rhs, (ast.Name, ast.Attribute)):
        return False  # rebinding one mirror name to another: aliasing
    return True


def _unprotected_mirror(expr: ast.AST) -> Optional[str]:
    """Mirror attr read inside a device-upload expression with no copy on
    the path to it; None when protected or no mirror involved."""
    protected_calls = {"copy", "astype"}
    for node in ast.walk(expr):
        if isinstance(node, ast.Attribute) and node.attr in MIRROR_ATTRS:
            cur = node
            ok = False
            while cur is not expr and cur is not None:
                parent = getattr(cur, "_rl_parent", None)
                if isinstance(parent, ast.Call):
                    fn = dotted(parent.func)
                    tail = fn.rpartition(".")[2] if fn else None
                    # dotted() can't render `a[i:j].copy` (chain bottoms
                    # in a Subscript) — fall back to the method name
                    if tail is None and isinstance(parent.func,
                                                   ast.Attribute):
                        tail = parent.func.attr
                    if tail in protected_calls or (
                            fn and fn.rpartition(".")[0] in ("np", "numpy")
                            and tail in _FRESH_NP):
                        ok = True
                        break
                if isinstance(parent, ast.Subscript):
                    idx = parent.slice
                    if isinstance(idx, (ast.Name, ast.List, ast.Call)):
                        ok = True  # advanced indexing copies
                        break
                if (isinstance(parent, ast.Attribute)
                        and parent.attr in protected_calls):
                    cur = parent
                    continue
                cur = parent
            if not ok:
                return node.attr
    return None


def check_rl003(ctx: FileContext) -> List[Finding]:
    # pass 1: module-local jitted defs with donated params
    donors = {}
    for node in ast.walk(ctx.tree):
        info = jit_info(node)
        if info and (info.donate_names or info.donate_nums):
            donated = set(info.donate_names)
            for i in info.donate_nums:
                if i < len(info.params):
                    donated.add(info.params[i])
            donors[node.name] = (donated, info.params)
    if not donors:
        return []
    out = []
    for call in ast.walk(ctx.tree):
        if not isinstance(call, ast.Call):
            continue
        callee = dotted(call.func)
        callee = callee.rpartition(".")[2] if callee else None
        if callee not in donors:
            continue
        donated_params, params = donors[callee]
        donated_names = []
        for i, arg in enumerate(call.args):
            if isinstance(arg, ast.Starred):
                # *args call sites hide the binding — out of scope for
                # static checking; the dispatch retry paths that use
                # them rebuild args per attempt by construction
                continue
            pname = params[i] if i < len(params) else None
            if pname in donated_params and isinstance(arg, ast.Name):
                donated_names.append(arg.id)
        for kw in call.keywords:
            if kw.arg in donated_params and isinstance(kw.value, ast.Name):
                donated_names.append(kw.value.id)
        if donated_names:
            out.extend(_reads_after(ctx, call, donated_names, callee))
    return out


def _reads_after(ctx: FileContext, call: ast.Call, names: List[str],
                 callee: str) -> List[Finding]:
    stmt = enclosing_statement(call)
    if stmt is None:
        return []
    parent = getattr(stmt, "_rl_parent", None)
    body = None
    for field in ("body", "orelse", "finalbody"):
        seq = getattr(parent, field, None)
        if isinstance(seq, list) and stmt in seq:
            body = seq
            break
    if body is None:
        return []
    live = set(names)
    # names rebound by the call's own statement are safe (y = f(x) with
    # the result re-stored over x is the canonical donation idiom)
    for tgt in _stored_names(stmt):
        live.discard(tgt)
    out = []
    for later in body[body.index(stmt) + 1:]:
        if not live:
            break
        loaded, stored = _loads_and_stores(later)
        for name in sorted(live & loaded):
            out.append(Finding(
                ctx.path, later.lineno, "RL003",
                f"{name!r} was donated to {callee}() and read again: "
                "its buffer is deleted after the call (donation retry "
                "bug class); rebuild the argument or drop the "
                "donation"))
            live.discard(name)
        live -= stored
    return out


def _stored_names(stmt: ast.stmt) -> set:
    stored = set()
    for node in ast.walk(stmt):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            stored.add(node.id)
    return stored


def _loads_and_stores(stmt: ast.stmt):
    loaded, stored = set(), set()
    for node in ast.walk(stmt):
        if isinstance(node, ast.Name):
            if isinstance(node.ctx, ast.Load):
                loaded.add(node.id)
            elif isinstance(node.ctx, ast.Store):
                stored.add(node.id)
    return loaded, stored
