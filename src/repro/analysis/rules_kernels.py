"""RL004 / RL007 — kernel-contract and partitioning-placement rules.

RL004  every `pl.pallas_call` site must be claimed by an entry in the
       KERNEL_CONTRACTS registry (kernels/ops.py) declaring its jnp ref
       oracle and the parity test that compares them. A kernel without a
       registered oracle is an exactness claim nobody is checking.
RL007  PartitionSpec literals constructed outside
       distributed/partitioning.py scatter the placement contract; the
       TP engine asserts placement against the helpers' output, so an
       inline pspec that drifts fails at runtime on a 4-device host
       only. Empty PartitionSpec() (fully replicated) is allowed — it
       encodes no placement decision.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.core import FileContext, Finding, dotted


def check_rl004(ctx: FileContext) -> List[Finding]:
    out = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        fn = dotted(node.func)
        if fn is None or fn.rpartition(".")[2] != "pallas_call":
            continue
        wrapper = _enclosing_def_name(node)
        entry = (ctx.registry or {}).get(wrapper)
        if entry is None:
            out.append(Finding(
                ctx.path, node.lineno, "RL004",
                f"pallas_call in {wrapper or '<module>'!s} has no "
                "KERNEL_CONTRACTS entry in kernels/ops.py; declare its "
                "ref oracle and parity test"))
        elif entry.get("module") != ctx.module:
            out.append(Finding(
                ctx.path, node.lineno, "RL004",
                f"KERNEL_CONTRACTS[{wrapper!r}] declares module "
                f"{entry.get('module')!r} but the pallas_call lives in "
                f"{ctx.module!r}; update the registry"))
    return out


def _enclosing_def_name(node: ast.AST):
    cur = getattr(node, "_rl_parent", None)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return cur.name
        cur = getattr(cur, "_rl_parent", None)
    return None


# spellings under which PartitionSpec is imported across the tree
_PSPEC_NAMES = {"PartitionSpec", "P"}


def check_rl007(ctx: FileContext) -> List[Finding]:
    if ctx.module.startswith("repro.distributed"):
        return []
    # resolve local aliases: `from jax.sharding import PartitionSpec as P`
    aliases = set()
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.ImportFrom):
            for a in node.names:
                if a.name == "PartitionSpec":
                    aliases.add(a.asname or a.name)
    names = _PSPEC_NAMES | aliases
    out = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        fn = dotted(node.func)
        if fn is None:
            continue
        if fn.rpartition(".")[2] not in names and fn not in (
                "jax.sharding.PartitionSpec",):
            continue
        if fn.rpartition(".")[2] == "P" and "P" not in aliases:
            continue  # bare P() only counts when P aliases PartitionSpec
        if not node.args and not node.keywords:
            continue  # PartitionSpec() == fully replicated: no decision
        out.append(Finding(
            ctx.path, node.lineno, "RL007",
            "inline PartitionSpec with axes: placement decisions live in "
            "distributed/partitioning.py helpers so the TP placement "
            "asserts check one source of truth"))
    return out
