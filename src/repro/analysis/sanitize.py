"""Compile-count sanitizer: tracing events per (entry point, variant).

JAX re-traces a jitted function whenever the (shape, dtype, static-arg)
signature changes, so a Python-level side effect placed *inside* the jit
body runs exactly once per compiled variant and never on cache hits.
:func:`note_trace` exploits that: each engine jit entry point calls it at
the top of its body with the shape-bucket/config values that legitimately
key its cache (bucket width, batch, temperature, kernel impl, ...).
Under ``REPRO_SANITIZE=1`` every compilation therefore increments a
counter keyed ``(name, sorted(key items))`` — and a shape-bucketing leak
(e.g. a raw length reaching a jit instead of its bucket) shows up as an
unbounded stream of new keys instead of a silent 10x slowdown.

Budget semantics: each key is one compiled variant, so the budget is
**1 tracing per key**; a second tracing for the same key means the cache
was defeated by something *outside* the key (weak-typed scalar flips,
accidental new hashable statics) and is exactly the regression class
this is built to catch.

CLI (``python -m repro.analysis.sanitize``): builds a tiny model, replays
the seeded bursty trace from serve/traffic.py, then replays it again on
a fresh engine in the same process — the second pass must add **zero**
new tracings (every bucket was already compiled) and no key may exceed
the budget. Exits nonzero otherwise. CI runs this in the
static-analysis job.
"""
from __future__ import annotations

import sys
from collections import Counter
from typing import Dict, Optional, Tuple

from repro.debug_flags import sanitize_enabled

Key = Tuple[str, Tuple[Tuple[str, object], ...]]

_trace_counts: Counter = Counter()


def note_trace(name: str, **key) -> None:
    """Record one tracing of jit entry point `name` for the cache variant
    described by `key`. Call from *inside* the jit body: the side effect
    fires at trace time only. No-op (one bool check) unless
    REPRO_SANITIZE=1, so the hot path pays nothing in production."""
    if not sanitize_enabled():
        return
    _trace_counts[(name, tuple(sorted(key.items())))] += 1


def trace_counts() -> Dict[Key, int]:
    return dict(_trace_counts)


def reset_trace_counts() -> None:
    _trace_counts.clear()


def new_traces(baseline: Dict[Key, int]) -> Dict[Key, int]:
    """Tracings that happened since `baseline` (a trace_counts() snapshot):
    {key: extra count}. Empty means the compile cache fully absorbed the
    workload — the steady-state invariant."""
    return {k: c - baseline.get(k, 0) for k, c in _trace_counts.items()
            if c > baseline.get(k, 0)}


def budget_violations(max_per_key: int = 1) -> Dict[Key, int]:
    """Keys traced more than `max_per_key` times. The key *is* the
    compile-cache signature we intend, so >1 means something outside the
    key forced a retrace."""
    return {k: c for k, c in _trace_counts.items() if c > max_per_key}


def format_report(baseline: Optional[Dict[Key, int]] = None) -> str:
    lines = [f"sanitize: {sum(_trace_counts.values())} tracings across "
             f"{len(_trace_counts)} compiled variants"]
    for (name, key), count in sorted(_trace_counts.items()):
        kv = ", ".join(f"{k}={v}" for k, v in key)
        lines.append(f"  {name}({kv}): {count}")
    if baseline is not None:
        fresh = new_traces(baseline)
        lines.append(f"sanitize: {sum(fresh.values())} new tracings since "
                     "baseline" + ("" if fresh else " (cache-stable)"))
    return "\n".join(lines)


def _build_engine():
    # deferred imports: the sanitizer CLI needs jax + the engine, but
    # note_trace() must stay importable from anywhere without them
    import jax

    from repro.configs import TINY
    from repro.models.transformer import init_lm

    cfg = TINY.replace(n_repeats=2, d_model=64, head_dim=16, d_ff=128)
    params = init_lm(cfg, jax.random.PRNGKey(0))
    return cfg, params


def main(argv=None) -> int:
    import os

    # the sanitizer CLI is the one legitimate writer of its own flag
    os.environ.setdefault("REPRO_SANITIZE", "1")  # repro-lint: disable=RL008
    if not sanitize_enabled():
        print("sanitize: REPRO_SANITIZE is explicitly disabled", flush=True)
        return 2
    from repro.serve.engine import ContinuousEngine
    from repro.serve.traffic import make_trace, replay

    cfg, params = _build_engine()
    trace = make_trace(kind="bursty", n=24, seed=0,
                       vocab_size=cfg.vocab_size)

    reset_trace_counts()
    eng = ContinuousEngine(cfg, params, n_slots=4)
    replay(eng, trace)
    first = trace_counts()
    print(format_report(), flush=True)

    # second replay, fresh engine, same process: the jit caches are
    # process-global, so every variant must already be compiled
    eng2 = ContinuousEngine(cfg, params, n_slots=4)
    replay(eng2, trace)
    fresh = new_traces(first)
    over = budget_violations(max_per_key=1)

    ok = True
    if fresh:
        ok = False
        print(f"sanitize: FAIL — {sum(fresh.values())} new tracings on "
              "second replay (compile cache defeated):")
        for (name, key), count in sorted(fresh.items()):
            kv = ", ".join(f"{k}={v}" for k, v in key)
            print(f"  {name}({kv}): +{count}")
    if over:
        ok = False
        print("sanitize: FAIL — per-variant compile budget (1) exceeded:")
        for (name, key), count in sorted(over.items()):
            kv = ", ".join(f"{k}={v}" for k, v in key)
            print(f"  {name}({kv}): {count}")
    if ok:
        print("sanitize: OK — second replay added zero tracings and every "
              "variant compiled exactly once")
    return 0 if ok else 1


if __name__ == "__main__":
    # under `python -m` this file runs as __main__ while the engine's
    # `from repro.analysis.sanitize import note_trace` loads the canonical
    # module instance — two copies of _trace_counts. Delegate to the
    # canonical one so the counts the engine writes are the counts we read.
    from repro.analysis.sanitize import main as _main
    sys.exit(_main())
