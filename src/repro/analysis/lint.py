"""repro-lint driver: `python -m repro.analysis.lint src/ [--tests tests]`.

Walks the given roots for .py files, runs every rule over each file,
applies `# repro-lint: disable=RLxxx` pragmas, then runs the tree-level
RL004 cross-checks (registry completeness both directions, ref-oracle
existence, parity-test existence). Exits 1 with `file:line RLxxx
message` lines when anything is found.
"""
from __future__ import annotations

import argparse
import ast
import os
import sys
from typing import List, Optional

from repro.analysis import rules_determinism, rules_kernels, rules_memory
from repro.analysis.core import FileContext, Finding, dotted, module_name_for

RULE_CHECKS = (
    rules_determinism.check_rl001,
    rules_memory.check_rl002,
    rules_memory.check_rl003,
    rules_kernels.check_rl004,
    rules_determinism.check_rl005,
    rules_determinism.check_rl006,
    rules_kernels.check_rl007,
    rules_determinism.check_rl008,
)

OPS_MODULE = "repro.kernels.ops"
REGISTRY_NAME = "KERNEL_CONTRACTS"


def iter_py_files(roots) -> List[str]:
    files = []
    for root in roots:
        if os.path.isfile(root):
            files.append(root)
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in ("__pycache__", ".git"))
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    files.append(os.path.join(dirpath, fn))
    return files


def extract_registry(files) -> Optional[dict]:
    """ast.literal_eval the KERNEL_CONTRACTS assignment out of
    kernels/ops.py — the registry is a pure literal by design so the
    linter never has to import (and thus trace) kernel code."""
    for path in files:
        if module_name_for(path) != OPS_MODULE:
            continue
        with open(path, encoding="utf-8") as f:
            tree = ast.parse(f.read(), filename=path)
        for node in tree.body:
            if (isinstance(node, ast.Assign)
                    and any(isinstance(t, ast.Name)
                            and t.id == REGISTRY_NAME
                            for t in node.targets)):
                return ast.literal_eval(node.value)
    return None


def lint_source(source: str, path: str, module: Optional[str] = None,
                registry: Optional[dict] = None) -> List[Finding]:
    """Lint one source string. Fixture tests call this directly: module
    controls rule scoping, registry=None makes RL004 flag every
    pallas_call site."""
    ctx = FileContext(path, module or module_name_for(path), source,
                      registry=registry)
    findings: List[Finding] = []
    for check in RULE_CHECKS:
        findings.extend(f for f in check(ctx) if not ctx.suppressed(f))
    return findings


def _collect_test_ids(tests_root: str) -> dict:
    """{relative test path: set of test function names} for parity-id
    validation; parsed, not collected, so the linter stays import-free."""
    ids = {}
    for path in iter_py_files([tests_root]):
        rel = os.path.relpath(path, os.path.dirname(tests_root) or ".")
        with open(path, encoding="utf-8") as f:
            try:
                tree = ast.parse(f.read(), filename=path)
            except SyntaxError:
                continue
        ids[rel.replace(os.sep, "/")] = {
            n.name for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
            and n.name.startswith("test")}
    return ids


def cross_check_registry(registry: Optional[dict], files,
                         tests_root: Optional[str]) -> List[Finding]:
    """Tree-level RL004 checks that no single file's AST can answer:
    stale registry entries, missing ref oracles, dangling parity ids."""
    out: List[Finding] = []
    ops_path = next((p for p in files
                     if module_name_for(p) == OPS_MODULE), "kernels/ops.py")
    if registry is None:
        if any(module_name_for(p).startswith("repro.kernels")
               for p in files):
            out.append(Finding(ops_path, 1, "RL004",
                               f"{REGISTRY_NAME} literal not found in "
                               f"{OPS_MODULE}"))
        return out
    # wrapper functions that actually contain a pallas_call, per module
    sites = {}
    for path in files:
        mod = module_name_for(path)
        with open(path, encoding="utf-8") as f:
            source = f.read()
        if "pallas_call" not in source:
            continue
        ctx = FileContext(path, mod, source)
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                d = dotted(node.func)
                if d and d.rpartition(".")[2] == "pallas_call":
                    name = rules_kernels._enclosing_def_name(node)
                    sites.setdefault(name, set()).add(mod)
    # ref oracle targets must exist in their declared module
    ref_defs = {}
    for path in files:
        mod = module_name_for(path)
        if any(e.get("ref", "").startswith(mod + ":")
               for e in registry.values()):
            with open(path, encoding="utf-8") as f:
                tree = ast.parse(f.read(), filename=path)
            ref_defs[mod] = {
                n.name for n in ast.walk(tree)
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    test_ids = _collect_test_ids(tests_root) if tests_root else None
    for wrapper, entry in sorted(registry.items()):
        if wrapper not in sites:
            out.append(Finding(ops_path, 1, "RL004",
                               f"{REGISTRY_NAME}[{wrapper!r}] is stale: "
                               "no pallas_call site with that wrapper "
                               "exists"))
            continue
        ref = entry.get("ref", "")
        mod, _, fn = ref.partition(":")
        if not fn or fn not in ref_defs.get(mod, set()):
            out.append(Finding(ops_path, 1, "RL004",
                               f"{REGISTRY_NAME}[{wrapper!r}] ref oracle "
                               f"{ref!r} does not resolve to a function"))
        if test_ids is not None:
            for tid in entry.get("parity", ()):
                tpath, _, tname = tid.partition("::")
                if tname not in test_ids.get(tpath, set()):
                    out.append(Finding(
                        ops_path, 1, "RL004",
                        f"{REGISTRY_NAME}[{wrapper!r}] parity id "
                        f"{tid!r} does not match a collected test"))
    return out


def lint_paths(roots, tests: Optional[str] = None) -> List[Finding]:
    files = iter_py_files(roots)
    registry = extract_registry(files)
    findings: List[Finding] = []
    for path in files:
        with open(path, encoding="utf-8") as f:
            source = f.read()
        try:
            findings.extend(lint_source(source, path, registry=registry))
        except SyntaxError as e:
            findings.append(Finding(path, e.lineno or 1, "RL000",
                                    f"syntax error: {e.msg}"))
    findings.extend(cross_check_registry(registry, files, tests))
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="repro-lint: codebase-specific static analysis")
    ap.add_argument("roots", nargs="+", help="files or directories to lint")
    ap.add_argument("--tests", default=None,
                    help="tests root for the RL004 parity-id cross-check")
    args = ap.parse_args(argv)
    findings = lint_paths(args.roots, tests=args.tests)
    for f in findings:
        print(f.format())
    if findings:
        print(f"repro-lint: {len(findings)} finding(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
