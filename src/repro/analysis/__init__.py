"""repro-lint: codebase-specific static analysis + runtime sanitizers.

Two halves:

* :mod:`repro.analysis.lint` — an AST-based analyzer with rule-coded
  diagnostics (``RL001``..``RL008``) that encode this repo's exactness
  contracts: virtual-clock-only time in the serving/kernel hot paths,
  ``.copy()`` discipline at jit boundaries for host mirrors, donation
  safety, a kernel-contract registry covering every ``pl.pallas_call``
  site, recompile hazards, int32 mirror dtypes, centralized pspecs, and
  centralized env-flag parsing. Run as ``python -m repro.analysis.lint
  src/``; exits nonzero with ``file:line RLxxx message`` lines.

* :mod:`repro.analysis.sanitize` — a runtime compile-count sanitizer.
  With ``REPRO_SANITIZE=1`` the engine's jit entry points record one
  tracing event per compiled variant; a seeded traffic replay then
  asserts a per-(entry point, shape-bucket/config) compile budget so a
  shape-bucketing leak fails CI instead of silently retracing per step.

Rule docs (code -> one-line contract) live in ``core.RULE_DOCS``;
DESIGN.md "Invariants & static analysis" has the full table with the
incidents that motivated each rule.
"""
from repro.analysis.core import Finding, RULE_DOCS  # noqa: F401
