"""RL001 / RL005 / RL006 / RL008 — determinism & compile-stability rules.

RL001  serve/ and kernels/ are replayed under a virtual clock and seeded
       RNG; any ambient-entropy read there breaks bit-exact replay.
RL005  jit construction inside a loop (or unhashable static-arg
       literals) defeats the compile cache — every iteration retraces.
RL006  slot mirrors and block tables are int32 by contract (device
       mirrors, gather indices, spill checksums all assume it).
RL008  REPRO_* env flags have one parse point (repro.debug_flags);
       scattered os.environ reads observe mid-process changes
       inconsistently.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.core import (FileContext, Finding, dotted, in_loop,
                                 decode_jit_call)

# ambient time sources; time.sleep is fine (pacing, not a value the
# token stream depends on) and the engine's virtual clock is its own module
_TIME_BANNED = {"time", "monotonic", "perf_counter", "process_time",
                "time_ns", "monotonic_ns", "perf_counter_ns",
                "process_time_ns"}
# np.random module-level calls draw from hidden global state; the
# explicitly-seeded constructors are the sanctioned path
_NP_RANDOM_OK = {"default_rng", "Generator", "SeedSequence", "PCG64",
                 "Philox"}
# request tables keyed by arrival id — bare iteration order is
# insertion order, which differs across replay variants; sorted() only
_ID_KEYED_DICTS = {"_prefilling"}


def _covered_rl001(module: str) -> bool:
    return module.startswith("repro.serve") or module.startswith(
        "repro.kernels")


def check_rl001(ctx: FileContext) -> List[Finding]:
    if not _covered_rl001(ctx.module):
        return []
    out = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call):
            name = dotted(node.func)
            if name is None:
                continue
            head, _, tail = name.rpartition(".")
            if head in ("time", "_time") and tail in _TIME_BANNED:
                out.append(Finding(ctx.path, node.lineno, "RL001",
                                   f"wall-clock read {name}() in a "
                                   "virtual-clock module; thread the "
                                   "clock in explicitly"))
            elif head == "random" or name.startswith("random."):
                out.append(Finding(ctx.path, node.lineno, "RL001",
                                   f"stdlib {name}() draws from ambient "
                                   "global RNG state; use a seeded "
                                   "np.random.default_rng or jax.random"))
            elif name.startswith("np.random.") or name.startswith(
                    "numpy.random."):
                if tail not in _NP_RANDOM_OK:
                    out.append(Finding(ctx.path, node.lineno, "RL001",
                                       f"{name}() uses numpy's hidden "
                                       "global RNG; use a seeded "
                                       "default_rng(seed)"))
                elif tail == "default_rng" and not (node.args
                                                    or node.keywords):
                    out.append(Finding(ctx.path, node.lineno, "RL001",
                                       "default_rng() without a seed is "
                                       "OS-entropy seeded; pass an "
                                       "explicit seed"))
        elif isinstance(node, ast.For):
            tgt = _iter_dict_name(node.iter)
            if tgt in _ID_KEYED_DICTS:
                out.append(Finding(ctx.path, node.lineno, "RL001",
                                   f"iteration over id-keyed dict "
                                   f"{tgt!r} in an event path depends "
                                   "on insertion order; wrap in "
                                   "sorted()"))
    return out


def _iter_dict_name(it: ast.AST):
    """The mirror-dict name iterated over, unless order-normalized.
    Matches `self._prefilling`, `self._prefilling.keys()/.values()/
    .items()`, and `list(self._prefilling)`; sorted(...) passes."""
    if isinstance(it, ast.Call):
        fn = dotted(it.func)
        if fn == "sorted":
            return None
        if fn == "list" and it.args:
            return _iter_dict_name(it.args[0])
        if isinstance(it.func, ast.Attribute) and it.func.attr in (
                "keys", "values", "items"):
            it = it.func.value
    name = dotted(it)
    if name:
        return name.rpartition(".")[2]
    return None


_UNHASHABLE = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
               ast.SetComp)
_UNHASHABLE_CTORS = {"list", "dict", "set"}


def check_rl005(ctx: FileContext) -> List[Finding]:
    out = []
    # pass 1: collect module-visible jitted defs and their static params,
    # so call sites can be checked for unhashable static-arg literals
    statics_by_fn = {}
    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            from repro.analysis.core import jit_info
            info = jit_info(node)
            if info and info.static_names:
                statics_by_fn[node.name] = (info.static_names, info.params)
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        if decode_jit_call(node) is not None and in_loop(node):
            out.append(Finding(ctx.path, node.lineno, "RL005",
                               "jax.jit constructed inside a loop: each "
                               "iteration makes a fresh callable with an "
                               "empty compile cache; hoist the jit out"))
            continue
        # call site of a known jitted def: static args must be hashable
        callee = dotted(node.func)
        callee = callee.rpartition(".")[2] if callee else None
        if callee in statics_by_fn:
            static_names, params = statics_by_fn[callee]
            for i, arg in enumerate(node.args):
                pname = params[i] if i < len(params) else None
                if pname in static_names and _unhashable(arg):
                    out.append(_rl005_static(ctx, arg, pname))
            for kw in node.keywords:
                if kw.arg in static_names and _unhashable(kw.value):
                    out.append(_rl005_static(ctx, kw.value, kw.arg))
    return out


def _unhashable(node: ast.AST) -> bool:
    if isinstance(node, _UNHASHABLE):
        return True
    if isinstance(node, ast.Call) and dotted(node.func) in _UNHASHABLE_CTORS:
        return True
    return False


def _rl005_static(ctx: FileContext, node: ast.AST, pname) -> Finding:
    return Finding(ctx.path, node.lineno, "RL005",
                   f"unhashable literal for static arg {pname!r}: jit "
                   "either raises or, via __eq__-based caching, silently "
                   "retraces; pass a tuple")


# int32-by-contract mirrors: device gather/scatter indices, block tables,
# and spill checksums all assume these never widen to int64
INT32_MIRRORS = {"cur_len", "last_tok", "tables"}
_NP_CTORS = {"zeros", "ones", "full", "empty", "arange", "asarray", "array"}


def check_rl006(ctx: FileContext) -> List[Finding]:
    if not ctx.module.startswith("repro.serve"):
        return []
    out = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Assign):
            continue
        for tgt in node.targets:
            if (isinstance(tgt, ast.Attribute)
                    and tgt.attr in INT32_MIRRORS
                    and isinstance(tgt.value, ast.Name)):
                bad = _np_ctor_not_int32(node.value)
                if bad is not None:
                    out.append(Finding(ctx.path, node.lineno, "RL006",
                                       f"mirror {tgt.attr!r} constructed "
                                       f"via np.{bad} without an explicit "
                                       "np.int32 dtype (platform-default "
                                       "int differs across hosts)"))
    return out


def _np_ctor_not_int32(value: ast.AST):
    """Name of the np constructor when `value` builds an array without an
    int32 dtype; None when int32 is explicit or the RHS isn't a fresh
    np construction. Unwraps trailing .copy()/.astype(...)."""
    while (isinstance(value, ast.Call)
           and isinstance(value.func, ast.Attribute)
           and value.func.attr in ("copy", "astype")):
        if value.func.attr == "astype" and _mentions_int32(value):
            return None
        value = value.func.value
    if not isinstance(value, ast.Call):
        return None
    name = dotted(value.func)
    if name is None:
        return None
    head, _, tail = name.rpartition(".")
    if head not in ("np", "numpy") or tail not in _NP_CTORS:
        return None
    return None if _mentions_int32(value) else tail


def _mentions_int32(call: ast.Call) -> bool:
    for sub in list(call.args) + [kw.value for kw in call.keywords]:
        d = dotted(sub)
        if d and d.rpartition(".")[2] == "int32":
            return True
    return False


def check_rl008(ctx: FileContext) -> List[Finding]:
    if ctx.module == "repro.debug_flags":
        return []
    out = []
    for node in ast.walk(ctx.tree):
        flag = None
        if isinstance(node, ast.Subscript):  # os.environ["REPRO_X"]
            if dotted(node.value) in ("os.environ", "environ"):
                flag = _repro_const(node.slice)
        elif isinstance(node, ast.Call):
            fn = dotted(node.func)
            if fn in ("os.getenv", "getenv") and node.args:
                flag = _repro_const(node.args[0])
            elif (isinstance(node.func, ast.Attribute)
                  and node.func.attr in ("get", "pop", "setdefault")
                  and dotted(node.func.value) in ("os.environ", "environ")
                  and node.args):
                flag = _repro_const(node.args[0])
        if flag:
            out.append(Finding(ctx.path, node.lineno, "RL008",
                               f"direct env read of {flag}; go through "
                               "repro.debug_flags so every module sees "
                               "the same parse"))
    return out


def _repro_const(node: ast.AST):
    if (isinstance(node, ast.Constant) and isinstance(node.value, str)
            and node.value.startswith("REPRO_")):
        return node.value
    return None
