"""Shared machinery for repro-lint rules: findings, pragmas, AST helpers.

Every rule module exposes ``check(ctx) -> list[Finding]`` functions that
take a :class:`FileContext` (parsed tree + per-file metadata) and return
rule-coded findings. The driver in :mod:`repro.analysis.lint` handles
file discovery, pragma suppression, and cross-file checks (the RL004
registry cross-check needs the kernel-contract registry and the test
tree, which no single file's AST contains).
"""
from __future__ import annotations

import ast
import dataclasses
import re
from typing import Iterable, Optional

# code -> one-line contract; keep in sync with the DESIGN.md rule table
RULE_DOCS = {
    "RL001": "no wall-clock/ambient nondeterminism in serve/ or kernels/ "
             "(time.time, stdlib random, unseeded np.random, unordered "
             "iteration over id-keyed request dicts)",
    "RL002": "host-mirror copy discipline: mirror attrs (cur_len, "
             "last_tok, active, tables) must cross the jit boundary via "
             ".copy()/np.asarray, never as views of donated buffers",
    "RL003": "donation safety: a name passed for a donated parameter may "
             "not be read again after the call in the same scope",
    "RL004": "every pl.pallas_call site maps to a KERNEL_CONTRACTS entry "
             "in kernels/ops.py declaring its ref oracle and parity test",
    "RL005": "recompile hazards: no jax.jit construction inside a loop, "
             "no unhashable literals for static args of jitted calls",
    "RL006": "int32 dtype contract: slot mirrors and block tables must be "
             "constructed as np.int32",
    "RL007": "PartitionSpec leaves come from distributed/partitioning.py "
             "helpers, not inline literals",
    "RL008": "REPRO_* env flags are read only via repro.debug_flags",
}

_PRAGMA_RE = re.compile(r"#\s*repro-lint:\s*disable=([A-Z0-9_,\s]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    rule: str
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line} {self.rule} {self.message}"


class FileContext:
    """One parsed source file plus everything rules need to judge it."""

    def __init__(self, path: str, module: str, source: str,
                 registry: Optional[dict] = None):
        self.path = path
        self.module = module  # dotted module name, e.g. "repro.serve.engine"
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        # registry: KERNEL_CONTRACTS from kernels/ops.py, or None when
        # linting a lone snippet (fixture tests) — RL004 then flags every
        # pallas_call site, which is exactly what the trigger fixture wants
        self.registry = registry
        annotate_parents(self.tree)

    def suppressed(self, finding: Finding) -> bool:
        """True if a `# repro-lint: disable=RLxxx` pragma names the rule,
        either trailing the finding's line or on a standalone comment line
        directly above it (a trailing pragma never leaks to the next
        statement)."""
        for lineno in (finding.line, finding.line - 1):
            if not 1 <= lineno <= len(self.lines):
                continue
            text = self.lines[lineno - 1]
            if lineno != finding.line and not text.lstrip().startswith("#"):
                continue
            m = _PRAGMA_RE.search(text)
            if m:
                codes = {c.strip() for c in m.group(1).split(",")}
                if finding.rule in codes or "ALL" in codes:
                    return True
        return False


def annotate_parents(tree: ast.AST) -> None:
    for parent in ast.walk(tree):
        for child in ast.iter_child_nodes(parent):
            child._rl_parent = parent  # type: ignore[attr-defined]


def parents(node: ast.AST) -> Iterable[ast.AST]:
    cur = getattr(node, "_rl_parent", None)
    while cur is not None:
        yield cur
        cur = getattr(cur, "_rl_parent", None)


def dotted(node: ast.AST) -> Optional[str]:
    """Render a Name/Attribute chain as 'a.b.c', else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def in_loop(node: ast.AST) -> bool:
    """True if the node sits inside a for/while body within the nearest
    enclosing function (a loop outside the function doesn't count: the
    function body is traced/compiled once regardless)."""
    for p in parents(node):
        if isinstance(p, (ast.For, ast.AsyncFor, ast.While)):
            return True
        if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            return False
    return False


def enclosing_function(node: ast.AST) -> Optional[ast.AST]:
    for p in parents(node):
        if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return p
    return None


def enclosing_statement(node: ast.AST) -> Optional[ast.stmt]:
    cur: Optional[ast.AST] = node
    while cur is not None and not isinstance(cur, ast.stmt):
        cur = getattr(cur, "_rl_parent", None)
    return cur


@dataclasses.dataclass
class JitInfo:
    """Decoded @jax.jit / @partial(jax.jit, ...) decoration of a def."""
    static_names: tuple
    donate_names: tuple
    donate_nums: tuple
    params: tuple  # positional+kw parameter names, in order


_JIT_NAMES = {"jax.jit", "jit"}


def _tuple_of_str(node: ast.AST) -> tuple:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(e.value for e in node.elts
                     if isinstance(e, ast.Constant) and isinstance(e.value, str))
    return ()


def _tuple_of_int(node: ast.AST) -> tuple:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(e.value for e in node.elts
                     if isinstance(e, ast.Constant) and isinstance(e.value, int))
    return ()


def decode_jit_call(call: ast.Call) -> Optional[JitInfo]:
    """Decode a jax.jit(...) or functools.partial(jax.jit, ...) call node
    into static/donate params; None if it isn't a jit construction."""
    fn = dotted(call.func)
    kwargs = call.keywords
    if fn in ("functools.partial", "partial") and call.args:
        inner = dotted(call.args[0])
        if inner not in _JIT_NAMES:
            return None
    elif fn not in _JIT_NAMES:
        return None
    static, dnames, dnums = (), (), ()
    for kw in kwargs:
        if kw.arg == "static_argnames":
            static = _tuple_of_str(kw.value)
        elif kw.arg == "donate_argnames":
            dnames = _tuple_of_str(kw.value)
        elif kw.arg == "donate_argnums":
            dnums = _tuple_of_int(kw.value)
    return JitInfo(static, dnames, dnums, ())


def jit_info(fndef: ast.AST) -> Optional[JitInfo]:
    """JitInfo for a decorated def, with params filled in; None when the
    def isn't jit-decorated."""
    if not isinstance(fndef, (ast.FunctionDef, ast.AsyncFunctionDef)):
        return None
    for dec in fndef.decorator_list:
        info = None
        if isinstance(dec, ast.Call):
            info = decode_jit_call(dec)
        elif dotted(dec) in _JIT_NAMES:
            info = JitInfo((), (), (), ())
        if info is not None:
            args = fndef.args
            params = tuple(a.arg for a in args.posonlyargs + args.args
                           + args.kwonlyargs)
            return JitInfo(info.static_names, info.donate_names,
                           info.donate_nums, params)
    return None


def module_name_for(path: str) -> str:
    """Dotted module name from a file path, rooted at the last 'repro'
    path component ('src/repro/serve/engine.py' -> 'repro.serve.engine');
    falls back to the bare stem for paths outside the package."""
    parts = path.replace("\\", "/").split("/")
    stem = parts[-1][:-3] if parts[-1].endswith(".py") else parts[-1]
    if "repro" in parts[:-1]:
        root = len(parts) - 2 - parts[-2::-1].index("repro")
        pkg = parts[root:-1]
        return ".".join(pkg + ([] if stem == "__init__" else [stem]))
    return stem
