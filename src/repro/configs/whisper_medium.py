"""Whisper-medium [arXiv:2212.04356]: enc-dec, 24+24L d1024 16H MHA d_ff 4096,
vocab 51865. Conv audio frontend is a stub: encoder consumes precomputed
frame embeddings via input_specs()."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    vocab_size=51865,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    # decoder layers: causal self-attn + cross-attn + MLP
    pattern=(LayerSpec(kind="attn", mlp="dense", cross_attn=True),),
    n_repeats=24,
    enc_dec=True,
    enc_pattern=(LayerSpec(kind="attn", mlp="dense"),),
    n_enc_repeats=24,
    norm="layernorm",
    act="gelu",
    rope="none",
    pos_emb="learned",
    max_position=32768,  # whisper uses 448; widened for the decode_32k cell
    frontend="audio",
)

SMOKE = CONFIG.replace(vocab_size=512, d_model=64, n_heads=4, n_kv_heads=4,
                       head_dim=16, d_ff=128, n_repeats=2, n_enc_repeats=2,
                       max_position=512)
