"""InternVL2-2B [arXiv:2404.16821]: InternLM2-1.8B backbone — 24L d2048 16H
GQA(kv=8) d_ff 8192, vocab 92553. InternViT frontend is a stub: input_specs()
provides precomputed patch embeddings prepended to the token sequence."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    vocab_size=92553,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    pattern=(LayerSpec(kind="attn", mlp="dense"),),
    n_repeats=24,
    norm="rmsnorm",
    act="silu",
    rope="full",
    frontend="vision",
    frontend_len=256,  # 448px, patch 14, pixel-shuffle 0.5 -> 256 tokens
)

SMOKE = CONFIG.replace(vocab_size=512, d_model=64, n_heads=4, n_kv_heads=2,
                       head_dim=16, d_ff=128, n_repeats=2, frontend_len=8)
