"""Mixtral-8x22B [arXiv:2401.04088]: 56L d6144 48H GQA(kv=8) MoE 8 experts
top-2 (d_ff 16384), vocab 32768, SWA window 4096 (per assignment spec)."""
from repro.models.config import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    vocab_size=32768,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    pattern=(LayerSpec(kind="attn", mlp="moe"),),
    n_repeats=56,
    norm="rmsnorm",
    act="silu",
    rope="full",
    rope_theta=1e6,
    attn_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16384),
    fsdp=True,
    serve_quant_bits=4,
)

SMOKE = CONFIG.replace(vocab_size=512, d_model=64, n_heads=4, n_kv_heads=2,
                       head_dim=16, d_ff=128, n_repeats=2, attn_window=32,
                       moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64),
                       fsdp=False)
