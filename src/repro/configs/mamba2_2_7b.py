"""Mamba2-2.7B [arXiv:2405.21060]: 64L d2560, attention-free SSD,
d_state 128, head_dim 64, expand 2 (d_inner 5120, 80 heads), vocab 50280."""
from repro.models.config import LayerSpec, Mamba2Config, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    vocab_size=50280,
    d_model=2560,
    n_heads=1,          # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    pattern=(LayerSpec(kind="mamba", mlp="none"),),
    n_repeats=64,
    norm="rmsnorm",
    act="silu",
    rope="none",
    mamba=Mamba2Config(d_state=128, head_dim=64, expand=2, d_conv=4,
                       n_groups=1, chunk=128),
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    vocab_size=512, d_model=64, n_repeats=2,
    mamba=Mamba2Config(d_state=16, head_dim=16, expand=2, d_conv=4,
                       n_groups=1, chunk=16))
