"""Jamba-1.5-Large (398B) [arXiv:2403.19887]: 72L d8192 64H GQA(kv=8),
attn:mamba 1:7 interleave (attn at index 4 of each 8-layer period), MoE 16
experts top-2 (d_ff 24576) on every other layer, vocab 65536.

Jamba uses Mamba-1 blocks upstream; our SSM substrate is Mamba2/SSD (the
TPU-friendly dual form) — noted in DESIGN.md §Arch-applicability.
"""
from repro.models.config import LayerSpec, Mamba2Config, ModelConfig, MoEConfig

_M = LayerSpec(kind="mamba", mlp="dense")
_MM = LayerSpec(kind="mamba", mlp="moe")
_A = LayerSpec(kind="attn", mlp="dense")
_AM = LayerSpec(kind="attn", mlp="moe")

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    vocab_size=65536,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    # period 8: attn at 4, MoE at odd indices
    pattern=(_M, _MM, _M, _MM, _A, _MM, _M, _MM),
    n_repeats=9,
    norm="rmsnorm",
    act="silu",
    rope="none",
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576),
    mamba=Mamba2Config(d_state=128, head_dim=64, expand=2, d_conv=4,
                       n_groups=1, chunk=128),
    fsdp=True,
    serve_quant_bits=4,
    moe_impl="shard_map",  # 16 experts divide the TP axis (§Perf)
)

SMOKE = CONFIG.replace(
    vocab_size=512, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, n_repeats=1,
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128),
    mamba=Mamba2Config(d_state=16, head_dim=16, expand=2, d_conv=4,
                       n_groups=1, chunk=16),
    fsdp=False)
