"""Llama-3.2-1B [hf:meta-llama/Llama-3.2-1B]: 16L d2048 32H GQA(kv=8)
d_ff 8192, vocab 128256, tied embeddings."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    vocab_size=128256,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    pattern=(LayerSpec(kind="attn", mlp="dense"),),
    n_repeats=16,
    norm="rmsnorm",
    act="silu",
    rope="full",
    rope_theta=5e5,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(vocab_size=512, d_model=64, n_heads=4, n_kv_heads=2,
                       head_dim=16, d_ff=160, n_repeats=2)
