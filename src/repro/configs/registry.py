"""Architecture registry: `--arch <id>` resolution for all assigned archs."""
from __future__ import annotations

import importlib

from repro.models.config import LayerSpec, ModelConfig

_MODULES = {
    "qwen2-0.5b": "repro.configs.qwen2_0_5b",
    "chatglm3-6b": "repro.configs.chatglm3_6b",
    "llama3.2-1b": "repro.configs.llama3_2_1b",
    "granite-20b": "repro.configs.granite_20b",
    "whisper-medium": "repro.configs.whisper_medium",
    "internvl2-2b": "repro.configs.internvl2_2b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large_398b",
    "mamba2-2.7b": "repro.configs.mamba2_2_7b",
}

# a tiny paper-style config used by examples/tests (the "paper's own" model:
# a small LLaMa-family decoder, where the paper reports its largest NT gains).
# RMSNorm (no re-centering) lets quantization drift accumulate with depth —
# the Figure-1 phenomenon — and 8 blocks make it visible.
TINY = ModelConfig(
    name="tiny-lm", family="dense", vocab_size=256, d_model=192, n_heads=4,
    n_kv_heads=4, head_dim=48, d_ff=576,
    pattern=(LayerSpec(kind="attn", mlp="dense"),), n_repeats=8,
    norm="rmsnorm", act="silu", rope="full", remat=False)


def list_archs() -> list[str]:
    return list(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name in ("tiny", "tiny-lm"):
        return TINY
    mod = importlib.import_module(_MODULES[name])
    cfg = mod.CONFIG
    cfg.validate()
    return cfg


def get_smoke_config(name: str) -> ModelConfig:
    if name in ("tiny", "tiny-lm"):
        return TINY
    mod = importlib.import_module(_MODULES[name])
    cfg = mod.SMOKE
    cfg.validate()
    return cfg
