"""Qwen2-0.5B [arXiv:2407.10671]: 24L d896 14H GQA(kv=2) d_ff 4864,
vocab 151936, QKV bias, tied embeddings."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    vocab_size=151936,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    pattern=(LayerSpec(kind="attn", mlp="dense"),),
    n_repeats=24,
    norm="rmsnorm",
    act="silu",
    rope="full",
    rope_theta=1e6,
    qkv_bias=True,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(vocab_size=512, d_model=64, n_heads=4, n_kv_heads=2,
                       head_dim=16, d_ff=128, n_repeats=2)
