"""ChatGLM3-6B [arXiv:2406.12793]: 28L d4096 32H GQA(kv=2) d_ff 13696,
vocab 65024, RoPE on half the channels ("2d"), QKV bias."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    vocab_size=65024,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    pattern=(LayerSpec(kind="attn", mlp="dense"),),
    n_repeats=28,
    norm="rmsnorm",
    act="silu",
    rope="half",
    qkv_bias=True,
    serve_quant_bits=4,
)

SMOKE = CONFIG.replace(vocab_size=512, d_model=96, n_heads=4, n_kv_heads=2,
                       head_dim=24, d_ff=192, n_repeats=2)
