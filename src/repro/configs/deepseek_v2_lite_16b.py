"""DeepSeek-V2-Lite (16B) [arXiv:2405.04434]: 27L d2048 16H, MLA
(kv_lora 512, nope 128 + rope 64, v 128), MoE 64 routed top-6 + 2 shared
experts (d_ff_expert 1408), first layer dense (d_ff 10944), vocab 102400.

Assignment-spec note: the pool line says "2 shared+160 routed"; 160 routed is
V2-*large*. We follow the "64e top-6" clause (matches the Lite paper).
"""
from repro.models.config import LayerSpec, MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    vocab_size=102400,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,  # first dense layer
    prefix_pattern=(LayerSpec(kind="attn", mlp="dense"),),
    pattern=(LayerSpec(kind="attn", mlp="moe"),),
    n_repeats=26,
    norm="rmsnorm",
    act="silu",
    rope="full",
    attention="mla",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_ff_expert=1408),
    serve_quant_bits=4,
    fsdp=True,  # 16B: replicated fp32 params+Adam exceed v5e HBM (see §Perf)
    moe_impl="shard_map",  # explicit all-to-all EP dispatch (§Perf: -88% coll.)
)

SMOKE = CONFIG.replace(
    vocab_size=512, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=160, n_repeats=2,
    mla=MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
                  v_head_dim=16),
    moe=MoEConfig(n_experts=8, top_k=2, n_shared=1, d_ff_expert=32))
