"""Granite-20B-Code [arXiv:2405.04324]: GPT-BigCode style — 52L d6144 48H
MQA(kv=1) d_ff 24576, vocab 49152, LayerNorm + biases, learned positions."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    vocab_size=49152,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    pattern=(LayerSpec(kind="attn", mlp="dense"),),
    n_repeats=52,
    norm="layernorm",
    act="gelu",
    rope="none",
    pos_emb="learned",
    max_position=32768,  # widened for decode_32k (native 8192)
    qkv_bias=True,
    o_bias=True,
    mlp_bias=True,
    fsdp=True,
    serve_quant_bits=4,
)

SMOKE = CONFIG.replace(vocab_size=512, d_model=96, n_heads=4, n_kv_heads=1,
                       head_dim=24, d_ff=192, n_repeats=2, max_position=512,
                       fsdp=False)
