"""Parameter partitioning: map every param leaf to logical axis names by
path, then to a PartitionSpec / NamedSharding via the rules table.

Handles stacked (scan) leaves — leading repeat dim stays unsharded — and
QuantizedTensor leaves (qw/scale inherit the weight's output-dim sharding).
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.core.quant.types import QuantizedTensor, pack_layout
from repro.distributed.sharding import (DEFAULT_RULES, TP_AXIS, _axis_size,
                                        spec_for)
from repro.models.config import ModelConfig

# (path regex, logical names per trailing dim). First match wins. Names are
# for the *unstacked* leaf; a leading scan/repeats dim is auto-padded None.
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed/w$", ("vocab", "embed")),
    (r"pos/w$", ("pos", "embed")),
    (r"lm_head/w$", ("embed_fsdp", "vocab")),
    # attention
    (r"attn/wq/w$", ("embed_fsdp", "heads_flat")),
    (r"attn/wk/w$", ("embed_fsdp", "kv_flat")),
    (r"attn/wv/w$", ("embed_fsdp", "kv_flat")),
    (r"attn/wo/w$", ("heads_flat", "embed_fsdp")),
    (r"attn/wq/b$", ("heads_flat",)),
    (r"attn/w[kv]/b$", ("kv_flat",)),
    (r"attn/wo/b$", ("embed",)),
    # MLA
    (r"attn/wdkv/w$", ("embed_fsdp", "kv_lora")),
    (r"attn/wukv/w$", ("kv_lora", "heads_flat")),
    # MoE — "expert"/"expert_ff" resolve to EP or expert-TP per config
    (r"moe/router/w$", ("embed", None)),
    (r"moe/experts/w[ig]/w$", ("expert", "embed_fsdp", "expert_ff")),
    (r"moe/experts/wo/w$", ("expert", "expert_ff", "embed_fsdp")),
    (r"(moe/shared/|)w[ig]/w$", ("embed_fsdp", "mlp")),
    (r"(moe/shared/|)wo/w$", ("mlp", "embed_fsdp")),
    (r"w[ig]/b$", ("mlp",)),
    (r"wo/b$", ("embed",)),
    # mamba2
    (r"mamba/in_proj/w$", ("embed_fsdp", "mamba_inner")),
    (r"mamba/out_proj/w$", ("mamba_inner", "embed_fsdp")),
    (r"mamba/conv_w$", ("conv", None)),
    (r"mamba/conv_b$", (None,)),
    (r"mamba/(A_log|D|dt_bias)$", (None,)),
    # norms and anything else 1-D: replicated
    (r"(scale|bias)$", (None,)),
]

# logical names used only in param specs
PARAM_RULES_EXTRA = {
    "heads_flat": "model",
    "kv_flat": "model",
    "mamba_inner": "model",
    "embed_fsdp": "data",
}


def rules_for_config(cfg: ModelConfig, mesh=None) -> dict:
    rules = dict(DEFAULT_RULES)
    rules.update(PARAM_RULES_EXTRA)
    rules["expert_ff"] = None
    if not cfg.fsdp:
        rules["embed_fsdp"] = None
    if cfg.moe is not None:
        model_size = mesh.shape.get("model", 16) if mesh is not None else 16
        # the dispatch buffer (E, C, d) has no batch dim: its capacity dim
        # MUST shard over the data axes too, or every data rank replicates
        # the full expert compute (a 16x FLOP bug found via roofline, §Perf)
        if cfg.moe.n_experts % model_size != 0:
            # expert count doesn't divide the TP axis -> tensor-parallel
            # *within* experts: shard the expert FF dim + the dispatch
            # capacity instead of the expert dim.
            # NOTE: capacity over ("data","model") removes the 16x FLOP
            # replication but XLA SPMD then all-gathers the token slots per
            # layer (+460% collective bytes, net-worse step time) — the real
            # fix is a shard_map all-to-all dispatch (future work, §Perf).
            rules["expert"] = None
            rules["expert_ff"] = "model"
            rules["capacity"] = "model"
        # (EP mode: capacity over "data" likewise trades 16x FLOP
        # replication for ~6x collective traffic under SPMD — net worse;
        # see §Perf. shard_map all-to-all dispatch is the correct fix.)
    return rules


def logical_axes_for(path: str, ndim: int) -> tuple:
    for pat, names in _PARAM_RULES:
        if re.search(pat, path):
            if len(names) == ndim:
                return names
            if len(names) == ndim - 1:        # stacked (scan) leaf
                return (None,) + names
    return (None,) * ndim                     # unknown -> replicated


def _walk(tree, prefix, fn):
    if isinstance(tree, QuantizedTensor):
        # reached via the linear's "w" key, so `prefix` already ends in /w.
        # qw (..., Kp, N) shares the weight's names; scale (..., G, N)
        # inherits the output-dim sharding always, and the K-dim sharding on
        # its group dim whenever there is more than one scale group (each
        # shard of a K-sharded grouped weight needs exactly its own groups;
        # a per-channel (1, N) scale stays whole on every K shard)
        wnames = logical_axes_for(prefix, len(tree.shape))
        pad = tree.qw.ndim - len(wnames)
        qw_names = (None,) * pad + wnames if pad >= 0 else wnames[-tree.qw.ndim:]
        gdim = tree.scale.shape[-2] if hasattr(tree.scale, "shape") else 1
        sc_names = qw_names[:-2] + ((qw_names[-2] if gdim > 1 else None),
                                    qw_names[-1])
        return QuantizedTensor(fn(prefix + "#qw", tree.qw, qw_names),
                               fn(prefix + "#scale", tree.scale, sc_names),
                               tree.bits, tree.group_size, tree.shape,
                               tree.act_bits)
    if isinstance(tree, dict):
        return {k: _walk(v, f"{prefix}/{k}" if prefix else k, fn)
                for k, v in tree.items()}
    names = logical_axes_for(prefix, getattr(tree, "ndim", 0))
    return fn(prefix, tree, names)


def param_specs(cfg: ModelConfig, params_shape) -> dict:
    """Tree of PartitionSpec matching `params_shape` (arrays or SDS)."""
    rules = rules_for_config(cfg)

    def fn(path, leaf, names):
        return spec_for(leaf.shape, names, mesh=None, rules=rules)

    # spec_for needs a mesh for divisibility checks; defer: return names
    return _walk(params_shape, "", lambda p, l, n: n)


def param_shardings(mesh, cfg: ModelConfig, params_shape) -> dict:
    rules = rules_for_config(cfg)

    def fn(path, leaf, names):
        spec = spec_for(leaf.shape, names, mesh=mesh, rules=rules)
        return NamedSharding(mesh, spec)

    return _walk(params_shape, "", fn)


def shard_struct(mesh, cfg: ModelConfig, params_shape) -> dict:
    """ShapeDtypeStructs with shardings attached (AOT lowering inputs)."""
    rules = rules_for_config(cfg)

    def fn(path, leaf, names):
        spec = spec_for(leaf.shape, names, mesh=mesh, rules=rules)
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))

    return _walk(params_shape, "", fn)


# --------------------------------------------------- tensor-parallel serving
#
# Placement contract for the continuous engine's shard_map TP (axis
# TP_AXIS = "model"; see DESIGN.md "Tensor-parallel serving"):
#
#   column-parallel (output dim on "model"):  attn wq/wk/wv (+ their biases),
#       mlp wi/wg (+ biases), mla wq/wukv
#   row-parallel (input dim on "model", psum after the matmul, bias added
#       post-psum):  attn wo, mlp wo, mla wo
#   replicated:  embed, lm_head, pos, norms, mla wdkv/kvnorm, all output
#       biases — logits are therefore identical on every shard and sampling
#       needs no vocab collective
#   paged KV pools shard along their kv-head dim (serve/kvcache.py)
#
# QuantizedTensor leaves shard qw and scale *jointly*: a K-dim (row-
# parallel) sharding is legal only when the packed rows split evenly AND
# the scale groups split with them (per-channel scales stay replicated —
# every K shard needs the whole (1, N) row). When the joint constraint
# fails the K sharding is dropped from both, never from only one.

def serve_tp_rules(cfg: ModelConfig) -> dict:
    """Logical->mesh rules for TP serving on a 1-D ("model",) mesh.

    No FSDP/data axes (a serving weight is either TP-sharded or
    replicated), embed/lm_head/pos replicated (identical logits per shard),
    and the MoE / Mamba axes neutralized — EP-sharded MoE serving and SSM
    serving TP are open items (ROADMAP)."""
    rules = dict(DEFAULT_RULES)
    rules.update(PARAM_RULES_EXTRA)
    rules["embed_fsdp"] = None
    rules["vocab"] = None
    rules["pos"] = None
    rules["expert"] = None
    rules["expert_ff"] = None
    rules["mamba_inner"] = None
    rules["ssm_heads"] = None
    return rules


def _qt_serve_spec(qt: QuantizedTensor, wnames: tuple, mesh, rules):
    """Joint (qw, scale) PartitionSpecs for one quantized leaf."""
    full = spec_for(qt.shape, wnames, mesh=mesh, rules=rules)
    k_ax, n_ax = full[-2], full[-1]
    n_groups = qt.scale.shape[-2]
    if k_ax is not None and mesh is not None:
        tp = _axis_size(mesh, k_ax)
        bpg, vpg = pack_layout(qt.bits)
        # each K shard must hold whole packed groups (bpg bytes / vpg
        # values), so shard boundaries never split a multi-byte word
        packed_ok = (qt.qw.shape[-2] % (tp * bpg) == 0
                     and qt.shape[-2] % (tp * vpg) == 0)
        groups_ok = n_groups == 1 or n_groups % tp == 0
        if not (packed_ok and groups_ok):
            k_ax = None                      # drop jointly, keep consistency
    lead = (None,) * (qt.qw.ndim - 2)
    qw_spec = PartitionSpec(*lead, k_ax, n_ax)
    sc_spec = PartitionSpec(*lead, k_ax if n_groups > 1 else None, n_ax)
    return qw_spec, sc_spec


def serve_param_shardings(mesh, cfg: ModelConfig, params,
                          specs_only: bool = False):
    """NamedSharding (or bare PartitionSpec) tree for TP serving placement.

    With `specs_only` (used for shard_map specs) `mesh` may still be given
    so divisibility checks run against the real axis size; a None mesh
    resolves names optimistically (spec_for keeps every named axis)."""
    rules = serve_tp_rules(cfg)

    def wrap(spec):
        if specs_only or mesh is None:
            return spec
        return NamedSharding(mesh, spec)

    def walk(tree, prefix):
        if isinstance(tree, QuantizedTensor):
            wnames = logical_axes_for(prefix, len(tree.shape))
            qw_spec, sc_spec = _qt_serve_spec(tree, wnames, mesh, rules)
            return QuantizedTensor(wrap(qw_spec), wrap(sc_spec), tree.bits,
                                   tree.group_size, tree.shape, tree.act_bits)
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}/{k}" if prefix else k)
                    for k, v in tree.items()}
        names = logical_axes_for(prefix, getattr(tree, "ndim", 0))
        return wrap(spec_for(tree.shape, names, mesh=mesh, rules=rules))

    return walk(params, "")


def tp_local_cfg(cfg: ModelConfig) -> ModelConfig:
    """Per-shard view of a TP-serving config (passed to the model code
    inside the engine's shard_map): head counts divided by tp with head_dim
    pinned first, so `cfg.hd` does not silently change when it was derived
    from d_model / n_heads. `tp` stays > 1 — that is how row-parallel
    linears know to psum over TP_AXIS."""
    if cfg.tp <= 1:
        return cfg
    assert cfg.n_heads % cfg.tp == 0, (cfg.n_heads, cfg.tp)
    return cfg.replace(head_dim=cfg.hd,
                       n_heads=cfg.n_heads // cfg.tp,
                       n_kv_heads=max(1, cfg.n_kv_heads // cfg.tp))


def serve_tp_widths(cfg: ModelConfig) -> list[int]:
    """Legal TP widths for a config: GQA head-group alignment — every shard
    must hold whole kv heads with all their grouped query heads — plus an
    evenly split MLP hidden dim. (MLA has per-token latent KV, so only the
    query/output heads constrain it.)"""
    def ok(tp):
        if cfg.n_heads % tp or cfg.d_ff % tp:
            return False
        if cfg.attention != "mla" and cfg.n_kv_heads % tp:
            return False
        return True

    return [tp for tp in range(1, cfg.n_heads + 1) if ok(tp)]


def moe_ep_dispatch_pspecs(daxes: tuple):
    """shard_map specs for the expert-parallel MoE dispatch
    (models/moe_shardmap.py): tokens batch-sharded over the data axes and
    replicated over "model" (each model rank slices its 1/M of the local
    tokens inside the body), router replicated, expert weight stacks
    sharded over "model" on the expert dim, output token-major like the
    input with a replicated aux scalar."""
    tok = PartitionSpec(daxes or None, None, None)
    expert = PartitionSpec("model", None, None)
    in_specs = (tok, PartitionSpec(None, None), expert, expert, expert)
    out_specs = (tok, PartitionSpec())
    return in_specs, out_specs


def paged_pool_pspecs(cache, mesh, axis: str = TP_AXIS):
    """PartitionSpec tree sharding every paged KV pool along its kv-head dim.

    The placement contract for tensor-parallel serving: value pools
    ``(..., P, page, KVH, hd)`` shard KVH over `axis` (dim ndim-2), scale
    pools ``(..., P, page, KVH)`` likewise (dim ndim-1); the page axes are
    NEVER sharded — every shard holds its head slice of *every* page, so
    block tables, fill counts, and the scheduler's page budget are
    shard-invariant. Pools whose head dim the axis cannot divide (the MLA
    latent pool has KVH == 1 — per-token latent, no head dim to split)
    come out replicated, as does every non-pool leaf (Mamba state is not
    paged and TP serving gates SSM archs off upstream).

    Lives here (not serve/kvcache.py) because this file is the single
    source of placement truth — repro-lint RL007 rejects PartitionSpec
    literals everywhere else; the pool *layout* rule it consults
    (POOL_KEYS / pool_head_dim) stays with the pools.
    """
    from repro.serve.kvcache import POOL_KEYS, pool_head_dim

    size = mesh.shape[axis]

    def leaf_spec(key, leaf):
        nd = getattr(leaf, "ndim", 0)
        if key not in POOL_KEYS:
            return PartitionSpec()
        hdim = pool_head_dim(key, nd)
        if leaf.shape[hdim] % size:
            return PartitionSpec()
        return PartitionSpec(*(axis if d == hdim else None
                               for d in range(nd)))

    def walk(tree, key=None):
        if isinstance(tree, dict):
            return {k: walk(v, k) for k, v in tree.items()}
        return leaf_spec(key, tree)

    return walk(cache)


def batch_shardings(mesh, tree, names_map: dict) -> dict:
    """Shardings for input batches: names_map maps leaf key -> logical names."""
    out = {}
    for k, v in tree.items():
        names = names_map.get(k, ("batch",) + (None,) * (v.ndim - 1))
        out[k] = jax.ShapeDtypeStruct(
            v.shape, v.dtype,
            sharding=NamedSharding(mesh, spec_for(v.shape, names, mesh=mesh)))
    return out
