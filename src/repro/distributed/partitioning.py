"""Parameter partitioning: map every param leaf to logical axis names by
path, then to a PartitionSpec / NamedSharding via the rules table.

Handles stacked (scan) leaves — leading repeat dim stays unsharded — and
QuantizedTensor leaves (qw/scale inherit the weight's output-dim sharding).
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.core.quant.types import QuantizedTensor
from repro.distributed.sharding import DEFAULT_RULES, spec_for
from repro.models.config import ModelConfig

# (path regex, logical names per trailing dim). First match wins. Names are
# for the *unstacked* leaf; a leading scan/repeats dim is auto-padded None.
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed/w$", ("vocab", "embed")),
    (r"pos/w$", ("pos", "embed")),
    (r"lm_head/w$", ("embed_fsdp", "vocab")),
    # attention
    (r"attn/wq/w$", ("embed_fsdp", "heads_flat")),
    (r"attn/wk/w$", ("embed_fsdp", "kv_flat")),
    (r"attn/wv/w$", ("embed_fsdp", "kv_flat")),
    (r"attn/wo/w$", ("heads_flat", "embed_fsdp")),
    (r"attn/wq/b$", ("heads_flat",)),
    (r"attn/w[kv]/b$", ("kv_flat",)),
    (r"attn/wo/b$", ("embed",)),
    # MLA
    (r"attn/wdkv/w$", ("embed_fsdp", "kv_lora")),
    (r"attn/wukv/w$", ("kv_lora", "heads_flat")),
    # MoE — "expert"/"expert_ff" resolve to EP or expert-TP per config
    (r"moe/router/w$", ("embed", None)),
    (r"moe/experts/w[ig]/w$", ("expert", "embed_fsdp", "expert_ff")),
    (r"moe/experts/wo/w$", ("expert", "expert_ff", "embed_fsdp")),
    (r"(moe/shared/|)w[ig]/w$", ("embed_fsdp", "mlp")),
    (r"(moe/shared/|)wo/w$", ("mlp", "embed_fsdp")),
    (r"w[ig]/b$", ("mlp",)),
    (r"wo/b$", ("embed",)),
    # mamba2
    (r"mamba/in_proj/w$", ("embed_fsdp", "mamba_inner")),
    (r"mamba/out_proj/w$", ("mamba_inner", "embed_fsdp")),
    (r"mamba/conv_w$", ("conv", None)),
    (r"mamba/conv_b$", (None,)),
    (r"mamba/(A_log|D|dt_bias)$", (None,)),
    # norms and anything else 1-D: replicated
    (r"(scale|bias)$", (None,)),
]

# logical names used only in param specs
PARAM_RULES_EXTRA = {
    "heads_flat": "model",
    "kv_flat": "model",
    "mamba_inner": "model",
    "embed_fsdp": "data",
}


def rules_for_config(cfg: ModelConfig, mesh=None) -> dict:
    rules = dict(DEFAULT_RULES)
    rules.update(PARAM_RULES_EXTRA)
    rules["expert_ff"] = None
    if not cfg.fsdp:
        rules["embed_fsdp"] = None
    if cfg.moe is not None:
        model_size = mesh.shape.get("model", 16) if mesh is not None else 16
        # the dispatch buffer (E, C, d) has no batch dim: its capacity dim
        # MUST shard over the data axes too, or every data rank replicates
        # the full expert compute (a 16x FLOP bug found via roofline, §Perf)
        if cfg.moe.n_experts % model_size != 0:
            # expert count doesn't divide the TP axis -> tensor-parallel
            # *within* experts: shard the expert FF dim + the dispatch
            # capacity instead of the expert dim.
            # NOTE: capacity over ("data","model") removes the 16x FLOP
            # replication but XLA SPMD then all-gathers the token slots per
            # layer (+460% collective bytes, net-worse step time) — the real
            # fix is a shard_map all-to-all dispatch (future work, §Perf).
            rules["expert"] = None
            rules["expert_ff"] = "model"
            rules["capacity"] = "model"
        # (EP mode: capacity over "data" likewise trades 16x FLOP
        # replication for ~6x collective traffic under SPMD — net worse;
        # see §Perf. shard_map all-to-all dispatch is the correct fix.)
    return rules


def logical_axes_for(path: str, ndim: int) -> tuple:
    for pat, names in _PARAM_RULES:
        if re.search(pat, path):
            if len(names) == ndim:
                return names
            if len(names) == ndim - 1:        # stacked (scan) leaf
                return (None,) + names
    return (None,) * ndim                     # unknown -> replicated


def _walk(tree, prefix, fn):
    if isinstance(tree, QuantizedTensor):
        # reached via the linear's "w" key, so `prefix` already ends in /w.
        # qw (..., Kp, N) shares the weight's names; scale (..., G, N) keeps
        # only the output-dim sharding
        wnames = logical_axes_for(prefix, len(tree.shape))
        pad = tree.qw.ndim - len(wnames)
        qw_names = (None,) * pad + wnames if pad >= 0 else wnames[-tree.qw.ndim:]
        sc_names = qw_names[:-2] + (None, qw_names[-1])
        return QuantizedTensor(fn(prefix + "#qw", tree.qw, qw_names),
                               fn(prefix + "#scale", tree.scale, sc_names),
                               tree.bits, tree.group_size, tree.shape,
                               tree.act_bits)
    if isinstance(tree, dict):
        return {k: _walk(v, f"{prefix}/{k}" if prefix else k, fn)
                for k, v in tree.items()}
    names = logical_axes_for(prefix, getattr(tree, "ndim", 0))
    return fn(prefix, tree, names)


def param_specs(cfg: ModelConfig, params_shape) -> dict:
    """Tree of PartitionSpec matching `params_shape` (arrays or SDS)."""
    rules = rules_for_config(cfg)

    def fn(path, leaf, names):
        return spec_for(leaf.shape, names, mesh=None, rules=rules)

    # spec_for needs a mesh for divisibility checks; defer: return names
    return _walk(params_shape, "", lambda p, l, n: n)


def param_shardings(mesh, cfg: ModelConfig, params_shape) -> dict:
    rules = rules_for_config(cfg)

    def fn(path, leaf, names):
        spec = spec_for(leaf.shape, names, mesh=mesh, rules=rules)
        return NamedSharding(mesh, spec)

    return _walk(params_shape, "", fn)


def shard_struct(mesh, cfg: ModelConfig, params_shape) -> dict:
    """ShapeDtypeStructs with shardings attached (AOT lowering inputs)."""
    rules = rules_for_config(cfg)

    def fn(path, leaf, names):
        spec = spec_for(leaf.shape, names, mesh=mesh, rules=rules)
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))

    return _walk(params_shape, "", fn)


def batch_shardings(mesh, tree, names_map: dict) -> dict:
    """Shardings for input batches: names_map maps leaf key -> logical names."""
    out = {}
    for k, v in tree.items():
        names = names_map.get(k, ("batch",) + (None,) * (v.ndim - 1))
        out[k] = jax.ShapeDtypeStruct(
            v.shape, v.dtype,
            sharding=NamedSharding(mesh, spec_for(v.shape, names, mesh=mesh)))
    return out
