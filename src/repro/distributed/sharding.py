"""Logical-axis sharding: MaxText-style rules mapping logical names to mesh axes.

Models annotate activations/params with *logical* axis names ("batch",
"mlp", "vocab", ...). A rules table maps those to physical mesh axes. When no
mesh context is active (unit tests on 1 CPU device) every annotation is a
no-op, so the same model code runs everywhere.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

Axes = Union[None, str, tuple[str, ...]]

# Mesh axis carrying tensor parallelism for serving: heads / kv-heads / mlp
# hidden / paged KV pools shard over it, row-parallel linears psum over it
# (inside the engine's shard_map; see distributed/partitioning.py
# `serve_param_shardings` for the full placement contract).
TP_AXIS = "model"

# Default logical->physical rules for the (pod, data, model) production mesh.
DEFAULT_RULES: dict[str, Axes] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "embed_fsdp": ("pod", "data"),   # param embed dim when FSDP is on
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "qk_dim": None,
    "mlp": "model",
    "expert": "model",
    "capacity": None,
    "kv_seq": None,
    "kv_lora": None,
    "conv": None,
    "ssm_heads": "model",
    "ssm_state": None,
    "norm": None,
    "pos": None,
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: dict[str, Axes] = dict(DEFAULT_RULES)


_CTX = _Ctx()


@contextlib.contextmanager
def sharding_ctx(mesh: Optional[Mesh], rules: Optional[dict[str, Axes]] = None):
    """Activate a mesh + logical rules for model annotations."""
    old = (_CTX.mesh, _CTX.rules)
    _CTX.mesh = mesh
    _CTX.rules = {**DEFAULT_RULES, **(rules or {})}
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = old


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def _axis_size(mesh: Mesh, axes: Axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def spec_for(shape: Sequence[int], names: Sequence[Optional[str]],
             mesh: Optional[Mesh] = None,
             rules: Optional[dict[str, Axes]] = None) -> PartitionSpec:
    """Resolve logical names to a PartitionSpec, dropping non-divisible axes."""
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules
    out = []
    used: set = set()
    for dim, name in zip(shape, names):
        axes = rules.get(name) if name else None
        if axes is not None and mesh is not None:
            # drop axes the mesh doesn't have (e.g. "pod" on single-pod)
            flat = (axes,) if isinstance(axes, str) else tuple(axes)
            flat = tuple(a for a in flat if a in mesh.shape)
            axes = None if not flat else (flat[0] if len(flat) == 1 else flat)
        if axes is not None and mesh is not None:
            if dim % _axis_size(mesh, axes) != 0:
                axes = None  # not divisible -> leave unsharded
        if axes is not None:
            flat = (axes,) if isinstance(axes, str) else tuple(axes)
            if any(a in used for a in flat):
                axes = None  # a mesh axis may appear once per spec
            else:
                used.update(flat)
        out.append(axes)
    return PartitionSpec(*out)


def lc(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Logical sharding constraint. No-op outside a sharding_ctx."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    if len(names) != x.ndim:
        raise ValueError(f"lc: {len(names)} names for rank-{x.ndim} array")
    spec = spec_for(x.shape, names, mesh, _CTX.rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(shape: Sequence[int], names: Sequence[Optional[str]],
                   mesh: Optional[Mesh] = None,
                   rules: Optional[dict[str, Axes]] = None) -> NamedSharding:
    mesh = mesh or _CTX.mesh
    assert mesh is not None, "named_sharding requires a mesh"
    return NamedSharding(mesh, spec_for(shape, names, mesh, rules))
