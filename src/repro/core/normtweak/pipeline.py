"""Norm-Tweaking PTQ pipeline — the paper's Algorithm 1.

Layer-by-layer over the model:
  1. the quantized stream qX feeds every layer (line 4-7);
  2. the float output fOut_l is computed from qX with float weights (line 8);
  3. the layer's linears are quantized (GPTQ/RTN/SmoothQuant, line 9);
  4. Adam updates ONLY the norm parameters against the channel-wise
     distribution loss for `iters` passes (lines 11-15), with the
     depth-increasing LR of Eq. 3 — the whole inner loop runs as one
     jitted `lax.scan` over sample-batch chunks with donated norm/opt
     buffers (`_tweak_scan`; per-chunk `_tweak_step` only for ragged
     calibration sets);
  5. qX advances through the final quantized layer.

Works for every zoo architecture: the block walker treats MLA latent norms,
Mamba gated norms and MoE layers uniformly. Set `tweak=False` to get the
plain quantizer baseline (GPTQ/RTN/SmoothQuant without the paper's plugin).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.normtweak.losses import LOSSES
from repro.core.normtweak.schedule import layer_lr
from repro.core.quant.blockquant import quantize_block
from repro.models.blocks import apply_block
from repro.models.config import ModelConfig
from repro.models.norms import is_norm_path
from repro.models.transformer import (block_spec, get_block, num_blocks,
                                      _embed)
from repro.optim.adam import adam_init, adam_update
from repro.utils.tree import tree_merge, tree_partition, tree_stack


@dataclasses.dataclass(frozen=True)
class NTConfig:
    method: str = "gptq"          # gptq | rtn | smoothquant
    bits: int = 4
    group_size: int = -1          # -1 = per-channel; 64 for W2 (paper)
    act_bits: int = 0             # 8 for SmoothQuant W4A8
    tweak: bool = True            # False => plain PTQ baseline
    iters: int = 1                # passes over the calibration set (Table 6)
    lr0: float = 1e-5
    lr_scale: float = 10.0        # Eq. 3 depth scaling
    loss: str = "dist"            # dist | mse | kl (Table 9)
    target: str = "fstream"       # fstream: fOut_l from the float model's own
                                  # activations (Fig. 1's objective); qstream:
                                  # float layer applied to the quantized
                                  # stream (a literal Algorithm-1 line-8 read)
    sample_batch: int = 8         # calibration samples per tweak step
    damp: float = 0.01
    actorder: bool = False
    alpha: float = 0.5            # SmoothQuant migration strength


def _tweak_update(cfg, spec, loss_name, norms, rest, opt_state, x, fout,
                  positions, lr):
    """One Adam step on the norm params for one sample-batch chunk."""
    loss_fn_ = LOSSES[loss_name]

    def loss_of(nrm):
        bp = tree_merge(nrm, rest)
        qout, _, _ = apply_block(cfg, spec, bp, x, positions=positions,
                                 mode="train")
        return loss_fn_(fout, qout)

    loss, grads = jax.value_and_grad(loss_of)(norms)
    new_norms, new_state = adam_update(grads, opt_state, norms, lr=lr)
    return new_norms, new_state, loss


@functools.partial(jax.jit, static_argnames=("cfg", "spec", "loss_name"))
def _tweak_step(cfg, spec, loss_name, norms, rest, opt_state, x, fout,
                positions, lr):
    """Per-chunk dispatch — kept for ragged calibration sets (n % sb != 0)
    and as the oracle the fused scan is asserted identical against."""
    return _tweak_update(cfg, spec, loss_name, norms, rest, opt_state, x,
                         fout, positions, lr)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "spec", "loss_name", "iters"),
                   donate_argnames=("norms", "opt_state"))
def _tweak_scan(cfg, spec, loss_name, norms, rest, opt_state, xs, fouts,
                pos_chunks, lr, *, iters: int):
    """The whole inner calibration loop (lines 11-15 of Algorithm 1) as ONE
    jitted lax.scan over sample-batch chunks x iters, with the norm/opt
    buffers donated — one dispatch per layer instead of iters * n_chunks,
    and no per-chunk host round-trips. Chunk math is identical to
    _tweak_step (same chunk order, same update), so final norms match the
    per-chunk loop bit-for-bit.

    xs / fouts: (C, sb, S, d); pos_chunks: (C, sb); returns the last
    chunk's loss like the loop did."""
    n_chunks = xs.shape[0]

    def body(carry, ci):
        norms, opt_state = carry
        new_norms, new_state, loss = _tweak_update(
            cfg, spec, loss_name, norms, rest, opt_state,
            xs[ci], fouts[ci], pos_chunks[ci], lr)
        return (new_norms, new_state), loss

    (norms, opt_state), losses = jax.lax.scan(
        body, (norms, opt_state),
        jnp.tile(jnp.arange(n_chunks, dtype=jnp.int32), iters))
    return norms, opt_state, losses[-1]


@functools.partial(jax.jit, static_argnames=("cfg", "spec"))
def _block_forward(cfg, spec, bp, x, positions):
    y, _, _ = apply_block(cfg, spec, bp, x, positions=positions, mode="train")
    return y


def tweak_layers(cfg: ModelConfig, specs, blocks: list[dict], x0: jax.Array,
                 nt: NTConfig, *, enc_out: Optional[jax.Array] = None,
                 layer_offset: int = 0, total_layers: Optional[int] = None,
                 log: Optional[Callable[[str], None]] = None):
    """Core loop over an ordered list of blocks. Returns (qblocks, qX, stats).

    x0: (n_samples, seq, d) activations entering the first block.
    """
    total_layers = total_layers or len(specs)
    n, s, _ = x0.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (n, s))
    qx = x0
    fx = x0  # float stream (used when nt.target == "fstream")
    qblocks = []
    stats = {"layer_loss": [], "layer_lr": []}

    def block_apply_full(spec, bp, x, taps=None):
        # full-batch apply (calibration sets are small; real deployments
        # stream sample_batch chunks — handled by the tweak loop below)
        y, _, _ = apply_block(cfg, spec, bp, x, positions=positions,
                              mode="train", enc_out=enc_out, taps=taps)
        return y

    for li, (spec, bp) in enumerate(zip(specs, blocks)):
        gi = layer_offset + li
        if nt.target == "fstream":
            fx = block_apply_full(spec, bp, fx)                  # float stream
            fout = fx
        else:
            fout = block_apply_full(spec, bp, qx)                # line 8
        taps: dict = {}
        block_apply_full(spec, bp, qx, taps=taps)                # capture X
        qbp = quantize_block(bp, taps, method=nt.method, bits=nt.bits,
                             group_size=nt.group_size, act_bits=nt.act_bits,
                             alpha=nt.alpha, damp=nt.damp,
                             actorder=nt.actorder)               # line 9-10

        if nt.tweak:
            norms, rest = tree_partition(qbp, is_norm_path)
            opt_state = adam_init(norms)
            lr = layer_lr(nt.lr0, nt.lr_scale, gi, total_layers)  # Eq. 3
            sb = max(1, min(nt.sample_batch, n))
            last_loss = jnp.zeros(())
            if nt.iters > 0 and n % sb == 0:
                # fused path: the whole iters x chunks loop is one jitted
                # scan with donated norm/opt buffers (see _tweak_scan)
                chunk = lambda a: a.reshape((n // sb, sb) + a.shape[1:])
                norms, opt_state, last_loss = _tweak_scan(
                    cfg, spec, nt.loss, norms, rest, opt_state,
                    chunk(qx), chunk(fout), chunk(positions), lr,
                    iters=nt.iters)
            else:
                # ragged tail (n % sb != 0) or iters=0 (a zero-length scan
                # cannot yield losses[-1]): keep the per-chunk dispatch
                for _ in range(nt.iters):                        # line 11
                    for s0 in range(0, n, sb):
                        norms, opt_state, last_loss = _tweak_step(
                            cfg, spec, nt.loss, norms, rest, opt_state,
                            qx[s0:s0 + sb], fout[s0:s0 + sb],
                            positions[s0:s0 + sb], lr)
            qbp = tree_merge(norms, rest)
            stats["layer_loss"].append(float(last_loss))
            stats["layer_lr"].append(lr)
        qblocks.append(qbp)
        qx = block_apply_full(spec, qbp, qx)                     # line 6
        if log:
            log(f"layer {gi + 1}/{total_layers} done "
                f"({'tweaked' if nt.tweak else 'quantized'})")
    return qblocks, qx, stats


def _restack(cfg: ModelConfig, params: dict, qblocks: list[dict]) -> dict:
    out = dict(params)
    np_ = len(cfg.prefix_pattern)
    if np_:
        out["prefix"] = {str(i): qblocks[i] for i in range(np_)}
    stack = {}
    pl = len(cfg.pattern)
    for j in range(pl):
        reps = [qblocks[np_ + r * pl + j] for r in range(cfg.n_repeats)]
        stack[f"p{j}"] = tree_stack(reps)
    out["stack"] = stack
    return out


def norm_tweak_ptq(cfg: ModelConfig, params: dict, calib_tokens: jax.Array,
                   nt: NTConfig,
                   ext_embeds: Optional[jax.Array] = None,
                   log: Optional[Callable[[str], None]] = None):
    """Quantize a decoder-only LM with Norm-Tweaking. Returns (qparams, stats).

    calib_tokens: (n_samples, token_length) — the paper uses 128×2048
    self-generated samples (see core/calibration).
    """
    n, s = calib_tokens.shape
    s_total = s + (ext_embeds.shape[1] if ext_embeds is not None else 0)
    positions = jnp.broadcast_to(
        jnp.arange(s_total, dtype=jnp.int32)[None], (n, s_total))
    x0 = _embed(cfg, params, calib_tokens, ext_embeds, positions)

    specs = [block_spec(cfg, i) for i in range(num_blocks(cfg))]
    blocks = [get_block(cfg, params, i) for i in range(num_blocks(cfg))]
    qblocks, _, stats = tweak_layers(cfg, specs, blocks, x0, nt, log=log)
    return _restack(cfg, params, qblocks), stats


def norm_tweak_ptq_encdec(cfg: ModelConfig, params: dict,
                          calib_frames: jax.Array, calib_tokens: jax.Array,
                          nt: NTConfig,
                          log: Optional[Callable[[str], None]] = None):
    """Whisper path: tweak encoder layers on the frame stream, then decoder
    layers on the token stream conditioned on the *quantized* encoder output."""
    from repro.models.encdec import enc_config, dec_config
    from repro.models.norms import apply_norm
    from repro.models.rope import sinusoidal_positions

    ecfg, dcfg = enc_config(cfg), dec_config(cfg)
    n, se, d = calib_frames.shape
    x0 = calib_frames.astype(ecfg.adtype) + \
        sinusoidal_positions(se, d, ecfg.adtype)[None]

    enc_specs = [block_spec(ecfg, i) for i in range(num_blocks(ecfg))]
    enc_blocks = [get_block(ecfg, params["enc"], i)
                  for i in range(num_blocks(ecfg))]
    total = len(enc_specs) + num_blocks(dcfg)
    q_enc_blocks, q_enc_out, st1 = tweak_layers(
        ecfg, enc_specs, enc_blocks, x0, nt, total_layers=total, log=log)
    q_enc_out = apply_norm(ecfg, params["enc"]["final_norm"], q_enc_out)

    nd, sd = calib_tokens.shape
    positions = jnp.broadcast_to(jnp.arange(sd, dtype=jnp.int32)[None],
                                 (nd, sd))
    xd0 = _embed(dcfg, params["dec"], calib_tokens, None, positions)
    dec_specs = [block_spec(dcfg, i) for i in range(num_blocks(dcfg))]
    dec_blocks = [get_block(dcfg, params["dec"], i)
                  for i in range(num_blocks(dcfg))]
    q_dec_blocks, _, st2 = tweak_layers(
        dcfg, dec_specs, dec_blocks, xd0, nt, enc_out=q_enc_out,
        layer_offset=len(enc_specs), total_layers=total, log=log)

    qparams = dict(params)
    qparams["enc"] = _restack(ecfg, params["enc"], q_enc_blocks)
    qparams["dec"] = _restack(dcfg, params["dec"], q_dec_blocks)
    stats = {"layer_loss": st1["layer_loss"] + st2["layer_loss"],
             "layer_lr": st1["layer_lr"] + st2["layer_lr"]}
    return qparams, stats
