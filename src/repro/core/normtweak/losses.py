"""Distribution losses for norm tweaking (paper Eq. 2 + Table 9 ablations).

L_dist: per-channel |Δmean| + |Δvar| averaged over channels — the paper's
relaxed alignment (channel structure preserved, no point-wise overfit).
L_mse and L_kl are the Table 9 baselines.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def channel_stats(x: jax.Array):
    """x: (..., C) -> (mean (C,), var (C,)) over all token dims, in f32."""
    xf = x.astype(jnp.float32).reshape(-1, x.shape[-1])
    mu = jnp.mean(xf, axis=0)
    var = jnp.var(xf, axis=0)
    return mu, var


def l_dist(f: jax.Array, q: jax.Array) -> jax.Array:
    """Channel-wise distribution loss (Eq. 2)."""
    mu_f, var_f = channel_stats(f)
    mu_q, var_q = channel_stats(q)
    return jnp.mean(jnp.abs(mu_f - mu_q) + jnp.abs(var_f - var_q))


def l_mse(f: jax.Array, q: jax.Array) -> jax.Array:
    return jnp.mean(jnp.square(f.astype(jnp.float32) - q.astype(jnp.float32)))


def l_kl(f: jax.Array, q: jax.Array) -> jax.Array:
    """Per-channel Gaussian KL(f || q) from matched moments."""
    mu_f, var_f = channel_stats(f)
    mu_q, var_q = channel_stats(q)
    var_f = jnp.maximum(var_f, 1e-8)
    var_q = jnp.maximum(var_q, 1e-8)
    kl = 0.5 * (jnp.log(var_q / var_f) +
                (var_f + jnp.square(mu_f - mu_q)) / var_q - 1.0)
    return jnp.mean(kl)


LOSSES = {"dist": l_dist, "mse": l_mse, "kl": l_kl}


def activation_divergence(f: jax.Array, q: jax.Array) -> jax.Array:
    """Figure-1 metric: mean absolute per-channel mean difference Δ_u."""
    mu_f, _ = channel_stats(f)
    mu_q, _ = channel_stats(q)
    return jnp.mean(jnp.abs(mu_f - mu_q))
