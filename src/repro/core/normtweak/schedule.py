"""Layer-level learning-rate scheduler (paper Eq. 3): deeper layers get a
larger LR because quantization error accumulates with depth."""
from __future__ import annotations


def layer_lr(lr0: float, scale: float, layer_idx: int, n_layers: int) -> float:
    return lr0 * (1.0 + scale * (layer_idx / max(n_layers, 1)))
