"""GPTQ (Frantar et al. 2022) in JAX: OBS-based row-serial weight
reconstruction with Hessian error compensation.

Layout: w (K, N) with out = x @ w — we quantize along K (the paper's
"columns" of the (N, K) torch layout). H = 2 Σ x xᵀ over calibration tokens.
The update loop is a `lax.fori_loop` over rows: compact HLO at any K, same
FLOP count as the blocked GPU formulation (blocking there is a locality
optimization, irrelevant under XLA fusion).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quant.types import (QuantizedTensor, compute_scales, pack,
                                    qmax_for_bits)


def hessian_from_inputs(x: jax.Array) -> jax.Array:
    """x: (..., T, K) calibration inputs for one linear -> H (K, K)."""
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    return 2.0 * x2.T @ x2


def _upper_cholesky(a: jax.Array) -> jax.Array:
    """U upper-triangular with a = Uᵀ U:  a = L Lᵀ  =>  U = Lᵀ."""
    return jnp.linalg.cholesky(a).T


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "actorder"))
def gptq_quantize_array(w: jax.Array, h: jax.Array, *, bits: int,
                        group_size: int = -1, damp: float = 0.01,
                        actorder: bool = False):
    """Returns (q int32 (K,N) on the symmetric grid, scale (G,N), err)."""
    k, n = w.shape
    wf = w.astype(jnp.float32)
    hf = h.astype(jnp.float32)

    # dead inputs: H diagonal zero -> pin to identity, zero those weight rows
    diag = jnp.diag(hf)
    dead = diag <= 0.0
    hf = hf + jnp.diag(jnp.where(dead, 1.0, 0.0))
    wf = jnp.where(dead[:, None], 0.0, wf)

    # static group scales from the original weights
    scale = compute_scales(wf, bits, group_size)                  # (G, N)
    g = scale.shape[0]
    rows_per_g = k // g
    row_scale = jnp.repeat(scale, rows_per_g, axis=0)             # (K, N)

    perm = jnp.argsort(-jnp.diag(hf)) if actorder else jnp.arange(k)
    inv_perm = jnp.argsort(perm)
    wf = wf[perm]
    row_scale_p = row_scale[perm]
    hf = hf[perm][:, perm]

    mean_diag = jnp.mean(jnp.diag(hf))
    hf = hf + damp * mean_diag * jnp.eye(k)

    hinv = jnp.linalg.inv(hf)
    u = _upper_cholesky(hinv)                                     # (K, K)

    qmax = qmax_for_bits(bits)
    rows = jnp.arange(k)

    def body(i, carry):
        wbuf, qbuf = carry
        wrow = jax.lax.dynamic_index_in_dim(wbuf, i, 0, keepdims=False)
        srow = jax.lax.dynamic_index_in_dim(row_scale_p, i, 0, keepdims=False)
        urow = jax.lax.dynamic_index_in_dim(u, i, 0, keepdims=False)   # (K,)
        d = jax.lax.dynamic_index_in_dim(urow, i, 0, keepdims=False)
        q = jnp.clip(jnp.round(wrow / srow), -qmax, qmax)
        err = (wrow - q * srow) / d
        mask = (rows > i).astype(jnp.float32)
        wbuf = wbuf - (urow * mask)[:, None] * err[None, :]
        qbuf = jax.lax.dynamic_update_index_in_dim(qbuf, q.astype(jnp.int32),
                                                   i, 0)
        return wbuf, qbuf

    _, qbuf = jax.lax.fori_loop(0, k, body, (wf, jnp.zeros((k, n), jnp.int32)))
    qbuf = qbuf[inv_perm]

    deq = qbuf.astype(jnp.float32) * row_scale
    err = jnp.mean((deq - jnp.where(dead[:, None], 0.0, w.astype(jnp.float32))) ** 2)
    return qbuf, scale, err


def gptq_quantize(w: jax.Array, h: jax.Array, *, bits: int,
                  group_size: int = -1, damp: float = 0.01,
                  actorder: bool = False, act_bits: int = 0):
    """GPTQ for a (K, N) linear or stacked (E, K, N) experts.

    `h`: (K, K) or (E, K, K). Returns (QuantizedTensor, mse_err).
    """
    if w.ndim == 3:
        fn = jax.vmap(lambda wi, hi: gptq_quantize_array(
            wi, hi, bits=bits, group_size=group_size, damp=damp,
            actorder=actorder))
        q, scale, err = fn(w, h)
        qw = jax.vmap(lambda qi: pack(qi, bits))(q)
        return QuantizedTensor(qw, scale, bits, group_size, tuple(w.shape),
                               act_bits), jnp.mean(err)
    q, scale, err = gptq_quantize_array(w, h, bits=bits, group_size=group_size,
                                        damp=damp, actorder=actorder)
    return QuantizedTensor(pack(q, bits), scale, bits, group_size,
                           tuple(w.shape), act_bits), err
