"""Round-to-nearest baseline quantizer (Table 4 of the paper)."""
from __future__ import annotations

import jax

from repro.core.quant.types import (QuantizedTensor, quantize,
                                    quantize_stacked)


def rtn_quantize(w: jax.Array, *, bits: int, group_size: int = -1,
                 act_bits: int = 0) -> QuantizedTensor:
    """RTN for (K, N) or stacked (E, K, N) weights."""
    if w.ndim == 3:
        qt = quantize_stacked(w, bits, group_size)
    else:
        qt = quantize(w, bits, group_size)
    if act_bits:
        qt = QuantizedTensor(qt.qw, qt.scale, qt.bits, qt.group_size,
                             qt.shape, act_bits)
    return qt
