"""Quantized tensor representation + symmetric per-channel / group quantizers.

Layout convention: linear weights are (K, N) = (d_in, d_out); `out = x @ w`.
Quantization grid is *symmetric* (FasterTransformer-compatible, as in the
paper): q in [-qmax, qmax], qmax = 2^(bits-1) - 1, value = q * scale.
Scales are per output-channel and per input-group: scale[g, n] applies to
rows k in [g*group_size, (g+1)*group_size).

Packing: values are stored offset-binary (u = q + qmax, fits in `bits` bits)
and packed along K into uint8. The layout is grouped: `pack_layout(bits)`
gives (bytes_per_group, values_per_group) — 2-bit packs 4 values/byte,
4-bit 2 values/byte, 8-bit is pass-through, and 3-bit packs 8 values into a
24-bit little-endian word stored as 3 consecutive bytes (0.375 B/value, so
W3 rides the same sub-byte bandwidth budget as W2/W4 instead of the old
byte-per-value layout). Packing along K keeps unpacking lane-local on TPU
(see kernels/dequant_matmul).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def qmax_for_bits(bits: int) -> int:
    return 2 ** (bits - 1) - 1


def pack_layout(bits: int) -> tuple[int, int]:
    """(bytes_per_group, values_per_group) of the K-packed byte layout.

    A packed group is the smallest run of K rows that maps to a whole number
    of bytes: bits*values_per_group == 8*bytes_per_group. For byte-aligned
    widths (2/4/8) a group is one byte; 3-bit needs a 3-byte / 8-value group
    (a 24-bit word)."""
    return {2: (1, 4), 3: (3, 8), 4: (1, 2), 8: (1, 1)}[bits]


def packed_rows(k: int, bits: int) -> int:
    """Rows of the uint8 qw array holding k packed values."""
    bpg, vpg = pack_layout(bits)
    return -(-k // vpg) * bpg


def unpacked_rows(pk: int, bits: int) -> int:
    """Values held by pk packed uint8 rows (inverse of `packed_rows`,
    up to end-of-K padding)."""
    bpg, vpg = pack_layout(bits)
    assert pk % bpg == 0, f"packed rows {pk} not a multiple of {bpg}"
    return (pk // bpg) * vpg


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """Packed low-bit weight. Drop-in leaf for a linear's `w`."""

    qw: Any        # uint8 (K_packed, N); experts: (E, K_packed, N)
    scale: Any     # (n_groups, N) float; experts: (E, n_groups, N)
    bits: int      # static
    group_size: int  # static; -1 means one group over all of K
    shape: tuple   # static original (K, N) or (E, K, N)
    act_bits: int = 0  # static; 8 => true per-token int8 A8 matmul path
                       # (kernels/w8a8_matmul); other >0 => per-tensor
                       # fake-quant activations (legacy SmoothQuant mode)

    def tree_flatten(self):
        return (self.qw, self.scale), (self.bits, self.group_size, self.shape,
                                       self.act_bits)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    @property
    def k(self) -> int:
        return self.shape[-2]

    @property
    def n(self) -> int:
        return self.shape[-1]

    def nbytes(self) -> int:
        qb = int(np.prod(self.qw.shape)) * 1
        sb = int(np.prod(self.scale.shape)) * self.scale.dtype.itemsize
        return qb + sb


def _group_count(k: int, group_size: int) -> int:
    if group_size == -1:
        return 1
    assert k % group_size == 0, f"K={k} not divisible by group_size={group_size}"
    return k // group_size


def compute_scales(w: jax.Array, bits: int, group_size: int = -1) -> jax.Array:
    """Symmetric scales: (n_groups, N). w is (K, N)."""
    k, n = w.shape
    g = _group_count(k, group_size)
    wg = w.reshape(g, k // g, n)
    amax = jnp.max(jnp.abs(wg), axis=1)
    scale = amax / qmax_for_bits(bits)
    return jnp.maximum(scale, 1e-10).astype(jnp.float32)


def quantize_values(w: jax.Array, scale: jax.Array, bits: int) -> jax.Array:
    """Round to the symmetric grid. Returns int32 q in [-qmax, qmax], (K, N)."""
    k, n = w.shape
    g = scale.shape[0]
    qmax = qmax_for_bits(bits)
    wg = w.reshape(g, k // g, n)
    q = jnp.round(wg / scale[:, None, :])
    q = jnp.clip(q, -qmax, qmax)
    return q.reshape(k, n).astype(jnp.int32)


def pack(q: jax.Array, bits: int) -> jax.Array:
    """Pack offset-binary values along K into uint8. q: int32 (K, N)."""
    k, n = q.shape
    qmax = qmax_for_bits(bits)
    bpg, vpg = pack_layout(bits)
    if (bpg, vpg) == (1, 1):
        return (q + qmax).astype(jnp.uint8)
    pad = (-k) % vpg
    u = (q + qmax).astype(jnp.uint32)
    if pad:
        u = jnp.concatenate([u, jnp.zeros((pad, n), jnp.uint32)], axis=0)
    u = u.reshape(-1, vpg, n)
    word = jnp.zeros((u.shape[0], n), jnp.uint32)
    for i in range(vpg):
        word = word | (u[:, i, :] << (bits * i))
    if bpg == 1:
        return word.astype(jnp.uint8)
    # multi-byte group (3-bit): emit the word little-endian along K
    out = jnp.stack([(word >> (8 * b)) & 0xFF for b in range(bpg)], axis=1)
    return out.reshape(-1, n).astype(jnp.uint8)


def unpack(qw: jax.Array, bits: int, k: int) -> jax.Array:
    """Inverse of `pack`: returns int32 q in [-qmax, qmax], (K, N)."""
    qmax = qmax_for_bits(bits)
    bpg, vpg = pack_layout(bits)
    if (bpg, vpg) == (1, 1):
        return qw.astype(jnp.int32) - qmax
    if bpg == 1:
        word = qw
    else:
        grp = qw.astype(jnp.uint32).reshape(-1, bpg, qw.shape[1])
        word = grp[:, 0, :]
        for b in range(1, bpg):
            word = word | (grp[:, b, :] << (8 * b))
    mask = (1 << bits) - 1
    parts = [((word >> (bits * i)) & mask) for i in range(vpg)]
    u = jnp.stack(parts, axis=1).reshape(-1, qw.shape[1])
    return u[:k].astype(jnp.int32) - qmax


def quantize(w: jax.Array, bits: int, group_size: int = -1,
             scale: jax.Array | None = None,
             act_bits: int = 0) -> QuantizedTensor:
    """RTN-quantize a (K, N) weight to a packed QuantizedTensor."""
    if scale is None:
        scale = compute_scales(w, bits, group_size)
    q = quantize_values(w, scale, bits)
    return QuantizedTensor(pack(q, bits), scale, bits, group_size,
                           tuple(w.shape), act_bits)


def dequantize(qt: QuantizedTensor, dtype=jnp.float32) -> jax.Array:
    """Works for any leading batch dims (experts and/or scan stacking):
    the trailing (K, N) come from the static shape, leading dims from qw
    itself (scan slices leaves without touching the static aux)."""
    k, n = qt.shape[-2], qt.shape[-1]
    lead = qt.qw.shape[:-2]
    if not lead:
        return _dequant2d(qt.qw, qt.scale, qt.bits, k, n).astype(dtype)
    qw = qt.qw.reshape((-1,) + qt.qw.shape[-2:])
    sc = qt.scale.reshape((-1,) + qt.scale.shape[-2:])
    fn = jax.vmap(lambda q, s: _dequant2d(q, s, qt.bits, k, n))
    return fn(qw, sc).reshape(lead + (k, n)).astype(dtype)


def _dequant2d(qw, scale, bits, k, n):
    q = unpack(qw, bits, k)
    g = scale.shape[0]
    if g == 1:
        return q.astype(jnp.float32) * scale
    # reshape-free: expanding scales by row-gather keeps the (K, N) value
    # tensor's sharding intact under SPMD (a (g, K/g, N) reshape forces a
    # regather whenever g doesn't divide the mesh axis)
    rows = jnp.arange(k) // (k // g)
    return q.astype(jnp.float32) * scale[rows]


def quantize_stacked(w: jax.Array, bits: int, group_size: int = -1,
                     act_bits: int = 0) -> QuantizedTensor:
    """RTN-quantize weights with any leading batch dims (..., K, N)."""

    def one(wi):
        s = compute_scales(wi, bits, group_size)
        return pack(quantize_values(wi, s, bits), bits), s

    lead = w.shape[:-2]
    if not lead:
        return quantize(w, bits, group_size, act_bits=act_bits)
    qw, scale = jax.vmap(one)(w.reshape((-1,) + w.shape[-2:]))
    return QuantizedTensor(qw.reshape(lead + qw.shape[-2:]),
                           scale.reshape(lead + scale.shape[-2:]),
                           bits, group_size, tuple(w.shape), act_bits)


def fake_quant(w: jax.Array, bits: int, group_size: int = -1,
               scale: jax.Array | None = None) -> jax.Array:
    """Quantize->dequantize without packing (same grid as `quantize`)."""
    if scale is None:
        scale = compute_scales(w, bits, group_size)
    k, n = w.shape
    g = scale.shape[0]
    q = quantize_values(w, scale, bits).reshape(g, k // g, n)
    return (q.astype(w.dtype) * scale[:, None, :].astype(w.dtype)).reshape(k, n)


def quantize_activation(x: jax.Array, bits: int = 8,
                        axis_name: str | None = None):
    """Dynamic symmetric per-token int8 activation quantization.

    Returns (q, scale): q int8 with shape of x, scale f32 (..., 1) such that
    q * scale ~= x with |error| <= scale / 2 elementwise (the amax of every
    row lands exactly on the grid, so clipping never adds error).

    `axis_name`: a shard_map/pmap axis over which the token's feature dim is
    split (tensor-parallel row-parallel linears). The amax is then pmax'ed
    so every shard quantizes its slice on the *same* per-token grid as a
    single device would — a shard-local amax would change the quantization
    itself, not just summation order, and break TP-vs-single-device token
    identity.
    """
    qmax = qmax_for_bits(bits)
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    if axis_name is not None:
        amax = jax.lax.pmax(amax, axis_name)
    scale = jnp.maximum(amax, 1e-10) / qmax
    q = jnp.clip(jnp.round(xf / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def fake_quant_activation(x: jax.Array, bits: int = 8,
                          axis_name: str | None = None) -> jax.Array:
    """Dynamic symmetric per-tensor activation fake-quant (SmoothQuant A8).

    `axis_name`: shard axis the feature dim is split over (TP row-parallel)
    — the per-tensor amax is pmax'ed so every shard fake-quants on the
    single-device grid (same contract as `quantize_activation`)."""
    qmax = qmax_for_bits(bits)
    amax = jnp.max(jnp.abs(x))
    if axis_name is not None:
        amax = jax.lax.pmax(amax, axis_name)
    scale = jnp.maximum(amax, 1e-10) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    return (q * scale).astype(x.dtype)


def quantized_like(qt: QuantizedTensor) -> bool:
    return isinstance(qt, QuantizedTensor)


def localize_quantized(params):
    """Rewrite every QuantizedTensor's static `shape` to match its (possibly
    shard-local) qw/scale arrays.

    Inside a tensor-parallel shard_map the pytree *children* (qw, scale) are
    the per-shard slices but the static aux still carries the global (K, N)
    — every consumer that derives dims from `qt.shape` (dequantize, kernel
    dispatch, reference matmuls) would then unpack garbage. The local K is
    recovered from the packed rows; `min` with the recorded K keeps
    unsharded leaves exact when packing padded K up to a whole group.
    `group_size` is untouched: K sharding is only ever legal on whole-group
    boundaries (distributed/partitioning.py `_qt_serve_spec`)."""

    def fix(t):
        if not isinstance(t, QuantizedTensor):
            return t
        k = min(t.shape[-2], unpacked_rows(t.qw.shape[-2], t.bits))
        n = t.qw.shape[-1]
        if (k, n) == t.shape[-2:] and t.qw.shape[:-2] == t.shape[:-2]:
            return t
        return QuantizedTensor(t.qw, t.scale, t.bits, t.group_size,
                               t.qw.shape[:-2] + (k, n), t.act_bits)

    return jax.tree.map(fix, params,
                        is_leaf=lambda x: isinstance(x, QuantizedTensor))
