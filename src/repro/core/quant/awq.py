"""AWQ (Lin et al. 2023): activation-aware weight quantization.

Per-input-channel scales s = amax_x^alpha protect salient weight channels;
alpha is grid-searched to minimize the *output* reconstruction error on
calibration activations. Like SmoothQuant the scale is an exact float
transform (folded into the producing norm); unlike SmoothQuant it optimizes
for weight-only quantization (no activation quant).

The paper's Table 10 positions Norm-Tweaking against / on top of AWQ — here
AWQ is another base quantizer the NT plugin attaches to.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant.types import fake_quant


def awq_search_scales(x: jax.Array, ws: list[jax.Array], *, bits: int,
                      group_size: int = -1, n_grid: int = 9):
    """x: (..., K) calibration input shared by `ws`; returns (s (K,), alpha)."""
    xf = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    amax = jnp.maximum(jnp.max(jnp.abs(xf), axis=0), 1e-5)

    best = (None, jnp.inf, 0.0)
    for i in range(n_grid):
        alpha = i / (n_grid - 1)
        s = amax ** alpha
        s = s / jnp.sqrt(jnp.maximum(jnp.max(s) * jnp.min(s), 1e-10))
        s = jnp.clip(s, 1e-4, 1e4)
        err = 0.0
        for w in ws:
            wf = w.astype(jnp.float32)
            wq = fake_quant(wf * s[:, None], bits, group_size) / s[:, None]
            y = xf @ wf
            yq = xf @ wq
            err += jnp.mean((y - yq) ** 2)
        err = float(err)
        if err < best[1]:
            best = (s, err, alpha)
    return best[0], best[2]
