"""Deployment transform: convert a float param tree to packed low-bit
weights (RTN path — the NT pipeline produces its own QuantizedTensors).
Shape-deterministic, so it composes with jax.eval_shape for the dry-run."""
from __future__ import annotations

import jax

from repro.core.quant.blockquant import iter_linears
from repro.core.quant.types import quantize_stacked
from repro.models.config import ModelConfig
from repro.utils.tree import tree_set

_SKIP = ("embed", "lm_head", "pos", "router", "conv")


def quantize_params_for_serving(cfg: ModelConfig, params: dict,
                                bits: int = 0, group_size: int = 0,
                                act_bits: int = 0) -> dict:
    """Pack every quantizable linear — stacked (E, K, N) expert weights
    included — for the serving fast paths. `act_bits=8` additionally tags
    each packed tensor for the true int8-activation (W8A8/W4A8) matmul
    path in models/linear.py."""
    bits = bits or cfg.serve_quant_bits
    group_size = group_size or cfg.serve_quant_group
    if not bits:
        return params
    # max_ndim=4: scan-stacked MoE expert weights are (L, E, K, N) — they
    # pack to a stacked (L, E, K/vpb, N) layout consumed per-layer by the
    # expert-batched kernel (previously they silently stayed float)
    for path, lin in list(iter_linears(params, max_ndim=4)):
        if any(s in path for s in _SKIP):
            continue
        w = lin["w"]
        if w.shape[-2] % (group_size if group_size > 0 else 1):
            gs = -1  # fall back to per-channel when K isn't divisible
        else:
            gs = group_size
        new_lin = dict(lin)
        new_lin["w"] = quantize_stacked(w, bits, gs, act_bits=act_bits)
        params = tree_set(params, path, new_lin)
    return params
