"""Deployment transform: convert a float param tree to packed low-bit
weights (RTN path — the NT pipeline produces its own QuantizedTensors).
Shape-deterministic, so it composes with jax.eval_shape for the dry-run.

With a mesh, packing is followed by the tensor-parallel placement step:
every leaf — packed qw/scale pairs included — is device_put onto the
serving mesh per `distributed.partitioning.serve_param_shardings`, so
deployed low-bit weights land sharded over the model axis instead of
replicated on every device (the whole point of low-bit serving at scale).
"""
from __future__ import annotations

import jax

from repro.core.quant.blockquant import iter_linears
from repro.core.quant.types import quantize_stacked
from repro.models.config import ModelConfig
from repro.utils.tree import tree_set

_SKIP = ("embed", "lm_head", "pos", "router", "conv")


def quantize_params_for_serving(cfg: ModelConfig, params: dict,
                                bits: int = 0, group_size: int = 0,
                                act_bits: int = 0, mesh=None) -> dict:
    """Pack every quantizable linear — stacked (E, K, N) expert weights
    included — for the serving fast paths. `act_bits=8` additionally tags
    each packed tensor for the true int8-activation (W8A8/W4A8) matmul
    path in models/linear.py. `mesh` (tensor-parallel serving) places the
    packed tree per the serving TP contract after packing."""
    bits = bits or cfg.serve_quant_bits
    group_size = group_size or cfg.serve_quant_group
    if not bits:
        return place_params_for_serving(cfg, params, mesh)
    # max_ndim=4: scan-stacked MoE expert weights are (L, E, K, N) — they
    # pack to a stacked (L, E, packed_rows(K), N) layout consumed per-layer by the
    # expert-batched kernel (previously they silently stayed float)
    for path, lin in list(iter_linears(params, max_ndim=4)):
        if any(s in path for s in _SKIP):
            continue
        w = lin["w"]
        if w.shape[-2] % (group_size if group_size > 0 else 1):
            gs = -1  # fall back to per-channel when K isn't divisible
        else:
            gs = group_size
        new_lin = dict(lin)
        new_lin["w"] = quantize_stacked(w, bits, gs, act_bits=act_bits)
        params = tree_set(params, path, new_lin)
    return place_params_for_serving(cfg, params, mesh)


def place_params_for_serving(cfg: ModelConfig, params: dict, mesh) -> dict:
    """Mesh-aware placement: device_put every leaf (packed or float) with
    its serving-TP NamedSharding. No-op when `mesh` is None, so the
    single-device path never touches placement."""
    if mesh is None:
        return params
    from repro.distributed.partitioning import serve_param_shardings

    shardings = serve_param_shardings(mesh, cfg, params)
    return jax.tree.map(jax.device_put, params, shardings)
