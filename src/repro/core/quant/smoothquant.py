"""SmoothQuant (Xiao et al. 2023): migrate activation outliers into weights.

For a group of linears fed by the same normalization layer, compute
per-input-channel smoothing factors s_j = amax_x_j^alpha / amax_w_j^(1-alpha),
scale weight rows by s and fold 1/s into the norm's scale (and bias) — an
exactly-equivalent transform in float that makes W·A8 quantization viable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def smooth_scales(act_amax: jax.Array, ws: list[jax.Array],
                  alpha: float = 0.5) -> jax.Array:
    """act_amax: (K,) per-channel |x| max; ws: list of (K, N) sharing input."""
    w_amax = jnp.max(jnp.stack([jnp.max(jnp.abs(w), axis=1) for w in ws]),
                     axis=0)                                      # (K,)
    act_amax = jnp.maximum(act_amax.astype(jnp.float32), 1e-5)
    w_amax = jnp.maximum(w_amax.astype(jnp.float32), 1e-5)
    s = act_amax ** alpha / w_amax ** (1.0 - alpha)
    return jnp.clip(s, 1e-5, 1e5)


def fold_into_norm(norm_params: dict, s: jax.Array) -> dict:
    """Divide the producing norm's affine params by s (x' = x / s)."""
    out = dict(norm_params)
    out["scale"] = (norm_params["scale"].astype(jnp.float32) / s).astype(
        norm_params["scale"].dtype)
    if "bias" in norm_params:
        out["bias"] = (norm_params["bias"].astype(jnp.float32) / s).astype(
            norm_params["bias"].dtype)
    return out


def scale_weight_rows(w: jax.Array, s: jax.Array) -> jax.Array:
    """w' = diag(s) @ w  (compensates the activation division)."""
    return (w.astype(jnp.float32) * s[:, None]).astype(w.dtype)
