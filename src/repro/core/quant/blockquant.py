"""Per-block quantization driver: walks a block's linear leaves, resolves the
calibration activations captured for each (taps), and applies RTN / GPTQ /
SmoothQuant. Routers and tiny 1-D params (conv, A_log, dt) stay float.
"""
from __future__ import annotations

from typing import Iterator, Optional

import jax
import jax.numpy as jnp

from repro.core.quant.gptq import gptq_quantize, hessian_from_inputs
from repro.core.quant.rtn import rtn_quantize
from repro.core.quant.smoothquant import (fold_into_norm, scale_weight_rows,
                                          smooth_scales)
from repro.core.quant.types import QuantizedTensor
from repro.utils.tree import tree_get, tree_set


def iter_linears(block: dict, prefix: str = "",
                 max_ndim: int = 3) -> Iterator[tuple[str, dict]]:
    """Yield (path, linear_param_dict) for every quantizable linear.

    Per-block calibration sees (K, N) / expert (E, K, N) leaves; the deploy
    transform walks the full scan-stacked tree, where expert weights carry
    an extra layer dim (L, E, K, N), and passes max_ndim=4."""
    for k, v in block.items():
        if not isinstance(v, dict):
            continue
        w = v.get("w")
        if w is not None and not isinstance(w, dict) and \
                2 <= getattr(w, "ndim", 0) <= max_ndim:
            yield prefix + k, v
        else:
            yield from iter_linears(v, prefix + k + "/", max_ndim)


def tap_key_for(path: str) -> str:
    """Map a linear param path to its calibration-tap key."""
    if path.endswith("experts/wi") or path.endswith("experts/wg"):
        return path.rsplit("/", 1)[0]                 # .../experts
    if path.endswith("experts/wo"):
        return path.rsplit("/", 1)[0] + "_out"        # .../experts_out
    return path


# norm feeding each linear group (for SmoothQuant folding). The first matching
# prefix rule wins; linears not listed here are quantized without smoothing.
_SMOOTH_GROUPS = [
    # (norm path, [linear paths]) — resolved against the block tree
    ("ln1", ["attn/wq", "attn/wk", "attn/wv"]),
    ("ln1", ["attn/wq", "attn/wdkv"]),                # MLA
    ("ln1", ["mamba/in_proj"]),
    ("lnx", ["xattn/wq"]),
    ("ln2", ["mlp/wi", "mlp/wg"]),
    ("ln2", ["moe/shared/wi", "moe/shared/wg"]),
]


def _exists(block: dict, path: str) -> bool:
    node = block
    for k in path.split("/"):
        if not isinstance(node, dict) or k not in node:
            return False
        node = node[k]
    return True


def smooth_block(block: dict, taps: dict, alpha: float = 0.5) -> dict:
    """Fold SmoothQuant scales into norms + weights (exact float transform)."""
    for norm_path, lin_paths in _SMOOTH_GROUPS:
        lins = [p for p in lin_paths if _exists(block, p)]
        if not lins or not _exists(block, norm_path):
            continue
        x = taps.get(tap_key_for(lins[0]))
        if x is None:
            continue
        amax = jnp.max(jnp.abs(x.reshape(-1, x.shape[-1])), axis=0)
        ws = [tree_get(block, p)["w"] for p in lins]
        s = smooth_scales(amax, ws, alpha)
        block = tree_set(block, norm_path, fold_into_norm(
            tree_get(block, norm_path), s))
        for p in lins:
            lin = dict(tree_get(block, p))
            lin["w"] = scale_weight_rows(lin["w"], s)
            block = tree_set(block, p, lin)
        # keep routing decisions identical: compensate the router to see
        # the un-smoothed activations (router stays float)
        if norm_path == "ln2" and _exists(block, "moe/router"):
            router = dict(tree_get(block, "moe/router"))
            router["w"] = scale_weight_rows(router["w"], s)
            block = tree_set(block, "moe/router", router)
        # routed experts share the ln2 input: scale their rows too
        if norm_path == "ln2" and _exists(block, "moe/experts"):
            for nm in ("wi", "wg"):
                lin = dict(tree_get(block, f"moe/experts/{nm}"))
                lin["w"] = (lin["w"].astype(jnp.float32) *
                            s[None, :, None]).astype(lin["w"].dtype)
                block = tree_set(block, f"moe/experts/{nm}", lin)
    return block


def awq_block(block: dict, taps: dict, *, bits: int,
              group_size: int = -1) -> dict:
    """AWQ: grid-searched activation-aware scales, folded like SmoothQuant."""
    from repro.core.quant.awq import awq_search_scales

    for norm_path, lin_paths in _SMOOTH_GROUPS:
        lins = [p for p in lin_paths if _exists(block, p)]
        if not lins or not _exists(block, norm_path):
            continue
        x = taps.get(tap_key_for(lins[0]))
        if x is None:
            continue
        ws = [tree_get(block, p)["w"] for p in lins]
        s, _ = awq_search_scales(x, ws, bits=bits, group_size=group_size)
        block = tree_set(block, norm_path, fold_into_norm(
            tree_get(block, norm_path), s))
        for p in lins:
            lin = dict(tree_get(block, p))
            lin["w"] = scale_weight_rows(lin["w"], s)
            block = tree_set(block, p, lin)
        if norm_path == "ln2" and _exists(block, "moe/router"):
            router = dict(tree_get(block, "moe/router"))
            router["w"] = scale_weight_rows(router["w"], s)
            block = tree_set(block, "moe/router", router)
        if norm_path == "ln2" and _exists(block, "moe/experts"):
            for nm in ("wi", "wg"):
                lin = dict(tree_get(block, f"moe/experts/{nm}"))
                lin["w"] = (lin["w"].astype(jnp.float32) *
                            s[None, :, None]).astype(lin["w"].dtype)
                block = tree_set(block, f"moe/experts/{nm}", lin)
    return block


def quantize_block(block: dict, taps: Optional[dict], *, method: str = "gptq",
                   bits: int = 4, group_size: int = -1, act_bits: int = 0,
                   alpha: float = 0.5, damp: float = 0.01,
                   actorder: bool = False,
                   skip_substrings: tuple = ("router",)) -> dict:
    """Quantize every linear in the block. Returns a new block tree."""
    if method == "smoothquant":
        assert taps is not None, "SmoothQuant needs calibration taps"
        block = smooth_block(block, taps, alpha)
    elif method == "awq":
        assert taps is not None, "AWQ needs calibration taps"
        block = awq_block(block, taps, bits=bits, group_size=group_size)

    for path, lin in list(iter_linears(block)):
        if any(s in path for s in skip_substrings):
            continue
        w = lin["w"]
        if isinstance(w, QuantizedTensor):
            continue
        if method == "gptq":
            assert taps is not None, "GPTQ needs calibration taps"
            x = taps[tap_key_for(path)]
            if w.ndim == 3:  # experts: per-expert Hessian from (E, C, K)
                h = jax.vmap(hessian_from_inputs)(x)
            else:
                h = hessian_from_inputs(x)
            qt, _ = gptq_quantize(w, h, bits=bits, group_size=group_size,
                                  damp=damp, actorder=actorder,
                                  act_bits=act_bits)
        else:  # rtn | smoothquant (weights via RTN after folding)
            qt = rtn_quantize(w, bits=bits, group_size=group_size,
                              act_bits=act_bits)
        new_lin = dict(lin)
        new_lin["w"] = qt
        block = tree_set(block, path, new_lin)
    return block
