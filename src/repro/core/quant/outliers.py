"""Outlier injection: a float-EQUIVALENT transform that reproduces the
large-LLM activation-outlier pathology in a small model.

Large transformers develop per-channel activation outliers (LLM.int8,
SmoothQuant): a few residual-stream channels carry values 10-100x larger
than the rest, and the norm layers amplify them. Symmetric per-output-channel
weight quantization then systematically destroys the small-magnitude weight
rows that read those channels, producing exactly the accumulating
distribution drift the paper's Figure 1 shows.

A tiny CPU-trainable model lacks this structure, so the reproduction
injects it *exactly*: for selected channels C and factor f,
    norm.scale[C] *= f   (and bias[C] *= f)
    w[C, :]       /= f   for every linear reading the norm's output.
The float model is bit-for-bit-modulo-rounding unchanged; the quantized
model is not — giving norm tweaking (and SmoothQuant) precisely the failure
mode they were designed to fix.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import block_spec, get_block, num_blocks
from repro.core.normtweak.pipeline import _restack
from repro.utils.tree import tree_get, tree_set

# linears fed by each norm, per block layout (dense GQA decoder)
_NORM_CONSUMERS = {
    "ln1": ["attn/wq", "attn/wk", "attn/wv", "mamba/in_proj"],
    "ln2": ["mlp/wi", "mlp/wg", "moe/shared/wi", "moe/shared/wg"],
}


def _exists(tree, path):
    node = tree
    for k in path.split("/"):
        if not isinstance(node, dict) or k not in node:
            return False
        node = node[k]
    return True


def inject_outliers(cfg: ModelConfig, params: dict, *, n_channels: int = 8,
                    factor: float = 40.0, seed: int = 0) -> dict:
    key = jax.random.PRNGKey(seed)
    chans = jax.random.choice(key, cfg.d_model, (n_channels,), replace=False)
    scale_vec = jnp.ones((cfg.d_model,)).at[chans].set(factor)

    blocks = []
    for i in range(num_blocks(cfg)):
        bp = get_block(cfg, params, i)
        for norm_key, consumers in _NORM_CONSUMERS.items():
            if not _exists(bp, norm_key):
                continue
            npar = dict(tree_get(bp, norm_key))
            npar["scale"] = npar["scale"] * scale_vec
            if "bias" in npar:
                npar["bias"] = npar["bias"] * scale_vec
            bp = tree_set(bp, norm_key, npar)
            for c in consumers:
                if not _exists(bp, c):
                    continue
                lin = dict(tree_get(bp, c))
                lin["w"] = lin["w"] / scale_vec[:, None]
                bp = tree_set(bp, c, lin)
        blocks.append(bp)
    return _restack(cfg, params, blocks)
