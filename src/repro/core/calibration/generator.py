"""Calibration data generation (paper §Calibration Data Generation).

Variants (Table 8):
  * real        — sampled windows from a real corpus (GPTQ default);
  * random      — uniform random token ids (the paper's failing baseline);
  * gen_v1      — LLM-QAT two-stage self-generation, first token uniform
                  over the whole vocabulary;
  * gen_v2      — ours/paper: first token restricted to the top corpus
                  languages (language-scope restriction).

Two-stage sampling (LLM-QAT): the first `stochastic_prefix` tokens are drawn
from the softmax distribution (temperature 1), the remainder greedily — the
generated text both activates the model's "neurons" and stays coherent.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import init_cache, lm_decode


@functools.partial(jax.jit,
                   static_argnames=("cfg", "length", "stochastic_prefix"))
def _generate_batch(cfg: ModelConfig, params, first_tokens, key, length,
                    stochastic_prefix=4, temperature=1.0):
    b = first_tokens.shape[0]
    cache = init_cache(cfg, b, length)

    def step(carry, t):
        cache, tok, key = carry
        key, sk = jax.random.split(key)
        pos = jnp.full((b, 1), t, jnp.int32)
        logits, cache = lm_decode(cfg, params, tok, cache, pos)
        sampled = jax.random.categorical(sk, logits / temperature, axis=-1)
        greedy = jnp.argmax(logits, axis=-1)
        nxt = jnp.where(t < stochastic_prefix, sampled, greedy).astype(jnp.int32)
        return (cache, nxt[:, None], key), tok[:, 0]

    (_, _, _), toks = jax.lax.scan(
        step, (cache, first_tokens[:, None], key),
        jnp.arange(length, dtype=jnp.int32))
    return toks.T                                                # (B, length)


def generate_calibration(cfg: ModelConfig, params, key, *, n_samples: int,
                         token_length: int,
                         allowed_first: Optional[np.ndarray] = None,
                         stochastic_prefix: int = 4,
                         batch_size: int = 16) -> jax.Array:
    """Self-generated calibration set (n_samples, token_length)."""
    out = []
    done = 0
    while done < n_samples:
        b = min(batch_size, n_samples - done)
        key, k1, k2 = jax.random.split(key, 3)
        if allowed_first is not None:
            idx = jax.random.randint(k1, (b,), 0, len(allowed_first))
            first = jnp.asarray(allowed_first)[idx].astype(jnp.int32)
        else:
            first = jax.random.randint(k1, (b,), 0, cfg.vocab_size,
                                       dtype=jnp.int32)
        toks = _generate_batch(cfg, params, first, k2, token_length,
                               stochastic_prefix)
        out.append(toks[:b])
        done += b
    return jnp.concatenate(out, axis=0)


def random_calibration(cfg: ModelConfig, key, *, n_samples: int,
                       token_length: int) -> jax.Array:
    return jax.random.randint(key, (n_samples, token_length), 0,
                              cfg.vocab_size, dtype=jnp.int32)


def real_calibration(corpus: np.ndarray, key, *, n_samples: int,
                     token_length: int) -> jax.Array:
    n_windows = (len(corpus) - 1) // token_length
    idx = jax.random.randint(key, (n_samples,), 0, n_windows)
    starts = np.asarray(idx) * token_length
    return jnp.asarray(
        np.stack([corpus[s:s + token_length] for s in starts])).astype(
            jnp.int32)
