"""Checkpoint store: nested-dict pytrees (incl. QuantizedTensor leaves) to
an npz + JSON-manifest directory, written atomically (tmp dir + rename) so a
failure mid-save never corrupts the latest checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant.types import QuantizedTensor

_QT_KEY = "__quantized_tensor__"


def _to_plain(tree: Any) -> Any:
    """QuantizedTensor -> tagged dict; leaves stay arrays."""
    if isinstance(tree, QuantizedTensor):
        return {_QT_KEY: {"qw": tree.qw, "scale": tree.scale,
                          "bits": tree.bits, "group_size": tree.group_size,
                          "shape": list(tree.shape),
                          "act_bits": tree.act_bits}}
    if isinstance(tree, dict):
        return {k: _to_plain(v) for k, v in tree.items()}
    return tree


def _from_plain(tree: Any) -> Any:
    if isinstance(tree, dict):
        if _QT_KEY in tree:
            d = tree[_QT_KEY]
            return QuantizedTensor(d["qw"], d["scale"], int(d["bits"]),
                                   int(d["group_size"]), tuple(d["shape"]),
                                   int(d.get("act_bits", 0)))
        return {k: _from_plain(v) for k, v in tree.items()}
    return tree


def _flatten(tree: Any, prefix: str = "") -> dict:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            assert "/" not in str(k), f"key {k} contains '/'"
            out.update(_flatten(v, f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def save_tree(path: str, tree: Any, extra_meta: dict | None = None) -> None:
    plain = _to_plain(tree)
    flat = _flatten(plain)
    arrays, scalars = {}, {}
    for k, v in flat.items():
        if isinstance(v, (jax.Array, np.ndarray)):
            arrays[k] = np.asarray(v)
        else:
            scalars[k] = v
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = tempfile.mkdtemp(dir=os.path.dirname(path) or ".",
                           prefix=".ckpt_tmp_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        meta = {"scalars": scalars, "extra": extra_meta or {}}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(path):
            shutil.rmtree(path)
        os.rename(tmp, path)
    finally:
        if os.path.exists(tmp):
            shutil.rmtree(tmp, ignore_errors=True)


# ------------------------------------------------------- engine snapshots
#
# ContinuousEngine.snapshot() returns an arbitrary nested structure — dicts
# with non-string (slot/rid) keys, tuples (event-log entries, fingerprint
# geometry), bytes, numpy arrays at any depth, None, scalars. save_tree's
# slash-path flattening can't represent that, so snapshots get their own
# codec: arrays are pulled into one npz (bfloat16 stored as a uint16 view —
# npz can't serialize ml_dtypes), and everything else becomes a tagged JSON
# manifest that decodes back to the exact same structure, key types and
# tuple-ness included. Atomicity matches save_tree (tmp dir + rename).

_ND, _TUP, _BYTES, _ITEMS, _BF16 = ("__nd__", "__tuple__", "__bytes__",
                                    "__items__", "bfloat16")


def _snap_encode(obj: Any, arrays: list) -> Any:
    if isinstance(obj, (jax.Array, np.ndarray)):
        arrays.append(np.asarray(obj))
        return {_ND: len(arrays) - 1}
    if isinstance(obj, np.generic):
        return _snap_encode(obj.item(), arrays)
    if isinstance(obj, bytes):
        return {_BYTES: obj.hex()}
    if isinstance(obj, tuple):
        return {_TUP: [_snap_encode(v, arrays) for v in obj]}
    if isinstance(obj, list):
        return [_snap_encode(v, arrays) for v in obj]
    if isinstance(obj, dict):
        # key-value pair list: keys keep their type (int slot/rid keys
        # must not come back as strings)
        return {_ITEMS: [[_snap_encode(k, arrays), _snap_encode(v, arrays)]
                         for k, v in obj.items()]}
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    raise TypeError(f"snapshot codec cannot serialize {type(obj)!r}")


def _snap_decode(obj: Any, arrays: dict) -> Any:
    if isinstance(obj, dict):
        if _ND in obj:
            return arrays[f"a{obj[_ND]}"]
        if _BYTES in obj:
            return bytes.fromhex(obj[_BYTES])
        if _TUP in obj:
            return tuple(_snap_decode(v, arrays) for v in obj[_TUP])
        assert set(obj) == {_ITEMS}, f"unknown snapshot node {set(obj)}"
        return {_snap_decode(k, arrays): _snap_decode(v, arrays)
                for k, v in obj[_ITEMS]}
    if isinstance(obj, list):
        return [_snap_decode(v, arrays) for v in obj]
    return obj


def save_snapshot(path: str, snap: Any) -> None:
    """Serialize an engine snapshot to a directory, atomically."""
    import ml_dtypes

    arrays: list = []
    manifest = _snap_encode(snap, arrays)
    named, dtypes = {}, {}
    for i, a in enumerate(arrays):
        if a.dtype == ml_dtypes.bfloat16:
            dtypes[f"a{i}"] = _BF16
            a = a.view(np.uint16)
        named[f"a{i}"] = a
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = tempfile.mkdtemp(dir=os.path.dirname(path) or ".",
                           prefix=".snap_tmp_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **named)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"manifest": manifest, "dtypes": dtypes,
                       "format": "engine-snapshot-v1"}, f)
        if os.path.exists(path):
            shutil.rmtree(path)
        os.rename(tmp, path)
    finally:
        if os.path.exists(tmp):
            shutil.rmtree(tmp, ignore_errors=True)


def load_snapshot(path: str) -> Any:
    """Inverse of save_snapshot: the exact structure snapshot() returned."""
    import ml_dtypes

    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    assert meta.get("format") == "engine-snapshot-v1", \
        f"{path}: not an engine snapshot"
    npz = np.load(os.path.join(path, "arrays.npz"))
    arrays = {}
    for k in npz.files:
        a = npz[k]
        if meta["dtypes"].get(k) == _BF16:
            a = a.view(ml_dtypes.bfloat16)
        arrays[k] = a
    return _snap_decode(meta["manifest"], arrays)


def load_tree(path: str) -> tuple[Any, dict]:
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    npz = np.load(os.path.join(path, "arrays.npz"))
    flat: dict[str, Any] = {k: jnp.asarray(npz[k]) for k in npz.files}
    flat.update(meta["scalars"])
    tree: dict = {}
    for key, val in flat.items():
        node = tree
        parts = key.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return _from_plain(tree), meta["extra"]
