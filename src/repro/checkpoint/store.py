"""Checkpoint store: nested-dict pytrees (incl. QuantizedTensor leaves) to
an npz + JSON-manifest directory, written atomically (tmp dir + rename) so a
failure mid-save never corrupts the latest checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant.types import QuantizedTensor

_QT_KEY = "__quantized_tensor__"


def _to_plain(tree: Any) -> Any:
    """QuantizedTensor -> tagged dict; leaves stay arrays."""
    if isinstance(tree, QuantizedTensor):
        return {_QT_KEY: {"qw": tree.qw, "scale": tree.scale,
                          "bits": tree.bits, "group_size": tree.group_size,
                          "shape": list(tree.shape),
                          "act_bits": tree.act_bits}}
    if isinstance(tree, dict):
        return {k: _to_plain(v) for k, v in tree.items()}
    return tree


def _from_plain(tree: Any) -> Any:
    if isinstance(tree, dict):
        if _QT_KEY in tree:
            d = tree[_QT_KEY]
            return QuantizedTensor(d["qw"], d["scale"], int(d["bits"]),
                                   int(d["group_size"]), tuple(d["shape"]),
                                   int(d.get("act_bits", 0)))
        return {k: _from_plain(v) for k, v in tree.items()}
    return tree


def _flatten(tree: Any, prefix: str = "") -> dict:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            assert "/" not in str(k), f"key {k} contains '/'"
            out.update(_flatten(v, f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def save_tree(path: str, tree: Any, extra_meta: dict | None = None) -> None:
    plain = _to_plain(tree)
    flat = _flatten(plain)
    arrays, scalars = {}, {}
    for k, v in flat.items():
        if isinstance(v, (jax.Array, np.ndarray)):
            arrays[k] = np.asarray(v)
        else:
            scalars[k] = v
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = tempfile.mkdtemp(dir=os.path.dirname(path) or ".",
                           prefix=".ckpt_tmp_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        meta = {"scalars": scalars, "extra": extra_meta or {}}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(path):
            shutil.rmtree(path)
        os.rename(tmp, path)
    finally:
        if os.path.exists(tmp):
            shutil.rmtree(tmp, ignore_errors=True)


def load_tree(path: str) -> tuple[Any, dict]:
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    npz = np.load(os.path.join(path, "arrays.npz"))
    flat: dict[str, Any] = {k: jnp.asarray(npz[k]) for k in npz.files}
    flat.update(meta["scalars"])
    tree: dict = {}
    for key, val in flat.items():
        node = tree
        parts = key.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return _from_plain(tree), meta["extra"]
