"""Checkpoint manager: step-numbered directories, retention policy, async
background saves, and exact-resume (params + optimizer + data cursor + RNG).
"""
from __future__ import annotations

import concurrent.futures as cf
import os
import re
import shutil
from typing import Any, Optional

import jax
import numpy as np

from repro.checkpoint.store import load_tree, save_tree

_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = cf.ThreadPoolExecutor(max_workers=1) if async_save else None
        self._pending: Optional[cf.Future] = None

    # ------------------------------------------------------------- queries
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = _STEP_RE.match(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # --------------------------------------------------------------- save
    def save(self, step: int, params: Any, opt_state: Any = None,
             extra: Optional[dict] = None, block: bool = False) -> None:
        # snapshot to host first (donated buffers may be reused by the next
        # train step while the write happens in the background)
        tree = {"params": params}
        if opt_state is not None:
            tree["opt_state"] = opt_state
        host = jax.tree.map(np.asarray, tree)
        meta = dict(extra or {})
        meta["step"] = step

        def do_save():
            save_tree(os.path.join(self.dir, f"step_{step}"), host, meta)
            self._gc()

        self.wait()
        if self._pool is not None and not block:
            self._pending = self._pool.submit(do_save)
        else:
            do_save()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -------------------------------------------------------------- restore
    def restore(self, step: Optional[int] = None):
        """Returns (step, params, opt_state_or_None, extra) or None."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None
        tree, extra = load_tree(os.path.join(self.dir, f"step_{step}"))
        return step, tree["params"], tree.get("opt_state"), extra
