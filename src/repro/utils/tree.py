"""Pytree path utilities used across the framework.

Params are nested dicts of jnp arrays (or QuantizedTensor leaves). We address
sub-trees by '/'-joined key paths, e.g. "blocks/attn/wq/w".
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

Tree = Any


def tree_paths(tree: Tree, prefix: str = "") -> list[str]:
    """All leaf paths of a nested-dict tree ('/'-joined)."""
    out: list[str] = []
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.extend(tree_paths(v, f"{prefix}{k}/"))
    else:
        out.append(prefix[:-1] if prefix else "")
    return out


def tree_get(tree: Tree, path: str) -> Any:
    node = tree
    for k in path.split("/"):
        node = node[k]
    return node


def tree_set(tree: Tree, path: str, value: Any) -> Tree:
    """Functional set: returns a new tree with `path` replaced by `value`."""
    keys = path.split("/")

    def rec(node: Tree, i: int) -> Tree:
        if i == len(keys):
            return value
        new = dict(node)
        new[keys[i]] = rec(node[keys[i]], i + 1)
        return new

    return rec(tree, 0)


def tree_partition(
    tree: Tree, predicate: Callable[[str], bool], prefix: str = ""
) -> tuple[Tree, Tree]:
    """Split a nested dict into (matching, rest) by path predicate.

    Structure is preserved; non-selected leaves are replaced by None so the
    two parts can be merged back with `tree_merge`. The predicate sees the
    '/'-joined path of each *subtree or leaf*; once it matches, the whole
    subtree goes to `matching`.
    """
    if not isinstance(tree, dict):
        return (tree, None) if predicate(prefix[:-1]) else (None, tree)
    if prefix and predicate(prefix[:-1]):
        return tree, None
    a: dict = {}
    b: dict = {}
    for k, v in tree.items():
        av, bv = tree_partition(v, predicate, f"{prefix}{k}/")
        a[k] = av
        b[k] = bv
    return a, b


def tree_merge(a: Tree, b: Tree) -> Tree:
    """Inverse of tree_partition: overlay two None-padded trees."""
    if a is None:
        return b
    if b is None:
        return a
    assert isinstance(a, dict) and isinstance(b, dict), (a, b)
    out = {}
    for k in a.keys() | b.keys():
        out[k] = tree_merge(a.get(k), b.get(k))
    return out


def tree_map_with_path(fn: Callable[[str, Any], Any], tree: Tree, prefix: str = "") -> Tree:
    if isinstance(tree, dict):
        return {k: tree_map_with_path(fn, v, f"{prefix}{k}/") for k, v in tree.items()}
    return fn(prefix[:-1], tree)


def tree_size_bytes(tree: Tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(x.size * x.dtype.itemsize for x in leaves if hasattr(x, "size"))


def tree_num_params(tree: Tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(int(x.size) for x in leaves if hasattr(x, "size"))


def tree_stack(trees: list[Tree]) -> Tree:
    """Stack a list of identically-structured trees along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_index(tree: Tree, i) -> Tree:
    """Take slice i of every leaf along its leading (stacked) axis."""
    return jax.tree.map(lambda x: x[i], tree)


def tree_dynamic_index(tree: Tree, i) -> Tree:
    """Like tree_index but with a traced integer index."""
    return jax.tree.map(
        lambda x: jax.lax.dynamic_index_in_dim(x, i, axis=0, keepdims=False), tree
    )
