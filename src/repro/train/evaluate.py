"""Evaluation: perplexity over a token stream + last-word accuracy (our
offline LAMBADA analogue: predict the final token of a held-out window)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import lm_forward


@functools.partial(jax.jit, static_argnames=("cfg",))
def _nll_batch(cfg: ModelConfig, params, tokens, labels):
    logits, _ = lm_forward(cfg, params, tokens)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    correct_last = (jnp.argmax(logits[:, -1, :], axis=-1) == labels[:, -1])
    return jnp.sum(nll), nll.size, jnp.sum(correct_last), correct_last.size


def perplexity(cfg: ModelConfig, params, tokens: np.ndarray, *,
               seq_len: int = 128, batch_size: int = 8,
               max_windows: int = 64) -> dict:
    """Sliding non-overlapping windows; returns {'ppl', 'nll', 'last_acc'}."""
    n_win = min((len(tokens) - 1) // seq_len, max_windows)
    tot_nll, tot_cnt, tot_corr, tot_last = 0.0, 0, 0.0, 0
    for b0 in range(0, n_win, batch_size):
        bn = min(batch_size, n_win - b0)
        idx = np.arange(b0, b0 + bn) * seq_len
        toks = jnp.asarray(np.stack([tokens[s:s + seq_len] for s in idx]))
        labs = jnp.asarray(np.stack([tokens[s + 1:s + seq_len + 1]
                                     for s in idx]))
        s_nll, cnt, s_corr, n_last = _nll_batch(cfg, params,
                                                toks.astype(jnp.int32),
                                                labs.astype(jnp.int32))
        tot_nll += float(s_nll)
        tot_cnt += int(cnt)
        tot_corr += float(s_corr)
        tot_last += int(n_last)
    nll = tot_nll / max(tot_cnt, 1)
    return {"ppl": float(np.exp(min(nll, 30.0))), "nll": nll,
            "last_acc": tot_corr / max(tot_last, 1)}
