"""Fault-tolerant training driver.

Guarantees:
  * exact resume — params, Adam state, RNG and the data cursor are all in
    the checkpoint; batches are a pure function of (seed, step), so a
    restarted run replays the identical trajectory;
  * async checkpointing — saves overlap the next steps;
  * straggler detection — per-step wall-time EWMA; a step slower than
    `straggler_z` standard deviations triggers a callback (at pod scale:
    checkpoint + remesh via launch/elastic.py).
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataPipeline
from repro.models.config import ModelConfig


class StepTimeMonitor:
    def __init__(self, alpha: float = 0.1, z: float = 4.0, warmup: int = 5):
        self.alpha = alpha
        self.z = z
        self.warmup = warmup
        self.mean = 0.0
        self.var = 0.0
        self.n = 0

    def update(self, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        self.n += 1
        if self.n <= self.warmup:
            self.mean = dt if self.n == 1 else \
                (1 - self.alpha) * self.mean + self.alpha * dt
            return False
        straggler = dt > self.mean + self.z * max(self.var, 1e-12) ** 0.5 \
            and dt > 1.5 * self.mean
        d = dt - self.mean
        self.mean += self.alpha * d
        self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return straggler


class Trainer:
    def __init__(self, cfg: ModelConfig, params, opt_state, step_fn,
                 pipeline: DataPipeline, ckpt: CheckpointManager, *,
                 rng_seed: int = 0,
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self.cfg = cfg
        self.params = params
        self.opt_state = opt_state
        self.step_fn = step_fn
        self.pipe = pipeline
        self.ckpt = ckpt
        self.rng_seed = rng_seed
        self.monitor = StepTimeMonitor()
        self.on_straggler = on_straggler
        self.start_step = 0
        self.history: list[dict] = []

    def maybe_resume(self) -> int:
        restored = self.ckpt.restore()
        if restored is not None:
            step, params, opt_state, extra = restored
            self.params = jax.tree.map(jnp.asarray, params)
            self.opt_state = jax.tree.map(jnp.asarray, opt_state)
            self.start_step = step + 1
        return self.start_step

    def run(self, num_steps: int, *, ckpt_every: int = 50,
            log_every: int = 10,
            log: Callable[[str], None] = print,
            crash_at: Optional[int] = None) -> dict:
        """`crash_at`: raise after that step (fault-injection for tests)."""
        step = self.start_step
        end = num_steps
        while step < end:
            t0 = time.time()
            batch = {k: jnp.asarray(v)
                     for k, v in self.pipe.batch_at(step).items()}
            rng = jax.random.fold_in(jax.random.PRNGKey(self.rng_seed), step)
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch, jnp.asarray(step), rng)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            if self.monitor.update(dt) and self.on_straggler:
                self.on_straggler(step, dt)
            self.history.append({"step": step, "loss": loss, "dt": dt})
            if log_every and step % log_every == 0:
                log(f"step {step} loss {loss:.4f} ({dt * 1e3:.0f} ms)")
            if ckpt_every and (step + 1) % ckpt_every == 0:
                self.ckpt.save(step, self.params, self.opt_state,
                               extra={"rng_seed": self.rng_seed})
            if crash_at is not None and step == crash_at:
                self.ckpt.wait()
                raise RuntimeError(f"injected failure at step {step}")
            step += 1
        self.ckpt.save(end - 1, self.params, self.opt_state,
                       extra={"rng_seed": self.rng_seed}, block=True)
        self.ckpt.wait()
        return {"final_loss": self.history[-1]["loss"] if self.history else None,
                "steps_run": len(self.history)}
