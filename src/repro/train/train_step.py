"""Jittable training step: loss -> grads -> (optional compression) -> clip ->
Adam. Supports microbatched gradient accumulation via `lax.scan`."""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import lm_loss
from repro.optim.adam import adam_init, adam_update, clip_by_global_norm
from repro.optim.compression import compress_decompress


def make_train_step(cfg: ModelConfig, *,
                    lr_schedule: Callable[[jax.Array], jax.Array],
                    clip_norm: float = 1.0,
                    weight_decay: float = 0.0,
                    accum_steps: int = 1,
                    grad_compress_bits: int = 0,
                    loss_fn=None,
                    donate: bool = True):
    loss_fn = loss_fn or lm_loss

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
        return loss, metrics, grads

    def step(params, opt_state, batch, step_idx, rng):
        if accum_steps > 1:
            def micro(carry, mb):
                gacc, lacc = carry
                loss, _, grads = grads_of(params, mb)
                return (jax.tree.map(jnp.add, gacc, grads), lacc + loss), None

            mbs = jax.tree.map(
                lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps,
                                    *x.shape[1:]), batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            (grads, loss), _ = jax.lax.scan(micro, (zero, jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss / accum_steps
            metrics = {"nll": loss, "aux": jnp.zeros(())}
        else:
            loss, metrics, grads = grads_of(params, batch)

        if grad_compress_bits:
            # int8/4 compression with error feedback: the residual rides in
            # opt_state["ef"] (simulates a compressed DP all-reduce)
            grads, ef = compress_decompress(grads, opt_state["ef"],
                                            bits=grad_compress_bits, rng=rng)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = lr_schedule(step_idx)
        new_params, new_adam = adam_update(
            grads, opt_state["adam"], params, lr=lr,
            weight_decay=weight_decay)
        new_state = dict(opt_state)
        new_state["adam"] = new_adam
        if grad_compress_bits:
            new_state["ef"] = ef
        metrics = dict(metrics)
        metrics.update({"loss": loss, "grad_norm": gnorm, "lr": lr})
        return new_params, new_state, metrics

    return jax.jit(step, donate_argnums=(0, 1) if donate else ())


def init_opt_state(cfg: ModelConfig, params, grad_compress_bits: int = 0):
    state = {"adam": adam_init(params)}
    if grad_compress_bits:
        state["ef"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state
