"""Tile-regime selection for every Pallas kernel: a deterministic fallback
table plus a measured autotuner with a persistent JSON config cache.

Every kernel dispatch in `kernels/ops.py` asks this module for its tile
plan. Resolution order, governed by ``REPRO_AUTOTUNE`` (debug_flags):

  * ``"0"``        — always the deterministic fallback table (the former
    hand heuristics, verbatim). CI and the compile-count sanitizer run
    here implicitly: with no cache file the default mode degrades to the
    table, so replay-twice sees identical plans and zero new tracings.
  * ``""`` (default) — a warm cache entry for the shape class if the JSON
    cache (``REPRO_AUTOTUNE_CACHE``) is readable and was written by this
    template generation; else the table.
  * ``"1"``        — measure real ``pallas_call`` candidates for a cold
    shape class, record the winner in-process, and persist it when a cache
    path is set.

Shape classes bucket the token dim (decode-skinny M <= 8 collapses to one
class, larger Ms to pow2 buckets) and key on everything that changes the
kernel's inner loop: kind, N, K, bits, group_size for matmuls;
page_size, KV dtype, m-rows bucket for the paged-attention walk.

Cache hygiene: the on-disk format embeds `template.TEMPLATE_VERSION` (a
content hash of kernels/template.py), so configs measured against an older
template generation are ignored wholesale; corrupt or unreadable files log
a warning and fall back to the table; individual entries are re-validated
against the kernel's tiling constraints before use, so a hand-edited or
stale entry can never reach a `pallas_call` that would reject it.
"""
from __future__ import annotations

import json
import logging
import os
import time
from typing import Optional

import jax
import numpy as np

from repro import debug_flags
from repro.core.quant.types import pack_layout
from repro.kernels import template

_LOG = logging.getLogger(__name__)

# ------------------------------------------------ deterministic fallback
# (the former hand heuristics from kernels/ops.py, verbatim — the plans the
# serving stack gets with a cold cache or REPRO_AUTOTUNE=0)

# decode-shaped tiles: minimal token rows, wide weight tiles
_SKINNY_M = 8
_SKINNY_BN = 512
_SKINNY_BK = 512

# paged-attention read-width regime: the page walk streams one KV tile per
# grid step; small pages ride whole (the common serving geometry — page_size
# 16/32 — is far below the cap), oversized pages split into <=256-token
# sub-tiles so a step's K/V/score working set stays VMEM-resident instead of
# scaling with page_size
_PAGE_TILE = 256


def pick_block(dim: int, target: int) -> int:
    if dim <= target:
        return dim
    b = target
    while dim % b != 0:
        b //= 2
        if b < 8:
            return dim  # fall back to a single block
    return b


def pick_bk(k: int, gs: int, vpg: int, target: int) -> Optional[int]:
    """K block size that divides K, packs whole byte groups (vpg values per
    `pack_layout` group), and tiles the scale groups (whole groups per
    block, or whole blocks per group). Returns None when no such block
    exists — e.g. a group size with a large odd factor — so callers can
    fall back to the jnp reference instead of spinning the shrink loop
    down to a mod-by-zero."""
    if gs == k:
        # per-channel (n_groups == 1): the group constraint collapses to
        # bk | k, so any divisor of K that packs whole byte groups works.
        # The halving loop below could only ever return K itself here (or
        # give up): target halvings rarely divide a non-pow2 K, and the
        # "whole blocks per group" branch needs bk | k anyway. Take the
        # largest such divisor <= target directly.
        if k % vpg != 0:
            return None
        for d in range(min(target, k), 7, -1):
            if k % d == 0 and d % vpg == 0:
                return d
        return k  # no >= 8-row divisor under target: one whole-K block
    bk = pick_block(k, target)
    while k % bk != 0 or (gs < bk and bk % gs != 0) or \
            (gs >= bk and gs % bk != 0) or bk % vpg != 0:
        bk //= 2  # halving can break K-divisibility; re-checked above
        if bk < max(vpg, 1):
            return None
    return bk


def matmul_blocks(m: int, bm: int, bn: int, bk: int):
    """Prefill-vs-decode tile regime: skinny token counts trade token-dim
    padding for wider weight tiles."""
    if m <= _SKINNY_M:
        return _SKINNY_M, max(bn, _SKINNY_BN), max(bk, _SKINNY_BK)
    return bm, bn, bk


def fallback_matmul_plan(m: int, k: int, n: int, *, bits: int,
                         group_size: int, bm: int, bn: int, bk: int):
    """Tile regime by token count, then concrete (bm, bn, bk) blocks.
    Returns None when K admits no valid block — callers fall back to the
    jnp ref."""
    gs = group_size if group_size != -1 else k
    vpg = pack_layout(bits)[1]
    bm, bn, bk = matmul_blocks(m, bm, bn, bk)
    bk_ = pick_bk(k, gs, vpg, bk)
    if bk_ is None:
        return None
    return pick_block(max(m, 8), bm), pick_block(n, bn), bk_


def fallback_paged_tile(page_size: int) -> int:
    """Token tile per page-walk step (read-width regime, see _PAGE_TILE)."""
    return pick_block(page_size, _PAGE_TILE)


# ------------------------------------------------------- shape-class keys

def m_bucket(m: int) -> int:
    """Token-dim bucket: decode-skinny Ms collapse to one class, larger Ms
    to the next power of two (the engine pads to pow2 buckets anyway)."""
    if m <= _SKINNY_M:
        return _SKINNY_M
    b = 16
    while b < m:
        b *= 2
    return b


def matmul_key(kind: str, m: int, k: int, n: int, bits: int,
               group_size: int) -> str:
    return f"{kind}:m{m_bucket(m)}:n{n}:k{k}:w{bits}:g{group_size}"


def paged_key(page_size: int, kv_dtype: str, m_rows: int) -> str:
    return f"paged:ps{page_size}:kv{kv_dtype}:m{m_bucket(m_rows)}"


# ------------------------------------------------------------ cache state

# in-memory view of the JSON cache, keyed by the path it was loaded from;
# measured winners land here too (and on disk when a path is set)
_state: dict = {"path": None, "entries": None}


def reset() -> None:
    """Drop the in-memory cache (tests; after rewriting the cache file)."""
    _state["path"] = None
    _state["entries"] = None


def load_cache(path: str) -> dict:
    """Entries from a cache file. Missing file -> cold ({}); corrupt,
    unreadable, wrong-shape, or stale-template-version files log a warning
    and also return {} — the deterministic table takes over, never an
    exception on the serving path."""
    try:
        with open(path, encoding="utf-8") as f:
            data = json.load(f)
        if not isinstance(data, dict) or not isinstance(
                data.get("entries"), dict):
            raise ValueError("expected {'version': ..., 'entries': {...}}")
    except FileNotFoundError:
        return {}
    except (OSError, ValueError) as e:
        _LOG.warning("autotune cache %s unreadable (%s); "
                     "using the deterministic table", path, e)
        return {}
    if data.get("version") != template.TEMPLATE_VERSION:
        _LOG.warning("autotune cache %s was measured against template "
                     "version %s (current %s); ignoring it", path,
                     data.get("version"), template.TEMPLATE_VERSION)
        return {}
    return data["entries"]


def save_cache(path: str, entries: dict) -> None:
    payload = {"version": template.TEMPLATE_VERSION, "entries": entries}
    tmp = path + ".tmp"
    with open(tmp, "w", encoding="utf-8") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def _entries() -> dict:
    path = debug_flags.autotune_cache_path()
    if _state["entries"] is None or _state["path"] != path:
        _state["path"] = path
        _state["entries"] = load_cache(path) if path else {}
    return _state["entries"]


def _persist(entries: dict) -> None:
    path = debug_flags.autotune_cache_path()
    if path:
        save_cache(path, entries)


# ------------------------------------------------------ entry validation

def _valid_matmul_plan(ent, *, k: int, n: int, bits: int, group_size: int):
    """A cached (bm, bn, bk) that satisfies the kernel's tiling constraints,
    or None. bm is free (ops pads the token dim to it); bn must tile N; bk
    must tile K, the byte groups, and the scale groups."""
    try:
        bm, bn, bk = int(ent["bm"]), int(ent["bn"]), int(ent["bk"])
    except (KeyError, TypeError, ValueError):
        return None
    gs = group_size if group_size != -1 else k
    vpg = pack_layout(bits)[1]
    if bm <= 0 or bn <= 0 or bk <= 0:
        return None
    if n % bn or k % bk or bk % vpg:
        return None
    if not ((gs >= bk and gs % bk == 0) or (gs < bk and bk % gs == 0)):
        return None
    return bm, bn, bk


def _valid_paged_tile(ent, page_size: int) -> Optional[int]:
    try:
        tile = int(ent["tile"])
    except (KeyError, TypeError, ValueError):
        return None
    if tile <= 0 or page_size % tile:
        return None
    return tile


# ------------------------------------------------------- measured search

def _time_candidate(fn, reps: int = 3) -> float:
    """Best-of-reps wall time of a jitted thunk. Wall-clock measurement is
    the whole point of this module and only ever runs under
    REPRO_AUTOTUNE=1 — never in CI, replay, or the sanitizer."""
    jax.block_until_ready(fn())  # compile + warm outside the timed reps
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()  # repro-lint: disable=RL001
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)  # repro-lint: disable=RL001
    return best


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _matmul_candidates(m: int, k: int, n: int, bits: int, group_size: int,
                       fallback):
    """Small deduped candidate grid around the shape: pow2 bm up to the
    m-bucket, bn/bk from the regimes both tile tables use, fallback always
    included so the search can only match or beat it."""
    gs = group_size if group_size != -1 else k
    vpg = pack_layout(bits)[1]
    mb = max(m_bucket(m), 8)
    bms = sorted({b for b in (8, 32, 128, 256) if b <= mb} | {mb})
    bns = sorted({b for b in (128, 256, 512) if b <= n and n % b == 0}
                 | {pick_block(n, 256)})
    bks = sorted({b for b in (128, 256, 512)
                  if b <= k and k % b == 0 and b % vpg == 0 and
                  ((gs >= b and gs % b == 0) or (gs < b and b % gs == 0))})
    fb_bk = pick_bk(k, gs, vpg, 256)
    if fb_bk is not None:
        bks = sorted(set(bks) | {fb_bk})
    cands = [(bm, bn, bk) for bm in bms for bn in bns for bk in bks]
    if fallback is not None and fallback not in cands:
        cands.append(fallback)
    return cands


def _search_matmul(kind: str, m: int, k: int, n: int, *, bits: int,
                   group_size: int, fallback):
    """Time every candidate on the real pallas_call with synthetic operands
    at the bucketed token count; return the fastest plan (or the fallback
    when no candidate is tileable)."""
    cands = _matmul_candidates(m, k, n, bits, group_size, fallback)
    if not cands:
        return fallback
    rng = np.random.default_rng(0)
    mb = max(m_bucket(m), 8)
    g = 1 if group_size == -1 else k // group_size
    pk = template.packed_tile_rows(k, bits)
    qw = rng.integers(0, 256, (pk, n)).astype(np.uint8)
    scale = rng.uniform(0.01, 0.1, (g, n)).astype(np.float32)
    expert = kind.startswith("expert_")
    int8_act = kind.endswith("w8a8")
    if int8_act:
        x = rng.integers(-127, 128, (mb, k)).astype(np.int8)
    else:
        x = rng.normal(size=(mb, k)).astype(np.float32)
    if expert:
        x = np.stack([x, x])
        qw = np.stack([qw, qw])
        scale = np.stack([scale, scale])
    kernel_fn = _MEASURE_FNS[kind]()
    best, best_t = None, float("inf")
    for bm, bn, bk in cands:
        pad = (-mb) % bm
        xp = np.pad(x, ((0, 0), (0, pad), (0, 0)) if expert
                    else ((0, pad), (0, 0)))
        try:
            t = _time_candidate(lambda: kernel_fn(
                xp, qw, scale, bits=bits, group_size=group_size, bm=bm,
                bn=bn, bk=bk, interpret=_interpret()))
        except Exception as e:  # candidate fails to lower: skip it
            _LOG.debug("autotune candidate %s rejected: %s",
                       (bm, bn, bk), e)
            continue
        if t < best_t:
            best, best_t = (bm, bn, bk), t
    return best if best is not None else fallback


def _measure_dequant():
    from repro.kernels.dequant_matmul import dequant_matmul_pallas
    return dequant_matmul_pallas


def _measure_expert_dequant():
    from repro.kernels.expert_dequant_matmul import expert_dequant_matmul_pallas
    return expert_dequant_matmul_pallas


def _measure_w8a8():
    from repro.kernels.w8a8_matmul import w8a8_matmul_pallas
    return w8a8_matmul_pallas


def _measure_expert_w8a8():
    from repro.kernels.expert_w8a8_matmul import expert_w8a8_matmul_pallas
    return expert_w8a8_matmul_pallas


_MEASURE_FNS = {
    "dequant": _measure_dequant,
    "expert_dequant": _measure_expert_dequant,
    "w8a8": _measure_w8a8,
    "expert_w8a8": _measure_expert_w8a8,
}


def _search_paged(page_size: int, kv_dtype: str, m_rows: int,
                  fallback: int) -> int:
    """Time the page-walk kernel per candidate tile on a synthetic
    two-slot case; return the fastest tile."""
    from repro.kernels.paged_attention import paged_attention_pallas

    import jax.numpy as jnp

    cands = sorted({t for t in (64, 128, 256, page_size, fallback)
                    if 0 < t <= page_size and page_size % t == 0})
    if len(cands) <= 1:
        return fallback
    rng = np.random.default_rng(0)
    s, kvh, hd, w = 2, 1, 128, 2
    rows = max(m_bucket(m_rows), 1) if m_rows > 1 else 1
    n_pages = 1 + s * w
    kf = rng.normal(size=(n_pages, page_size, kvh, hd)).astype(np.float32)
    vf = rng.normal(size=(n_pages, page_size, kvh, hd)).astype(np.float32)
    if kv_dtype == "int8":
        ks = np.abs(kf).max(axis=-1) / 127.0 + 1e-6
        vs = np.abs(vf).max(axis=-1) / 127.0 + 1e-6
        pools = (np.clip(np.round(kf / ks[..., None]), -127, 127)
                 .astype(np.int8),
                 np.clip(np.round(vf / vs[..., None]), -127, 127)
                 .astype(np.int8),
                 ks.astype(np.float32), vs.astype(np.float32))
    else:
        pools = (kf.astype(jnp.bfloat16), vf.astype(jnp.bfloat16),
                 None, None)
    bt = np.arange(1, 1 + s * w, dtype=np.int32).reshape(s, w)
    kv_len = np.full((s,), w * page_size, np.int32)
    q = rng.normal(size=(s, kvh, rows, hd)).astype(np.float32)
    best, best_t = fallback, float("inf")
    for tile in cands:
        try:
            t = _time_candidate(lambda: paged_attention_pallas(
                q, pools[0], pools[1], bt, kv_len, pools[2], pools[3],
                window=None, tile=tile, m_rows=rows if rows > 1 else 1,
                interpret=_interpret()))
        except Exception as e:
            _LOG.debug("autotune paged tile %s rejected: %s", tile, e)
            continue
        if t < best_t:
            best, best_t = tile, t
    return best


# -------------------------------------------------------- plan resolution

def matmul_plan(kind: str, m: int, k: int, n: int, *, bits: int,
                group_size: int, bm: int = 128, bn: int = 256,
                bk: int = 256):
    """(bm, bn, bk) for one quantized-matmul dispatch, or None (no valid
    tiling: the caller takes the jnp reference). kind is the shape-class
    kernel family: dequant / expert_dequant / w8a8 / expert_w8a8."""
    fallback = fallback_matmul_plan(m, k, n, bits=bits,
                                    group_size=group_size, bm=bm, bn=bn,
                                    bk=bk)
    mode = debug_flags.autotune_mode()
    if mode == "0":
        return fallback
    key = matmul_key(kind, m, k, n, bits, group_size)
    entries = _entries()
    ent = entries.get(key)
    if ent is not None:
        plan = _valid_matmul_plan(ent, k=k, n=n, bits=bits,
                                  group_size=group_size)
        if plan is not None:
            return plan
        _LOG.warning("autotune entry %s = %r violates the tiling "
                     "constraints; ignoring it", key, ent)
    if mode == "1":
        plan = _search_matmul(kind, m, k, n, bits=bits,
                              group_size=group_size, fallback=fallback)
        if plan is not None:
            entries[key] = {"bm": plan[0], "bn": plan[1], "bk": plan[2]}
            _persist(entries)
        return plan
    return fallback


def paged_tile(page_size: int, kv_dtype: str, m_rows: int) -> int:
    """Token tile per page-walk grid step for one paged-attention
    dispatch."""
    fallback = fallback_paged_tile(page_size)
    mode = debug_flags.autotune_mode()
    if mode == "0":
        return fallback
    key = paged_key(page_size, kv_dtype, m_rows)
    entries = _entries()
    ent = entries.get(key)
    if ent is not None:
        tile = _valid_paged_tile(ent, page_size)
        if tile is not None:
            return tile
        _LOG.warning("autotune entry %s = %r violates the tiling "
                     "constraints; ignoring it", key, ent)
    if mode == "1":
        tile = _search_paged(page_size, kv_dtype, m_rows, fallback)
        entries[key] = {"tile": tile}
        _persist(entries)
        return tile
    return fallback
