"""Pallas TPU kernel: true W8A8 / W4A8 int8 MXU matmul (FPTQ-style).

Replaces the fake-quant-then-bf16 detour that `act_bits=8` used to take:
activations are dynamically quantized to int8 with a per-token scale
(`quantize_activation` in core/quant/types.py), packed weights are unpacked
to int8 values in VREGs, and each scale group runs one
int8 x int8 -> int32 MXU dot. The int32 partials are rescaled per group by
the weight scale and accumulated in an f32 VMEM tile; the per-token
activation scale is a rank-1 rescale applied by the caller (kernels/ops.py)
so the kernel's operands stay MXU-shaped int8/uint8 tiles.

Works for any packed bits in {2, 3, 4, 8}: the unpacked values always fit
int8 (|q| <= 127), so W4A8 — the regime FPTQ shows is the practical
sweet spot — uses the exact same kernel as W8A8.

Grid: (M/bm, N/bn, K/bk), K innermost, accumulating across K steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dequant_matmul import (_scale_blockspec, packed_tile_rows,
                                          unpack_tile)


def _w8a8_matmul_kernel(x_ref, qw_ref, scale_ref, o_ref, *, bits: int,
                        bk: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # unpacked values always fit int8 (|q| <= 127), so the MXU dots below
    # run int8 x int8 -> int32 for any packed bits
    w8 = unpack_tile(qw_ref[...], bits, bk).astype(jnp.int8)   # (bk, bn)
    x8 = x_ref[...]                                    # (bm, bk) int8
    s = scale_ref[...]                                 # (gb, bn) f32
    gb = s.shape[0]
    gsb = bk // gb
    acc = o_ref[...]
    for gi in range(gb):
        d = jnp.dot(x8[:, gi * gsb:(gi + 1) * gsb],
                    w8[gi * gsb:(gi + 1) * gsb],
                    preferred_element_type=jnp.int32)
        acc = acc + d.astype(jnp.float32) * s[gi][None, :]
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "bm", "bn",
                                             "bk", "interpret"))
def w8a8_matmul_pallas(xq: jax.Array, qw: jax.Array, scale: jax.Array, *,
                       bits: int, group_size: int, bm: int = 128,
                       bn: int = 128, bk: int = 256,
                       interpret: bool = False) -> jax.Array:
    """xq: (M, K) int8; qw: (packed_rows(K), N) uint8; scale: (G, N).
    Returns (M, N) f32 — *before* the per-token activation rescale."""
    m, k = xq.shape
    n = qw.shape[1]
    g = scale.shape[0]
    bm = min(bm, m)
    bk = min(bk, k)
    bn = min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, bm, bk, bn)
    pk = packed_tile_rows(bk, bits)
    # every K-block must hold whole scale groups: the int32 accumulator is
    # rescaled group-by-group inside the block
    gs = group_size if group_size != -1 else k
    assert (gs >= bk and gs % bk == 0) or (gs < bk and bk % gs == 0)

    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_w8a8_matmul_kernel, bits=bits, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((pk, bn), lambda i, j, kk: (kk, j)),
            _scale_blockspec(group_size, k, g, bk, bn),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(xq, qw, scale.astype(jnp.float32))
