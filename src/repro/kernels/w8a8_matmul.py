"""Pallas TPU kernel: true W8A8 / W4A8 int8 MXU matmul (FPTQ-style).

Replaces the fake-quant-then-bf16 detour that `act_bits=8` used to take:
activations are dynamically quantized to int8 with a per-token scale
(`quantize_activation` in core/quant/types.py), packed weights are unpacked
to int8 values in VREGs, and each scale group runs one
int8 x int8 -> int32 MXU dot. The int32 partials are rescaled per group by
the weight scale and accumulated in an f32 VMEM tile; the per-token
activation scale is a rank-1 rescale applied by the caller (kernels/ops.py)
so the kernel's operands stay MXU-shaped int8/uint8 tiles.

Works for any packed bits in {2, 3, 4, 8}: the unpacked values always fit
int8 (|q| <= 127), so W4A8 — the regime FPTQ shows is the practical
sweet spot — uses the exact same kernel as W8A8.

Template instance: MatmulSpec(epilogue="int8_mxu") from
`kernels/template.py`. Grid: (M/bm, N/bn, K/bk), K innermost,
accumulating across K steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.template import (MatmulSpec, matmul_grid, matmul_in_specs,
                                    matmul_out_spec, make_matmul_kernel)

_SPEC = MatmulSpec("w8a8_matmul", epilogue="int8_mxu")


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "bm", "bn",
                                             "bk", "interpret"))
def w8a8_matmul_pallas(xq: jax.Array, qw: jax.Array, scale: jax.Array, *,
                       bits: int, group_size: int, bm: int = 128,
                       bn: int = 128, bk: int = 256,
                       interpret: bool = False) -> jax.Array:
    """xq: (M, K) int8; qw: (packed_rows(K), N) uint8; scale: (G, N).
    Returns (M, N) f32 — *before* the per-token activation rescale."""
    m, k = xq.shape
    n = qw.shape[1]
    g = scale.shape[0]
    bm = min(bm, m)
    bk = min(bk, k)
    bn = min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, bm, bk, bn)
    # every K-block must hold whole scale groups: the int32 accumulator is
    # rescaled group-by-group inside the block
    gs = group_size if group_size != -1 else k
    assert (gs >= bk and gs % bk == 0) or (gs < bk and bk % gs == 0)

    dims = dict(k=k, g=g, bm=bm, bn=bn, bk=bk)
    return pl.pallas_call(
        make_matmul_kernel(_SPEC, bits=bits, bk=bk),
        grid=matmul_grid(_SPEC, e=1, m=m, n=n, k=k, bm=bm, bn=bn, bk=bk),
        in_specs=matmul_in_specs(_SPEC, bits=bits, group_size=group_size,
                                 **dims),
        out_specs=matmul_out_spec(_SPEC, bm=bm, bn=bn),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(xq, qw, scale.astype(jnp.float32))
