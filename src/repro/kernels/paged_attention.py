"""Pallas TPU kernel: fused paged-attention decode with inline int8-KV dequant.

The serving decode hot path (vLLM/PagedAttention-style): one query token per
slot attends over that slot's paged KV cache. Instead of gathering every
slot's pages into a contiguous ``(S, maxp*page_size, ...)`` HBM view and
running a dense einsum (the PR-1 path, which reads — and for int8 KV
materializes in bf16 — the *provisioned* window regardless of fill), the
kernel walks the block table directly: per (slot, kv-head) grid cell it
streams one page tile per grid step HBM->VMEM, dequantizes int8 K/V inline
from the scale pools (which ride the same block table), and folds the tile
into an online-softmax accumulator held in VMEM scratch. Pages beyond a
slot's fill count — and, under sliding-window attention, pages wholly
behind the window — are never touched: their grid steps are routed to the
scratch page by the index map and skipped by ``pl.when``, so decode HBM
traffic scales with *live* tokens, not ``maxp*page_size`` padding.

Grid: ``(S, KVH, W * tiles_per_page)``, the page-walk axis innermost so the
(m, l, acc) scratch accumulators carry across one cell's pages. The block
table and fill counts are scalar-prefetched (``PrefetchScalarGridSpec``) so
index maps can chase page indices before each tile's DMA is issued.

Verify regime (``m_rows > 1``): self-speculative decoding verifies the
draft's last ``m_rows`` tokens of a slot in one read. The query block grows
to ``m_rows * G`` rows, laid out m-major (row r belongs to verify token
``r // G``, which sits at fill position ``kv_len - m_rows + r // G``), and
the causal/window masks become per-row fill limits. One page walk serves
all ``m_rows`` tokens, so a verify step streams each live KV tile once
instead of ``m_rows`` times. ``m_rows == 1`` reduces exactly to the decode
read — same masks, same accumulator updates, bit-identical output.

Numerics mirror ``kernels/ref.paged_attention_ref`` op-for-op (same walk
order, same f32 accumulation) so interpret-mode runs are bit-comparable
with the jnp reference on CPU.

Tensor parallelism (serve/engine.py shard_map): the kernel runs per shard
on the local kv-head slice of the pools — the grid's KVH axis shrinks to
KVH/tp while the scalar-prefetched block table / fill counts stay
replicated, and per-head online softmax needs no cross-shard collective
(the psum lives at the attention output projection, outside the kernel).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _tile_coords(t: jax.Array, *, page_size: int, tile: int):
    """Grid step t on the page-walk axis -> (page slot w, sub-tile, base pos)."""
    nt = page_size // tile
    w = t // nt
    sub = t % nt
    base = w * page_size + sub * tile
    return w, sub, base


def _tile_live(s, t, bt, kl, *, page_size: int, tile: int,
               window: Optional[int], m_rows: int = 1):
    """Does grid step t hold any live (unmasked) token for slot s?

    Dead tiles are skipped entirely: beyond the fill count, on an unheld
    block-table entry (-1), or — with sliding-window attention — wholly
    behind the window. This predicate is shared by the index maps (route
    the DMA to the scratch page) and the kernel body (skip the compute).

    With ``m_rows`` verify rows the earliest row's window starts at
    ``kl - (m_rows - 1) - window``, so the SWA liveness bound loosens by
    exactly ``m_rows - 1`` tokens (rows that reach further back than a
    given tile mask it per-row inside the kernel).
    """
    w, _, base = _tile_coords(t, page_size=page_size, tile=tile)
    live = (base < kl[s]) & (bt[s, w] >= 0)
    if window is not None:
        live &= (base + tile) > (kl[s] - (m_rows - 1) - window)
    return live


def _page_map(s, h, t, bt, kl, *, page_size: int, tile: int,
              window: Optional[int], m_rows: int = 1):
    """Block index of the K/V page tile for grid cell (s, h, t)."""
    w, sub, _ = _tile_coords(t, page_size=page_size, tile=tile)
    live = _tile_live(s, t, bt, kl, page_size=page_size, tile=tile,
                      window=window, m_rows=m_rows)
    page = jnp.where(live, jnp.maximum(bt[s, w], 0), 0)
    return page, sub, h, 0


def _scale_map(s, h, t, bt, kl, *, page_size: int, tile: int,
               window: Optional[int], m_rows: int = 1):
    w, sub, _ = _tile_coords(t, page_size=page_size, tile=tile)
    live = _tile_live(s, t, bt, kl, page_size=page_size, tile=tile,
                      window=window, m_rows=m_rows)
    page = jnp.where(live, jnp.maximum(bt[s, w], 0), 0)
    return page, sub, h


def _paged_attn_kernel(bt_ref, kl_ref, q_ref, k_ref, v_ref, *rest,
                       page_size: int, tile: int, window: Optional[int],
                       m_rows: int, quant: bool, sm_scale: float,
                       n_steps: int):
    if quant:
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    s_i = pl.program_id(0)
    t_i = pl.program_id(2)
    kl = kl_ref[s_i]
    _, _, base = _tile_coords(t_i, page_size=page_size, tile=tile)
    live = _tile_live(s_i, t_i, bt_ref, kl_ref, page_size=page_size,
                      tile=tile, window=window, m_rows=m_rows)

    @pl.when(t_i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                  # (R, hd)
        k = k_ref[0, :, 0, :]                                # (tile, hd)
        v = v_ref[0, :, 0, :]                                # (tile, hd_v)
        if quant:
            kf = k.astype(jnp.float32) * ks_ref[0, :, 0][:, None]
            vf = v.astype(jnp.float32) * vs_ref[0, :, 0][:, None]
        else:
            kf = k.astype(jnp.float32)
            vf = v.astype(jnp.float32)
        s = jax.lax.dot_general(q, kf, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                                     # (R, tile)
        pos = base + jax.lax.broadcasted_iota(jnp.int32, (1, tile), 1)
        rows = q.shape[0]                                    # R = m_rows * G
        g = rows // m_rows
        # row r verifies the token at fill position kl - m_rows + r//g, so
        # its causal limit is kl - (m_rows - 1 - r//g); at m_rows == 1 this
        # is the scalar kl of the decode read
        r = jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0)
        lim = kl - (m_rows - 1 - r // g)
        valid = pos < lim
        if window is not None:
            valid &= pos > (lim - 1 - window)
        s = jnp.where(valid, s, NEG)
        m_prev = m_scr[...]                                  # (R, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                               # (R, tile)
        # a live tile can sit wholly outside an *early* row's reach
        # (m_rows > 1); that row's m_new is still NEG there, making
        # exp(NEG - NEG) garbage — zero masked columns explicitly. At
        # m_rows == 1 every live tile has a valid column, m_new > NEG, and
        # masked columns underflow to exactly 0.0 anyway: bit-identical.
        p = jnp.where(valid, p, 0.0)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jnp.dot(
            p, vf, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(t_i == n_steps - 1)
    def _finalize():
        # empty slots (kv_len == 0) never accumulate: l stays 0 and the
        # guarded divide emits exact zeros (the engine discards them)
        o_ref[0, 0] = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)


@functools.partial(jax.jit, static_argnames=("window", "tile", "m_rows",
                                             "interpret"))
def paged_attention_pallas(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                           block_table: jax.Array, kv_len: jax.Array,
                           k_scale_pool: Optional[jax.Array] = None,
                           v_scale_pool: Optional[jax.Array] = None, *,
                           window: Optional[int] = None, tile: int = 0,
                           m_rows: int = 1,
                           interpret: bool = False) -> jax.Array:
    """q: (S, KVH, m_rows*G, hd) m-major rows; pools: (P, page, KVH,
    hd[/hd_v]); block_table: (S, W) page ids (-1 = unheld); kv_len: (S,)
    fill counts *including* all m_rows verify tokens (row m sits at
    position kv_len - m_rows + m; at m_rows == 1 q is the current token at
    kv_len - 1). Scale pools (P, page, KVH) mark int8 pools. Returns
    (S, KVH, m_rows*G, hd_v) f32."""
    s, kvh, rows, hd = q.shape
    assert rows % m_rows == 0, (rows, m_rows)
    page_size = k_pool.shape[1]
    hd_v = v_pool.shape[-1]
    w = block_table.shape[1]
    tile = tile or page_size
    assert page_size % tile == 0, (page_size, tile)
    quant = k_scale_pool is not None
    n_steps = w * (page_size // tile)
    sm_scale = 1.0 / (hd ** 0.5)
    geom = dict(page_size=page_size, tile=tile, window=window,
                m_rows=m_rows)

    in_specs = [
        pl.BlockSpec((1, 1, rows, hd),
                     lambda s_, h_, t_, bt, kl: (s_, h_, 0, 0)),
        pl.BlockSpec((1, tile, 1, hd), functools.partial(_page_map, **geom)),
        pl.BlockSpec((1, tile, 1, hd_v), functools.partial(_page_map, **geom)),
    ]
    args = [q, k_pool, v_pool]
    if quant:
        in_specs += [
            pl.BlockSpec((1, tile, 1), functools.partial(_scale_map, **geom)),
            pl.BlockSpec((1, tile, 1), functools.partial(_scale_map, **geom)),
        ]
        args += [k_scale_pool.astype(jnp.float32),
                 v_scale_pool.astype(jnp.float32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(s, kvh, n_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, rows, hd_v),
                               lambda s_, h_, t_, bt, kl: (s_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rows, 1), jnp.float32),      # running max
            pltpu.VMEM((rows, 1), jnp.float32),      # running denominator
            pltpu.VMEM((rows, hd_v), jnp.float32),   # output accumulator
        ],
    )
    kernel = functools.partial(_paged_attn_kernel, quant=quant,
                               sm_scale=sm_scale, n_steps=n_steps, **geom)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s, kvh, rows, hd_v), jnp.float32),
        interpret=interpret,
    )(block_table.astype(jnp.int32), kv_len.astype(jnp.int32), *args)
