"""Pallas TPU kernel: fused paged attention with inline int8-KV dequant.

The serving read hot path (vLLM/PagedAttention-style). Instead of gathering
every slot's pages into a contiguous ``(S, maxp*page_size, ...)`` HBM view
and running a dense einsum (the PR-1 path, which reads — and for int8 KV
materializes in bf16 — the *provisioned* window regardless of fill), the
kernel walks the block table directly: per (slot, kv-head) grid cell it
streams one page tile per grid step HBM->VMEM, dequantizes int8 K/V inline
from the scale pools (which ride the same block table), and folds the tile
into an online-softmax accumulator held in VMEM scratch. Pages beyond a
slot's fill count — and, under sliding-window attention, pages wholly
behind the window — are never touched: their grid steps are routed to the
scratch page by the index map and skipped by ``pl.when``, so HBM traffic
scales with *live* tokens, not ``maxp*page_size`` padding.

Template instance: the page-walk body, liveness predicate, index maps and
``PrefetchScalarGridSpec`` all come from `kernels/template.py`
(:class:`PagedSpec`); only the ``pl.pallas_call`` site lives here. The
grid is ``(S, KVH, W * tiles_per_page)``, the page-walk axis innermost so
the (m, l, acc) scratch accumulators carry across one cell's pages.

Multi-row regime (``m_rows > 1``) serves two callers through one body:
  * spec-decode *verify* — the draft's last ``m_rows`` tokens of a slot
    verified in one read;
  * chunked/suffix *prefill* — a slot's left-padded prefill chunk read
    against its own earlier pages plus any shared prefix pages, replacing
    the gather-oracle prefill path (row j of the padded chunk sits at fill
    position ``kv_len - m_rows + j`` exactly like a verify row, so ragged
    chunk lengths inside one padded bucket need no extra masking — pad
    rows carry positions < 0, write to the scratch page, and read as
    garbage the engine discards).
The query block is ``m_rows * G`` rows, laid out m-major, and the
causal/window masks become per-row fill limits. ``m_rows == 1`` reduces
exactly to the decode read — same masks, same accumulator updates,
bit-identical output.

Numerics mirror ``kernels/ref.paged_attention_ref`` op-for-op (same walk
order, same f32 accumulation) so interpret-mode runs are bit-comparable
with the jnp reference on CPU.

Tensor parallelism (serve/engine.py shard_map): the kernel runs per shard
on the local kv-head slice of the pools — the grid's KVH axis shrinks to
KVH/tp while the scalar-prefetched block table / fill counts stay
replicated, and per-head online softmax needs no cross-shard collective
(the psum lives at the attention output projection, outside the kernel).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.template import (NEG, PagedSpec, make_paged_kernel,
                                    paged_grid_spec)

__all__ = ["paged_attention_pallas", "NEG"]


@functools.partial(jax.jit, static_argnames=("window", "tile", "m_rows",
                                             "interpret"))
def paged_attention_pallas(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                           block_table: jax.Array, kv_len: jax.Array,
                           k_scale_pool: Optional[jax.Array] = None,
                           v_scale_pool: Optional[jax.Array] = None, *,
                           window: Optional[int] = None, tile: int = 0,
                           m_rows: int = 1,
                           interpret: bool = False) -> jax.Array:
    """q: (S, KVH, m_rows*G, hd) m-major rows; pools: (P, page, KVH,
    hd[/hd_v]); block_table: (S, W) page ids (-1 = unheld); kv_len: (S,)
    fill counts *including* all m_rows query tokens (row m sits at
    position kv_len - m_rows + m; at m_rows == 1 q is the current token at
    kv_len - 1). Scale pools (P, page, KVH) mark int8 pools. Returns
    (S, KVH, m_rows*G, hd_v) f32."""
    s, kvh, rows, hd = q.shape
    assert rows % m_rows == 0, (rows, m_rows)
    page_size = k_pool.shape[1]
    hd_v = v_pool.shape[-1]
    w = block_table.shape[1]
    tile = tile or page_size
    quant = k_scale_pool is not None
    n_steps = w * (page_size // tile)
    sm_scale = 1.0 / (hd ** 0.5)
    spec = PagedSpec(page_size=page_size, tile=tile, window=window,
                     m_rows=m_rows, quant=quant)

    args = [q, k_pool, v_pool]
    if quant:
        args += [k_scale_pool.astype(jnp.float32),
                 v_scale_pool.astype(jnp.float32)]
    return pl.pallas_call(
        make_paged_kernel(spec, sm_scale=sm_scale, n_steps=n_steps),
        grid_spec=paged_grid_spec(spec, s=s, kvh=kvh, rows=rows, hd=hd,
                                  hd_v=hd_v, n_steps=n_steps),
        out_shape=jax.ShapeDtypeStruct((s, kvh, rows, hd_v), jnp.float32),
        interpret=interpret,
    )(block_table.astype(jnp.int32), kv_len.astype(jnp.int32), *args)
