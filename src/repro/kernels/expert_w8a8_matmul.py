"""Pallas TPU kernel: expert-batched true W4A8/W8A8 int8 MXU matmul.

Closes the last fake-quant gap: a quantized MoE with `act_bits == 8` used
to fake-quantize activations to a bf16 grid and run the bf16 dequant
kernel — the weights were unpacked to float even though both operands were
already integer-grid. Now the expert capacity blocks are dynamically
quantized to int8 per token (like the dense A8 path) and each expert slab
runs the same int8 x int8 -> int32 MXU epilogue as the dense W8A8 kernel,
with the per-(expert, token) activation scale applied by the caller
(kernels/ops.py).

Template instance: MatmulSpec(expert_dim=True, epilogue="int8_mxu") — the
dense int8 epilogue from `kernels/template.py` lifted over a leading
expert grid axis. Grid: (E, C/bm, N/bn, K/bk), K innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.template import (MatmulSpec, matmul_grid, matmul_in_specs,
                                    matmul_out_spec, make_matmul_kernel)

_SPEC = MatmulSpec("expert_w8a8_matmul", epilogue="int8_mxu",
                   expert_dim=True)


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "bm", "bn",
                                             "bk", "interpret"))
def expert_w8a8_matmul_pallas(xq: jax.Array, qw: jax.Array, scale: jax.Array,
                              *, bits: int, group_size: int, bm: int = 128,
                              bn: int = 128, bk: int = 256,
                              interpret: bool = False) -> jax.Array:
    """xq: (E, C, K) int8; qw: (E, packed_rows(K), N) uint8;
    scale: (E, G, N). Returns (E, C, N) f32 — *before* the per-token
    activation rescale."""
    e, c, k = xq.shape
    n = qw.shape[-1]
    g = scale.shape[-2]
    bm = min(bm, c)
    bk = min(bk, k)
    bn = min(bn, n)
    assert c % bm == 0 and k % bk == 0 and n % bn == 0, (c, k, n, bm, bk, bn)
    gs = group_size if group_size != -1 else k
    assert (gs >= bk and gs % bk == 0) or (gs < bk and bk % gs == 0)

    dims = dict(k=k, g=g, bm=bm, bn=bn, bk=bk)
    return pl.pallas_call(
        make_matmul_kernel(_SPEC, bits=bits, bk=bk),
        grid=matmul_grid(_SPEC, e=e, m=c, n=n, k=k, bm=bm, bn=bn, bk=bk),
        in_specs=matmul_in_specs(_SPEC, bits=bits, group_size=group_size,
                                 **dims),
        out_specs=matmul_out_spec(_SPEC, bm=bm, bn=bn),
        out_shape=jax.ShapeDtypeStruct((e, c, n), jnp.float32),
        interpret=interpret,
    )(xq, qw, scale.astype(jnp.float32))
