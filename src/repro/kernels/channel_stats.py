"""Pallas TPU kernel: fused per-channel sum / sum-of-squares reduction.

Feeds the paper's channel-wise distribution loss (Eq. 2): one pass over the
activation tensor (T, C) accumulates per-channel first and second moments —
bandwidth-bound, so fusing both moments halves HBM traffic vs two jnp
reductions. Grid: (C/bc, T/bt) with T innermost, accumulating into the
(1, bc) output tiles held in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stats_kernel(x_ref, sum_ref, sq_ref):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        sq_ref[...] = jnp.zeros_like(sq_ref)

    xb = x_ref[...].astype(jnp.float32)                # (bt, bc)
    sum_ref[...] += jnp.sum(xb, axis=0, keepdims=True)
    sq_ref[...] += jnp.sum(xb * xb, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("bt", "bc", "interpret"))
def channel_stats_pallas(x: jax.Array, *, bt: int = 256, bc: int = 256,
                         interpret: bool = False):
    """x: (T, C) -> (mean (C,), var (C,)) in f32."""
    t, c = x.shape
    bt = min(bt, t)
    bc = min(bc, c)
    assert t % bt == 0 and c % bc == 0, (t, c, bt, bc)
    grid = (c // bc, t // bt)
    s, sq = pl.pallas_call(
        _stats_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bt, bc), lambda j, i: (i, j))],
        out_specs=[pl.BlockSpec((1, bc), lambda j, i: (0, j)),
                   pl.BlockSpec((1, bc), lambda j, i: (0, j))],
        out_shape=[jax.ShapeDtypeStruct((1, c), jnp.float32),
                   jax.ShapeDtypeStruct((1, c), jnp.float32)],
        interpret=interpret,
    )(x)
    mean = s[0] / t
    var = sq[0] / t - mean * mean
    return mean, jnp.maximum(var, 0.0)
