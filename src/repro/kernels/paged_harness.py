"""Shared fixtures for the paged-attention differential harness.

Used by tests/test_kernel_parity.py and benchmarks/paged_attn_bench.py so
the fused kernel's independent oracle — and the pool/block-table builder it
is evaluated against — live in exactly one place: a geometry or oracle
change cannot leave the benchmark measuring something the parity tests no
longer verify.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import _dequant_kv, _quant_kv, attention_core
from repro.serve.kvcache import contiguous_positions, gather_pages


def build_paged_case(seed: int, s: int, w: int, ps: int, kvh: int, g: int,
                     hd: int, fills, kv_bits: int):
    """Random pools + block tables with per-slot fills 0..w*ps. Empty slots
    hold no pages (block-table row all -1), like a retired/idle slot.
    Returns (q, pools dict, block_table, kv_len)."""
    rng = np.random.default_rng(seed)
    n_pages = 1 + s * w
    perm = rng.permutation(np.arange(1, n_pages))
    bt = np.full((s, w), -1, np.int32)
    nxt = 0
    for si in range(s):
        need = -(-int(fills[si]) // ps)
        bt[si, :need] = perm[nxt:nxt + need]
        nxt += need
    kf = jnp.asarray(rng.normal(size=(n_pages, ps, kvh, hd)), jnp.float32)
    vf = jnp.asarray(rng.normal(size=(n_pages, ps, kvh, hd)), jnp.float32)
    if kv_bits == 8:
        kq, ks = _quant_kv(kf)
        vq, vs = _quant_kv(vf)
        pools = dict(k_pool=kq, v_pool=vq, k_scale_pool=ks, v_scale_pool=vs)
    else:
        pools = dict(k_pool=kf.astype(jnp.bfloat16),
                     v_pool=vf.astype(jnp.bfloat16),
                     k_scale_pool=None, v_scale_pool=None)
    q = jnp.asarray(rng.normal(size=(s, kvh * g, hd)), jnp.float32)
    return q, pools, jnp.asarray(bt), jnp.asarray(fills, dtype=jnp.int32)


def build_verify_case(seed: int, s: int, m: int, w: int, ps: int, kvh: int,
                      g: int, hd: int, fills, kv_bits: int):
    """Verify-shaped variant of `build_paged_case`: q gets M query rows per
    slot (the spec-decode verify tail; row r of slot si sits at fill
    position fills[si] - m + r). Fills must be 0 (idle slot) or >= m.
    Returns (q (S, M, H, hd), pools, block_table, kv_len)."""
    assert all(f == 0 or f >= m for f in fills), fills
    _, pools, bt, kv_len = build_paged_case(seed, s, w, ps, kvh, g, hd,
                                            fills, kv_bits)
    rng = np.random.default_rng(seed + 1)
    q = jnp.asarray(rng.normal(size=(s, m, kvh * g, hd)), jnp.float32)
    return q, pools, bt, kv_len


def build_prefill_case(seed: int, s: int, m: int, w: int, ps: int, kvh: int,
                       g: int, hd: int, fills, kv_bits: int):
    """Chunked-prefill variant of `build_verify_case`: q is a left-padded
    prefill chunk bucket of M rows per slot (row j sits at fill position
    fills[si] - m + j, like a verify row). Unlike verify, fills may be
    *smaller* than M — a short prompt padded into the bucket leaves rows
    with fill limit <= 0, which the kernel defines as exact zeros.
    Returns (q (S, M, H, hd), pools, block_table, kv_len)."""
    _, pools, bt, kv_len = build_paged_case(seed, s, w, ps, kvh, g, hd,
                                            fills, kv_bits)
    rng = np.random.default_rng(seed + 2)
    q = jnp.asarray(rng.normal(size=(s, m, kvh * g, hd)), jnp.float32)
    return q, pools, bt, kv_len


def prefill_oracle(q: jax.Array, pools: dict, bt: jax.Array,
                   kv_len: jax.Array, window: Optional[int],
                   chunk) -> jax.Array:
    """Gather-based oracle for the fused chunked-prefill read: the
    PR-3 chunked path's math — gather the whole context contiguous,
    dequant, dense attention with per-row positions kv_len - M + j. Rows
    outside the chunk (j < M - chunk[si]) and empty slots are garbage
    (all-masked softmax); the kernel defines those as exact zeros —
    compare live chunk rows of live slots only (see `prefill_live_rows`)."""
    del chunk  # masking happens at comparison time; positions are per-row
    return verify_oracle(q, pools, bt, kv_len, window)


def prefill_live_rows(kv_len, chunk, m: int) -> np.ndarray:
    """(S, M) bool: rows the engine actually consumes — slot live and row
    inside the slot's left-padded chunk."""
    kv = np.asarray(kv_len)
    ch = np.asarray(chunk)
    j = np.arange(m)[None, :]
    return (kv[:, None] > 0) & (j >= m - ch[:, None])


def verify_oracle(q: jax.Array, pools: dict, bt: jax.Array,
                  kv_len: jax.Array, window: Optional[int]) -> jax.Array:
    """Gather-based oracle for the verify read: dense attention with the
    per-row causal positions kv_len - M + [0..M). Garbage rows for slots
    with fill < M (all-masked softmax); the kernel defines those as exact
    zeros — compare live slots only."""
    m = q.shape[1]
    if pools["k_scale_pool"] is not None:
        kg = _dequant_kv(gather_pages(pools["k_pool"], bt),
                         gather_pages(pools["k_scale_pool"], bt), q.dtype)
        vg = _dequant_kv(gather_pages(pools["v_pool"], bt),
                         gather_pages(pools["v_scale_pool"], bt), q.dtype)
    else:
        kg = gather_pages(pools["k_pool"], bt)
        vg = gather_pages(pools["v_pool"], bt)
    kv_pos = contiguous_positions(kv_len, kg.shape[1])
    q_pos = (kv_len[:, None] - m + jnp.arange(m, dtype=jnp.int32)[None, :])
    return attention_core(q, kg, vg, q_pos=q_pos, kv_pos=kv_pos,
                          causal=True, window=window, block_kv=1 << 30)


def gather_oracle(q: jax.Array, pools: dict, bt: jax.Array,
                  kv_len: jax.Array, window: Optional[int]) -> jax.Array:
    """The PR-1 decode read: gather pages contiguous, dequant, dense einsum
    (attention_core single-shot) — the fused kernel's independent oracle.
    Note it emits garbage for empty slots (softmax over all-masked rows);
    the fused kernel defines those as exact zeros."""
    if pools["k_scale_pool"] is not None:
        kg = _dequant_kv(gather_pages(pools["k_pool"], bt),
                         gather_pages(pools["k_scale_pool"], bt), q.dtype)
        vg = _dequant_kv(gather_pages(pools["v_pool"], bt),
                         gather_pages(pools["v_scale_pool"], bt), q.dtype)
    else:
        kg = gather_pages(pools["k_pool"], bt)
        vg = gather_pages(pools["v_pool"], bt)
    kv_pos = contiguous_positions(kv_len, kg.shape[1])
    o = attention_core(q[:, None], kg, vg, q_pos=(kv_len - 1)[:, None],
                       kv_pos=kv_pos, causal=True, window=window,
                       block_kv=1 << 30)
    return o[:, 0]
