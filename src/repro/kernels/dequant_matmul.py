"""Pallas TPU kernel: fused low-bit dequantize + matmul.

The deployment hot-spot of weight-only PTQ (the paper's serving story):
y = x @ dequant(qw, scale). Packed uint8 weights stream HBM->VMEM at 1/2
(W4), 3/16 (W3) or 1/4 (W2) of bf16 bytes; sub-byte fields are unpacked
with lane-local shift/mask ops in VREGs, scaled per group, and fed to the
MXU as (bk, bn) bf16 tiles.

Since the kernel-template refactor this module is a spec instance: the
body, grid and block specs come from `kernels/template.py`
(MatmulSpec(epilogue="dequant_bf16")); only the `pl.pallas_call` site —
and with it the RL004 contract identity — lives here. See DESIGN.md
"Kernel templates & autotuning".

Grid: (M/bm, N/bn, K/bk), K innermost; the f32 output tile accumulates
across the K steps in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quant.types import pack_layout
from repro.kernels.template import (MatmulSpec, matmul_grid, matmul_in_specs,
                                    matmul_out_spec, make_matmul_kernel,
                                    packed_tile_rows, scale_blockspec,
                                    scale_tile, unpack_tile)

# re-exported for the kernel modules (and tests) that historically imported
# the shared packed-walk helpers from here; they live in template.py now
__all__ = ["dequant_matmul_pallas", "packed_tile_rows", "scale_tile",
           "unpack_tile", "_scale_blockspec"]
_scale_blockspec = scale_blockspec

_SPEC = MatmulSpec("dequant_matmul", epilogue="dequant_bf16")


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "bm", "bn",
                                             "bk", "interpret"))
def dequant_matmul_pallas(x: jax.Array, qw: jax.Array, scale: jax.Array, *,
                          bits: int, group_size: int, bm: int = 128,
                          bn: int = 128, bk: int = 256,
                          interpret: bool = False) -> jax.Array:
    """x: (M, K); qw: (packed_rows(K), N) uint8; scale: (G, N) -> (M, N) f32."""
    m, k = x.shape
    n = qw.shape[1]
    g = scale.shape[0]
    vpg = pack_layout(bits)[1]
    bm = min(bm, m)
    bk = min(bk, k)
    bn = min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, bm, bk, bn)
    assert bk % vpg == 0

    dims = dict(k=k, g=g, bm=bm, bn=bn, bk=bk)
    return pl.pallas_call(
        make_matmul_kernel(_SPEC, bits=bits, bk=bk),
        grid=matmul_grid(_SPEC, e=1, m=m, n=n, k=k, bm=bm, bn=bn, bk=bk),
        in_specs=matmul_in_specs(_SPEC, bits=bits, group_size=group_size,
                                 **dims),
        out_specs=matmul_out_spec(_SPEC, bm=bm, bn=bn),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, qw, scale.astype(jnp.float32))
