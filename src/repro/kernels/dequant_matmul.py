"""Pallas TPU kernel: fused low-bit dequantize + matmul.

The deployment hot-spot of weight-only PTQ (the paper's serving story):
y = x @ dequant(qw, scale). Packed uint8 weights stream HBM->VMEM at 1/2
(W4), 3/16 (W3) or 1/4 (W2) of bf16 bytes; sub-byte fields are unpacked
with lane-local shift/mask ops in VREGs (packing is along K, so no
cross-lane movement — TPUs have no warp shuffles; W3 first reassembles its
3-byte/8-value little-endian word), scaled per group, and fed to the MXU
as (bk, bn) bf16 tiles via `jnp.dot(..., preferred_element_type=f32)`.

Grid: (M/bm, N/bn, K/bk), K innermost; the f32 output tile accumulates
across the K steps in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quant.types import pack_layout, qmax_for_bits


def packed_tile_rows(bk: int, bits: int) -> int:
    """uint8 rows of a packed tile holding bk values (bk % vpg == 0)."""
    bpg, vpg = pack_layout(bits)
    assert bk % vpg == 0, (bk, bits)
    return bk // vpg * bpg


def unpack_tile(qw: jax.Array, bits: int, bk: int) -> jax.Array:
    """(packed_tile_rows(bk), bn) packed uint8 tile -> (bk, bn) int32 values
    in [-qmax, qmax]. Lane-local shift/mask unpack (packing is along K, rows
    interleave as r*vpg+i), shared by every dequant-style kernel."""
    bpg, vpg = pack_layout(bits)
    qmax = qmax_for_bits(bits)
    bn = qw.shape[-1]
    if (bpg, vpg) == (1, 1):
        u = qw
    else:
        if bpg == 1:
            word = qw
        else:
            # multi-byte group (W3): rebuild the little-endian word first
            grp = qw.astype(jnp.uint32).reshape(bk // vpg, bpg, bn)
            word = grp[:, 0, :]
            for b in range(1, bpg):
                word = word | (grp[:, b, :] << (8 * b))
        mask = (1 << bits) - 1
        parts = [(word >> (bits * i)) & mask for i in range(vpg)]
        u = jnp.stack(parts, axis=1).reshape(bk, bn)
    return u.astype(jnp.int32) - qmax


def scale_tile(q: jax.Array, s: jax.Array, bk: int) -> jax.Array:
    """Apply a (gb, bn) group-scale block to a (bk, bn) int tile -> f32."""
    gb, bn = s.shape
    if gb == 1:
        return q.astype(jnp.float32) * s
    return (q.reshape(gb, bk // gb, bn).astype(jnp.float32) *
            s[:, None, :]).reshape(bk, bn)


def _dequant_matmul_kernel(x_ref, qw_ref, scale_ref, o_ref, *, bits: int,
                           group_size: int, bk: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = unpack_tile(qw_ref[...], bits, bk)             # (bk, bn) int32
    w = scale_tile(q, scale_ref[...], bk)              # (bk, bn) f32
    x = x_ref[...]                                     # (bm, bk)
    o_ref[...] += jnp.dot(x.astype(jnp.bfloat16),
                          w.astype(jnp.bfloat16),
                          preferred_element_type=jnp.float32)


def _scale_blockspec(group_size: int, k: int, g: int, bk: int, bn: int):
    if g == 1:
        return pl.BlockSpec((1, bn), lambda i, j, kk: (0, j))
    gs = k // g
    if gs >= bk:
        assert gs % bk == 0
        return pl.BlockSpec((1, bn), lambda i, j, kk: (kk * bk // gs, j))
    assert bk % gs == 0
    gpb = bk // gs
    # index_map is in BLOCK units: kv-block kk covers scale rows
    # [kk*gpb, (kk+1)*gpb) == block row kk of a (gpb, bn) block
    return pl.BlockSpec((gpb, bn), lambda i, j, kk: (kk, j))


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "bm", "bn",
                                             "bk", "interpret"))
def dequant_matmul_pallas(x: jax.Array, qw: jax.Array, scale: jax.Array, *,
                          bits: int, group_size: int, bm: int = 128,
                          bn: int = 128, bk: int = 256,
                          interpret: bool = False) -> jax.Array:
    """x: (M, K); qw: (packed_rows(K), N) uint8; scale: (G, N) -> (M, N) f32."""
    m, k = x.shape
    n = qw.shape[1]
    g = scale.shape[0]
    vpg = pack_layout(bits)[1]
    bm = min(bm, m)
    bk = min(bk, k)
    bn = min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, bm, bk, bn)
    assert bk % vpg == 0

    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_dequant_matmul_kernel, bits=bits,
                               group_size=group_size, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((packed_tile_rows(bk, bits), bn),
                         lambda i, j, kk: (kk, j)),
            _scale_blockspec(group_size, k, g, bk, bn),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, qw, scale.astype(jnp.float32))
