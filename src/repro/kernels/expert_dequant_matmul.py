"""Pallas TPU kernel: expert-batched fused low-bit dequantize + matmul.

The MoE serving hot-spot: every expert's packed weight slab is consumed
directly from the stacked (E, packed_rows(K), N) layout, so a quantized Mixtral/
DeepSeek/Jamba MoE block never materializes a float (E, K, N) expert stack
in HBM (the former `dequantize`-then-einsum path did exactly that, and at
W4 the float stack is 4x the packed bytes).

Grid: (E, M/bm, N/bn, K/bk) with K innermost; each (e, i, j) output tile
accumulates across K steps in VMEM, and the expert dimension is the
outermost loop so one expert's packed tiles stream HBM->VMEM while the
previous expert's tail is still in flight. Per-tile math (unpack nibbles
lane-locally, scale per group, bf16 MXU dot) is identical to the dense
kernel in dequant_matmul.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dequant_matmul import (_scale_blockspec, packed_tile_rows,
                                          scale_tile, unpack_tile)


def _expert_dequant_matmul_kernel(x_ref, qw_ref, scale_ref, o_ref, *,
                                  bits: int, group_size: int, bk: int):
    k_step = pl.program_id(3)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = unpack_tile(qw_ref[0], bits, bk)               # (bk, bn) int32
    w = scale_tile(q, scale_ref[0], bk)                # (bk, bn) f32
    x = x_ref[0]                                       # (bm, bk)
    o_ref[0] += jnp.dot(x.astype(jnp.bfloat16),
                        w.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)


def _expert_scale_blockspec(group_size: int, k: int, g: int, bk: int, bn: int):
    """The dense `_scale_blockspec` lifted over the leading expert grid
    axis: same (G, N) indexing, stacked (E, G, N) layout."""
    s = _scale_blockspec(group_size, k, g, bk, bn)
    return pl.BlockSpec((1,) + tuple(s.block_shape),
                        lambda e, i, j, kk: (e,) + tuple(s.index_map(i, j, kk)))


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "bm", "bn",
                                             "bk", "interpret"))
def expert_dequant_matmul_pallas(x: jax.Array, qw: jax.Array,
                                 scale: jax.Array, *, bits: int,
                                 group_size: int, bm: int = 128,
                                 bn: int = 128, bk: int = 256,
                                 interpret: bool = False) -> jax.Array:
    """x: (E, M, K); qw: (E, packed_rows(K), N) uint8; scale: (E, G, N).
    Returns (E, M, N) f32."""
    e, m, k = x.shape
    n = qw.shape[-1]
    g = scale.shape[-2]
    bm = min(bm, m)
    bk = min(bk, k)
    bn = min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, bm, bk, bn)
    pk = packed_tile_rows(bk, bits)

    grid = (e, m // bm, n // bn, k // bk)
    kernel = functools.partial(_expert_dequant_matmul_kernel, bits=bits,
                               group_size=group_size, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda e_, i, j, kk: (e_, i, kk)),
            pl.BlockSpec((1, pk, bn), lambda e_, i, j, kk: (e_, kk, j)),
            _expert_scale_blockspec(group_size, k, g, bk, bn),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda e_, i, j, kk: (e_, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, m, n), jnp.float32),
        interpret=interpret,
    )(x, qw, scale.astype(jnp.float32))
