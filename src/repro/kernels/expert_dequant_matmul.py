"""Pallas TPU kernel: expert-batched fused low-bit dequantize + matmul.

The MoE serving hot-spot: every expert's packed weight slab is consumed
directly from the stacked (E, packed_rows(K), N) layout, so a quantized
Mixtral/DeepSeek/Jamba MoE block never materializes a float (E, K, N)
expert stack in HBM (the former `dequantize`-then-einsum path did exactly
that, and at W4 the float stack is 4x the packed bytes).

Template instance: MatmulSpec(expert_dim=True, epilogue="dequant_bf16") —
the dense dequant body and block specs from `kernels/template.py`, lifted
over a leading expert grid axis. Grid: (E, M/bm, N/bn, K/bk), K innermost;
each (e, i, j) output tile accumulates across K steps in VMEM, and the
expert dimension is the outermost loop so one expert's packed tiles stream
HBM->VMEM while the previous expert's tail is still in flight.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.template import (MatmulSpec, matmul_grid, matmul_in_specs,
                                    matmul_out_spec, make_matmul_kernel)

_SPEC = MatmulSpec("expert_dequant_matmul", epilogue="dequant_bf16",
                   expert_dim=True)


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "bm", "bn",
                                             "bk", "interpret"))
def expert_dequant_matmul_pallas(x: jax.Array, qw: jax.Array,
                                 scale: jax.Array, *, bits: int,
                                 group_size: int, bm: int = 128,
                                 bn: int = 128, bk: int = 256,
                                 interpret: bool = False) -> jax.Array:
    """x: (E, M, K); qw: (E, packed_rows(K), N) uint8; scale: (E, G, N).
    Returns (E, M, N) f32."""
    e, m, k = x.shape
    n = qw.shape[-1]
    g = scale.shape[-2]
    bm = min(bm, m)
    bk = min(bk, k)
    bn = min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, bm, bk, bn)

    dims = dict(k=k, g=g, bm=bm, bn=bn, bk=bk)
    return pl.pallas_call(
        make_matmul_kernel(_SPEC, bits=bits, bk=bk),
        grid=matmul_grid(_SPEC, e=e, m=m, n=n, k=k, bm=bm, bn=bn, bk=bk),
        in_specs=matmul_in_specs(_SPEC, bits=bits, group_size=group_size,
                                 **dims),
        out_specs=matmul_out_spec(_SPEC, bm=bm, bn=bn),
        out_shape=jax.ShapeDtypeStruct((e, m, n), jnp.float32),
        interpret=interpret,
    )(x, qw, scale.astype(jnp.float32))
