"""Pallas TPU kernel: RTN quantize + pack (offline/deploy-time path).

Rounds a (K, N) float weight tile to the symmetric grid and packs
offset-binary values along K in `pack_layout(bits)` groups (one byte for
2/4/8-bit, a 3-byte/8-value word for 3-bit), writing packed uint8 tiles.
Keeps the whole quantize->pack in VMEM (no int staging in HBM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quant.types import pack_layout, qmax_for_bits
from repro.kernels.template import packed_tile_rows, scale_blockspec


def _quantize_kernel(w_ref, scale_ref, o_ref, *, bits: int, bk: int):
    w = w_ref[...].astype(jnp.float32)                 # (bk, bn)
    s = scale_ref[...]                                 # (gb, bn)
    gb, bn = s.shape
    qmax = qmax_for_bits(bits)
    ws = (w.reshape(gb, bk // gb, bn) / s[:, None, :]).reshape(bk, bn)
    q = jnp.clip(jnp.round(ws), -qmax, qmax).astype(jnp.int32)
    bpg, vpg = pack_layout(bits)
    if (bpg, vpg) == (1, 1):
        o_ref[...] = (q + qmax).astype(jnp.uint8)
        return
    u = (q + qmax).astype(jnp.uint32).reshape(bk // vpg, vpg, bn)
    word = jnp.zeros((bk // vpg, bn), jnp.uint32)
    for i in range(vpg):
        word = word | (u[:, i, :] << (bits * i))
    if bpg == 1:
        o_ref[...] = word.astype(jnp.uint8)
    else:
        # multi-byte group (W3): emit the word little-endian along K
        out = jnp.stack([(word >> (8 * b)) & 0xFF for b in range(bpg)],
                        axis=1)
        o_ref[...] = out.reshape(bk // vpg * bpg, bn).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "bk", "bn",
                                             "interpret"))
def quantize_pack_pallas(w: jax.Array, scale: jax.Array, *, bits: int,
                         group_size: int, bk: int = 256, bn: int = 256,
                         interpret: bool = False) -> jax.Array:
    """w: (K, N); scale: (G, N). Returns packed uint8 (packed_rows(K), N)."""
    k, n = w.shape
    g = scale.shape[0]
    vpg = pack_layout(bits)[1]
    bk = min(bk, k)
    bn = min(bn, n)
    assert k % bk == 0 and n % bn == 0 and bk % vpg == 0

    # reuse the dequant scale indexing, adding a dummy leading grid dim
    sspec = scale_blockspec(group_size, k, g, bk, bn)
    sspec2 = pl.BlockSpec(sspec.block_shape,
                          lambda kk, j: sspec.index_map(0, j, kk))

    kernel = functools.partial(_quantize_kernel, bits=bits, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=(k // bk, n // bn),
        in_specs=[pl.BlockSpec((bk, bn), lambda kk, j: (kk, j)), sspec2],
        out_specs=pl.BlockSpec((packed_tile_rows(bk, bits), bn),
                               lambda kk, j: (kk, j)),
        out_shape=jax.ShapeDtypeStruct((packed_tile_rows(k, bits), n),
                                       jnp.uint8),
        interpret=interpret,
    )(w, scale.astype(jnp.float32))
