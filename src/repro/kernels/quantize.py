"""Pallas TPU kernel: RTN quantize + pack (offline/deploy-time path).

Rounds a (K, N) float weight tile to the symmetric grid and packs `vpb`
offset-binary values per byte along K, writing (bk/vpb, bn) uint8 tiles.
Keeps the whole quantize->pack in VMEM (no int staging in HBM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quant.types import qmax_for_bits, values_per_byte
from repro.kernels.dequant_matmul import _scale_blockspec


def _quantize_kernel(w_ref, scale_ref, o_ref, *, bits: int, bk: int):
    w = w_ref[...].astype(jnp.float32)                 # (bk, bn)
    s = scale_ref[...]                                 # (gb, bn)
    gb, bn = s.shape
    qmax = qmax_for_bits(bits)
    ws = (w.reshape(gb, bk // gb, bn) / s[:, None, :]).reshape(bk, bn)
    q = jnp.clip(jnp.round(ws), -qmax, qmax).astype(jnp.int32)
    u = (q + qmax).astype(jnp.uint8)
    vpb = values_per_byte(bits)
    if vpb == 1:
        o_ref[...] = u
    else:
        u = u.reshape(bk // vpb, vpb, bn)
        acc = jnp.zeros((bk // vpb, bn), jnp.uint8)
        for i in range(vpb):
            acc = acc | (u[:, i, :] << (bits * i))
        o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "bk", "bn",
                                             "interpret"))
def quantize_pack_pallas(w: jax.Array, scale: jax.Array, *, bits: int,
                         group_size: int, bk: int = 256, bn: int = 256,
                         interpret: bool = False) -> jax.Array:
    """w: (K, N); scale: (G, N). Returns packed uint8 (K/vpb, N)."""
    k, n = w.shape
    g = scale.shape[0]
    vpb = values_per_byte(bits)
    bk = min(bk, k)
    bn = min(bn, n)
    assert k % bk == 0 and n % bn == 0 and bk % vpb == 0

    # reuse the dequant scale indexing, adding a dummy leading grid dim
    sspec = _scale_blockspec(group_size, k, g, bk, bn)
    sspec2 = pl.BlockSpec(sspec.block_shape,
                          lambda kk, j: sspec.index_map(0, j, kk))

    kernel = functools.partial(_quantize_kernel, bits=bits, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=(k // bk, n // bn),
        in_specs=[pl.BlockSpec((bk, bn), lambda kk, j: (kk, j)), sspec2],
        out_specs=pl.BlockSpec((bk // vpb, bn), lambda kk, j: (kk, j)),
        out_shape=jax.ShapeDtypeStruct((k // vpb, n), jnp.uint8),
        interpret=interpret,
    )(w, scale.astype(jnp.float32))
