"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant.types import (QuantizedTensor, compute_scales,
                                    dequantize, pack, quantize_values, unpack)


def dequant_matmul_ref(x: jax.Array, qw: jax.Array, scale: jax.Array, *,
                       bits: int, group_size: int, k: int) -> jax.Array:
    qt = QuantizedTensor(qw, scale, bits, group_size, (k, qw.shape[1]))
    w = dequantize(qt, jnp.float32)
    return jnp.dot(x.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32)


def expert_dequant_matmul_ref(x: jax.Array, qw: jax.Array, scale: jax.Array,
                              *, bits: int, group_size: int,
                              k: int) -> jax.Array:
    """x: (E, M, K) @ packed (E, K/vpb, N) -> (E, M, N) f32."""
    e = x.shape[0]
    qt = QuantizedTensor(qw, scale, bits, group_size, (e, k, qw.shape[-1]))
    w = dequantize(qt, jnp.float32)
    return jnp.einsum("emk,ekn->emn", x.astype(jnp.bfloat16),
                      w.astype(jnp.bfloat16),
                      preferred_element_type=jnp.float32)


def w8a8_matmul_ref(xq: jax.Array, qw: jax.Array, scale: jax.Array, *,
                    bits: int, group_size: int, k: int) -> jax.Array:
    """Exact int32 oracle for the W8A8 kernel (pre activation-rescale).
    xq: (M, K) int8; qw: (K/vpb, N); scale: (G, N). Returns (M, N) f32."""
    m = xq.shape[0]
    n = qw.shape[1]
    q = unpack(qw, bits, k)                            # (K, N) int32
    g = scale.shape[0]
    acc = jnp.einsum("mgk,gkn->mgn",
                     xq.astype(jnp.int32).reshape(m, g, k // g),
                     q.reshape(g, k // g, n),
                     preferred_element_type=jnp.int32)
    return jnp.sum(acc.astype(jnp.float32) *
                   scale.astype(jnp.float32)[None], axis=1)


def channel_stats_ref(x: jax.Array):
    xf = x.astype(jnp.float32)
    return jnp.mean(xf, axis=0), jnp.var(xf, axis=0)


def quantize_pack_ref(w: jax.Array, scale: jax.Array, *, bits: int) -> jax.Array:
    q = quantize_values(w.astype(jnp.float32), scale.astype(jnp.float32), bits)
    return pack(q, bits)
