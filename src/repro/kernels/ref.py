"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant.types import (QuantizedTensor, compute_scales,
                                    dequantize, pack, quantize_values)


def dequant_matmul_ref(x: jax.Array, qw: jax.Array, scale: jax.Array, *,
                       bits: int, group_size: int, k: int) -> jax.Array:
    qt = QuantizedTensor(qw, scale, bits, group_size, (k, qw.shape[1]))
    w = dequantize(qt, jnp.float32)
    return jnp.dot(x.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32)


def channel_stats_ref(x: jax.Array):
    xf = x.astype(jnp.float32)
    return jnp.mean(xf, axis=0), jnp.var(xf, axis=0)


def quantize_pack_ref(w: jax.Array, scale: jax.Array, *, bits: int) -> jax.Array:
    q = quantize_values(w.astype(jnp.float32), scale.astype(jnp.float32), bits)
    return pack(q, bits)
