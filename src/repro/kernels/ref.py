"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant.types import (QuantizedTensor, compute_scales,
                                    dequantize, pack, quantize_values, unpack)


def dequant_matmul_ref(x: jax.Array, qw: jax.Array, scale: jax.Array, *,
                       bits: int, group_size: int, k: int) -> jax.Array:
    qt = QuantizedTensor(qw, scale, bits, group_size, (k, qw.shape[1]))
    w = dequantize(qt, jnp.float32)
    return jnp.dot(x.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32)


def expert_dequant_matmul_ref(x: jax.Array, qw: jax.Array, scale: jax.Array,
                              *, bits: int, group_size: int,
                              k: int) -> jax.Array:
    """x: (E, M, K) @ packed (E, pk, N) -> (E, M, N) f32 (pk = packed
    rows, see types.pack_layout)."""
    e = x.shape[0]
    qt = QuantizedTensor(qw, scale, bits, group_size, (e, k, qw.shape[-1]))
    w = dequantize(qt, jnp.float32)
    return jnp.einsum("emk,ekn->emn", x.astype(jnp.bfloat16),
                      w.astype(jnp.bfloat16),
                      preferred_element_type=jnp.float32)


def w8a8_matmul_ref(xq: jax.Array, qw: jax.Array, scale: jax.Array, *,
                    bits: int, group_size: int, k: int) -> jax.Array:
    """Exact int32 oracle for the W8A8 kernel (pre activation-rescale).
    xq: (M, K) int8; qw: (pk, N) packed rows; scale: (G, N). Returns
    (M, N) f32."""
    m = xq.shape[0]
    n = qw.shape[1]
    q = unpack(qw, bits, k)                            # (K, N) int32
    g = scale.shape[0]
    acc = jnp.einsum("mgk,gkn->mgn",
                     xq.astype(jnp.int32).reshape(m, g, k // g),
                     q.reshape(g, k // g, n),
                     preferred_element_type=jnp.int32)
    return jnp.sum(acc.astype(jnp.float32) *
                   scale.astype(jnp.float32)[None], axis=1)


def expert_w8a8_matmul_ref(xq: jax.Array, qw: jax.Array, scale: jax.Array, *,
                           bits: int, group_size: int, k: int) -> jax.Array:
    """Expert-stacked W8A8 oracle: xq (E, C, K) int8 @ packed (E, pk, N)
    with scale (E, G, N). Returns (E, C, N) f32 (pre activation-rescale),
    one `w8a8_matmul_ref` per expert."""
    return jax.vmap(lambda x2, w2, s2: w8a8_matmul_ref(
        x2, w2, s2, bits=bits, group_size=group_size, k=k))(xq, qw, scale)


def paged_attention_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                        block_table: jax.Array, kv_len: jax.Array,
                        k_scale_pool: Optional[jax.Array] = None,
                        v_scale_pool: Optional[jax.Array] = None, *,
                        window: Optional[int] = None,
                        tile: int = 0, m_rows: int = 1) -> jax.Array:
    """jnp mirror of kernels/paged_attention.py — same page-walk order, same
    per-tile online-softmax updates, same f32 accumulation, so interpret-mode
    kernel runs are bit-comparable on CPU. Dead tiles (beyond fill, unheld
    pages, wholly behind the sliding window) leave the accumulators
    untouched, exactly like the kernel's ``pl.when`` skip.

    q: (S, KVH, m_rows*G, hd) m-major rows (verify regime: row r belongs to
    the token at fill position kv_len - m_rows + r//G; decode is
    m_rows == 1); pools: (P, page, KVH, hd[/hd_v]); block_table: (S, W);
    kv_len: (S,). Returns (S, KVH, m_rows*G, hd_v) f32."""
    s, kvh, rows, hd = q.shape
    assert rows % m_rows == 0, (rows, m_rows)
    g = rows // m_rows
    page_size = k_pool.shape[1]
    hd_v = v_pool.shape[-1]
    w = block_table.shape[1]
    tile = tile or page_size
    assert page_size % tile == 0, (page_size, tile)
    nt = page_size // tile
    n_steps = w * nt
    quant = k_scale_pool is not None
    sm_scale = 1.0 / (hd ** 0.5)
    neg = -1e30

    def cell(qgh, bt_row, kl, h_idx):
        """One (slot, kv-head) grid cell: walk the row's page tiles."""
        qf = qgh.astype(jnp.float32)                         # (R, hd)

        def step(carry, t):
            m, l, acc = carry
            wi, sub, base = t // nt, t % nt, (t // nt) * page_size + \
                (t % nt) * tile
            live = (base < kl) & (bt_row[wi] >= 0)
            if window is not None:
                live &= (base + tile) > (kl - (m_rows - 1) - window)
            page = jnp.where(live, jnp.maximum(bt_row[wi], 0), 0)
            k = jax.lax.dynamic_slice(
                k_pool, (page, sub * tile, h_idx, 0),
                (1, tile, 1, hd))[0, :, 0, :]                # (tile, hd)
            v = jax.lax.dynamic_slice(
                v_pool, (page, sub * tile, h_idx, 0),
                (1, tile, 1, hd_v))[0, :, 0, :]              # (tile, hd_v)
            if quant:
                ks = jax.lax.dynamic_slice(
                    k_scale_pool, (page, sub * tile, h_idx),
                    (1, tile, 1))[0, :, 0].astype(jnp.float32)
                vs = jax.lax.dynamic_slice(
                    v_scale_pool, (page, sub * tile, h_idx),
                    (1, tile, 1))[0, :, 0].astype(jnp.float32)
                kf = k.astype(jnp.float32) * ks[:, None]
                vf = v.astype(jnp.float32) * vs[:, None]
            else:
                kf = k.astype(jnp.float32)
                vf = v.astype(jnp.float32)
            sc = jax.lax.dot_general(qf, kf, (((1,), (1,)), ((), ())),
                                     preferred_element_type=jnp.float32)
            sc = sc * sm_scale                               # (R, tile)
            pos = base + jax.lax.broadcasted_iota(jnp.int32, (1, tile), 1)
            # per-row causal fill limit (scalar kl at m_rows == 1)
            r = jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0)
            lim = kl - (m_rows - 1 - r // g)
            valid = pos < lim
            if window is not None:
                valid &= pos > (lim - 1 - window)
            sc = jnp.where(valid, sc, neg)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1, keepdims=True))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(sc - m_new)
            p = jnp.where(valid, p, 0.0)
            l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = acc * corr + jnp.dot(p, vf,
                                           preferred_element_type=jnp.float32)
            keep = lambda new, old: jnp.where(live, new, old)
            return (keep(m_new, m), keep(l_new, l), keep(acc_new, acc)), None

        init = (jnp.full((rows, 1), neg, jnp.float32),
                jnp.zeros((rows, 1), jnp.float32),
                jnp.zeros((rows, hd_v), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(step, init,
                                      jnp.arange(n_steps, dtype=jnp.int32))
        return acc / jnp.maximum(l, 1e-30)

    heads = jnp.arange(kvh, dtype=jnp.int32)
    per_slot = jax.vmap(cell, in_axes=(0, None, None, 0))    # over kv-heads
    return jax.vmap(per_slot, in_axes=(0, 0, 0, None))(
        q, block_table.astype(jnp.int32), kv_len.astype(jnp.int32), heads)


def paged_attention_prefill_ref(q: jax.Array, k_pool: jax.Array,
                                v_pool: jax.Array, block_table: jax.Array,
                                kv_len: jax.Array,
                                k_scale_pool: Optional[jax.Array] = None,
                                v_scale_pool: Optional[jax.Array] = None, *,
                                window: Optional[int] = None,
                                tile: int = 0, m_rows: int = 1) -> jax.Array:
    """Named oracle for the fused chunked/suffix-prefill read. The walk is
    identical to the verify regime of :func:`paged_attention_ref` — a
    prefill chunk's left-padded row j sits at fill position
    ``kv_len - m_rows + j`` exactly like a verify row — so this simply
    delegates; the separate name keeps the KERNEL_CONTRACTS mapping and
    fallback counters per dispatch site."""
    return paged_attention_ref(q, k_pool, v_pool, block_table, kv_len,
                               k_scale_pool, v_scale_pool, window=window,
                               tile=tile, m_rows=m_rows)


def channel_stats_ref(x: jax.Array):
    xf = x.astype(jnp.float32)
    return jnp.mean(xf, axis=0), jnp.var(xf, axis=0)


def quantize_pack_ref(w: jax.Array, scale: jax.Array, *, bits: int) -> jax.Array:
    q = quantize_values(w.astype(jnp.float32), scale.astype(jnp.float32), bits)
    return pack(q, bits)
