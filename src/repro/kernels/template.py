"""Declarative Pallas kernel templates shared by every quantized kernel.

The four serving kernels (dense dequant matmul, expert-batched dequant,
W8A8/W4A8 int8 MXU, paged attention) share one structure: walk a packed
operand per `pack_layout`, rescale per scale group, fold each tile into an
accumulator (plain f32 add for matmuls, online softmax for attention).
This module is the single place that structure lives. A kernel module
declares a spec — :class:`MatmulSpec` (grid shape, packed-walk params,
epilogue) or :class:`PagedSpec` (page geometry, window, verify rows) — and
asks the builders here for the kernel body + block specs; only the
`pl.pallas_call` site stays in the kernel module (so the RL004 contract
registry keeps one wrapper-per-kernel granularity).

The generated bodies perform the *identical op sequence* the handwritten
kernels used — same unpack shifts, same dot/accumulate order, same mask
and softmax updates — so interpret-mode runs stay bit-comparable with the
jnp references in `kernels/ref.py` and the parity matrix pins the refactor.

`TEMPLATE_VERSION` is a content hash of this file; the autotune cache
(kernels/autotune.py) embeds it in its on-disk format so tile configs
measured against an older template generation are ignored, not replayed.

Epilogues:
  * "dequant_bf16": unpack -> per-group f32 scale -> bf16 MXU dot,
    f32 accumulate (weight-only serving path).
  * "int8_mxu": unpack to int8 values -> one int8 x int8 -> int32 MXU dot
    per scale group -> f32 rescale-accumulate (FPTQ-style W4A8/W8A8; the
    per-token activation scale is applied by the caller).
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import pathlib
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant.types import pack_layout, qmax_for_bits

NEG = -1e30


def _template_version() -> str:
    src = pathlib.Path(__file__.replace(".pyc", ".py")).read_bytes()
    return hashlib.sha256(src).hexdigest()[:16]


TEMPLATE_VERSION = _template_version()


# ------------------------------------------------------- packed-operand walk

def packed_tile_rows(bk: int, bits: int) -> int:
    """uint8 rows of a packed tile holding bk values (bk % vpg == 0)."""
    bpg, vpg = pack_layout(bits)
    assert bk % vpg == 0, (bk, bits)
    return bk // vpg * bpg


def unpack_tile(qw: jax.Array, bits: int, bk: int) -> jax.Array:
    """(packed_tile_rows(bk), bn) packed uint8 tile -> (bk, bn) int32 values
    in [-qmax, qmax]. Lane-local shift/mask unpack (packing is along K, rows
    interleave as r*vpg+i), shared by every dequant-style kernel."""
    bpg, vpg = pack_layout(bits)
    qmax = qmax_for_bits(bits)
    bn = qw.shape[-1]
    if (bpg, vpg) == (1, 1):
        u = qw
    else:
        if bpg == 1:
            word = qw
        else:
            # multi-byte group (W3): rebuild the little-endian word first
            grp = qw.astype(jnp.uint32).reshape(bk // vpg, bpg, bn)
            word = grp[:, 0, :]
            for b in range(1, bpg):
                word = word | (grp[:, b, :] << (8 * b))
        mask = (1 << bits) - 1
        parts = [(word >> (bits * i)) & mask for i in range(vpg)]
        u = jnp.stack(parts, axis=1).reshape(bk, bn)
    return u.astype(jnp.int32) - qmax


def scale_tile(q: jax.Array, s: jax.Array, bk: int) -> jax.Array:
    """Apply a (gb, bn) group-scale block to a (bk, bn) int tile -> f32."""
    gb, bn = s.shape
    if gb == 1:
        return q.astype(jnp.float32) * s
    return (q.reshape(gb, bk // gb, bn).astype(jnp.float32) *
            s[:, None, :]).reshape(bk, bn)


def scale_blockspec(group_size: int, k: int, g: int, bk: int, bn: int):
    """BlockSpec walking a (G, N) scale tensor alongside (bk, bn) K tiles:
    one broadcast row (per-channel), whole groups per block, or whole
    blocks per group."""
    if g == 1:
        return pl.BlockSpec((1, bn), lambda i, j, kk: (0, j))
    gs = k // g
    if gs >= bk:
        assert gs % bk == 0
        return pl.BlockSpec((1, bn), lambda i, j, kk: (kk * bk // gs, j))
    assert bk % gs == 0
    gpb = bk // gs
    # index_map is in BLOCK units: kv-block kk covers scale rows
    # [kk*gpb, (kk+1)*gpb) == block row kk of a (gpb, bn) block
    return pl.BlockSpec((gpb, bn), lambda i, j, kk: (kk, j))


def lift_expert(s: pl.BlockSpec) -> pl.BlockSpec:
    """Lift a dense (i, j, kk)-indexed BlockSpec over a leading expert grid
    axis: same block indexing, stacked (E, ...) layout."""
    return pl.BlockSpec(
        (1,) + tuple(s.block_shape),
        lambda e, i, j, kk: (e,) + tuple(s.index_map(i, j, kk)))


# --------------------------------------------------------- matmul templates

@dataclasses.dataclass(frozen=True)
class MatmulSpec:
    """One packed-matmul kernel variant.

    expert_dim: prepend an expert axis to the grid — operands arrive as
    stacked (E, ...) slabs and every dense block spec is `lift_expert`ed.
    epilogue: accumulate stage (see module docstring).
    """
    name: str
    epilogue: str = "dequant_bf16"
    expert_dim: bool = False

    def __post_init__(self):
        assert self.epilogue in ("dequant_bf16", "int8_mxu"), self.epilogue


def make_matmul_kernel(spec: MatmulSpec, *, bits: int, bk: int):
    """Kernel body for `spec`: zero-init on the first K step, unpack the
    packed tile, run the epilogue's dot(s), accumulate into the output
    tile. Op-for-op identical to the former handwritten bodies."""
    k_axis = 3 if spec.expert_dim else 2

    def kernel(x_ref, qw_ref, scale_ref, o_ref):
        k_step = pl.program_id(k_axis)

        @pl.when(k_step == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        if spec.expert_dim:
            x, qw, s = x_ref[0], qw_ref[0], scale_ref[0]
        else:
            x, qw, s = x_ref[...], qw_ref[...], scale_ref[...]
        q = unpack_tile(qw, bits, bk)                  # (bk, bn) int32
        if spec.epilogue == "dequant_bf16":
            w = scale_tile(q, s, bk)                   # (bk, bn) f32
            acc = jnp.dot(x.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                          preferred_element_type=jnp.float32)
            if spec.expert_dim:
                o_ref[0] += acc
            else:
                o_ref[...] += acc
        else:  # int8_mxu
            # unpacked values always fit int8 (|q| <= 127), so the MXU dots
            # below run int8 x int8 -> int32 for any packed bits
            w8 = q.astype(jnp.int8)                    # (bk, bn)
            gb = s.shape[0]
            gsb = bk // gb
            acc = o_ref[0] if spec.expert_dim else o_ref[...]
            for gi in range(gb):
                d = jnp.dot(x[:, gi * gsb:(gi + 1) * gsb],
                            w8[gi * gsb:(gi + 1) * gsb],
                            preferred_element_type=jnp.int32)
                acc = acc + d.astype(jnp.float32) * s[gi][None, :]
            if spec.expert_dim:
                o_ref[0] = acc
            else:
                o_ref[...] = acc

    kernel.__name__ = f"_{spec.name}_kernel"
    return kernel


def matmul_grid(spec: MatmulSpec, *, e: int, m: int, n: int, k: int,
                bm: int, bn: int, bk: int):
    base = (m // bm, n // bn, k // bk)
    return (e,) + base if spec.expert_dim else base


def matmul_in_specs(spec: MatmulSpec, *, bits: int, group_size: int, k: int,
                    g: int, bm: int, bn: int, bk: int):
    """[x, packed qw, scale] block specs for the (M, N, K) grid walk."""
    specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((packed_tile_rows(bk, bits), bn),
                     lambda i, j, kk: (kk, j)),
        scale_blockspec(group_size, k, g, bk, bn),
    ]
    if spec.expert_dim:
        specs = [lift_expert(s) for s in specs]
    return specs


def matmul_out_spec(spec: MatmulSpec, *, bm: int, bn: int):
    o = pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))
    return lift_expert(o) if spec.expert_dim else o


# ------------------------------------------------- paged-attention template

@dataclasses.dataclass(frozen=True)
class PagedSpec:
    """One paged-attention page-walk variant: decode (m_rows == 1), verify
    and chunked prefill (m_rows > 1) are the same walk with per-row causal
    fill limits; `quant` adds the int8 scale-pool operands."""
    page_size: int
    tile: int
    window: Optional[int]
    m_rows: int
    quant: bool

    def __post_init__(self):
        assert self.page_size % self.tile == 0, (self.page_size, self.tile)


def _tile_coords(t: jax.Array, *, page_size: int, tile: int):
    """Grid step t on the page-walk axis -> (page slot w, sub-tile, base pos)."""
    nt = page_size // tile
    w = t // nt
    sub = t % nt
    base = w * page_size + sub * tile
    return w, sub, base


def tile_live(spec: PagedSpec, s, t, bt, kl):
    """Does grid step t hold any live (unmasked) token for slot s?

    Dead tiles are skipped entirely: beyond the fill count, on an unheld
    block-table entry (-1), or — with sliding-window attention — wholly
    behind the window. This predicate is shared by the index maps (route
    the DMA to the scratch page) and the kernel body (skip the compute).

    With ``m_rows`` query rows the earliest row's window starts at
    ``kl - (m_rows - 1) - window``, so the SWA liveness bound loosens by
    exactly ``m_rows - 1`` tokens (rows that reach further back than a
    given tile mask it per-row inside the kernel).
    """
    w, _, base = _tile_coords(t, page_size=spec.page_size, tile=spec.tile)
    live = (base < kl[s]) & (bt[s, w] >= 0)
    if spec.window is not None:
        live &= (base + spec.tile) > (kl[s] - (spec.m_rows - 1) - spec.window)
    return live


def page_map(spec: PagedSpec):
    """Index map for the K/V pool tiles of grid cell (s, h, t)."""
    def index(s, h, t, bt, kl):
        w, sub, _ = _tile_coords(t, page_size=spec.page_size, tile=spec.tile)
        live = tile_live(spec, s, t, bt, kl)
        page = jnp.where(live, jnp.maximum(bt[s, w], 0), 0)
        return page, sub, h, 0
    return index


def scale_map(spec: PagedSpec):
    def index(s, h, t, bt, kl):
        w, sub, _ = _tile_coords(t, page_size=spec.page_size, tile=spec.tile)
        live = tile_live(spec, s, t, bt, kl)
        page = jnp.where(live, jnp.maximum(bt[s, w], 0), 0)
        return page, sub, h
    return index


def make_paged_kernel(spec: PagedSpec, *, sm_scale: float, n_steps: int):
    """Online-softmax page-walk body: init scratch on the first step, fold
    each live KV tile into the (m, l, acc) accumulators with per-row causal
    fill limits, finalize with the guarded divide on the last step."""
    page_size, tile, window, m_rows = (spec.page_size, spec.tile,
                                       spec.window, spec.m_rows)

    def kernel(bt_ref, kl_ref, q_ref, k_ref, v_ref, *rest):
        if spec.quant:
            ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
        else:
            o_ref, m_scr, l_scr, acc_scr = rest
        s_i = pl.program_id(0)
        t_i = pl.program_id(2)
        kl = kl_ref[s_i]
        _, _, base = _tile_coords(t_i, page_size=page_size, tile=tile)
        live = tile_live(spec, s_i, t_i, bt_ref, kl_ref)

        @pl.when(t_i == 0)
        def _init():
            m_scr[...] = jnp.full_like(m_scr, NEG)
            l_scr[...] = jnp.zeros_like(l_scr)
            acc_scr[...] = jnp.zeros_like(acc_scr)

        @pl.when(live)
        def _compute():
            q = q_ref[0, 0].astype(jnp.float32)              # (R, hd)
            k = k_ref[0, :, 0, :]                            # (tile, hd)
            v = v_ref[0, :, 0, :]                            # (tile, hd_v)
            if spec.quant:
                kf = k.astype(jnp.float32) * ks_ref[0, :, 0][:, None]
                vf = v.astype(jnp.float32) * vs_ref[0, :, 0][:, None]
            else:
                kf = k.astype(jnp.float32)
                vf = v.astype(jnp.float32)
            s = jax.lax.dot_general(q, kf, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
            s = s * sm_scale                                 # (R, tile)
            pos = base + jax.lax.broadcasted_iota(jnp.int32, (1, tile), 1)
            rows = q.shape[0]                                # R = m_rows * G
            g = rows // m_rows
            # row r holds the token at fill position kl - m_rows + r//g, so
            # its causal limit is kl - (m_rows - 1 - r//g); at m_rows == 1
            # this is the scalar kl of the decode read
            r = jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0)
            lim = kl - (m_rows - 1 - r // g)
            valid = pos < lim
            if window is not None:
                valid &= pos > (lim - 1 - window)
            s = jnp.where(valid, s, NEG)
            m_prev = m_scr[...]                              # (R, 1)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
            corr = jnp.exp(m_prev - m_new)
            p = jnp.exp(s - m_new)                           # (R, tile)
            # a live tile can sit wholly outside an *early* row's reach
            # (m_rows > 1); that row's m_new is still NEG there, making
            # exp(NEG - NEG) garbage — zero masked columns explicitly. At
            # m_rows == 1 every live tile has a valid column, m_new > NEG,
            # and masked columns underflow to exactly 0.0 anyway:
            # bit-identical.
            p = jnp.where(valid, p, 0.0)
            l_scr[...] = (l_scr[...] * corr +
                          jnp.sum(p, axis=-1, keepdims=True))
            acc_scr[...] = acc_scr[...] * corr + jnp.dot(
                p, vf, preferred_element_type=jnp.float32)
            m_scr[...] = m_new

        @pl.when(t_i == n_steps - 1)
        def _finalize():
            # empty slots (kv_len == 0) never accumulate: l stays 0 and the
            # guarded divide emits exact zeros (the engine discards them)
            o_ref[0, 0] = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)

    return kernel


def paged_grid_spec(spec: PagedSpec, *, s: int, kvh: int, rows: int, hd: int,
                    hd_v: int, n_steps: int):
    """PrefetchScalarGridSpec for the (S, KVH, page-walk) grid: block table
    + fill counts scalar-prefetched so index maps chase page ids before
    each tile's DMA, (m, l, acc) accumulators in VMEM scratch."""
    tile = spec.tile
    in_specs = [
        pl.BlockSpec((1, 1, rows, hd),
                     lambda s_, h_, t_, bt, kl: (s_, h_, 0, 0)),
        pl.BlockSpec((1, tile, 1, hd), page_map(spec)),
        pl.BlockSpec((1, tile, 1, hd_v), page_map(spec)),
    ]
    if spec.quant:
        in_specs += [
            pl.BlockSpec((1, tile, 1), scale_map(spec)),
            pl.BlockSpec((1, tile, 1), scale_map(spec)),
        ]
    return pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(s, kvh, n_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, rows, hd_v),
                               lambda s_, h_, t_, bt, kl: (s_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rows, 1), jnp.float32),      # running max
            pltpu.VMEM((rows, 1), jnp.float32),      # running denominator
            pltpu.VMEM((rows, hd_v), jnp.float32),   # output accumulator
        ],
    )
