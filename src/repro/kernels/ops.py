"""Jitted public wrappers around the Pallas kernels.

Dispatch: real TPU -> compiled Pallas; CPU -> `interpret=True` when forced
via REPRO_DEQUANT_IMPL=pallas (tests), else the jnp reference (same math,
fast on CPU). Handles token-dim padding and block-size selection so callers
never deal with tiling constraints.

Block-size selection has two regimes (see DESIGN.md "Quantized serving
fast paths"): prefill-shaped calls (M > 8) use square-ish tiles, while
decode-shaped skinny-M calls (M <= 8 — one token per serving slot) keep
bm at the minimal 8-row tile and widen bn/bk instead, so per-step decode
streams more packed weight bytes per grid step instead of padding tokens
up to prefill tiles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant.types import (QuantizedTensor, pack_layout,
                                    quantize_activation)
from repro.debug_flags import dequant_impl, strict_kernels
from repro.kernels import ref
from repro.kernels.channel_stats import channel_stats_pallas
from repro.kernels.dequant_matmul import dequant_matmul_pallas
from repro.kernels.expert_dequant_matmul import expert_dequant_matmul_pallas
from repro.kernels.paged_attention import paged_attention_pallas
from repro.kernels.quantize import quantize_pack_pallas
from repro.kernels.w8a8_matmul import w8a8_matmul_pallas

# Kernel-contract registry: every `pl.pallas_call` site in the tree maps
# to exactly one entry here, keyed by the wrapper function that contains
# it, declaring the jnp reference oracle it is differentially tested
# against and the parity test(s) that do the comparison. repro-lint RL004
# cross-checks all three directions (site without entry, stale entry,
# oracle/parity id that doesn't resolve), so an unregistered — i.e.
# unverified — kernel cannot land. Kept a *pure literal* so the linter can
# ast.literal_eval it without importing (and tracing) kernel code.
KERNEL_CONTRACTS = {
    "dequant_matmul_pallas": {
        "module": "repro.kernels.dequant_matmul",
        "ref": "repro.kernels.ref:dequant_matmul_ref",
        "parity": ("tests/test_kernel_parity.py::test_dense_parity",),
    },
    "expert_dequant_matmul_pallas": {
        "module": "repro.kernels.expert_dequant_matmul",
        "ref": "repro.kernels.ref:expert_dequant_matmul_ref",
        "parity": ("tests/test_kernel_parity.py::test_expert_parity",),
    },
    "w8a8_matmul_pallas": {
        "module": "repro.kernels.w8a8_matmul",
        "ref": "repro.kernels.ref:w8a8_matmul_ref",
        "parity": ("tests/test_kernel_parity.py::test_w8a8_parity",),
    },
    "quantize_pack_pallas": {
        "module": "repro.kernels.quantize",
        "ref": "repro.kernels.ref:quantize_pack_ref",
        "parity": ("tests/test_kernels.py::test_quantize_pack_vs_ref",),
    },
    "channel_stats_pallas": {
        "module": "repro.kernels.channel_stats",
        "ref": "repro.kernels.ref:channel_stats_ref",
        "parity": ("tests/test_kernels.py::test_channel_stats_vs_ref",),
    },
    "paged_attention_pallas": {
        "module": "repro.kernels.paged_attention",
        "ref": "repro.kernels.ref:paged_attention_ref",
        "parity": (
            "tests/test_kernel_parity.py::test_paged_attention_parity",
            "tests/test_kernel_parity.py::test_paged_attention_verify_parity",
        ),
    },
}

# decode-shaped tiles: minimal token rows, wide weight tiles
_SKINNY_M = 8
_SKINNY_BN = 512
_SKINNY_BK = 512

# paged-attention read-width regime: the page walk streams one KV tile per
# grid step; small pages ride whole (the common serving geometry — page_size
# 16/32 — is far below the cap), oversized pages split into <=256-token
# sub-tiles so a step's K/V/score working set stays VMEM-resident instead of
# scaling with page_size (the read-width analogue of the skinny-M rules:
# fix the token-tile height, let the page *walk* — not the tile — absorb
# the width)
_PAGE_TILE = 256


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pick_block(dim: int, target: int) -> int:
    if dim <= target:
        return dim
    b = target
    while dim % b != 0:
        b //= 2
        if b < 8:
            return dim  # fall back to a single block
    return b


def _pick_bk(k: int, gs: int, vpg: int, target: int) -> int | None:
    """K block size that divides K, packs whole byte groups (vpg values per
    `pack_layout` group), and tiles the scale groups (whole groups per
    block, or whole blocks per group). Returns None when no such block
    exists — e.g. a group size with a large odd factor — so callers can
    fall back to the jnp reference instead of spinning this shrink loop
    down to a mod-by-zero."""
    bk = _pick_block(k, target)
    while k % bk != 0 or (gs < bk and bk % gs != 0) or \
            (gs >= bk and gs % bk != 0) or bk % vpg != 0:
        bk //= 2  # halving can break K-divisibility; re-checked above
        if bk < max(vpg, 1):
            return None
    return bk


def _matmul_blocks(m: int, bm: int, bn: int, bk: int):
    """Prefill-vs-decode tile regime: skinny token counts trade token-dim
    padding for wider weight tiles."""
    if m <= _SKINNY_M:
        return _SKINNY_M, max(bn, _SKINNY_BN), max(bk, _SKINNY_BK)
    return bm, bn, bk


def _plan_tiles(m: int, k: int, n: int, qt: QuantizedTensor,
                bm: int, bn: int, bk: int):
    """Shared dispatch planning for every quantized-matmul wrapper: tile
    regime by token count, then concrete (bm, bn, bk) blocks. Returns None
    when K admits no valid block — callers fall back to the jnp ref."""
    gs = qt.group_size if qt.group_size != -1 else k
    vpg = pack_layout(qt.bits)[1]
    bm, bn, bk = _matmul_blocks(m, bm, bn, bk)
    bk_ = _pick_bk(k, gs, vpg, bk)
    if bk_ is None:
        return None
    return _pick_block(max(m, 8), bm), _pick_block(n, bn), bk_


def dequant_matmul(x: jax.Array, qt: QuantizedTensor, *, out_dtype=None,
                   bm: int = 128, bn: int = 256, bk: int = 256) -> jax.Array:
    """x: (M, K) @ packed (K, N) -> (M, N). Pads M to the tile size."""
    out_dtype = out_dtype or x.dtype
    m, k = x.shape
    plan = _plan_tiles(m, k, qt.n, qt, bm, bn, bk)
    if plan is None:
        y = ref.dequant_matmul_ref(x, qt.qw, qt.scale, bits=qt.bits,
                                   group_size=qt.group_size, k=k)
        return y.astype(out_dtype)
    bm_, bn_, bk_ = plan
    pad_m = (-m) % bm_
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
    y = dequant_matmul_pallas(x, qt.qw, qt.scale, bits=qt.bits,
                              group_size=qt.group_size, bm=bm_, bn=bn_,
                              bk=bk_, interpret=_interpret())
    if pad_m:
        y = y[:m]
    return y.astype(out_dtype)


def expert_dequant_matmul(x: jax.Array, qt: QuantizedTensor, *,
                          out_dtype=None, bm: int = 128, bn: int = 256,
                          bk: int = 256) -> jax.Array:
    """Expert-batched x: (E, C, K) @ packed (E, K, N) -> (E, C, N).

    Consumes the stacked packed layout directly — no float (E, K, N)
    expert stack is ever materialized. Pads the capacity dim to the tile
    size; decode-shaped capacities (C <= 8) take the skinny tiles."""
    out_dtype = out_dtype or x.dtype
    e, c, k = x.shape
    plan = _plan_tiles(c, k, qt.n, qt, bm, bn, bk)
    if plan is None:
        y = ref.expert_dequant_matmul_ref(x, qt.qw, qt.scale, bits=qt.bits,
                                          group_size=qt.group_size, k=k)
        return y.astype(out_dtype)
    bm_, bn_, bk_ = plan
    pad_c = (-c) % bm_
    if pad_c:
        x = jnp.pad(x, ((0, 0), (0, pad_c), (0, 0)))
    y = expert_dequant_matmul_pallas(x, qt.qw, qt.scale, bits=qt.bits,
                                     group_size=qt.group_size, bm=bm_,
                                     bn=bn_, bk=bk_, interpret=_interpret())
    if pad_c:
        y = y[:, :c]
    return y.astype(out_dtype)


def w8a8_matmul(x: jax.Array, qt: QuantizedTensor, *, out_dtype=None,
                bm: int = 128, bn: int = 256, bk: int = 256,
                amax_axis: str | None = None) -> jax.Array:
    """True A8 path: per-token int8 activation quantize, int8 x int8 -> int32
    MXU matmul, per-(token, channel-group) rescale. x: (M, K) -> (M, N).
    `amax_axis`: shard axis the K dim is split over (TP row-parallel) — the
    activation amax is pmax'ed so every shard uses the single-device grid."""
    out_dtype = out_dtype or x.dtype
    m, k = x.shape
    xq, xs = quantize_activation(x, 8, axis_name=amax_axis)  # int8, (M,1) f32
    plan = _plan_tiles(m, k, qt.n, qt, bm, bn, bk)
    if plan is None:
        y = ref.w8a8_matmul_ref(xq, qt.qw, qt.scale, bits=qt.bits,
                                group_size=qt.group_size, k=k)
        return (y * xs).astype(out_dtype)
    bm_, bn_, bk_ = plan
    pad_m = (-m) % bm_
    if pad_m:
        xq = jnp.pad(xq, ((0, pad_m), (0, 0)))
    y = w8a8_matmul_pallas(xq, qt.qw, qt.scale, bits=qt.bits,
                           group_size=qt.group_size, bm=bm_, bn=bn_,
                           bk=bk_, interpret=_interpret())
    if pad_m:
        y = y[:m]
    return (y * xs).astype(out_dtype)


def _paged_tile(page_size: int) -> int:
    """Token tile per page-walk step (read-width regime, see _PAGE_TILE)."""
    return _pick_block(page_size, _PAGE_TILE)


# trace-time pallas -> reference fallbacks, per op name. A kernel that fails
# to *lower* (bad tile regime on an exotic shape, backend gap) raises while
# the jit is being traced — serving can survive that by building the
# reference path into the same computation instead. The counter makes the
# degradation observable; REPRO_STRICT_KERNELS=1 (set in the kernel-parity
# CI job) disables the net so a broken kernel fails loudly there, never
# silently passing parity via its own oracle.
DISPATCH_FALLBACKS: dict[str, int] = {"paged_attention": 0,
                                      "paged_attention_verify": 0}


def _kernel_fallback(name: str, kernel_fn, ref_fn):
    try:
        return kernel_fn()
    except Exception:
        if strict_kernels():
            raise
        DISPATCH_FALLBACKS[name] += 1
        return ref_fn()


def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    block_table: jax.Array, kv_len: jax.Array, *,
                    k_scale_pool=None, v_scale_pool=None, window=None,
                    out_dtype=None) -> jax.Array:
    """Fused paged-attention decode: q (S, H, hd) one token per slot against
    the slot's block-table pages, int8 K/V dequantized inline from the scale
    pools. Returns (S, H, hd_v) without materializing the gathered
    (S, maxp*page_size, ...) KV view. CPU default runs the jnp page-walk
    reference (same math); REPRO_DEQUANT_IMPL=pallas lowers the kernel in
    interpret mode; TPU compiles it.

    Under tensor-parallel serving this op is invoked *per shard* inside the
    engine's shard_map: the pools arrive with the shard-local kv-head slice
    (KVH/tp) while block tables and fill counts are replicated scalars
    (scalar-prefetch inputs are never sharded), so the grid simply shrinks
    along its KVH axis — attention is head-independent and the kernel needs
    no TP awareness. H here is the shard-local head count; the GQA group
    width H/KVH is TP-invariant because legal widths divide n_kv_heads."""
    s, h, hd = q.shape
    kvh = k_pool.shape[2]
    qg = q.reshape(s, kvh, h // kvh, hd)
    tile = _paged_tile(k_pool.shape[1])
    if _interpret() and dequant_impl() != "pallas":
        o = ref.paged_attention_ref(qg, k_pool, v_pool, block_table, kv_len,
                                    k_scale_pool, v_scale_pool,
                                    window=window, tile=tile)
    else:
        o = _kernel_fallback(
            "paged_attention",
            lambda: paged_attention_pallas(
                qg, k_pool, v_pool, block_table, kv_len, k_scale_pool,
                v_scale_pool, window=window, tile=tile,
                interpret=_interpret()),
            lambda: ref.paged_attention_ref(
                qg, k_pool, v_pool, block_table, kv_len, k_scale_pool,
                v_scale_pool, window=window, tile=tile))
    return o.reshape(s, h, v_pool.shape[-1]).astype(out_dtype or q.dtype)


def paged_attention_verify(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, block_table: jax.Array,
                           kv_len: jax.Array, *, k_scale_pool=None,
                           v_scale_pool=None, window=None,
                           out_dtype=None) -> jax.Array:
    """Fused verify read for self-speculative decoding: q (S, M, H, hd) —
    the M draft-proposed tail tokens of each slot — against the slot's
    pages, with per-row causal fill masks (row m attends through position
    kv_len - M + m). kv_len counts the fill *including* all M tokens.
    Returns (S, M, H, hd_v). One page walk serves all M rows, so the
    verify forward streams each live KV tile once instead of M times.
    M == 1 is exactly the decode read (`paged_attention`)."""
    s, m, h, hd = q.shape
    kvh = k_pool.shape[2]
    g = h // kvh
    # rows go m-major within each kv head: (S, KVH, M*G, hd)
    qg = q.reshape(s, m, kvh, g, hd).transpose(0, 2, 1, 3, 4)
    qg = qg.reshape(s, kvh, m * g, hd)
    tile = _paged_tile(k_pool.shape[1])
    if _interpret() and dequant_impl() != "pallas":
        o = ref.paged_attention_ref(qg, k_pool, v_pool, block_table, kv_len,
                                    k_scale_pool, v_scale_pool,
                                    window=window, tile=tile, m_rows=m)
    else:
        o = _kernel_fallback(
            "paged_attention_verify",
            lambda: paged_attention_pallas(
                qg, k_pool, v_pool, block_table, kv_len, k_scale_pool,
                v_scale_pool, window=window, tile=tile, m_rows=m,
                interpret=_interpret()),
            lambda: ref.paged_attention_ref(
                qg, k_pool, v_pool, block_table, kv_len, k_scale_pool,
                v_scale_pool, window=window, tile=tile, m_rows=m))
    hd_v = v_pool.shape[-1]
    o = o.reshape(s, kvh, m, g, hd_v).transpose(0, 2, 1, 3, 4)
    return o.reshape(s, m, h, hd_v).astype(out_dtype or q.dtype)


def channel_stats(x: jax.Array):
    """x: (..., C) -> per-channel (mean, var)."""
    x2 = x.reshape(-1, x.shape[-1])
    t, c = x2.shape
    if _interpret() and dequant_impl() != "pallas":
        return ref.channel_stats_ref(x2)
    bt = _pick_block(t, 256)
    bc = _pick_block(c, 256)
    return channel_stats_pallas(x2, bt=bt, bc=bc, interpret=_interpret())


def quantize_pack(w: jax.Array, scale: jax.Array, *, bits: int,
                  group_size: int) -> jax.Array:
    k, n = w.shape
    if _interpret() and dequant_impl() != "pallas":
        return ref.quantize_pack_ref(w, scale, bits=bits)
    gs = group_size if group_size != -1 else k
    vpg = pack_layout(bits)[1]
    bk = _pick_bk(k, gs, vpg, 256)
    if bk is None:  # no valid tiling (e.g. group_size with odd factors)
        return ref.quantize_pack_ref(w, scale, bits=bits)
    bn = _pick_block(n, 256)
    return quantize_pack_pallas(w, scale, bits=bits, group_size=group_size,
                                bk=bk, bn=bn, interpret=_interpret())
