"""Jitted public wrappers around the Pallas kernels.

Dispatch: real TPU -> compiled Pallas; CPU -> `interpret=True` when forced
via REPRO_DEQUANT_IMPL=pallas (tests), else the jnp reference (same math,
fast on CPU). Handles token-dim padding and block-size selection so callers
never deal with tiling constraints.

Block-size selection consults `kernels/autotune.py` per shape class: a
measured JSON config cache when warm, else the deterministic fallback
table (the former hand heuristics — see DESIGN.md "Kernel templates &
autotuning"). The table has two regimes: prefill-shaped calls (M > 8) use
square-ish tiles, while decode-shaped skinny-M calls (M <= 8 — one token
per serving slot) keep bm at the minimal 8-row tile and widen bn/bk
instead, so per-step decode streams more packed weight bytes per grid
step instead of padding tokens up to prefill tiles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant.types import (QuantizedTensor, pack_layout,
                                    quantize_activation)
from repro.debug_flags import dequant_impl, strict_kernels
from repro.kernels import autotune, ref
from repro.kernels.channel_stats import channel_stats_pallas
from repro.kernels.dequant_matmul import dequant_matmul_pallas
from repro.kernels.expert_dequant_matmul import expert_dequant_matmul_pallas
from repro.kernels.expert_w8a8_matmul import expert_w8a8_matmul_pallas
from repro.kernels.paged_attention import paged_attention_pallas
from repro.kernels.quantize import quantize_pack_pallas
from repro.kernels.w8a8_matmul import w8a8_matmul_pallas

# Kernel-contract registry: every `pl.pallas_call` site in the tree maps
# to exactly one entry here, keyed by the wrapper function that contains
# it, declaring the jnp reference oracle it is differentially tested
# against and the parity test(s) that do the comparison. repro-lint RL004
# cross-checks all three directions (site without entry, stale entry,
# oracle/parity id that doesn't resolve), so an unregistered — i.e.
# unverified — kernel cannot land. Kept a *pure literal* so the linter can
# ast.literal_eval it without importing (and tracing) kernel code.
KERNEL_CONTRACTS = {
    "dequant_matmul_pallas": {
        "module": "repro.kernels.dequant_matmul",
        "ref": "repro.kernels.ref:dequant_matmul_ref",
        "parity": ("tests/test_kernel_parity.py::test_dense_parity",),
    },
    "expert_dequant_matmul_pallas": {
        "module": "repro.kernels.expert_dequant_matmul",
        "ref": "repro.kernels.ref:expert_dequant_matmul_ref",
        "parity": ("tests/test_kernel_parity.py::test_expert_parity",),
    },
    "w8a8_matmul_pallas": {
        "module": "repro.kernels.w8a8_matmul",
        "ref": "repro.kernels.ref:w8a8_matmul_ref",
        "parity": ("tests/test_kernel_parity.py::test_w8a8_parity",),
    },
    "expert_w8a8_matmul_pallas": {
        "module": "repro.kernels.expert_w8a8_matmul",
        "ref": "repro.kernels.ref:expert_w8a8_matmul_ref",
        "parity": ("tests/test_kernel_parity.py::test_expert_w8a8_parity",),
    },
    "quantize_pack_pallas": {
        "module": "repro.kernels.quantize",
        "ref": "repro.kernels.ref:quantize_pack_ref",
        "parity": ("tests/test_kernels.py::test_quantize_pack_vs_ref",),
    },
    "channel_stats_pallas": {
        "module": "repro.kernels.channel_stats",
        "ref": "repro.kernels.ref:channel_stats_ref",
        "parity": ("tests/test_kernels.py::test_channel_stats_vs_ref",),
    },
    "paged_attention_pallas": {
        "module": "repro.kernels.paged_attention",
        "ref": "repro.kernels.ref:paged_attention_ref",
        "parity": (
            "tests/test_kernel_parity.py::test_paged_attention_parity",
            "tests/test_kernel_parity.py::test_paged_attention_verify_parity",
            "tests/test_kernel_parity.py::test_paged_attention_prefill_parity",
        ),
    },
}

# tile heuristics live in kernels/autotune.py now (they are its
# deterministic fallback table); the aliases keep this module the single
# import point the dispatch-regime unit tests pin against
_pick_block = autotune.pick_block
_pick_bk = autotune.pick_bk
_matmul_blocks = autotune.matmul_blocks
_paged_tile = autotune.fallback_paged_tile


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _plan_tiles(m: int, k: int, n: int, qt: QuantizedTensor,
                bm: int, bn: int, bk: int, *, kind: str):
    """Shared dispatch planning for every quantized-matmul wrapper:
    autotuned plan for the shape class when the config cache is warm, else
    the deterministic table. Returns None when K admits no valid block —
    callers fall back to the jnp ref."""
    return autotune.matmul_plan(kind, m, k, n, bits=qt.bits,
                                group_size=qt.group_size, bm=bm, bn=bn,
                                bk=bk)


def dequant_matmul(x: jax.Array, qt: QuantizedTensor, *, out_dtype=None,
                   bm: int = 128, bn: int = 256, bk: int = 256) -> jax.Array:
    """x: (M, K) @ packed (K, N) -> (M, N). Pads M to the tile size."""
    out_dtype = out_dtype or x.dtype
    m, k = x.shape
    plan = _plan_tiles(m, k, qt.n, qt, bm, bn, bk, kind="dequant")
    if plan is None:
        y = ref.dequant_matmul_ref(x, qt.qw, qt.scale, bits=qt.bits,
                                   group_size=qt.group_size, k=k)
        return y.astype(out_dtype)
    bm_, bn_, bk_ = plan
    pad_m = (-m) % bm_
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
    y = dequant_matmul_pallas(x, qt.qw, qt.scale, bits=qt.bits,
                              group_size=qt.group_size, bm=bm_, bn=bn_,
                              bk=bk_, interpret=_interpret())
    if pad_m:
        y = y[:m]
    return y.astype(out_dtype)


def expert_dequant_matmul(x: jax.Array, qt: QuantizedTensor, *,
                          out_dtype=None, bm: int = 128, bn: int = 256,
                          bk: int = 256) -> jax.Array:
    """Expert-batched x: (E, C, K) @ packed (E, K, N) -> (E, C, N).

    Consumes the stacked packed layout directly — no float (E, K, N)
    expert stack is ever materialized. Pads the capacity dim to the tile
    size; decode-shaped capacities (C <= 8) take the skinny tiles."""
    out_dtype = out_dtype or x.dtype
    e, c, k = x.shape
    plan = _plan_tiles(c, k, qt.n, qt, bm, bn, bk, kind="expert_dequant")
    if plan is None:
        y = ref.expert_dequant_matmul_ref(x, qt.qw, qt.scale, bits=qt.bits,
                                          group_size=qt.group_size, k=k)
        return y.astype(out_dtype)
    bm_, bn_, bk_ = plan
    pad_c = (-c) % bm_
    if pad_c:
        x = jnp.pad(x, ((0, 0), (0, pad_c), (0, 0)))
    y = expert_dequant_matmul_pallas(x, qt.qw, qt.scale, bits=qt.bits,
                                     group_size=qt.group_size, bm=bm_,
                                     bn=bn_, bk=bk_, interpret=_interpret())
    if pad_c:
        y = y[:, :c]
    return y.astype(out_dtype)


def w8a8_matmul(x: jax.Array, qt: QuantizedTensor, *, out_dtype=None,
                bm: int = 128, bn: int = 256, bk: int = 256,
                amax_axis: str | None = None) -> jax.Array:
    """True A8 path: per-token int8 activation quantize, int8 x int8 -> int32
    MXU matmul, per-(token, channel-group) rescale. x: (M, K) -> (M, N).
    `amax_axis`: shard axis the K dim is split over (TP row-parallel) — the
    activation amax is pmax'ed so every shard uses the single-device grid."""
    out_dtype = out_dtype or x.dtype
    m, k = x.shape
    xq, xs = quantize_activation(x, 8, axis_name=amax_axis)  # int8, (M,1) f32
    plan = _plan_tiles(m, k, qt.n, qt, bm, bn, bk, kind="w8a8")
    if plan is None:
        y = ref.w8a8_matmul_ref(xq, qt.qw, qt.scale, bits=qt.bits,
                                group_size=qt.group_size, k=k)
        return (y * xs).astype(out_dtype)
    bm_, bn_, bk_ = plan
    pad_m = (-m) % bm_
    if pad_m:
        xq = jnp.pad(xq, ((0, pad_m), (0, 0)))
    y = w8a8_matmul_pallas(xq, qt.qw, qt.scale, bits=qt.bits,
                           group_size=qt.group_size, bm=bm_, bn=bn_,
                           bk=bk_, interpret=_interpret())
    if pad_m:
        y = y[:m]
    return (y * xs).astype(out_dtype)


def expert_w8a8_matmul(x: jax.Array, qt: QuantizedTensor, *, out_dtype=None,
                       bm: int = 128, bn: int = 256, bk: int = 256,
                       amax_axis: str | None = None) -> jax.Array:
    """Expert-batched true A8 path: per-token int8 activation quantize over
    the flattened (E*C) token dim, int8 x int8 -> int32 MXU matmul per
    expert slab, per-(expert, token) rescale. x: (E, C, K) -> (E, C, N).
    Replaces the fake-quant + bf16-dequant detour the MoE act_bits=8 path
    used to take."""
    out_dtype = out_dtype or x.dtype
    e, c, k = x.shape
    xq, xs = quantize_activation(x.reshape(e * c, k), 8, axis_name=amax_axis)
    xq = xq.reshape(e, c, k)
    xs = xs.reshape(e, c, 1)
    plan = _plan_tiles(c, k, qt.n, qt, bm, bn, bk, kind="expert_w8a8")
    if plan is None:
        y = ref.expert_w8a8_matmul_ref(xq, qt.qw, qt.scale, bits=qt.bits,
                                       group_size=qt.group_size, k=k)
        return (y * xs).astype(out_dtype)
    bm_, bn_, bk_ = plan
    pad_c = (-c) % bm_
    if pad_c:
        xq = jnp.pad(xq, ((0, 0), (0, pad_c), (0, 0)))
    y = expert_w8a8_matmul_pallas(xq, qt.qw, qt.scale, bits=qt.bits,
                                  group_size=qt.group_size, bm=bm_, bn=bn_,
                                  bk=bk_, interpret=_interpret())
    if pad_c:
        y = y[:, :c]
    return (y * xs).astype(out_dtype)


# trace-time pallas -> reference fallbacks, per op name. A kernel that fails
# to *lower* (bad tile regime on an exotic shape, backend gap) raises while
# the jit is being traced — serving can survive that by building the
# reference path into the same computation instead. The counter makes the
# degradation observable; REPRO_STRICT_KERNELS=1 (set in the kernel-parity
# CI job) disables the net so a broken kernel fails loudly there, never
# silently passing parity via its own oracle.
DISPATCH_FALLBACKS: dict[str, int] = {"paged_attention": 0,
                                      "paged_attention_verify": 0,
                                      "paged_attention_prefill": 0}


def _kv_dtype(k_scale_pool) -> str:
    return "int8" if k_scale_pool is not None else "bf16"


def _kernel_fallback(name: str, kernel_fn, ref_fn):
    try:
        return kernel_fn()
    except Exception:
        if strict_kernels():
            raise
        DISPATCH_FALLBACKS[name] += 1
        return ref_fn()


def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    block_table: jax.Array, kv_len: jax.Array, *,
                    k_scale_pool=None, v_scale_pool=None, window=None,
                    out_dtype=None) -> jax.Array:
    """Fused paged-attention decode: q (S, H, hd) one token per slot against
    the slot's block-table pages, int8 K/V dequantized inline from the scale
    pools. Returns (S, H, hd_v) without materializing the gathered
    (S, maxp*page_size, ...) KV view. CPU default runs the jnp page-walk
    reference (same math); REPRO_DEQUANT_IMPL=pallas lowers the kernel in
    interpret mode; TPU compiles it.

    Under tensor-parallel serving this op is invoked *per shard* inside the
    engine's shard_map: the pools arrive with the shard-local kv-head slice
    (KVH/tp) while block tables and fill counts are replicated scalars
    (scalar-prefetch inputs are never sharded), so the grid simply shrinks
    along its KVH axis — attention is head-independent and the kernel needs
    no TP awareness. H here is the shard-local head count; the GQA group
    width H/KVH is TP-invariant because legal widths divide n_kv_heads."""
    s, h, hd = q.shape
    kvh = k_pool.shape[2]
    qg = q.reshape(s, kvh, h // kvh, hd)
    tile = autotune.paged_tile(k_pool.shape[1], _kv_dtype(k_scale_pool), 1)
    if _interpret() and dequant_impl() != "pallas":
        o = ref.paged_attention_ref(qg, k_pool, v_pool, block_table, kv_len,
                                    k_scale_pool, v_scale_pool,
                                    window=window, tile=tile)
    else:
        o = _kernel_fallback(
            "paged_attention",
            lambda: paged_attention_pallas(
                qg, k_pool, v_pool, block_table, kv_len, k_scale_pool,
                v_scale_pool, window=window, tile=tile,
                interpret=_interpret()),
            lambda: ref.paged_attention_ref(
                qg, k_pool, v_pool, block_table, kv_len, k_scale_pool,
                v_scale_pool, window=window, tile=tile))
    return o.reshape(s, h, v_pool.shape[-1]).astype(out_dtype or q.dtype)


def _paged_rows_read(name: str, ref_fn, q: jax.Array, k_pool: jax.Array,
                     v_pool: jax.Array, block_table: jax.Array,
                     kv_len: jax.Array, *, k_scale_pool=None,
                     v_scale_pool=None, window=None,
                     out_dtype=None) -> jax.Array:
    """Shared multi-row page walk behind the verify and prefill reads:
    q (S, M, H, hd) — M tail tokens per slot, row m at fill position
    kv_len - M + m — against the slot's pages, with per-row causal fill
    masks. kv_len counts the fill *including* all M tokens. Returns
    (S, M, H, hd_v). One page walk serves all M rows, so each live KV tile
    streams once instead of M times."""
    s, m, h, hd = q.shape
    kvh = k_pool.shape[2]
    g = h // kvh
    # rows go m-major within each kv head: (S, KVH, M*G, hd)
    qg = q.reshape(s, m, kvh, g, hd).transpose(0, 2, 1, 3, 4)
    qg = qg.reshape(s, kvh, m * g, hd)
    tile = autotune.paged_tile(k_pool.shape[1], _kv_dtype(k_scale_pool), m)
    if _interpret() and dequant_impl() != "pallas":
        o = ref_fn(qg, k_pool, v_pool, block_table, kv_len,
                   k_scale_pool, v_scale_pool, window=window, tile=tile,
                   m_rows=m)
    else:
        o = _kernel_fallback(
            name,
            lambda: paged_attention_pallas(
                qg, k_pool, v_pool, block_table, kv_len, k_scale_pool,
                v_scale_pool, window=window, tile=tile, m_rows=m,
                interpret=_interpret()),
            lambda: ref_fn(
                qg, k_pool, v_pool, block_table, kv_len, k_scale_pool,
                v_scale_pool, window=window, tile=tile, m_rows=m))
    hd_v = v_pool.shape[-1]
    o = o.reshape(s, kvh, m, g, hd_v).transpose(0, 2, 1, 3, 4)
    return o.reshape(s, m, h, hd_v).astype(out_dtype or q.dtype)


def paged_attention_verify(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, block_table: jax.Array,
                           kv_len: jax.Array, *, k_scale_pool=None,
                           v_scale_pool=None, window=None,
                           out_dtype=None) -> jax.Array:
    """Fused verify read for self-speculative decoding: the M rows are the
    draft-proposed tail tokens of each slot (see `_paged_rows_read`).
    M == 1 is exactly the decode read (`paged_attention`)."""
    return _paged_rows_read(
        "paged_attention_verify", ref.paged_attention_ref, q, k_pool, v_pool,
        block_table, kv_len, k_scale_pool=k_scale_pool,
        v_scale_pool=v_scale_pool, window=window, out_dtype=out_dtype)


def paged_attention_prefill(q: jax.Array, k_pool: jax.Array,
                            v_pool: jax.Array, block_table: jax.Array,
                            kv_len: jax.Array, *, k_scale_pool=None,
                            v_scale_pool=None, window=None,
                            out_dtype=None) -> jax.Array:
    """Fused chunked/suffix-prefill read: the M rows are a slot's
    left-padded prefill chunk (row j holds the token at fill position
    kv_len - M + j whatever the row's real chunk length — left-padding
    makes ragged chunks line up on the same per-row fill limits the verify
    read uses; pad rows carry positions < 0, land on the scratch page, and
    read back as values the engine discards). Replaces the
    gather-the-context oracle on the prefill hot path: earlier context —
    the slot's own prior chunks or shared prefix pages — streams through
    the same page walk as decode instead of materializing a contiguous
    (S, width*page_size, ...) HBM view."""
    return _paged_rows_read(
        "paged_attention_prefill", ref.paged_attention_prefill_ref, q,
        k_pool, v_pool, block_table, kv_len, k_scale_pool=k_scale_pool,
        v_scale_pool=v_scale_pool, window=window, out_dtype=out_dtype)


def channel_stats(x: jax.Array):
    """x: (..., C) -> per-channel (mean, var)."""
    x2 = x.reshape(-1, x.shape[-1])
    t, c = x2.shape
    if _interpret() and dequant_impl() != "pallas":
        return ref.channel_stats_ref(x2)
    bt = _pick_block(t, 256)
    bc = _pick_block(c, 256)
    return channel_stats_pallas(x2, bt=bt, bc=bc, interpret=_interpret())


def quantize_pack(w: jax.Array, scale: jax.Array, *, bits: int,
                  group_size: int) -> jax.Array:
    k, n = w.shape
    if _interpret() and dequant_impl() != "pallas":
        return ref.quantize_pack_ref(w, scale, bits=bits)
    gs = group_size if group_size != -1 else k
    vpg = pack_layout(bits)[1]
    bk = _pick_bk(k, gs, vpg, 256)
    if bk is None:  # no valid tiling (e.g. group_size with odd factors)
        return ref.quantize_pack_ref(w, scale, bits=bits)
    bn = _pick_block(n, 256)
    return quantize_pack_pallas(w, scale, bits=bits, group_size=group_size,
                                bk=bk, bn=bn, interpret=_interpret())
