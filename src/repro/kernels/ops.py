"""Jitted public wrappers around the Pallas kernels.

Dispatch: real TPU -> compiled Pallas; CPU -> `interpret=True` when forced
via REPRO_DEQUANT_IMPL=pallas (tests), else the jnp reference (same math,
fast on CPU). Handles token-dim padding and block-size selection so callers
never deal with tiling constraints.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core.quant.types import QuantizedTensor, values_per_byte
from repro.kernels import ref
from repro.kernels.channel_stats import channel_stats_pallas
from repro.kernels.dequant_matmul import dequant_matmul_pallas
from repro.kernels.quantize import quantize_pack_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pick_block(dim: int, target: int) -> int:
    if dim <= target:
        return dim
    b = target
    while dim % b != 0:
        b //= 2
        if b < 8:
            return dim  # fall back to a single block
    return b


def dequant_matmul(x: jax.Array, qt: QuantizedTensor, *, out_dtype=None,
                   bm: int = 128, bn: int = 256, bk: int = 256) -> jax.Array:
    """x: (M, K) @ packed (K, N) -> (M, N). Pads M to the tile size."""
    out_dtype = out_dtype or x.dtype
    m, k = x.shape
    n = qt.n
    gs = qt.group_size if qt.group_size != -1 else k
    bm_ = _pick_block(max(m, 8), bm)
    pad_m = (-m) % bm_
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
    bk_ = _pick_block(k, bk)
    # keep scale-group tiling consistent
    vpb = values_per_byte(qt.bits)
    while (gs < bk_ and bk_ % gs != 0) or (gs >= bk_ and gs % bk_ != 0) or \
            bk_ % vpb != 0:
        bk_ //= 2
        assert bk_ >= vpb, (k, gs, vpb)
    bn_ = _pick_block(n, bn)
    y = dequant_matmul_pallas(x, qt.qw, qt.scale, bits=qt.bits,
                              group_size=qt.group_size, bm=bm_, bn=bn_,
                              bk=bk_, interpret=_interpret())
    if pad_m:
        y = y[:m]
    return y.astype(out_dtype)


def channel_stats(x: jax.Array):
    """x: (..., C) -> per-channel (mean, var)."""
    x2 = x.reshape(-1, x.shape[-1])
    t, c = x2.shape
    if _interpret() and os.environ.get("REPRO_DEQUANT_IMPL") != "pallas":
        return ref.channel_stats_ref(x2)
    bt = _pick_block(t, 256)
    bc = _pick_block(c, 256)
    return channel_stats_pallas(x2, bt=bt, bc=bc, interpret=_interpret())


def quantize_pack(w: jax.Array, scale: jax.Array, *, bits: int,
                  group_size: int) -> jax.Array:
    k, n = w.shape
    if _interpret() and os.environ.get("REPRO_DEQUANT_IMPL") != "pallas":
        return ref.quantize_pack_ref(w, scale, bits=bits)
    gs = group_size if group_size != -1 else k
    bk = _pick_block(k, 256)
    vpb = values_per_byte(bits)
    while (gs < bk and bk % gs != 0) or (gs >= bk and gs % bk != 0) or \
            bk % vpb != 0:
        bk //= 2
    bn = _pick_block(n, 256)
    return quantize_pack_pallas(w, scale, bits=bits, group_size=group_size,
                                bk=bk, bn=bn, interpret=_interpret())
