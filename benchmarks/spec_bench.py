"""Self-speculative decoding benchmark, recorded to BENCH_spec.json.

Runs the same greedy workload through the target-only continuous engine
and through spec-decode with a truly-packed W2 and W3 draft of the same
checkpoint, asserting the token streams are bit-identical (the greedy
losslessness contract) before reporting anything.

Measured columns are CPU wall-clock (where the draft's extra forwards
*cost* time — the jnp reference dispatch has no bandwidth advantage to
recover them). The modeled columns carry the TPU story: decode is
weight-bytes-bound, so per emitted token the baseline streams the full
target weights once per step, while spec decode streams (k+1) draft
passes plus one target verify pass per round and amortizes them over the
measured mean accepted length. A W2 draft is ~bits/16 of the bf16 target
footprint, so the pipeline wins whenever acceptance clears
(k+1) * draft_bytes / (target_bytes * (L - 1)) — with random tiny-model
weights acceptance is near zero, so the *acceptance-sensitivity* table
models the win across the acceptance range instead of pretending the toy
checkpoint predicts real-model rates.

    PYTHONPATH=src:. python benchmarks/spec_bench.py
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.configs import TINY
from repro.models.transformer import init_lm
from repro.serve.engine import ContinuousEngine
from repro.utils.tree import tree_size_bytes

N_SLOTS = 4
N_REQUESTS = 8
N_REPS = 3
SPEC_K = 4
OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "BENCH_spec.json")


def make_cfg():
    return TINY.replace(d_model=256, head_dim=64, d_ff=768, n_repeats=4)


def make_workload(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, cfg.vocab_size, int(rng.choice([8, 16, 32]))),
             int(rng.choice([8, 16, 24]))) for _ in range(N_REQUESTS)]


def make_engine(cfg, params, **kw):
    return ContinuousEngine(cfg, params, n_slots=N_SLOTS, max_len=64,
                            page_size=16, prefill_bucket=8, **kw)


def serve_rep(eng, work):
    for prompt, max_new in work:
        eng.submit(prompt, max_new=max_new, arrival=0.0)
    t0 = time.time()
    done = eng.run(clock=lambda: time.time() - t0, max_steps=1_000_000)
    dt = time.time() - t0
    useful = sum(len(r.tokens) for r in done)
    return {"tok_s": useful / dt, "wall_s": dt, "useful_tokens": useful,
            "tokens": [r.tokens for r in done]}


def modeled_bytes_per_token(target_bytes, draft_bytes, k, mean_accepted):
    """Weight-bytes streamed per emitted token. Baseline: one target pass
    per token. Spec: per round, k+1 draft decode passes + 1 target verify
    pass, emitting mean_accepted tokens."""
    base = float(target_bytes)
    spec = ((k + 1) * draft_bytes + target_bytes) / max(mean_accepted, 1e-9)
    return base, spec


def run(rows=None):
    cfg = make_cfg()
    params = init_lm(cfg, jax.random.PRNGKey(0))
    work = make_workload(cfg)

    base_eng = make_engine(cfg, params)
    target_bytes = tree_size_bytes(base_eng.params)
    serve_rep(base_eng, work)                          # warm
    base = None
    for _ in range(N_REPS):
        r = serve_rep(base_eng, work)
        if base is None or r["tok_s"] > base["tok_s"]:
            base = r
    base_tokens = base.pop("tokens")

    out = {
        "workload": {"n_requests": N_REQUESTS, "n_slots": N_SLOTS,
                     "spec_k": SPEC_K, "arch": "tiny-dense-4L-d256"},
        "target_only": {**base, "weight_bytes": target_bytes,
                        "modeled_hbm_bytes_per_token": float(target_bytes)},
        "drafts": {},
    }
    for bits in (2, 3):
        eng = make_engine(cfg, params, spec_decode=True, draft_bits=bits,
                          spec_k=SPEC_K)
        draft_bytes = tree_size_bytes(eng.draft_params)
        serve_rep(eng, work)                           # warm
        best = None
        for _ in range(N_REPS):
            r = serve_rep(eng, work)
            if best is None or r["tok_s"] > best["tok_s"]:
                best = r
        # the greedy losslessness contract, asserted on every rep
        assert best.pop("tokens") == base_tokens, \
            f"W{bits} spec-decode diverged from target-only greedy output"
        st = eng.spec_stats()
        mean_l = st["mean_accepted_len"]
        b_base, b_spec = modeled_bytes_per_token(
            target_bytes, draft_bytes, SPEC_K, mean_l)
        # the same model across the acceptance range: where the pipeline
        # starts winning does not depend on the toy checkpoint's rate
        sensitivity = {}
        for l_hyp in (1.5, 2.0, 3.0, 4.0, 5.0):
            _, s = modeled_bytes_per_token(target_bytes, draft_bytes,
                                           SPEC_K, l_hyp)
            sensitivity[f"L={l_hyp}"] = round(b_base / s, 3)
        out["drafts"][f"w{bits}"] = {
            **best,
            "draft_weight_bytes": draft_bytes,
            "draft_bytes_per_value": round(
                draft_bytes / max(tree_size_bytes(params) / 4, 1), 4),
            "acceptance_rate": round(st["acceptance_rate"], 4),
            "mean_accepted_len": round(mean_l, 4),
            "target_forwards": eng.n_decode_steps,
            "draft_tokens": st["draft_tokens"],
            "tok_s_vs_target_only": round(best["tok_s"] / base["tok_s"], 3),
            "modeled_hbm_bytes_per_token": round(b_spec, 1),
            "modeled_hbm_win_at_measured_acceptance":
                round(b_base / b_spec, 3),
            "modeled_hbm_win_by_accepted_len": sensitivity,
        }
    with open(OUT, "w") as f:
        json.dump(out, f, indent=2)
    print(f"target-only {base['tok_s']:7.1f} tok/s  "
          f"({target_bytes / 1e6:.1f} MB weights)")
    for bits in (2, 3):
        d = out["drafts"][f"w{bits}"]
        print(f"W{bits} draft    {d['tok_s']:7.1f} tok/s  "
              f"accept {d['acceptance_rate']:.2f}  "
              f"L {d['mean_accepted_len']:.2f}  "
              f"modeled HBM win {d['modeled_hbm_win_at_measured_acceptance']}"
              f"x (at L=3: {d['modeled_hbm_win_by_accepted_len']['L=3.0']}x)")
    print(f"-> {OUT}")
    if rows is not None:
        for bits in (2, 3):
            d = out["drafts"][f"w{bits}"]
            rows.append((f"spec/w{bits}_tok_s", d["tok_s"],
                         f"accept={d['acceptance_rate']:.2f} "
                         f"modeled_hbm_win_at_L3="
                         f"{d['modeled_hbm_win_by_accepted_len']['L=3.0']}x"))
        return rows
    return out


if __name__ == "__main__":
    run()
