"""Table 3 analogue: quantization runtime, GPTQ vs GPTQ+NT.

Paper: minutes on A100 for BLOOM-7B/LLaMA-7B/OPT-13B; NT overhead < GPTQ
itself (16-76%). Here: seconds on CPU for the tiny model; the derived column
reports the NT overhead fraction.
"""
from __future__ import annotations

import time

from benchmarks.common import get_trained_tiny
from benchmarks.nt_common import make_calib
from repro.core.normtweak.pipeline import NTConfig, norm_tweak_ptq


def run(rows: list):
    cfg, params, (corpus, meta, train_toks, held, evals) = get_trained_tiny()
    calib = make_calib(cfg, params, meta)

    def timed(tweak):
        nt = NTConfig(method="gptq", bits=4, tweak=tweak, lr0=1e-3, iters=1,
                      sample_batch=4)
        t0 = time.time()
        norm_tweak_ptq(cfg, params, calib, nt)
        return time.time() - t0

    timed(False)  # warm the jit caches so the comparison is fair
    t_gptq = timed(False)
    t_nt = timed(True)
    rows.append(("table3/gptq", t_gptq * 1e6, "baseline"))
    rows.append(("table3/gptq+nt", t_nt * 1e6,
                 f"overhead={100 * (t_nt - t_gptq) / t_gptq:.0f}%"))
    return rows


if __name__ == "__main__":
    out = []
    run(out)
    for r in out:
        print(",".join(str(x) for x in r))
