"""Kernel microbenchmarks: fused dequant-matmul vs dequant-then-matmul ref.

On CPU the Pallas kernel runs in interpret mode (not representative), so the
timed comparison is ref-vs-ref at different bit widths; the derived column
reports the *modeled* TPU v5e HBM-traffic advantage of the packed format
(weight bytes are the decode-time bottleneck for weight-only PTQ serving).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.quant.types import quantize
from repro.kernels import ref

HBM_BW = 819e9


def _time(fn, *args, reps=5):
    fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.time() - t0) / reps


def run(rows: list):
    m, k, n = 32, 2048, 2048
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.05
    wbf = w.astype(jnp.bfloat16)

    base = jax.jit(lambda a, b: a.astype(jnp.bfloat16) @ b)
    t_fp = _time(base, x, wbf)
    rows.append((f"kernels/matmul_bf16_{m}x{k}x{n}", t_fp * 1e6,
                 f"bytes={k * n * 2}"))

    for bits, gs in [(8, -1), (4, 128), (2, 64)]:
        qt = quantize(w, bits, gs)
        fn = jax.jit(lambda xx, qw=qt.qw, sc=qt.scale: ref.dequant_matmul_ref(
            xx, qw, sc, bits=bits, group_size=gs, k=k))
        t = _time(fn, x)
        wbytes = qt.nbytes()
        # decode-time model: weight-bytes-bound; packed vs bf16 traffic
        speedup = (k * n * 2) / wbytes
        rows.append((f"kernels/dequant_matmul_w{bits}_{m}x{k}x{n}", t * 1e6,
                     f"bytes={wbytes};modeled_tpu_decode_speedup="
                     f"{speedup:.2f}x"))
    return rows


if __name__ == "__main__":
    out = []
    run(out)
    for r in out:
        print(",".join(str(x) for x in r))
