"""Kernel microbenchmarks: fused dequant-matmul vs dequant-then-matmul ref.

On CPU the Pallas kernel runs in interpret mode (not representative), so the
timed comparison is ref-vs-ref at different bit widths; the derived column
reports the *modeled* TPU v5e HBM-traffic advantage of the packed format
(weight bytes are the decode-time bottleneck for weight-only PTQ serving).

Three kernel families (see DESIGN.md "Quantized serving fast paths"):

  * dense dequant matmul          — (M, K) x packed (K, N)
  * expert-batched dequant matmul — (E, C, K) x stacked packed (E, K, N);
    the ref baseline column times the old path (dequantize the full float
    expert stack, then einsum) the kernel removes
  * W8A8 int8 matmul              — per-token int8 activations x packed
    weights on the int8 MXU; the model adds the 2x int8-vs-bf16 MXU rate

Plus two PR-10 rows: the autotuned tile plan vs the deterministic fallback
table on the real pallas_call (interpret mode on CPU — the *search
machinery* is what's exercised here, the win column is only meaningful on
TPU), and the fused chunked-prefill page walk vs the gather-the-context
oracle with its modeled provisioned-vs-live HBM traffic.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant.types import (dequantize, quantize, quantize_activation,
                                    quantize_stacked)
from repro.kernels import autotune, ref
from repro.kernels.paged_harness import build_prefill_case, prefill_oracle

HBM_BW = 819e9
MXU_INT8_RATE = 2.0                    # int8 MXU throughput vs bf16 (v5e)


def _time(fn, *args, reps=5):
    fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.time() - t0) / reps


def run(rows: list):
    m, k, n = 32, 2048, 2048
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.05
    wbf = w.astype(jnp.bfloat16)

    base = jax.jit(lambda a, b: a.astype(jnp.bfloat16) @ b)
    t_fp = _time(base, x, wbf)
    rows.append((f"kernels/matmul_bf16_{m}x{k}x{n}", t_fp * 1e6,
                 f"bytes={k * n * 2}"))

    for bits, gs in [(8, -1), (4, 128), (2, 64)]:
        qt = quantize(w, bits, gs)
        fn = jax.jit(lambda xx, qw=qt.qw, sc=qt.scale: ref.dequant_matmul_ref(
            xx, qw, sc, bits=bits, group_size=gs, k=k))
        t = _time(fn, x)
        wbytes = qt.nbytes()
        # decode-time model: weight-bytes-bound; packed vs bf16 traffic
        speedup = (k * n * 2) / wbytes
        rows.append((f"kernels/dequant_matmul_w{bits}_{m}x{k}x{n}", t * 1e6,
                     f"bytes={wbytes};modeled_tpu_decode_speedup="
                     f"{speedup:.2f}x"))

    # ---- expert-batched: stacked packed slabs vs float-stack einsum ----
    e, c, ke, ne = 8, 32, 1024, 1024
    xe = jax.random.normal(jax.random.PRNGKey(2), (e, c, ke), jnp.float32)
    we = jax.random.normal(jax.random.PRNGKey(3), (e, ke, ne)) * 0.05
    for bits, gs in [(4, 128), (2, 64)]:
        qte = quantize_stacked(we, bits, gs)
        fused = jax.jit(lambda xx, qw=qte.qw, sc=qte.scale:
                        ref.expert_dequant_matmul_ref(xx, qw, sc, bits=bits,
                                                      group_size=gs, k=ke))
        stack = jax.jit(lambda xx, qt_=qte: jnp.einsum(
            "eck,ekn->ecn", xx.astype(jnp.bfloat16),
            dequantize(qt_, jnp.bfloat16),
            preferred_element_type=jnp.float32))
        t_fused = _time(fused, xe)
        t_stack = _time(stack, xe)
        wbytes = qte.nbytes()
        speedup = (e * ke * ne * 2) / wbytes
        rows.append((f"kernels/expert_dequant_w{bits}_{e}x{c}x{ke}x{ne}",
                     t_fused * 1e6,
                     f"bytes={wbytes};float_stack_ref_us={t_stack * 1e6:.0f};"
                     f"modeled_tpu_decode_speedup={speedup:.2f}x"))

    # ---- W8A8: int8 MXU path (per-token activation scales) ----
    for bits in (8, 4):
        qt8 = quantize(w, bits, -1, act_bits=8)
        xq, xs = quantize_activation(x, 8)

        def w8a8(xx_q, xx_s, qw=qt8.qw, sc=qt8.scale, b=bits):
            return ref.w8a8_matmul_ref(xx_q, qw, sc, bits=b, group_size=-1,
                                       k=k) * xx_s

        t8 = _time(jax.jit(w8a8), xq, xs)
        wbytes = qt8.nbytes()
        # two regimes, modeled separately: decode is weight-bytes-bound
        # (packed traffic advantage; the MXU rate doesn't matter there),
        # prefill is compute-bound (int8 MXU rate vs bf16)
        decode_speedup = (k * n * 2) / wbytes
        rows.append((f"kernels/w8a8_matmul_w{bits}a8_{m}x{k}x{n}", t8 * 1e6,
                     f"bytes={wbytes};modeled_tpu_decode_speedup="
                     f"{decode_speedup:.2f}x;"
                     f"modeled_tpu_prefill_mxu_speedup="
                     f"{MXU_INT8_RATE:.1f}x"))

    # ---- autotuned vs heuristic tile plan (real pallas_call, interpret) --
    ma, ka, na, bits_a, gs_a = 8, 256, 256, 4, 64
    qt_a = quantize(jax.random.normal(jax.random.PRNGKey(5),
                                      (ka, na)) * 0.05, bits_a, gs_a)
    xa = jax.random.normal(jax.random.PRNGKey(6), (ma, ka), jnp.float32)
    table = autotune.fallback_matmul_plan(ma, ka, na, bits=bits_a,
                                          group_size=gs_a, bm=128, bn=256,
                                          bk=256)
    tuned = autotune._search_matmul("dequant", ma, ka, na, bits=bits_a,
                                    group_size=gs_a, fallback=table)
    kernel_fn = autotune._MEASURE_FNS["dequant"]()
    times = {}
    for tag, (bm, bn, bk) in (("table", table), ("tuned", tuned)):
        xp = jnp.pad(xa, ((0, (-ma) % bm), (0, 0)))
        times[tag] = autotune._time_candidate(lambda: kernel_fn(
            xp, qt_a.qw, qt_a.scale, bits=bits_a, group_size=gs_a, bm=bm,
            bn=bn, bk=bk, interpret=jax.default_backend() != "tpu"))
    rows.append((f"kernels/autotuned_dequant_w{bits_a}_{ma}x{ka}x{na}",
                 times["tuned"] * 1e6,
                 f"table_plan={table};tuned_plan={tuned};"
                 f"table_us={times['table'] * 1e6:.0f};"
                 f"win={times['table'] / max(times['tuned'], 1e-12):.2f}x"))

    # ---- fused chunked-prefill page walk vs gather-the-context oracle ----
    s, mrows, wtab, ps, kvh, g, hd = 2, 16, 8, 16, 2, 2, 64
    fills = (16 + mrows, 5 * ps + mrows)
    chunk = (mrows, mrows)
    from repro.kernels import ops

    q, pools, bt, kv_len = build_prefill_case(11, s, mrows, wtab, ps, kvh,
                                              g, hd, fills, 8)
    fused = jax.jit(lambda qq: ops.paged_attention_prefill(
        qq, pools["k_pool"], pools["v_pool"], bt, kv_len,
        k_scale_pool=pools["k_scale_pool"],
        v_scale_pool=pools["v_scale_pool"]))
    gathered = jax.jit(lambda qq: prefill_oracle(qq, pools, bt, kv_len,
                                                 None, chunk))
    t_fused = _time(fused, q)
    t_gather = _time(gathered, q)
    live = int(np.sum(-(-np.asarray(kv_len) // ps) * ps))
    provisioned = s * wtab * ps
    rows.append((f"kernels/prefill_attn_fused_s{s}m{mrows}ps{ps}",
                 t_fused * 1e6,
                 f"gather_us={t_gather * 1e6:.0f};"
                 f"modeled_hbm_live_vs_provisioned="
                 f"{provisioned / max(live, 1):.2f}x"))
    return rows


if __name__ == "__main__":
    out = []
    run(out)
    for r in out:
        print(",".join(str(x) for x in r))
