"""Kernel microbenchmarks: fused dequant-matmul vs dequant-then-matmul ref.

On CPU the Pallas kernel runs in interpret mode (not representative), so the
timed comparison is ref-vs-ref at different bit widths; the derived column
reports the *modeled* TPU v5e HBM-traffic advantage of the packed format
(weight bytes are the decode-time bottleneck for weight-only PTQ serving).

Three kernel families (see DESIGN.md "Quantized serving fast paths"):

  * dense dequant matmul          — (M, K) x packed (K, N)
  * expert-batched dequant matmul — (E, C, K) x stacked packed (E, K, N);
    the ref baseline column times the old path (dequantize the full float
    expert stack, then einsum) the kernel removes
  * W8A8 int8 matmul              — per-token int8 activations x packed
    weights on the int8 MXU; the model adds the 2x int8-vs-bf16 MXU rate
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.quant.types import (dequantize, quantize, quantize_activation,
                                    quantize_stacked)
from repro.kernels import ref

HBM_BW = 819e9
MXU_INT8_RATE = 2.0                    # int8 MXU throughput vs bf16 (v5e)


def _time(fn, *args, reps=5):
    fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.time() - t0) / reps


def run(rows: list):
    m, k, n = 32, 2048, 2048
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.05
    wbf = w.astype(jnp.bfloat16)

    base = jax.jit(lambda a, b: a.astype(jnp.bfloat16) @ b)
    t_fp = _time(base, x, wbf)
    rows.append((f"kernels/matmul_bf16_{m}x{k}x{n}", t_fp * 1e6,
                 f"bytes={k * n * 2}"))

    for bits, gs in [(8, -1), (4, 128), (2, 64)]:
        qt = quantize(w, bits, gs)
        fn = jax.jit(lambda xx, qw=qt.qw, sc=qt.scale: ref.dequant_matmul_ref(
            xx, qw, sc, bits=bits, group_size=gs, k=k))
        t = _time(fn, x)
        wbytes = qt.nbytes()
        # decode-time model: weight-bytes-bound; packed vs bf16 traffic
        speedup = (k * n * 2) / wbytes
        rows.append((f"kernels/dequant_matmul_w{bits}_{m}x{k}x{n}", t * 1e6,
                     f"bytes={wbytes};modeled_tpu_decode_speedup="
                     f"{speedup:.2f}x"))

    # ---- expert-batched: stacked packed slabs vs float-stack einsum ----
    e, c, ke, ne = 8, 32, 1024, 1024
    xe = jax.random.normal(jax.random.PRNGKey(2), (e, c, ke), jnp.float32)
    we = jax.random.normal(jax.random.PRNGKey(3), (e, ke, ne)) * 0.05
    for bits, gs in [(4, 128), (2, 64)]:
        qte = quantize_stacked(we, bits, gs)
        fused = jax.jit(lambda xx, qw=qte.qw, sc=qte.scale:
                        ref.expert_dequant_matmul_ref(xx, qw, sc, bits=bits,
                                                      group_size=gs, k=ke))
        stack = jax.jit(lambda xx, qt_=qte: jnp.einsum(
            "eck,ekn->ecn", xx.astype(jnp.bfloat16),
            dequantize(qt_, jnp.bfloat16),
            preferred_element_type=jnp.float32))
        t_fused = _time(fused, xe)
        t_stack = _time(stack, xe)
        wbytes = qte.nbytes()
        speedup = (e * ke * ne * 2) / wbytes
        rows.append((f"kernels/expert_dequant_w{bits}_{e}x{c}x{ke}x{ne}",
                     t_fused * 1e6,
                     f"bytes={wbytes};float_stack_ref_us={t_stack * 1e6:.0f};"
                     f"modeled_tpu_decode_speedup={speedup:.2f}x"))

    # ---- W8A8: int8 MXU path (per-token activation scales) ----
    for bits in (8, 4):
        qt8 = quantize(w, bits, -1, act_bits=8)
        xq, xs = quantize_activation(x, 8)

        def w8a8(xx_q, xx_s, qw=qt8.qw, sc=qt8.scale, b=bits):
            return ref.w8a8_matmul_ref(xx_q, qw, sc, bits=b, group_size=-1,
                                       k=k) * xx_s

        t8 = _time(jax.jit(w8a8), xq, xs)
        wbytes = qt8.nbytes()
        # two regimes, modeled separately: decode is weight-bytes-bound
        # (packed traffic advantage; the MXU rate doesn't matter there),
        # prefill is compute-bound (int8 MXU rate vs bf16)
        decode_speedup = (k * n * 2) / wbytes
        rows.append((f"kernels/w8a8_matmul_w{bits}a8_{m}x{k}x{n}", t8 * 1e6,
                     f"bytes={wbytes};modeled_tpu_decode_speedup="
                     f"{decode_speedup:.2f}x;"
                     f"modeled_tpu_prefill_mxu_speedup="
                     f"{MXU_INT8_RATE:.1f}x"))
    return rows


if __name__ == "__main__":
    out = []
    run(out)
    for r in out:
        print(",".join(str(x) for x in r))
