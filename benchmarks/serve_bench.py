"""Static vs. continuous batching on a mixed-length serving workload.

The acceptance workload for the continuous-batching refactor: 16 requests
over 8 slots, prompt lengths 8-64, per-request decode budgets 8-64. The
static baseline is what the old engine can actually do — uniform-prompt-
length groups, every group decoding in lockstep to the group's largest
max_new — while the continuous engine retires each request at its own depth
and refills the slot. Both engines are warmed first so jit compilation is
excluded from the timings.

The model is the paper's tiny LLaMA-style decoder widened to serving scale
(d_model 512): at the test-suite width the per-step XLA op-dispatch
overhead on CPU swamps the actual compute and hides the batching effect
this benchmark exists to measure. The continuous engine's page pool is
deliberately provisioned below worst case (41 pages ≈ 656 tokens vs. the
8 * 128 worst case) — right-sizing the pool to live traffic is the point
of paging, and the per-step cache rewrite cost scales with pool size.

Writes tok/s and p50/p99 per-request latency to BENCH_serve.json:

    PYTHONPATH=src:. python benchmarks/serve_bench.py
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.configs import TINY
from repro.models.transformer import init_lm
from repro.serve.engine import ContinuousEngine, ServeEngine

N_SLOTS = 8
N_REQUESTS = 16
N_REPS = 3
N_PAGES = 41                           # right-sized pool (see docstring)
PROMPT_LENS = [8, 16, 32, 64]          # 4 requests each -> 4 static groups
MAX_NEW_CHOICES = [8, 16, 24, 32, 40, 48, 56, 64]
OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "BENCH_serve.json")


def make_workload(cfg, seed=0):
    rng = np.random.default_rng(seed)
    work = []
    for i in range(N_REQUESTS):
        plen = PROMPT_LENS[i % len(PROMPT_LENS)]
        max_new = int(rng.choice(MAX_NEW_CHOICES))
        work.append((rng.integers(0, cfg.vocab_size, plen), max_new))
    return work


def static_rep(eng, plan):
    t0 = time.time()
    latency, useful = [], 0
    for prompts, mnew, mnews in plan:
        eng.generate(prompts, max_new=mnew, temperature=0.0)
        done_at = time.time() - t0
        latency += [done_at] * len(mnews)      # whole group waits for max_new
        useful += sum(mnews)
    dt = time.time() - t0
    return {"tok_s": useful / dt, "wall_s": dt, "useful_tokens": useful,
            "p50_latency_s": float(np.percentile(latency, 50)),
            "p99_latency_s": float(np.percentile(latency, 99))}


def continuous_rep(eng, work):
    for prompt, max_new in work:
        eng.submit(prompt, max_new=max_new, arrival=0.0)
    steps0 = eng.n_decode_steps
    t0 = time.time()
    done = eng.run(clock=lambda: time.time() - t0, max_steps=1_000_000)
    dt = time.time() - t0
    useful = sum(len(r.tokens) for r in done)
    latency = [r.finished_at for r in done]
    return {"tok_s": useful / dt, "wall_s": dt, "useful_tokens": useful,
            "decode_steps": eng.n_decode_steps - steps0,
            "p50_latency_s": float(np.percentile(latency, 50)),
            "p99_latency_s": float(np.percentile(latency, 99))}


def run():
    cfg = TINY.replace(d_model=512, head_dim=128, d_ff=1536)
    params = init_lm(cfg, jax.random.PRNGKey(0))
    work = make_workload(cfg)

    st_eng = ServeEngine(cfg, params)
    groups: dict[int, list] = {}
    for prompt, max_new in work:
        groups.setdefault(len(prompt), []).append((prompt, max_new))
    plan = []
    for plen, items in sorted(groups.items()):
        for i in range(0, len(items), N_SLOTS):
            chunk = items[i:i + N_SLOTS]
            plan.append((np.stack([p for p, _ in chunk]),
                         max(m for _, m in chunk),
                         [m for _, m in chunk]))

    max_len = max(PROMPT_LENS) + max(MAX_NEW_CHOICES)
    ct_eng = ContinuousEngine(cfg, params, n_slots=N_SLOTS, max_len=max_len,
                              page_size=16, n_pages=N_PAGES, prefill_bucket=8)

    # warm both engines (every shape the timed reps will hit)
    for prompts, mnew, _ in plan:
        st_eng.generate(prompts, max_new=mnew, temperature=0.0)
    continuous_rep(ct_eng, work)

    # interleave reps so background CPU contention hits both engines alike;
    # best-of-N per engine filters the remaining noise
    static, cont = None, None
    for _ in range(N_REPS):
        s = static_rep(st_eng, plan)
        c = continuous_rep(ct_eng, work)
        if static is None or s["tok_s"] > static["tok_s"]:
            static = s
        if cont is None or c["tok_s"] > cont["tok_s"]:
            cont = c
    result = {
        "workload": {"n_requests": N_REQUESTS, "n_slots": N_SLOTS,
                     "prompt_lens": PROMPT_LENS,
                     "max_new_choices": MAX_NEW_CHOICES},
        "static": static,
        "continuous": cont,
        "speedup": cont["tok_s"] / static["tok_s"],
    }
    with open(OUT, "w") as f:
        json.dump(result, f, indent=2)
    print(f"static     {static['tok_s']:8.1f} tok/s  "
          f"p99 {static['p99_latency_s']:.3f}s")
    print(f"continuous {cont['tok_s']:8.1f} tok/s  "
          f"p99 {cont['p99_latency_s']:.3f}s")
    print(f"speedup    {result['speedup']:.2f}x  -> {OUT}")
    return result


if __name__ == "__main__":
    run()
