"""Table 8 analogue: calibration-data choice vs cross-dataset generalization.

Paper: GPTQ calibrated on WikiText2/PTB/C4/random/generated-v1/generated-v2;
PPL evaluated on all three real sets. Real data helps its own set, random
fails, self-generated data (esp. language-restricted V2) generalizes.

Here the per-language held-out corpora play the role of the three datasets:
calibrate on language-0 windows / random ids / generated-V1 (first token
uniform over the vocab) / generated-V2 (first token restricted to the top-2
corpus languages), evaluate PPL per language set.
"""
from __future__ import annotations

import jax

from benchmarks.common import get_trained_tiny
from benchmarks.nt_common import EVAL_KW
from repro.core.calibration.generator import (generate_calibration,
                                              random_calibration,
                                              real_calibration)
from repro.core.normtweak.pipeline import NTConfig, norm_tweak_ptq
from repro.train.evaluate import perplexity


def run(rows: list):
    cfg, params, (corpus, meta, train_toks, held, evals) = get_trained_tiny()
    key = jax.random.PRNGKey(11)
    lang0 = evals["lang0"]

    calibs = {
        "real_lang0": real_calibration(lang0, key, n_samples=32,
                                       token_length=64),
        "random": random_calibration(cfg, key, n_samples=32, token_length=64),
        "gen_v1": generate_calibration(cfg, params, key, n_samples=32,
                                       token_length=64),
        "gen_v2": generate_calibration(
            cfg, params, key, n_samples=32, token_length=64,
            allowed_first=meta.top_language_tokens(2)),
    }
    nt = NTConfig(method="gptq", bits=2, group_size=64, tweak=False)
    for name, calib in calibs.items():
        qp, _ = norm_tweak_ptq(cfg, params, calib, nt)
        per = {k: perplexity(cfg, qp, v, **EVAL_KW)["ppl"]
               for k, v in sorted(evals.items())}
        geo = 1.0
        for v in per.values():
            geo *= v
        geo = geo ** (1.0 / len(per))
        detail = ";".join(f"{k}={v:.3f}" for k, v in per.items())
        rows.append((f"table8/{name}", 0.0, f"geo={geo:.3f};{detail}"))
    return rows


if __name__ == "__main__":
    out = []
    run(out)
    for r in out:
        print(",".join(str(x) for x in r))
