"""Tensor-parallel serving benchmark -> BENCH_tp_serve.json.

Runs the W4 GQA serving workload on the continuous engine at TP=1/2/4 over
a forced 4-device CPU host mesh: measured tokens/s per width (orientation
only on CPU — four virtual devices share the same socket and the psums are
memcpys, so TP *costs* time here), greedy-token identity asserted against
TP=1, and the deployment story the placement actually buys: per-device
bytes for packed weights and KV pools from the live buffer shardings —
on a real mesh that is the per-device HBM footprint, which is what lets a
norm-tweaked W4 checkpoint of a model N x too big for one device serve at
all (the paper's low-bit deployment regime at scale).

    PYTHONPATH=src:. python benchmarks/tp_serve_bench.py
"""
from __future__ import annotations

import json
import os
import time

os.environ.setdefault("JAX_PLATFORMS", "cpu")
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (_flags +
                               " --xla_force_host_platform_device_count=4"
                               ).strip()

import jax                                                    # noqa: E402
import numpy as np                                            # noqa: E402

from repro.configs import TINY                                # noqa: E402
from repro.models.transformer import init_lm                  # noqa: E402
from repro.serve.engine import ContinuousEngine               # noqa: E402

N_SLOTS = 4
N_REQUESTS = 12
N_REPS = 3
QUANT_BITS = 4
QUANT_GROUP = 32
OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "BENCH_tp_serve.json")


def make_cfg():
    # GQA geometry with kv-head headroom so every measured width divides it
    return TINY.replace(d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
                        d_ff=512, n_repeats=4)


def make_workload(cfg, seed=0):
    rng = np.random.default_rng(seed)
    work = []
    for _ in range(N_REQUESTS):
        plen = int(rng.integers(8, 33))
        mnew = int(rng.integers(8, 25))
        work.append((rng.integers(0, cfg.vocab_size, plen), mnew))
    return work


def run_engine(cfg, params, work, tp):
    eng = ContinuousEngine(cfg, params, n_slots=N_SLOTS, max_len=96,
                           page_size=16, prefill_bucket=16, tp=tp,
                           quant_bits=QUANT_BITS, quant_group=QUANT_GROUP)
    for prompt, mnew in work:
        eng.submit(prompt, max_new=mnew)
    done = eng.run(max_steps=100_000)               # warm-up + tokens
    tokens = [r.tokens for r in done]
    times = []
    for _ in range(N_REPS):
        for prompt, mnew in work:
            eng.submit(prompt, max_new=mnew)
        t0 = time.time()
        rep = eng.run(max_steps=100_000)
        times.append(time.time() - t0)
        assert [r.tokens for r in rep] == tokens, "rep diverged"
    total = sum(len(t) for t in tokens)
    return tokens, total / min(times), eng.tp_placement_report()


def main():
    assert len(jax.devices()) >= 4, "needs XLA-forced 4 CPU devices"
    cfg = make_cfg()
    params = init_lm(cfg, jax.random.PRNGKey(0))
    work = make_workload(cfg)
    rows = []
    base_tokens = None
    for tp in (1, 2, 4):
        tokens, tps, rep = run_engine(cfg, params, work, tp)
        if base_tokens is None:
            base_tokens = tokens
        else:
            assert tokens == base_tokens, f"tp={tp} tokens diverged from tp=1"
        assert not rep["replicated_quant_leaves"], rep
        assert not rep["replicated_pool_leaves"], rep
        row = {
            "tp": tp,
            "tokens_per_s_cpu_measured": round(tps, 2),
            "params_bytes_per_device": rep["params"]["per_device_bytes"],
            "params_bytes_global": rep["params"]["global_bytes"],
            "kv_pool_bytes_per_device": rep["kv"]["per_device_bytes"],
            "kv_pool_bytes_global": rep["kv"]["global_bytes"],
            "greedy_tokens_identical_to_tp1": True,
        }
        rows.append(row)
        print(f"tp={tp}: {tps:7.1f} tok/s (CPU), "
              f"{row['params_bytes_per_device'] / 1e6:.2f} MB params/dev, "
              f"{row['kv_pool_bytes_per_device'] / 1e6:.2f} MB KV/dev")
    out = {
        "bench": "tp_serve",
        "config": {"arch": "tiny-gqa", "d_model": cfg.d_model,
                   "n_heads": cfg.n_heads, "n_kv_heads": cfg.n_kv_heads,
                   "n_layers": cfg.n_layers, "quant_bits": QUANT_BITS,
                   "quant_group": QUANT_GROUP, "n_slots": N_SLOTS,
                   "n_requests": N_REQUESTS},
        "note": ("measured tok/s on a forced 4-device CPU host mesh — "
                 "collectives are memcpys on one socket, so TP costs "
                 "wall-clock here; the deployment signal is the per-device "
                 "byte columns (HBM footprint on a real mesh) plus the "
                 "asserted greedy-token identity"),
        "rows": rows,
    }
    with open(OUT, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
