"""Fault tolerance: snapshot/restore cost, goodput under injected
faults, and the fused->gather fallback overhead.

Everything runs the seeded traffic harness under the virtual clock, so
the fault schedule, the crash point, and every counter are deterministic
and machine-independent; only the wall-clock timings vary by host.

  snapshot   — full-engine snapshot()/restore() latency and the on-disk
               round trip through checkpoint.store, at two engine sizes
               (the cost scales with KV pool bytes, not request count).
  goodput    — the same bursty trace fault-free vs under a seeded chaos
               schedule (NaN logits, pool exhaustion, kernel faults,
               corrupt spills, latency spikes, one mid-trace crash
               recovered from snapshot). Requests the faults never
               touched are asserted token-identical to the baseline.
  fallback   — trace wall time on the fused paged-attention path vs the
               same trace with an injected kernel fault forcing the
               mid-trace downgrade to the gather oracle; token streams
               are asserted identical (gather is the kernel's oracle).

Writes BENCH_faults.json:

    PYTHONPATH=src:. python benchmarks/faults_bench.py
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import jax
import numpy as np

from repro.checkpoint.store import load_snapshot, save_snapshot
from repro.configs import TINY
from repro.models.transformer import init_lm
from repro.serve import traffic
from repro.serve.engine import ContinuousEngine
from repro.serve.faults import FaultPlan, run_resilient

PAGE_SIZE = 8
SEED = 7
CHAOS_SEED = 3
OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "BENCH_faults.json")


def make_trace(cfg, n=16):
    return traffic.make_trace(
        kind="bursty", n=n, rate=1.0, seed=SEED,
        vocab_size=cfg.vocab_size, prompt_len=(8, 16), max_new=(4, 12),
        batch_frac=0.5, burst_len=1.0, idle_len=8.0, burst_rate_mult=8.0)


def _engine(cfg, params, *, n_slots=2, n_pages=24, max_len=64, **kw):
    return ContinuousEngine(cfg, params, n_slots=n_slots, max_len=max_len,
                            page_size=PAGE_SIZE, prefill_bucket=8,
                            n_pages=n_pages, preempt=True,
                            age_promote=200.0, **kw)


def _snap_bytes(obj) -> int:
    if isinstance(obj, np.ndarray):
        return obj.nbytes
    if isinstance(obj, dict):
        return sum(_snap_bytes(v) for v in obj.values())
    if isinstance(obj, (list, tuple)):
        return sum(_snap_bytes(v) for v in obj)
    return 0


def _time(fn, iters=5):
    fn()                                    # warm (compiles, first sync)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e3     # ms


def bench_snapshot(cfg, params):
    """snapshot()/restore() and the disk round trip vs engine size."""
    out = {}
    for name, kw in (("2slots_24pages", dict(n_slots=2, n_pages=24)),
                     ("4slots_96pages", dict(n_slots=4, n_pages=96,
                                             max_len=128))):
        eng = _engine(cfg, params, **kw)
        for it in make_trace(cfg, n=6):
            eng.submit(it.prompt, max_new=it.max_new, arrival=it.arrival,
                       priority=it.priority)
        for _ in range(4):                  # mid-trace state, not step 0
            eng.step(float(eng.t))
            eng.t += 1
        snap = eng.snapshot()
        fresh = _engine(cfg, params, **kw)
        with tempfile.TemporaryDirectory() as tmp:
            path = os.path.join(tmp, "snap")
            out[name] = {
                "kv_pool_bytes": _snap_bytes(snap["cache"]),
                "snapshot_bytes": _snap_bytes(snap),
                "snapshot_ms": _time(eng.snapshot),
                "restore_ms": _time(lambda: fresh.restore(snap)),
                "save_ms": _time(lambda: save_snapshot(path, snap)),
                "load_ms": _time(lambda: load_snapshot(path)),
            }
        fresh.run(max_steps=100_000)        # restored engine must drain
        fresh.pool.check_invariants()
    return out


def bench_goodput(cfg, params, trace):
    """Fault-free vs seeded chaos on the same trace; survivors must be
    token-identical."""
    base_eng = _engine(cfg, params, max_len=64)
    base = traffic.replay(base_eng, trace, max_steps=200_000)
    want = {r.rid: list(r.tokens) for r in base["requests"]}

    plan = FaultPlan.seeded(CHAOS_SEED, n_steps=40, n_slots=2, n_faults=5,
                            crashes=1)
    res = run_resilient(lambda: _engine(cfg, params, max_len=64), trace,
                        faults=plan, snapshot_every=8, max_steps=200_000)
    rep = res["report"]
    untouched = [r for r in res["requests"]
                 if not (r.error or r.shed or r.cancelled or r.n_preempts)]
    for r in untouched:
        assert list(r.tokens) == want[r.rid], \
            f"fault schedule perturbed untouched request {r.rid}"
    res["engine"].pool.check_invariants()
    strip = lambda rp: {k: v for k, v in rp.items() if k != "requests"}
    return {
        "fault_free": strip(base),
        "chaos": strip(rep),
        "n_crashes": res["n_crashes"],
        "n_snapshots": res["n_snapshots"],
        "goodput_tok_per_step": {
            "fault_free": base["overall"]["goodput_tok_per_t"],
            "chaos": rep["overall"]["goodput_tok_per_t"]},
        "survivors_token_identical": len(untouched),
    }


def bench_fallback(cfg, params, trace):
    """Wall time fused vs mid-trace fused->gather downgrade."""
    from repro.serve.faults import Fault

    def drive(faults):
        eng = _engine(cfg, params, max_len=64, paged_attn="fused",
                      faults=faults)
        for it in trace:
            eng.submit(it.prompt, max_new=it.max_new, arrival=it.arrival,
                       priority=it.priority)
        eng.run(max_steps=200_000)          # warm compile both paths
        t0 = time.perf_counter()
        eng2 = _engine(cfg, params, max_len=64, paged_attn="fused",
                       faults=faults)
        reqs = [eng2.submit(it.prompt, max_new=it.max_new,
                            arrival=it.arrival, priority=it.priority)
                for it in trace]
        eng2.run(max_steps=200_000)
        dt = time.perf_counter() - t0
        return dt, {r.rid: list(r.tokens) for r in reqs}, eng2

    t_fused, toks_fused, _ = drive(None)
    plan = FaultPlan([Fault(step=2, kind="kernel_fault")])
    t_fall, toks_fall, eng = drive(plan)
    assert toks_fused == toks_fall, "fallback changed greedy tokens"
    assert eng.n_kernel_fallbacks == 1
    assert eng.cfg.paged_attn_impl == "gather"
    return {"fused_s": t_fused, "fallback_s": t_fall,
            "overhead_x": t_fall / t_fused if t_fused else None,
            "tokens_identical": True}


def run():
    cfg = TINY
    params = init_lm(cfg, jax.random.PRNGKey(0))
    trace = make_trace(cfg)

    result = {
        "workload": {"n_requests": len(trace), "page_size": PAGE_SIZE,
                     "trace": "bursty", "seed": SEED,
                     "chaos_seed": CHAOS_SEED},
        "snapshot": bench_snapshot(cfg, params),
        "goodput": bench_goodput(cfg, params, trace),
        "fallback": bench_fallback(cfg, params, trace),
    }
    with open(OUT, "w") as f:
        json.dump(result, f, indent=2)
    for name, s in result["snapshot"].items():
        print(f"snapshot[{name}]: {s['snapshot_bytes'] / 1e6:.1f} MB, "
              f"snap {s['snapshot_ms']:.1f} ms / restore "
              f"{s['restore_ms']:.1f} ms, disk {s['save_ms']:.1f}/"
              f"{s['load_ms']:.1f} ms")
    g = result["goodput"]
    print(f"goodput tok/step: fault-free "
          f"{g['goodput_tok_per_step']['fault_free']:.2f} vs chaos "
          f"{g['goodput_tok_per_step']['chaos']:.2f} "
          f"({g['n_crashes']} crash, {g['n_snapshots']} snapshots, "
          f"{g['survivors_token_identical']} survivors token-identical)")
    f_ = result["fallback"]
    print(f"fallback: fused {f_['fused_s']:.2f}s vs downgraded "
          f"{f_['fallback_s']:.2f}s ({f_['overhead_x']:.2f}x) -> {OUT}")
    return result


if __name__ == "__main__":
    run()
