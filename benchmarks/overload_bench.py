"""Overload discipline: FIFO vs priority scheduling with preemptive spill.

The overload scenario the scheduler was built for: a bursty trace whose
on-phases arrive far faster than two slots can drain, batch requests
holding slots for long decodes while short interactive requests queue
behind them. Both engines replay the *same* seeded trace under the
virtual clock (one scheduler step = one time unit), so every number here
is deterministic and machine-independent.

  fifo      — every request submitted class-blind (single arrival-order
              queue, no preemption): the PR-4 behaviour. Per-class
              metrics are recovered afterwards from the trace's labels.
  priority  — interactive requests jump the queue and preempt batch
              victims (KV spilled to host RAM, restored later); aging
              bounds batch starvation.

Greedy token streams are asserted identical between the two runs —
preemption changes *when* a request runs, never *what* it generates —
so the TTFT/goodput comparison is pure scheduling. Writes
BENCH_overload.json:

    PYTHONPATH=src:. python benchmarks/overload_bench.py
"""
from __future__ import annotations

import json
import os

import jax

from repro.configs import TINY
from repro.models.transformer import init_lm
from repro.serve import traffic
from repro.serve.engine import ContinuousEngine

N_SLOTS = 2
N_PAGES = 24                 # tight page budget: preemption must free pages
N_REQUESTS = 32
PAGE_SIZE = 8
BATCH_MAX_NEW = 48           # batch requests decode long, holding slots
SEED = 7
AGE_PROMOTE = 200.0
OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "BENCH_overload.json")


def make_trace(cfg):
    trace = traffic.make_trace(
        kind="bursty", n=N_REQUESTS, rate=1.0, seed=SEED,
        vocab_size=cfg.vocab_size, prompt_len=(8, 24), max_new=(4, 8),
        batch_frac=0.5, burst_len=1.0, idle_len=12.0, burst_rate_mult=8.0)
    for it in trace:            # stretch batch decodes: the overload source
        if it.priority == 1:
            it.max_new = BATCH_MAX_NEW
    return trace


def run_policy(cfg, params, trace, *, preempt):
    max_len = max(len(it.prompt) + it.max_new for it in trace) + PAGE_SIZE
    eng = ContinuousEngine(cfg, params, n_slots=N_SLOTS, max_len=max_len,
                           page_size=PAGE_SIZE, prefill_bucket=8,
                           n_pages=N_PAGES, preempt=preempt,
                           age_promote=AGE_PROMOTE if preempt else None)
    if preempt:
        reqs = [eng.submit(it.prompt, max_new=it.max_new, arrival=it.arrival,
                           priority=it.priority) for it in trace]
    else:
        # class-blind FIFO: one queue, arrival order; recover the class
        # labels afterwards so the per-class report uses the same split
        reqs = [eng.submit(it.prompt, max_new=it.max_new, arrival=it.arrival)
                for it in trace]
    done = eng.run(clock=None, max_steps=200_000)
    assert len(done) == len(trace)
    for r, it in zip(reqs, trace):
        r.priority = it.priority
    report = traffic.summarize(done)
    report["scheduler"] = eng.sched.stats()
    report["spill"] = {"spilled_pages": eng.n_spilled_pages,
                       "restored_pages": eng.n_restored_pages}
    eng.pool.check_invariants()
    tokens = {r.rid: list(r.tokens) for r in reqs if not r.rejected}
    return report, tokens


def run():
    cfg = TINY
    params = init_lm(cfg, jax.random.PRNGKey(0))
    trace = make_trace(cfg)

    fifo, fifo_toks = run_policy(cfg, params, trace, preempt=False)
    prio, prio_toks = run_policy(cfg, params, trace, preempt=True)
    common = set(fifo_toks) & set(prio_toks)
    assert common, "no request completed under both policies"
    for rid in common:
        assert fifo_toks[rid] == prio_toks[rid], \
            f"preemption changed greedy tokens of request {rid}"

    fi, pi = (r["classes"]["interactive"] for r in (fifo, prio))
    result = {
        "workload": {"n_requests": N_REQUESTS, "n_slots": N_SLOTS,
                     "n_pages": N_PAGES, "page_size": PAGE_SIZE,
                     "trace": "bursty", "seed": SEED,
                     "batch_max_new": BATCH_MAX_NEW,
                     "age_promote": AGE_PROMOTE},
        "fifo": fifo,
        "priority_preempt": prio,
        "interactive_ttft_p95_steps": {"fifo": fi["ttft_p95"],
                                       "priority_preempt": pi["ttft_p95"]},
        "interactive_ttft_p95_improvement":
            fi["ttft_p95"] / pi["ttft_p95"] if pi["ttft_p95"] else None,
        "goodput_tok_per_step": {
            "fifo": fifo["overall"]["goodput_tok_per_t"],
            "priority_preempt": prio["overall"]["goodput_tok_per_t"]},
        "tokens_identical_on_common_requests": len(common),
    }
    with open(OUT, "w") as f:
        json.dump(result, f, indent=2)
    print("fifo:")
    print(traffic.format_report(fifo))
    print("priority + preempt "
          f"({prio['scheduler']['n_preemptions']} preemptions, "
          f"{result['priority_preempt']['spill']['spilled_pages']} pages "
          "spilled):")
    print(traffic.format_report(prio))
    print(f"interactive ttft p95: {fi['ttft_p95']:.1f} -> {pi['ttft_p95']:.1f}"
          f" steps ({result['interactive_ttft_p95_improvement']:.2f}x)"
          f"  -> {OUT}")
    assert pi["ttft_p95"] < fi["ttft_p95"], \
        "priority scheduling failed to improve interactive TTFT p95"
    return result


if __name__ == "__main__":
    run()
