"""Shared benchmark fixtures: a trained tiny LM + synthetic corpus, cached on
disk so every paper-table benchmark reuses the same float baseline."""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TINY
from repro.checkpoint.store import load_tree, save_tree
from repro.data.pipeline import DataPipeline
from repro.data.synthetic import heldout_split, make_corpus, make_eval_sets
from repro.models.transformer import init_lm
from repro.optim.schedules import warmup_cosine
from repro.train.train_step import init_opt_state, make_train_step

CACHE = os.environ.get("REPRO_CACHE", "/root/repo/.cache")
TRAIN_STEPS = int(os.environ.get("REPRO_TINY_STEPS", "700"))


def get_corpus():
    corpus, meta = make_corpus(TINY.vocab_size, 200_000, seed=0)
    train_toks, held = heldout_split(corpus)
    evals = make_eval_sets(meta)
    return corpus, meta, train_toks, held, evals


def get_trained_tiny(verbose: bool = True):
    """Returns (cfg, params, corpus bundle). Trains + caches on first call."""
    cfg = TINY
    bundle = get_corpus()
    tag = f"{cfg.d_model}x{cfg.n_repeats}_{cfg.norm}"
    path = os.path.join(CACHE, f"tiny_lm_{tag}_{TRAIN_STEPS}")
    if os.path.isdir(path):
        params, _ = load_tree(path)
        return cfg, params, bundle
    _, _, train_toks, _, _ = bundle
    params = init_lm(cfg, jax.random.PRNGKey(0))
    pipe = DataPipeline(train_toks, batch_size=16, seq_len=64, seed=0)
    step_fn = make_train_step(
        cfg, lr_schedule=warmup_cosine(3e-3, 20, TRAIN_STEPS), clip_norm=1.0)
    opt = init_opt_state(cfg, params)
    rng = jax.random.PRNGKey(1)
    t0 = time.time()
    for s in range(TRAIN_STEPS):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
        params, opt, m = step_fn(params, opt, batch, jnp.asarray(s), rng)
        if verbose and s % 100 == 0:
            print(f"[tiny-lm] step {s} loss {float(m['loss']):.4f} "
                  f"({time.time() - t0:.0f}s)")
    save_tree(path, params, {"steps": TRAIN_STEPS})
    if verbose:
        print(f"[tiny-lm] trained {TRAIN_STEPS} steps in "
              f"{time.time() - t0:.0f}s -> {path}")
    return cfg, params, bundle
