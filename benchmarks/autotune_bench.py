"""Autotuner benchmark, recorded to BENCH_autotune.json.

Exercises the full REPRO_AUTOTUNE=1 machinery end to end on small shapes:
the measured candidate search (real ``pallas_call`` timings — interpret
mode on CPU, so the absolute numbers and win ratios are only meaningful on
TPU; what this records on CPU is the search cost and that the plumbing
selects, persists, and re-serves plans), the warm-cache resolution cost in
the default mode, and a tiny serving run proving a warm cache drives the
engine without recompiles or fallbacks.

Sections of the JSON:
  search   — per shape class: candidate count, search wall time, the table
             plan, the measured winner, and timed table-vs-winner us
  paged    — same for the page-walk tile at an oversized page size
  warm     — cache-hit resolution latency (us) vs the cold table lookup
  serve    — greedy tok/s of a tiny engine under the deterministic table
             vs a warm measured cache (same tokens asserted)
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import jax
import numpy as np

from repro.kernels import autotune, template

OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "BENCH_autotune.json")

# (kind, m, k, n, bits, group_size) — decode-skinny and prefill classes
# across the kernel families
SHAPES = [
    ("dequant", 8, 256, 256, 4, 64),
    ("dequant", 64, 512, 256, 4, 128),
    ("w8a8", 8, 256, 256, 8, -1),
    ("expert_dequant", 16, 256, 128, 2, 64),
]


def _timed_plan(kind, m, k, n, bits, gs, plan):
    kernel_fn = autotune._MEASURE_FNS[kind]()
    rng = np.random.default_rng(0)
    mb = max(autotune.m_bucket(m), 8)
    g = 1 if gs == -1 else k // gs
    pk = template.packed_tile_rows(k, bits)
    qw = rng.integers(0, 256, (pk, n)).astype(np.uint8)
    scale = rng.uniform(0.01, 0.1, (g, n)).astype(np.float32)
    if kind.endswith("w8a8"):
        x = rng.integers(-127, 128, (mb, k)).astype(np.int8)
    else:
        x = rng.normal(size=(mb, k)).astype(np.float32)
    if kind.startswith("expert_"):
        x, qw, scale = np.stack([x, x]), np.stack([qw, qw]), \
            np.stack([scale, scale])
    bm, bn, bk = plan
    pad = (-mb) % bm
    xp = np.pad(x, ((0, 0), (0, pad), (0, 0))
                if kind.startswith("expert_") else ((0, pad), (0, 0)))
    return autotune._time_candidate(lambda: kernel_fn(
        xp, qw, scale, bits=bits, group_size=gs, bm=bm, bn=bn, bk=bk,
        interpret=jax.default_backend() != "tpu"))


def _serve_tok_s(cache_path: str | None):
    from repro.configs import TINY
    from repro.models.transformer import init_lm
    from repro.serve.engine import ContinuousEngine

    if cache_path is None:
        os.environ["REPRO_AUTOTUNE"] = "0"
        os.environ.pop("REPRO_AUTOTUNE_CACHE", None)
    else:
        os.environ["REPRO_AUTOTUNE"] = ""
        os.environ["REPRO_AUTOTUNE_CACHE"] = cache_path
    autotune.reset()
    cfg = TINY.replace(n_repeats=2, d_model=64, head_dim=16, d_ff=128)
    params = init_lm(cfg, jax.random.PRNGKey(0))
    eng = ContinuousEngine(cfg, params, n_slots=3, max_len=64, page_size=16,
                           prefill_bucket=8, chunked_prefill=16)
    rng = np.random.default_rng(0)
    for i in range(6):
        eng.submit(rng.integers(0, cfg.vocab_size, 24), max_new=8,
                   arrival=float(i))
    t0 = time.time()
    done = eng.run(max_steps=2000)
    dt = time.time() - t0
    toks = {r.rid: r.tokens for r in done}
    return sum(len(t) for t in toks.values()) / dt, toks


def run(rows: list):
    out = {"template_version": template.TEMPLATE_VERSION,
           "backend": jax.default_backend(),
           "note": ("interpret-mode timings on CPU: search machinery and "
                    "cache behavior are what is measured; win ratios are "
                    "only meaningful on TPU"),
           "search": {}, "paged": {}, "warm": {}, "serve": {}}
    saved = {k: os.environ.get(k) for k in ("REPRO_AUTOTUNE",
                                            "REPRO_AUTOTUNE_CACHE")}
    tmp = tempfile.mkdtemp(prefix="repro_autotune_bench_")
    cache = os.path.join(tmp, "tune.json")
    try:
        os.environ["REPRO_AUTOTUNE"] = "1"
        os.environ["REPRO_AUTOTUNE_CACHE"] = cache
        autotune.reset()
        for kind, m, k, n, bits, gs in SHAPES:
            key = autotune.matmul_key(kind, m, k, n, bits, gs)
            table = autotune.fallback_matmul_plan(
                m, k, n, bits=bits, group_size=gs, bm=128, bn=256, bk=256)
            n_cands = len(autotune._matmul_candidates(m, k, n, bits, gs,
                                                      table))
            t0 = time.time()
            tuned = autotune.matmul_plan(kind, m, k, n, bits=bits,
                                         group_size=gs)
            search_s = time.time() - t0
            t_table = _timed_plan(kind, m, k, n, bits, gs, table)
            t_tuned = _timed_plan(kind, m, k, n, bits, gs, tuned)
            out["search"][key] = {
                "candidates": n_cands,
                "search_s": round(search_s, 3),
                "table_plan": list(table),
                "tuned_plan": list(tuned),
                "table_us": round(t_table * 1e6, 1),
                "tuned_us": round(t_tuned * 1e6, 1),
                "win": round(t_table / max(t_tuned, 1e-12), 3),
            }
            rows.append((f"autotune/search_{key}", search_s * 1e6,
                         f"candidates={n_cands};tuned={tuned};"
                         f"table={table}"))
        # paged tile search at an oversized page size (real candidates)
        t0 = time.time()
        tile = autotune.paged_tile(512, "bf16", 1)
        out["paged"]["paged:ps512:kvbf16:m8"] = {
            "search_s": round(time.time() - t0, 3),
            "table_tile": autotune.fallback_paged_tile(512),
            "tuned_tile": tile,
        }
        rows.append(("autotune/search_paged_ps512", (time.time() - t0) * 1e6,
                     f"tuned_tile={tile}"))

        # warm-cache resolution latency vs the deterministic table
        os.environ["REPRO_AUTOTUNE"] = ""
        autotune.reset()
        kind, m, k, n, bits, gs = SHAPES[0]
        reps = 200
        t0 = time.perf_counter()
        for _ in range(reps):
            autotune.matmul_plan(kind, m, k, n, bits=bits, group_size=gs)
        warm_us = (time.perf_counter() - t0) / reps * 1e6
        os.environ["REPRO_AUTOTUNE"] = "0"
        t0 = time.perf_counter()
        for _ in range(reps):
            autotune.matmul_plan(kind, m, k, n, bits=bits, group_size=gs)
        table_us = (time.perf_counter() - t0) / reps * 1e6
        out["warm"] = {"cache_hit_us": round(warm_us, 2),
                       "table_us": round(table_us, 2)}
        rows.append(("autotune/warm_cache_hit", warm_us,
                     f"table_us={table_us:.2f}"))

        # tiny serving run: table mode vs warm cache, same greedy tokens
        tok_table, toks_a = _serve_tok_s(None)
        tok_warm, toks_b = _serve_tok_s(cache)
        assert toks_a == toks_b, "warm autotune cache changed greedy tokens"
        out["serve"] = {"table_tok_s": round(tok_table, 1),
                        "warm_cache_tok_s": round(tok_warm, 1),
                        "tokens_identical": True}
        rows.append(("autotune/serve_warm_cache_tok_s", 1e6 / max(tok_warm,
                                                                  1e-9),
                     f"table_tok_s={tok_table:.1f};"
                     f"warm_tok_s={tok_warm:.1f};tokens_identical=True"))
    finally:
        for k_, v in saved.items():
            if v is None:
                os.environ.pop(k_, None)
            else:
                os.environ[k_] = v
        autotune.reset()
    with open(OUT, "w") as f:
        json.dump(out, f, indent=2)
    return rows


if __name__ == "__main__":
    out = []
    run(out)
    for r in out:
        print(",".join(str(x) for x in r))
